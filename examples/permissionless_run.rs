//! Permissionless participation showcase: heavy churn + a high adversary
//! rate, demonstrating that Gauntlet keeps the run healthy (paper §2.2,
//! §4.4, Appendix A).
//!
//! ```bash
//! cargo run --release --example permissionless_run -- \
//!     --artifacts artifacts/tiny --rounds 12 --adversarial 0.4
//! ```
//!
//! Prints per-round validator verdicts (who was selected, who was caught,
//! and why) and the participation summary.

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::runtime::Engine;
use covenant::train::{Schedule, Segment};
use covenant::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let artifacts = args.get_or("artifacts", "artifacts/tiny");
    let rounds = args.get_usize("rounds", 12)?;
    let adversarial = args.get_f64("adversarial", 0.4)?;
    let peers = args.get_usize("peers", 8)?;

    let eng = Engine::new(&artifacts)?;
    let h = eng.manifest().config.inner_steps;
    println!(
        "permissionless_run: {} rounds, target {} peers, {:.0}% of joiners adversarial",
        rounds,
        peers,
        adversarial * 100.0
    );

    let mut run = RunConfig::default();
    run.artifacts = artifacts.clone();
    run.max_contributors = peers.saturating_sub(2).max(2);
    run.target_active = peers;
    run.seed = 0x7EE5;
    let mut p = NetworkParams::quick(run, h, rounds);
    p.initial_peers = peers;
    p.churn.p_adversarial = adversarial;
    p.churn.p_leave = 0.08; // heavy churn
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1_000_000 }]);

    let mut net = Network::new(&eng, p)?;
    let mut adv_submitted_total = 0usize;
    let mut adv_selected_total = 0usize;
    let mut contributing_sum = 0usize;
    let mut active_sum = 0usize;
    for r in 0..rounds {
        let rep = net.run_round()?;
        adv_submitted_total += rep.adversarial_submitted;
        adv_selected_total += rep.adversarial_selected;
        contributing_sum += rep.contributing;
        active_sum += rep.active;
        println!(
            "round {r:>3}: active {:>2} submitted {:>2} selected {:>2} | adversarial submitted {:>2} selected {:>2} | loss {:.4}",
            rep.active,
            rep.submitted,
            rep.contributing,
            rep.adversarial_submitted,
            rep.adversarial_selected,
            rep.mean_loss,
        );
    }

    let filter_rate = if adv_submitted_total > 0 {
        100.0 * (1.0 - adv_selected_total as f64 / adv_submitted_total as f64)
    } else {
        100.0
    };
    println!("\n== summary ==");
    println!("mean active peers:       {:.1}", active_sum as f64 / rounds as f64);
    println!("mean contributing peers: {:.1}", contributing_sum as f64 / rounds as f64);
    println!(
        "adversarial submissions: {} ({} slipped through) -> {:.1}% filtered",
        adv_submitted_total, adv_selected_total, filter_rate
    );
    println!("unique peers ever seen:  {}", net.unique_peers_ever());
    println!(
        "final loss: {:.4} (ln V = {:.3})",
        net.recent_loss(3),
        (eng.manifest().config.vocab_size as f64).ln()
    );
    println!("permissionless_run OK");
    Ok(())
}
