//! Quickstart: the SparseLoCo protocol by hand, two peers, two rounds.
//!
//! ```bash
//! make artifacts                      # once
//! cargo run --release --example quickstart
//! ```
//!
//! Walks through the paper's core loop with the public API:
//! inner steps -> pseudo-gradient -> Top-k + 2-bit compression with error
//! feedback (Eq. 1) -> wire encode -> aggregate -> outer step (Eq. 2).

use anyhow::Result;
use covenant::data::grammar::GrammarKind;
use covenant::data::{BatchSampler, Grammar};
use covenant::runtime::{ops, Engine};
use covenant::sparseloco::{codec, Payload};
use covenant::train::Trainer;

fn main() -> Result<()> {
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts/tiny".to_string());
    let eng = Engine::new(&dir)?;
    let man = eng.manifest().clone();
    println!(
        "model '{}': {} params ({} chunks of {}), H={} inner steps",
        man.config.name, man.n_params, man.n_chunks, man.config.chunk, man.config.inner_steps
    );

    // Shared global model + per-peer data.
    let global = ops::init_params(&eng, 0)?;
    let grammar = Grammar::new(man.config.vocab_size, 1234);
    let h = man.config.inner_steps;
    let lrs = vec![2e-3f32; h];
    let beta = man.config.ef_beta as f32;

    let mut peers: Vec<(Trainer, BatchSampler, Vec<f32>)> = (0..2)
        .map(|i| {
            let stream = grammar.stream(GrammarKind::Web, i as u64, 40_000);
            let sampler = BatchSampler::new(
                stream,
                man.config.seq_len,
                man.config.batch_size,
                i as u64,
            );
            (
                Trainer::from_params(&eng, global.clone()),
                sampler,
                vec![0f32; man.n_alloc], // error-feedback buffer
            )
        })
        .collect();

    let mut global = global;
    for round in 0..2 {
        println!("\n== round {round} ==");
        let mut payloads: Vec<Payload> = Vec::new();
        for (i, (trainer, sampler, ef)) in peers.iter_mut().enumerate() {
            // --- compute phase: H inner AdamW steps --------------------
            let tokens = sampler.round_batch(h);
            let mask = sampler.ones_round_mask(h);
            let losses = trainer.round(&tokens, &mask, &lrs)?;
            // --- communication phase: compress pseudo-gradient ----------
            let delta: Vec<f32> = global
                .iter()
                .zip(&trainer.params)
                .map(|(g, l)| g - l)
                .collect();
            let (ef_new, payload) = ops::compress(&eng, &delta, ef, beta)?;
            *ef = ef_new;
            let wire = codec::encode(&payload);
            println!(
                "peer {i}: loss {:.3} -> {:.3} | payload {} KB ({:.1} bits/value, {:.0}x vs dense f32)",
                losses.first().unwrap(),
                losses.last().unwrap(),
                wire.len() / 1024,
                wire.len() as f64 * 8.0 / payload.n_values() as f64,
                (man.n_alloc * 4) as f64 / wire.len() as f64,
            );
            payloads.push(payload);
        }
        // --- aggregation + outer step (every peer computes the same) ----
        let refs: Vec<&Payload> = payloads.iter().collect();
        let delta = covenant::coordinator::aggregate(&refs, man.n_alloc)?;
        global = ops::outer_step(&eng, &global, &delta, 1.0)?;
        for (trainer, _, _) in peers.iter_mut() {
            trainer.set_params(global.clone());
        }
        println!("outer step applied; replicas synchronized");
    }

    // Held-out loss of the synced global model.
    let stream = grammar.stream(GrammarKind::Web, 999, 10_000);
    let mut sampler =
        BatchSampler::new(stream, man.config.seq_len, man.config.batch_size, 77);
    let loss = ops::eval_loss(&eng, &global, &sampler.batch(), &sampler.ones_mask())?;
    println!(
        "\nheld-out loss after 2 rounds: {loss:.3} (init would be ~ln V = {:.3})",
        (man.config.vocab_size as f64).ln()
    );
    println!("quickstart OK");
    Ok(())
}
