//! End-to-end driver: full permissionless pre-training on a real (small)
//! workload, proving all layers compose — Pallas kernels inside the AOT
//! HLO, the PJRT runtime, SparseLoCo compression, Gauntlet validation,
//! object-store comms, chain, churn.
//!
//! ```bash
//! make artifacts CONFIGS=tiny,small,base
//! cargo run --release --example e2e_pretrain -- \
//!     --artifacts artifacts/base --rounds 30 --peers 4 --out results/e2e
//! ```
//!
//! Logs the loss curve to `<out>/loss_curve.csv`, the round timeline to
//! `<out>/timeline.csv`, participation to `<out>/participation.csv`, and
//! runs the benchmark suites before/after (recorded in EXPERIMENTS.md).

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::data::Grammar;
use covenant::eval::Scorer;
use covenant::metrics::{self, timeline};
use covenant::runtime::Engine;
use covenant::train::{checkpoint, Schedule};
use covenant::util::cli::Args;
use covenant::util::stats::fmt_time;

fn main() -> Result<()> {
    let args = Args::parse();
    let artifacts = args.get_or("artifacts", "artifacts/small");
    let rounds = args.get_usize("rounds", 30)?;
    let peers = args.get_usize("peers", 4)?;
    let out = args.get_or("out", "results/e2e");
    let seed = args.get_u64("seed", 0xC0DE)?;
    let eval_tasks = args.get_usize("eval-tasks", 60)?;
    let lr_peak = args.get_f64("lr-peak", 3e-3)?;

    let eng = Engine::new(&artifacts)?;
    let man = eng.manifest().clone();
    let h = man.config.inner_steps;
    println!(
        "e2e_pretrain: config={} ({} params), {} rounds x {} peers, H={}",
        man.config.name, man.n_params, rounds, peers, h
    );

    let mut run = RunConfig::default();
    run.artifacts = artifacts.clone();
    run.rounds = rounds;
    run.max_contributors = peers;
    run.target_active = peers + 2;
    run.seed = seed;
    let mut p = NetworkParams::quick(run, h, rounds);
    p.initial_peers = peers;
    // Short-run schedule: same shape as the paper's Fig. 2, compressed to
    // this run's horizon, with a CPU-scale peak LR.
    let total_inner = (rounds * h) as f64;
    p.schedule = scale_lr(Schedule::covenant_pretrain_scaled(total_inner / 183_000.0), lr_peak / 1.2e-4);
    p.churn.p_adversarial = 0.15;
    // CPU-testbed fast path (verified equivalent to the Pallas kernel).
    p.rust_compress = !args.has_flag("xla-compress");

    // --- eval before -----------------------------------------------------
    let grammar = Grammar::new(man.config.vocab_size, seed ^ 0xDA7A); // matches NetworkParams::quick world_seed
    let scorer = Scorer::new(&eng);
    let mut net = Network::new(&eng, p)?;
    let before = scorer.run_all(&net.global_params, &grammar, eval_tasks, 1)?;

    // --- train -------------------------------------------------------------
    let t0 = std::time::Instant::now();
    let mut loss_rows: Vec<Vec<String>> = Vec::new();
    let mut part_rows: Vec<Vec<String>> = Vec::new();
    for r in 0..rounds {
        let rep = net.run_round()?;
        loss_rows.push(vec![
            r.to_string(),
            format!("{}", (r + 1) * h),
            format!("{:.5}", rep.mean_loss),
            format!("{:.4}", rep.outer_alpha),
        ]);
        part_rows.push(vec![
            r.to_string(),
            rep.active.to_string(),
            rep.submitted.to_string(),
            rep.contributing.to_string(),
            rep.adversarial_submitted.to_string(),
            rep.adversarial_selected.to_string(),
        ]);
        if r % 5 == 0 || r + 1 == rounds {
            println!(
                "round {r:>4}: loss {:.4} | active {} submitted {} contributing {} | t_comm {:.1}s util {:.1}% | wall {}",
                rep.mean_loss,
                rep.active,
                rep.submitted,
                rep.contributing,
                rep.t_comm(),
                100.0 * rep.utilization(),
                fmt_time(t0.elapsed().as_secs_f64()),
            );
        }
    }

    // --- eval after --------------------------------------------------------
    let after = scorer.run_all(&net.global_params, &grammar, eval_tasks, 1)?;
    println!("\n== benchmark suites (accuracy, 4 choices, chance=25%) ==");
    println!("{:<36} {:>8} {:>8}", "suite", "init", "trained");
    for (b, a) in before.iter().zip(&after) {
        println!(
            "{:<36} {:>7.1}% {:>7.1}%",
            b.suite.name(),
            100.0 * b.accuracy(),
            100.0 * a.accuracy()
        );
    }

    // --- emit artifacts ------------------------------------------------------
    metrics::write_csv(
        format!("{out}/loss_curve.csv"),
        "round,inner_step,mean_loss,outer_alpha",
        &loss_rows,
    )?;
    metrics::write_csv(
        format!("{out}/participation.csv"),
        "round,active,submitted,contributing,adversarial_submitted,adversarial_selected",
        &part_rows,
    )?;
    let rows = timeline::rows(&net.reports);
    std::fs::write(format!("{out}/timeline.csv"), timeline::to_csv(&rows))?;
    checkpoint::save(format!("{out}/final.ckpt"), &net.global_params)?;

    let losses: Vec<f64> = net.reports.iter().map(|r| r.mean_loss).collect();
    println!("\nloss curve: {}", metrics::sparkline(&losses));
    println!(
        "loss {:.4} -> {:.4} (ln V = {:.3}) | mean util {:.1}% | unique peers ever: {}",
        losses.first().unwrap(),
        losses.last().unwrap(),
        (man.config.vocab_size as f64).ln(),
        100.0 * timeline::mean_utilization(&rows),
        net.unique_peers_ever(),
    );
    println!("wrote {out}/loss_curve.csv, participation.csv, timeline.csv, final.ckpt");
    println!("e2e_pretrain OK ({} wall)", fmt_time(t0.elapsed().as_secs_f64()));
    Ok(())
}

/// Scale every LR in a schedule by `f` (keeps the Fig. 2 shape, adapts the
/// magnitude to the small model).
fn scale_lr(s: Schedule, f: f64) -> Schedule {
    use covenant::train::Segment;
    Schedule::new(
        s.segments
            .into_iter()
            .map(|seg| match seg {
                Segment::Linear { from, to, steps } => {
                    Segment::Linear { from: from * f, to: to * f, steps }
                }
                Segment::Cosine { from, to, steps } => {
                    Segment::Cosine { from: from * f, to: to * f, steps }
                }
                Segment::Constant { lr, steps } => {
                    Segment::Constant { lr: lr * f, steps }
                }
            })
            .collect(),
    )
}
