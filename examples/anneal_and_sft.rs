//! Annealing + two-stage SFT pipeline (paper §4.1 annealing, §5 SFT,
//! Tables 2 & 3 analogues).
//!
//! ```bash
//! cargo run --release --example anneal_and_sft -- \
//!     --artifacts artifacts/tiny --pretrain-rounds 20 --out results/sft
//! ```
//!
//! 1. quick SparseLoCo pre-training on the web mixture (or load
//!    --checkpoint from e2e_pretrain),
//! 2. *anneal*: short high-quality-mixture phase (Table 3 before/after),
//! 3. *SFT stage 1*: instruction data, answer-masked loss,
//! 4. *SFT stage 2*: continued with 20% pre-training replay,
//! 5. evals after every phase.

#![allow(clippy::field_reassign_with_default)]

use anyhow::Result;
use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::data::grammar::{GrammarKind, AMARK, QMARK};
use covenant::data::{BatchSampler, Grammar};
use covenant::eval::{Scorer, SuiteResult};
use covenant::runtime::Engine;
use covenant::train::{checkpoint, Schedule, Segment, Trainer};
use covenant::util::cli::Args;
use covenant::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse();
    let artifacts = args.get_or("artifacts", "artifacts/tiny");
    let pre_rounds = args.get_usize("pretrain-rounds", 20)?;
    let anneal_steps = args.get_usize("anneal-steps", 40)?;
    let sft1_steps = args.get_usize("sft1-steps", 60)?;
    let sft2_steps = args.get_usize("sft2-steps", 40)?;
    let eval_tasks = args.get_usize("eval-tasks", 60)?;
    let out = args.get_or("out", "results/sft");
    let ckpt = args.get("checkpoint").map(|s| s.to_string());

    let eng = Engine::new(&artifacts)?;
    let man = eng.manifest().clone();
    let h = man.config.inner_steps;
    let grammar = Grammar::new(man.config.vocab_size, 0xC0DE ^ 0xDA7A); // == quick(run.seed=0xC0DE) world
    let scorer = Scorer::new(&eng);

    // ---- phase 0: pre-train (or load) ------------------------------------
    let base_params = match ckpt {
        Some(path) => {
            println!("loading checkpoint {path}");
            checkpoint::load(path)?
        }
        None => {
            println!("pre-training {pre_rounds} rounds on the web mixture...");
            let mut run = RunConfig::default();
            run.artifacts = artifacts.clone();
            run.max_contributors = 4;
            run.target_active = 5;
            run.seed = 0xC0DE;
            let mut p = NetworkParams::quick(run, h, pre_rounds);
            p.initial_peers = 4;
            p.schedule =
                Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1_000_000 }]);
            let mut net = Network::new(&eng, p)?;
            for r in 0..pre_rounds {
                let rep = net.run_round()?;
                if r % 5 == 0 {
                    println!("  round {r}: loss {:.4}", rep.mean_loss);
                }
            }
            net.global_params.clone()
        }
    };
    let eval_pre = scorer.run_all(&base_params, &grammar, eval_tasks, 1)?;

    // ---- phase 1: anneal on the high-quality mixture (Table 3) ----------
    println!("\nannealing {anneal_steps} steps on the high-quality mixture...");
    let mut tr = Trainer::from_params(&eng, base_params.clone());
    let hq = grammar.stream(GrammarKind::HighQuality, 42, 200_000);
    // 25% replay of natural web text, mirroring the paper's anneal blend.
    let replay = grammar.stream(GrammarKind::Web, 43, 70_000);
    let mut blend = hq;
    blend.extend(replay);
    let mut sampler = BatchSampler::new(blend, man.config.seq_len, man.config.batch_size, 7);
    // rapid warmup + decay (the Fig. 2 anneal tail shape)
    let anneal_sched = Schedule::new(vec![
        Segment::Linear { from: 1e-4, to: 1e-3, steps: anneal_steps / 8 },
        Segment::Cosine { from: 1e-3, to: 1e-5, steps: anneal_steps - anneal_steps / 8 },
    ]);
    for s in 0..anneal_steps {
        tr.step(&sampler.batch(), &sampler.ones_mask(), anneal_sched.lr(s) as f32)?;
    }
    let annealed = tr.params.clone();
    let eval_anneal = scorer.run_all(&annealed, &grammar, eval_tasks, 1)?;

    // ---- phase 2: SFT stage 1 (instruction data, answer-masked) ----------
    println!("SFT stage 1: {sft1_steps} steps on instruction data (answer-masked loss, clip=1.0)...");
    let mut sft = Trainer::from_params(&eng, annealed.clone());
    sft.clip = 1.0; // paper §5: gradient clipping at 1.0
    sft.reset_optimizer();
    let sched1 = Schedule::sft_stage1_scaled(sft1_steps as f64 / 36_500.0);
    let mut rng = Rng::new(0x5F7);
    for s in 0..sft1_steps {
        let (tokens, mask) = instruction_batch(&grammar, &man, &mut rng, 0.0);
        // SFT LRs are tiny at paper scale; scale up for the small model.
        let lr = (sched1.lr(s) * 200.0) as f32;
        sft.step(&tokens, &mask, lr)?;
    }
    let eval_sft1 = scorer.run_all(&sft.params, &grammar, eval_tasks, 1)?;

    // ---- phase 3: SFT stage 2 (20% pre-training replay) -------------------
    println!("SFT stage 2: {sft2_steps} steps with 20% replay...");
    let sched2 = Schedule::sft_stage2_scaled(sft2_steps as f64 / 20_500.0);
    for s in 0..sft2_steps {
        let (tokens, mask) = instruction_batch(&grammar, &man, &mut rng, 0.2);
        let lr = (sched2.lr(s) * 200.0) as f32;
        sft.step(&tokens, &mask, lr)?;
    }
    let eval_sft2 = scorer.run_all(&sft.params, &grammar, eval_tasks, 1)?;

    // ---- report (Tables 2/3 analogue) -------------------------------------
    println!("\n== accuracy by phase (4 choices, chance=25%) ==");
    println!(
        "{:<36} {:>9} {:>9} {:>9} {:>9}",
        "suite", "pre", "anneal", "sft-1", "sft-2"
    );
    let rows = |r: &[SuiteResult]| -> Vec<f64> { r.iter().map(|x| x.accuracy()).collect() };
    let (a, b, c, d) = (rows(&eval_pre), rows(&eval_anneal), rows(&eval_sft1), rows(&eval_sft2));
    for i in 0..eval_pre.len() {
        println!(
            "{:<36} {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}%",
            eval_pre[i].suite.name(),
            100.0 * a[i],
            100.0 * b[i],
            100.0 * c[i],
            100.0 * d[i]
        );
    }
    checkpoint::save(format!("{out}/covenant-chat.ckpt"), &sft.params)?;
    println!("\nwrote {out}/covenant-chat.ckpt");
    println!("anneal_and_sft OK");
    Ok(())
}

/// An instruction-formatted batch with the loss masked to the answer
/// token (the paper masks non-answer content), with `replay_frac` of rows
/// drawn from the natural web mixture (full-sequence loss).
fn instruction_batch(
    grammar: &Grammar,
    man: &covenant::runtime::Manifest,
    rng: &mut Rng,
    replay_frac: f64,
) -> (Vec<i32>, Vec<f32>) {
    let b = man.config.batch_size;
    let t = man.config.seq_len;
    let mut tokens = Vec::with_capacity(b * (t + 1));
    let mut mask = vec![0f32; b * t];
    for row in 0..b {
        if rng.f64() < replay_frac {
            let stream = grammar.stream(GrammarKind::Web, rng.next_u64(), t + 64);
            tokens.extend_from_slice(&stream[..t + 1]);
            for j in 0..t {
                mask[row * t + j] = 1.0;
            }
        } else {
            let stream = grammar.stream(GrammarKind::Instruction, rng.next_u64(), t + 64);
            tokens.extend_from_slice(&stream[..t + 1]);
            // mask only answer positions: target index j predicts token
            // j+1; we want positions where token j+1 follows AMARK.
            for j in 0..t {
                if stream[j] == AMARK {
                    mask[row * t + j] = 1.0;
                }
                // also keep a small LM signal on question starts
                if stream[j + 1] == QMARK {
                    mask[row * t + j] = 0.1;
                }
            }
        }
    }
    (tokens, mask)
}
