"""L2 — AdamW inner optimizer (paper §4.1) over the flat parameter vector.

Bias-corrected Adam with decoupled weight decay; decay applies only to
2-D tensors (mask from the layout). Optional global-norm gradient
clipping (used by the SFT stage, clip=1.0; disabled with clip<=0).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import model


def clip_by_global_norm(g: jax.Array, clip: jax.Array) -> jax.Array:
    """Scale g so ||g|| <= clip; no-op when clip <= 0."""
    norm = jnp.sqrt(jnp.sum(jnp.square(g)))
    scale = jnp.where(
        clip > 0.0, jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12)), 1.0
    )
    return g * scale


def adamw_step(params, grads, m, v, step, lr, clip, cfg: ModelConfig, wd_mask):
    """One AdamW step. ``step`` is the 1-based step index (f32 scalar).

    Returns (params', m', v').
    """
    g = clip_by_global_norm(grads, clip)
    b1 = cfg.adam_b1
    b2 = cfg.adam_b2
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * jnp.square(g)
    mh = m2 / (1.0 - b1**step)
    vh = v2 / (1.0 - b2**step)
    upd = mh / (jnp.sqrt(vh) + cfg.adam_eps) + cfg.weight_decay * wd_mask * params
    return params - lr * upd, m2, v2


def train_step(params, m, v, step, tokens, loss_mask, lr, clip, cfg: ModelConfig):
    """fwd/bwd + AdamW for one inner step.

    tokens: [B, T+1] i32; loss_mask: [B, T] f32; step/lr/clip: f32 scalars.
    Returns (params', m', v', loss).
    """
    wd_mask = model.decay_mask(model.build_layout(cfg))
    loss, grads = jax.value_and_grad(model.loss_fn)(params, tokens, loss_mask, cfg)
    params2, m2, v2 = adamw_step(params, grads, m, v, step, lr, clip, cfg, wd_mask)
    return params2, m2, v2, loss


def train_round(params, m, v, step0, tokens, loss_mask, lrs, clip, cfg: ModelConfig):
    """H inner steps as one fused graph (lax.scan) — the compute phase.

    tokens: [H, B, T+1] i32; loss_mask: [H, B, T] f32; lrs: [H] f32;
    step0: f32 scalar (0-based global inner-step count before this round).
    Returns (params', m', v', losses [H]).

    One call per round means one host<->device round-trip per compute
    window instead of per step (DESIGN §Perf L2/L3).
    """
    wd_mask = model.decay_mask(model.build_layout(cfg))

    def body(carry, xs):
        p, m_, v_, s = carry
        toks, mask, lr = xs
        loss, grads = jax.value_and_grad(model.loss_fn)(p, toks, mask, cfg)
        p2, m2, v2 = adamw_step(p, grads, m_, v_, s + 1.0, lr, clip, cfg, wd_mask)
        return (p2, m2, v2, s + 1.0), loss

    (p, m2, v2, _), losses = jax.lax.scan(
        body, (params, m, v, step0), (tokens, loss_mask, lrs)
    )
    return p, m2, v2, losses
