"""AOT: lower every L2 graph to HLO *text* + a manifest for the Rust runtime.

Run once per config (``make artifacts``):

    cd python && python -m compile.aot --config tiny --out ../artifacts/tiny

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per config (all f32 unless noted; Na = padded flat param length,
nc = Na/4096 chunks, k = 64, B/T/H from the config):

  init_params  (seed i32)                                   -> (params[Na])
  train_step   (params,m,v, step, tokens[B,T+1]i32,
                mask[B,T], lr, clip)                        -> (params',m',v', loss)
  train_round  (params,m,v, step0, tokens[H,B,T+1]i32,
                mask[H,B,T], lrs[H], clip)                  -> (params',m',v', losses[H])
  compress     (delta[Na], ef[Na], beta)                    -> (ef'[Na], idx[nc,k]i32,
                                                                codes[nc,k]i32, scales[nc,1])
  decompress   (idx, codes, scales)                         -> (dense[Na])
  outer_step   (params[Na], delta[Na], alpha)               -> (params')
  eval_loss    (params, tokens[B,T+1]i32, mask[B,T])        -> (loss)
  loss_per_seq (params, tokens[B,T+1]i32, mask[B,T])        -> (losses[B])
"""

import argparse
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import get_config, build_layout, PRESETS, asdict
from . import model, optim, sparseloco


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(cfg):
    """Returns {name: (fn, example_args)} for one config."""
    lay = build_layout(cfg)
    na = lay.n_alloc
    nc = lay.n_chunks
    k = cfg.topk
    b, t, h = cfg.batch_size, cfg.seq_len, cfg.inner_steps
    f32 = jnp.float32
    i32 = jnp.int32
    scalar = _spec((), f32)

    arts = {
        "init_params": (
            lambda seed: (model.init_params(seed, cfg),),
            [_spec((), i32)],
        ),
        "train_step": (
            lambda p, m, v, s, tok, msk, lr, cl: optim.train_step(
                p, m, v, s, tok, msk, lr, cl, cfg
            ),
            [
                _spec((na,)), _spec((na,)), _spec((na,)), scalar,
                _spec((b, t + 1), i32), _spec((b, t)), scalar, scalar,
            ],
        ),
        "train_round": (
            lambda p, m, v, s, tok, msk, lrs, cl: optim.train_round(
                p, m, v, s, tok, msk, lrs, cl, cfg
            ),
            [
                _spec((na,)), _spec((na,)), _spec((na,)), scalar,
                _spec((h, b, t + 1), i32), _spec((h, b, t)), _spec((h,)), scalar,
            ],
        ),
        "compress": (
            lambda d, ef, beta: sparseloco.compress(d, ef, beta, cfg),
            [_spec((na,)), _spec((na,)), scalar],
        ),
        "decompress": (
            lambda idx, codes, scales: (sparseloco.decompress(idx, codes, scales, cfg),),
            [_spec((nc, k), i32), _spec((nc, k), i32), _spec((nc, 1))],
        ),
        "outer_step": (
            lambda p, d, a: (sparseloco.outer_step(p, d, a),),
            [_spec((na,)), _spec((na,)), scalar],
        ),
        "eval_loss": (
            lambda p, tok, msk: (model.loss_fn(p, tok, msk, cfg),),
            [_spec((na,)), _spec((b, t + 1), i32), _spec((b, t))],
        ),
        "loss_per_seq": (
            lambda p, tok, msk: (model.loss_per_seq(p, tok, msk, cfg),),
            [_spec((na,)), _spec((b, t + 1), i32), _spec((b, t))],
        ),
    }
    return arts


def _dt(s: jax.ShapeDtypeStruct) -> str:
    return {"float32": "f32", "int32": "i32"}[str(s.dtype)]


def compile_config(name: str, out_dir: Path, only=None) -> dict:
    cfg = get_config(name)
    lay = build_layout(cfg)
    out_dir.mkdir(parents=True, exist_ok=True)
    arts = build_artifacts(cfg)
    manifest = {
        "config": asdict(cfg),
        "n_params": lay.n_params,
        "n_alloc": lay.n_alloc,
        "n_chunks": lay.n_chunks,
        "tensors": [
            {
                "name": s.name, "shape": list(s.shape), "offset": s.offset,
                "size": s.size, "slot": s.slot, "is_2d": s.is_2d,
                "decay": s.decay,
            }
            for s in lay.slots
        ],
        "artifacts": {},
    }
    for art_name, (fn, args) in arts.items():
        if only and art_name not in only:
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{art_name}.hlo.txt"
        (out_dir / fname).write_text(text)
        outs = lowered.out_info
        out_list = jax.tree_util.tree_leaves(outs)
        manifest["artifacts"][art_name] = {
            "file": fname,
            "inputs": [{"shape": list(a.shape), "dtype": _dt(a)} for a in args],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dt(o)} for o in out_list
            ],
        }
        print(
            f"  {name}/{art_name}: {len(text)/1e6:.2f} MB HLO text "
            f"({time.time()-t0:.1f}s)"
        )
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="tiny,small",
                    help=f"comma-separated preset names from {sorted(PRESETS)}")
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts root (per-config subdirs)")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names (default: all)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    root = Path(args.out)
    for name in args.config.split(","):
        name = name.strip()
        print(f"[aot] lowering config '{name}' -> {root / name}")
        compile_config(name, root / name, only=only)
    print("[aot] done")


if __name__ == "__main__":
    main()
