"""Build-time-only Python package: L2 JAX model + L1 Pallas kernels + AOT.

Nothing here runs at request time — ``aot.py`` lowers everything to HLO
text once (``make artifacts``), and the Rust coordinator is self-contained
afterwards.
"""
