"""L2 — LLaMA-3-style decoder-only transformer over a flat parameter vector.

Architecture (paper §4.1, Table 4): GQA, RoPE (theta=500k), RMSNorm,
SwiGLU, tied token-embedding/LM-head. All parameters live in one flat f32
vector with the chunk-aligned, 64x64-block-major layout of ``configs.py``
so SparseLoCo compression is a plain reshape and Rust handles exactly one
buffer per state (params / m / v / error-feedback).

The forward calls the L1 Pallas kernels (rmsnorm, gqa_attention); their
backward passes are jax.vjp of the jnp references (remat policy).
"""

from typing import Dict

import jax
import jax.numpy as jnp

from .configs import ModelConfig, Layout, build_layout, BLOCK
from .kernels.rmsnorm import rmsnorm
from .kernels.attention import gqa_attention


# --------------------------------------------------------------------------
# Flat-vector <-> named-tensor (block-major layout)
# --------------------------------------------------------------------------
def to_block_major(t: jax.Array) -> jax.Array:
    """Flatten a tensor into its stored order.

    2-D [R, C] (R, C multiples of 64) -> 64x64 blocks, block-row-major,
    each block row-major. 1-D -> identity.
    """
    if t.ndim == 1:
        return t
    r, c = t.shape
    return (
        t.reshape(r // BLOCK, BLOCK, c // BLOCK, BLOCK)
        .transpose(0, 2, 1, 3)
        .reshape(-1)
    )


def from_block_major(flat: jax.Array, shape) -> jax.Array:
    """Inverse of :func:`to_block_major`."""
    if len(shape) == 1:
        return flat.reshape(shape)
    r, c = shape
    return (
        flat.reshape(r // BLOCK, c // BLOCK, BLOCK, BLOCK)
        .transpose(0, 2, 1, 3)
        .reshape(r, c)
    )


def unflatten(flat: jax.Array, lay: Layout) -> Dict[str, jax.Array]:
    """Slice the flat vector into named tensors (undoing block-major)."""
    out = {}
    for s in lay.slots:
        raw = jax.lax.dynamic_slice_in_dim(flat, s.offset, s.size)
        out[s.name] = from_block_major(raw, s.shape)
    return out


def flatten(tensors: Dict[str, jax.Array], lay: Layout) -> jax.Array:
    """Pack named tensors into the flat vector (block-major + slot pad)."""
    parts = []
    for s in lay.slots:
        t = to_block_major(tensors[s.name].astype(jnp.float32))
        if s.slot > s.size:
            t = jnp.concatenate([t, jnp.zeros(s.slot - s.size, jnp.float32)])
        parts.append(t)
    return jnp.concatenate(parts)


def decay_mask(lay: Layout) -> jax.Array:
    """1.0 where weight decay applies (2-D tensors), 0.0 elsewhere
    (norm gains and slot padding). Built from broadcasts so the lowered
    HLO stays small (no giant literal)."""
    parts = []
    for s in lay.slots:
        parts.append(jnp.full((s.size,), 1.0 if s.decay else 0.0, jnp.float32))
        if s.slot > s.size:
            parts.append(jnp.zeros(s.slot - s.size, jnp.float32))
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------
def init_params(seed: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Deterministic init from an int32 seed -> flat param vector.

    N(0, init_std) for 2-D tensors, with the residual-output projections
    (wo, w_down) scaled by 1/sqrt(2*n_layers) (GPT-2/LLaMA practice);
    norm gains init to 1.
    """
    lay = build_layout(cfg)
    key = jax.random.PRNGKey(seed)
    tensors = {}
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for s in lay.slots:
        key, sub = jax.random.split(key)
        if not s.is_2d:
            tensors[s.name] = jnp.ones(s.shape, jnp.float32)
            continue
        std = cfg.init_std
        t = jax.random.normal(sub, s.shape, jnp.float32) * std
        if s.name.endswith("wo") or s.name.endswith("w_down"):
            t = t * resid_scale
        tensors[s.name] = t
    return flatten(tensors, lay)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_cos_sin(t: int, dh: int, theta: float):
    """cos/sin tables [T, dh/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    pos = jnp.arange(t, dtype=jnp.float32)
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, n, T, dh] -> rotated pairs (x0, x1) convention."""
    x0 = x[..., 0::2]
    x1 = x[..., 1::2]
    r0 = x0 * cos - x1 * sin
    r1 = x0 * sin + x1 * cos
    # Interleave back.
    return jnp.stack([r0, r1], axis=-1).reshape(x.shape)


# --------------------------------------------------------------------------
# Forward + loss
# --------------------------------------------------------------------------
def forward_logits(flat_params: jax.Array, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens: [B, T] int32 -> logits [B, T, V]."""
    lay = build_layout(cfg)
    p = unflatten(flat_params, lay)
    b, t = tokens.shape
    x = p["embed"][tokens]                                  # [B, T, D]
    cos, sin = rope_cos_sin(t, cfg.d_head, cfg.rope_theta)  # [T, dh/2]
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        h = rmsnorm(x.reshape(b * t, cfg.d_model), p[pre + "attn_norm"], cfg.norm_eps)
        h = h.reshape(b, t, cfg.d_model)
        q = (h @ p[pre + "wq"]).reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        k = (h @ p[pre + "wk"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
        v = (h @ p[pre + "wv"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        a = gqa_attention(q, k, v)                          # [B, H, T, dh]
        a = a.transpose(0, 2, 1, 3).reshape(b, t, cfg.q_dim)
        x = x + a @ p[pre + "wo"]
        h2 = rmsnorm(x.reshape(b * t, cfg.d_model), p[pre + "mlp_norm"], cfg.norm_eps)
        h2 = h2.reshape(b, t, cfg.d_model)
        gate = jax.nn.silu(h2 @ p[pre + "w_gate"]) * (h2 @ p[pre + "w_up"])
        x = x + gate @ p[pre + "w_down"]
    x = rmsnorm(x.reshape(b * t, cfg.d_model), p["final_norm"], cfg.norm_eps)
    head = p["lm_head"] if cfg.untie_embeddings else p["embed"]
    return (x @ head.T).reshape(b, t, cfg.vocab_size)


def loss_per_seq(flat_params: jax.Array, tokens: jax.Array, loss_mask: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    """Masked mean next-token cross-entropy per sequence.

    tokens: [B, T+1] int32; loss_mask: [B, T] f32 over *target* positions.
    Returns [B] f32 (nats).
    """
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits = forward_logits(flat_params, inp, cfg)          # [B, T, V]
    lse = jax.nn.logsumexp(logits, axis=-1)                 # [B, T]
    tl = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = lse - tl                                           # [B, T]
    denom = jnp.maximum(jnp.sum(loss_mask, axis=1), 1e-6)
    return jnp.sum(ce * loss_mask, axis=1) / denom


def loss_fn(flat_params: jax.Array, tokens: jax.Array, loss_mask: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    """Masked mean cross-entropy over the whole batch (scalar, nats)."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits = forward_logits(flat_params, inp, cfg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tl = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = lse - tl
    return jnp.sum(ce * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1e-6)
