"""Causal GQA attention as a Pallas kernel.

TPU mapping (DESIGN §Hardware-Adaptation): the paper's GPU attention
tiles over threadblocks with shared-memory staging; here the BlockSpec
grid is (batch, query-head, query-block). Each grid step keeps one
(Tq, dh) query tile plus the full (T, dh) K and V panels of the *shared
KV head* in VMEM — GQA means H/KV query heads reuse the same K/V panel,
which the index_map expresses directly (h -> h // group), so the HBM->VMEM
traffic for K/V is amortized across the group exactly like the paper's
shared-memory reuse. The two matmuls are MXU-shaped ((Tq,dh)x(dh,T) and
(Tq,T)x(T,dh)); VMEM footprint per step is
  Tq*dh + 2*T*dh + Tq*T floats  (~1.3 MiB at T=2048, dh=128, Tq=128).

interpret=True on this CPU testbed (lowers to plain HLO; real-TPU would
emit a Mosaic custom-call the CPU PJRT client cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .common import row_block

_TARGET_TQ = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, *, tq: int):
    q = q_ref[0, 0]          # [tq, dh]
    k = k_ref[0, 0]          # [T, dh]
    v = v_ref[0, 0]          # [T, dh]
    t, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.dot(q, k.T) * scale                       # [tq, T]
    row = pl.program_id(2) * tq + jax.lax.iota(jnp.int32, tq)
    col = jax.lax.iota(jnp.int32, t)
    mask = col[None, :] <= row[:, None]
    s = jnp.where(mask, s, jnp.asarray(-1e30, s.dtype))
    # Numerically-stable softmax over the key axis.
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v)


def gqa_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """q: [B,H,T,dh]; k,v: [B,KV,T,dh] -> [B,H,T,dh] (causal)."""
    b, h, t, dh = q.shape
    kv = k.shape[1]
    group = h // kv
    tq = row_block(t, _TARGET_TQ)
    kv_spec = pl.BlockSpec((1, 1, t, dh), lambda bi, hi, qi: (bi, hi // group, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, tq=tq),
        grid=(b, h, t // tq),
        in_specs=[
            pl.BlockSpec((1, 1, tq, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, tq, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, k, v)


# --------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, jax.vjp-of-reference backward
# (flash-style remat: the backward recomputes attention probabilities from
# q,k,v instead of materializing the [B,H,T,T] tensor in residuals).
# --------------------------------------------------------------------------
@jax.custom_vjp
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    return gqa_attention_pallas(q, k, v)


def _fwd(q, k, v):
    return gqa_attention_pallas(q, k, v), (q, k, v)


def _bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(ref.gqa_attention, q, k, v)
    return vjp(g)


gqa_attention.defvjp(_fwd, _bwd)
