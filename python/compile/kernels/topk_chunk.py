"""Fused chunk-wise Top-k + 2-bit quantize + error-feedback payload kernel.

This is the paper's communication hot-spot (SparseLoCo Eq. 1): for every
4096-element chunk of the (block-major) flat pseudo-gradient accumulator,
select the Top-k=64 entries by magnitude, quantize them to 2 bits with a
per-chunk max-abs scale, and emit both the wire payload (indices, codes,
scales) and the dense dequantized "transmitted" tensor that the
error-feedback update subtracts (ef' = acc - transmitted).

TPU mapping (DESIGN §Hardware-Adaptation): the paper's GPU implementation
assigns chunks to threadblocks; here the grid tiles chunk rows, with each
step holding a (rows_block, 4096) tile in VMEM (1 MiB at rows_block=64).
Top-k, quantization and the scatter are all VPU work fused into a single
HBM read/write pass per chunk — the dense accumulator is touched exactly
once, which is what makes the communication phase cheap relative to the
compute window (paper §4.3).

interpret=True on this CPU testbed (lowers to plain HLO).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .common import row_block

_TARGET_ROWS = 64


def _kernel(x_ref, idx_ref, code_ref, scale_ref, trans_ref, *, k: int):
    x = x_ref[...]                                     # [br, C]
    br, _ = x.shape
    # argsort (-> HLO `sort`) instead of lax.top_k: the TopK op's
    # `largest=` attribute is rejected by the 0.5.1 HLO-text parser.
    idx = jnp.argsort(-jnp.abs(x), axis=-1)[..., :k]   # [br, k]
    vals = jnp.take_along_axis(x, idx, axis=1)
    scales = jnp.max(jnp.abs(vals), axis=1, keepdims=True)
    xq = vals / jnp.maximum(scales, 1e-12)
    codes = jnp.where(
        xq < -2.0 / 3.0, 0, jnp.where(xq < 0.0, 1, jnp.where(xq < 2.0 / 3.0, 2, 3))
    )
    deq = ref.levels(codes) * scales
    rows = jnp.arange(br)[:, None]
    idx_ref[...] = idx.astype(jnp.int32)
    code_ref[...] = codes.astype(jnp.int32)
    scale_ref[...] = scales
    trans_ref[...] = jnp.zeros_like(x).at[rows, idx].set(deq)


def compress_chunks_pallas(chunks: jax.Array, k: int):
    """chunks: [nc, C] f32 -> (idx [nc,k] i32, codes [nc,k] i32,
    scales [nc,1] f32, transmitted [nc,C] f32)."""
    nc, c = chunks.shape
    br = row_block(nc, _TARGET_ROWS)
    grid = (nc // br,)
    row_spec = lambda cols: pl.BlockSpec((br, cols), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[row_spec(c)],
        out_specs=(row_spec(k), row_spec(k), row_spec(1), row_spec(c)),
        out_shape=(
            jax.ShapeDtypeStruct((nc, k), jnp.int32),
            jax.ShapeDtypeStruct((nc, k), jnp.int32),
            jax.ShapeDtypeStruct((nc, 1), jnp.float32),
            jax.ShapeDtypeStruct((nc, c), jnp.float32),
        ),
        interpret=True,
    )(chunks)
