"""Shared helpers for the Pallas kernels."""


def row_block(rows: int, target: int) -> int:
    """Largest divisor of ``rows`` that is <= ``target``.

    Pallas grids must tile the array exactly; all our row counts (B*T,
    n_chunks, ...) are highly composite, so an exact divisor close to the
    VMEM-friendly target always exists.
    """
    if rows <= target:
        return rows
    best = 1
    for d in range(1, target + 1):
        if rows % d == 0:
            best = d
    return best
