"""Fused RMSNorm as a Pallas kernel.

TPU mapping (DESIGN §Hardware-Adaptation): the grid tiles rows (tokens) so
each step holds a (rows_block, D) tile plus the (D,) gain in VMEM; the
reduction and scale are VPU element-wise work fused into one pass over the
tile (one HBM read + one write per element instead of the 3 passes of the
unfused mean/rsqrt/mul chain).

interpret=True everywhere on this CPU testbed — the kernel lowers to plain
HLO so the AOT artifacts run on the PJRT CPU client (see README gotchas).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .common import row_block

# VMEM budget: rows_block * D * 4B * ~3 live tiles <= ~2 MiB at D=8192.
_TARGET_ROWS = 64


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w_ref[...]


def rmsnorm_pallas(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """x: [rows, D]; w: [D] -> [rows, D]."""
    rows, d = x.shape
    br = row_block(rows, _TARGET_ROWS)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, w)


# --------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, jax.vjp-of-reference backward
# (remat policy: backward recomputes the cheap normalization instead of
# saving rsqrt residuals — see DESIGN §Perf L2).
# --------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    return rmsnorm_pallas(x, w, eps)


def _fwd(x, w, eps):
    return rmsnorm_pallas(x, w, eps), (x, w)


def _bwd(eps, res, g):
    x, w = res
    _, vjp = jax.vjp(lambda x_, w_: ref.rmsnorm(x_, w_, eps), x, w)
    return vjp(g)


rmsnorm.defvjp(_fwd, _bwd)
