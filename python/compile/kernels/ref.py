"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest compares each kernel's
output against its oracle with ``assert_allclose`` (including hypothesis
shape/dtype sweeps), and the model's custom-VJP backward passes are the
``jax.vjp`` of these references (remat-style recompute — see DESIGN §Perf).
"""

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """y = x / rms(x) * w along the last axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


# --------------------------------------------------------------------------
# GQA causal attention
# --------------------------------------------------------------------------
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal grouped-query attention.

    q: [B, H, T, dh]; k, v: [B, KV, T, dh]; H % KV == 0.
    Returns [B, H, T, dh].
    """
    b, h, t, dh = q.shape
    kv = k.shape[1]
    group = h // kv
    # Expand KV heads to query heads.
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(mask[None, None, :, :], s, jnp.asarray(-1e30, s.dtype))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


# --------------------------------------------------------------------------
# 2-bit symmetric quantization
# --------------------------------------------------------------------------
# Codebook: code c in {0,1,2,3} -> level (c * 2/3 - 1) in
# {-1, -1/3, +1/3, +1}, times the per-chunk scale. Decision thresholds at
# {-2/3, 0, +2/3} * scale. The arithmetic form (instead of a lookup table)
# is used so the Pallas kernels need no captured constants and kernel/ref
# agree bit-for-bit.
def levels(codes: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * jnp.float32(2.0 / 3.0) - 1.0


def quantize2bit(vals: jax.Array, scale: jax.Array) -> jax.Array:
    """vals: [..., k]; scale: [..., 1] (max-abs per chunk). Returns int32 codes."""
    x = vals / jnp.maximum(scale, 1e-12)
    c = jnp.where(x < -2.0 / 3.0, 0, jnp.where(x < 0.0, 1, jnp.where(x < 2.0 / 3.0, 2, 3)))
    return c.astype(jnp.int32)


def dequantize2bit(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of quantize2bit (up to quantization error)."""
    return levels(codes) * scale


# --------------------------------------------------------------------------
# Chunk-wise Top-k compression (SparseLoCo Eq. 1 compression operator)
# --------------------------------------------------------------------------
def topk_abs_indices(x: jax.Array, k: int) -> jax.Array:
    """Indices of the k largest |values| along the last axis (desc order).

    Implemented with argsort rather than ``jax.lax.top_k``: the TopK HLO op
    grew a ``largest=`` attribute in recent XLA that the 0.5.1 HLO-text
    parser used by the Rust loader rejects; ``sort`` round-trips cleanly.
    """
    return jnp.argsort(-jnp.abs(x), axis=-1)[..., :k].astype(jnp.int32)


def compress_chunks(chunks: jax.Array, k: int):
    """Per-chunk Top-k by |value| + 2-bit quantization.

    chunks: [nc, C] f32.
    Returns (idx [nc,k] i32, codes [nc,k] i32, scales [nc,1] f32,
             transmitted [nc, C] f32) where ``transmitted`` is the dense
    dequantized payload (what every peer will reconstruct), used for the
    error-feedback update ef' = acc - transmitted.
    """
    nc, _ = chunks.shape
    idx = topk_abs_indices(chunks, k)                     # [nc, k]
    vals = jnp.take_along_axis(chunks, idx, axis=1)       # [nc, k]
    scales = jnp.max(jnp.abs(vals), axis=1, keepdims=True)  # [nc, 1]
    codes = quantize2bit(vals, scales)
    deq = dequantize2bit(codes, scales)
    rows = jnp.arange(nc)[:, None]
    transmitted = jnp.zeros_like(chunks).at[rows, idx].set(deq)
    return idx.astype(jnp.int32), codes, scales, transmitted


def decompress_chunks(idx: jax.Array, codes: jax.Array, scales: jax.Array,
                      chunk: int) -> jax.Array:
    """Scatter dequantized values back to dense [nc, C]."""
    nc = idx.shape[0]
    deq = dequantize2bit(codes, scales)
    rows = jnp.arange(nc)[:, None]
    dense = jnp.zeros((nc, chunk), dtype=jnp.float32)
    return dense.at[rows, idx].set(deq)
