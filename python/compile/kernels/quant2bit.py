"""2-bit symmetric quantization of transmitted Top-k values (Pallas).

The paper quantizes the Top-k-selected pseudo-gradient values to 2 bits
per value (§2.1, §4.1), with indices encoded at 12 bits/value, for a
total >146x compression vs dense f32. The codebook here is symmetric
4-level: {-1, -1/3, +1/3, +1} * scale with scale = per-chunk max-|v|.

TPU mapping: pure VPU element-wise work; grid tiles rows of the (n, k)
value matrix so each step holds one (rows_block, k) tile in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .common import row_block

_TARGET_ROWS = 256


def _quant_kernel(v_ref, s_ref, o_ref):
    x = v_ref[...] / jnp.maximum(s_ref[...], 1e-12)
    c = jnp.where(x < -2.0 / 3.0, 0, jnp.where(x < 0.0, 1, jnp.where(x < 2.0 / 3.0, 2, 3)))
    o_ref[...] = c.astype(jnp.int32)


def _dequant_kernel(c_ref, s_ref, o_ref):
    o_ref[...] = ref.levels(c_ref[...]) * s_ref[...]


def quantize2bit_pallas(vals: jax.Array, scales: jax.Array) -> jax.Array:
    """vals: [n, k]; scales: [n, 1] -> int32 codes [n, k]."""
    n, k = vals.shape
    br = row_block(n, _TARGET_ROWS)
    return pl.pallas_call(
        _quant_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.int32),
        interpret=True,
    )(vals, scales)


def dequantize2bit_pallas(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """codes: [n, k] int32; scales: [n, 1] -> f32 values [n, k]."""
    n, k = codes.shape
    br = row_block(n, _TARGET_ROWS)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(codes, scales)
