"""L1 — Pallas kernels for the paper's compute hot-spots.

- ``topk_chunk``: fused chunk-wise Top-k + 2-bit quant + EF payload
  (SparseLoCo's compression operator — the communication hot-spot).
- ``quant2bit``: standalone 2-bit quantize/dequantize.
- ``rmsnorm``: fused RMSNorm used by every transformer block.
- ``attention``: causal GQA attention.
- ``ref``: pure-jnp oracles for all of the above.

All kernels run with interpret=True so the AOT HLO executes on the CPU
PJRT client; TPU performance is estimated analytically (DESIGN §Perf).
"""

from . import ref  # noqa: F401
from .rmsnorm import rmsnorm, rmsnorm_pallas  # noqa: F401
from .attention import gqa_attention, gqa_attention_pallas  # noqa: F401
from .quant2bit import quantize2bit_pallas, dequantize2bit_pallas  # noqa: F401
from .topk_chunk import compress_chunks_pallas  # noqa: F401
