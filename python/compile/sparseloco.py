"""L2 — SparseLoCo pseudo-gradient compression + outer step (paper §2.1).

The flat parameter layout makes the paper's chunking a single reshape:
contiguous 4096-element chunks are exactly 64x64 blocks for 2-D tensors
(block-major storage) and contiguous runs for 1-D tensors. The fused L1
Pallas kernel does Top-k + 2-bit quant + the dense transmitted tensor in
one pass; this module wires it to the error-feedback recursion:

    acc   = beta * ef + delta
    (idx, codes, scales, transmitted) = TopK+Q(acc)      # kernel
    ef'   = acc - transmitted                            # Eq. 1

and the outer update theta' = theta - alpha * mean_r(decompress(payload_r))
(Eq. 2 — the mean and median-norm scaling happen in Rust where individual
peer payloads live; the dense-delta apply is this graph).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.topk_chunk import compress_chunks_pallas
from .kernels.quant2bit import dequantize2bit_pallas


def compress(delta: jax.Array, ef: jax.Array, beta: jax.Array, cfg: ModelConfig):
    """SparseLoCo compression with error feedback.

    delta, ef: [Na] f32 (Na a multiple of cfg.chunk); beta: f32 scalar.
    Returns (ef_new [Na], idx [nc,k] i32, codes [nc,k] i32, scales [nc,1]).
    """
    acc = beta * ef + delta
    chunks = acc.reshape(-1, cfg.chunk)
    nc = chunks.shape[0]
    # Pad chunk rows to a multiple of 64 so the Pallas grid uses a large
    # row block (a ragged row count like 3085 = 5*617 would force a
    # 5-row block and 617 grid steps — ~6x slower; see EXPERIMENTS §Perf).
    pad = (-nc) % 64
    if pad:
        chunks = jnp.concatenate(
            [chunks, jnp.zeros((pad, cfg.chunk), jnp.float32)], axis=0
        )
    idx, codes, scales, transmitted = compress_chunks_pallas(chunks, cfg.topk)
    if pad:
        idx = idx[:nc]
        codes = codes[:nc]
        scales = scales[:nc]
        transmitted = transmitted[:nc]
    ef_new = acc - transmitted.reshape(-1)
    return ef_new, idx, codes, scales


def decompress(idx: jax.Array, codes: jax.Array, scales: jax.Array,
               cfg: ModelConfig) -> jax.Array:
    """Payload -> dense flat delta [Na] (what every peer reconstructs)."""
    nc = idx.shape[0]
    deq = dequantize2bit_pallas(codes, scales)          # [nc, k]
    rows = jnp.arange(nc)[:, None]
    dense = jnp.zeros((nc, cfg.chunk), jnp.float32).at[rows, idx].set(deq)
    return dense.reshape(-1)


def outer_step(params: jax.Array, delta_mean: jax.Array, alpha: jax.Array) -> jax.Array:
    """theta' = theta - alpha * mean-aggregated pseudo-gradient (Eq. 2)."""
    return params - alpha * delta_mean
