"""Model configurations and the flat, chunk-aligned parameter layout.

Covenant models are LLaMA-3-style decoder-only transformers (GQA, RoPE,
RMSNorm, SwiGLU, tied embeddings).  All parameters live in a single flat
f32 vector so that the Rust coordinator handles exactly one parameter
buffer per replica, and so that SparseLoCo's chunk-wise Top-k compression
is a plain ``reshape(-1, CHUNK)`` over that vector:

* every tensor's allocation is padded to a multiple of ``CHUNK`` (4096),
  so chunks never straddle tensors;
* 2-D tensors are stored in 64x64 *block-major* order, which makes each
  contiguous 4096-element chunk of the flat vector exactly one 64x64
  block of the matrix — the paper's 2-D chunking (SparseLoCo §2.1);
* 1-D tensors (norm gains) are stored contiguously, giving the paper's
  1-D chunking with chunk size 4096 (zero-padded tail).

The same layout metadata is exported to ``manifest.json`` for Rust.
"""

from dataclasses import dataclass, asdict, field
from typing import List, Tuple
import math

CHUNK = 4096          # SparseLoCo chunk size (= 64*64 block)
BLOCK = 64            # 2-D block edge
TOPK = 64             # values kept per chunk
INDEX_BITS = 12       # wire bits per index (paper: 12 bits/value overhead)
VALUE_BITS = 2        # 2-bit quantization of transmitted values


@dataclass
class ModelConfig:
    """Architecture + training-shape configuration for one artifact set."""

    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    seq_len: int          # training context length T (tokens input is T+1)
    batch_size: int       # per-peer inner-step batch
    inner_steps: int      # H — inner steps per outer round (train_round)
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    init_std: float = 0.02
    # AdamW (paper §4.1)
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.1
    # SparseLoCo (paper §2.1/§4.1)
    ef_beta: float = 0.95
    topk: int = TOPK
    chunk: int = CHUNK
    # The paper states tied embeddings (§4.1), but the published Table-4
    # parameter count (72,747,327,488) is only consistent with untied
    # input/output embedding accounting (see EXPERIMENTS.md T4): with
    # d_ff=28672 untied accounting lands within 0.0015%.
    untie_embeddings: bool = False

    def __post_init__(self):
        assert self.d_model % BLOCK == 0, "d_model must be a multiple of 64"
        assert self.vocab_size % BLOCK == 0, "vocab must be a multiple of 64"
        assert self.d_ff % BLOCK == 0, "d_ff must be a multiple of 64"
        assert (self.n_heads * self.d_head) % BLOCK == 0
        assert (self.n_kv_heads * self.d_head) % BLOCK == 0
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head


# (name, shape, is_2d, wd) — wd: participates in weight decay
def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], bool]]:
    """Ordered parameter spec. Order defines the flat layout."""
    spec: List[Tuple[str, Tuple[int, ...], bool]] = []
    spec.append(("embed", (cfg.vocab_size, cfg.d_model), True))
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        spec.append((p + "attn_norm", (cfg.d_model,), False))
        spec.append((p + "wq", (cfg.d_model, cfg.q_dim), True))
        spec.append((p + "wk", (cfg.d_model, cfg.kv_dim), True))
        spec.append((p + "wv", (cfg.d_model, cfg.kv_dim), True))
        spec.append((p + "wo", (cfg.q_dim, cfg.d_model), True))
        spec.append((p + "mlp_norm", (cfg.d_model,), False))
        spec.append((p + "w_gate", (cfg.d_model, cfg.d_ff), True))
        spec.append((p + "w_up", (cfg.d_model, cfg.d_ff), True))
        spec.append((p + "w_down", (cfg.d_ff, cfg.d_model), True))
    spec.append(("final_norm", (cfg.d_model,), False))
    if cfg.untie_embeddings:
        spec.append(("lm_head", (cfg.vocab_size, cfg.d_model), True))
    return spec


@dataclass
class TensorSlot:
    name: str
    shape: Tuple[int, ...]
    offset: int     # start in the flat vector
    size: int       # prod(shape)
    slot: int       # padded allocation (multiple of CHUNK)
    is_2d: bool
    decay: bool     # weight decay applies


@dataclass
class Layout:
    slots: List[TensorSlot] = field(default_factory=list)
    n_params: int = 0     # true parameter count
    n_alloc: int = 0      # padded flat length (multiple of CHUNK)

    @property
    def n_chunks(self) -> int:
        return self.n_alloc // CHUNK

    def by_name(self, name: str) -> TensorSlot:
        for s in self.slots:
            if s.name == name:
                return s
        raise KeyError(name)


def build_layout(cfg: ModelConfig) -> Layout:
    lay = Layout()
    off = 0
    for name, shape, is_2d in param_spec(cfg):
        size = math.prod(shape)
        slot = ((size + CHUNK - 1) // CHUNK) * CHUNK
        # Norm gains don't get weight decay; everything 2-D (incl. the tied
        # embedding) does — standard LLaMA practice and the paper's AdamW.
        lay.slots.append(
            TensorSlot(name, tuple(shape), off, size, slot, is_2d, is_2d)
        )
        off += slot
        lay.n_params += size
    lay.n_alloc = off
    return lay


def count_params(cfg: ModelConfig) -> int:
    return build_layout(cfg).n_params


# ---------------------------------------------------------------------------
# Presets.  `covenant-72b` is the paper's exact configuration (Table 4) and
# exists for the config/param-count reproduction; it is never AOT-compiled
# here.  The small presets keep the identical architecture family at CPU
# scale (see DESIGN.md substitutions).
# ---------------------------------------------------------------------------
PRESETS = {
    # Test config: sub-second artifacts, used by pytest + cargo test.
    "tiny": ModelConfig(
        name="tiny", vocab_size=512, d_model=128, n_layers=2,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=320,
        seq_len=32, batch_size=4, inner_steps=4,
    ),
    # Bench/e2e config (~4M params): fast rounds on one CPU core.
    "small": ModelConfig(
        name="small", vocab_size=4096, d_model=256, n_layers=4,
        n_heads=8, n_kv_heads=2, d_head=32, d_ff=704,
        seq_len=128, batch_size=4, inner_steps=10,
    ),
    # Recorded e2e run (~13M params).
    "base": ModelConfig(
        name="base", vocab_size=8192, d_model=384, n_layers=6,
        n_heads=6, n_kv_heads=2, d_head=64, d_ff=1024,
        seq_len=128, batch_size=4, inner_steps=10,
    ),
    # ~90M-param config, built on demand (make artifacts CONFIGS=m100).
    "m100": ModelConfig(
        name="m100", vocab_size=16384, d_model=768, n_layers=12,
        n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048,
        seq_len=256, batch_size=4, inner_steps=10,
    ),
    # The paper's model (Table 4): 72,747,327,488 parameters. Config-only,
    # never AOT-compiled here; used for param counting + Fig.3 payload
    # sizing at true 72B scale.
    "covenant-72b": ModelConfig(
        name="covenant-72b", vocab_size=262_208, d_model=8192, n_layers=80,
        n_heads=64, n_kv_heads=8, d_head=128, d_ff=28_672,
        seq_len=2048, batch_size=192, inner_steps=30,
        untie_embeddings=True,
    ),
}


def get_config(name: str) -> ModelConfig:
    return PRESETS[name]
