import os
import sys

# Make `compile` importable when pytest runs from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

# Deterministic, CPU-only.
jax.config.update("jax_platform_name", "cpu")
