"""L2 model tests: layout/flatten round-trips, forward shapes, loss
sanity, gradient flow, RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import get_config, build_layout, CHUNK
from compile import model


CFG = get_config("tiny")
LAY = build_layout(CFG)


def key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# Layout / flatten
# ---------------------------------------------------------------------------
def test_layout_totals():
    assert LAY.n_alloc % CHUNK == 0
    assert LAY.n_params <= LAY.n_alloc
    assert LAY.n_chunks == LAY.n_alloc // CHUNK


def test_layout_offsets_chunk_aligned():
    for s in LAY.slots:
        assert s.offset % CHUNK == 0
        assert s.slot % CHUNK == 0


def test_block_major_roundtrip_2d():
    t = jax.random.normal(key(1), (128, 320))
    flat = model.to_block_major(t)
    back = model.from_block_major(flat, (128, 320))
    np.testing.assert_array_equal(t, back)


def test_block_major_is_blockwise():
    # First 4096 elements of a block-major 2-D tensor == first 64x64 block.
    t = jax.random.normal(key(2), (128, 128))
    flat = model.to_block_major(t)
    np.testing.assert_array_equal(
        np.asarray(flat[:4096]).reshape(64, 64), np.asarray(t[:64, :64])
    )


def test_flatten_unflatten_roundtrip():
    tensors = {}
    for s in LAY.slots:
        tensors[s.name] = jax.random.normal(key(hash(s.name) % 2**31), s.shape)
    flat = model.flatten(tensors, LAY)
    assert flat.shape == (LAY.n_alloc,)
    back = model.unflatten(flat, LAY)
    for s in LAY.slots:
        np.testing.assert_array_equal(tensors[s.name], back[s.name])


def test_decay_mask_padding_zero():
    mask = model.decay_mask(LAY)
    assert mask.shape == (LAY.n_alloc,)
    m = np.asarray(mask)
    for s in LAY.slots:
        seg = m[s.offset : s.offset + s.slot]
        # padding is 0
        np.testing.assert_array_equal(seg[s.size :], 0.0)
        np.testing.assert_array_equal(seg[: s.size], 1.0 if s.decay else 0.0)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def test_init_deterministic_and_seed_sensitive():
    p0 = model.init_params(jnp.int32(0), CFG)
    p0b = model.init_params(jnp.int32(0), CFG)
    p1 = model.init_params(jnp.int32(1), CFG)
    np.testing.assert_array_equal(p0, p0b)
    assert float(jnp.max(jnp.abs(p0 - p1))) > 0


def test_init_norms_are_one_padding_zero():
    p = np.asarray(model.init_params(jnp.int32(0), CFG))
    for s in LAY.slots:
        seg = p[s.offset : s.offset + s.slot]
        np.testing.assert_array_equal(seg[s.size :], 0.0)
        if not s.is_2d:
            np.testing.assert_array_equal(seg[: s.size], 1.0)


def test_init_std_approx():
    p = model.init_params(jnp.int32(0), CFG)
    emb = model.unflatten(p, LAY)["embed"]
    std = float(jnp.std(emb))
    assert abs(std - CFG.init_std) < 0.15 * CFG.init_std


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def test_rope_preserves_norm():
    cos, sin = model.rope_cos_sin(16, 32, 500_000.0)
    x = jax.random.normal(key(3), (2, 4, 16, 32))
    y = model.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
    )


def test_rope_position_zero_identity():
    cos, sin = model.rope_cos_sin(8, 16, 500_000.0)
    x = jax.random.normal(key(4), (1, 1, 8, 16))
    y = model.apply_rope(x, cos, sin)
    np.testing.assert_allclose(y[:, :, 0], x[:, :, 0], rtol=1e-6)


def test_rope_relative_property():
    # <rope(q,m), rope(k,n)> depends only on m-n: shift both by 1.
    t, dh = 8, 16
    cos, sin = model.rope_cos_sin(t, dh, 500_000.0)
    q = jax.random.normal(key(5), (1, 1, t, dh))
    k = jax.random.normal(key(6), (1, 1, t, dh))
    rq = model.apply_rope(q, cos, sin)[0, 0]
    rk = model.apply_rope(k, cos, sin)[0, 0]
    # score(m=2,n=1) with originals at positions 2,1 == score(3,2) when the
    # same unrotated vectors are placed at 3,2.
    q2 = jnp.zeros_like(q).at[0, 0, 2].set(q[0, 0, 2])
    q3 = jnp.zeros_like(q).at[0, 0, 3].set(q[0, 0, 2])
    k1 = jnp.zeros_like(k).at[0, 0, 1].set(k[0, 0, 1])
    k2 = jnp.zeros_like(k).at[0, 0, 2].set(k[0, 0, 1])
    s_a = jnp.dot(model.apply_rope(q2, cos, sin)[0, 0, 2], model.apply_rope(k1, cos, sin)[0, 0, 1])
    s_b = jnp.dot(model.apply_rope(q3, cos, sin)[0, 0, 3], model.apply_rope(k2, cos, sin)[0, 0, 2])
    np.testing.assert_allclose(s_a, s_b, rtol=1e-4)
    _ = (rq, rk)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------
def test_forward_shapes():
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(7), (2, CFG.seq_len), 0, CFG.vocab_size)
    logits = model.forward_logits(p, tok, CFG)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab_size)


def test_init_loss_close_to_uniform():
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(8), (4, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((4, CFG.seq_len))
    loss = float(model.loss_fn(p, tok, mask, CFG))
    assert abs(loss - np.log(CFG.vocab_size)) < 0.5


def test_loss_mask_zero_positions_ignored():
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(9), (2, CFG.seq_len + 1), 0, CFG.vocab_size)
    half = jnp.concatenate(
        [jnp.ones((2, CFG.seq_len // 2)), jnp.zeros((2, CFG.seq_len // 2))], axis=1
    )
    # Changing targets in the masked-out half must not change the loss.
    tok2 = tok.at[:, CFG.seq_len // 2 + 1 :].set(
        (tok[:, CFG.seq_len // 2 + 1 :] + 7) % CFG.vocab_size
    )
    l1 = float(model.loss_fn(p, tok, half, CFG))
    l2 = float(model.loss_fn(p, tok2, half, CFG))
    assert abs(l1 - l2) < 1e-5


def test_loss_per_seq_matches_scalar_loss():
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(10), (4, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((4, CFG.seq_len))
    per = model.loss_per_seq(p, tok, mask, CFG)
    total = model.loss_fn(p, tok, mask, CFG)
    np.testing.assert_allclose(jnp.mean(per), total, rtol=1e-5)


def test_gradients_flow_to_all_tensors():
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(11), (2, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((2, CFG.seq_len))
    g = jax.grad(model.loss_fn)(p, tok, mask, CFG)
    gt = model.unflatten(g, LAY)
    for s in LAY.slots:
        assert float(jnp.max(jnp.abs(gt[s.name]))) > 0, f"zero grad for {s.name}"


def test_gradient_zero_on_padding():
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(12), (2, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((2, CFG.seq_len))
    g = np.asarray(jax.grad(model.loss_fn)(p, tok, mask, CFG))
    for s in LAY.slots:
        np.testing.assert_array_equal(g[s.offset + s.size : s.offset + s.slot], 0.0)


@given(b=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_loss_finite_hypothesis(b, seed):
    p = model.init_params(jnp.int32(seed % 100), CFG)
    tok = jax.random.randint(key(seed), (b, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((b, CFG.seq_len))
    loss = float(model.loss_fn(p, tok, mask, CFG))
    assert np.isfinite(loss)


# ---------------------------------------------------------------------------
# Config presets
# ---------------------------------------------------------------------------
def test_covenant72b_param_count():
    cfg = get_config("covenant-72b")
    lay = build_layout(cfg)
    target = 72_747_327_488
    rel = abs(lay.n_params - target) / target
    assert rel < 2e-5, f"{lay.n_params} vs {target}"


@pytest.mark.parametrize("name", ["tiny", "small", "base", "m100"])
def test_presets_buildable(name):
    cfg = get_config(name)
    lay = build_layout(cfg)
    assert lay.n_params > 0
    assert lay.n_alloc % CHUNK == 0
