"""AdamW inner-optimizer tests: hand-computed step, weight-decay masking,
clipping, train_step/train_round consistency."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.configs import get_config, build_layout
from compile import model, optim


CFG = get_config("tiny")
LAY = build_layout(CFG)


def key(i=0):
    return jax.random.PRNGKey(i)


def test_adamw_first_step_hand_computed():
    # On step 1 with m=v=0: m_hat = g, v_hat = g^2
    # -> update = g/(|g|+eps) + wd*mask*p.
    n = 8
    p = jnp.asarray([1.0, -2.0, 0.5, 0.0, 3.0, -1.0, 2.0, -0.5])
    g = jnp.asarray([0.1, -0.2, 0.3, 0.0, -0.1, 0.2, -0.3, 0.4])
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    mask = jnp.ones(n)
    lr = jnp.float32(0.1)
    p2, m2, v2 = optim.adamw_step(p, g, m, v, jnp.float32(1.0), lr, jnp.float32(0.0), CFG, mask)
    sign = np.sign(np.asarray(g))
    expected = np.asarray(p) - 0.1 * (
        np.asarray(g) / (np.abs(np.asarray(g)) + CFG.adam_eps)
        + CFG.weight_decay * np.asarray(p)
    )
    # positions with g=0: update is wd only
    np.testing.assert_allclose(p2, expected, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m2, (1 - CFG.adam_b1) * g, rtol=1e-6)
    np.testing.assert_allclose(v2, (1 - CFG.adam_b2) * g * g, rtol=1e-6)
    _ = sign


def test_weight_decay_masked_out_for_norms():
    p = model.init_params(jnp.int32(0), CFG)
    g = jnp.zeros_like(p)
    mask = model.decay_mask(LAY)
    p2, _, _ = optim.adamw_step(p, g, jnp.zeros_like(p), jnp.zeros_like(p),
                                jnp.float32(1.0), jnp.float32(0.1), jnp.float32(0.0), CFG, mask)
    t2 = model.unflatten(p2, LAY)
    t1 = model.unflatten(p, LAY)
    for s in LAY.slots:
        if s.is_2d:
            # decayed towards zero
            assert float(jnp.max(jnp.abs(t2[s.name]))) < float(jnp.max(jnp.abs(t1[s.name]))) + 1e-9
        else:
            np.testing.assert_allclose(t2[s.name], t1[s.name], rtol=1e-6)


def test_clip_by_global_norm():
    g = jnp.asarray([3.0, 4.0])  # norm 5
    np.testing.assert_allclose(optim.clip_by_global_norm(g, jnp.float32(1.0)),
                               g / 5.0, rtol=1e-6)
    # clip larger than norm: unchanged
    np.testing.assert_allclose(optim.clip_by_global_norm(g, jnp.float32(10.0)), g, rtol=1e-6)
    # disabled
    np.testing.assert_allclose(optim.clip_by_global_norm(g, jnp.float32(0.0)), g, rtol=1e-6)
    np.testing.assert_allclose(optim.clip_by_global_norm(g, jnp.float32(-1.0)), g, rtol=1e-6)


def test_train_step_reduces_loss():
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(1), (CFG.batch_size, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((CFG.batch_size, CFG.seq_len))
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    loss0 = float(model.loss_fn(p, tok, mask, CFG))
    for step in range(3):
        p, m, v, _ = optim.train_step(p, m, v, jnp.float32(step + 1), tok, mask,
                                      jnp.float32(3e-3), jnp.float32(0.0), CFG)
    loss1 = float(model.loss_fn(p, tok, mask, CFG))
    assert loss1 < loss0 - 0.1, f"{loss0} -> {loss1}"


def test_train_round_equals_sequential_steps():
    h = CFG.inner_steps
    p = model.init_params(jnp.int32(0), CFG)
    tok = jax.random.randint(key(2), (h, CFG.batch_size, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((h, CFG.batch_size, CFG.seq_len))
    lrs = jnp.full((h,), 1e-3)
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    pr, mr, vr, losses = optim.train_round(p, m, v, jnp.float32(0.0), tok, mask, lrs,
                                           jnp.float32(0.0), CFG)
    # sequential
    ps, ms, vs = p, m, v
    seq_losses = []
    for i in range(h):
        ps, ms, vs, li = optim.train_step(ps, ms, vs, jnp.float32(i + 1), tok[i], mask[i],
                                          jnp.float32(1e-3), jnp.float32(0.0), CFG)
        seq_losses.append(float(li))
    # scan vs unrolled reassociates float ops; agreement is ~1e-5 absolute.
    np.testing.assert_allclose(pr, ps, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(losses), np.asarray(seq_losses), rtol=2e-4, atol=1e-5)


def test_losses_monotone_on_repeated_batch():
    # Same batch every step: loss should drop monotonically (small lr).
    h = 4
    p = model.init_params(jnp.int32(0), CFG)
    one = jax.random.randint(key(3), (CFG.batch_size, CFG.seq_len + 1), 0, CFG.vocab_size)
    tok = jnp.broadcast_to(one, (h,) + one.shape)
    mask = jnp.ones((h, CFG.batch_size, CFG.seq_len))
    lrs = jnp.full((h,), 2e-3)
    _, _, _, losses = optim.train_round(p, jnp.zeros_like(p), jnp.zeros_like(p),
                                        jnp.float32(0.0), tok, mask, lrs, jnp.float32(0.0), CFG)
    ls = np.asarray(losses)
    assert (np.diff(ls) < 0).all(), ls


@given(lr=st.sampled_from([1e-4, 1e-3, 5e-3]), seed=st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_train_step_finite_hypothesis(lr, seed):
    p = model.init_params(jnp.int32(seed), CFG)
    tok = jax.random.randint(key(seed), (CFG.batch_size, CFG.seq_len + 1), 0, CFG.vocab_size)
    mask = jnp.ones((CFG.batch_size, CFG.seq_len))
    p2, m2, v2, loss = optim.train_step(p, jnp.zeros_like(p), jnp.zeros_like(p),
                                        jnp.float32(1.0), tok, mask, jnp.float32(lr),
                                        jnp.float32(1.0), CFG)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(p2)).all()
