"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
with hypothesis sweeps over shapes/k/values (the CORE correctness signal
for the compression hot-spot)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rmsnorm import rmsnorm, rmsnorm_pallas
from compile.kernels.attention import gqa_attention, gqa_attention_pallas
from compile.kernels.quant2bit import quantize2bit_pallas, dequantize2bit_pallas
from compile.kernels.topk_chunk import compress_chunks_pallas
from compile.kernels.common import row_block


def key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# row_block
# ---------------------------------------------------------------------------
@given(rows=st.integers(1, 4096), target=st.integers(1, 256))
@settings(max_examples=200, deadline=None)
def test_row_block_divides(rows, target):
    b = row_block(rows, target)
    assert rows % b == 0
    assert 1 <= b <= max(rows, 1)
    assert b <= target or rows <= target


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows,d", [(1, 64), (64, 128), (96, 320), (128, 256)])
def test_rmsnorm_matches_ref(rows, d):
    x = jax.random.normal(key(1), (rows, d))
    w = jax.random.normal(key(2), (d,)) + 1.0
    np.testing.assert_allclose(
        rmsnorm_pallas(x, w), ref.rmsnorm(x, w), rtol=1e-5, atol=1e-6
    )


def test_rmsnorm_scale_invariance_of_direction():
    # rmsnorm(c*x) == rmsnorm(x) up to eps effects for c>0.
    x = jax.random.normal(key(3), (8, 128))
    w = jnp.ones((128,))
    a = rmsnorm_pallas(x, w)
    b = rmsnorm_pallas(4.0 * x, w)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_rmsnorm_grad_matches_ref_grad():
    x = jax.random.normal(key(4), (16, 64))
    w = jax.random.normal(key(5), (64,)) + 1.0

    def f_kernel(x, w):
        return jnp.sum(jnp.sin(rmsnorm(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(ref.rmsnorm(x, w)))

    gk = jax.grad(f_kernel, argnums=(0, 1))(x, w)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@given(
    rows=st.sampled_from([2, 4, 8, 32, 96]),
    d=st.sampled_from([64, 128, 320]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_rmsnorm_hypothesis_sweep(rows, d, seed):
    x = jax.random.normal(key(seed), (rows, d)) * 3.0
    w = jax.random.normal(key(seed + 1), (d,))
    np.testing.assert_allclose(
        rmsnorm_pallas(x, w), ref.rmsnorm(x, w), rtol=2e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,h,kv,t,dh", [(1, 2, 1, 16, 8), (2, 4, 2, 32, 16), (1, 8, 2, 64, 32), (2, 6, 2, 128, 64)]
)
def test_attention_matches_ref(b, h, kv, t, dh):
    q = jax.random.normal(key(10), (b, h, t, dh))
    k = jax.random.normal(key(11), (b, kv, t, dh))
    v = jax.random.normal(key(12), (b, kv, t, dh))
    np.testing.assert_allclose(
        gqa_attention_pallas(q, k, v), ref.gqa_attention(q, k, v), rtol=2e-5, atol=2e-5
    )


def test_attention_is_causal():
    # Output at position i must not depend on inputs at positions > i.
    b, h, kv, t, dh = 1, 2, 1, 32, 16
    q = jax.random.normal(key(13), (b, h, t, dh))
    k = jax.random.normal(key(14), (b, kv, t, dh))
    v = jax.random.normal(key(15), (b, kv, t, dh))
    out1 = gqa_attention_pallas(q, k, v)
    # Perturb the future (last position) of k and v.
    k2 = k.at[:, :, -1, :].set(99.0)
    v2 = v.at[:, :, -1, :].set(-99.0)
    out2 = gqa_attention_pallas(q, k2, v2)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], rtol=1e-5, atol=1e-6)


def test_attention_rows_are_convex_combinations():
    # Each output row is a convex combination of value rows -> within range.
    b, h, kv, t, dh = 1, 2, 2, 16, 8
    q = jax.random.normal(key(16), (b, h, t, dh))
    k = jax.random.normal(key(17), (b, kv, t, dh))
    v = jnp.ones((b, kv, t, dh))
    out = gqa_attention_pallas(q, k, v)
    np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5)


def test_attention_grad_matches_ref():
    b, h, kv, t, dh = 1, 4, 2, 16, 8
    q = jax.random.normal(key(18), (b, h, t, dh))
    k = jax.random.normal(key(19), (b, kv, t, dh))
    v = jax.random.normal(key(20), (b, kv, t, dh))

    def f(att):
        def g(q, k, v):
            return jnp.sum(att(q, k, v) ** 2)
        return g

    gk = jax.grad(f(gqa_attention), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f(ref.gqa_attention), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-5)


@given(
    b=st.sampled_from([1, 2]),
    heads=st.sampled_from([(2, 1), (4, 2), (6, 3), (8, 2)]),
    t=st.sampled_from([8, 32, 96]),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_attention_hypothesis_sweep(b, heads, t, dh, seed):
    h, kv = heads
    q = jax.random.normal(key(seed), (b, h, t, dh))
    k = jax.random.normal(key(seed + 1), (b, kv, t, dh))
    v = jax.random.normal(key(seed + 2), (b, kv, t, dh))
    np.testing.assert_allclose(
        gqa_attention_pallas(q, k, v), ref.gqa_attention(q, k, v), rtol=3e-5, atol=3e-5
    )


# ---------------------------------------------------------------------------
# 2-bit quantization
# ---------------------------------------------------------------------------
def test_quantize_codebook_edges():
    scale = jnp.ones((1, 1))
    vals = jnp.asarray([[-1.0, -0.67, -0.5, -0.01, 0.01, 0.5, 0.67, 1.0]])
    codes = quantize2bit_pallas(vals, scale)
    assert codes.tolist() == [[0, 0, 1, 1, 2, 2, 3, 3]]


def test_dequantize_levels():
    scale = 2.0 * jnp.ones((1, 1))
    codes = jnp.asarray([[0, 1, 2, 3]], dtype=jnp.int32)
    deq = dequantize2bit_pallas(codes, scale)
    np.testing.assert_allclose(deq, [[-2.0, -2.0 / 3.0, 2.0 / 3.0, 2.0]], rtol=1e-6)


@given(n=st.sampled_from([1, 3, 16, 128]), k=st.sampled_from([4, 64]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_quant_roundtrip_error_bounded(n, k, seed):
    vals = jax.random.normal(key(seed), (n, k))
    scales = jnp.max(jnp.abs(vals), axis=1, keepdims=True)
    codes = quantize2bit_pallas(vals, scales)
    np.testing.assert_array_equal(codes, ref.quantize2bit(vals, scales))
    deq = dequantize2bit_pallas(codes, scales)
    np.testing.assert_allclose(deq, ref.dequantize2bit(codes, scales), rtol=1e-6)
    # 4-level symmetric quantizer: |err| <= scale/3 per element.
    err = jnp.abs(deq - vals)
    assert jnp.all(err <= scales / 3.0 + 1e-6)


def test_quant_codes_in_range():
    vals = 100.0 * jax.random.normal(key(30), (32, 64))
    scales = jnp.max(jnp.abs(vals), axis=1, keepdims=True)
    codes = quantize2bit_pallas(vals, scales)
    assert int(codes.min()) >= 0 and int(codes.max()) <= 3


# ---------------------------------------------------------------------------
# Fused chunk compression
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nc,c,k", [(1, 256, 16), (8, 4096, 64), (105, 4096, 64)])
def test_compress_matches_ref(nc, c, k):
    chunks = jax.random.normal(key(40), (nc, c))
    i1, c1, s1, t1 = compress_chunks_pallas(chunks, k)
    i2, c2, s2, t2 = ref.compress_chunks(chunks, k)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    np.testing.assert_allclose(t1, t2, rtol=1e-6)


def test_compress_transmitted_support_is_topk():
    chunks = jax.random.normal(key(41), (4, 512))
    idx, codes, scales, trans = compress_chunks_pallas(chunks, 32)
    nz = np.count_nonzero(np.asarray(trans), axis=1)
    # <=k nonzeros (quantized value can be 0 only if code level *scale == 0)
    assert (nz <= 32).all()
    # the k selected positions carry the largest magnitudes
    for r in range(4):
        sel = set(np.asarray(idx[r]).tolist())
        absrow = np.abs(np.asarray(chunks[r]))
        kth = np.sort(absrow)[-32]
        above = set(np.where(absrow > kth)[0].tolist())
        assert above.issubset(sel)


def test_compress_error_feedback_identity():
    # acc = transmitted + residual, residual = acc outside support.
    chunks = jax.random.normal(key(42), (8, 4096))
    idx, codes, scales, trans = compress_chunks_pallas(chunks, 64)
    resid = np.asarray(chunks - trans)
    trans = np.asarray(trans)
    chunks = np.asarray(chunks)
    rows = np.arange(8)[:, None]
    # Off-support: residual equals acc exactly.
    mask = np.ones_like(chunks, dtype=bool)
    mask[rows, np.asarray(idx)] = False
    np.testing.assert_array_equal(resid[mask], chunks[mask])
    # On-support: |residual| <= scale/3 (quantization error bound).
    s = np.asarray(scales)
    per_row_bound = s[:, 0] / 3.0 + 1e-6
    on = ~mask
    for r in range(8):
        assert np.all(np.abs(resid[r][on[r]]) <= per_row_bound[r])


@given(
    nc=st.sampled_from([1, 2, 16]),
    c=st.sampled_from([128, 1024, 4096]),
    kk=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_compress_hypothesis_sweep(nc, c, kk, seed):
    chunks = jax.random.normal(key(seed), (nc, c)) * 0.1
    i1, c1, s1, t1 = compress_chunks_pallas(chunks, kk)
    i2, c2, s2, t2 = ref.compress_chunks(chunks, kk)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_allclose(t1, t2, rtol=1e-6, atol=1e-9)


def test_compress_zero_input():
    chunks = jnp.zeros((2, 256))
    idx, codes, scales, trans = compress_chunks_pallas(chunks, 8)
    np.testing.assert_allclose(scales, 0.0)
    np.testing.assert_allclose(trans, 0.0)
