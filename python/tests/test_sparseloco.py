"""SparseLoCo compression tests: error-feedback recursion, compress/
decompress round-trip, convergence of EF over repeated rounds, outer step."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.configs import get_config, build_layout
from compile import sparseloco
from compile.kernels import ref


CFG = get_config("tiny")
LAY = build_layout(CFG)
NA = LAY.n_alloc


def key(i=0):
    return jax.random.PRNGKey(i)


def test_compress_shapes():
    delta = jax.random.normal(key(1), (NA,)) * 1e-3
    ef = jnp.zeros((NA,))
    ef2, idx, codes, scales = sparseloco.compress(delta, ef, jnp.float32(0.95), CFG)
    assert ef2.shape == (NA,)
    assert idx.shape == (LAY.n_chunks, CFG.topk)
    assert codes.shape == (LAY.n_chunks, CFG.topk)
    assert scales.shape == (LAY.n_chunks, 1)


def test_ef_recursion_identity():
    # ef' + decompress(payload) == beta*ef + delta exactly.
    delta = jax.random.normal(key(2), (NA,)) * 1e-3
    ef = jax.random.normal(key(3), (NA,)) * 1e-4
    beta = jnp.float32(0.95)
    ef2, idx, codes, scales = sparseloco.compress(delta, ef, beta, CFG)
    dense = sparseloco.decompress(idx, codes, scales, CFG)
    np.testing.assert_allclose(ef2 + dense, beta * ef + delta, rtol=1e-5, atol=1e-8)


def test_decompress_matches_ref():
    delta = jax.random.normal(key(4), (NA,)) * 1e-3
    ef = jnp.zeros((NA,))
    _, idx, codes, scales = sparseloco.compress(delta, ef, jnp.float32(0.95), CFG)
    dense = sparseloco.decompress(idx, codes, scales, CFG)
    expected = ref.decompress_chunks(idx, codes, scales, CFG.chunk).reshape(-1)
    np.testing.assert_allclose(dense, expected, rtol=1e-6)


def test_transmitted_fraction():
    # Exactly k of C positions per chunk are transmitted.
    delta = jax.random.normal(key(5), (NA,))
    _, idx, _, _ = sparseloco.compress(delta, jnp.zeros((NA,)), jnp.float32(0.0), CFG)
    # all indices within chunk bounds, distinct per chunk
    i = np.asarray(idx)
    assert i.min() >= 0 and i.max() < CFG.chunk
    for r in range(i.shape[0]):
        assert len(set(i[r].tolist())) == CFG.topk


def test_error_feedback_accumulates_untransmitted():
    # With beta=1 and a constant delta, repeated compression must transmit
    # an increasing share: the EF norm relative to accumulated mass shrinks.
    delta = jax.random.normal(key(6), (NA,)) * 1e-3
    ef = jnp.zeros((NA,))
    transmitted_total = jnp.zeros((NA,))
    beta = jnp.float32(1.0)
    for _ in range(4):
        ef, idx, codes, scales = sparseloco.compress(delta, ef, beta, CFG)
        transmitted_total = transmitted_total + sparseloco.decompress(idx, codes, scales, CFG)
    # Conservation: transmitted + ef == 4 * delta (beta=1).
    np.testing.assert_allclose(transmitted_total + ef, 4.0 * delta, rtol=1e-4, atol=1e-7)


def test_outer_step():
    p = jax.random.normal(key(7), (NA,))
    d = jax.random.normal(key(8), (NA,))
    p2 = sparseloco.outer_step(p, d, jnp.float32(0.65))
    np.testing.assert_allclose(p2, p - 0.65 * d, rtol=1e-6)


def test_compression_reduces_error_vs_no_ef():
    # Classic EF property: with error feedback, the *cumulative* applied
    # update tracks the cumulative signal better than without.
    signal = jax.random.normal(key(9), (NA,)) * 1e-3
    beta = jnp.float32(1.0)

    ef = jnp.zeros((NA,))
    applied_ef = jnp.zeros((NA,))
    applied_noef = jnp.zeros((NA,))
    for _ in range(5):
        ef, i, c, s = sparseloco.compress(signal, ef, beta, CFG)
        applied_ef = applied_ef + sparseloco.decompress(i, c, s, CFG)
        _, i2, c2, s2 = sparseloco.compress(signal, jnp.zeros((NA,)), jnp.float32(0.0), CFG)
        applied_noef = applied_noef + sparseloco.decompress(i2, c2, s2, CFG)
    target = 5.0 * signal
    err_ef = float(jnp.linalg.norm(applied_ef - target))
    err_noef = float(jnp.linalg.norm(applied_noef - target))
    assert err_ef < err_noef, (err_ef, err_noef)


@given(beta=st.sampled_from([0.0, 0.5, 0.95, 1.0]), seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_ef_identity_hypothesis(beta, seed):
    delta = jax.random.normal(key(seed), (NA,)) * 1e-2
    ef = jax.random.normal(key(seed + 1), (NA,)) * 1e-3
    b = jnp.float32(beta)
    ef2, idx, codes, scales = sparseloco.compress(delta, ef, b, CFG)
    dense = sparseloco.decompress(idx, codes, scales, CFG)
    np.testing.assert_allclose(ef2 + dense, b * ef + delta, rtol=1e-4, atol=1e-7)
