"""AOT pipeline tests: lowering produces parseable HLO text + a manifest
consistent with the layout; HLO contains no ops the 0.5.1 parser rejects."""

import json
from pathlib import Path

import pytest

from compile.configs import get_config, build_layout
from compile.aot import build_artifacts, compile_config


def test_build_artifacts_signatures():
    cfg = get_config("tiny")
    lay = build_layout(cfg)
    arts = build_artifacts(cfg)
    assert set(arts) == {
        "init_params", "train_step", "train_round", "compress",
        "decompress", "outer_step", "eval_loss", "loss_per_seq",
    }
    # train_step: params,m,v,step,tokens,mask,lr,clip
    _, args = arts["train_step"]
    assert args[0].shape == (lay.n_alloc,)
    assert args[4].shape == (cfg.batch_size, cfg.seq_len + 1)
    # train_round stacks H batches
    _, args = arts["train_round"]
    assert args[4].shape == (cfg.inner_steps, cfg.batch_size, cfg.seq_len + 1)


@pytest.fixture(scope="module")
def compiled_tiny(tmp_path_factory):
    out = tmp_path_factory.mktemp("arts") / "tiny"
    manifest = compile_config("tiny", out, only={"outer_step", "compress"})
    return out, manifest


def test_manifest_contents(compiled_tiny):
    out, manifest = compiled_tiny
    data = json.loads((out / "manifest.json").read_text())
    cfg = get_config("tiny")
    lay = build_layout(cfg)
    assert data["n_alloc"] == lay.n_alloc
    assert data["n_params"] == lay.n_params
    assert data["n_chunks"] == lay.n_chunks
    assert data["config"]["vocab_size"] == cfg.vocab_size
    names = [t["name"] for t in data["tensors"]]
    assert names[0] == "embed" and names[-1] == "final_norm"
    art = data["artifacts"]["outer_step"]
    assert art["inputs"][0]["shape"] == [lay.n_alloc]
    assert art["outputs"][0]["shape"] == [lay.n_alloc]


def test_hlo_text_exists_and_versionless(compiled_tiny):
    out, _ = compiled_tiny
    text = (out / "compress.hlo.txt").read_text()
    assert text.startswith("HloModule")
    # Ops that the xla_extension 0.5.1 HLO parser rejects must not appear.
    for bad in ["topk(", "largest=true"]:
        assert bad not in text, f"forbidden op '{bad}' in lowered HLO"


def test_repo_artifacts_in_sync_if_present():
    """If `make artifacts` already ran, the checked manifest must match the
    current python layout (guards against stale artifacts)."""
    repo_manifest = Path(__file__).resolve().parents[2] / "artifacts/tiny/manifest.json"
    if not repo_manifest.exists():
        pytest.skip("artifacts not built")
    data = json.loads(repo_manifest.read_text())
    lay = build_layout(get_config("tiny"))
    assert data["n_alloc"] == lay.n_alloc
    assert data["n_params"] == lay.n_params
