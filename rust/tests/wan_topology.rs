//! WAN topology property pins (PR 10 satellite).
//!
//! Three properties ISSUE.md names:
//!
//! * **Churn-stable regions** — a hotkey's region is a pure function of
//!   `(run seed, hotkey)`, so a peer that leaves and rejoins lands in
//!   the same region (and gets the same link shape), no matter how much
//!   churn happened in between.
//! * **FIFO trunks never reorder** — the oversubscribed region uplink
//!   trunk serializes transfers in charge order; completion times are
//!   non-decreasing and spaced by at least the trunk's service time,
//!   both on a bare [`Link`] and end-to-end through a swarm round.
//! * **Pure draws, no RNG** — every `(latency, bandwidth, region)` draw
//!   is reproducible bit-for-bit across call orders, repeat calls and
//!   fresh model instances; nothing consumes an RNG stream, so draw
//!   order cannot shift any other peer's values.

use covenant::netsim::{FaultConfig, HeterogeneityConfig, Link, WanConfig, WanModel};
use covenant::peer::{SwarmConfig, SwarmSim};

/// Non-pristine fault config (stays off under a CI-wide
/// `COVENANT_FAULT_SCENARIO` pass), so the trunk-order test sees exactly
/// one upload charge per peer.
fn pinned_faults_off() -> FaultConfig {
    FaultConfig { retry_backoff_s: 31.0, ..Default::default() }
}

fn wan_on() -> WanConfig {
    WanConfig { enabled: true, ..Default::default() }
}

#[test]
fn region_assignment_is_stable_under_churn() {
    let mut cfg = SwarmConfig::default();
    cfg.faults = pinned_faults_off();
    cfg.wan = wan_on();
    let mut sim = SwarmSim::new(cfg);

    let hotkeys: Vec<String> = (0..48).map(|i| format!("churny-{i:04}")).collect();
    let mut region0 = Vec::new();
    let mut shape0 = Vec::new();
    for hk in &hotkeys {
        let slot = sim.join(hk);
        region0.push(sim.roster().region(slot));
        shape0.push(sim.wan().link_shape(hk, 110e6, 500e6, 0.2));
    }
    // regions actually spread (4 regions over 48 hotkeys)
    assert!(region0.iter().any(|&r| r != region0[0]), "all peers hashed to one region");

    // heavy churn: everyone leaves, half rejoin interleaved with fresh
    // peers, then the other half rejoin
    for slot in 0..hotkeys.len() {
        sim.leave(slot);
    }
    for hk in hotkeys.iter().take(24) {
        sim.join(hk);
        sim.join_fresh();
    }
    sim.run_round();
    for hk in hotkeys.iter().skip(24) {
        sim.join(hk);
    }

    for (i, hk) in hotkeys.iter().enumerate() {
        assert_eq!(
            sim.wan().region(hk),
            region0[i],
            "{hk} changed region across leave/rejoin"
        );
        let s = sim.wan().link_shape(hk, 110e6, 500e6, 0.2);
        assert_eq!(s.up_bps.to_bits(), shape0[i].up_bps.to_bits());
        assert_eq!(s.down_bps.to_bits(), shape0[i].down_bps.to_bits());
        assert_eq!(s.latency_s.to_bits(), shape0[i].latency_s.to_bits());
    }
}

#[test]
fn bare_trunk_link_never_reorders_completions() {
    // charge a FIFO link with wildly out-of-order request times; the
    // completion sequence must still be non-decreasing, spaced by at
    // least the per-transfer service time
    let bps = 25e6;
    let bytes = 12_192usize;
    let service_s = bytes as f64 * 8.0 / bps;
    let mut trunk = Link::new(bps, 0.05);
    let mut z = 0x9E37_79B9u64;
    let mut prev = f64::NEG_INFINITY;
    for _ in 0..500 {
        z ^= z << 13;
        z ^= z >> 7;
        z ^= z << 17;
        let req = (z % 1_000) as f64 / 3.0; // non-monotone requests
        let fin = trunk.transfer(req, bytes);
        assert!(fin >= prev + service_s - 1e-9, "trunk reordered or overlapped transfers");
        prev = fin;
    }
}

#[test]
fn oversubscribed_trunk_serializes_in_charge_order_end_to_end() {
    let mut cfg = SwarmConfig::default();
    cfg.faults = pinned_faults_off();
    // distinct compute finish times (jitter on) so the charge order is
    // non-trivial; trunk far below the per-peer uplink = real contention
    cfg.heterogeneity = HeterogeneityConfig { enabled: true, ..Default::default() };
    cfg.wan = WanConfig { enabled: true, region_uplink_bps: 30e6, ..Default::default() };
    let mut sim = SwarmSim::new(cfg);
    sim.spawn(64);
    let stats = sim.run_round();
    assert_eq!(stats.population.uploaded, 64);
    assert_eq!(sim.wan().trunks().len(), 4, "one trunk per region");

    let lanes = sim.sampled_lanes(0);
    let service_s = sim.cfg.wire_bytes as f64 * 8.0 / 30e6;
    let n_regions = sim.wan().trunks().len();
    for region in 0..n_regions {
        // reconstruct the charge order: uploads are requested at compute
        // end, and the event spine breaks time ties by insertion (slot)
        // order
        let mut charged: Vec<(f64, usize, f64)> = lanes
            .iter()
            .filter(|l| sim.roster().region(l.uid) == region)
            .map(|l| {
                let (_, compute_end) = l.compute.expect("honest peer computed");
                let (_, fin) = l.upload.expect("honest peer uploaded");
                (compute_end, l.uid, fin)
            })
            .collect();
        charged.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        assert!(charged.len() > 4, "region {region} too empty to exercise the trunk");
        for w in charged.windows(2) {
            let (_, _, fin_a) = w[0];
            let (_, _, fin_b) = w[1];
            assert!(
                fin_b >= fin_a + service_s - 1e-9,
                "region {region} trunk reordered completions: {fin_a} then {fin_b}"
            );
        }
    }
}

#[test]
fn wan_draws_are_pure_functions_of_seed_and_hotkey() {
    let seed = 0xBEEF_CAFE;
    let a = WanModel::new(seed, wan_on());
    let b = WanModel::new(seed, wan_on());
    let hotkeys: Vec<String> = (0..64).map(|i| format!("pure-{i:03}")).collect();

    // forward order on `a`, reverse order on `b`, with interleaved
    // repeat calls: every draw bit-identical — nothing consumes a
    // stream, so call order cannot matter
    let fwd: Vec<_> = hotkeys.iter().map(|h| a.link_shape(h, 110e6, 500e6, 0.2)).collect();
    let rev: Vec<_> = hotkeys
        .iter()
        .rev()
        .map(|h| {
            let _ = b.region(h); // extra interleaved draw
            b.link_shape(h, 110e6, 500e6, 0.2)
        })
        .collect();
    for (i, h) in hotkeys.iter().enumerate() {
        let f = fwd[i];
        let r = rev[hotkeys.len() - 1 - i];
        assert_eq!(f.up_bps.to_bits(), r.up_bps.to_bits(), "{h} uplink draw moved");
        assert_eq!(f.down_bps.to_bits(), r.down_bps.to_bits(), "{h} downlink draw moved");
        assert_eq!(f.latency_s.to_bits(), r.latency_s.to_bits(), "{h} latency draw moved");
        assert_eq!(a.region(h), b.region(h), "{h} region draw moved");
        // the prefix-keyed fast path used at swarm join time agrees
        let p = a.prefix(h);
        assert_eq!(a.region_from(p), a.region(h));
        let s = a.shape_from(p, 110e6, 500e6, 0.2);
        assert_eq!(s.up_bps.to_bits(), f.up_bps.to_bits());
        // repeat calls are bitwise-stable too
        let again = a.link_shape(h, 110e6, 500e6, 0.2);
        assert_eq!(again.up_bps.to_bits(), f.up_bps.to_bits());
    }

    // the seed matters: a different run re-rolls the topology
    let other = WanModel::new(seed ^ 1, wan_on());
    assert!(
        hotkeys.iter().any(|h| {
            other.link_shape(h, 110e6, 500e6, 0.2).up_bps.to_bits()
                != a.link_shape(h, 110e6, 500e6, 0.2).up_bps.to_bits()
        }),
        "seed did not enter the WAN draws"
    );
}
