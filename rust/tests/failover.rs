//! Shard fail-over acceptance tests (ISSUE 8):
//!
//! 1. **Degenerate parity** — faults off + zero-cost placement (with or
//!    without spare hosts) is *bit-identical* to the pre-placement
//!    rounds: same params, same timing bits, same event trace, zero
//!    fault events.
//! 2. **Recovery byte-identity** — a scripted host crash mid-run is
//!    detected, the dead shard's chunk range is reassigned, its state is
//!    rebuilt from the object store, and the final model is
//!    *byte-identical* to the fault-free run at `n_shards` in {1, 3} —
//!    deterministic across reruns and across the parallel/serial peer
//!    loops.
//! 3. **Stalls and measured barriers** — a host stall delays the
//!    cross-shard barrier (timing only); a nonzero inter-host link makes
//!    the barrier cost measurable. Neither touches the model bytes.
//! 4. **Upload flaps** — retried uploads converge to the fault-free
//!    model; an exhausted retry budget orphans the submission
//!    (`OrphanedUpload`) and the round applies nothing.
//!
//! Every config here pins `FaultScenario::Scripted(..)` explicitly
//! (including the fault-free baselines, via an *empty* script), so the
//! `COVENANT_FAULT_SCENARIO` env var CI exports on its third pass can
//! never reshape these runs — see `FaultConfig::with_env`.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::coordinator::shard::ShardedNetwork;
use covenant::netsim::{Event, FaultConfig, FaultKind, FaultScenario, ScriptedFault};
use covenant::runtime::Engine;
use covenant::train::{OuterAlphaSchedule, Schedule, Segment};

/// Explicitly fault-free: differs from the pristine default only in the
/// scenario, which is exactly what opts a run out of the CI env var.
fn pinned_fault_free() -> FaultConfig {
    FaultConfig { scenario: FaultScenario::Scripted(vec![]), ..Default::default() }
}

/// A scripted fault config (crashes/stalls fire exactly as listed).
fn scripted(faults: Vec<ScriptedFault>) -> FaultConfig {
    FaultConfig {
        enabled: true,
        scenario: FaultScenario::Scripted(faults),
        ..Default::default()
    }
}

fn build_params(seed: u64, peers: usize) -> NetworkParams {
    let mut run = RunConfig::default();
    run.artifacts = "artifacts/tiny".into();
    run.max_contributors = peers;
    run.target_active = peers;
    run.seed = seed;
    run.faults = pinned_fault_free();
    let mut p = NetworkParams::quick(run, 4, 10);
    p.initial_peers = peers;
    p.churn.p_adversarial = 0.0;
    p.churn.p_leave = 0.0;
    p.p_slow_upload = 0.0;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, 4);
    p
}

fn is_fault_event(e: &Event) -> bool {
    matches!(
        e,
        Event::HostCrash { .. }
            | Event::ShardReassigned { .. }
            | Event::ShardAnnounce { .. }
            | Event::UploadRetry { .. }
    )
}

fn assert_identical_runs(a: &Network, b: &Network, what: &str) {
    assert_eq!(a.global_params, b.global_params, "{what}: params diverged");
    assert_eq!(a.event_log.len(), b.event_log.len(), "{what}: event count");
    for (x, y) in a.event_log.iter().zip(&b.event_log) {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{what}: event time bits");
        assert_eq!(x.1, y.1, "{what}: event kind");
    }
}

#[test]
fn zero_cost_placement_with_spare_hosts_is_bit_identical_to_default() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let rounds = 3usize;
    for n_shards in [1usize, 3] {
        // Run A: the default placement (one host per shard, zero-cost
        // link) — the pre-placement degenerate config.
        let pa = build_params(0xFA11, 4);
        let mut a = ShardedNetwork::new(&eng, pa, n_shards).unwrap();
        // Run B: explicit placement with spare hosts over a zero-cost
        // link. Placement must be *observably free* until a link cost or
        // a fault makes it otherwise.
        let mut pb = build_params(0xFA11, 4);
        pb.run.placement.n_hosts = n_shards + 2;
        let mut b = ShardedNetwork::new(&eng, pb, n_shards).unwrap();
        for _ in 0..rounds {
            let ra = a.run_round().unwrap();
            let rb = b.run_round().unwrap();
            assert_eq!(ra.contributing, 4, "{:?}", ra.rejections);
            assert_eq!(ra.t_comm_end.to_bits(), rb.t_comm_end.to_bits());
            assert_eq!(ra.recovered_shards, 0);
            assert_eq!((ra.retried_uploads, ra.orphaned_slices), (0, 0));
            for (la, lb) in ra.shard_lanes.iter().zip(&rb.shard_lanes) {
                assert_eq!(la.ready_at.to_bits(), lb.ready_at.to_bits());
                assert_eq!(la.applied_at.to_bits(), lb.applied_at.to_bits());
                assert!(la.takeover.is_none() && lb.takeover.is_none());
            }
        }
        assert_identical_runs(&a.net, &b.net, &format!("n_shards={n_shards} placement"));
        assert!(
            !a.net.event_log.iter().any(|(_, e)| is_fault_event(e)),
            "degenerate run emitted fault/placement events"
        );
    }

    // The pinned-fault-free config is itself bit-identical to the
    // pristine default. Compared at n_shards = 1, where ci-crashy is a
    // no-op by construction (a single host has no failure domain), so
    // this holds even under CI's COVENANT_FAULT_SCENARIO pass.
    let mut pc = build_params(0xFA11, 4);
    pc.run.faults = FaultConfig::default();
    let mut c = ShardedNetwork::new(&eng, pc, 1).unwrap();
    let mut a = ShardedNetwork::new(&eng, build_params(0xFA11, 4), 1).unwrap();
    for _ in 0..rounds {
        c.run_round().unwrap();
        a.run_round().unwrap();
    }
    assert_identical_runs(&a.net, &c.net, "pristine default vs pinned fault-free");
}

#[test]
fn scripted_crash_recovers_and_reproduces_the_fault_free_model_bytes() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let peers = 4usize;
    let rounds = 3usize;
    // (n_shards, n_hosts, dead host, expected takeover host): at one
    // shard the whole model fails over; at three only shard 1 moves.
    for (n_shards, n_hosts, dead, takeover) in [(1usize, 2usize, 0usize, 1usize), (3, 3, 1, 0)] {
        let place = |p: &mut NetworkParams| p.run.placement.n_hosts = n_hosts;

        let mut pb = build_params(0x0DD ^ n_shards as u64, peers);
        place(&mut pb);
        let mut baseline = ShardedNetwork::new(&eng, pb, n_shards).unwrap();

        let crash = vec![ScriptedFault { round: 1, host: dead, kind: FaultKind::HostCrash }];
        let mut pf = build_params(0x0DD ^ n_shards as u64, peers);
        place(&mut pf);
        pf.run.faults = scripted(crash.clone());
        let mut faulted = ShardedNetwork::new(&eng, pf, n_shards).unwrap();

        for r in 0..rounds {
            let rb = baseline.run_round().unwrap();
            let rf = faulted.run_round().unwrap();
            assert_eq!(rb.contributing, peers, "{:?}", rb.rejections);
            assert_eq!(rf.contributing, peers, "{:?}", rf.rejections);
            if r == 1 {
                // The crash round: every shard on the dead host failed
                // over to the lowest-index survivor, detection waited
                // out the timeout past the deadline, and the barrier
                // (hence the round) stretched to cover the rebuild.
                let moved: Vec<_> = rf
                    .shard_lanes
                    .iter()
                    .filter(|l| l.takeover.is_some())
                    .collect();
                assert_eq!(rf.recovered_shards, moved.len());
                assert!(rf.recovered_shards >= 1, "crash round recovered nothing");
                let t_detect = rf.deadline + faulted.net.faults.cfg.failover_timeout_s;
                for l in &moved {
                    let (from, detect, recovered) = l.takeover.unwrap();
                    assert_eq!((from, l.host), (dead, takeover));
                    assert_eq!(detect.to_bits(), t_detect.to_bits());
                    assert!(recovered >= detect);
                    assert!(l.applied_at >= recovered);
                }
                assert!(rf.t_comm_end > rb.t_comm_end, "recovery must cost time");
                assert!(faulted
                    .net
                    .event_log
                    .iter()
                    .any(|(_, e)| matches!(e, Event::HostCrash { host } if *host == dead)));
                assert!(faulted.net.event_log.iter().any(|(_, e)| matches!(
                    e,
                    Event::ShardReassigned { from, to, .. } if (*from, *to) == (dead, takeover)
                )));
            } else {
                assert_eq!(rf.recovered_shards, 0, "round {r} re-recovered");
            }
        }
        // Crashes are permanent: the reassignment sticks.
        assert!(!faulted.net.shard_set.hosts_alive()[dead]);
        for (s, &h) in faulted.net.shard_set.assignment().iter().enumerate() {
            assert_ne!(h, dead, "shard {s} still assigned to the dead host");
        }

        // The contract: all selected slices survived in the object
        // store, so the recovered run's final model is *byte-identical*
        // to the fault-free run.
        assert_eq!(
            baseline.net.global_params, faulted.net.global_params,
            "n_shards={n_shards}: recovery changed the model bytes"
        );

        // Determinism of the faulted path itself: rerun bit-equal, and
        // the serial peer loop reproduces the parallel one.
        for parallel in [true, false] {
            let mut pr = build_params(0x0DD ^ n_shards as u64, peers);
            place(&mut pr);
            pr.run.faults = scripted(crash.clone());
            pr.parallel = parallel;
            let mut rerun = ShardedNetwork::new(&eng, pr, n_shards).unwrap();
            for _ in 0..rounds {
                rerun.run_round().unwrap();
            }
            assert_identical_runs(
                &faulted.net,
                &rerun.net,
                &format!("n_shards={n_shards} parallel={parallel} rerun"),
            );
        }
    }
}

#[test]
fn host_stall_delays_the_barrier_without_touching_the_model() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let peers = 4usize;
    let n_shards = 3usize;

    let mut pb = build_params(0x57A, peers);
    pb.run.placement.n_hosts = n_shards;
    let mut baseline = ShardedNetwork::new(&eng, pb, n_shards).unwrap();

    let mut ps = build_params(0x57A, peers);
    ps.run.placement.n_hosts = n_shards;
    ps.run.faults =
        scripted(vec![ScriptedFault { round: 1, host: 0, kind: FaultKind::HostStall }]);
    let stall_s = ps.run.faults.stall_s;
    let mut stalled = ShardedNetwork::new(&eng, ps, n_shards).unwrap();

    for r in 0..3 {
        let rb = baseline.run_round().unwrap();
        let rs = stalled.run_round().unwrap();
        assert_eq!(rs.recovered_shards, 0, "a stall must never trigger fail-over");
        let (ab, as_) = (rb.shard_lanes[0].applied_at, rs.shard_lanes[0].applied_at);
        if r == 1 {
            // Shard 0's announcement left host 0 `stall_s` late; the
            // barrier is the max arrival, and with a 300 s stall the
            // stalled shard dominates every healthy ready time.
            let want = rs.shard_lanes[0].ready_at + stall_s;
            assert_eq!(as_.to_bits(), want.to_bits(), "stalled barrier");
            assert!(as_ > ab && rs.t_comm_end > rb.t_comm_end);
        }
    }
    assert!(!stalled
        .net
        .event_log
        .iter()
        .any(|(_, e)| matches!(e, Event::ShardReassigned { .. } | Event::HostCrash { .. })));
    assert_eq!(
        baseline.net.global_params, stalled.net.global_params,
        "a stall is timing-only"
    );

    // Measured barrier: a nonzero inter-host link charges every
    // announcement its latency, shifting the barrier by exactly that
    // cost (arrivals are unchanged, and max commutes with +latency).
    let lat = 2.5f64;
    let mut pl = build_params(0x57A, peers);
    pl.run.placement.n_hosts = n_shards;
    pl.run.placement.interhost_latency_s = lat;
    let mut linked = ShardedNetwork::new(&eng, pl, n_shards).unwrap();
    let mut pb2 = build_params(0x57A, peers);
    pb2.run.placement.n_hosts = n_shards;
    let mut base2 = ShardedNetwork::new(&eng, pb2, n_shards).unwrap();
    for _ in 0..2 {
        let rb = base2.run_round().unwrap();
        let rl = linked.run_round().unwrap();
        assert_eq!(
            rl.shard_lanes[0].applied_at.to_bits(),
            (rb.shard_lanes[0].applied_at + lat).to_bits(),
            "placed barrier must cost exactly one announce latency"
        );
        let announces = linked
            .net
            .event_log
            .iter()
            .filter(|(_, e)| matches!(e, Event::ShardAnnounce { .. }))
            .count();
        assert!(announces >= n_shards, "every shard announces over the link");
    }
    assert_eq!(base2.net.global_params, linked.net.global_params);
}

#[test]
fn retried_uploads_converge_to_the_fault_free_model() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let peers = 4usize;

    let mut baseline = ShardedNetwork::new(&eng, build_params(0xF1A9, peers), 2).unwrap();

    // Every flap is retried well inside the deadline (short backoff and
    // a budget the flap rate cannot plausibly exhaust: abandonment needs
    // 11 consecutive flaps, p ~ 0.35^11), so all slices eventually land
    // and selection is unchanged.
    let mut pf = build_params(0xF1A9, peers);
    pf.run.faults = FaultConfig {
        enabled: true,
        p_link_flap: 0.35,
        max_upload_retries: 10,
        retry_backoff_s: 0.25,
        scenario: FaultScenario::Scripted(vec![]),
        ..Default::default()
    };
    let mut flappy = ShardedNetwork::new(&eng, pf, 2).unwrap();

    let mut retried = 0u64;
    for _ in 0..3 {
        let rb = baseline.run_round().unwrap();
        let rf = flappy.run_round().unwrap();
        assert_eq!(rb.contributing, peers, "{:?}", rb.rejections);
        assert_eq!(rf.contributing, peers, "{:?}", rf.rejections);
        assert_eq!(rf.orphaned_slices, 0, "nothing abandoned at this budget");
        retried += rf.retried_uploads;
    }
    assert!(retried > 0, "a 35% flap rate over 3 rounds must retry something");
    assert!(flappy
        .net
        .event_log
        .iter()
        .any(|(_, e)| matches!(e, Event::UploadRetry { .. })));
    assert_eq!(
        baseline.net.global_params, flappy.net.global_params,
        "retried uploads deliver the same bytes"
    );
}

#[test]
fn flap_storm_orphans_every_submission_and_applies_nothing() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let peers = 4usize;
    let mut p = build_params(0x0FA7, peers);
    p.run.faults = FaultConfig {
        enabled: true,
        p_link_flap: 1.0, // every attempt is cut
        max_upload_retries: 2,
        retry_backoff_s: 0.5,
        scenario: FaultScenario::Scripted(vec![]),
        ..Default::default()
    };
    let max_retries = p.run.faults.max_upload_retries as u64;
    let mut net = ShardedNetwork::new(&eng, p, 2).unwrap();
    let before = net.net.global_params.clone();

    let rep = net.run_round().unwrap();
    assert_eq!(rep.submitted, peers, "everyone computed and tried to upload");
    assert_eq!(rep.contributing, 0, "every upload exhausted its retry budget");
    // Each submitter burns exactly its budget on the first slice, then
    // abandons: later slices are never attempted, so nothing lands and
    // nothing is orphaned *in the store* — only the submissions are.
    assert_eq!(rep.retried_uploads, peers as u64 * max_retries);
    assert_eq!(rep.orphaned_slices, 0);
    assert_eq!(rep.rejections.len(), peers);
    for r in &rep.rejections {
        assert!(r.contains("OrphanedUpload"), "unexpected rejection: {r}");
    }
    for lane in &rep.lanes {
        assert!(!lane.retry_at.is_empty(), "{} never retried", lane.hotkey);
        let (_, end) = lane.upload.expect("upload was attempted");
        assert!(end.is_infinite(), "{} upload should be abandoned", lane.hotkey);
    }
    assert_eq!(net.net.global_params, before, "an empty round applies nothing");
}
