//! Ablation: error-feedback decay beta sweep on the manual SparseLoCo
//! loop (DESIGN.md ablation hook). Run explicitly:
//!   cargo test --release --test ef_sweep -- --ignored --nocapture

#![allow(clippy::field_reassign_with_default)]

use covenant::data::grammar::GrammarKind;
use covenant::data::{BatchSampler, Grammar};
use covenant::runtime::{ops, Engine};
use covenant::sparseloco::Payload;
use covenant::train::Trainer;

fn artifacts_dir() -> String {
    format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"))
}

fn run_beta(eng: &Engine, beta: f32, rounds: usize, lr: f32) -> f32 {
    let man = eng.manifest().clone();
    let h = man.config.inner_steps;
    let peers = 4;
    let na = man.n_alloc;
    let grammar = Grammar::new(man.config.vocab_size, 0x11 ^ 0xDA7A);
    let mut global = ops::init_params(eng, 0x11).unwrap();
    let lrs = vec![lr; h];
    let mut states: Vec<(Trainer, BatchSampler, Vec<f32>)> = (0..peers)
        .map(|i| {
            let stream = grammar.stream(GrammarKind::Web, i as u64, 200_000);
            let sampler =
                BatchSampler::new(stream, man.config.seq_len, man.config.batch_size, i as u64);
            (Trainer::from_params(eng, global.clone()), sampler, vec![0f32; na])
        })
        .collect();
    for _ in 0..rounds {
        let mut payloads: Vec<Payload> = Vec::new();
        for (tr, sampler, ef) in states.iter_mut() {
            let tokens = sampler.round_batch(h);
            let mask = sampler.ones_round_mask(h);
            tr.round(&tokens, &mask, &lrs).unwrap();
            let delta: Vec<f32> = global.iter().zip(&tr.params).map(|(g, l)| g - l).collect();
            let (ef2, payload) = ops::compress(eng, &delta, ef, beta).unwrap();
            *ef = ef2;
            payloads.push(payload);
        }
        let refs: Vec<&Payload> = payloads.iter().collect();
        let delta = covenant::coordinator::aggregate(&refs, na).unwrap();
        global = ops::outer_step(eng, &global, &delta, 1.0).unwrap();
        for (tr, _, _) in states.iter_mut() {
            tr.set_params(global.clone());
        }
    }
    let stream = grammar.stream(GrammarKind::Web, 0xE0E0, 30_000);
    let mut sampler =
        BatchSampler::new(stream, man.config.seq_len, man.config.batch_size, 0x77);
    ops::eval_loss(eng, &global, &sampler.batch(), &sampler.ones_mask()).unwrap()
}

fn net_loss(extra: usize, p_leave: f64, p_adv: f64, p_slow: f64, seed: u64) -> f32 {
    use covenant::config::run::RunConfig;
    use covenant::coordinator::network::{Network, NetworkParams};
    use covenant::train::{OuterAlphaSchedule, Schedule, Segment};
    let eng = Engine::new(artifacts_dir()).unwrap();
    let man = eng.manifest().clone();
    let h = man.config.inner_steps;
    let rounds = 45;
    let mut run = RunConfig::default();
    run.artifacts = artifacts_dir();
    run.max_contributors = 4;
    run.target_active = 4 + extra;
    run.seed = seed;
    let mut p = NetworkParams::quick(run, h, rounds);
    p.initial_peers = 4;
    p.churn.p_adversarial = p_adv;
    p.churn.p_leave = p_leave;
    p.p_slow_upload = p_slow;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 3e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, h);
    let mut net = Network::new(&eng, p).unwrap();
    for _ in 0..rounds {
        net.run_round().unwrap();
    }
    let grammar = Grammar::new(man.config.vocab_size, seed ^ 0xDA7A);
    let stream = grammar.stream(GrammarKind::Web, 0xE0E0, 30_000);
    let mut sampler =
        BatchSampler::new(stream, man.config.seq_len, man.config.batch_size, 0x77);
    ops::eval_loss(&eng, &net.global_params, &sampler.batch(), &sampler.ones_mask()).unwrap()
}

#[test]
#[ignore = "env bisect; run with --ignored --nocapture"]
fn env_bisect() {
    println!("clean(4,0,0,0):      {:.4}", net_loss(0, 0.0, 0.0, 0.0, 0x7AB1));
    println!("+extra2:             {:.4}", net_loss(2, 0.0, 0.0, 0.0, 0x7AB1));
    println!("+churn 0.02:         {:.4}", net_loss(2, 0.02, 0.0, 0.0, 0x7AB1));
    println!("+adv 0.15:           {:.4}", net_loss(2, 0.02, 0.15, 0.0, 0x7AB1));
    println!("+slow 0.04 (=table1):{:.4}", net_loss(2, 0.02, 0.15, 0.04, 0x7AB1));
}

#[test]
#[ignore = "env ablation; run with --ignored --nocapture"]
fn clean_network_vs_manual_45() {
    use covenant::config::run::RunConfig;
    use covenant::coordinator::network::{Network, NetworkParams};
    use covenant::train::{OuterAlphaSchedule, Schedule, Segment};
    let eng = Engine::new(artifacts_dir()).unwrap();
    let man = eng.manifest().clone();
    let h = man.config.inner_steps;
    let rounds = 45;
    let mut run = RunConfig::default();
    run.artifacts = artifacts_dir();
    run.max_contributors = 4;
    run.target_active = 4;
    run.seed = 0x11;
    let mut p = NetworkParams::quick(run, h, rounds);
    p.initial_peers = 4;
    p.churn.p_adversarial = 0.0;
    p.churn.p_leave = 0.0;
    p.p_slow_upload = 0.0;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 3e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, h);
    let mut net = Network::new(&eng, p).unwrap();
    for _ in 0..rounds {
        net.run_round().unwrap();
    }
    let grammar = Grammar::new(man.config.vocab_size, 0x11 ^ 0xDA7A);
    let stream = grammar.stream(GrammarKind::Web, 0xE0E0, 30_000);
    let mut sampler =
        BatchSampler::new(stream, man.config.seq_len, man.config.batch_size, 0x77);
    let loss = ops::eval_loss(&eng, &net.global_params, &sampler.batch(), &sampler.ones_mask()).unwrap();
    println!("clean network 45 rounds -> {loss:.4}");
    let manual = run_beta(&eng, 0.95, 45, 3e-3);
    println!("manual EF    45 rounds -> {manual:.4}");
}

#[test]
#[ignore = "ablation sweep; run with --ignored --nocapture"]
fn ef_beta_sweep() {
    let eng = Engine::new(artifacts_dir()).unwrap();
    for beta in [0.0f32, 0.5, 0.9, 0.95, 1.0] {
        let loss = run_beta(&eng, beta, 20, 3e-3);
        println!("beta={beta:<5} -> held-out loss {loss:.4}");
    }
}
