//! Parity experiment: the full Network path (Gauntlet + churn disabled /
//! neutralized) must match a hand-rolled SparseLoCo loop with the same
//! peers, data and schedule. Guards against coordinator-level training
//! bugs that unit tests can't see.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::data::grammar::GrammarKind;
use covenant::data::{BatchSampler, Grammar};
use covenant::runtime::{ops, Engine};
use covenant::sparseloco::Payload;
use covenant::train::{OuterAlphaSchedule, Schedule, Segment, Trainer};

fn artifacts_dir() -> String {
    format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn network_matches_manual_sparseloco_quality() {
    let eng = Engine::new(artifacts_dir()).expect("tiny preset resolves without artifacts");
    let man = eng.manifest().clone();
    let h = man.config.inner_steps;
    let peers = 4usize;
    let rounds = 8usize;
    let lr = 2e-3f32;

    // ---- network path, adversary-free, churn-free --------------------------
    let mut run = RunConfig::default();
    run.artifacts = artifacts_dir();
    run.max_contributors = peers;
    run.target_active = peers;
    run.seed = 0x11;
    let mut p = NetworkParams::quick(run, h, rounds);
    p.initial_peers = peers;
    p.churn.p_adversarial = 0.0;
    p.churn.p_leave = 0.0;
    p.p_slow_upload = 0.0;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: lr as f64, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, h);
    let mut net = Network::new(&eng, p).unwrap();
    for _ in 0..rounds {
        let rep = net.run_round().unwrap();
        if rep.contributing != peers {
            for r in &rep.rejections {
                eprintln!("  rejection: {r}");
            }
        }
        assert_eq!(rep.contributing, peers, "all honest peers must be selected");
    }

    // ---- manual SparseLoCo loop (same compression, with EF) -----------------
    let grammar = Grammar::new(man.config.vocab_size, 0x11 ^ 0xDA7A);
    let mut global = ops::init_params(&eng, 0x11).unwrap();
    let na = man.n_alloc;
    let lrs = vec![lr; h];
    let mut states: Vec<(Trainer, BatchSampler, Vec<f32>)> = (0..peers)
        .map(|i| {
            let stream = grammar.stream(GrammarKind::Web, i as u64, 100_000);
            let sampler =
                BatchSampler::new(stream, man.config.seq_len, man.config.batch_size, i as u64);
            (Trainer::from_params(&eng, global.clone()), sampler, vec![0f32; na])
        })
        .collect();
    for _ in 0..rounds {
        let mut payloads: Vec<Payload> = Vec::new();
        for (tr, sampler, ef) in states.iter_mut() {
            let tokens = sampler.round_batch(h);
            let mask = sampler.ones_round_mask(h);
            tr.round(&tokens, &mask, &lrs).unwrap();
            let delta: Vec<f32> =
                global.iter().zip(&tr.params).map(|(g, l)| g - l).collect();
            let (ef2, payload) = ops::compress(&eng, &delta, ef, 0.95).unwrap();
            *ef = ef2;
            payloads.push(payload);
        }
        let refs: Vec<&Payload> = payloads.iter().collect();
        let delta = covenant::coordinator::aggregate(&refs, na).unwrap();
        global = ops::outer_step(&eng, &global, &delta, 1.0).unwrap();
        for (tr, _, _) in states.iter_mut() {
            tr.set_params(global.clone());
        }
    }

    // ---- compare on a held-out batch ---------------------------------------
    let stream = grammar.stream(GrammarKind::Web, 0xE0E0, 30_000);
    let mut sampler =
        BatchSampler::new(stream, man.config.seq_len, man.config.batch_size, 0x77);
    let tokens = sampler.batch();
    let mask = sampler.ones_mask();
    let loss_net = ops::eval_loss(&eng, &net.global_params, &tokens, &mask).unwrap();
    let loss_manual = ops::eval_loss(&eng, &global, &tokens, &mask).unwrap();
    println!("network: {loss_net:.4}  manual: {loss_manual:.4}");
    assert!(
        (loss_net - loss_manual).abs() < 0.25,
        "network path diverges from manual SparseLoCo: {loss_net:.4} vs {loss_manual:.4}"
    );
}
