//! Multi-coordinator sharding acceptance tests (ISSUE 5):
//!
//! 1. **Aggregation parity** — for any shard count (uneven splits,
//!    1-chunk shards, the full manifest geometry), the stitched sharded
//!    aggregate is *bitwise identical* to the unsharded
//!    `coordinator::aggregate` over random payload sets.
//! 2. **Degenerate round parity** — `n_shards = 1` reproduces the
//!    unsharded round bit-exactly: a single whole-payload upload per
//!    peer (the historical `Link` arithmetic), no `ShardUploadDone`
//!    events, one `ShardAggregated` event at the last selected upload,
//!    and bit-identical replicated runs.
//! 3. **Shard-count invariance** — full runs with churn + adversaries
//!    produce byte-identical global models for `n_shards` in {1, 2, 3,
//!    5}: sharding changes timings and wire overhead, never the math.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::coordinator::shard::{ShardSet, ShardedNetwork};
use covenant::coordinator::{aggregate, aggregator};
use covenant::netsim::{Event, Link};
use covenant::runtime::Engine;
use covenant::sparseloco::{codec, envelope, topk, Payload};
use covenant::train::{OuterAlphaSchedule, Schedule, Segment};
use covenant::util::rng::Rng;

fn random_payloads(seed: u64, n: usize, n_chunks: usize, chunk: usize) -> Vec<Payload> {
    (0..n)
        .map(|i| {
            let mut rng = Rng::new(seed ^ (i as u64) << 16);
            // mixed magnitudes so median-norm weights actually dampen
            let mag = if i % 3 == 0 { 0.5 } else { 0.01 };
            let dense: Vec<f32> =
                (0..n_chunks * chunk).map(|_| rng.normal() as f32 * mag).collect();
            topk::compress_dense(&dense, chunk, 8usize.min(chunk))
        })
        .collect()
}

#[test]
fn sharded_aggregate_bitwise_equals_unsharded_over_random_payload_sets() {
    for trial in 0..10u64 {
        let (n_chunks, chunk, n) = match trial % 3 {
            0 => (7, 64, 5),   // uneven split for every shard count below
            1 => (12, 32, 3),  // divisible by 2 and 3, not 5
            _ => (5, 16, 8),   // 1-chunk shards at n_shards = 5
        };
        let payloads = random_payloads(0xA11CE ^ trial, n, n_chunks, chunk);
        let refs: Vec<&Payload> = payloads.iter().collect();
        let unsharded = aggregate(&refs, n_chunks * chunk).unwrap();
        for n_shards in [1usize, 2, 3, 5] {
            let mut set = ShardSet::new(n_chunks, chunk, n_shards).unwrap();
            let sharded = set.aggregate_selected(&refs).unwrap();
            assert_eq!(
                sharded.len(),
                unsharded.len(),
                "trial {trial} n_shards {n_shards}"
            );
            // bitwise, not approximate: identical accumulation order
            for (i, (a, b)) in sharded.iter().zip(&unsharded).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "trial {trial} n_shards {n_shards} position {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn per_slice_weights_would_diverge_so_weights_must_be_global() {
    // Negative control for the invariant's weight leg: computing
    // median-norm weights from *slice* norms instead of full-payload
    // norms produces a different aggregate — the cross-shard norm
    // exchange is load-bearing, not a formality.
    let payloads = random_payloads(0xBAD, 5, 8, 32);
    let refs: Vec<&Payload> = payloads.iter().collect();
    let global = aggregate(&refs, 8 * 32).unwrap();
    let mut sliced_weights = Vec::new();
    for (a, b) in [(0usize, 4usize), (4, 8)] {
        let slices: Vec<Payload> =
            refs.iter().map(|p| p.slice_chunks(a, b).unwrap()).collect();
        let srefs: Vec<&Payload> = slices.iter().collect();
        let w = aggregator::median_norm_weights(&srefs);
        let part = aggregator::aggregate_weighted(&srefs, &w, (b - a) * 32).unwrap();
        sliced_weights.extend(part);
    }
    assert_eq!(sliced_weights.len(), global.len());
    assert!(
        sliced_weights.iter().zip(&global).any(|(a, b)| a.to_bits() != b.to_bits()),
        "slice-local weights happened to match global ones; pick payloads \
         with more norm spread"
    );
}

fn build_params(seed: u64, peers: usize, adversarial: f64) -> NetworkParams {
    let mut run = RunConfig::default();
    run.artifacts = "artifacts/tiny".into();
    run.max_contributors = peers;
    run.target_active = peers;
    run.seed = seed;
    let mut p = NetworkParams::quick(run, 4, 10);
    p.initial_peers = peers;
    p.churn.p_adversarial = adversarial;
    p.churn.p_leave = 0.0;
    p.p_slow_upload = 0.0;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, 4);
    p
}

#[test]
fn n_shards_one_reproduces_the_unsharded_round_bit_exactly() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let man = eng.manifest().clone();
    let peers = 4usize;
    let rounds = 3usize;
    let p = build_params(0x51, peers, 0.0);
    assert_eq!(p.run.n_shards, 1, "single coordinator is the default");
    let window = p.run.network.compute_window_s;
    let (up_bps, lat) = (p.run.network.uplink_bps, p.run.network.latency_s);
    // One whole-payload slice per peer, sealed in a signed envelope: the
    // 48-byte CVEV header + the 8-byte "hk-NNNNN" hotkey ride on top of
    // the bare codec bytes.
    let wb = envelope::sealed_size(8, codec::wire_size(man.n_chunks, man.config.topk));

    let mut net = Network::new(&eng, p).unwrap();
    let mut t_start = 0.0f64;
    for _ in 0..rounds {
        let rep = net.run_round().unwrap();
        assert_eq!(rep.contributing, peers, "{:?}", rep.rejections);
        // The historical single-coordinator arithmetic: one upload of
        // the *whole* wire payload per peer, charged from the barrier.
        let compute_end = t_start + window;
        let up_done = Link::new(up_bps, lat).transfer(compute_end, wb);
        for lane in &rep.lanes {
            let (_, ue) = lane.upload.expect("every peer uploaded");
            assert_eq!(ue.to_bits(), up_done.to_bits(), "one whole-payload transfer");
        }
        // Exactly one shard lane covering every chunk; its ready time
        // and the barrier are the last selected upload — the historical
        // round-turnover condition.
        assert_eq!(rep.shard_lanes.len(), 1);
        let sl = &rep.shard_lanes[0];
        assert_eq!((sl.chunk0, sl.chunk1), (0, man.n_chunks));
        assert_eq!(sl.ready_at.to_bits(), up_done.to_bits());
        assert_eq!(sl.applied_at.to_bits(), up_done.to_bits());
        assert_eq!(sl.bytes, (peers * wb) as u64);
        // the degenerate event stream: no per-slice events, exactly one
        // shard aggregation event at the barrier
        assert!(!net
            .event_log
            .iter()
            .any(|(_, e)| matches!(e, Event::ShardUploadDone { .. })));
        let aggs: Vec<f64> = net
            .event_log
            .iter()
            .filter(|(_, e)| matches!(e, Event::ShardAggregated { .. }))
            .map(|&(t, _)| t)
            .collect();
        assert_eq!(aggs.len(), 1);
        assert_eq!(aggs[0].to_bits(), up_done.to_bits());
        t_start = rep.t_comm_end;
    }

    // Bit-reproducibility of the full degenerate path (params + trace).
    let mut net2 = Network::new(&eng, build_params(0x51, peers, 0.0)).unwrap();
    for _ in 0..rounds {
        net2.run_round().unwrap();
    }
    assert_eq!(net.global_params, net2.global_params);
    assert_eq!(net.event_log.len(), net2.event_log.len());
    for (a, b) in net.event_log.iter().zip(&net2.event_log) {
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.1, b.1);
    }
}

#[test]
fn global_model_is_invariant_across_shard_counts() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let man = eng.manifest().clone();
    let peers = 5usize;
    let rounds = 3usize;
    let seed = 0x5AD;

    let mut reference: Option<Vec<f32>> = None;
    let mut bytes_up_single = 0u64;
    for n_shards in [1usize, 2, 3, 5] {
        let mut net =
            ShardedNetwork::new(&eng, build_params(seed, peers, 0.2), n_shards).unwrap();
        assert_eq!(net.n_shards(), n_shards.min(man.n_chunks));
        let mut bytes_up = 0u64;
        let mut rounds_with_selection = 0usize;
        for _ in 0..rounds {
            let rep = net.run_round().unwrap();
            bytes_up += rep.bytes_up;
            if rep.contributing > 0 {
                rounds_with_selection += 1;
                // shard lanes cover the chunk space disjointly, in order
                assert_eq!(rep.shard_lanes.len(), net.n_shards());
                assert_eq!(rep.shard_lanes[0].chunk0, 0);
                assert_eq!(rep.shard_lanes.last().unwrap().chunk1, man.n_chunks);
                for w in rep.shard_lanes.windows(2) {
                    assert_eq!(w[0].chunk1, w[1].chunk0);
                }
                // every shard ready by the barrier; barrier identical
                // across lanes
                let barrier = rep.shard_lanes[0].applied_at;
                for l in &rep.shard_lanes {
                    assert!(l.ready_at <= barrier);
                    assert_eq!(l.applied_at.to_bits(), barrier.to_bits());
                }
            }
        }
        // per-shard coordinator state advanced on every selecting round
        assert!(rounds_with_selection > 0, "no round selected anything");
        assert!(net
            .shards()
            .iter()
            .all(|s| s.rounds_aggregated == rounds_with_selection));
        match &reference {
            None => {
                reference = Some(net.net.global_params.clone());
                bytes_up_single = bytes_up;
            }
            Some(r) => {
                assert_eq!(
                    &net.net.global_params, r,
                    "global model must not depend on the shard count \
                     (n_shards={n_shards})"
                );
                assert!(
                    bytes_up >= bytes_up_single,
                    "sharding adds per-slice wire overhead, never removes bytes"
                );
            }
        }
    }
}
