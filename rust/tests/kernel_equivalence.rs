//! End-to-end equivalence of the kernel modes.
//!
//! The unit tests in `runtime::kernels` / `sparseloco::*` cover the raw
//! kernels on odd and panel-boundary shapes; this file asserts the
//! properties where they matter, through full ops:
//!
//! * **Blocked == Reference, bitwise**: a full `train_step` /
//!   `train_round` / `eval_loss` through the blocked/parallel path is
//!   byte-identical to the same ops with every kernel pinned to the
//!   naive serial reference.
//! * **Simd codec/quant lane == scalar, bitwise**: the whole
//!   error-feedback compress + encode + decode chain produces identical
//!   payloads and wire bytes under the Simd process mode.
//! * **Simd matmul class**: bit-identical across thread counts, panel
//!   splits and reruns (the lane tree depends only on the reduction
//!   length), and within a documented tolerance of the blocked path
//!   end-to-end (reassociation forbids bitwise equality there).
//!
//! The kernel-mode switch is process-global and `cargo test` runs tests
//! on multiple threads, so every test that *sets* the global mode
//! serializes on a mutex and pins the modes it compares explicitly —
//! otherwise one test's mode window could overlap another's and the
//! comparison would silently degenerate (e.g. naive-vs-naive, passing
//! even if the optimized kernels regressed). Tests that only need a
//! specific path use the `*_mode` entry points and never touch the
//! global.

use std::sync::Mutex;

use covenant::runtime::kernels::{self, KernelMode};
use covenant::runtime::{ops, Engine};
use covenant::sparseloco::{codec, topk};
use covenant::util::rng::Rng;

/// Serializes every test that sets the process-global kernel mode (an
/// assert failure poisons the mutex; later tests just take the poisoned
/// guard).
static MODE_TOGGLE: Mutex<()> = Mutex::new(());

/// Relative tolerance for the lane-accumulated (Simd) matmul class vs
/// the blocked reference, end-to-end. The 8-lane tree reassociates f32
/// reductions of length <= a few hundred (the tiny preset's dims), which
/// perturbs each element by a few ulps (~1e-7 relative); 1e-3 through a
/// train step / eval leaves ~4 orders of magnitude of headroom while
/// still failing hard on any structural kernel error, which produces
/// O(1) divergence. This is the documented tolerance pin from the
/// determinism contract (ARCHITECTURE.md).
const SIMD_E2E_REL_TOL: f64 = 1e-3;

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).abs()).fold(0.0, f64::max)
}

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

#[test]
fn train_step_blocked_parallel_bit_identical_to_naive_serial() {
    let _guard = MODE_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let ambient = kernels::mode();
    let eng = Engine::from_preset("tiny").unwrap();
    let cfg = eng.manifest().config.clone();
    let n = eng.manifest().n_alloc;
    let params = ops::init_params(&eng, 3).unwrap();
    let m = vec![0f32; n];
    let v = vec![0f32; n];
    let mut rng = Rng::new(21);
    let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let mask = vec![1f32; cfg.batch_size * cfg.seq_len];

    kernels::set_mode(KernelMode::Blocked);
    let (p_f, m_f, v_f, loss_f) =
        ops::train_step(&eng, &params, &m, &v, 1.0, &tokens, &mask, 2e-3, 0.5).unwrap();
    kernels::set_mode(KernelMode::Reference);
    let (p_n, m_n, v_n, loss_n) =
        ops::train_step(&eng, &params, &m, &v, 1.0, &tokens, &mask, 2e-3, 0.5).unwrap();
    kernels::set_mode(ambient);

    assert_eq!(loss_f.to_bits(), loss_n.to_bits());
    assert!(bits_eq(&p_f, &p_n), "params diverged");
    assert!(bits_eq(&m_f, &m_n), "first moments diverged");
    assert!(bits_eq(&v_f, &v_n), "second moments diverged");
}

#[test]
fn train_round_and_eval_loss_bit_identical_to_naive_serial() {
    let _guard = MODE_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let ambient = kernels::mode();
    let eng = Engine::from_preset("tiny").unwrap();
    let cfg = eng.manifest().config.clone();
    let n = eng.manifest().n_alloc;
    let h = cfg.inner_steps;
    let params = ops::init_params(&eng, 8).unwrap();
    let m = vec![0f32; n];
    let v = vec![0f32; n];
    let mut rng = Rng::new(33);
    let round_tokens: Vec<i32> = (0..h * cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let round_mask = vec![1f32; h * cfg.batch_size * cfg.seq_len];
    let lrs = vec![1e-3f32; h];
    let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let mask = vec![1f32; cfg.batch_size * cfg.seq_len];

    kernels::set_mode(KernelMode::Blocked);
    let (p_f, _, _, losses_f) =
        ops::train_round(&eng, &params, &m, &v, 0.0, &round_tokens, &round_mask, &lrs, 0.0)
            .unwrap();
    let eval_f = ops::eval_loss(&eng, &p_f, &tokens, &mask).unwrap();
    kernels::set_mode(KernelMode::Reference);
    let (p_n, _, _, losses_n) =
        ops::train_round(&eng, &params, &m, &v, 0.0, &round_tokens, &round_mask, &lrs, 0.0)
            .unwrap();
    let eval_n = ops::eval_loss(&eng, &p_n, &tokens, &mask).unwrap();
    kernels::set_mode(ambient);

    assert!(bits_eq(&p_f, &p_n), "round params diverged");
    assert!(bits_eq(&losses_f, &losses_n), "per-step losses diverged");
    assert_eq!(eval_f.to_bits(), eval_n.to_bits());
}

#[test]
fn in_place_round_matches_out_of_place() {
    // No toggle guard needed: whichever kernel path is active, both runs
    // here use the same one, and every mode is rerun-deterministic.
    let eng = Engine::from_preset("tiny").unwrap();
    let cfg = eng.manifest().config.clone();
    let n = eng.manifest().n_alloc;
    let h = cfg.inner_steps;
    let params = ops::init_params(&eng, 5).unwrap();
    let mut rng = Rng::new(44);
    let round_tokens: Vec<i32> = (0..h * cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let round_mask = vec![1f32; h * cfg.batch_size * cfg.seq_len];
    let lrs = vec![2e-3f32; h];

    let zeros = vec![0f32; n];
    let (p_out, m_out, v_out, losses_out) =
        ops::train_round(&eng, &params, &zeros, &zeros, 0.0, &round_tokens, &round_mask, &lrs, 0.0)
            .unwrap();
    let mut p = params;
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    let losses_in = ops::train_round_in_place(
        &eng, &mut p, &mut m, &mut v, 0.0, &round_tokens, &round_mask, &lrs, 0.0,
    )
    .unwrap();
    assert!(bits_eq(&p_out, &p), "in-place params diverged");
    assert!(bits_eq(&m_out, &m), "in-place m diverged");
    assert!(bits_eq(&v_out, &v), "in-place v diverged");
    assert!(bits_eq(&losses_out, &losses_in));
}

#[test]
fn simd_codec_and_ef_compress_bit_identical_to_scalar_end_to_end() {
    // The whole bitwise-exact SIMD class through the real compress path:
    // EF combine + TopK + lane quantize + SWAR encode + SWAR decode,
    // under the *process-global* Simd mode (the same dispatch the round
    // engine uses), vs the same chain under Blocked and Reference.
    // Geometries cover odd k (partial code byte, odd index tail, partial
    // lane strips) and the chunk-parallel threshold.
    let _guard = MODE_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let ambient = kernels::mode();
    let mut rng = Rng::new(55);
    for (n_chunks, chunk, k) in [(3usize, 64usize, 7usize), (40, 64, 9), (20, 256, 33)] {
        let n = n_chunks * chunk;
        let delta: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
        let ef0: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.001).collect();
        let mut results = Vec::new();
        for mode in [KernelMode::Reference, KernelMode::Blocked, KernelMode::Simd] {
            kernels::set_mode(mode);
            let (payload, ef1) = topk::compress_with_ef(&delta, &ef0, 0.95, chunk, k);
            let wire = codec::encode(&payload);
            let decoded = codec::decode(&wire).unwrap();
            results.push((payload, ef1, wire, decoded));
        }
        kernels::set_mode(ambient);
        let (p0, ef_0, w0, d0) = &results[0];
        for (i, (p, ef1, w, d)) in results.iter().enumerate().skip(1) {
            assert_eq!(p0, p, "payload differs in mode #{i} ({n_chunks}x{chunk} k={k})");
            assert!(bits_eq(ef_0, ef1), "EF residual differs in mode #{i}");
            assert_eq!(w0, w, "wire bytes differ in mode #{i}");
            assert_eq!(d0, d, "decoded payload differs in mode #{i}");
        }
    }
}

#[test]
fn simd_matmul_bit_identical_across_thread_counts() {
    // The lane assignment and combine tree depend only on the reduction
    // length — never on the rayon pool — so the same multiply must
    // produce identical bits from pools of 1, 2 and 4 threads (which
    // also changes rows_per_task, i.e. the row-panel split). Uses the
    // mode-explicit entry point: no global state touched.
    let mut rng = Rng::new(66);
    let shapes = [(33usize, 320usize, 65usize), (64, 256, 128), (9, 257, 7)];
    for &(m, p, n) in &shapes {
        let a: Vec<f32> = (0..m * p).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..p * n).map(|_| rng.normal() as f32).collect();
        let mut reference: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 4] {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            for rerun in 0..2 {
                let mut out = vec![0f32; m * n];
                pool.install(|| kernels::matmul_mode(KernelMode::Simd, &a, &b, m, p, n, &mut out));
                match &reference {
                    None => reference = Some(out),
                    Some(r) => assert!(
                        bits_eq(r, &out),
                        "simd matmul bits changed: {m}x{p}x{n}, {threads} threads, rerun {rerun}"
                    ),
                }
            }
        }
    }
}

#[test]
fn simd_train_and_eval_within_tolerance_of_blocked_and_rerun_identical() {
    let _guard = MODE_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let ambient = kernels::mode();
    let eng = Engine::from_preset("tiny").unwrap();
    let cfg = eng.manifest().config.clone();
    let n = eng.manifest().n_alloc;
    let params = ops::init_params(&eng, 9).unwrap();
    let m = vec![0f32; n];
    let v = vec![0f32; n];
    let mut rng = Rng::new(77);
    let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let mask = vec![1f32; cfg.batch_size * cfg.seq_len];

    kernels::set_mode(KernelMode::Blocked);
    let (p_b, _, _, loss_b) =
        ops::train_step(&eng, &params, &m, &v, 1.0, &tokens, &mask, 2e-3, 0.5).unwrap();
    let eval_b = ops::eval_loss(&eng, &p_b, &tokens, &mask).unwrap();

    kernels::set_mode(KernelMode::Simd);
    let (p_s, _, _, loss_s) =
        ops::train_step(&eng, &params, &m, &v, 1.0, &tokens, &mask, 2e-3, 0.5).unwrap();
    let eval_s = ops::eval_loss(&eng, &p_s, &tokens, &mask).unwrap();
    // Rerun identity: the Simd class is bit-deterministic end-to-end.
    let (p_s2, _, _, loss_s2) =
        ops::train_step(&eng, &params, &m, &v, 1.0, &tokens, &mask, 2e-3, 0.5).unwrap();
    let eval_s2 = ops::eval_loss(&eng, &p_s2, &tokens, &mask).unwrap();
    kernels::set_mode(ambient);

    assert_eq!(loss_s.to_bits(), loss_s2.to_bits(), "simd rerun loss changed");
    assert_eq!(eval_s.to_bits(), eval_s2.to_bits(), "simd rerun eval changed");
    assert!(bits_eq(&p_s, &p_s2), "simd rerun params changed");

    // Tolerance pins vs blocked (bitwise equality is impossible: the
    // lane tree reassociates every matmul reduction).
    let dl = rel_diff(loss_b as f64, loss_s as f64);
    assert!(dl < SIMD_E2E_REL_TOL, "train loss rel diff {dl:.2e}");
    let de = rel_diff(eval_b as f64, eval_s as f64);
    assert!(de < SIMD_E2E_REL_TOL, "eval loss rel diff {de:.2e}");
    // One optimizer step at lr 2e-3 from zero moments: the adam-scaled
    // update is O(lr), so a lane-level perturbation of the gradient
    // moves params by orders of magnitude less than lr. 1e-4 absolute
    // catches any structural divergence.
    let dp = max_abs_diff(&p_b, &p_s);
    assert!(dp < 1e-4, "param abs diff {dp:.2e}");
}
