//! End-to-end bit-equivalence of the blocked/parallel kernels against the
//! naive serial references.
//!
//! The unit tests in `runtime::kernels` cover the raw kernels on odd and
//! panel-boundary shapes; this file asserts the property where it
//! matters: a full `train_step` / `train_round` / `eval_loss` through the
//! optimized path produces byte-identical params, moments and losses to
//! the same ops with every kernel forced onto the naive serial reference
//! (`kernels::force_naive`).
//!
//! The switch is process-global and `cargo test` runs tests on multiple
//! threads, so the two toggling tests serialize on a mutex: otherwise one
//! test's naive window could overlap another's "optimized" pass and the
//! comparison would silently become naive-vs-naive — passing even if the
//! optimized kernels regressed.

use std::sync::Mutex;

use covenant::runtime::{kernels, ops, Engine};
use covenant::util::rng::Rng;

/// Serializes every test that flips `force_naive` (an assert failure
/// poisons the mutex; later tests just take the poisoned guard).
static NAIVE_TOGGLE: Mutex<()> = Mutex::new(());

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn train_step_blocked_parallel_bit_identical_to_naive_serial() {
    let _guard = NAIVE_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let eng = Engine::from_preset("tiny").unwrap();
    let cfg = eng.manifest().config.clone();
    let n = eng.manifest().n_alloc;
    let params = ops::init_params(&eng, 3).unwrap();
    let m = vec![0f32; n];
    let v = vec![0f32; n];
    let mut rng = Rng::new(21);
    let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let mask = vec![1f32; cfg.batch_size * cfg.seq_len];

    let (p_f, m_f, v_f, loss_f) =
        ops::train_step(&eng, &params, &m, &v, 1.0, &tokens, &mask, 2e-3, 0.5).unwrap();
    kernels::force_naive(true);
    let (p_n, m_n, v_n, loss_n) =
        ops::train_step(&eng, &params, &m, &v, 1.0, &tokens, &mask, 2e-3, 0.5).unwrap();
    kernels::force_naive(false);

    assert_eq!(loss_f.to_bits(), loss_n.to_bits());
    assert!(bits_eq(&p_f, &p_n), "params diverged");
    assert!(bits_eq(&m_f, &m_n), "first moments diverged");
    assert!(bits_eq(&v_f, &v_n), "second moments diverged");
}

#[test]
fn train_round_and_eval_loss_bit_identical_to_naive_serial() {
    let _guard = NAIVE_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let eng = Engine::from_preset("tiny").unwrap();
    let cfg = eng.manifest().config.clone();
    let n = eng.manifest().n_alloc;
    let h = cfg.inner_steps;
    let params = ops::init_params(&eng, 8).unwrap();
    let m = vec![0f32; n];
    let v = vec![0f32; n];
    let mut rng = Rng::new(33);
    let round_tokens: Vec<i32> = (0..h * cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let round_mask = vec![1f32; h * cfg.batch_size * cfg.seq_len];
    let lrs = vec![1e-3f32; h];
    let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let mask = vec![1f32; cfg.batch_size * cfg.seq_len];

    let (p_f, _, _, losses_f) =
        ops::train_round(&eng, &params, &m, &v, 0.0, &round_tokens, &round_mask, &lrs, 0.0)
            .unwrap();
    let eval_f = ops::eval_loss(&eng, &p_f, &tokens, &mask).unwrap();
    kernels::force_naive(true);
    let (p_n, _, _, losses_n) =
        ops::train_round(&eng, &params, &m, &v, 0.0, &round_tokens, &round_mask, &lrs, 0.0)
            .unwrap();
    let eval_n = ops::eval_loss(&eng, &p_n, &tokens, &mask).unwrap();
    kernels::force_naive(false);

    assert!(bits_eq(&p_f, &p_n), "round params diverged");
    assert!(bits_eq(&losses_f, &losses_n), "per-step losses diverged");
    assert_eq!(eval_f.to_bits(), eval_n.to_bits());
}

#[test]
fn in_place_round_matches_out_of_place() {
    // No toggle guard needed: whichever kernel path is active, both runs
    // here use the same one, and both paths are bit-identical anyway.
    let eng = Engine::from_preset("tiny").unwrap();
    let cfg = eng.manifest().config.clone();
    let n = eng.manifest().n_alloc;
    let h = cfg.inner_steps;
    let params = ops::init_params(&eng, 5).unwrap();
    let mut rng = Rng::new(44);
    let round_tokens: Vec<i32> = (0..h * cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size) as i32)
        .collect();
    let round_mask = vec![1f32; h * cfg.batch_size * cfg.seq_len];
    let lrs = vec![2e-3f32; h];

    let zeros = vec![0f32; n];
    let (p_out, m_out, v_out, losses_out) =
        ops::train_round(&eng, &params, &zeros, &zeros, 0.0, &round_tokens, &round_mask, &lrs, 0.0)
            .unwrap();
    let mut p = params;
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    let losses_in = ops::train_round_in_place(
        &eng, &mut p, &mut m, &mut v, 0.0, &round_tokens, &round_mask, &lrs, 0.0,
    )
    .unwrap();
    assert!(bits_eq(&p_out, &p), "in-place params diverged");
    assert!(bits_eq(&m_out, &m), "in-place m diverged");
    assert!(bits_eq(&v_out, &v), "in-place v diverged");
    assert!(bits_eq(&losses_out, &losses_in));
}
