//! Event-spine acceptance tests (ISSUE 4):
//!
//! 1. **Barrier equivalence** — with a degenerate compute model (every
//!    peer identical, overlap off) the event-driven round loop must
//!    reproduce the historical barrier-model timings *bit-exactly*: the
//!    expected values are recomputed here with the same `netsim::Link`
//!    arithmetic the barrier implementation used (uplink transfer from
//!    the compute-window end, downloads fanned from the same barrier).
//! 2. **Straggler dynamics** — with heterogeneity enabled, straggler-tier
//!    peers genuinely miss the `fast_checks` deadline (flagged Late every
//!    round, never selected), and enabling overlap strictly shrinks the
//!    per-round wall-clock because downloads hide behind the next
//!    round's compute.
//! 3. **Stalled uploads** — a stalled connection is cut by the
//!    `DeadlineHit` event and yields a `LateUpload` verdict instead of a
//!    silent duration bump.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::netsim::{testkit, ComputeTier, Event, Link};
use covenant::runtime::Engine;
use covenant::sparseloco::{codec, envelope};
use covenant::train::{OuterAlphaSchedule, Schedule, Segment};

fn build_params(seed: u64, peers: usize) -> NetworkParams {
    let mut run = RunConfig::default();
    run.artifacts = "artifacts/tiny".into();
    run.max_contributors = peers;
    run.target_active = peers;
    run.seed = seed;
    let mut p = NetworkParams::quick(run, 4, 10);
    p.initial_peers = peers;
    p.churn.p_adversarial = 0.0;
    p.churn.p_leave = 0.0;
    p.p_slow_upload = 0.0;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, 4);
    p
}

#[test]
fn degenerate_event_spine_reproduces_barrier_timings() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let man = eng.manifest().clone();
    let peers = 4usize;
    let rounds = 4usize;
    let p = build_params(0x11, peers);
    let window = p.run.network.compute_window_s;
    let comm_deadline = p.comm_deadline_s;
    let (up_bps, down_bps, lat) =
        (p.run.network.uplink_bps, p.run.network.downlink_bps, p.run.network.latency_s);
    // Uploads are sealed in signed envelopes (the default wire format):
    // each peer's single slice carries the 48-byte CVEV header plus its
    // 8-byte "hk-NNNNN" hotkey on top of the bare codec bytes.
    let wb = envelope::sealed_size(8, codec::wire_size(man.n_chunks, man.config.topk));

    let mut net = Network::new(&eng, p).unwrap();
    let mut t_start_expected = 0.0f64;
    for _ in 0..rounds {
        let rep = net.run_round().unwrap();
        assert_eq!(rep.contributing, peers, "all honest peers selected: {:?}", rep.rejections);

        // ---- replicate the historical barrier arithmetic ----------------
        let compute_end = t_start_expected + window;
        // uplink: one payload per peer, charged from the compute barrier
        let up_done = Link::new(up_bps, lat).transfer(compute_end, wb);
        // downlink: every peer pulls the other peers' selected payloads
        let down_done = Link::new(down_bps, lat).transfer(compute_end, (peers - 1) * wb);
        let t_comm_end = compute_end.max(down_done).max(up_done);

        assert_eq!(rep.t_start.to_bits(), t_start_expected.to_bits(), "round start");
        assert_eq!(rep.t_compute_end.to_bits(), compute_end.to_bits(), "compute barrier");
        assert_eq!(
            rep.deadline.to_bits(),
            (compute_end + comm_deadline).to_bits(),
            "deadline anchor"
        );
        assert_eq!(rep.t_comm_end.to_bits(), t_comm_end.to_bits(), "comm end");
        assert_eq!(rep.lanes.len(), peers);
        for lane in &rep.lanes {
            assert_eq!(lane.tier, ComputeTier::Median, "degenerate model: one tier");
            assert!(!lane.late);
            let (cs, ce) = lane.compute.expect("every peer computed");
            assert_eq!(cs.to_bits(), t_start_expected.to_bits());
            assert_eq!(ce.to_bits(), compute_end.to_bits());
            let (_, ue) = lane.upload.expect("every peer uploaded");
            assert_eq!(ue.to_bits(), up_done.to_bits(), "upload completion");
            let (ds, de) = lane.download.expect("every peer downloaded");
            assert_eq!(ds.to_bits(), compute_end.to_bits(), "downloads fan from barrier");
            assert_eq!(de.to_bits(), down_done.to_bits(), "download completion");
        }
        assert_eq!(rep.late_submissions, 0);
        t_start_expected = t_comm_end;
    }

    // The final round's event trace has the full typed-event cast.
    let count = |f: &dyn Fn(&Event) -> bool| {
        net.event_log.iter().filter(|(_, e)| f(e)).count()
    };
    assert_eq!(count(&|e| matches!(e, Event::ComputeDone { .. })), peers);
    assert_eq!(count(&|e| matches!(e, Event::UploadDone { .. })), peers);
    assert_eq!(count(&|e| matches!(e, Event::DownloadDone { .. })), peers);
    assert_eq!(count(&|e| matches!(e, Event::DeadlineHit)), 1);
    assert!(
        count(&|e| matches!(e, Event::ChainBlock { .. })) > 50,
        "a 20-minute round spans many 12s blocks"
    );

    // Bit-reproducibility: an identical run produces identical params and
    // an identical event trace.
    let mut net2 = Network::new(&eng, build_params(0x11, peers)).unwrap();
    for _ in 0..rounds {
        net2.run_round().unwrap();
    }
    assert_eq!(net.global_params, net2.global_params);
    assert_eq!(net.event_log.len(), net2.event_log.len());
    for (a, b) in net.event_log.iter().zip(&net2.event_log) {
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.1, b.1);
    }
}

fn het_params(seed: u64, peers: usize, overlap: bool) -> NetworkParams {
    let mut p = build_params(seed, peers);
    // 1.5 * 20min stragglers: past the 24min deadline every round.
    p.run.network.heterogeneity = testkit::stress_heterogeneity(0.0);
    p.run.network.overlap = overlap;
    p
}

#[test]
fn stragglers_miss_deadlines_and_overlap_shortens_rounds() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let peers = 6usize;
    let rounds = 3usize;
    let (seed, n_stragglers) =
        testkit::seed_with_straggler_minority(peers, &testkit::stress_heterogeneity(0.0));

    let mut barrier = Network::new(&eng, het_params(seed, peers, false)).unwrap();
    let mut overlap = Network::new(&eng, het_params(seed, peers, true)).unwrap();
    let mut wall_barrier = 0.0;
    let mut wall_overlap = 0.0;
    for r in 0..rounds {
        let rb = barrier.run_round().unwrap();
        let ro = overlap.run_round().unwrap();
        for rep in [&rb, &ro] {
            // Stragglers compute past the deadline -> flagged late, never
            // selected; the punctual majority still carries the round.
            assert_eq!(
                rep.late_submissions, n_stragglers,
                "round {r}: exactly the stragglers are late: {:?}",
                rep.rejections
            );
            // The punctual majority carries the round; stragglers are
            // excluded by their Late verdicts, so selection can never
            // exceed the punctual peer count. Any selection at all means
            // the overlap run turns over at its (pre-deadline) t_agg,
            // while the barrier run waits out the straggler to the
            // deadline — the wall-clock gap asserted below.
            assert!(
                rep.contributing >= 1 && rep.contributing <= peers - n_stragglers,
                "round {r}: contributing={} punctual={}: {:?}",
                rep.contributing,
                peers - n_stragglers,
                rep.rejections
            );
            for lane in &rep.lanes {
                let is_straggler = lane.tier == ComputeTier::Straggler;
                assert_eq!(lane.late, is_straggler, "late flag follows tier");
                let (_, ce) = lane.compute.unwrap();
                if is_straggler {
                    assert!(ce > rep.deadline, "straggler compute overruns the deadline");
                } else {
                    assert!(ce <= rep.deadline);
                }
            }
        }
        // Barrier: the round is held open to the timeout by the
        // straggler's missing upload. Overlap: it turns over as soon as
        // the selected (punctual) uploads land — before the deadline.
        assert_eq!(rb.t_comm_end.to_bits(), rb.deadline.to_bits());
        assert!(ro.t_comm_end < ro.deadline);
        wall_barrier += rb.wall_clock();
        wall_overlap += ro.wall_clock();
        if r > 0 {
            // Overlap: every peer's compute starts strictly after the
            // round boundary, because its previous download was still in
            // flight when the round turned over.
            for lane in &ro.lanes {
                if let Some((cs, _)) = lane.compute {
                    assert!(cs > ro.t_start, "compute overlaps prior comm");
                }
            }
        }
    }
    assert!(
        wall_overlap < wall_barrier,
        "overlap must strictly shrink wall-clock: {wall_overlap} vs {wall_barrier}"
    );
}

#[test]
fn stalled_upload_cut_at_deadline_yields_late_upload() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let peers = 3usize;
    let mut p = build_params(7, peers);
    p.p_slow_upload = 1.0; // every upload stalls
    let mut net = Network::new(&eng, p).unwrap();
    let rep = net.run_round().unwrap();

    assert_eq!(rep.submitted, peers);
    assert_eq!(rep.late_submissions, peers);
    assert_eq!(rep.contributing, 0, "stalled uploads never aggregate");
    assert_eq!(rep.bytes_up, 0);
    for lane in &rep.lanes {
        let (_, ue) = lane.upload.expect("upload attempted");
        assert!(ue.is_infinite(), "stalled upload never completes");
        assert!(lane.late);
        assert!(lane.download.is_none(), "nothing selected, nothing to download");
    }
    // The verdicts are LateUpload (cut at the deadline), not Late.
    assert!(
        rep.rejections.iter().all(|r| r.contains("LateUpload")),
        "rejections: {:?}",
        rep.rejections
    );
    // The deadline event is in the trace; no UploadDone ever fired.
    assert!(net.event_log.iter().any(|(t, e)| {
        matches!(e, Event::DeadlineHit) && t.to_bits() == rep.deadline.to_bits()
    }));
    assert!(!net.event_log.iter().any(|(_, e)| matches!(e, Event::UploadDone { .. })));
    // Barrier collection waited out the timeout: the round stretches
    // exactly to the deadline where the stalled transfers were cut.
    assert_eq!(rep.t_comm_end.to_bits(), rep.deadline.to_bits());
}
