//! Swarm-scale contract (PR 10's tentpole): peer count is a scaling
//! axis, not a constant.
//!
//! Four pins, matching ISSUE.md's satellite list:
//!
//! 1. **Budget** — a steady-state 10k-peer [`SwarmSim`] round stays
//!    inside a pinned wall-clock budget and performs (essentially) zero
//!    heap allocation; the allocation count is *identical* at 1k and
//!    10k peers, which is the scale-independence proof that no per-peer
//!    allocation survives in the round loop.
//! 2. **Representation equivalence** — the full round engine at 16
//!    peers produces byte-identical global models, verdict accounting,
//!    lane sets and event traces whether per-peer links live in the
//!    classic `LinkPair`-per-slot form or the struct-of-arrays
//!    [`SwarmLinks`](covenant::peer::SwarmLinks) bank
//!    (`network.soa_links`).
//! 3. **Pool determinism** — 1k-peer swarm rounds with every stochastic
//!    layer on (tiers, WAN trunks, link flaps, stalls) produce
//!    bit-identical stats and event traces across rayon pools of
//!    1/2/4 threads.
//! 4. **Degenerate WAN** — an explicitly-disabled region model (with
//!    every other knob cranked) is bit-exact with today's default
//!    timings, in both the swarm driver and the full engine.
//!
//! Plus the O(peers)-metrics regression: a 100k-peer lane table yields
//! exact full-population counters and a 64-lane materialized sample
//! without allocating per-peer lane strings.

#![allow(clippy::field_reassign_with_default)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::time::{Duration, Instant};

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams, PeerLane, RoundReport};
use covenant::netsim::sched::Event;
use covenant::netsim::{ComputeTier, FaultConfig, HeterogeneityConfig, WanConfig};
use covenant::peer::{LaneTable, SwarmConfig, SwarmRoundStats, SwarmSim};
use covenant::runtime::Engine;
use covenant::telemetry::{sample_indices, TelemetryConfig};
use covenant::train::{OuterAlphaSchedule, Schedule, Segment};

// ---------------------------------------------------------------------------
// Counting allocator: per-thread allocation counter over the system
// allocator. Thread-local so parallel test threads (and rayon workers)
// can't pollute a measurement taken on the current thread — which is
// also why budget measurements below run with `parallel: false`.
// ---------------------------------------------------------------------------

struct CountingAlloc;

thread_local! {
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOC_COUNT.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

const ROUNDS: usize = 2;

/// Fault layer off, but *not* the pristine default, so a CI-wide
/// `COVENANT_FAULT_SCENARIO` pass cannot flip it on (see
/// `FaultConfig::with_env`) — budget and bit-exactness pins must hold
/// under that pass too.
fn pinned_faults_off() -> FaultConfig {
    FaultConfig { retry_backoff_s: 31.0, ..Default::default() }
}

/// Telemetry off and non-pristine (same reasoning, for
/// `COVENANT_TELEMETRY=1` passes). `sample_lanes: 0` keeps the full
/// lane set in reports, which the equivalence tests compare whole.
fn explicit_off() -> TelemetryConfig {
    TelemetryConfig { enabled: false, sample_lanes: 0, trace: false, run_log: false }
}

fn build_params(seed: u64, peers: usize, n_shards: usize, soa_links: bool) -> NetworkParams {
    let mut run = RunConfig::default();
    run.artifacts = "artifacts/tiny".into();
    run.max_contributors = peers;
    run.target_active = peers;
    run.seed = seed;
    run.n_shards = n_shards;
    run.telemetry = explicit_off();
    run.network.soa_links = soa_links;
    let mut p = NetworkParams::quick(run, 4, 10);
    p.initial_peers = peers;
    p.churn.p_adversarial = 0.25;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, 4);
    p.rust_compress = true;
    p
}

struct RunOut {
    global: Vec<f32>,
    reports: Vec<RoundReport>,
    traces: Vec<Vec<(f64, Event)>>,
}

fn run_net(eng: &Engine, p: NetworkParams) -> RunOut {
    let mut net = Network::new(eng, p).unwrap();
    let mut traces = Vec::new();
    for _ in 0..ROUNDS {
        net.run_round().unwrap();
        traces.push(net.event_log.clone());
    }
    RunOut { global: net.global_params.clone(), reports: net.reports.clone(), traces }
}

/// The verdict-side accounting that must not move across
/// representations.
fn accounting(r: &RoundReport) -> impl PartialEq + std::fmt::Debug {
    (
        (r.round, r.active, r.submitted, r.contributing, r.late_submissions),
        (r.rejected_pre_decode, r.adversarial_submitted, r.adversarial_selected),
        (r.retried_uploads, r.orphaned_slices, r.recovered_shards),
        (r.mean_loss.to_bits(), r.bytes_up, r.bytes_down),
        r.rejections.clone(),
        r.lane_population,
    )
}

/// A bit-exact comparable signature of a lane (f64s as bits).
#[allow(clippy::type_complexity)]
fn lane_sig(l: &PeerLane) -> (usize, String, ComputeTier, [Option<(u64, u64)>; 3], bool, Vec<u64>) {
    let seg = |s: Option<(f64, f64)>| s.map(|(a, b)| (a.to_bits(), b.to_bits()));
    (
        l.uid,
        l.hotkey.clone(),
        l.tier,
        [seg(l.compute), seg(l.upload), seg(l.download)],
        l.late,
        l.retry_at.iter().map(|t| t.to_bits()).collect(),
    )
}

fn assert_traces_identical(a: &[Vec<(f64, Event)>], b: &[Vec<(f64, Event)>]) {
    assert_eq!(a.len(), b.len());
    for (ta, tb) in a.iter().zip(b) {
        assert_eq!(ta.len(), tb.len(), "event counts differ");
        for ((t0, e0), (t1, e1)) in ta.iter().zip(tb) {
            assert_eq!(t0.to_bits(), t1.to_bits(), "event time drifted");
            assert_eq!(e0, e1, "event payload drifted");
        }
    }
}

fn assert_runs_identical(a: &RunOut, b: &RunOut, what: &str) {
    assert_eq!(a.global, b.global, "global model drifted ({what})");
    assert_eq!(a.reports.len(), b.reports.len());
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(accounting(ra), accounting(rb), "accounting drifted ({what})");
        assert_eq!(ra.lanes.len(), rb.lanes.len(), "lane counts drifted ({what})");
        for (la, lb) in ra.lanes.iter().zip(&rb.lanes) {
            assert_eq!(lane_sig(la), lane_sig(lb), "a lane drifted ({what})");
        }
    }
    assert_traces_identical(&a.traces, &b.traces);
}

// ---------------------------------------------------------------------------
// 1. Budget: wall-clock + allocation, scale-independent
// ---------------------------------------------------------------------------

/// Run `peers` through two warm-up rounds (all capacity growth happens
/// there), then measure the third: allocation delta and wall clock.
fn steady_state_round(peers: usize) -> (u64, Duration, SwarmRoundStats) {
    let mut cfg = SwarmConfig::default();
    cfg.faults = pinned_faults_off();
    let mut sim = SwarmSim::new(cfg);
    sim.spawn(peers);
    sim.run_round();
    sim.run_round();
    let before = allocs_now();
    let t0 = Instant::now();
    let stats = sim.run_round();
    (allocs_now() - before, t0.elapsed(), stats)
}

#[test]
fn ten_k_peer_round_within_pinned_budget() {
    let (a1k, _, s1k) = steady_state_round(1_000);
    let (a10k, elapsed, s10k) = steady_state_round(10_000);

    assert_eq!(s10k.peers, 10_000);
    assert_eq!(s10k.population.computed, 10_000);
    assert_eq!(s10k.population.uploaded, 10_000);
    assert_eq!(s10k.bytes_up, 10_000 * 12_192, "one wire payload per peer");
    assert_eq!(s1k.population.uploaded, 1_000);

    // pinned wall-clock budget: a timing-only 10k-peer round is ~30k
    // heap events; 10s is orders of magnitude of headroom on any CI box
    assert!(elapsed < Duration::from_secs(10), "10k-peer round took {elapsed:?}");

    // zero per-peer allocation: the steady-state allocation count does
    // not move between 1k and 10k peers, and is itself (near) zero
    assert!(a10k <= 8, "steady-state 10k round allocated {a10k} times");
    assert_eq!(a10k, a1k, "round allocations must be independent of peer count");
}

// ---------------------------------------------------------------------------
// 2. SoA links representation equivalence in the full engine
// ---------------------------------------------------------------------------

#[test]
fn soa_links_are_byte_identical_to_per_peer_links_at_16_peers() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    for n_shards in [1usize, 3] {
        let aos = run_net(&eng, build_params(0x50A0, 16, n_shards, false));
        let soa = run_net(&eng, build_params(0x50A0, 16, n_shards, true));
        assert_runs_identical(&aos, &soa, &format!("soa_links, n_shards={n_shards}"));
    }
}

// ---------------------------------------------------------------------------
// 3. Event-trace determinism across rayon pool sizes at 1k peers
// ---------------------------------------------------------------------------

/// Every stochastic layer on: tiers + jitter, WAN regions with an
/// oversubscribed trunk, link flaps, slow uploads. All pure-hash draws,
/// so pool size must not move a bit.
fn stochastic_swarm() -> (Vec<SwarmRoundStats>, Vec<Vec<(f64, Event)>>) {
    let mut cfg = SwarmConfig::default();
    cfg.seed = 0xC0FE;
    cfg.p_slow_upload = 0.02;
    cfg.heterogeneity = HeterogeneityConfig { enabled: true, ..Default::default() };
    cfg.wan = WanConfig { enabled: true, region_uplink_bps: 40e6, ..Default::default() };
    cfg.faults = FaultConfig { enabled: true, p_link_flap: 0.15, ..Default::default() };
    cfg.parallel = true;
    cfg.record_events = true;
    let mut sim = SwarmSim::new(cfg);
    sim.spawn(1_000);
    let mut stats = Vec::new();
    let mut traces = Vec::new();
    for _ in 0..ROUNDS {
        stats.push(sim.run_round());
        traces.push(sim.event_log.clone());
    }
    (stats, traces)
}

#[test]
fn swarm_traces_bit_identical_across_rayon_pools() {
    let (base_stats, base_traces) = stochastic_swarm();

    // sanity: the stochastic layers actually fired at this scale
    let p = &base_stats[0].population;
    assert!(p.retries > 0, "link flaps should fire at 1k peers");
    assert!(p.stalled > 0, "slow uploads should fire at 1k peers");
    assert!(p.late > 0, "the trunk + flaps should push someone past the deadline");

    for threads in [1usize, 2, 4] {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        let (stats, traces) = pool.install(stochastic_swarm);
        assert_eq!(stats, base_stats, "stats drifted on a {threads}-thread pool");
        for (s, b) in stats.iter().zip(&base_stats) {
            assert_eq!(s.t_end.to_bits(), b.t_end.to_bits());
        }
        assert_traces_identical(&traces, &base_traces);
    }
}

// ---------------------------------------------------------------------------
// 4. Region model off == today's timings, bit-exact
// ---------------------------------------------------------------------------

#[test]
fn swarm_wan_off_is_bit_exact_with_default_timings() {
    let mk = |wan: WanConfig| {
        let mut cfg = SwarmConfig::default();
        cfg.faults = pinned_faults_off();
        cfg.wan = wan;
        cfg.record_events = true;
        let mut sim = SwarmSim::new(cfg);
        sim.spawn(256);
        let mut out = Vec::new();
        for _ in 0..ROUNDS {
            let st = sim.run_round();
            out.push((st, sim.event_log.clone()));
        }
        out
    };
    let base = mk(WanConfig::default());
    // disabled wins over every other knob — cranked values must be inert
    let off = mk(WanConfig {
        enabled: false,
        n_regions: 9,
        inter_region_latency_s: 0.7,
        uplink_spread: 0.9,
        downlink_spread: 0.9,
        region_uplink_bps: 1e6,
    });
    assert_eq!(base.len(), off.len());
    for ((sa, ta), (sb, tb)) in base.iter().zip(&off) {
        assert_eq!(sa, sb, "stats drifted with a disabled WAN model");
        assert_eq!(sa.t_end.to_bits(), sb.t_end.to_bits());
        assert_traces_identical(std::slice::from_ref(ta), std::slice::from_ref(tb));
    }
}

#[test]
fn network_wan_off_keeps_default_rounds_bit_exact() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let base = run_net(&eng, build_params(0xD00D, 6, 3, false));
    let mut p = build_params(0xD00D, 6, 3, false);
    p.run.network.wan = WanConfig {
        enabled: false,
        n_regions: 9,
        inter_region_latency_s: 0.7,
        uplink_spread: 0.9,
        downlink_spread: 0.9,
        region_uplink_bps: 1e6,
    };
    let off = run_net(&eng, p);
    assert_runs_identical(&base, &off, "wan disabled-with-knobs vs default");
}

// ---------------------------------------------------------------------------
// O(peers) metrics regression: 100k-peer lane assembly
// ---------------------------------------------------------------------------

#[test]
fn hundred_k_peer_report_allocates_no_per_peer_lane_strings() {
    let n = 100_000usize;
    let names: Vec<String> = (0..n).map(|i| format!("swm-{i:08}")).collect();
    let mut tab = LaneTable::with_len(n);
    for (i, _) in names.iter().enumerate() {
        let t = i as f64;
        tab.set_compute(i, t, t + 1.0);
        tab.set_upload(i, t + 1.0, t + 2.0);
    }
    tab.push_retry(17, 3.0);

    let before = allocs_now();
    let pop = tab.population();
    let keep = sample_indices(0x5EED, names.iter().map(|s| s.as_str()), 64);
    let lanes = tab.materialize(&keep, |i| (i, names[i].clone(), ComputeTier::Median));
    let spent = allocs_now() - before;

    // exact counters cover the whole population...
    assert_eq!(pop.peers, 100_000);
    assert_eq!(pop.computed, 100_000);
    assert_eq!(pop.uploaded, 100_000);
    assert_eq!(pop.retries, 1);
    // ...while lane materialization is O(sample): 64 lanes, and an
    // allocation count that cannot contain 100k hotkey strings
    assert_eq!(keep.len(), 64);
    assert_eq!(lanes.len(), 64);
    assert!(
        spent < 1_000,
        "100k-peer lane assembly allocated {spent} times — per-peer work crept back in"
    );
}
