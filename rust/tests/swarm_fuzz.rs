//! Seeded swarm fuzz (PR 10 satellite): random join/leave/adversary
//! schedules at 1k peers, with every stochastic timing layer on.
//!
//! Three pins from ISSUE.md: **no panic** across the schedule, **no
//! unbounded memory growth** (retained heap reaches a fixed point once
//! churn stops — the steady-state zero-allocation contract seen from
//! the outside), and **bit-identical reruns** (the whole run is a pure
//! function of the fuzz seed; virtual times compare as bits).

#![allow(clippy::field_reassign_with_default)]

use covenant::netsim::{FaultConfig, HeterogeneityConfig, WanConfig};
use covenant::peer::{SwarmConfig, SwarmRoundStats, SwarmSim};
use covenant::util::rng::Rng;

const PEERS: usize = 1_000;
const CHURN_ROUNDS: usize = 12;
const STEADY_ROUNDS: usize = 12;

/// Everything on, explicitly (non-pristine, so the CI fault-scenario
/// pass cannot re-roll the schedule): tiers, WAN trunks, link flaps,
/// slow uploads.
fn fuzz_cfg(seed: u64) -> SwarmConfig {
    let mut cfg = SwarmConfig::default();
    cfg.seed = seed;
    cfg.p_slow_upload = 0.03;
    cfg.heterogeneity = HeterogeneityConfig { enabled: true, ..Default::default() };
    cfg.wan = WanConfig { enabled: true, region_uplink_bps: 60e6, ..Default::default() };
    cfg.faults = FaultConfig { enabled: true, p_link_flap: 0.2, ..Default::default() };
    cfg
}

/// Drive one seeded schedule: `CHURN_ROUNDS` rounds of random
/// leave/join/adversary-flip mutations, then `STEADY_ROUNDS` quiet
/// rounds. Returns per-round stats plus the retained heap measured at
/// the churn/steady boundary and at the end.
fn drive(seed: u64) -> (Vec<SwarmRoundStats>, usize, usize) {
    let mut sim = SwarmSim::new(fuzz_cfg(seed));
    sim.spawn(PEERS);
    let mut rng = Rng::new(seed ^ 0xF022);
    let mut stats = Vec::with_capacity(CHURN_ROUNDS + STEADY_ROUNDS);
    for _ in 0..CHURN_ROUNDS {
        for _ in 0..rng.below(8) {
            let slot = rng.below(sim.roster().slots());
            // keep at least half the swarm alive so rounds stay busy
            if sim.roster().is_alive(slot) && sim.roster().alive() > PEERS / 2 {
                sim.leave(slot);
            }
        }
        for _ in 0..rng.below(8) {
            sim.join_fresh();
        }
        for _ in 0..rng.below(16) {
            let slot = rng.below(sim.roster().slots());
            if sim.roster().is_alive(slot) {
                sim.set_adversarial(slot, rng.below(2) == 0);
            }
        }
        stats.push(sim.run_round());
    }
    let heap_churned = sim.heap_bytes();
    for _ in 0..STEADY_ROUNDS {
        stats.push(sim.run_round());
    }
    (stats, heap_churned, sim.heap_bytes())
}

fn check_invariants(stats: &[SwarmRoundStats]) {
    for (k, s) in stats.iter().enumerate() {
        assert_eq!(s.round, k, "rounds numbered consecutively");
        assert!(s.t_end >= s.t_start, "round {k} ran backwards");
        assert!(s.peers >= PEERS / 2, "round {k} lost too many peers");
        let p = &s.population;
        assert!(p.peers >= s.peers as u64, "lane rows cover every live peer");
        assert!(p.computed <= s.peers as u64);
        assert!(p.uploaded + p.stalled <= p.peers, "upload verdicts overcounted");
        assert_eq!(p.downloaded, s.peers as u64, "every live peer downloads");
        assert!(s.bytes_up >= p.uploaded * 12_192, "uploaded lanes charge wire bytes");
        assert_eq!(s.bytes_down, s.peers as u64 * 12_192 * 20);
        if k > 0 {
            assert_eq!(
                s.t_start.to_bits(),
                stats[k - 1].t_end.to_bits(),
                "rounds chain in virtual time"
            );
        }
    }
    // the stochastic layers actually fired somewhere in the schedule
    let total_retries: u64 = stats.iter().map(|s| s.population.retries).sum();
    let total_stalls: u64 = stats.iter().map(|s| s.population.stalled).sum();
    assert!(total_retries > 0, "link flaps never fired");
    assert!(total_stalls > 0, "slow uploads never fired");
}

#[test]
fn seeded_schedules_run_clean_and_bounded() {
    for seed in [0xFA57_0001u64, 0xFA57_0002] {
        let (stats, heap_churned, heap_end) = drive(seed);
        check_invariants(&stats);
        // once churn stops, retained heap is (almost) a fixed point:
        // only the retry scratch and event heap may still round up
        assert!(
            heap_end <= heap_churned + 16 * 1024,
            "seed {seed:#x}: heap grew {heap_churned} -> {heap_end} with no churn"
        );
    }
}

#[test]
fn rerun_is_bit_deterministic() {
    let (a, ha, _) = drive(0xFA57_0003);
    let (b, hb, _) = drive(0xFA57_0003);
    assert_eq!(ha, hb, "retained heap layout diverged across reruns");
    assert_eq!(a.len(), b.len());
    for (sa, sb) in a.iter().zip(&b) {
        assert_eq!(sa, sb, "round stats diverged across reruns");
        assert_eq!(sa.t_start.to_bits(), sb.t_start.to_bits());
        assert_eq!(sa.t_end.to_bits(), sb.t_end.to_bits());
    }
}
