//! Parallel-vs-serial round-engine determinism.
//!
//! The round engine fans peer compute/compress/encode out across a rayon
//! pool; correctness of the whole refactor rests on the invariant that
//! the parallel and serial paths are *byte-identical*: per-peer RNGs are
//! seeded from (run seed, hotkey, round), submissions merge in stable
//! hotkey order, and aggregation accumulates payloads in submission order
//! within disjoint chunk ranges. This test drives full rounds — churn,
//! adversaries, Gauntlet scoring, aggregation, outer step — both ways and
//! demands bit-equality of the resulting global model and round reports.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams, RoundReport};
use covenant::runtime::Engine;
use covenant::train::{OuterAlphaSchedule, Schedule, Segment};
use covenant::util::proptest::check;

fn build_params(seed: u64, peers: usize, adversarial: f64, parallel: bool) -> NetworkParams {
    let mut run = RunConfig::default();
    run.artifacts = "artifacts/tiny".into();
    run.max_contributors = peers;
    run.target_active = peers;
    run.seed = seed;
    let mut p = NetworkParams::quick(run, 4, 10);
    p.initial_peers = peers;
    p.churn.p_adversarial = adversarial;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, 4);
    p.rust_compress = true; // fused compressor on the fan-out path
    p.parallel = parallel;
    p
}

fn run_rounds(eng: &Engine, p: NetworkParams, rounds: usize) -> (Vec<f32>, Vec<RoundReport>) {
    let mut net = Network::new(eng, p).unwrap();
    for _ in 0..rounds {
        net.run_round().unwrap();
    }
    (net.global_params.clone(), net.reports.clone())
}

#[test]
fn parallel_and_serial_rounds_bit_identical() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    check(
        3,
        |r| (r.next_u64(), 3 + r.below(2), [0.0, 0.25][r.below(2)]),
        |&(seed, peers, adversarial)| {
            let rounds = 2;
            let (g_par, rep_par) =
                run_rounds(&eng, build_params(seed, peers, adversarial, true), rounds);
            let (g_ser, rep_ser) =
                run_rounds(&eng, build_params(seed, peers, adversarial, false), rounds);
            // aggregated gradients fed the outer step: global params must
            // agree bit for bit
            if g_par != g_ser {
                return false;
            }
            rep_par.len() == rep_ser.len()
                && rep_par.iter().zip(&rep_ser).all(|(a, b)| {
                    a.round == b.round
                        && a.submitted == b.submitted
                        && a.contributing == b.contributing
                        && a.adversarial_submitted == b.adversarial_submitted
                        && a.adversarial_selected == b.adversarial_selected
                        && a.mean_loss.to_bits() == b.mean_loss.to_bits()
                        && a.bytes_up == b.bytes_up
                        && a.bytes_down == b.bytes_down
                })
        },
    );
}

#[test]
fn fused_and_engine_compress_paths_agree() {
    // rust_compress toggles between the fused in-place EF compressor and
    // the engine-tracked ops::compress; the round trajectories must match
    // exactly.
    let eng = Engine::new("artifacts/tiny").unwrap();
    let mut fast = build_params(0xAB, 3, 0.0, true);
    fast.rust_compress = true;
    let mut slow = build_params(0xAB, 3, 0.0, true);
    slow.rust_compress = false;
    let (g_fast, _) = run_rounds(&eng, fast, 2);
    let (g_slow, _) = run_rounds(&eng, slow, 2);
    assert_eq!(g_fast, g_slow);
}
