//! Gauntlet scoring under churn: peers leaving mid-round, rejoining with
//! recycled UIDs, and the probation invariant (no unproven peer ever
//! enters the selected set), plus bit-equality of the serial and
//! rayon-fan-out `score_round` paths.
//!
//! These tests drive `Validator::score_round` directly with synthetic
//! submissions so churn events land exactly where we want them: "left
//! mid-round" is a submission whose upload never beats the deadline;
//! "rejoined with a recycled UID" is a fresh hotkey reusing a departed
//! peer's UID. Proven/suspended state is keyed by hotkey (the on-chain
//! identity), so a recycled UID must never inherit its predecessor's
//! probation clearance.

use std::collections::HashSet;

use covenant::config::run::GauntletConfig;
use covenant::gauntlet::testkit::{synthetic_submission as sub, SyntheticEvalData};
use covenant::gauntlet::validator::{RoundVerdict, Validator};
use covenant::runtime::{ops, Engine};

/// Tiny honest-looking payload scale: improvements land well inside the
/// harmful threshold (|dloss| << 5e-3), so these peers always test clean.
const CLEAN_SCALE: f32 = 1e-5;

/// Shared deterministic fixture (`gauntlet::testkit`): the hotpath bench
/// drives `score_round` with the same provider and submission shapes, so
/// it measures exactly the workload these tests validate.
fn provider_for(eng: &Engine) -> SyntheticEvalData {
    SyntheticEvalData::for_engine(eng)
}

const DEADLINE: f64 = 1e9;
const ALPHA: f32 = 0.05;

/// Three rounds of churn: honest trio; one peer's upload dies mid-round;
/// that peer is replaced by a fresh hotkey on the recycled UID.
fn churn_scenario(parallel: bool) -> Vec<RoundVerdict> {
    let eng = Engine::from_preset("tiny").unwrap();
    let base = ops::init_params(&eng, 11).unwrap();
    let cfg = GauntletConfig {
        loss_eval_fraction: 1.0,
        eval_batches: 1,
        parallel_eval: parallel,
        ..Default::default()
    };
    let mut val = Validator::new(cfg, 0x5EED);
    let mut provider = provider_for(&eng);
    let mut out = Vec::new();
    // round 0: alice(0), bob(1), carol(2)
    let subs0 = vec![
        sub(&eng, "alice", 0, 0, 1, CLEAN_SCALE),
        sub(&eng, "bob", 1, 0, 2, CLEAN_SCALE),
        sub(&eng, "carol", 2, 0, 3, CLEAN_SCALE),
    ];
    out.push(
        val.score_round(&eng, &base, &subs0, 0, DEADLINE, ALPHA, 2, &mut provider).unwrap(),
    );
    // round 1: bob leaves mid-round — his upload never completes in time
    let mut bob1 = sub(&eng, "bob", 1, 1, 5, CLEAN_SCALE);
    bob1.uploaded_at = DEADLINE + 1.0;
    let subs1 = vec![
        sub(&eng, "alice", 0, 1, 4, CLEAN_SCALE),
        bob1,
        sub(&eng, "carol", 2, 1, 6, CLEAN_SCALE),
    ];
    out.push(
        val.score_round(&eng, &base, &subs1, 1, DEADLINE, ALPHA, 2, &mut provider).unwrap(),
    );
    // round 2: bob is gone; dave joined on bob's recycled uid 1
    let subs2 = vec![
        sub(&eng, "alice", 0, 2, 7, CLEAN_SCALE),
        sub(&eng, "carol", 2, 2, 8, CLEAN_SCALE),
        sub(&eng, "dave", 1, 2, 9, CLEAN_SCALE),
    ];
    out.push(
        val.score_round(&eng, &base, &subs2, 2, DEADLINE, ALPHA, 2, &mut provider).unwrap(),
    );
    out
}

fn assert_verdicts_identical(a: &[RoundVerdict], b: &[RoundVerdict]) {
    assert_eq!(a.len(), b.len());
    for (va, vb) in a.iter().zip(b) {
        assert_eq!(va.selected, vb.selected);
        assert_eq!(va.weights.len(), vb.weights.len());
        for ((ua, wa), (ub, wb)) in va.weights.iter().zip(&vb.weights) {
            assert_eq!(ua, ub);
            assert_eq!(wa.to_bits(), wb.to_bits());
        }
        assert_eq!(va.per_peer.len(), vb.per_peer.len());
        for (pa, pb) in va.per_peer.iter().zip(&vb.per_peer) {
            assert_eq!(pa.hotkey, pb.hotkey);
            assert_eq!(pa.uid, pb.uid);
            assert_eq!(pa.selected, pb.selected);
            assert_eq!(pa.score.to_bits(), pb.score.to_bits());
            assert_eq!(pa.loss_eval.is_some(), pb.loss_eval.is_some());
            if let (Some(la), Some(lb)) = (pa.loss_eval, pb.loss_eval) {
                assert_eq!(
                    la.assigned_improvement.to_bits(),
                    lb.assigned_improvement.to_bits()
                );
                assert_eq!(
                    la.unassigned_improvement.to_bits(),
                    lb.unassigned_improvement.to_bits()
                );
                assert_eq!(la.suspected_copy, lb.suspected_copy);
            }
        }
    }
}

#[test]
fn scoring_is_deterministic_under_churn_and_recycled_uids() {
    let a = churn_scenario(true);
    let b = churn_scenario(true);
    assert_verdicts_identical(&a, &b);
    // sanity on the scenario itself: the mid-round leaver is rejected,
    // everyone else lands
    assert!(!a[1].per_peer[1].selected, "late leaver must not be selected");
    assert!(a[1].per_peer[1].score < 0.0);
    assert_eq!(a[0].selected.len(), 2); // contributor cap holds
}

#[test]
fn parallel_and_serial_score_round_bit_identical() {
    let par = churn_scenario(true);
    let ser = churn_scenario(false);
    assert_verdicts_identical(&par, &ser);
}

#[test]
fn unproven_peers_never_selected() {
    // Reconstruct the probation set from the verdicts alone: a peer is
    // proven once it has a clean LossScore eval (no copy suspicion, no
    // harmful improvement). Every selected peer must be proven by its
    // selection round — in particular dave, on bob's recycled uid, cannot
    // ride on bob's clearance.
    let verdicts = churn_scenario(true);
    let mut proven: HashSet<String> = HashSet::new();
    for v in &verdicts {
        let clean: HashSet<String> = v
            .per_peer
            .iter()
            .filter(|p| {
                p.loss_eval
                    .map(|le| !le.suspected_copy && le.assigned_improvement >= -5e-3)
                    .unwrap_or(false)
            })
            .map(|p| p.hotkey.clone())
            .collect();
        for p in v.per_peer.iter().filter(|p| p.selected) {
            assert!(
                proven.contains(&p.hotkey) || clean.contains(&p.hotkey),
                "unproven peer {} entered the selected set",
                p.hotkey
            );
        }
        proven.extend(clean);
    }
    // dave was evaluated on arrival (unproven peers are always evaluated)
    let dave = verdicts[2].per_peer.iter().find(|p| p.hotkey == "dave").unwrap();
    assert!(dave.loss_eval.is_some(), "fresh peer on a recycled uid must be evaluated");
}

#[test]
fn whale_excluded_until_clean_then_rehabilitated() {
    // A peer submitting abnormal-norm payloads fails fast checks every
    // round (never evaluated, never proven, never selected) — and once it
    // submits a clean payload it is force-evaluated (unproven) and only
    // then becomes selectable.
    let eng = Engine::from_preset("tiny").unwrap();
    let base = ops::init_params(&eng, 12).unwrap();
    let cfg = GauntletConfig {
        loss_eval_fraction: 1.0,
        eval_batches: 1,
        ..Default::default()
    };
    let mut val = Validator::new(cfg, 0xF00D);
    let mut provider = provider_for(&eng);
    let honest = |round: usize, seed_base: u64| {
        vec![
            sub(&eng, "alice", 0, round, seed_base, CLEAN_SCALE),
            sub(&eng, "bob", 1, round, seed_base + 1, CLEAN_SCALE),
            sub(&eng, "carol", 2, round, seed_base + 2, CLEAN_SCALE),
        ]
    };
    for round in 0..2 {
        let mut subs = honest(round, 10 * (round as u64 + 1));
        // 1000x the honest scale: > max_norm_ratio * median
        subs.push(sub(&eng, "whale", 3, round, 99 + round as u64, CLEAN_SCALE * 1000.0));
        let v = val
            .score_round(&eng, &base, &subs, round, DEADLINE, ALPHA, 8, &mut provider)
            .unwrap();
        let w = v.per_peer.iter().find(|p| p.hotkey == "whale").unwrap();
        assert!(!w.selected, "whale selected in round {round}");
        assert!(w.score < 0.0);
        assert!(w.loss_eval.is_none(), "fast-check failures are not evaluated");
    }
    // round 2: the whale reforms and submits a clean payload
    let mut subs = honest(2, 30);
    subs.push(sub(&eng, "whale", 3, 2, 101, CLEAN_SCALE));
    let v = val.score_round(&eng, &base, &subs, 2, DEADLINE, ALPHA, 8, &mut provider).unwrap();
    let w = v.per_peer.iter().find(|p| p.hotkey == "whale").unwrap();
    assert!(w.loss_eval.is_some(), "unproven peer must be force-evaluated");
    assert!(w.selected, "clean-tested peer becomes selectable");
}

#[test]
fn unproven_peers_forced_into_eval_even_at_zero_fraction() {
    // With loss_eval_fraction = 0 nothing would be evaluated by sampling
    // alone; probation must still force first-round peers through
    // LossScore, and proven peers must remain selectable without
    // re-evaluation.
    let eng = Engine::from_preset("tiny").unwrap();
    let base = ops::init_params(&eng, 13).unwrap();
    let cfg = GauntletConfig {
        loss_eval_fraction: 0.0,
        eval_batches: 1,
        ..Default::default()
    };
    let mut val = Validator::new(cfg, 0xABCD);
    let mut provider = provider_for(&eng);
    let subs0 = vec![
        sub(&eng, "alice", 0, 0, 50, CLEAN_SCALE),
        sub(&eng, "bob", 1, 0, 51, CLEAN_SCALE),
    ];
    let v0 = val.score_round(&eng, &base, &subs0, 0, DEADLINE, ALPHA, 8, &mut provider).unwrap();
    for p in &v0.per_peer {
        assert!(p.loss_eval.is_some(), "unproven {} skipped eval", p.hotkey);
        assert!(p.selected, "clean first-rounder {} not selected", p.hotkey);
    }
    // round 1: both proven; fraction 0 means no evals at all now
    let subs1 = vec![
        sub(&eng, "alice", 0, 1, 52, CLEAN_SCALE),
        sub(&eng, "bob", 1, 1, 53, CLEAN_SCALE),
    ];
    let v1 = val.score_round(&eng, &base, &subs1, 1, DEADLINE, ALPHA, 8, &mut provider).unwrap();
    for p in &v1.per_peer {
        assert!(p.loss_eval.is_none(), "proven {} re-evaluated at fraction 0", p.hotkey);
        assert!(p.selected, "proven {} lost selection", p.hotkey);
    }
    // round 2: a newcomer on a fresh uid is still forced through eval
    let subs2 = vec![
        sub(&eng, "alice", 0, 2, 54, CLEAN_SCALE),
        sub(&eng, "bob", 1, 2, 55, CLEAN_SCALE),
        sub(&eng, "dave", 5, 2, 56, CLEAN_SCALE),
    ];
    let v2 = val.score_round(&eng, &base, &subs2, 2, DEADLINE, ALPHA, 8, &mut provider).unwrap();
    let dave = v2.per_peer.iter().find(|p| p.hotkey == "dave").unwrap();
    assert!(dave.loss_eval.is_some(), "newcomer skipped probation eval");
    let alice = v2.per_peer.iter().find(|p| p.hotkey == "alice").unwrap();
    assert!(alice.loss_eval.is_none());
}
