//! Integration tests over the native engine (tiny config).
//!
//! `Engine::new("artifacts/tiny")` resolves to the `tiny` preset when no
//! AOT artifact directory exists, so these run hermetically — real
//! training dynamics, no Python, no artifacts.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::data::grammar::GrammarKind;
use covenant::data::{Grammar, ShardStore};
use covenant::eval::{EvalSuite, Scorer};
use covenant::runtime::{ops, Engine};
use covenant::sparseloco::{codec, topk};
use covenant::storage::ObjectStore;
use covenant::train::{OuterAlphaSchedule, Schedule, Trainer};
use covenant::util::rng::Rng;

fn artifacts_dir() -> String {
    let root = env!("CARGO_MANIFEST_DIR");
    format!("{root}/artifacts/tiny")
}

fn engine() -> Engine {
    Engine::new(artifacts_dir()).expect("tiny preset resolves without artifacts")
}

#[test]
fn manifest_matches_rust_layout() {
    let eng = engine();
    let man = eng.manifest();
    let cfg = covenant::config::presets::get("tiny").unwrap();
    let lay = covenant::config::Layout::build(&cfg);
    assert_eq!(man.n_alloc, lay.n_alloc);
    assert_eq!(man.n_params, lay.n_params);
    assert_eq!(man.n_chunks, lay.n_chunks());
    // tensor-by-tensor
    assert_eq!(man.tensors.len(), lay.slots.len());
    for (a, b) in man.tensors.iter().zip(&lay.slots) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.offset, b.offset);
        assert_eq!(a.size, b.size);
    }
}

#[test]
fn ops_compress_matches_topk_reference() {
    // ops::compress must stay interchangeable with the library
    // compressor — peers mix both paths (`rust_compress`) and the
    // determinism tests require bit-equality.
    let eng = engine();
    let man = eng.manifest();
    let na = man.n_alloc;
    let mut rng = Rng::new(42);
    let delta: Vec<f32> = (0..na).map(|_| rng.normal() as f32 * 1e-3).collect();
    let ef: Vec<f32> = (0..na).map(|_| rng.normal() as f32 * 1e-4).collect();
    let beta = 0.95f32;
    let (ef_ops, payload_ops) = ops::compress(&eng, &delta, &ef, beta).unwrap();
    let (payload_rs, ef_rs) =
        topk::compress_with_ef(&delta, &ef, beta, man.config.chunk, man.config.topk);
    assert_eq!(payload_ops.idx, payload_rs.idx);
    assert_eq!(payload_ops.codes, payload_rs.codes);
    assert_eq!(payload_ops.scales, payload_rs.scales);
    assert_eq!(ef_ops, ef_rs);
    // decompress agreement: ops path vs pure-Rust scatter
    let dense_ops = ops::decompress(&eng, &payload_ops).unwrap();
    assert_eq!(dense_ops, payload_ops.to_dense());
}

#[test]
fn wire_roundtrip_through_real_payload() {
    let eng = engine();
    let man = eng.manifest();
    let na = man.n_alloc;
    let mut rng = Rng::new(1);
    let delta: Vec<f32> = (0..na).map(|_| rng.normal() as f32 * 1e-3).collect();
    let zeros = vec![0f32; na];
    let (_, payload) = ops::compress(&eng, &delta, &zeros, 0.95).unwrap();
    let wire = codec::encode(&payload);
    // paper geometry: ~14.5 bits/value incl. scales+header
    let bpv = wire.len() as f64 * 8.0 / payload.n_values() as f64;
    assert!(bpv < 15.0, "bits/value = {bpv}");
    let decoded = codec::decode(&wire).unwrap();
    assert_eq!(decoded, payload);
}

#[test]
fn trainer_loss_decreases_on_fixed_batch() {
    let eng = engine();
    let man = eng.manifest().clone();
    let mut t = Trainer::new(&eng, 0).unwrap();
    let g = Grammar::new(man.config.vocab_size, 7);
    let stream = g.stream(GrammarKind::Web, 0, 20_000);
    let mut sampler = covenant::data::BatchSampler::new(
        stream,
        man.config.seq_len,
        man.config.batch_size,
        3,
    );
    let tokens = sampler.batch();
    let mask = sampler.ones_mask();
    let l0 = t.eval(&tokens, &mask).unwrap();
    for _ in 0..8 {
        t.step(&tokens, &mask, 3e-3).unwrap();
    }
    let l1 = t.eval(&tokens, &mask).unwrap();
    assert!(
        l1 < l0 - 0.3,
        "loss did not decrease enough: {l0} -> {l1}"
    );
}

#[test]
fn sparseloco_two_replicas_agree_after_round() {
    // Two peers starting from the same params, after exchanging compressed
    // pseudo-gradients and applying the same outer step, hold identical
    // models (the SparseLoCo synchronization invariant).
    let eng = engine();
    let man = eng.manifest().clone();
    let g = Grammar::new(man.config.vocab_size, 11);
    let params = ops::init_params(&eng, 5).unwrap();
    let h = man.config.inner_steps;
    let lrs = vec![2e-3f32; h];
    let mut payloads = Vec::new();
    let mut replicas = Vec::new();
    for peer in 0..2 {
        let mut tr = Trainer::from_params(&eng, params.clone());
        let stream = g.stream(GrammarKind::Web, peer as u64, 20_000);
        let mut sampler = covenant::data::BatchSampler::new(
            stream,
            man.config.seq_len,
            man.config.batch_size,
            peer as u64,
        );
        let tokens = sampler.round_batch(h);
        let mask = sampler.ones_round_mask(h);
        tr.round(&tokens, &mask, &lrs).unwrap();
        let delta: Vec<f32> =
            params.iter().zip(&tr.params).map(|(g, l)| g - l).collect();
        let zeros = vec![0.0; params.len()];
        let (_, payload) = ops::compress(&eng, &delta, &zeros, 0.95).unwrap();
        payloads.push(payload);
        replicas.push(tr);
    }
    let refs: Vec<&covenant::sparseloco::Payload> = payloads.iter().collect();
    let delta = covenant::coordinator::aggregate(&refs, params.len()).unwrap();
    let new_global_a = ops::outer_step(&eng, &params, &delta, 1.0).unwrap();
    let new_global_b = ops::outer_step(&eng, &params, &delta, 1.0).unwrap();
    assert_eq!(new_global_a, new_global_b);
    // and the outer step moved the model
    let moved = new_global_a
        .iter()
        .zip(&params)
        .filter(|(a, b)| a != b)
        .count();
    assert!(moved > params.len() / 100, "outer step barely moved: {moved}");
}

#[test]
fn network_three_rounds_loss_falls_and_adversaries_filtered() {
    let eng = engine();
    let mut run = RunConfig::default();
    run.artifacts = artifacts_dir();
    run.rounds = 3;
    run.max_contributors = 6;
    run.target_active = 8;
    run.seed = 99;
    let h = eng.manifest().config.inner_steps;
    let mut p = NetworkParams::quick(run, h, 50);
    p.initial_peers = 8;
    p.schedule = Schedule::new(vec![covenant::train::Segment::Constant {
        lr: 2e-3,
        steps: 100_000,
    }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, h);
    p.churn.p_adversarial = 0.3;
    let mut net = Network::new(&eng, p).unwrap();
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..3 {
        let rep = net.run_round().unwrap();
        assert!(rep.contributing <= 6);
        assert!(rep.contributing > 0, "no contributors selected");
        if first_loss.is_none() {
            first_loss = Some(rep.mean_loss);
        }
        last_loss = rep.mean_loss;
        // honest majority: adversaries that did get selected are rare
        assert!(rep.adversarial_selected <= rep.contributing / 2);
        // timeline sanity
        assert!(rep.t_comm() >= 0.0);
        assert!(rep.utilization() > 0.5);
    }
    assert!(
        last_loss < first_loss.unwrap(),
        "loss did not fall: {first_loss:?} -> {last_loss}"
    );
    assert!(net.unique_peers_ever() >= 8);
}

#[test]
fn eval_scorer_runs_and_untrained_model_is_at_chance() {
    let eng = engine();
    let man = eng.manifest();
    let g = Grammar::new(man.config.vocab_size, 42);
    let params = ops::init_params(&eng, 0).unwrap();
    let scorer = Scorer::new(&eng);
    let res = scorer
        .run_suite(&params, &g, EvalSuite::FactsEasy, 40, 1)
        .unwrap();
    assert_eq!(res.n, 40);
    // untrained: near chance (25%), allow wide noise band
    let acc = res.accuracy();
    assert!(acc < 0.6, "untrained accuracy suspiciously high: {acc}");
}

#[test]
fn shard_pipeline_through_object_store() {
    let eng = engine();
    let man = eng.manifest();
    let g = Grammar::new(man.config.vocab_size, 3);
    let ss = ShardStore::new(g, 8192, 8);
    let mut store = ObjectStore::new();
    ss.publish(&mut store, GrammarKind::Web).unwrap();
    let toks = ss.fetch(&mut store, GrammarKind::Web, 2).unwrap();
    assert_eq!(toks.len(), 8192);
    assert!(toks.iter().all(|&t| (t as usize) < man.config.vocab_size));
}
