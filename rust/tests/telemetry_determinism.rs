//! The telemetry spine's two-sided determinism contract.
//!
//! Side one — **pure observation**: telemetry must never perturb the
//! run. A default-off network and a telemetry-enabled network driven
//! from the same seed produce byte-identical global models, identical
//! round verdict accounting, and bit-identical event traces; enabling
//! telemetry changes only what is *recorded*.
//!
//! Side two — **deterministic recording**: what is recorded is itself
//! bit-reproducible. The registry snapshot, the Chrome-trace JSON, and
//! the JSONL run log are byte-identical across rayon pool sizes and
//! across reruns, because the registry uses only commutative u64 adds,
//! snapshots sort keys, and the trace/run-log lanes replay the (already
//! deterministic) event spine in virtual time.
//!
//! The sampled-lanes contract rides along: sampling truncates only the
//! `RoundReport::lanes` detail vector, while `lane_population` carries
//! exact full-population counters either way.
//!
//! Note on configs: an explicitly-constructed "off" config must differ
//! from `TelemetryConfig::default()` — the `COVENANT_TELEMETRY` env var
//! (set for a whole CI pass) flips only *pristine* defaults, and these
//! tests must hold under that pass too.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::RunConfig;
use covenant::coordinator::network::{Network, NetworkParams, RoundReport};
use covenant::netsim::sched::Event;
use covenant::runtime::Engine;
use covenant::telemetry::{lane_population, TelemetryConfig};
use covenant::train::{OuterAlphaSchedule, Schedule, Segment};

const ROUNDS: usize = 2;

/// Telemetry off, but *not* the pristine default, so a CI-wide
/// `COVENANT_TELEMETRY=1` cannot flip it on (see `TelemetryConfig::with_env`).
fn explicit_off() -> TelemetryConfig {
    TelemetryConfig { enabled: false, sample_lanes: 0, trace: false, run_log: false }
}

fn explicit_on(sample_lanes: usize) -> TelemetryConfig {
    TelemetryConfig { enabled: true, sample_lanes, trace: true, run_log: true }
}

fn build_params(seed: u64, peers: usize, n_shards: usize, tcfg: TelemetryConfig) -> NetworkParams {
    let mut run = RunConfig::default();
    run.artifacts = "artifacts/tiny".into();
    run.max_contributors = peers;
    run.target_active = peers;
    run.seed = seed;
    run.n_shards = n_shards;
    run.telemetry = tcfg;
    let mut p = NetworkParams::quick(run, 4, 10);
    p.initial_peers = peers;
    p.churn.p_adversarial = 0.25;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, 4);
    p.rust_compress = true;
    p
}

struct RunOut {
    global: Vec<f32>,
    reports: Vec<RoundReport>,
    /// Per-round event-spine clones (`event_log` is cleared each round).
    traces: Vec<Vec<(f64, Event)>>,
    snapshot_json: String,
    trace_json: Option<String>,
    run_log: Option<String>,
}

fn run_net(eng: &Engine, p: NetworkParams) -> RunOut {
    let mut net = Network::new(eng, p).unwrap();
    let mut traces = Vec::new();
    for _ in 0..ROUNDS {
        net.run_round().unwrap();
        traces.push(net.event_log.clone());
    }
    RunOut {
        global: net.global_params.clone(),
        reports: net.reports.clone(),
        traces,
        snapshot_json: net.telemetry.snapshot().to_json(),
        trace_json: net.telemetry.trace_json(),
        run_log: net.telemetry.run_log_jsonl(),
    }
}

/// The verdict-side accounting that must not move when telemetry turns
/// on (lanes themselves may legitimately differ: sampling truncates).
fn accounting(r: &RoundReport) -> impl PartialEq + std::fmt::Debug {
    (
        (r.round, r.active, r.submitted, r.contributing, r.late_submissions),
        (r.rejected_pre_decode, r.adversarial_submitted, r.adversarial_selected),
        (r.retried_uploads, r.orphaned_slices, r.recovered_shards),
        (r.mean_loss.to_bits(), r.bytes_up, r.bytes_down),
        r.rejections.clone(),
        r.lane_population,
    )
}

fn assert_traces_identical(a: &RunOut, b: &RunOut) {
    assert_eq!(a.traces.len(), b.traces.len());
    for (ta, tb) in a.traces.iter().zip(&b.traces) {
        assert_eq!(ta.len(), tb.len(), "event counts differ");
        for ((t0, e0), (t1, e1)) in ta.iter().zip(tb) {
            assert_eq!(t0.to_bits(), t1.to_bits(), "event time drifted");
            assert_eq!(e0, e1, "event payload drifted");
        }
    }
}

#[test]
fn telemetry_off_vs_on_is_pure_observation() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    for n_shards in [1, 3] {
        let off = run_net(&eng, build_params(0x7E1E, 4, n_shards, explicit_off()));
        let on = run_net(&eng, build_params(0x7E1E, 4, n_shards, explicit_on(0)));

        // the run itself is untouched: model bytes, verdicts, spine
        assert_eq!(off.global, on.global, "global model drifted (n_shards={n_shards})");
        for (ro, rn) in off.reports.iter().zip(&on.reports) {
            assert_eq!(accounting(ro), accounting(rn));
            assert_eq!(ro.lanes.len(), rn.lanes.len(), "sampling off: lanes untouched");
        }
        assert_traces_identical(&off, &on);

        // only what is *recorded* changes
        assert_eq!(off.trace_json, None);
        assert_eq!(off.run_log, None);
        assert!(covenant::telemetry::RegistrySnapshot::default().to_json() == off.snapshot_json);
        let trace = on.trace_json.expect("enabled run records a trace");
        assert!(trace.contains("traceEvents"));
        let log = on.run_log.expect("enabled run records a run log");
        assert_eq!(log.lines().count(), ROUNDS, "one JSONL record per round");
        assert_ne!(on.snapshot_json, off.snapshot_json, "registry saw the run");
    }
}

#[test]
fn recorded_artifacts_bit_identical_across_pools_and_reruns() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let runs: Vec<RunOut> = [1usize, 2, 4]
        .iter()
        .map(|&threads| {
            let pool =
                rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            pool.install(|| run_net(&eng, build_params(0xAB5, 4, 3, explicit_on(0))))
        })
        .chain(std::iter::once(run_net(&eng, build_params(0xAB5, 4, 3, explicit_on(0)))))
        .collect();
    let first = &runs[0];
    assert!(first.trace_json.is_some() && first.run_log.is_some());
    for r in &runs[1..] {
        assert_eq!(r.global, first.global);
        assert_eq!(r.snapshot_json, first.snapshot_json, "snapshot depends on pool size");
        assert_eq!(r.trace_json, first.trace_json, "trace depends on pool size");
        assert_eq!(r.run_log, first.run_log, "run log depends on pool size");
    }
}

#[test]
fn sampled_lane_counters_match_full_population() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let full = run_net(&eng, build_params(0xFACE, 6, 1, explicit_on(0)));
    let sampled = run_net(&eng, build_params(0xFACE, 6, 1, explicit_on(2)));
    assert_eq!(full.global, sampled.global, "sampling is pure observation too");
    for (rf, rs) in full.reports.iter().zip(&sampled.reports) {
        assert!(rs.lanes.len() <= 2, "lane detail truncated to the sample");
        // exact counters survive sampling: both runs carry the counters
        // of the FULL population, and they agree with a recount over the
        // unsampled run's complete lane set
        assert_eq!(rs.lane_population, rf.lane_population);
        assert_eq!(rf.lane_population, lane_population(&rf.lanes));
        // the sampled cohort is a subset of the full lanes, in lane order
        let full_keys: Vec<&str> = rf.lanes.iter().map(|l| l.hotkey.as_str()).collect();
        let mut cursor = 0;
        for l in &rs.lanes {
            let pos = full_keys[cursor..]
                .iter()
                .position(|k| *k == l.hotkey)
                .expect("sampled lane exists in full set, order preserved");
            cursor += pos + 1;
        }
    }
}
