//! Malformed-wire robustness (ISSUE 6 satellite): hostile bytes at the
//! codec and envelope layers must come back as clean `Err`s — never a
//! panic, never an allocation sized by an attacker-controlled length
//! field. Both decoders validate the *exact* buffer length against the
//! header's geometry before touching (or sizing anything from) the
//! variable sections, so every case here is cheap to reject.
//!
//! Every codec case runs through **every kernel mode** (ISSUE 7): the
//! vectorized (SWAR) decode shares all validation with the scalar path —
//! geometry checks happen before any section is parsed in either — so
//! the modes must agree on every `Err`, and byte-for-byte on every `Ok`.

use covenant::runtime::kernels::KernelMode;
use covenant::sparseloco::{codec, envelope, topk, Payload};
use covenant::util::rng::Rng;

/// A small valid payload (3 chunks of 64, k = 4 -> 45 wire bytes).
fn payload() -> Payload {
    let mut rng = Rng::new(0x0B0E);
    let dense: Vec<f32> = (0..3 * 64).map(|_| rng.normal() as f32 * 0.01).collect();
    topk::compress_dense(&dense, 64, 4)
}

/// Decode under every kernel mode; assert the modes agree (same Err-ness,
/// byte-identical payload on Ok) and return the scalar result.
fn decode_all_modes(bytes: &[u8]) -> anyhow::Result<Payload> {
    let reference = codec::decode_mode(bytes, KernelMode::Reference);
    for mode in [KernelMode::Blocked, KernelMode::Simd] {
        let got = codec::decode_mode(bytes, mode);
        match (&reference, &got) {
            (Ok(a), Ok(b)) => assert_eq!(a, b, "{mode:?} decoded differently"),
            (Err(_), Err(_)) => {}
            _ => panic!(
                "{mode:?} disagrees with Reference on Err-ness: {} vs {}",
                reference.is_ok(),
                got.is_ok()
            ),
        }
    }
    reference
}

#[test]
fn every_truncation_of_a_codec_buffer_errs() {
    let bytes = codec::encode(&payload());
    for len in 0..bytes.len() {
        assert!(decode_all_modes(&bytes[..len]).is_err(), "prefix of {len} bytes decoded");
    }
}

#[test]
fn oversized_codec_buffers_err() {
    let bytes = codec::encode(&payload());
    for extra in [1usize, 7, 100, 4096] {
        let mut b = bytes.clone();
        b.resize(bytes.len() + extra, 0);
        assert!(decode_all_modes(&b).is_err(), "{extra} trailing bytes decoded");
    }
}

#[test]
fn header_bit_flips_are_rejected_or_at_worst_reinterpreted() {
    let p = payload();
    let bytes = codec::encode(&p);
    for pos in 0..12usize {
        for bit in 0..8u8 {
            let mut b = bytes.clone();
            b[pos] ^= 1 << bit;
            let out = decode_all_modes(&b);
            match pos {
                // magic / version / k / n_chunks: every flip breaks an
                // invariant the decoder checks up front (the k and
                // n_chunks fields feed the exact-length check — wire
                // size is strictly monotone in n_chunks * k, so any
                // change mismatches the buffer).
                0..=6 | 8..=11 => {
                    assert!(out.is_err(), "flip at byte {pos} bit {bit} decoded");
                }
                // chunk_log2 does not affect the wire size: a flip may
                // parse (smaller/larger chunk space) as long as every
                // index still validates — but it can never panic, and
                // it can never reproduce the original payload.
                _ => {
                    if let Ok(q) = out {
                        assert_ne!(q, p, "flip at byte {pos} bit {bit} round-tripped");
                    }
                }
            }
        }
    }
}

#[test]
fn body_bit_flips_never_panic_and_never_oom() {
    // Scales/codes/indices corruption: decode may succeed with garbage
    // content (the tag-checked envelope layer is what rejects tampering)
    // or fail index validation — either way it returns, cleanly, with
    // all kernel modes in agreement (index corruption especially: the
    // SWAR 12-bit extraction must truncate hostile fields exactly like
    // the scalar shift-and-mask).
    let bytes = codec::encode(&payload());
    for pos in 12..bytes.len() {
        for bit in 0..8u8 {
            let mut b = bytes.clone();
            b[pos] ^= 1 << bit;
            let _ = decode_all_modes(&b);
        }
    }
}

#[test]
fn hostile_chunk_counts_bounce_off_the_length_check() {
    let bytes = codec::encode(&payload());
    // n_chunks = u32::MAX with a 45-byte buffer: the expected size
    // computation happens before any section is sliced or any vector is
    // sized — in every kernel mode — so this is a cheap Err, not a
    // 16-GiB allocation attempt.
    for hostile in [u32::MAX, u32::MAX / 2, 1 << 24, 0] {
        let mut b = bytes.clone();
        b[8..12].copy_from_slice(&hostile.to_le_bytes());
        assert!(decode_all_modes(&b).is_err(), "n_chunks={hostile} decoded");
    }
}

#[test]
fn every_truncation_of_a_sealed_envelope_errs() {
    let wire = codec::encode(&payload());
    let key = envelope::SigningKey::derive(0x0B0E, "hk-00000");
    let sealed = envelope::seal(&wire, "hk-00000", 3, 0, 3, &key);
    for len in 0..sealed.len() {
        assert!(envelope::open(&sealed[..len]).is_err(), "prefix of {len} bytes opened");
        // the compat path routes sealed-magic prefixes to open() and
        // everything else to the bare codec — both reject truncations
        assert!(
            envelope::decode_compat(&sealed[..len]).is_err(),
            "truncated envelope decoded at {len}"
        );
    }
}

#[test]
fn hostile_envelope_length_fields_err_without_allocating() {
    let wire = codec::encode(&payload());
    let key = envelope::SigningKey::derive(0x0B0E, "hk-00000");
    let sealed = envelope::seal(&wire, "hk-00000", 3, 0, 3, &key);
    // hotkey_len = u16::MAX
    let mut b = sealed.clone();
    b[6..8].copy_from_slice(&u16::MAX.to_le_bytes());
    assert!(envelope::open(&b).is_err());
    // payload_len = u32::MAX: the expected-length sum is computed in u64
    // so it cannot overflow into a "valid" small value
    let mut b = sealed.clone();
    b[28..32].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(envelope::open(&b).is_err());
    // the untampered buffer still opens and verifies, as a control —
    // and the inner payload decodes identically under every kernel mode
    let env = envelope::open(&sealed).unwrap();
    assert!(env.verify(&key.verifying()));
    assert_eq!(decode_all_modes(env.payload).unwrap(), payload());
}

#[test]
fn envelope_bit_flips_never_verify_clean() {
    let wire = codec::encode(&payload());
    let key = envelope::SigningKey::derive(0x0B0E, "hk-00001");
    let sealed = envelope::seal(&wire, "hk-00001", 1, 0, 1, &key);
    let vk = key.verifying();
    for pos in 0..sealed.len() {
        let mut b = sealed.clone();
        b[pos] ^= 1;
        if let Ok(env) = envelope::open(&b) {
            assert!(!env.verify(&vk), "tamper at byte {pos} verified clean");
        }
    }
}

#[test]
fn hostile_wire_bytes_same_err_in_every_mode_fuzz() {
    // Random garbage with a valid magic/version prefix (so it reaches
    // the geometry checks): every mode must agree on the outcome, byte
    // for byte when Ok. Deterministic "fuzz" — seeded, so a failure is
    // reproducible.
    let mut rng = Rng::new(0xF0_22);
    for _ in 0..200 {
        let len = rng.below(160) + 12;
        let mut b: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        b[0..4].copy_from_slice(b"CVPG");
        b[4..6].copy_from_slice(&1u16.to_le_bytes());
        let _ = decode_all_modes(&b);
    }
}
