//! Deterministic adversary gauntlet (ISSUE 6): the payload-auth trust
//! boundary under attack.
//!
//! 1. **Pre-decode rejection** — forged envelopes (`BadSignature`) and
//!    replayed ones (`ReplayedPayload`) are rejected by signature +
//!    nonce-freshness checks before any codec decode: the pre-verdicts
//!    pre-empt the fast-check battery and the rejected bytes land only
//!    in the shards' rejected accounting.
//! 2. **Honest parity** — with the full adversary cohort injected
//!    (sybil swarm, replayer, forger, shard spammer, gradient-inflation
//!    whale), the honest peers' global model stays *byte-identical* to
//!    the adversary-free run, at `n_shards` 1 and 3.
//! 3. **Determinism** — every adversary scenario reproduces bit-exactly
//!    across reruns: global params, event traces, auth counters.
//!
//! The cohort is injected via `RunConfig::adversary` (appended after the
//! honest initial peers, so honest identities and RNG streams are
//! untouched) and churn is frozen (`p_leave = 0`,
//! `max_joins_per_round = 0`) so the population is exactly the
//! configured one for the whole run.

#![allow(clippy::field_reassign_with_default)]

use covenant::config::run::{AdversaryConfig, RunConfig};
use covenant::coordinator::network::{Network, NetworkParams};
use covenant::coordinator::shard::ShardedNetwork;
use covenant::gauntlet::auth::AuthStats;
use covenant::netsim::Event;
use covenant::runtime::Engine;
use covenant::train::{OuterAlphaSchedule, Schedule, Segment};

const HONEST: usize = 4;

fn build_params(seed: u64, adv: AdversaryConfig) -> NetworkParams {
    let mut run = RunConfig::default();
    run.artifacts = "artifacts/tiny".into();
    run.max_contributors = HONEST;
    run.target_active = HONEST;
    run.seed = seed;
    run.adversary = adv;
    let mut p = NetworkParams::quick(run, 4, 10);
    p.initial_peers = HONEST;
    p.churn.p_adversarial = 0.0;
    // Exactly-frozen population: no leaves, and the speculative-join
    // roll is clamped to zero, so the cohort is precisely HONEST honest
    // peers + the injected adversaries for every round.
    p.churn.p_leave = 0.0;
    p.churn.max_joins_per_round = 0;
    p.p_slow_upload = 0.0;
    p.schedule = Schedule::new(vec![Segment::Constant { lr: 2e-3, steps: 1 << 20 }]);
    p.alpha = OuterAlphaSchedule::scaled(1.0, 4);
    p
}

fn full_cohort() -> AdversaryConfig {
    AdversaryConfig {
        sybils: 2,
        replayers: 1,
        forgers: 1,
        shard_spammers: 1,
        spam_shard: 1,
        whales: 1,
    }
}

#[test]
fn forged_and_replayed_payloads_are_rejected_before_decode() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let adv = AdversaryConfig { sybils: 2, replayers: 1, forgers: 1, ..Default::default() };
    let mut net = Network::new(&eng, build_params(0x6A, adv)).unwrap();
    for round in 0..3usize {
        let rep = net.run_round().unwrap();
        assert_eq!(rep.contributing, HONEST, "round {round}: {:?}", rep.rejections);
        assert_eq!(rep.adversarial_selected, 0, "no adversary ever aggregates");
        // Round 0: the forger (BadSignature) and the second sybil
        // (shared window already advanced -> ReplayedPayload) are
        // rejected pre-decode; the replayer has no previous round to
        // replay yet, so it degenerates to a validly signed empty
        // payload (caught by the Empty fast check, not by auth). From
        // round 1 on, the replayer's verbatim copy of a victim's
        // previous-round slices carries a stale nonce and joins them.
        let expect = if round == 0 { 2 } else { 3 };
        assert_eq!(rep.rejected_pre_decode, expect, "round {round}: {:?}", rep.rejections);
        assert!(
            rep.rejections.iter().any(|r| r.contains("BadSignature")),
            "round {round}: forger missing from rejections: {:?}",
            rep.rejections
        );
        assert!(
            rep.rejections.iter().any(|r| r.contains("ReplayedPayload")),
            "round {round}: replay missing from rejections: {:?}",
            rep.rejections
        );
        if round > 0 {
            // Both flavours of replay are live: the sybil bouncing off
            // the shared window AND the free-rider replaying a victim.
            let replays =
                rep.rejections.iter().filter(|r| r.contains("ReplayedPayload")).count();
            assert_eq!(replays, 2, "round {round}: {:?}", rep.rejections);
        }
    }
    // Lifetime auth counters: per round, HONEST honest + 1 sybil master
    // (+ the replayer's fallback in round 0) verify; the forger is a
    // BadSignature every round; replays accumulate as above.
    assert_eq!(
        net.auth.stats,
        AuthStats {
            verified: (HONEST as u64 + 1) * 3 + 1,
            bad_signature: 3,
            replayed: 1 + 2 + 2,
        }
    );
}

#[test]
fn honest_aggregate_is_byte_identical_to_the_adversary_free_run() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let rounds = 3usize;
    for n_shards in [1usize, 3] {
        let mut clean =
            ShardedNetwork::new(&eng, build_params(0x5EC, AdversaryConfig::default()), n_shards)
                .unwrap();
        let mut attacked =
            ShardedNetwork::new(&eng, build_params(0x5EC, full_cohort()), n_shards).unwrap();
        for round in 0..rounds {
            let rc = clean.run_round().unwrap();
            let ra = attacked.run_round().unwrap();
            // The same honest peers are selected under attack; every
            // adversary bounces off auth or the fast checks.
            assert_eq!(rc.contributing, HONEST);
            assert_eq!(ra.contributing, HONEST, "round {round}: {:?}", ra.rejections);
            assert_eq!(ra.adversarial_selected, 0);
            assert!(ra.rejected_pre_decode >= 3, "sybil#2 + forger + spammer at least");
        }
        assert_eq!(
            clean.net.global_params, attacked.net.global_params,
            "n_shards={n_shards}: the adversary cohort must not move a single \
             bit of the honest aggregate"
        );
    }
}

#[test]
fn adversary_scenarios_are_deterministic_across_reruns() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let rounds = 3usize;
    let run_once = || {
        let mut net = Network::new(&eng, build_params(0xD7, full_cohort())).unwrap();
        let mut rejections = Vec::new();
        for _ in 0..rounds {
            rejections.extend(net.run_round().unwrap().rejections);
        }
        (net.global_params.clone(), net.event_log.clone(), net.auth.stats, rejections)
    };
    let (params_a, events_a, stats_a, rej_a) = run_once();
    let (params_b, events_b, stats_b, rej_b) = run_once();
    assert_eq!(params_a, params_b, "global params reproduce bit-exactly");
    assert_eq!(stats_a, stats_b, "auth counters reproduce");
    assert_eq!(rej_a, rej_b, "verdict strings reproduce");
    assert_eq!(events_a.len(), events_b.len());
    for (a, b) in events_a.iter().zip(&events_b) {
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "event times reproduce bit-exactly");
        assert_eq!(a.1, b.1, "event order reproduces");
    }
}

#[test]
fn shard_targeted_spam_lands_in_the_target_shards_accounting() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let rounds = 2usize;
    let adv = AdversaryConfig { shard_spammers: 1, spam_shard: 1, ..Default::default() };
    let mut net = ShardedNetwork::new(&eng, build_params(0x3AD, adv), 3).unwrap();
    for round in 0..rounds {
        let rep = net.run_round().unwrap();
        assert_eq!(rep.rejected_pre_decode, 1, "round {round}: {:?}", rep.rejections);
        assert!(rep.rejections.iter().any(|r| r.contains("BadSignature")));
        // The junk slice landing on its target is visible on the event
        // spine, once per round, aimed at the configured shard.
        let spam: Vec<usize> = net
            .net
            .event_log
            .iter()
            .filter_map(|(_, e)| match e {
                Event::AdversarySpam { shard, .. } => Some(*shard),
                _ => None,
            })
            .collect();
        assert_eq!(spam, vec![1], "round {round}: once per round, at the target");
    }
    // Every shard refused its slice of the spammer's submission, but the
    // 4x-oversized junk was aimed at shard 1: the byte accounting says
    // exactly where the attack bandwidth went.
    let shards = net.shards();
    assert!(shards.iter().all(|s| s.rejected_slices == rounds as u64));
    assert!(
        shards[1].rejected_bytes > shards[0].rejected_bytes
            && shards[1].rejected_bytes > shards[2].rejected_bytes,
        "target shard absorbed the junk: {:?}",
        shards.iter().map(|s| s.rejected_bytes).collect::<Vec<_>>()
    );
}

#[test]
fn sybil_swarm_shares_one_window_one_submission_per_round() {
    let eng = Engine::new("artifacts/tiny").unwrap();
    let adv = AdversaryConfig { sybils: 3, ..Default::default() };
    let mut net = Network::new(&eng, build_params(0x5B1, adv)).unwrap();
    for round in 0..2usize {
        let rep = net.run_round().unwrap();
        // One shared key, one accepted envelope per round: the other two
        // swarm members bounce off the shared replay window pre-decode.
        assert_eq!(rep.rejected_pre_decode, 2, "round {round}: {:?}", rep.rejections);
        // The swarm master that does get through is liveness-only (empty
        // payload) and is caught by the ordinary fast checks.
        assert!(
            rep.rejections.iter().any(|r| r.contains("Empty")),
            "round {round}: {:?}",
            rep.rejections
        );
        assert_eq!(rep.contributing, HONEST);
    }
    assert_eq!(
        net.auth.stats,
        AuthStats { verified: (HONEST as u64 + 1) * 2, bad_signature: 0, replayed: 4 }
    );
}

#[test]
fn legacy_unsigned_mode_still_runs_with_bare_wire_bytes() {
    use covenant::sparseloco::codec;
    let eng = Engine::new("artifacts/tiny").unwrap();
    let man = eng.manifest().clone();
    let mut p = build_params(0x01D, AdversaryConfig::default());
    p.run.sign_payloads = false;
    let mut net = Network::new(&eng, p).unwrap();
    let rep = net.run_round().unwrap();
    assert_eq!(rep.contributing, HONEST);
    assert_eq!(rep.rejected_pre_decode, 0);
    assert_eq!(net.auth.stats, AuthStats::default(), "auth never consulted");
    // Bare codec bytes on the wire: no envelope header, no hotkey.
    let bare = codec::wire_size(man.n_chunks, man.config.topk) as u64;
    assert_eq!(rep.bytes_up, HONEST as u64 * bare);
}
