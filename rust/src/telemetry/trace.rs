//! Chrome/Perfetto trace export: replay the netsim event spine into
//! per-peer and per-host tracks in **virtual time**.
//!
//! The output is the Chrome Trace Event JSON format (`{"traceEvents":
//! [...]}`), openable directly at `ui.perfetto.dev` (Open trace file)
//! or `chrome://tracing`. Three synthetic processes give the track
//! layout:
//!
//! | pid | track            | rows (tid)                 |
//! |-----|------------------|----------------------------|
//! | 0   | run              | rounds / deadline / barrier |
//! | 1   | peers            | one row per peer uid        |
//! | 2   | shard hosts      | one row per host            |
//!
//! Determinism: timestamps are *virtual-time* integer microseconds
//! (never wall clock), events are appended in the round engine's
//! deterministic replay order, and serde_json's object map is a
//! `BTreeMap`, so the serialized bytes are identical across thread
//! counts and reruns. `ChainBlock` events are deliberately not
//! exported (hundreds of uniform ticks per round would drown the
//! interesting tracks); they remain in `Network::event_log`.

use serde_json::{json, Value};
use std::collections::BTreeSet;

use crate::coordinator::network::RoundReport;
use crate::netsim::sched::Event;

/// pid for the run-level track (round spans, deadline/barrier instants).
const PID_RUN: u64 = 0;
/// pid for per-peer tracks (tid = peer uid).
const PID_PEERS: u64 = 1;
/// pid for per-host tracks (tid = host index).
const PID_HOSTS: u64 = 2;

/// Virtual seconds -> integer trace microseconds. Callers never pass
/// non-finite times (stalled-upload `+inf` ends are clamped to the
/// deadline first), but clamp defensively anyway.
fn us(t: f64) -> u64 {
    if t.is_finite() {
        (t.max(0.0) * 1e6).round() as u64
    } else {
        0
    }
}

/// Incremental trace builder; one [`TraceBuilder::add_round`] call per
/// completed round.
#[derive(Default)]
pub struct TraceBuilder {
    events: Vec<Value>,
    named_procs: BTreeSet<u64>,
    named_threads: BTreeSet<(u64, u64)>,
}

impl TraceBuilder {
    /// An empty trace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of trace events recorded so far.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether no events have been recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    fn name_process(&mut self, pid: u64, name: &str) {
        if self.named_procs.insert(pid) {
            self.events.push(json!({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": name},
            }));
        }
    }

    fn name_thread(&mut self, pid: u64, tid: u64, name: &str) {
        if self.named_threads.insert((pid, tid)) {
            self.events.push(json!({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "args": {"name": name},
            }));
        }
    }

    /// Complete ("X") span on `[a, b)`.
    fn span(&mut self, pid: u64, tid: u64, name: String, a: f64, b: f64, args: Value) {
        let ts = us(a);
        self.events.push(json!({
            "ph": "X", "name": name, "pid": pid, "tid": tid,
            "ts": ts, "dur": us(b).saturating_sub(ts),
            "args": args,
        }));
    }

    /// Thread-scoped instant ("i") marker.
    fn instant(&mut self, pid: u64, tid: u64, name: String, t: f64) {
        self.events.push(json!({
            "ph": "i", "s": "t", "name": name, "pid": pid, "tid": tid,
            "ts": us(t),
        }));
    }

    /// Replay one completed round: lane segments become per-peer spans,
    /// shard lanes become per-host gather/takeover spans, and the raw
    /// event spine contributes the crash/reassignment/retry instants.
    pub fn add_round(&mut self, rep: &RoundReport, events: &[(f64, Event)]) {
        self.name_process(PID_RUN, "run");
        self.name_thread(PID_RUN, 0, "rounds");

        // Run-level round span + deadline marker.
        self.span(
            PID_RUN,
            0,
            format!("round {}", rep.round),
            rep.t_start,
            rep.t_comm_end,
            json!({
                "active": rep.active,
                "submitted": rep.submitted,
                "selected": rep.contributing,
                "late": rep.late_submissions,
                "mean_loss": rep.mean_loss,
            }),
        );
        self.instant(PID_RUN, 0, format!("deadline r{}", rep.round), rep.deadline);

        // Per-peer lanes (possibly a sampled subset — membership is the
        // deterministic bottom-k of lane_hash, see telemetry::sample).
        if !rep.lanes.is_empty() {
            self.name_process(PID_PEERS, "peers");
        }
        for l in &rep.lanes {
            let tid = l.uid as u64;
            self.name_thread(PID_PEERS, tid, &l.hotkey);
            let args = json!({"round": rep.round, "tier": format!("{:?}", l.tier)});
            if let Some((a, b)) = l.compute {
                self.span(PID_PEERS, tid, "compute".to_string(), a, b, args.clone());
            }
            if let Some((a, b)) = l.upload {
                if b.is_finite() {
                    self.span(PID_PEERS, tid, "upload".to_string(), a, b, args.clone());
                } else {
                    // stalled upload: clamp to the deadline cut, tag it
                    let mut stalled = args.clone();
                    stalled["stalled"] = json!(true);
                    self.span(
                        PID_PEERS,
                        tid,
                        "upload (stalled)".to_string(),
                        a,
                        rep.deadline.max(a),
                        stalled,
                    );
                }
            }
            if let Some((a, b)) = l.download {
                self.span(PID_PEERS, tid, "download".to_string(), a, b, args.clone());
            }
            if l.late {
                self.instant(PID_PEERS, tid, "late".to_string(), rep.deadline);
            }
        }

        // Shard-host lanes: gather window + outer-step barrier, plus the
        // fail-over takeover window when a crash was detected.
        if !rep.shard_lanes.is_empty() {
            self.name_process(PID_HOSTS, "shard hosts");
            let barrier = rep.shard_lanes[0].applied_at;
            if barrier.is_finite() {
                self.instant(
                    PID_RUN,
                    0,
                    format!("outer-step barrier r{}", rep.round),
                    barrier,
                );
            }
            for sl in &rep.shard_lanes {
                let tid = sl.host as u64;
                self.name_thread(PID_HOSTS, tid, &format!("host {}", sl.host));
                if sl.ready_at.is_finite() {
                    self.span(
                        PID_HOSTS,
                        tid,
                        format!("shard {} gather", sl.shard),
                        rep.t_compute_end.min(sl.ready_at),
                        sl.ready_at,
                        json!({
                            "round": rep.round,
                            "bytes": sl.bytes,
                            "chunks": [sl.chunk0, sl.chunk1],
                        }),
                    );
                }
                if let Some((from, t_detect, recovered_at)) = sl.takeover {
                    self.span(
                        PID_HOSTS,
                        tid,
                        format!("shard {} takeover", sl.shard),
                        t_detect,
                        recovered_at,
                        json!({"round": rep.round, "from": from}),
                    );
                }
            }
        }

        // Raw spine instants: crashes, reassignment, retries, spam.
        for &(t, ev) in events {
            match ev {
                Event::HostCrash { host } => {
                    self.name_process(PID_HOSTS, "shard hosts");
                    self.name_thread(PID_HOSTS, host as u64, &format!("host {host}"));
                    self.instant(PID_HOSTS, host as u64, "host crash".to_string(), t);
                }
                Event::ShardReassigned { shard, from, to } => {
                    self.name_process(PID_HOSTS, "shard hosts");
                    self.name_thread(PID_HOSTS, to as u64, &format!("host {to}"));
                    self.instant(
                        PID_HOSTS,
                        to as u64,
                        format!("shard {shard} reassigned {from}->{to}"),
                        t,
                    );
                }
                Event::ShardAnnounce { shard, host } => {
                    self.name_process(PID_HOSTS, "shard hosts");
                    self.name_thread(PID_HOSTS, host as u64, &format!("host {host}"));
                    self.instant(PID_HOSTS, host as u64, format!("announce shard {shard}"), t);
                }
                Event::UploadRetry { peer, shard, attempt } => {
                    if let Some(l) = rep.lanes.get(peer) {
                        self.instant(
                            PID_PEERS,
                            l.uid as u64,
                            format!("retry shard {shard} #{attempt}"),
                            t,
                        );
                    }
                }
                Event::AdversarySpam { peer, shard } => {
                    if let Some(l) = rep.lanes.get(peer) {
                        self.instant(
                            PID_PEERS,
                            l.uid as u64,
                            format!("spam shard {shard}"),
                            t,
                        );
                    }
                }
                // Covered by the lane spans above (ComputeDone/UploadDone/
                // ShardUploadDone/DownloadDone/ShardAggregated/DeadlineHit)
                // or too dense to chart (ChainBlock).
                _ => {}
            }
        }
    }

    /// Serialize to the Chrome Trace Event JSON envelope. Object keys
    /// are sorted (BTreeMap) and the event array keeps insertion order,
    /// so the bytes are deterministic.
    pub fn to_json(&self) -> String {
        json!({
            "displayTimeUnit": "ms",
            "traceEvents": Value::Array(self.events.clone()),
        })
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::network::PeerLane;
    use crate::netsim::ComputeTier;

    fn report() -> RoundReport {
        RoundReport {
            round: 0,
            t_start: 0.0,
            t_compute_end: 100.0,
            t_comm_end: 110.0,
            deadline: 120.0,
            active: 1,
            submitted: 1,
            contributing: 1,
            adversarial_submitted: 0,
            adversarial_selected: 0,
            late_submissions: 0,
            rejected_pre_decode: 0,
            mean_loss: 1.0,
            bytes_up: 64,
            bytes_down: 0,
            retried_uploads: 0,
            orphaned_slices: 0,
            recovered_shards: 0,
            outer_alpha: 1.0,
            rejections: Vec::new(),
            lanes: vec![PeerLane {
                uid: 3,
                hotkey: "hk-00003".into(),
                tier: ComputeTier::Median,
                compute: Some((0.0, 100.0)),
                upload: Some((100.0, f64::INFINITY)),
                download: None,
                late: true,
                retry_at: Vec::new(),
            }],
            shard_lanes: Vec::new(),
            lane_population: Default::default(),
        }
    }

    #[test]
    fn round_replay_emits_valid_deterministic_json() {
        let mut tb = TraceBuilder::new();
        tb.add_round(&report(), &[(5.0, Event::HostCrash { host: 1 })]);
        assert!(!tb.is_empty());
        let j = tb.to_json();
        let v: serde_json::Value = serde_json::from_str(&j).unwrap();
        let evs = v["traceEvents"].as_array().unwrap();
        assert!(!evs.is_empty());
        // every X event carries the required fields with integer ts/dur
        for e in evs.iter().filter(|e| e["ph"] == "X") {
            for field in ["name", "ts", "dur", "pid", "tid"] {
                assert!(e.get(field).is_some(), "missing {field}: {e}");
            }
            assert!(e["ts"].is_u64() && e["dur"].is_u64(), "integer virtual time: {e}");
        }
        // the stalled upload was clamped to the deadline, not +inf
        let stalled = evs
            .iter()
            .find(|e| e["name"] == "upload (stalled)")
            .expect("stalled upload span present");
        assert_eq!(stalled["ts"].as_u64().unwrap(), 100_000_000);
        assert_eq!(stalled["dur"].as_u64().unwrap(), 20_000_000);
        assert_eq!(stalled["args"]["stalled"], serde_json::json!(true));
        // crash instant landed on the host track
        assert!(evs.iter().any(|e| e["ph"] == "i" && e["name"] == "host crash"));
        // identical replay -> identical bytes
        let mut tb2 = TraceBuilder::new();
        tb2.add_round(&report(), &[(5.0, Event::HostCrash { host: 1 })]);
        assert_eq!(j, tb2.to_json());
    }
}
