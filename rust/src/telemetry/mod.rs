//! Deterministic telemetry spine: structured counters/histograms,
//! Perfetto trace export, JSONL run logs, and sampled metrics lanes.
//!
//! The whole subsystem is **pure observation**. The hard contract,
//! pinned by `tests/telemetry_determinism.rs` and a dedicated CI pass:
//!
//! * With [`TelemetryConfig`] default-off (the default), every model
//!   byte, verdict, and event trace is byte-identical to a run without
//!   this module compiled in at all — the disabled handle is a `None`
//!   and every record call is a single branch.
//! * Enabling telemetry changes only what is *recorded*, never what is
//!   computed: no RNG draws, no timing contributions, no control flow.
//! * Snapshots and exports are **bit-deterministic** across serial and
//!   parallel execution and across reruns. This falls out of two rules:
//!   the registry performs only commutative atomic adds (order under
//!   rayon cannot matter), and nothing derived from wall-clock time is
//!   ever recorded — histograms hold counts, byte sizes, and *virtual*
//!   time in integer microseconds ([`registry::log2_bucket`] is pure
//!   integer math, no float bucket boundaries to accumulate error).
//!
//! Layout:
//!
//! * [`registry`] — typed metric registry: counters, gauges, fixed
//!   65-bucket log2 histograms; `RegistrySnapshot` with stable JSON.
//! * [`span`] — scoped spans around hot paths (round engine phases,
//!   gauntlet scoring, shard aggregation). A span is a pair of named
//!   counters (`span.<name>.calls` / `.completed`); wall-clock timing is
//!   deliberately excluded from the deterministic registry (the engine's
//!   `exec_stats` remains the wall-clock profile lane).
//! * [`trace`] — Chrome/Perfetto `trace.json` exporter replaying the
//!   netsim event spine into per-peer and per-host tracks in virtual
//!   time; open the file at `ui.perfetto.dev`.
//! * [`runlog`] — JSONL structured run log, one record per round, plus
//!   the CSV bridge for `metrics::write_csv`.
//! * [`sample`] — deterministic lane sampling keyed by a pure hash of
//!   (run seed, hotkey), with exact [`LanePopulation`] counters kept
//!   alongside so `RoundReport` lane cost is O(sample), not O(peers).

pub mod registry;
pub mod runlog;
pub mod sample;
pub mod span;
pub mod trace;

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::network::RoundReport;
use crate::netsim::sched::Event;

pub use registry::{MetricRegistry, MetricValue, RegistrySnapshot};
pub use sample::{
    lane_hash, lane_hash_finish, lane_hash_prefix, lane_population, sample_indices,
    sample_lanes, LanePopulation,
};
pub use span::SpanGuard;
pub use trace::TraceBuilder;

/// Telemetry configuration (a `RunConfig` block; also settable from JSON
/// under `"telemetry"`). Default-off: the degenerate config records
/// nothing and costs one branch per call site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Master switch. Off (the default) keeps runs byte-identical to
    /// pre-telemetry behavior; the handle holds no state at all.
    pub enabled: bool,
    /// Keep only this many peer lanes per `RoundReport`, chosen by the
    /// deterministic bottom-k of `lane_hash(run seed, hotkey)`. `0`
    /// (the default) keeps every lane. Exact population counters are
    /// recorded in `RoundReport::lane_population` either way, so the
    /// sampled report loses rendering detail, never accounting.
    pub sample_lanes: usize,
    /// Build the Perfetto `trace.json` event stream.
    pub trace: bool,
    /// Build the JSONL structured run log (one record per round).
    pub run_log: bool,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self { enabled: false, sample_lanes: 0, trace: true, run_log: true }
    }
}

impl TelemetryConfig {
    /// Resolve the ambient `COVENANT_TELEMETRY` env var: an explicitly
    /// configured (non-pristine-default) config always wins; only the
    /// pristine default picks up the env switch (`"1"`/`"true"`/`"on"`).
    /// Same precedence rule as `FaultConfig::with_env`.
    pub fn with_env(self, env: Option<&str>) -> Self {
        if self != TelemetryConfig::default() {
            return self;
        }
        match env {
            Some("1") | Some("true") | Some("on") => Self { enabled: true, ..self },
            _ => self,
        }
    }
}

/// Shared state behind an enabled handle.
struct Inner {
    cfg: TelemetryConfig,
    registry: MetricRegistry,
    trace: Mutex<TraceBuilder>,
    run_log: Mutex<Vec<serde_json::Value>>,
}

/// The telemetry handle threaded through the stack (network, validator,
/// shard set, peer fan-out). Cheap to clone (an `Option<Arc>`); the
/// disabled handle — [`Telemetry::default`] — is a `None`, so every
/// record call on the hot path is a single branch. `Send + Sync`:
/// counter/histogram updates are commutative atomic adds, safe (and
/// bit-deterministic) from inside the rayon fan-out.
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry").field("enabled", &self.enabled()).finish()
    }
}

/// Convert a *virtual-time* duration (seconds) to integer microseconds
/// for histogram observation. Non-finite or negative durations yield
/// `None` (never recorded): stalled uploads carry `+inf` sentinels that
/// must not poison a histogram.
pub(crate) fn virtual_us(dt_s: f64) -> Option<u64> {
    if dt_s.is_finite() && dt_s >= 0.0 {
        Some((dt_s * 1e6).round() as u64)
    } else {
        None
    }
}

impl Telemetry {
    /// Build a handle from a resolved config. A disabled config returns
    /// the stateless disabled handle.
    pub fn new(cfg: TelemetryConfig) -> Self {
        if !cfg.enabled {
            return Self::default();
        }
        Self {
            inner: Some(Arc::new(Inner {
                cfg,
                registry: MetricRegistry::new(),
                trace: Mutex::new(TraceBuilder::new()),
                run_log: Mutex::new(Vec::new()),
            })),
        }
    }

    /// The stateless disabled handle (same as `Telemetry::default()`).
    pub fn disabled() -> Self {
        Self::default()
    }

    /// Whether this handle records anything at all.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// The resolved config, when enabled.
    pub fn config(&self) -> Option<&TelemetryConfig> {
        self.inner.as_deref().map(|i| &i.cfg)
    }

    /// `Some(k)` when lane sampling is active (enabled and
    /// `sample_lanes > 0`), else `None` (keep full lanes).
    pub fn sample_lanes(&self) -> Option<usize> {
        match self.inner.as_deref() {
            Some(i) if i.cfg.sample_lanes > 0 => Some(i.cfg.sample_lanes),
            _ => None,
        }
    }

    /// Add `n` to the named counter (commutative atomic add — safe from
    /// the rayon fan-out without affecting determinism).
    pub fn count(&self, name: &str, n: u64) {
        if let Some(i) = self.inner.as_deref() {
            i.registry.count(name, n);
        }
    }

    /// Set the named gauge. Serial call sites only: last-writer-wins is
    /// order-dependent, so gauges must never be set from the fan-out.
    pub fn gauge_set(&self, name: &str, v: i64) {
        if let Some(i) = self.inner.as_deref() {
            i.registry.gauge_set(name, v);
        }
    }

    /// Observe a value into the named log2 histogram (commutative:
    /// bucket/count/sum adds only).
    pub fn observe(&self, name: &str, v: u64) {
        if let Some(i) = self.inner.as_deref() {
            i.registry.observe(name, v);
        }
    }

    /// Observe a *virtual-time* duration (seconds -> integer
    /// microseconds); non-finite or negative durations are skipped.
    pub fn observe_virtual_s(&self, name: &str, dt_s: f64) {
        if let Some(i) = self.inner.as_deref() {
            if let Some(us) = virtual_us(dt_s) {
                i.registry.observe(name, us);
            }
        }
    }

    /// Count one popped scheduler event under `sched.event.<kind>`.
    pub fn count_event(&self, ev: &Event) {
        if let Some(i) = self.inner.as_deref() {
            i.registry.count(&format!("sched.event.{}", ev.kind()), 1);
        }
    }

    /// Open a scoped span: counts `span.<name>.calls` now and
    /// `span.<name>.completed` when the guard drops.
    pub fn span(&self, name: &'static str) -> SpanGuard {
        SpanGuard::enter(self.clone(), name)
    }

    /// Deterministic snapshot of every metric (sorted by name).
    pub fn snapshot(&self) -> RegistrySnapshot {
        match self.inner.as_deref() {
            Some(i) => i.registry.snapshot(),
            None => RegistrySnapshot::default(),
        }
    }

    /// Record a completed round: one run-log record and one trace
    /// replay of the round's event spine (each gated by its config
    /// flag). Serial call site (end of `Network::run_round`).
    pub fn record_round(&self, rep: &RoundReport, events: &[(f64, Event)]) {
        let Some(i) = self.inner.as_deref() else { return };
        if i.cfg.run_log {
            i.run_log.lock().unwrap().push(runlog::round_record(rep));
        }
        if i.cfg.trace {
            i.trace.lock().unwrap().add_round(rep, events);
        }
    }

    /// The Perfetto trace as a JSON string (`None` when disabled or the
    /// trace lane is off). Bit-deterministic: sorted object keys,
    /// integer virtual-time microseconds.
    pub fn trace_json(&self) -> Option<String> {
        let i = self.inner.as_deref()?;
        if !i.cfg.trace {
            return None;
        }
        Some(i.trace.lock().unwrap().to_json())
    }

    /// The structured run log as JSONL (one JSON object per line;
    /// `None` when disabled or the run-log lane is off).
    pub fn run_log_jsonl(&self) -> Option<String> {
        let i = self.inner.as_deref()?;
        if !i.cfg.run_log {
            return None;
        }
        let records = i.run_log.lock().unwrap();
        let mut out = String::new();
        for r in records.iter() {
            out.push_str(&r.to_string());
            out.push('\n');
        }
        Some(out)
    }

    /// Write the run artifacts into `dir` (`trace.json`,
    /// `runlog.jsonl`, `registry.json` — each only when its lane is on)
    /// and return the paths written. A disabled handle writes nothing.
    pub fn write_artifacts(&self, dir: &Path) -> Result<Vec<PathBuf>> {
        if !self.enabled() {
            return Ok(Vec::new());
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let mut written = Vec::new();
        if let Some(trace) = self.trace_json() {
            let p = dir.join("trace.json");
            std::fs::write(&p, trace).with_context(|| format!("writing {}", p.display()))?;
            written.push(p);
        }
        if let Some(log) = self.run_log_jsonl() {
            let p = dir.join("runlog.jsonl");
            std::fs::write(&p, log).with_context(|| format!("writing {}", p.display()))?;
            written.push(p);
        }
        let p = dir.join("registry.json");
        std::fs::write(&p, self.snapshot().to_json())
            .with_context(|| format!("writing {}", p.display()))?;
        written.push(p);
        Ok(written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_off_and_degenerate() {
        let c = TelemetryConfig::default();
        assert!(!c.enabled);
        assert_eq!(c.sample_lanes, 0, "0 = keep every lane");
        let t = Telemetry::new(c);
        assert!(!t.enabled());
        assert!(t.sample_lanes().is_none());
        assert!(t.trace_json().is_none());
        assert!(t.run_log_jsonl().is_none());
        // recording into a disabled handle is a no-op, not an error
        t.count("x", 1);
        t.observe("y", 2);
        t.gauge_set("z", 3);
        assert!(t.snapshot().metrics.is_empty());
    }

    #[test]
    fn env_override_pristine_default_only() {
        // pristine default + env -> enabled
        let on = TelemetryConfig::default().with_env(Some("1"));
        assert!(on.enabled);
        assert!(TelemetryConfig::default().with_env(Some("true")).enabled);
        assert!(TelemetryConfig::default().with_env(Some("on")).enabled);
        // unknown values and absence leave the default untouched
        assert_eq!(TelemetryConfig::default().with_env(Some("nope")), TelemetryConfig::default());
        assert_eq!(TelemetryConfig::default().with_env(None), TelemetryConfig::default());
        // an explicitly configured (non-pristine) config always wins —
        // including an explicit off (run_log flipped marks it explicit)
        let pinned_off = TelemetryConfig { run_log: false, ..TelemetryConfig::default() };
        assert!(!pinned_off.clone().with_env(Some("1")).enabled);
        let pinned_on = TelemetryConfig { enabled: true, ..TelemetryConfig::default() };
        assert!(pinned_on.with_env(None).enabled);
    }

    #[test]
    fn enabled_handle_records_and_snapshots() {
        let t = Telemetry::new(TelemetryConfig { enabled: true, ..Default::default() });
        t.count("a.calls", 2);
        t.count("a.calls", 3);
        t.observe("a.bytes", 1500);
        t.gauge_set("a.active", 7);
        let s = t.snapshot();
        assert_eq!(s.counter("a.calls"), 5);
        assert_eq!(s.metrics.get("a.active"), Some(&MetricValue::Gauge(7)));
        match s.metrics.get("a.bytes") {
            Some(MetricValue::Histogram { count, sum, .. }) => {
                assert_eq!((*count, *sum), (1, 1500));
            }
            other => panic!("histogram expected, got {other:?}"),
        }
        // clones share state
        let t2 = t.clone();
        t2.count("a.calls", 1);
        assert_eq!(t.snapshot().counter("a.calls"), 6);
    }

    #[test]
    fn virtual_us_skips_non_finite_and_negative() {
        assert_eq!(virtual_us(1.5), Some(1_500_000));
        assert_eq!(virtual_us(0.0), Some(0));
        assert_eq!(virtual_us(-1.0), None);
        assert_eq!(virtual_us(f64::INFINITY), None);
        assert_eq!(virtual_us(f64::NAN), None);
    }

    #[test]
    fn span_counts_calls_and_completions() {
        let t = Telemetry::new(TelemetryConfig { enabled: true, ..Default::default() });
        {
            let _g = t.span("phase");
            assert_eq!(t.snapshot().counter("span.phase.calls"), 1);
            assert_eq!(t.snapshot().counter("span.phase.completed"), 0);
        }
        assert_eq!(t.snapshot().counter("span.phase.completed"), 1);
        // disabled spans record nothing and cost only the branch
        let off = Telemetry::default();
        drop(off.span("phase"));
        assert!(off.snapshot().metrics.is_empty());
    }

    #[test]
    fn artifacts_roundtrip() {
        let t = Telemetry::new(TelemetryConfig { enabled: true, ..Default::default() });
        t.count("k", 1);
        let dir = std::env::temp_dir().join("covenant-telemetry-artifacts");
        let written = t.write_artifacts(&dir).unwrap();
        assert_eq!(written.len(), 3, "trace + runlog + registry");
        for p in &written {
            assert!(p.exists(), "{}", p.display());
        }
        let reg: serde_json::Value =
            serde_json::from_str(&std::fs::read_to_string(dir.join("registry.json")).unwrap())
                .unwrap();
        assert!(reg.get("metrics").is_some());
        // disabled handle writes nothing
        assert!(Telemetry::default().write_artifacts(&dir).unwrap().is_empty());
        std::fs::remove_dir_all(dir).ok();
    }
}
