//! JSONL structured run log: one self-describing record per round, so
//! downstream tooling (jq, pandas, dashboards) reads accounting
//! directly instead of spelunking `RoundReport` fields — plus the CSV
//! bridge feeding `metrics::write_csv` for the per-round series.

use serde_json::{json, Value};

use crate::coordinator::network::RoundReport;

/// Schema tag carried by every record (bump on breaking field changes).
pub const SCHEMA: &str = "covenant.runlog.v1";

/// Outer-step barrier cost for a round: how long the earliest-ready
/// shard waited for the barrier (`applied_at - max(ready_at)`), `0.0`
/// for unsharded/degenerate rounds or non-finite inputs.
pub fn barrier_cost_s(rep: &RoundReport) -> f64 {
    if rep.shard_lanes.is_empty() {
        return 0.0;
    }
    let applied = rep.shard_lanes[0].applied_at;
    let max_ready = rep
        .shard_lanes
        .iter()
        .map(|l| l.ready_at)
        .fold(f64::NEG_INFINITY, f64::max);
    let cost = applied - max_ready;
    if cost.is_finite() && cost >= 0.0 {
        cost
    } else {
        0.0
    }
}

/// Finite float or JSON null (stalled-upload sentinels are `+inf`).
fn fin(v: f64) -> Value {
    if v.is_finite() {
        json!(v)
    } else {
        Value::Null
    }
}

/// Build the JSON record for one completed round. Field values are
/// drawn from the report only (no wall clock, no environment), so the
/// record is bit-deterministic; serde_json sorts object keys.
pub fn round_record(rep: &RoundReport) -> Value {
    let pop = &rep.lane_population;
    json!({
        "schema": SCHEMA,
        "round": rep.round,
        "t_start_s": fin(rep.t_start),
        "t_compute_end_s": fin(rep.t_compute_end),
        "t_comm_end_s": fin(rep.t_comm_end),
        "deadline_s": fin(rep.deadline),
        "wall_clock_s": fin(rep.wall_clock()),
        "utilization": fin(rep.utilization()),
        "active": rep.active,
        "submitted": rep.submitted,
        "contributing": rep.contributing,
        "adversarial_submitted": rep.adversarial_submitted,
        "adversarial_selected": rep.adversarial_selected,
        "late_submissions": rep.late_submissions,
        "rejected_pre_decode": rep.rejected_pre_decode,
        "rejections": rep.rejections.len(),
        "retried_uploads": rep.retried_uploads,
        "orphaned_slices": rep.orphaned_slices,
        "recovered_shards": rep.recovered_shards,
        "mean_loss": fin(rep.mean_loss),
        "outer_alpha": fin(rep.outer_alpha),
        "bytes_up": rep.bytes_up,
        "bytes_down": rep.bytes_down,
        "barrier_cost_s": json!(barrier_cost_s(rep)),
        "shards": rep.shard_lanes.iter().map(|l| json!({
            "shard": l.shard,
            "host": l.host,
            "bytes": l.bytes,
            "ready_at_s": fin(l.ready_at),
            "applied_at_s": fin(l.applied_at),
            "takeover": l.takeover.map(|(from, t_detect, recovered_at)| json!({
                "from": from,
                "t_detect_s": fin(t_detect),
                "recovered_at_s": fin(recovered_at),
            })),
        })).collect::<Vec<_>>(),
        "lanes": {
            "sampled": rep.lanes.len(),
            "population": {
                "peers": pop.peers,
                "computed": pop.computed,
                "uploaded": pop.uploaded,
                "stalled": pop.stalled,
                "downloaded": pop.downloaded,
                "late": pop.late,
                "retries": pop.retries,
                "compute_us": pop.compute_us,
                "upload_us": pop.upload_us,
                "download_us": pop.download_us,
            },
        },
    })
}

/// Header row for the per-round CSV series (see [`csv_rows`]).
pub fn csv_header() -> &'static str {
    "round,wall_clock_s,utilization,active,submitted,contributing,late,\
     rejected_pre_decode,retried_uploads,orphaned_slices,recovered_shards,\
     barrier_cost_s,mean_loss,bytes_up,bytes_down"
}

/// Per-round CSV rows matching [`csv_header`], for `metrics::write_csv`.
pub fn csv_rows(reports: &[RoundReport]) -> Vec<Vec<String>> {
    reports
        .iter()
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{:.3}", r.wall_clock()),
                format!("{:.4}", r.utilization()),
                r.active.to_string(),
                r.submitted.to_string(),
                r.contributing.to_string(),
                r.late_submissions.to_string(),
                r.rejected_pre_decode.to_string(),
                r.retried_uploads.to_string(),
                r.orphaned_slices.to_string(),
                r.recovered_shards.to_string(),
                format!("{:.3}", barrier_cost_s(r)),
                format!("{:.6}", r.mean_loss),
                r.bytes_up.to_string(),
                r.bytes_down.to_string(),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::ShardLane;

    fn report() -> RoundReport {
        RoundReport {
            round: 2,
            t_start: 0.0,
            t_compute_end: 100.0,
            t_comm_end: 110.0,
            deadline: 120.0,
            active: 4,
            submitted: 4,
            contributing: 3,
            adversarial_submitted: 1,
            adversarial_selected: 0,
            late_submissions: 1,
            rejected_pre_decode: 1,
            mean_loss: 2.5,
            bytes_up: 4096,
            bytes_down: 1024,
            retried_uploads: 2,
            orphaned_slices: 3,
            recovered_shards: 1,
            outer_alpha: 0.5,
            rejections: vec!["hk-x: fast=Late".into()],
            lanes: Vec::new(),
            shard_lanes: vec![
                ShardLane {
                    shard: 0,
                    chunk0: 0,
                    chunk1: 8,
                    ready_at: 104.0,
                    applied_at: 107.0,
                    bytes: 2048,
                    host: 0,
                    takeover: None,
                },
                ShardLane {
                    shard: 1,
                    chunk0: 8,
                    chunk1: 16,
                    ready_at: 106.0,
                    applied_at: 107.0,
                    bytes: 2048,
                    host: 1,
                    takeover: Some((0, 105.0, 106.5)),
                },
            ],
            lane_population: Default::default(),
        }
    }

    #[test]
    fn record_carries_required_fields() {
        let v = round_record(&report());
        assert_eq!(v["schema"], SCHEMA);
        assert_eq!(v["round"], 2);
        assert_eq!(v["contributing"], 3);
        assert_eq!(v["rejections"], 1);
        assert_eq!(v["bytes_up"], 4096);
        assert_eq!(v["shards"].as_array().unwrap().len(), 2);
        assert_eq!(v["shards"][1]["takeover"]["from"], 0);
        assert!(v["shards"][0]["takeover"].is_null());
        // barrier cost: applied 107 - max ready 106
        assert!((v["barrier_cost_s"].as_f64().unwrap() - 1.0).abs() < 1e-9);
        // identical reports -> identical serialized records
        assert_eq!(v.to_string(), round_record(&report()).to_string());
    }

    #[test]
    fn barrier_cost_degenerate_cases() {
        let mut r = report();
        r.shard_lanes.clear();
        assert_eq!(barrier_cost_s(&r), 0.0, "unsharded round");
        let mut r2 = report();
        r2.shard_lanes[0].ready_at = f64::INFINITY;
        assert_eq!(barrier_cost_s(&r2), 0.0, "non-finite inputs never leak");
    }

    #[test]
    fn csv_rows_match_header_arity() {
        let rows = csv_rows(&[report()]);
        let n_cols = csv_header().split(',').count();
        assert_eq!(rows[0].len(), n_cols);
        assert_eq!(rows[0][0], "2");
        assert_eq!(rows[0][n_cols - 2], "4096");
    }
}
