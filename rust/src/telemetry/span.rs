//! Scoped spans over the deterministic registry.
//!
//! A span is deliberately *not* a wall-clock timer: wall-clock durations
//! differ across machines and runs, so they can never live in the
//! bit-deterministic registry (the engine's `exec_stats` remains the
//! wall-clock profiling lane). Instead a span is a pair of counters —
//! `span.<name>.calls` at entry and `span.<name>.completed` when the
//! guard drops — which makes early exits (error paths that skip the
//! guard's scope end) visible as `calls != completed`, while staying
//! byte-identical across thread counts and reruns. Virtual-time costs
//! of the spanned work are recorded separately via
//! `Telemetry::observe_virtual_s`.

use super::Telemetry;

/// RAII guard returned by [`Telemetry::span`]. Counts
/// `span.<name>.calls` when created and `span.<name>.completed` on
/// drop; on a disabled handle both are single-branch no-ops.
#[must_use = "a span guard records its completion when dropped at scope end"]
pub struct SpanGuard {
    tele: Telemetry,
    name: &'static str,
}

impl SpanGuard {
    pub(crate) fn enter(tele: Telemetry, name: &'static str) -> Self {
        if tele.enabled() {
            tele.count(&format!("span.{name}.calls"), 1);
        }
        Self { tele, name }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.tele.enabled() {
            self.tele.count(&format!("span.{}.completed", self.name), 1);
        }
    }
}
