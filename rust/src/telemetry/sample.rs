//! Deterministic lane sampling + exact population counters.
//!
//! `RoundReport::lanes` is the only O(peers) payload a round keeps
//! around; at swarm scale (ROADMAP: 10k–1M peers) it must become
//! O(sample). The sample is *deterministic*, not random: membership is
//! the bottom-k of a pure hash of `(run seed, hotkey)`, so the same
//! peers are sampled every round, every rerun, and on every machine —
//! a stable cohort you can follow across a whole run. Exact
//! whole-population counters ([`LanePopulation`]) are computed over the
//! full lane set *before* truncation, so accounting never degrades,
//! only rendering detail does.

use crate::coordinator::network::PeerLane;

/// Pure hash of `(run seed, hotkey)` — FNV-1a over the hotkey bytes
/// folded with the run seed, finished with a splitmix64 mix (same
/// construction as the round-engine's `round_seed`, minus the round).
pub fn lane_hash(run_seed: u64, hotkey: &str) -> u64 {
    lane_hash_finish(lane_hash_prefix(hotkey), run_seed)
}

/// The hotkey-bytes half of [`lane_hash`] (seed-independent FNV-1a),
/// split out so swarm-scale rosters can hash each hotkey once at join
/// time: `lane_hash(seed, hk) == lane_hash_finish(lane_hash_prefix(hk), seed)`
/// bit-for-bit.
pub fn lane_hash_prefix(hotkey: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in hotkey.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The per-run half of [`lane_hash`]: fold the run seed into a
/// [`lane_hash_prefix`] and run the finalizer.
pub fn lane_hash_finish(prefix: u64, run_seed: u64) -> u64 {
    let mut h = prefix ^ run_seed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    h
}

/// Keep the `k` lanes with the smallest `lane_hash(run_seed, hotkey)`
/// (ties broken by position), preserving the original lane order.
/// `k == 0` or `k >= lanes.len()` keeps everything. Membership depends
/// only on the hotkey *set*, not on lane ordering.
pub fn sample_lanes(run_seed: u64, lanes: Vec<PeerLane>, k: usize) -> Vec<PeerLane> {
    if k == 0 || lanes.len() <= k {
        return lanes;
    }
    let mut ranked: Vec<(u64, usize)> = lanes
        .iter()
        .enumerate()
        .map(|(i, l)| (lane_hash(run_seed, &l.hotkey), i))
        .collect();
    ranked.sort_unstable();
    let mut keep: Vec<usize> = ranked.into_iter().take(k).map(|(_, i)| i).collect();
    keep.sort_unstable();
    let mut out = Vec::with_capacity(k);
    let mut lanes = lanes;
    // drain from the back so earlier indices stay valid
    for &i in keep.iter().rev() {
        out.push(lanes.swap_remove(i));
    }
    out.reverse();
    out
}

/// Index-level twin of [`sample_lanes`]: the bottom-k positions by
/// `lane_hash(run_seed, hotkey)` (ties broken by position), returned in
/// ascending position order. `k == 0` or `n <= k` keeps every index.
/// Picking indices *first* is what lets a swarm-scale report materialize
/// only the sampled lanes — O(sample) hotkey strings — instead of
/// building all n `PeerLane`s and truncating afterwards.
pub fn sample_indices<'a, I>(run_seed: u64, hotkeys: I, k: usize) -> Vec<usize>
where
    I: ExactSizeIterator<Item = &'a str>,
{
    let n = hotkeys.len();
    if k == 0 || n <= k {
        return (0..n).collect();
    }
    let mut ranked: Vec<(u64, usize)> =
        hotkeys.enumerate().map(|(i, hk)| (lane_hash(run_seed, hk), i)).collect();
    ranked.sort_unstable();
    let mut keep: Vec<usize> = ranked.into_iter().take(k).map(|(_, i)| i).collect();
    keep.sort_unstable();
    keep
}

/// Exact whole-population counters over a round's peer lanes. All
/// fields are integers (durations in virtual-time microseconds, summed
/// over finite segments only), so equality is exact and the struct is
/// `Eq` — the determinism tests compare it directly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LanePopulation {
    /// Total number of lanes (peers with any activity this round).
    pub peers: u64,
    /// Lanes with a compute segment.
    pub computed: u64,
    /// Lanes whose upload finished (finite end).
    pub uploaded: u64,
    /// Lanes whose upload never finished (stalled, `+inf` end).
    pub stalled: u64,
    /// Lanes with a download segment.
    pub downloaded: u64,
    /// Lanes flagged late by the deadline check.
    pub late: u64,
    /// Total upload retry ticks across all lanes.
    pub retries: u64,
    /// Summed compute time, virtual microseconds (finite segments).
    pub compute_us: u64,
    /// Summed upload time, virtual microseconds (finite segments).
    pub upload_us: u64,
    /// Summed download time, virtual microseconds (finite segments).
    pub download_us: u64,
}

fn seg_us(seg: Option<(f64, f64)>) -> u64 {
    match seg {
        Some((a, b)) => super::virtual_us(b - a).unwrap_or(0),
        None => 0,
    }
}

/// Compute [`LanePopulation`] over a full (unsampled) lane set.
pub fn lane_population(lanes: &[PeerLane]) -> LanePopulation {
    let mut p = LanePopulation { peers: lanes.len() as u64, ..Default::default() };
    for l in lanes {
        if l.compute.is_some() {
            p.computed += 1;
        }
        match l.upload {
            Some((_, b)) if b.is_finite() => p.uploaded += 1,
            Some(_) => p.stalled += 1,
            None => {}
        }
        if l.download.is_some() {
            p.downloaded += 1;
        }
        if l.late {
            p.late += 1;
        }
        p.retries += l.retry_at.len() as u64;
        p.compute_us += seg_us(l.compute);
        p.upload_us += seg_us(l.upload);
        p.download_us += seg_us(l.download);
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::ComputeTier;

    fn lane(uid: usize, hotkey: &str) -> PeerLane {
        PeerLane {
            uid,
            hotkey: hotkey.to_string(),
            tier: ComputeTier::Median,
            compute: Some((0.0, 10.0)),
            upload: Some((10.0, 20.0)),
            download: Some((20.0, 25.0)),
            late: false,
            retry_at: Vec::new(),
        }
    }

    #[test]
    fn hash_is_stable_and_distinct() {
        let a = lane_hash(7, "hk-00000");
        assert_eq!(a, lane_hash(7, "hk-00000"), "pure function");
        assert_ne!(a, lane_hash(7, "hk-00001"), "hotkey feeds the hash");
        assert_ne!(a, lane_hash(8, "hk-00000"), "seed feeds the hash");
    }

    #[test]
    fn sampling_is_deterministic_and_order_independent() {
        let names = ["hk-a", "hk-b", "hk-c", "hk-d", "hk-e"];
        let forward: Vec<PeerLane> =
            names.iter().enumerate().map(|(i, n)| lane(i, n)).collect();
        let mut reversed = forward.clone();
        reversed.reverse();
        let kept_f: Vec<String> =
            sample_lanes(3, forward, 2).into_iter().map(|l| l.hotkey).collect();
        let mut kept_r: Vec<String> =
            sample_lanes(3, reversed, 2).into_iter().map(|l| l.hotkey).collect();
        kept_r.sort();
        let mut kept_f_sorted = kept_f.clone();
        kept_f_sorted.sort();
        assert_eq!(kept_f_sorted, kept_r, "membership depends on the hotkey set only");
        assert_eq!(kept_f.len(), 2);
        // different seed -> (very likely) different cohort; pinned here
        // so any hash change shows up as a test diff, not silence
        let again: Vec<String> = sample_lanes(
            3,
            names.iter().enumerate().map(|(i, n)| lane(i, n)).collect(),
            2,
        )
        .into_iter()
        .map(|l| l.hotkey)
        .collect();
        assert_eq!(kept_f, again, "same seed + same set -> identical sample");
    }

    #[test]
    fn prefix_split_matches_lane_hash_bitwise() {
        for hk in ["hk-00000", "swm-000042", ""] {
            let p = lane_hash_prefix(hk);
            for seed in [0u64, 7, 0xC0DE, u64::MAX] {
                assert_eq!(lane_hash(seed, hk), lane_hash_finish(p, seed));
            }
        }
    }

    #[test]
    fn sample_indices_matches_sample_lanes_membership() {
        let names: Vec<String> = (0..9).map(|i| format!("hk-{i:05}")).collect();
        let lanes: Vec<PeerLane> =
            names.iter().enumerate().map(|(i, n)| lane(i, n)).collect();
        for k in [0usize, 3, 5, 9, 20] {
            let kept = sample_lanes(0x5EED, lanes.clone(), k);
            let idx = sample_indices(0x5EED, names.iter().map(|s| s.as_str()), k);
            assert_eq!(
                kept.iter().map(|l| l.uid).collect::<Vec<_>>(),
                idx,
                "k={k}: index twin must pick the same cohort in the same order"
            );
        }
    }

    #[test]
    fn sampling_preserves_lane_order_and_degenerate_k() {
        let lanes: Vec<PeerLane> =
            (0..6).map(|i| lane(i, &format!("hk-{i:05}"))).collect();
        let kept = sample_lanes(11, lanes.clone(), 4);
        let uids: Vec<usize> = kept.iter().map(|l| l.uid).collect();
        let mut sorted = uids.clone();
        sorted.sort_unstable();
        assert_eq!(uids, sorted, "original lane order preserved");
        // k = 0 and k >= len keep everything
        assert_eq!(sample_lanes(11, lanes.clone(), 0).len(), 6);
        assert_eq!(sample_lanes(11, lanes, 10).len(), 6);
    }

    #[test]
    fn population_counts_exactly() {
        let mut lanes: Vec<PeerLane> =
            (0..4).map(|i| lane(i, &format!("hk-{i:05}"))).collect();
        lanes[1].upload = Some((10.0, f64::INFINITY)); // stalled
        lanes[1].download = None;
        lanes[2].late = true;
        lanes[2].retry_at = vec![12.0, 14.0];
        lanes[3].compute = None;
        let p = lane_population(&lanes);
        assert_eq!(p.peers, 4);
        assert_eq!(p.computed, 3);
        assert_eq!(p.uploaded, 3);
        assert_eq!(p.stalled, 1);
        assert_eq!(p.downloaded, 3);
        assert_eq!(p.late, 1);
        assert_eq!(p.retries, 2);
        assert_eq!(p.compute_us, 3 * 10_000_000);
        // stalled upload contributes nothing (non-finite duration)
        assert_eq!(p.upload_us, 3 * 10_000_000);
        assert_eq!(p.download_us, 3 * 5_000_000);
        assert_eq!(lane_population(&[]), LanePopulation::default());
    }
}
