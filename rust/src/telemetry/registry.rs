//! Typed metric registry: counters, gauges, and fixed-bucket log2
//! histograms with **no floating-point bucket math**.
//!
//! Determinism rules:
//!
//! * Counters and histograms only ever *add* (relaxed atomics). Addition
//!   of `u64`s is commutative and associative, so the final totals are
//!   independent of the interleaving the rayon fan-out happened to take.
//! * Histogram buckets are powers of two selected by
//!   [`log2_bucket`] — pure integer math on the observed value, so the
//!   same value always lands in the same bucket on every platform.
//! * Gauges are last-writer-wins and therefore **serial-only** by
//!   convention (documented on `Telemetry::gauge_set`).
//! * Snapshots iterate names in sorted order ([`RegistrySnapshot`] is a
//!   `BTreeMap`), so rendering and JSON export are byte-stable.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Number of log2 histogram buckets: bucket 0 holds exactly `0`, bucket
/// `i >= 1` holds values in `[2^(i-1), 2^i)`, up to bucket 64 for the
/// top half of the `u64` range.
pub const N_BUCKETS: usize = 65;

/// The bucket index for an observed value — pure integer math.
pub fn log2_bucket(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros()) as usize
    }
}

/// Inclusive lower bound of bucket `i` (for rendering/export).
pub fn bucket_floor(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

enum Metric {
    Counter(AtomicU64),
    Gauge(AtomicI64),
    Histogram {
        buckets: [AtomicU64; N_BUCKETS],
        count: AtomicU64,
        sum: AtomicU64,
    },
}

impl Metric {
    fn new_histogram() -> Self {
        Metric::Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// Concurrent metric store. Lookup takes a read lock (the common case
/// once a name exists); first use of a name takes the write lock once.
pub struct MetricRegistry {
    metrics: RwLock<HashMap<String, Arc<Metric>>>,
}

impl Default for MetricRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self { metrics: RwLock::new(HashMap::new()) }
    }

    fn get_or_insert(&self, name: &str, make: fn() -> Metric) -> Arc<Metric> {
        if let Some(m) = self.metrics.read().unwrap().get(name) {
            return m.clone();
        }
        self.metrics
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(make()))
            .clone()
    }

    /// Add `n` to the counter `name`.
    pub fn count(&self, name: &str, n: u64) {
        let m = self.get_or_insert(name, || Metric::Counter(AtomicU64::new(0)));
        match &*m {
            Metric::Counter(c) => {
                c.fetch_add(n, Ordering::Relaxed);
            }
            _ => debug_assert!(false, "metric kind mismatch: {name} is not a counter"),
        }
    }

    /// Set the gauge `name` (serial call sites only).
    pub fn gauge_set(&self, name: &str, v: i64) {
        let m = self.get_or_insert(name, || Metric::Gauge(AtomicI64::new(0)));
        match &*m {
            Metric::Gauge(g) => g.store(v, Ordering::Relaxed),
            _ => debug_assert!(false, "metric kind mismatch: {name} is not a gauge"),
        }
    }

    /// Observe `v` into the histogram `name`.
    pub fn observe(&self, name: &str, v: u64) {
        let m = self.get_or_insert(name, Metric::new_histogram);
        match &*m {
            Metric::Histogram { buckets, count, sum } => {
                buckets[log2_bucket(v)].fetch_add(1, Ordering::Relaxed);
                count.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(v, Ordering::Relaxed);
            }
            _ => debug_assert!(false, "metric kind mismatch: {name} is not a histogram"),
        }
    }

    /// Deterministic point-in-time snapshot, sorted by metric name.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let metrics = self.metrics.read().unwrap();
        let mut out = BTreeMap::new();
        for (name, m) in metrics.iter() {
            let v = match &**m {
                Metric::Counter(c) => MetricValue::Counter(c.load(Ordering::Relaxed)),
                Metric::Gauge(g) => MetricValue::Gauge(g.load(Ordering::Relaxed)),
                Metric::Histogram { buckets, count, sum } => {
                    let mut nonzero = Vec::new();
                    for (i, b) in buckets.iter().enumerate() {
                        let n = b.load(Ordering::Relaxed);
                        if n > 0 {
                            nonzero.push((i as u32, n));
                        }
                    }
                    MetricValue::Histogram {
                        count: count.load(Ordering::Relaxed),
                        sum: sum.load(Ordering::Relaxed),
                        buckets: nonzero,
                    }
                }
            };
            out.insert(name.clone(), v);
        }
        RegistrySnapshot { metrics: out }
    }
}

/// One metric's snapshotted value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MetricValue {
    /// Monotonic event count.
    Counter(u64),
    /// Last-written level.
    Gauge(i64),
    /// Log2 histogram: total count, exact integer sum (wrapping at
    /// `u64`), and the non-zero `(bucket index, count)` pairs.
    Histogram {
        /// Number of observations.
        count: u64,
        /// Exact sum of observed values.
        sum: u64,
        /// Non-zero buckets as `(log2 bucket index, count)`.
        buckets: Vec<(u32, u64)>,
    },
}

/// A deterministic snapshot of every metric, sorted by name. Two
/// snapshots of equivalent runs compare equal (`Eq`) and serialize to
/// identical JSON.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RegistrySnapshot {
    /// Metric name -> value, in sorted (BTreeMap) order.
    pub metrics: BTreeMap<String, MetricValue>,
}

impl RegistrySnapshot {
    /// Counter value by name (0 when absent or not a counter) — the
    /// convenient form for test assertions.
    pub fn counter(&self, name: &str) -> u64 {
        match self.metrics.get(name) {
            Some(MetricValue::Counter(n)) => *n,
            _ => 0,
        }
    }

    /// Histogram `(count, sum)` by name (`None` when absent or not a
    /// histogram).
    pub fn histogram(&self, name: &str) -> Option<(u64, u64)> {
        match self.metrics.get(name) {
            Some(MetricValue::Histogram { count, sum, .. }) => Some((*count, *sum)),
            _ => None,
        }
    }

    /// Stable JSON: `{"metrics": {name: {...}, ...}}` with sorted keys
    /// (serde_json maps are BTreeMaps) — byte-identical across reruns.
    pub fn to_json(&self) -> String {
        let mut metrics = serde_json::Map::new();
        for (name, v) in &self.metrics {
            let jv = match v {
                MetricValue::Counter(n) => serde_json::json!({"type": "counter", "value": n}),
                MetricValue::Gauge(g) => serde_json::json!({"type": "gauge", "value": g}),
                MetricValue::Histogram { count, sum, buckets } => {
                    let b: Vec<serde_json::Value> = buckets
                        .iter()
                        .map(|(i, n)| {
                            serde_json::json!({
                                "ge": bucket_floor(*i as usize),
                                "count": n,
                            })
                        })
                        .collect();
                    serde_json::json!({
                        "type": "histogram",
                        "count": count,
                        "sum": sum,
                        "buckets": b,
                    })
                }
            };
            metrics.insert(name.clone(), jv);
        }
        serde_json::Value::Object(
            [("metrics".to_string(), serde_json::Value::Object(metrics))].into_iter().collect(),
        )
        .to_string()
    }

    /// Compact human-readable rendering (sorted), for the end-of-run
    /// summary.
    pub fn render(&self) -> String {
        let mut out = String::from("telemetry registry:\n");
        for (name, v) in &self.metrics {
            match v {
                MetricValue::Counter(n) => {
                    out.push_str(&format!("  {name:<42} {n}\n"));
                }
                MetricValue::Gauge(g) => {
                    out.push_str(&format!("  {name:<42} {g} (gauge)\n"));
                }
                MetricValue::Histogram { count, sum, .. } => {
                    let mean = if *count > 0 { *sum as f64 / *count as f64 } else { 0.0 };
                    out.push_str(&format!(
                        "  {name:<42} n={count} sum={sum} mean={mean:.1}\n"
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_pure_integer() {
        assert_eq!(log2_bucket(0), 0);
        assert_eq!(log2_bucket(1), 1);
        assert_eq!(log2_bucket(2), 2);
        assert_eq!(log2_bucket(3), 2);
        assert_eq!(log2_bucket(4), 3);
        assert_eq!(log2_bucket(1023), 10);
        assert_eq!(log2_bucket(1024), 11);
        assert_eq!(log2_bucket(u64::MAX), 64);
        assert!(log2_bucket(u64::MAX) < N_BUCKETS);
        assert_eq!(bucket_floor(0), 0);
        assert_eq!(bucket_floor(1), 1);
        assert_eq!(bucket_floor(11), 1024);
        assert_eq!(bucket_floor(64), 1u64 << 63);
        // every value lands in the bucket whose floor it is >= to
        for v in [0u64, 1, 2, 7, 1000, 1 << 40, u64::MAX] {
            assert!(v >= bucket_floor(log2_bucket(v)));
        }
    }

    #[test]
    fn counts_accumulate_and_snapshot_sorted() {
        let r = MetricRegistry::new();
        r.count("b.second", 2);
        r.count("a.first", 1);
        r.count("b.second", 3);
        let s = r.snapshot();
        assert_eq!(s.counter("a.first"), 1);
        assert_eq!(s.counter("b.second"), 5);
        let names: Vec<&String> = s.metrics.keys().collect();
        assert_eq!(names, ["a.first", "b.second"]);
    }

    #[test]
    fn histogram_tracks_exact_sum_and_buckets() {
        let r = MetricRegistry::new();
        for v in [0u64, 1, 1, 5, 1024] {
            r.observe("h", v);
        }
        let s = r.snapshot();
        assert_eq!(s.histogram("h"), Some((5, 1031)));
        match s.metrics.get("h").unwrap() {
            MetricValue::Histogram { buckets, .. } => {
                // 0 -> bucket 0; 1,1 -> bucket 1; 5 -> bucket 3; 1024 -> bucket 11
                assert_eq!(buckets, &vec![(0, 1), (1, 2), (3, 1), (11, 1)]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn parallel_adds_are_order_independent() {
        use rayon::prelude::*;
        let serial = MetricRegistry::new();
        for i in 0..100u64 {
            serial.count("c", i);
            serial.observe("h", i * 31);
        }
        let par = MetricRegistry::new();
        (0..100u64).into_par_iter().for_each(|i| {
            par.count("c", i);
            par.observe("h", i * 31);
        });
        assert_eq!(serial.snapshot(), par.snapshot());
        assert_eq!(serial.snapshot().to_json(), par.snapshot().to_json());
    }

    #[test]
    fn json_shape_is_stable() {
        let r = MetricRegistry::new();
        r.count("c", 7);
        r.gauge_set("g", -3);
        r.observe("h", 9);
        let j = r.snapshot().to_json();
        let v: serde_json::Value = serde_json::from_str(&j).unwrap();
        assert_eq!(v["metrics"]["c"]["type"], "counter");
        assert_eq!(v["metrics"]["c"]["value"], 7);
        assert_eq!(v["metrics"]["g"]["value"], -3);
        assert_eq!(v["metrics"]["h"]["count"], 1);
        assert_eq!(v["metrics"]["h"]["buckets"][0]["ge"], 8);
        // rendering includes every name
        let rendered = r.snapshot().render();
        for name in ["c", "g", "h"] {
            assert!(rendered.contains(name));
        }
    }
}
