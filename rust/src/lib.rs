//! # Covenant — permissionless distributed LLM pre-training
//!
//! Reproduction of "Covenant-72B: Pre-Training a 72B LLM with Trustless
//! Peers Over-the-Internet" (cs.DC 2026): a SparseLoCo + Gauntlet
//! training network with open participation. This crate is the whole
//! system at CPU scale — the coordinator (peers, validator, chain,
//! object-store comms, round orchestration), the SparseLoCo compression
//! stack (chunk-wise Top-k, 2-bit quantization, error feedback, the
//! 14-bit/value wire codec), and a native execution backend implementing
//! the model math (transformer forward/backward + AdamW over a flat
//! chunk-aligned parameter layout) in pure Rust.
//!
//! The round engine is parallel: one [`coordinator::Network::run_round`]
//! fans every peer's compute → compress → encode pipeline across the
//! rayon pool, then merges deterministically — parallel and serial
//! rounds produce byte-identical global models (per-peer RNGs are seeded
//! from (run seed, hotkey, round); aggregation accumulates in submission
//! order within disjoint chunk ranges). The coordinator itself is
//! *sharded* ([`coordinator::shard`]): the flat parameter vector splits
//! into contiguous chunk-range shards, each owned by a
//! `ShardCoordinator` with its own aggregation bucket, and the outer
//! step applies at a cross-shard barrier. The shard invariant — disjoint
//! chunk ranges, fixed accumulation order, globally shared median-norm
//! weights — makes the sharded aggregate bitwise identical to the
//! unsharded one at every shard count, so the single-coordinator path
//! is just `n_shards = 1` (`tests/shard_parity.rs`). Simulated *time* runs on a
//! discrete-event spine ([`netsim::sched`]): per-peer compute durations
//! ([`netsim::compute_model`] hardware tiers), FIFO link transfers,
//! deadline cuts and chain blocks are typed events on a binary heap, so
//! stragglers miss deadlines for real and the paper's Fig.-1 overlap
//! (comm hidden behind the next compute window) is simulated rather than
//! assumed. The compute hot path underneath
//! is built the same way: [`runtime::kernels`] are cache-blocked and
//! rayon-parallel yet bit-identical to their serial references (fixed
//! per-element accumulation order), ops run allocation-free over pooled
//! [`runtime::workspace::Workspace`]s, and the Gauntlet validator fans
//! LossScore evaluations across the same pool.
//!
//! Start at the `README.md` module map; `examples/quickstart.rs` walks
//! the protocol by hand.

pub mod chain;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod gauntlet;
pub mod metrics;
pub mod netsim;
pub mod peer;
pub mod runtime;
pub mod sparseloco;
pub mod storage;
pub mod telemetry;
pub mod train;
pub mod util;
