//! # Covenant — permissionless distributed LLM pre-training
//!
//! Reproduction of "Covenant-72B: Pre-Training a 72B LLM with Trustless
//! Peers Over-the-Internet" (CS.DC 2026): a SparseLoCo + Gauntlet training
//! network. Layer 3 (this crate) is the coordinator — peers, validator,
//! chain, object-store comms, round orchestration; Layers 2/1 (JAX model +
//! Pallas kernels) are AOT-compiled to HLO artifacts executed via PJRT.
//!
//! See DESIGN.md for the module inventory and experiment index.

pub mod chain;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod gauntlet;
pub mod metrics;
pub mod peer;
pub mod train;
pub mod config;
pub mod netsim;
pub mod runtime;
pub mod sparseloco;
pub mod storage;
pub mod util;
