//! Peer worker: one participant's full replica state and per-round
//! behaviour (honest SparseLoCo, or one of the adversarial strategies the
//! Gauntlet mechanism must withstand in an open-participation setting).
//!
//! Peers are designed to run concurrently: all round-local randomness
//! draws from a per-peer RNG reseeded from (run seed, hotkey, round) via
//! [`PeerState::begin_round`], so a peer's behaviour is a pure function
//! of its identity and the round — independent of scheduling order. The
//! compress phase fuses the EF accumulator into a per-peer scratch
//! buffer and shares the Eq. 1 residual update
//! (`topk::compress_acc_update_ef`), so steady-state rounds allocate
//! nothing on the EF hot path.

use anyhow::Result;

use crate::coordinator::shard::ShardSpec;
use crate::gauntlet::Submission;
use crate::netsim::ComputeTier;
use crate::runtime::{ops, Engine};
use crate::sparseloco::{codec, envelope, topk, Payload};
use crate::util::rng::Rng;

/// Wire-encode a payload as per-coordinator-shard slices, one buffer per
/// shard in shard order (what the peer actually uploads under
/// multi-coordinator sharding — each slice lands in the owning shard's
/// bucket). With a single full-cover shard this is exactly one buffer,
/// byte-identical to `codec::encode(payload)` — the degenerate
/// single-coordinator upload. With more shards the total byte count
/// grows slightly (per-slice headers and sub-byte packing tails): the
/// real wire cost of sharding, charged to the uplink by the round
/// engine.
pub fn encode_payload_slices(payload: &Payload, specs: &[ShardSpec]) -> Result<Vec<Vec<u8>>> {
    if let [spec] = specs {
        if spec.covers_all(payload.n_chunks) {
            return Ok(vec![codec::encode(payload)]);
        }
    }
    specs
        .iter()
        .map(|sp| Ok(codec::encode(&payload.slice_chunks(sp.chunk0, sp.chunk1)?)))
        .collect()
}

/// [`encode_payload_slices`], then seal each slice in a signed envelope:
/// one `CVEV` buffer per shard carrying `(hotkey, round, shard, nonce)`
/// and the authentication tag the Gauntlet verifies before any decode.
/// The nonce is shared across the slice set (one submission, one nonce).
pub fn seal_payload_slices(
    payload: &Payload,
    specs: &[ShardSpec],
    key: &envelope::SigningKey,
    hotkey: &str,
    round: u64,
    nonce: u64,
) -> Result<Vec<Vec<u8>>> {
    Ok(encode_payload_slices(payload, specs)?
        .into_iter()
        .enumerate()
        .map(|(s, wire)| envelope::seal(&wire, hotkey, round, s as u32, nonce, key))
        .collect())
}

/// The peer-side upload retry policy: how long a peer waits before
/// re-sending a slice whose transfer was cut by a link flap. Attempt `k`
/// (0-based) waits `base_s * 2^k` — bounded deterministic exponential
/// backoff, a pure function with no RNG so retried rounds stay
/// bit-reproducible. The round engine charges the wait against the
/// peer's own timeline; the retry budget
/// (`FaultConfig::max_upload_retries`) caps total attempts, after which
/// the submission is abandoned (`FastCheck::OrphanedUpload`).
pub fn upload_backoff_s(base_s: f64, attempt: u32) -> f64 {
    base_s * (1u64 << attempt.min(62)) as f64
}

/// Tally one peer's completed round into the telemetry registry. Called
/// by the round engine from inside the (possibly rayon-parallel) peer
/// fan-out, so it must use only commutative counter adds — order across
/// peers must not matter. Free on the disabled path (single branch).
pub fn record_peer_round(
    tele: &crate::telemetry::Telemetry,
    behavior: Behavior,
    computed: bool,
    wire_bytes: u64,
    n_slices: u64,
) {
    if !tele.enabled() {
        return;
    }
    tele.count("peer.rounds", 1);
    tele.count(&format!("peer.behavior.{behavior:?}"), 1);
    if computed {
        tele.count("peer.compute.calls", 1);
    }
    if behavior.is_adversarial() {
        tele.count("peer.adversarial", 1);
    }
    tele.count("peer.encode.slices", n_slices);
    tele.observe("peer.wire.bytes", wire_bytes);
}

/// Peer behaviour. Adversarial variants exercise Gauntlet's defenses:
/// copiers are caught by assigned-vs-unassigned LossScore, whales by
/// median-norm checks, stale peers by the sync check, free-riders by the
/// empty-payload check, and noise peers by LossScore itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Behavior {
    /// Runs real inner steps on assigned data, compresses honestly.
    Honest,
    /// Re-submits another peer's previous payload (no compute).
    Copier,
    /// Fabricates a random payload with a plausible norm.
    Noise,
    /// Trains honestly but from a stale global model.
    Stale,
    /// Submits an all-zero payload (liveness without work).
    FreeRider,
    /// Submits an abnormally large-magnitude update (dominance attack).
    Whale,
    /// Sybil swarm member: many hotkeys registered with ONE shared
    /// signing key (liveness farming). Submits an empty payload; the
    /// shared key's replay window lets at most one envelope through per
    /// round, so the rest of the swarm is `ReplayedPayload`.
    Sybil,
    /// Free-rider that replays another peer's previous-round *sealed*
    /// slices verbatim — valid signature, stale nonce. Caught by the
    /// replay window before decode.
    Replayer,
    /// Signs with a key that does not match the hotkey's registered
    /// verifying key (payload forgery / impersonation attempt):
    /// `BadSignature` before decode.
    Forger,
    /// Floods one targeted coordinator shard with oversized junk bytes
    /// in place of its slice; the whole submission fails envelope
    /// parsing, and the junk is charged to the target shard's rejected
    /// accounting.
    ShardSpammer,
}

impl Behavior {
    /// The classic payload-level adversaries the churn model rolls for
    /// organically joining peers. The envelope-level kinds (`Sybil`,
    /// `Replayer`, `Forger`, `ShardSpammer`) are NOT rolled here — they
    /// are injected explicitly via `config::run::AdversaryConfig`, so
    /// adding them left the churn roll distribution untouched.
    pub fn adversarial_kinds() -> [Behavior; 4] {
        [Behavior::Copier, Behavior::Noise, Behavior::FreeRider, Behavior::Whale]
    }

    pub fn is_adversarial(&self) -> bool {
        !matches!(self, Behavior::Honest | Behavior::Stale)
    }

    /// Whether this behaviour runs the honest compute path (real inner
    /// steps on assigned data).
    pub fn computes(&self) -> bool {
        matches!(self, Behavior::Honest | Behavior::Stale | Behavior::Whale)
    }
}

/// One peer's replica + protocol state.
pub struct PeerState {
    pub hotkey: String,
    pub uid: usize,
    pub behavior: Behavior,
    /// Hardware tier (netsim compute model): fixed at join from
    /// (run seed, hotkey), drives this peer's simulated compute duration
    /// each round. Median for every peer when heterogeneity is disabled.
    pub tier: ComputeTier,
    /// Local replica (synchronized global params after each outer step).
    pub params: Vec<f32>,
    /// Inner AdamW moments (per-peer).
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// SparseLoCo error-feedback buffer (per-peer, Eq. 1).
    pub ef: Vec<f32>,
    /// Global inner-step counter.
    pub inner_step: usize,
    /// Round the local params correspond to.
    pub base_round: usize,
    /// Rounds participated (for liveness stats).
    pub rounds_done: usize,
    rng: Rng,
    /// Reusable EF accumulator (compress phase scratch).
    scratch_acc: Vec<f32>,
}

impl PeerState {
    /// A peer joining at `round` with the current global params.
    #[allow(clippy::too_many_arguments)]
    pub fn join(
        hotkey: String,
        uid: usize,
        behavior: Behavior,
        tier: ComputeTier,
        global_params: &[f32],
        inner_step: usize,
        round: usize,
        seed: u64,
    ) -> Self {
        let n = global_params.len();
        Self {
            hotkey,
            uid,
            behavior,
            tier,
            params: global_params.to_vec(),
            m: vec![0.0; n],
            v: vec![0.0; n],
            ef: vec![0.0; n],
            inner_step,
            base_round: round,
            rounds_done: 0,
            rng: Rng::new(seed),
            scratch_acc: Vec::new(),
        }
    }

    /// Reseed the per-round RNG. The round engine calls this with a seed
    /// derived from (run seed, hotkey, round) before fanning peers out, so
    /// round behaviour is identical whether peers run serially or across
    /// a thread pool.
    pub fn begin_round(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    /// Draw a bernoulli from the peer's round RNG (upload-slowness rolls).
    pub fn roll_bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Draw a uniform index from the peer's round RNG (copy-source pick).
    pub fn roll_below(&mut self, n: usize) -> usize {
        self.rng.below(n)
    }

    /// Compute phase: H inner steps on assigned data (honest path),
    /// updating the replica (params/m/v) in place — no cloning of the
    /// full state per round. Returns per-step losses.
    pub fn compute_phase(
        &mut self,
        eng: &Engine,
        tokens: &[i32],
        mask: &[f32],
        lrs: &[f32],
    ) -> Result<Vec<f32>> {
        let losses = ops::train_round_in_place(
            eng,
            &mut self.params,
            &mut self.m,
            &mut self.v,
            self.inner_step as f32,
            tokens,
            mask,
            lrs,
            0.0,
        )?;
        self.inner_step += lrs.len();
        Ok(losses)
    }

    /// Communication phase, peer side: pseudo-gradient delta = theta_global
    /// - theta_local, then SparseLoCo compress with error feedback.
    ///
    /// `fast_path` selects the fused in-place compressor (delta never
    /// materialized; the EF accumulator is a reusable per-peer scratch
    /// buffer — zero allocations beyond the payload itself). The
    /// engine-tracked path computes the identical result through
    /// `ops::compress` and shows up in `Engine::exec_stats`.
    pub fn compress_phase(
        &mut self,
        eng: &Engine,
        global_params: &[f32],
        beta: f32,
        fast_path: bool,
    ) -> Result<Payload> {
        let man = eng.manifest();
        if fast_path {
            let n = self.params.len();
            self.scratch_acc.resize(n, 0.0);
            // acc = beta*ef + (theta_global - theta_local), fused
            for i in 0..n {
                self.scratch_acc[i] = beta * self.ef[i] + (global_params[i] - self.params[i]);
            }
            Ok(topk::compress_acc_update_ef(
                &self.scratch_acc,
                &mut self.ef,
                man.config.chunk,
                man.config.topk,
            ))
        } else {
            let delta: Vec<f32> = global_params
                .iter()
                .zip(&self.params)
                .map(|(g, l)| g - l)
                .collect();
            let (ef_new, payload) = ops::compress(eng, &delta, &self.ef, beta)?;
            self.ef = ef_new;
            Ok(payload)
        }
    }

    /// Produce this round's submission according to the behaviour.
    ///
    /// `honest_payload` is the payload computed by the honest path (None
    /// for behaviours that skip compute); `copy_source` is some other
    /// peer's payload (for Copier).
    #[allow(clippy::too_many_arguments)]
    pub fn fabricate_submission(
        &mut self,
        round: usize,
        honest_payload: Option<Payload>,
        copy_source: Option<&Payload>,
        n_chunks: usize,
        k: usize,
        chunk: usize,
        median_norm_hint: f32,
        uploaded_at: f64,
    ) -> Submission {
        let payload = match self.behavior {
            Behavior::Honest | Behavior::Stale => {
                honest_payload.expect("honest peers computed a payload")
            }
            Behavior::Copier => match copy_source {
                Some(p) => p.clone(),
                None => self.noise_payload(n_chunks, k, chunk, median_norm_hint),
            },
            Behavior::Noise => self.noise_payload(n_chunks, k, chunk, median_norm_hint),
            // Sybils are liveness-only free-riders: the swarm's goal is
            // registered presence, not gradient mass, so the payload is
            // empty (the envelope layer is what makes the swarm visible).
            Behavior::FreeRider | Behavior::Sybil => Self::empty_payload(n_chunks, k, chunk),
            // The replayer's in-memory payload mirrors the victim slice
            // set it replays on the wire; with no victim yet (round 0) it
            // has nothing to replay and degenerates to an empty payload.
            Behavior::Replayer => match copy_source {
                Some(p) => p.clone(),
                None => Self::empty_payload(n_chunks, k, chunk),
            },
            // Forgers and spammers carry plausible-looking content — the
            // attack is in the envelope, not the payload.
            Behavior::Forger | Behavior::ShardSpammer => {
                self.noise_payload(n_chunks, k, chunk, median_norm_hint)
            }
            Behavior::Whale => {
                let mut p = honest_payload
                    .unwrap_or_else(|| self.noise_payload(n_chunks, k, chunk, median_norm_hint));
                for s in &mut p.scales {
                    *s *= 1000.0;
                }
                p
            }
        };
        let base_round = if self.behavior == Behavior::Stale {
            round.saturating_sub(2)
        } else {
            self.base_round
        };
        Submission {
            hotkey: self.hotkey.clone(),
            uid: self.uid,
            round,
            base_round,
            // Exact wire length without serializing (the store path
            // encodes once, outside this call).
            wire_bytes: codec::wire_size(payload.n_chunks, payload.k),
            payload,
            uploaded_at,
        }
    }

    /// The all-zero payload (FreeRider / Sybil / fallback Replayer).
    fn empty_payload(n_chunks: usize, k: usize, chunk: usize) -> Payload {
        Payload {
            n_chunks,
            k,
            chunk,
            idx: vec![0; n_chunks * k],
            codes: vec![2; n_chunks * k],
            scales: vec![0.0; n_chunks],
        }
    }

    /// Random payload with roughly the given norm (Noise behaviour).
    fn noise_payload(&mut self, n_chunks: usize, k: usize, chunk: usize, norm: f32) -> Payload {
        let n = n_chunks * chunk;
        let per = (norm / (n as f32).sqrt()).max(1e-8);
        let dense: Vec<f32> =
            (0..n).map(|_| self.rng.normal() as f32 * per * 3.0).collect();
        topk::compress_dense(&dense, chunk, k)
    }

    /// Outer sync: adopt the new global parameters (Eq. 2 applied by the
    /// aggregation path; every peer converges to the same theta).
    pub fn sync(&mut self, global_params: &[f32], round: usize) {
        self.params.copy_from_slice(global_params);
        self.base_round = round;
        self.rounds_done += 1;
    }

    /// The validator did NOT select this round's payload: the transmitted
    /// mass never reached the global model, so it returns to the
    /// error-feedback buffer (ef := beta*ef_prev + delta = acc), exactly
    /// as if nothing had been transmitted. Without this, unselected
    /// honest compute is silently dropped from the EF recursion.
    pub fn restore_unselected(&mut self, payload: &Payload) {
        payload
            .accumulate_into(&mut self.ef, 1.0)
            .expect("own payload geometry");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_peer(b: Behavior) -> PeerState {
        PeerState::join("hk".into(), 0, b, ComputeTier::Median, &[0.0; 256], 0, 3, 7)
    }

    #[test]
    fn freerider_payload_is_empty() {
        let mut p = mk_peer(Behavior::FreeRider);
        let sub = p.fabricate_submission(3, None, None, 4, 8, 64, 1.0, 0.0);
        assert_eq!(sub.payload.l2_norm(), 0.0);
    }

    #[test]
    fn wire_bytes_matches_encoded_length() {
        let mut p = mk_peer(Behavior::Noise);
        let sub = p.fabricate_submission(3, None, None, 4, 8, 64, 1.0, 0.0);
        assert_eq!(sub.wire_bytes, codec::encode(&sub.payload).len());
    }

    #[test]
    fn whale_scales_blown_up() {
        let mut p = mk_peer(Behavior::Whale);
        let honest = topk::compress_dense(&[0.01; 256], 64, 8);
        let n0 = honest.l2_norm();
        let sub = p.fabricate_submission(3, Some(honest), None, 4, 8, 64, 1.0, 0.0);
        assert!(sub.payload.l2_norm() > 100.0 * n0);
    }

    #[test]
    fn stale_reports_old_base_round() {
        let mut p = mk_peer(Behavior::Stale);
        let honest = topk::compress_dense(&[0.01; 256], 64, 8);
        let sub = p.fabricate_submission(5, Some(honest), None, 4, 8, 64, 1.0, 0.0);
        assert_eq!(sub.base_round, 3);
    }

    #[test]
    fn copier_copies() {
        let mut p = mk_peer(Behavior::Copier);
        let src = topk::compress_dense(&[0.5; 256], 64, 8);
        let sub = p.fabricate_submission(3, None, Some(&src), 4, 8, 64, 1.0, 0.0);
        assert_eq!(sub.payload, src);
    }

    #[test]
    fn noise_norm_plausible() {
        let mut p = mk_peer(Behavior::Noise);
        let sub = p.fabricate_submission(3, None, None, 4, 8, 64, 1.0, 0.0);
        let n = sub.payload.l2_norm();
        assert!(n > 0.0 && n < 100.0, "norm={n}");
    }

    #[test]
    fn begin_round_makes_rolls_deterministic() {
        let mut a = mk_peer(Behavior::Noise);
        let mut b = mk_peer(Behavior::Noise);
        a.begin_round(1234);
        b.begin_round(1234);
        for _ in 0..20 {
            assert_eq!(a.roll_bool(0.3), b.roll_bool(0.3));
            assert_eq!(a.roll_below(17), b.roll_below(17));
        }
        // same seed -> identical fabricated noise payloads
        a.begin_round(99);
        b.begin_round(99);
        let sa = a.fabricate_submission(3, None, None, 4, 8, 64, 1.0, 0.0);
        let sb = b.fabricate_submission(3, None, None, 4, 8, 64, 1.0, 0.0);
        assert_eq!(sa.payload, sb.payload);
    }

    #[test]
    fn slice_encoding_degenerate_and_sharded() {
        use crate::coordinator::shard::ShardSet;
        let p = topk::compress_dense(&[0.01f32; 256], 64, 8); // 4 chunks
        // single full-cover shard: byte-identical to the plain encode
        let one = ShardSet::new(4, 64, 1).unwrap();
        let slices = encode_payload_slices(&p, &one.specs()).unwrap();
        assert_eq!(slices, vec![codec::encode(&p)]);
        // three shards: each slice decodes back to its chunk range, and
        // the total wire cost strictly exceeds the unsharded encode
        // (per-slice headers — the price of sharding)
        let three = ShardSet::new(4, 64, 3).unwrap();
        let slices = encode_payload_slices(&p, &three.specs()).unwrap();
        assert_eq!(slices.len(), 3);
        let total: usize = slices.iter().map(Vec::len).sum();
        assert!(total > codec::encode(&p).len());
        for (sp, wire) in three.specs().iter().zip(&slices) {
            let dec = codec::decode(wire).unwrap();
            assert_eq!(dec, p.slice_chunks(sp.chunk0, sp.chunk1).unwrap());
        }
    }

    #[test]
    fn sync_adopts_global() {
        let mut p = mk_peer(Behavior::Honest);
        p.params[0] = 5.0;
        let g = vec![1.0; 256];
        p.sync(&g, 9);
        assert_eq!(p.params[0], 1.0);
        assert_eq!(p.base_round, 9);
        assert_eq!(p.rounds_done, 1);
    }

    #[test]
    fn sybil_payload_is_empty_and_liveness_only() {
        let mut p = mk_peer(Behavior::Sybil);
        let sub = p.fabricate_submission(3, None, None, 4, 8, 64, 1.0, 0.0);
        assert_eq!(sub.payload.l2_norm(), 0.0);
        assert!(!Behavior::Sybil.computes());
        assert!(Behavior::Sybil.is_adversarial());
    }

    #[test]
    fn replayer_mirrors_victim_or_degenerates_to_empty() {
        let mut p = mk_peer(Behavior::Replayer);
        let victim = topk::compress_dense(&[0.5; 256], 64, 8);
        let sub = p.fabricate_submission(3, None, Some(&victim), 4, 8, 64, 1.0, 0.0);
        assert_eq!(sub.payload, victim);
        // round 0: nothing to replay
        let sub0 = p.fabricate_submission(0, None, None, 4, 8, 64, 1.0, 0.0);
        assert_eq!(sub0.payload.l2_norm(), 0.0);
    }

    #[test]
    fn envelope_kinds_are_adversarial_but_not_rolled_by_churn() {
        for b in [Behavior::Sybil, Behavior::Replayer, Behavior::Forger, Behavior::ShardSpammer] {
            assert!(b.is_adversarial());
            assert!(!b.computes());
            assert!(
                !Behavior::adversarial_kinds().contains(&b),
                "{b:?} must not enter the churn roll distribution"
            );
        }
    }

    #[test]
    fn upload_backoff_doubles_and_never_overflows() {
        assert_eq!(upload_backoff_s(5.0, 0), 5.0);
        assert_eq!(upload_backoff_s(5.0, 1), 10.0);
        assert_eq!(upload_backoff_s(5.0, 3), 40.0);
        // absurd attempt counts clamp instead of overflowing the shift
        assert!(upload_backoff_s(1.0, 200).is_finite());
    }

    #[test]
    fn sealed_slices_verify_and_size_as_predicted() {
        use crate::coordinator::shard::ShardSet;
        let key = envelope::SigningKey::derive(0x5EED, "hk-00002");
        let p = topk::compress_dense(&[0.01f32; 256], 64, 8);
        let three = ShardSet::new(4, 64, 3).unwrap();
        let bare = encode_payload_slices(&p, &three.specs()).unwrap();
        let sealed =
            seal_payload_slices(&p, &three.specs(), &key, "hk-00002", 5, 5).unwrap();
        assert_eq!(sealed.len(), 3);
        let vk = key.verifying();
        for (s, (b, w)) in bare.iter().zip(&sealed).enumerate() {
            assert_eq!(w.len(), envelope::sealed_size("hk-00002".len(), b.len()));
            let env = envelope::open(w).unwrap();
            assert_eq!(env.shard as usize, s);
            assert_eq!((env.hotkey, env.round, env.nonce), ("hk-00002", 5, 5));
            assert_eq!(env.payload, &b[..]);
            assert!(env.verify(&vk));
        }
    }
}
