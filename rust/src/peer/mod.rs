//! Peers: replica state, honest + adversarial behaviours, and the churn
//! model for dynamic permissionless participation (paper §4.4, App. A).

pub mod churn;
pub mod worker;

pub use churn::{ChurnConfig, ChurnModel};
pub use worker::{Behavior, PeerState};
