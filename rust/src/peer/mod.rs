//! Peers: replica state, honest + adversarial behaviours, and the churn
//! model for dynamic permissionless participation (paper §4.4, App. A).
//!
//! At swarm scale (10k–100k+ peers) the per-peer round state moves to
//! the struct-of-arrays storage in [`swarm`]: a flat link bank that
//! replicates the FIFO link arithmetic bit-for-bit, a lane table with
//! exact whole-population counters, and a timing-only round driver
//! with zero per-peer heap allocation in steady state.

pub mod churn;
pub mod swarm;
pub mod worker;

pub use churn::{ChurnConfig, ChurnModel};
pub use swarm::{LaneTable, SwarmConfig, SwarmLinks, SwarmRoster, SwarmRoundStats, SwarmSim};
pub use worker::{Behavior, PeerState};
