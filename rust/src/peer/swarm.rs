//! Swarm-scale peer state: struct-of-arrays storage for 100k+ peers.
//!
//! The full round engine (`coordinator::network`) runs real transformer
//! compute per peer, which caps it at tens of peers. Scaling the *netsim*
//! side to the paper's open-swarm regime (10k–100k+ peers) needs three
//! things this module provides:
//!
//! * [`SwarmLinks`] — the per-peer FIFO link state
//!   ([`Link`](crate::netsim::Link)) flattened into parallel `f64`
//!   arrays, replicating `Link::transfer` / `release_at` / `cut_at`
//!   arithmetic **bit-for-bit** (unit-tested against a `Vec<Link>`
//!   mirror), so the round engine can swap representations without
//!   moving a single timing bit.
//! * [`LaneTable`] — per-round lane segments (compute/upload/download
//!   intervals, late flags, retry ticks) as parallel arrays with `NaN`
//!   absent-markers instead of one heap-allocated
//!   [`PeerLane`](crate::coordinator::network::PeerLane) (with its
//!   hotkey `String`) per peer. Exact
//!   [`LanePopulation`](crate::telemetry::LanePopulation) counters
//!   come straight off the arrays; `PeerLane`s are materialized only
//!   for the sampled cohort, making full-population counters the *only*
//!   O(peers) metrics work per round.
//! * [`SwarmSim`] — a timing-only swarm round driver over the same
//!   discrete-event spine ([`Scheduler`](crate::netsim::Scheduler)),
//!   compute tiers, WAN topology ([`WanModel`](crate::netsim::WanModel))
//!   and fault model as the real engine, but with constant per-peer
//!   wire sizes instead of real gradients. Steady-state rounds perform
//!   **zero per-peer heap allocation**: every vector is reset in place,
//!   the event heap is reused via `Scheduler::reset`, and all
//!   randomness is pure `(seed, hotkey)` hashing off prefixes computed
//!   once at join time.
//!
//! Determinism: everything is a pure function of `(seed, hotkey,
//! round)`. The only parallel section (the per-peer duration fill,
//! opt-in via `SwarmConfig::parallel`) writes disjoint indices of a
//! scratch array, so event traces are bit-identical across rayon pool
//! sizes — pinned by `tests/swarm_scale.rs`.

use crate::coordinator::network::PeerLane;
use crate::netsim::compute_model::{mix_finish, unit};
use crate::netsim::{
    ComputeModel, ComputeTier, Event, FaultConfig, FaultModel, HeterogeneityConfig, Link,
    Scheduler, VirtualClock, WanConfig, WanModel,
};
use crate::telemetry::{lane_hash_prefix, sample_indices, LanePopulation};

use super::worker::upload_backoff_s;

/// Hash tag for the per-round slow-upload (stall) draw in [`SwarmSim`].
const TAG_SLOW_UPLOAD: u64 = 0x510_77;

// ---------------------------------------------------------------------------
// SwarmLinks: Link/LinkPair state as struct-of-arrays
// ---------------------------------------------------------------------------

/// Per-peer asymmetric FIFO link state stored as parallel arrays — the
/// struct-of-arrays twin of a `Vec<LinkPair>`. Every operation
/// replicates the corresponding [`Link`](crate::netsim::Link) method
/// with the identical floating-point expression (same op order), so the
/// two representations produce bit-identical completion times on any
/// input sequence.
#[derive(Debug, Clone, Default)]
pub struct SwarmLinks {
    up_bps: Vec<f64>,
    up_latency: Vec<f64>,
    up_busy: Vec<f64>,
    up_bytes: Vec<u64>,
    down_bps: Vec<f64>,
    down_latency: Vec<f64>,
    down_busy: Vec<f64>,
    down_bytes: Vec<u64>,
}

impl SwarmLinks {
    /// An empty bank.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of peer slots.
    pub fn len(&self) -> usize {
        self.up_bps.len()
    }

    /// Whether the bank holds no slots.
    pub fn is_empty(&self) -> bool {
        self.up_bps.is_empty()
    }

    /// Append an idle link pair (mirrors `LinkPair::new`).
    pub fn push(&mut self, uplink_bps: f64, downlink_bps: f64, latency_s: f64) {
        assert!(uplink_bps > 0.0 && downlink_bps > 0.0);
        self.up_bps.push(uplink_bps);
        self.up_latency.push(latency_s);
        self.up_busy.push(0.0);
        self.up_bytes.push(0);
        self.down_bps.push(downlink_bps);
        self.down_latency.push(latency_s);
        self.down_busy.push(0.0);
        self.down_bytes.push(0);
    }

    /// Re-initialize slot `i` as an idle link pair (slot reuse on churn).
    pub fn set(&mut self, i: usize, uplink_bps: f64, downlink_bps: f64, latency_s: f64) {
        assert!(uplink_bps > 0.0 && downlink_bps > 0.0);
        self.up_bps[i] = uplink_bps;
        self.up_latency[i] = latency_s;
        self.up_busy[i] = 0.0;
        self.up_bytes[i] = 0;
        self.down_bps[i] = downlink_bps;
        self.down_latency[i] = latency_s;
        self.down_busy[i] = 0.0;
        self.down_bytes[i] = 0;
    }

    /// Remove slot `i`, shifting later slots down (mirrors
    /// `Vec::remove` so the bank stays index-aligned with a peer vec
    /// that removes by index on churn).
    pub fn remove(&mut self, i: usize) {
        self.up_bps.remove(i);
        self.up_latency.remove(i);
        self.up_busy.remove(i);
        self.up_bytes.remove(i);
        self.down_bps.remove(i);
        self.down_latency.remove(i);
        self.down_busy.remove(i);
        self.down_bytes.remove(i);
    }

    /// `Link::transfer` on slot `i`'s uplink — identical arithmetic,
    /// identical result bits.
    pub fn up_transfer(&mut self, i: usize, start: f64, bytes: usize) -> f64 {
        let begin = start.max(self.up_busy[i]);
        let duration = self.up_latency[i] + bytes as f64 * 8.0 / self.up_bps[i];
        self.up_busy[i] = begin + duration;
        self.up_bytes[i] += bytes as u64;
        self.up_busy[i]
    }

    /// `Link::busy_until` on slot `i`'s uplink.
    pub fn up_busy_until(&self, i: usize) -> f64 {
        self.up_busy[i]
    }

    /// `Link::release_at` on slot `i`'s uplink (monotone raise).
    pub fn up_release_at(&mut self, i: usize, t: f64) {
        self.up_busy[i] = self.up_busy[i].max(t);
    }

    /// `Link::cut_at` on slot `i`'s uplink: frees the tail of an
    /// in-flight transfer; charged bytes stay charged.
    pub fn up_cut_at(&mut self, i: usize, t: f64) -> bool {
        if self.up_busy[i] > t {
            self.up_busy[i] = t;
            true
        } else {
            false
        }
    }

    /// `Link::transfer` on slot `i`'s downlink.
    pub fn down_transfer(&mut self, i: usize, start: f64, bytes: usize) -> f64 {
        let begin = start.max(self.down_busy[i]);
        let duration = self.down_latency[i] + bytes as f64 * 8.0 / self.down_bps[i];
        self.down_busy[i] = begin + duration;
        self.down_bytes[i] += bytes as u64;
        self.down_busy[i]
    }

    /// `Link::busy_until` on slot `i`'s downlink.
    pub fn down_busy_until(&self, i: usize) -> f64 {
        self.down_busy[i]
    }

    /// Total bytes moved on slot `i` (uplink + downlink), mirroring the
    /// two `Link::bytes_total` counters.
    pub fn bytes_total(&self, i: usize) -> u64 {
        self.up_bytes[i] + self.down_bytes[i]
    }

    /// Retained heap, in bytes (capacity-based; for growth assertions).
    pub fn heap_bytes(&self) -> usize {
        (self.up_bps.capacity()
            + self.up_latency.capacity()
            + self.up_busy.capacity()
            + self.down_bps.capacity()
            + self.down_latency.capacity()
            + self.down_busy.capacity())
            * std::mem::size_of::<f64>()
            + (self.up_bytes.capacity() + self.down_bytes.capacity())
                * std::mem::size_of::<u64>()
    }
}

// ---------------------------------------------------------------------------
// LaneTable: per-round lane segments as struct-of-arrays
// ---------------------------------------------------------------------------

/// Per-round peer lane segments as parallel arrays. `NaN` in a
/// segment-start slot means "no segment" (virtual times are asserted
/// non-NaN by the scheduler, so the sentinel can never collide with a
/// real time); a finite upload start with a `+inf` end is a stalled
/// upload, exactly as in [`PeerLane`].
///
/// The table is the allocation-free representation the round engines
/// fill during the event waves; [`LaneTable::population`] computes the
/// exact whole-population counters directly from the arrays (the same
/// semantics as `telemetry::lane_population` over materialized lanes,
/// field for field), and [`LaneTable::materialize`] builds real
/// [`PeerLane`]s — hotkey strings and all — **only** for a sampled
/// index subset, so a 100k-peer report allocates lane strings for just
/// the sampled cohort.
#[derive(Debug, Clone, Default)]
pub struct LaneTable {
    compute_a: Vec<f64>,
    compute_b: Vec<f64>,
    upload_a: Vec<f64>,
    upload_b: Vec<f64>,
    download_a: Vec<f64>,
    download_b: Vec<f64>,
    late: Vec<bool>,
    /// `(lane, restart_time)` in push order (chronological per lane).
    retries: Vec<(u32, f64)>,
}

fn seg(a: f64, b: f64) -> Option<(f64, f64)> {
    if a.is_nan() {
        None
    } else {
        Some((a, b))
    }
}

fn seg_us(a: f64, b: f64) -> u64 {
    if a.is_nan() {
        return 0;
    }
    crate::telemetry::virtual_us(b - a).unwrap_or(0)
}

impl LaneTable {
    /// An empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// A table with `n` empty lanes.
    pub fn with_len(n: usize) -> Self {
        let mut t = Self::new();
        t.reset(n);
        t
    }

    /// Clear and resize to `n` empty lanes, retaining capacity — the
    /// per-round reset is allocation-free once the table has grown to
    /// the swarm size.
    pub fn reset(&mut self, n: usize) {
        for v in [
            &mut self.compute_a,
            &mut self.compute_b,
            &mut self.upload_a,
            &mut self.upload_b,
            &mut self.download_a,
            &mut self.download_b,
        ] {
            v.clear();
            v.resize(n, f64::NAN);
        }
        self.late.clear();
        self.late.resize(n, false);
        self.retries.clear();
    }

    /// Number of lanes.
    pub fn len(&self) -> usize {
        self.late.len()
    }

    /// Whether the table has no lanes.
    pub fn is_empty(&self) -> bool {
        self.late.is_empty()
    }

    /// Record lane `i`'s compute segment `[a, b)`.
    pub fn set_compute(&mut self, i: usize, a: f64, b: f64) {
        self.compute_a[i] = a;
        self.compute_b[i] = b;
    }

    /// Record lane `i`'s upload segment `[a, b)` (`b = +inf` = stalled).
    pub fn set_upload(&mut self, i: usize, a: f64, b: f64) {
        self.upload_a[i] = a;
        self.upload_b[i] = b;
    }

    /// Record lane `i`'s download segment `[a, b)`.
    pub fn set_download(&mut self, i: usize, a: f64, b: f64) {
        self.download_a[i] = a;
        self.download_b[i] = b;
    }

    /// Flag lane `i` late.
    pub fn set_late(&mut self, i: usize) {
        self.late[i] = true;
    }

    /// Record an upload-retry restart tick on lane `i`.
    pub fn push_retry(&mut self, i: usize, t: f64) {
        self.retries.push((i as u32, t));
    }

    /// Lane `i`'s upload segment, if recorded.
    pub fn upload(&self, i: usize) -> Option<(f64, f64)> {
        seg(self.upload_a[i], self.upload_b[i])
    }

    /// Exact whole-population counters over every lane — field-for-field
    /// the same semantics as `telemetry::lane_population` applied to the
    /// fully materialized lane set, without building a single `PeerLane`.
    pub fn population(&self) -> LanePopulation {
        let mut p = LanePopulation { peers: self.len() as u64, ..Default::default() };
        for i in 0..self.len() {
            if !self.compute_a[i].is_nan() {
                p.computed += 1;
            }
            if !self.upload_a[i].is_nan() {
                if self.upload_b[i].is_finite() {
                    p.uploaded += 1;
                } else {
                    p.stalled += 1;
                }
            }
            if !self.download_a[i].is_nan() {
                p.downloaded += 1;
            }
            if self.late[i] {
                p.late += 1;
            }
            p.compute_us += seg_us(self.compute_a[i], self.compute_b[i]);
            p.upload_us += seg_us(self.upload_a[i], self.upload_b[i]);
            p.download_us += seg_us(self.download_a[i], self.download_b[i]);
        }
        p.retries = self.retries.len() as u64;
        p
    }

    /// Materialize [`PeerLane`]s for the lanes in `keep` (ascending
    /// positions), calling `ident(i)` for each kept lane's
    /// `(uid, hotkey, tier)` identity. This is the only place lane
    /// hotkey `String`s are allocated — O(|keep|), never O(peers).
    pub fn materialize<F>(&self, keep: &[usize], mut ident: F) -> Vec<PeerLane>
    where
        F: FnMut(usize) -> (usize, String, ComputeTier),
    {
        let mut out = Vec::with_capacity(keep.len());
        for &i in keep {
            let (uid, hotkey, tier) = ident(i);
            let retry_at: Vec<f64> = self
                .retries
                .iter()
                .filter(|(j, _)| *j as usize == i)
                .map(|(_, t)| *t)
                .collect();
            out.push(PeerLane {
                uid,
                hotkey,
                tier,
                compute: seg(self.compute_a[i], self.compute_b[i]),
                upload: seg(self.upload_a[i], self.upload_b[i]),
                download: seg(self.download_a[i], self.download_b[i]),
                late: self.late[i],
                retry_at,
            });
        }
        out
    }

    /// Retained heap, in bytes (capacity-based; for growth assertions).
    pub fn heap_bytes(&self) -> usize {
        (self.compute_a.capacity()
            + self.compute_b.capacity()
            + self.upload_a.capacity()
            + self.upload_b.capacity()
            + self.download_a.capacity()
            + self.download_b.capacity())
            * std::mem::size_of::<f64>()
            + self.late.capacity()
            + self.retries.capacity() * std::mem::size_of::<(u32, f64)>()
    }
}

// ---------------------------------------------------------------------------
// SwarmRoster: peer identities + pure-hash prefixes, slot-reusing
// ---------------------------------------------------------------------------

/// Swarm peer identities in struct-of-arrays form. Hotkey bytes live in
/// one shared arena (`(offset, len)` spans per slot), and each slot
/// carries the two hash prefixes every per-round draw needs — the
/// `(seed, hotkey)` `mix` prefix (compute durations, stalls, faults,
/// WAN) and the seed-independent `lane_hash` prefix (telemetry
/// sampling) — so steady-state rounds never re-hash a hotkey string.
///
/// Departed peers leave tombstoned slots on a free list; a joining peer
/// reuses the lowest freed slot, overwriting the arena span in place
/// when the new hotkey has the same byte length (always true for the
/// fixed-width hotkeys [`SwarmSim`] mints), so sustained churn reaches
/// a fixed point in retained heap.
#[derive(Debug, Clone, Default)]
pub struct SwarmRoster {
    names: Vec<u8>,
    spans: Vec<(u32, u32)>,
    mix_pref: Vec<u64>,
    lane_pref: Vec<u64>,
    tier: Vec<ComputeTier>,
    region: Vec<u32>,
    /// Non-computing (free-rider) flag per slot.
    freerider: Vec<bool>,
    alive: Vec<bool>,
    free: Vec<u32>,
    n_alive: usize,
}

impl SwarmRoster {
    /// An empty roster.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total slots (alive + tombstoned).
    pub fn slots(&self) -> usize {
        self.spans.len()
    }

    /// Alive peers.
    pub fn alive(&self) -> usize {
        self.n_alive
    }

    /// Whether slot `i` holds a live peer.
    pub fn is_alive(&self, i: usize) -> bool {
        self.alive[i]
    }

    /// Slot `i`'s hotkey.
    pub fn name(&self, i: usize) -> &str {
        let (off, len) = self.spans[i];
        std::str::from_utf8(&self.names[off as usize..(off + len) as usize])
            .expect("roster names are always valid UTF-8")
    }

    /// Slot `i`'s `(seed, hotkey)` mix prefix.
    pub fn mix_prefix(&self, i: usize) -> u64 {
        self.mix_pref[i]
    }

    /// Slot `i`'s seed-independent `lane_hash` prefix.
    pub fn lane_prefix(&self, i: usize) -> u64 {
        self.lane_pref[i]
    }

    /// Slot `i`'s hardware tier.
    pub fn tier(&self, i: usize) -> ComputeTier {
        self.tier[i]
    }

    /// Slot `i`'s WAN region.
    pub fn region(&self, i: usize) -> usize {
        self.region[i] as usize
    }

    /// Whether slot `i` is a non-computing free-rider.
    pub fn is_freerider(&self, i: usize) -> bool {
        self.freerider[i]
    }

    /// Mark slot `i` honest (computes) or free-riding (uploads junk
    /// without computing) — the timing-level adversary toggle.
    pub fn set_freerider(&mut self, i: usize, yes: bool) {
        self.freerider[i] = yes;
    }

    /// Join `hotkey`, deriving its tier, region and hash prefixes from
    /// the models. Reuses the lowest tombstoned slot when one exists
    /// (in-place when hotkey byte lengths match); returns the slot
    /// index. The caller keeps its per-slot arrays (links, `ready_at`)
    /// aligned by matching push-vs-overwrite on the returned index.
    pub fn join(&mut self, hotkey: &str, compute: &ComputeModel, wan: &WanModel) -> usize {
        let mpref = compute.prefix(hotkey);
        let lpref = lane_hash_prefix(hotkey);
        let tier = compute.tier_from(mpref);
        let region = wan.region(hotkey) as u32;
        if let Some(slot) = self.free.pop() {
            let i = slot as usize;
            let (off, len) = self.spans[i];
            if len as usize == hotkey.len() {
                self.names[off as usize..(off + len) as usize].copy_from_slice(hotkey.as_bytes());
            } else {
                let off = self.names.len() as u32;
                self.names.extend_from_slice(hotkey.as_bytes());
                self.spans[i] = (off, hotkey.len() as u32);
            }
            self.mix_pref[i] = mpref;
            self.lane_pref[i] = lpref;
            self.tier[i] = tier;
            self.region[i] = region;
            self.freerider[i] = false;
            self.alive[i] = true;
            self.n_alive += 1;
            i
        } else {
            let off = self.names.len() as u32;
            self.names.extend_from_slice(hotkey.as_bytes());
            self.spans.push((off, hotkey.len() as u32));
            self.mix_pref.push(mpref);
            self.lane_pref.push(lpref);
            self.tier.push(tier);
            self.region.push(region);
            self.freerider.push(false);
            self.alive.push(true);
            self.n_alive += 1;
            self.spans.len() - 1
        }
    }

    /// Tombstone slot `i` (peer leaves). The slot is recycled by the
    /// next join.
    pub fn leave(&mut self, i: usize) {
        assert!(self.alive[i], "leave on a dead slot");
        self.alive[i] = false;
        self.freerider[i] = false;
        self.n_alive -= 1;
        self.free.push(i as u32);
    }

    /// Retained heap, in bytes (capacity-based; for growth assertions).
    pub fn heap_bytes(&self) -> usize {
        self.names.capacity()
            + self.spans.capacity() * std::mem::size_of::<(u32, u32)>()
            + (self.mix_pref.capacity() + self.lane_pref.capacity()) * 8
            + self.tier.capacity() * std::mem::size_of::<ComputeTier>()
            + self.region.capacity() * 4
            + self.freerider.capacity()
            + self.alive.capacity()
            + self.free.capacity() * 4
    }
}

// ---------------------------------------------------------------------------
// SwarmSim: the timing-only swarm round driver
// ---------------------------------------------------------------------------

/// Knobs for the timing-only swarm simulation. Defaults match the
/// paper's §4.3 operating point and the tiny-config wire size; every
/// stochastic layer (heterogeneity, WAN, faults, slow uploads) defaults
/// off, making the default round fully deterministic flat-model timing.
#[derive(Debug, Clone)]
pub struct SwarmConfig {
    /// Run seed feeding every pure-hash draw.
    pub seed: u64,
    /// Nominal compute window, seconds.
    pub compute_window_s: f64,
    /// Upload deadline past the compute window, seconds.
    pub comm_deadline_s: f64,
    /// Base uplink bits/s (per-peer, before WAN shaping).
    pub uplink_bps: f64,
    /// Base downlink bits/s.
    pub downlink_bps: f64,
    /// Base latency floor, seconds.
    pub latency_s: f64,
    /// Bytes each peer uploads per round (one compressed payload).
    pub wire_bytes: usize,
    /// Selected payloads every peer downloads per round
    /// (`download bytes = wire_bytes * agg_payloads`).
    pub agg_payloads: usize,
    /// Per-peer per-round probability of a stalled (never-finishing)
    /// upload, drawn by pure hash — no RNG stream.
    pub p_slow_upload: f64,
    /// Hardware-tier model knobs.
    pub heterogeneity: HeterogeneityConfig,
    /// WAN topology knobs.
    pub wan: WanConfig,
    /// Fault-injection knobs (only link flaps apply here).
    pub faults: FaultConfig,
    /// Fill per-peer compute durations on the rayon pool. Pure indexed
    /// writes, so traces stay bit-identical across pool sizes.
    pub parallel: bool,
    /// Keep the `(time, Event)` trace of each round in
    /// [`SwarmSim::event_log`] (costs O(events) memory per round).
    pub record_events: bool,
}

impl Default for SwarmConfig {
    fn default() -> Self {
        Self {
            seed: 0x5A17,
            compute_window_s: 1200.0,
            comm_deadline_s: 240.0,
            uplink_bps: 110e6,
            downlink_bps: 500e6,
            latency_s: 0.2,
            wire_bytes: 12_192,
            agg_payloads: 20,
            p_slow_upload: 0.0,
            heterogeneity: HeterogeneityConfig::default(),
            wan: WanConfig::default(),
            faults: FaultConfig::default(),
            parallel: false,
            record_events: false,
        }
    }
}

/// One round's aggregate outcome. `population.peers` counts lane-table
/// rows (all slots, tombstones included); `peers` counts live peers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwarmRoundStats {
    /// Round index.
    pub round: usize,
    /// Virtual round start.
    pub t_start: f64,
    /// Virtual round end (barrier: last download, or the deadline).
    pub t_end: f64,
    /// Live peers this round.
    pub peers: usize,
    /// Exact whole-population lane counters.
    pub population: LanePopulation,
    /// Bytes charged to uplinks (including flapped attempts).
    pub bytes_up: u64,
    /// Bytes charged to downlinks.
    pub bytes_down: u64,
}

/// The timing-only swarm round driver: tens of thousands of peers over
/// the real event spine, compute tiers, WAN topology and fault model,
/// with constant wire sizes standing in for real payloads. See the
/// module docs for the allocation and determinism contracts.
#[derive(Debug)]
pub struct SwarmSim {
    /// The knobs in effect.
    pub cfg: SwarmConfig,
    compute: ComputeModel,
    wan: WanModel,
    faults: FaultModel,
    roster: SwarmRoster,
    links: SwarmLinks,
    trunks: Vec<Link>,
    ready_at: Vec<f64>,
    lanes: LaneTable,
    sched: Scheduler,
    scratch_dur: Vec<f64>,
    t: f64,
    round: usize,
    next_id: u64,
    /// The `(time, Event)` trace of the most recent round, when
    /// `cfg.record_events` is on (cleared at each round start).
    pub event_log: Vec<(f64, Event)>,
}

impl SwarmSim {
    /// A fresh, empty swarm.
    pub fn new(cfg: SwarmConfig) -> Self {
        let compute = ComputeModel::new(cfg.seed, cfg.heterogeneity.clone());
        let wan = WanModel::new(cfg.seed, cfg.wan.clone());
        // Same env-resolution contract as the full round engine: only a
        // pristine default fault config picks up COVENANT_FAULT_SCENARIO.
        let faults = FaultModel::new(
            cfg.seed,
            cfg.faults
                .clone()
                .with_env(std::env::var("COVENANT_FAULT_SCENARIO").ok().as_deref()),
        );
        let trunks = wan.trunks();
        Self {
            cfg,
            compute,
            wan,
            faults,
            roster: SwarmRoster::new(),
            links: SwarmLinks::new(),
            trunks,
            ready_at: Vec::new(),
            lanes: LaneTable::new(),
            sched: Scheduler::new(VirtualClock::new()),
            scratch_dur: Vec::new(),
            t: 0.0,
            round: 0,
            next_id: 0,
            event_log: Vec::new(),
        }
    }

    /// The roster (names, tiers, regions, liveness).
    pub fn roster(&self) -> &SwarmRoster {
        &self.roster
    }

    /// The most recent round's lane table.
    pub fn lanes(&self) -> &LaneTable {
        &self.lanes
    }

    /// The WAN model in effect.
    pub fn wan(&self) -> &WanModel {
        &self.wan
    }

    /// Current virtual time (next round's start).
    pub fn now(&self) -> f64 {
        self.t
    }

    /// Rounds completed.
    pub fn rounds_done(&self) -> usize {
        self.round
    }

    /// Join a peer under an explicit hotkey; returns its slot. The
    /// slot's link is shaped by the WAN model (bit-identical to the
    /// base link when WAN is off) and its first compute may start
    /// immediately.
    pub fn join(&mut self, hotkey: &str) -> usize {
        let shape =
            self.wan.link_shape(hotkey, self.cfg.uplink_bps, self.cfg.downlink_bps, self.cfg.latency_s);
        let slot = self.roster.join(hotkey, &self.compute, &self.wan);
        if slot == self.links.len() {
            self.links.push(shape.up_bps, shape.down_bps, shape.latency_s);
            self.ready_at.push(self.t);
        } else {
            self.links.set(slot, shape.up_bps, shape.down_bps, shape.latency_s);
            self.ready_at[slot] = self.t;
        }
        slot
    }

    /// Join a freshly minted fixed-width hotkey (`swm-<8 digits>`, so
    /// churned slots recycle their arena spans in place).
    pub fn join_fresh(&mut self) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        let hk = format!("swm-{id:08}");
        self.join(&hk)
    }

    /// Join `n` fresh peers.
    pub fn spawn(&mut self, n: usize) {
        for _ in 0..n {
            self.join_fresh();
        }
    }

    /// Peer at `slot` leaves; the slot is tombstoned and recycled by
    /// the next join.
    pub fn leave(&mut self, slot: usize) {
        self.roster.leave(slot);
    }

    /// Toggle the timing-level adversary behaviour (free-riding) on a
    /// live slot.
    pub fn set_adversarial(&mut self, slot: usize, yes: bool) {
        self.roster.set_freerider(slot, yes);
    }

    /// Retained heap across all per-peer state, in bytes
    /// (capacity-based). Steady-state rounds must not grow this — the
    /// fuzz suite pins it.
    pub fn heap_bytes(&self) -> usize {
        self.roster.heap_bytes()
            + self.links.heap_bytes()
            + self.lanes.heap_bytes()
            + (self.ready_at.capacity() + self.scratch_dur.capacity()) * 8
            + self.trunks.capacity() * std::mem::size_of::<Link>()
            + self.sched.capacity() * 48
            + self.event_log.capacity() * std::mem::size_of::<(f64, Event)>()
    }

    /// Materialize the deterministic bottom-`k` sampled lane cohort of
    /// the most recent round (all lanes when `k == 0`). The only place
    /// the sim allocates per-lane strings — O(k), not O(peers).
    pub fn sampled_lanes(&self, k: usize) -> Vec<PeerLane> {
        let n = self.lanes.len();
        let keep =
            sample_indices(self.cfg.seed, (0..n).map(|i| self.roster.name(i)), k);
        self.lanes
            .materialize(&keep, |i| (i, self.roster.name(i).to_string(), self.roster.tier(i)))
    }

    fn record(&mut self, t: f64, ev: Event) {
        if self.cfg.record_events {
            self.event_log.push((t, ev));
        }
    }

    /// Attempt (or re-attempt after a flap) peer `i`'s upload at `req`.
    /// Returns bytes charged. Mirrors the round engine's flap handling:
    /// deterministic cut fraction, bounded exponential backoff, budget
    /// exhaustion abandons the submission (upload end = `+inf`).
    fn try_upload(&mut self, i: usize, req: f64, attempt: u32, round: usize, deadline: f64) -> u64 {
        let wire = self.cfg.wire_bytes;
        let begin = req.max(self.links.up_busy_until(i));
        let done = self.links.up_transfer(i, req, wire);
        let flapped = self.faults.flaps_enabled()
            && self.faults.link_flaps(self.roster.name(i), 0, round, attempt);
        if flapped {
            let frac = self.faults.flap_cut_frac(self.roster.name(i), 0, round, attempt);
            let cut_t = begin + frac * (done - begin);
            self.links.up_cut_at(i, cut_t);
            if attempt >= self.faults.cfg.max_upload_retries {
                // budget exhausted: abandoned, reads as a stalled lane
                self.lanes.set_upload(i, begin, f64::INFINITY);
            } else {
                let retry_at = cut_t + upload_backoff_s(self.faults.cfg.retry_backoff_s, attempt);
                self.lanes.push_retry(i, retry_at);
                self.sched
                    .schedule_at(retry_at, Event::UploadRetry { peer: i, shard: 0, attempt: attempt + 1 });
            }
            return wire as u64;
        }
        let mut fin = done;
        if !self.trunks.is_empty() {
            // FIFO region trunk: serializes, never reorders
            fin = self.trunks[self.roster.region(i)].transfer(fin, wire);
        }
        self.lanes.set_upload(i, begin, fin);
        if fin > deadline {
            self.lanes.set_late(i);
        }
        self.sched.schedule_at(fin, Event::UploadDone { peer: i });
        wire as u64
    }

    /// Run one swarm round: compute completions, FIFO uploads (with
    /// stalls, flaps and region trunks), a deadline tick, then the
    /// download wave — all on the discrete-event spine. Steady-state
    /// calls perform zero per-peer heap allocation.
    pub fn run_round(&mut self) -> SwarmRoundStats {
        let round = self.round;
        let t_start = self.t;
        let n = self.roster.slots();
        let window = self.cfg.compute_window_s;
        let compute_end = t_start + window;
        let deadline = compute_end + self.cfg.comm_deadline_s;
        let mut bytes_up = 0u64;
        let mut bytes_down = 0u64;

        self.lanes.reset(n);
        self.sched.reset(t_start);
        self.event_log.clear();

        // per-peer durations: pure hash off join-time prefixes; the
        // parallel fill writes disjoint indices, so pool size can't
        // move a bit
        self.scratch_dur.clear();
        self.scratch_dur.resize(n, 0.0);
        {
            let compute = &self.compute;
            let roster = &self.roster;
            let fill = |(i, d): (usize, &mut f64)| {
                *d = compute.duration_from(roster.mix_prefix(i), round, window);
            };
            if self.cfg.parallel {
                use rayon::prelude::*;
                self.scratch_dur.par_iter_mut().enumerate().for_each(fill);
            } else {
                self.scratch_dur.iter_mut().enumerate().for_each(fill);
            }
        }

        // wave 1: computes -> uploads -> deadline
        for i in 0..n {
            if !self.roster.is_alive(i) {
                continue;
            }
            let start = t_start.max(self.ready_at[i]);
            if self.roster.is_freerider(i) {
                // fabricates without computing: upload fires immediately
                self.sched.schedule_at(start, Event::ComputeDone { peer: i });
            } else {
                let fin = start + self.scratch_dur[i];
                self.lanes.set_compute(i, start, fin);
                self.sched.schedule_at(fin, Event::ComputeDone { peer: i });
            }
        }
        self.sched.schedule_at(deadline, Event::DeadlineHit);

        while let Some((t, ev)) = self.sched.pop() {
            self.record(t, ev);
            match ev {
                Event::ComputeDone { peer } => {
                    let stalled = self.cfg.p_slow_upload > 0.0
                        && unit(mix_finish(
                            self.roster.mix_prefix(peer),
                            TAG_SLOW_UPLOAD
                                ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        )) < self.cfg.p_slow_upload;
                    if stalled {
                        self.links.up_release_at(peer, deadline.max(t));
                        self.lanes.set_upload(peer, t, f64::INFINITY);
                    } else {
                        bytes_up += self.try_upload(peer, t, 0, round, deadline);
                    }
                }
                Event::UploadRetry { peer, attempt, .. } => {
                    bytes_up += self.try_upload(peer, t, attempt, round, deadline);
                }
                _ => {}
            }
        }

        // wave 2: every live peer downloads the selected aggregate
        self.sched.reset(t_start);
        let download_start = deadline;
        let agg_bytes = self.cfg.wire_bytes * self.cfg.agg_payloads;
        let mut t_end = deadline;
        for i in 0..n {
            if !self.roster.is_alive(i) {
                continue;
            }
            let begin = download_start.max(self.links.down_busy_until(i));
            let done = self.links.down_transfer(i, download_start, agg_bytes);
            bytes_down += agg_bytes as u64;
            self.lanes.set_download(i, begin, done);
            self.ready_at[i] = done;
            t_end = t_end.max(done);
            self.sched.schedule_at(done, Event::DownloadDone { peer: i });
        }
        while let Some((t, ev)) = self.sched.pop() {
            self.record(t, ev);
        }

        self.t = t_end;
        self.round += 1;
        SwarmRoundStats {
            round,
            t_start,
            t_end,
            peers: self.roster.alive(),
            population: self.lanes.population(),
            bytes_up,
            bytes_down,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::LinkPair;
    use crate::telemetry::lane_population;

    #[test]
    fn swarm_links_bitwise_match_link_pairs() {
        // drive an identical op sequence through SwarmLinks and a
        // Vec<LinkPair> mirror; every completion time and busy state
        // must match bit-for-bit
        let mut soa = SwarmLinks::new();
        let mut aos: Vec<LinkPair> = Vec::new();
        for i in 0..8 {
            let up = 50e6 + i as f64 * 7e6;
            let down = 200e6 + i as f64 * 13e6;
            let lat = 0.05 * (i + 1) as f64;
            soa.push(up, down, lat);
            aos.push(LinkPair::new(up, down, lat));
        }
        let mut z = 0x1234_5678_9abc_def0u64;
        let mut next = || {
            z ^= z << 13;
            z ^= z >> 7;
            z ^= z << 17;
            z
        };
        for step in 0..400 {
            let i = (next() % 8) as usize;
            let start = (next() % 10_000) as f64 / 10.0;
            let bytes = (next() % 2_000_000) as usize + 1;
            match step % 5 {
                0 | 1 => {
                    let a = soa.up_transfer(i, start, bytes);
                    let b = aos[i].up.transfer(start, bytes);
                    assert_eq!(a.to_bits(), b.to_bits(), "up_transfer diverged at {step}");
                }
                2 => {
                    let a = soa.down_transfer(i, start, bytes);
                    let b = aos[i].down.transfer(start, bytes);
                    assert_eq!(a.to_bits(), b.to_bits(), "down_transfer diverged at {step}");
                }
                3 => {
                    soa.up_release_at(i, start);
                    aos[i].up.release_at(start);
                }
                _ => {
                    let a = soa.up_cut_at(i, start);
                    let b = aos[i].up.cut_at(start);
                    assert_eq!(a, b, "cut_at verdict diverged at {step}");
                }
            }
            assert_eq!(
                soa.up_busy_until(i).to_bits(),
                aos[i].up.busy_until().to_bits(),
                "uplink busy state diverged at {step}"
            );
            assert_eq!(
                soa.down_busy_until(i).to_bits(),
                aos[i].down.busy_until().to_bits()
            );
            assert_eq!(
                soa.bytes_total(i),
                aos[i].up.bytes_total + aos[i].down.bytes_total
            );
        }
        // remove keeps the bank index-aligned with Vec::remove
        soa.remove(3);
        aos.remove(3);
        assert_eq!(soa.len(), aos.len());
        for i in 0..soa.len() {
            assert_eq!(soa.up_busy_until(i).to_bits(), aos[i].up.busy_until().to_bits());
        }
    }

    #[test]
    fn lane_table_population_matches_materialized_recount() {
        let mut t = LaneTable::with_len(5);
        t.set_compute(0, 0.0, 10.0);
        t.set_upload(0, 10.0, 20.0);
        t.set_download(0, 20.0, 25.0);
        t.set_compute(1, 0.0, 12.0);
        t.set_upload(1, 12.0, f64::INFINITY); // stalled
        t.set_compute(2, 0.0, 9.0);
        t.set_upload(2, 9.0, 30.0);
        t.set_late(2);
        t.push_retry(2, 15.0);
        t.push_retry(2, 22.0);
        t.set_download(3, 20.0, 21.0);
        // lane 4 stays empty
        let keep: Vec<usize> = (0..5).collect();
        let lanes = t.materialize(&keep, |i| (i, format!("hk-{i:05}"), ComputeTier::Median));
        assert_eq!(t.population(), lane_population(&lanes), "SoA counters == recount");
        assert_eq!(lanes[2].retry_at, vec![15.0, 22.0]);
        assert_eq!(lanes[1].upload, Some((12.0, f64::INFINITY)));
        assert_eq!(lanes[4].compute, None);
        // subset materialization allocates only the kept lanes
        let some = t.materialize(&[1, 3], |i| (i, format!("hk-{i:05}"), ComputeTier::Median));
        assert_eq!(some.len(), 2);
        assert_eq!(some[0].uid, 1);
        assert_eq!(some[1].uid, 3);
    }

    #[test]
    fn lane_table_reset_retains_capacity() {
        let mut t = LaneTable::with_len(1000);
        t.push_retry(5, 1.0);
        let cap = t.heap_bytes();
        for _ in 0..10 {
            t.reset(1000);
        }
        assert_eq!(t.heap_bytes(), cap, "reset must not reallocate");
        assert_eq!(t.population(), LanePopulation { peers: 1000, ..Default::default() });
    }

    #[test]
    fn roster_recycles_slots_and_names_in_place() {
        let cfg = SwarmConfig::default();
        let compute = ComputeModel::new(cfg.seed, cfg.heterogeneity.clone());
        let wan = WanModel::new(cfg.seed, cfg.wan.clone());
        let mut r = SwarmRoster::new();
        for i in 0..10 {
            assert_eq!(r.join(&format!("swm-{i:08}"), &compute, &wan), i);
        }
        // first churn cycle may grow the free-list's capacity; the heap
        // fixed point is measured across subsequent cycles
        r.leave(3);
        r.leave(7);
        assert_eq!(r.alive(), 8);
        // same-width hotkeys reuse the freed slots and arena spans (LIFO)
        let s1 = r.join("swm-00000099", &compute, &wan);
        let s2 = r.join("swm-00000100", &compute, &wan);
        assert_eq!((s1, s2), (7, 3));
        assert_eq!(r.slots(), 10);
        assert_eq!(r.name(s1), "swm-00000099");
        assert_eq!(r.name(s2), "swm-00000100");
        let heap1 = r.heap_bytes();
        for k in 0..20 {
            r.leave(k % 10);
            let s = r.join(&format!("swm-{:08}", 200 + k), &compute, &wan);
            assert_eq!(s, k % 10);
        }
        assert_eq!(r.heap_bytes(), heap1, "fixed-width churn reaches a heap fixed point");
        assert_eq!(r.alive(), 10);
    }

    #[test]
    fn default_swarm_round_is_deterministic_and_flat() {
        let mk = || {
            let mut s = SwarmSim::new(SwarmConfig::default());
            s.spawn(64);
            s
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..3 {
            let sa = a.run_round();
            let sb = b.run_round();
            assert_eq!(sa, sb);
            assert_eq!(sa.t_end.to_bits(), sb.t_end.to_bits());
            // flat default: everyone computes exactly the window and uploads
            assert_eq!(sa.population.computed, 64);
            assert_eq!(sa.population.uploaded, 64);
            assert_eq!(sa.population.stalled, 0);
            assert_eq!(sa.population.retries, 0);
        }
    }

    #[test]
    fn freerider_skips_compute_but_uploads() {
        let mut s = SwarmSim::new(SwarmConfig::default());
        s.spawn(8);
        s.set_adversarial(2, true);
        let st = s.run_round();
        assert_eq!(st.population.computed, 7);
        assert_eq!(st.population.uploaded, 8);
        // the free-rider's upload began at round start, not window end
        let (a, _) = s.lanes().upload(2).unwrap();
        assert!(a < s.cfg.compute_window_s);
    }

    #[test]
    fn sampled_lanes_are_bounded_and_ordered() {
        let mut s = SwarmSim::new(SwarmConfig::default());
        s.spawn(50);
        s.run_round();
        let all = s.sampled_lanes(0);
        assert_eq!(all.len(), 50);
        let some = s.sampled_lanes(8);
        assert_eq!(some.len(), 8);
        let mut cursor = 0;
        for l in &some {
            let pos = all[cursor..].iter().position(|f| f.hotkey == l.hotkey);
            let pos = pos.expect("sampled lane exists in full set, order preserved");
            cursor += pos + 1;
        }
    }
}
