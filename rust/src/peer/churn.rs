//! Churn model: peers join and leave freely (paper §4.4).
//!
//! The reward mechanism is calibrated so there are always slightly more
//! active participants than aggregated contributors (App. A): when the
//! active count drops below target, open slots fill quickly (emissions
//! attract new registrations); a small per-round leave probability models
//! voluntary exits and failures. Calibrated to reproduce Fig. 4/6's means
//! (~24.4 active, ~16.9 contributing with cap 20) and Fig. 5's >=70
//! unique participants over a long run.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct ChurnConfig {
    /// Target active population (paper observes mean ~24.4).
    pub target_active: usize,
    /// Per-round probability each active peer leaves.
    pub p_leave: f64,
    /// Per-round cap on joins (registration rate limit).
    pub max_joins_per_round: usize,
    /// Probability a *new* join is an adversarial peer.
    pub p_adversarial: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        Self { target_active: 25, p_leave: 0.02, max_joins_per_round: 4, p_adversarial: 0.12 }
    }
}

/// Events produced for one round.
#[derive(Debug, Clone, Default)]
pub struct ChurnEvents {
    /// Hotkeys of peers that leave this round.
    pub leaves: Vec<String>,
    /// Number of fresh peers joining this round.
    pub joins: usize,
}

/// Stateful churn process over rounds.
#[derive(Debug)]
pub struct ChurnModel {
    pub cfg: ChurnConfig,
    rng: Rng,
    /// Monotone counter for fresh hotkey names.
    next_id: usize,
}

impl ChurnModel {
    pub fn new(cfg: ChurnConfig, seed: u64) -> Self {
        Self { cfg, rng: Rng::new(seed), next_id: 0 }
    }

    /// Mint a fresh unique hotkey.
    pub fn fresh_hotkey(&mut self) -> String {
        let id = self.next_id;
        self.next_id += 1;
        format!("hk-{id:05}")
    }

    /// Whether a fresh join should be adversarial, and which kind (index
    /// into the Behavior::adversarial_kinds table).
    pub fn roll_adversarial(&mut self) -> Option<usize> {
        if self.rng.bool(self.cfg.p_adversarial) {
            Some(self.rng.below(4))
        } else {
            None
        }
    }

    /// Compute this round's churn for the current active hotkeys.
    pub fn step(&mut self, active: &[String]) -> ChurnEvents {
        let mut ev = ChurnEvents::default();
        for hk in active {
            if self.rng.bool(self.cfg.p_leave) {
                ev.leaves.push(hk.clone());
            }
        }
        let after_leave = active.len() - ev.leaves.len();
        if after_leave < self.cfg.target_active {
            let deficit = self.cfg.target_active - after_leave;
            // Incentive pressure: most of the deficit fills immediately.
            let base = deficit.min(self.cfg.max_joins_per_round);
            let noise = self.rng.poisson(0.3);
            ev.joins = (base + noise).min(self.cfg.max_joins_per_round);
        } else {
            // At/above target: occasional speculative join — still capped
            // by the registration rate limit, so max_joins_per_round = 0
            // really means zero churn-driven joins (the adversary-suite
            // tests rely on an exactly-frozen population).
            ev.joins = usize::from(self.rng.bool(0.05)).min(self.cfg.max_joins_per_round);
        }
        ev
    }

    pub fn unique_peers_minted(&self) -> usize {
        self.next_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_population(rounds: usize, seed: u64) -> (f64, usize) {
        let mut cm = ChurnModel::new(ChurnConfig::default(), seed);
        let mut active: Vec<String> = (0..25).map(|_| cm.fresh_hotkey()).collect();
        let mut sum = 0usize;
        for _ in 0..rounds {
            let ev = cm.step(&active);
            active.retain(|hk| !ev.leaves.contains(hk));
            for _ in 0..ev.joins {
                active.push(cm.fresh_hotkey());
            }
            sum += active.len();
        }
        (sum as f64 / rounds as f64, cm.unique_peers_minted())
    }

    #[test]
    fn population_hovers_near_target() {
        let (mean, _) = run_population(500, 42);
        assert!((mean - 25.0).abs() < 2.0, "mean active = {mean}");
    }

    #[test]
    fn long_run_reaches_70_unique_peers() {
        // Fig. 5: at least 70 unique participants over the run.
        let (_, unique) = run_population(500, 7);
        assert!(unique >= 70, "unique = {unique}");
    }

    #[test]
    fn fresh_hotkeys_unique() {
        let mut cm = ChurnModel::new(ChurnConfig::default(), 1);
        let a = cm.fresh_hotkey();
        let b = cm.fresh_hotkey();
        assert_ne!(a, b);
    }

    #[test]
    fn deterministic() {
        assert_eq!(run_population(100, 9), run_population(100, 9));
    }

    #[test]
    fn zero_max_joins_freezes_the_population() {
        let cfg = ChurnConfig {
            target_active: 4,
            p_leave: 0.0,
            max_joins_per_round: 0,
            p_adversarial: 0.0,
        };
        let mut cm = ChurnModel::new(cfg, 11);
        let active: Vec<String> = (0..4).map(|_| cm.fresh_hotkey()).collect();
        for _ in 0..200 {
            let ev = cm.step(&active);
            assert!(ev.leaves.is_empty());
            assert_eq!(ev.joins, 0, "speculative joins must respect the cap");
        }
    }

    #[test]
    fn adversarial_rate() {
        let mut cm = ChurnModel::new(ChurnConfig::default(), 3);
        let n = 10_000;
        let hits = (0..n).filter(|_| cm.roll_adversarial().is_some()).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.12).abs() < 0.02, "rate={rate}");
    }
}
