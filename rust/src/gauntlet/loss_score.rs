//! LossScore (paper §2.2): the validator's main evaluation signal — the
//! loss difference before and after applying a participant's contribution,
//! measured on small batches of the peer's *assigned* data and on random
//! *unassigned* data. Improving unassigned data more than assigned data
//! indicates copying/duplication and earns a negative score.

use anyhow::Result;

use crate::runtime::{ops, Engine};
use crate::sparseloco::Payload;

/// One evaluation batch: (tokens [B,(T+1)], mask [B,T]).
pub type EvalBatch = (Vec<i32>, Vec<f32>);

/// LossScore outcome for one submission.
#[derive(Debug, Clone, Copy)]
pub struct LossScoreResult {
    /// Mean loss improvement on the peer's assigned shards.
    pub assigned_improvement: f64,
    /// Mean loss improvement on random unassigned data.
    pub unassigned_improvement: f64,
    /// Anti-copy flag: unassigned improved more than assigned (+margin).
    pub suspected_copy: bool,
}

impl LossScoreResult {
    /// Scalar score: assigned improvement, negated on copy suspicion.
    pub fn score(&self) -> f64 {
        if self.suspected_copy {
            -self.assigned_improvement.abs().max(1e-6)
        } else {
            self.assigned_improvement
        }
    }
}

/// Apply a single peer's contribution to the base model (pure Rust —
/// candidate = base - alpha * decompress(payload)).
pub fn apply_single(base: &[f32], payload: &Payload, alpha: f32) -> Vec<f32> {
    let mut candidate = base.to_vec();
    payload
        .accumulate_into(&mut candidate, -alpha)
        .expect("payload geometry checked by fast checks");
    candidate
}

/// Mean loss across batches. One workspace checkout for the whole set
/// (`ops::eval_loss_many`), so the candidate's weights unpack once no
/// matter how many batches — or how many concurrent evaluations share
/// the engine's workspace pool.
pub fn mean_loss(eng: &Engine, params: &[f32], batches: &[EvalBatch]) -> Result<f64> {
    let losses = ops::eval_loss_many(eng, params, batches)?;
    let acc: f64 = losses.iter().map(|&l| l as f64).sum();
    Ok(acc / losses.len().max(1) as f64)
}

/// Full LossScore for one submission.
///
/// `base_assigned_loss` / `base_unassigned_loss` are the base model's mean
/// losses on the same batches (computed once per round by the validator,
/// not per peer — that's what makes the subset evaluation cheap).
#[allow(clippy::too_many_arguments)]
pub fn loss_score(
    eng: &Engine,
    base: &[f32],
    payload: &Payload,
    alpha: f32,
    assigned: &[EvalBatch],
    unassigned: &[EvalBatch],
    base_assigned_loss: f64,
    base_unassigned_loss: f64,
    copy_margin: f64,
) -> Result<LossScoreResult> {
    let candidate = apply_single(base, payload, alpha);
    let a = base_assigned_loss - mean_loss(eng, &candidate, assigned)?;
    let u = base_unassigned_loss - mean_loss(eng, &candidate, unassigned)?;
    Ok(LossScoreResult {
        assigned_improvement: a,
        unassigned_improvement: u,
        suspected_copy: u > a + copy_margin,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_single_subtracts_scaled() {
        let base = vec![1.0f32; 128];
        let payload = crate::sparseloco::topk::compress_dense(&[0.5f32; 128], 64, 2);
        let cand = apply_single(&base, &payload, 2.0);
        // exactly 2 positions per chunk changed by -2*0.5
        let changed: Vec<f32> = cand.iter().copied().filter(|&x| x != 1.0).collect();
        assert_eq!(changed.len(), 4);
        for c in changed {
            assert!((c - 0.0).abs() < 0.4, "got {c}"); // 1 - 2*~0.5
        }
    }

    #[test]
    fn score_sign() {
        let good = LossScoreResult {
            assigned_improvement: 0.1,
            unassigned_improvement: 0.05,
            suspected_copy: false,
        };
        assert!(good.score() > 0.0);
        let copycat = LossScoreResult {
            assigned_improvement: 0.1,
            unassigned_improvement: 0.3,
            suspected_copy: true,
        };
        assert!(copycat.score() < 0.0);
    }
}
