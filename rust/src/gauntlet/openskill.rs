//! OpenSkill ratings — Weng–Lin Bayesian approximation, Plackett–Luce
//! model (Algorithm 4 of Weng & Lin 2011; the model used by the paper's
//! Gauntlet to maintain persistent peer rankings under per-round
//! randomness, §2.2).
//!
//! Single-player teams (each peer is its own team). Defaults match
//! openskill.py: mu=25, sigma=25/3, beta=25/6.

use std::collections::BTreeMap;

/// One peer's persistent rating.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rating {
    pub mu: f64,
    pub sigma: f64,
}

impl Default for Rating {
    fn default() -> Self {
        Rating { mu: 25.0, sigma: 25.0 / 3.0 }
    }
}

impl Rating {
    /// Conservative skill estimate (openskill's `ordinal`).
    pub fn ordinal(&self) -> f64 {
        self.mu - 3.0 * self.sigma
    }
}

const BETA: f64 = 25.0 / 6.0;
const KAPPA: f64 = 1e-4; // sigma floor factor

/// Update ratings for one "match": `ranked` lists (key, rank) where rank 0
/// is best; ties share a rank. Returns the updated ratings in input order.
pub fn rate_plackett_luce(ratings: &[(Rating, usize)]) -> Vec<Rating> {
    let n = ratings.len();
    if n < 2 {
        return ratings.iter().map(|(r, _)| *r).collect();
    }
    let c: f64 = ratings
        .iter()
        .map(|(r, _)| r.sigma * r.sigma + BETA * BETA)
        .sum::<f64>()
        .sqrt();
    // A_q: number of teams tied with q.
    let a: Vec<f64> = ratings
        .iter()
        .map(|(_, rq)| ratings.iter().filter(|(_, r2)| r2 == rq).count() as f64)
        .collect();
    // sum_q[q] = sum over s with rank(s) >= rank(q) of exp(mu_s / c)
    let expmu: Vec<f64> = ratings.iter().map(|(r, _)| (r.mu / c).exp()).collect();
    let sum_q: Vec<f64> = ratings
        .iter()
        .map(|(_, rq)| {
            ratings
                .iter()
                .zip(&expmu)
                .filter(|((_, rs), _)| rs >= rq)
                .map(|(_, e)| *e)
                .sum::<f64>()
        })
        .collect();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let (ri, rank_i) = ratings[i];
        let mut omega = 0.0;
        let mut delta = 0.0;
        let gamma = ri.sigma / c;
        for q in 0..n {
            let (_, rank_q) = ratings[q];
            if rank_q > rank_i {
                continue; // only q with rank(q) <= rank(i)
            }
            let p_iq = expmu[i] / sum_q[q];
            let d = if q == i { 1.0 } else { 0.0 };
            omega += (d - p_iq) / a[q];
            delta += gamma * p_iq * (1.0 - p_iq) / a[q];
        }
        let sigma2 = ri.sigma * ri.sigma;
        let mu2 = ri.mu + omega * sigma2 / c;
        let sig_scale = (1.0 - delta * sigma2 / (c * c)).max(KAPPA);
        let sigma_new = ri.sigma * sig_scale.sqrt();
        out.push(Rating { mu: mu2, sigma: sigma_new });
    }
    out
}

/// Persistent book of ratings keyed by hotkey.
#[derive(Debug, Default, Clone)]
pub struct RatingBook {
    ratings: BTreeMap<String, Rating>,
}

impl RatingBook {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, key: &str) -> Rating {
        self.ratings.get(key).copied().unwrap_or_default()
    }

    pub fn ordinal(&self, key: &str) -> f64 {
        self.get(key).ordinal()
    }

    /// Record one match: `ranked[i] = (hotkey, rank)`, rank 0 best.
    pub fn record_match(&mut self, ranked: &[(&str, usize)]) {
        let rs: Vec<(Rating, usize)> =
            ranked.iter().map(|(k, r)| (self.get(k), *r)).collect();
        let updated = rate_plackett_luce(&rs);
        for ((k, _), r) in ranked.iter().zip(updated) {
            self.ratings.insert(k.to_string(), r);
        }
    }

    pub fn forget(&mut self, key: &str) {
        self.ratings.remove(key);
    }

    pub fn len(&self) -> usize {
        self.ratings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ratings.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn winner_gains_loser_loses() {
        let r = vec![(Rating::default(), 0), (Rating::default(), 1)];
        let out = rate_plackett_luce(&r);
        assert!(out[0].mu > 25.0, "winner mu {}", out[0].mu);
        assert!(out[1].mu < 25.0, "loser mu {}", out[1].mu);
        assert!(out[0].sigma < 25.0 / 3.0);
        assert!(out[1].sigma < 25.0 / 3.0);
    }

    #[test]
    fn repeated_wins_converge_to_ordering() {
        let mut book = RatingBook::new();
        for _ in 0..30 {
            book.record_match(&[("strong", 0), ("mid", 1), ("weak", 2)]);
        }
        let s = book.ordinal("strong");
        let m = book.ordinal("mid");
        let w = book.ordinal("weak");
        assert!(s > m && m > w, "{s} {m} {w}");
        // sigma shrinks with evidence (PL updates shrink slowly)
        assert!(book.get("strong").sigma < 25.0 / 3.0);
    }

    #[test]
    fn upset_moves_ratings_more() {
        let mut book = RatingBook::new();
        for _ in 0..20 {
            book.record_match(&[("a", 0), ("b", 1)]);
        }
        let a_before = book.get("a").mu;
        // upset: b beats a
        book.record_match(&[("b", 0), ("a", 1)]);
        let drop_upset = a_before - book.get("a").mu;
        assert!(drop_upset > 0.0);
    }

    #[test]
    fn ties_share_rank() {
        let r = vec![(Rating::default(), 0), (Rating::default(), 0)];
        let out = rate_plackett_luce(&r);
        assert!((out[0].mu - out[1].mu).abs() < 1e-9);
    }

    #[test]
    fn single_entry_noop() {
        let r = vec![(Rating::default(), 0)];
        let out = rate_plackett_luce(&r);
        assert_eq!(out[0], Rating::default());
    }

    #[test]
    fn new_peer_default_rating() {
        let book = RatingBook::new();
        assert_eq!(book.get("nobody"), Rating::default());
        assert!((book.ordinal("nobody") - 0.0).abs() < 1e-9);
    }

    #[test]
    fn sigma_never_collapses_to_zero() {
        let mut book = RatingBook::new();
        for _ in 0..500 {
            book.record_match(&[("x", 0), ("y", 1)]);
        }
        assert!(book.get("x").sigma > 0.0);
    }
}
