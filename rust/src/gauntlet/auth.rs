//! Pre-decode payload authentication for the Gauntlet fast-check path.
//!
//! The trust boundary sits *before* the codec: a submission's sealed
//! shard-slices are parsed ([`envelope::open`], zero-copy), the tag is
//! verified against the chain's registered key for the claimed hotkey,
//! and nonce freshness is checked against a per-key replay window — all
//! without decoding a single payload byte. Failures become pre-verdicts
//! ([`FastCheck::BadSignature`] / [`FastCheck::ReplayedPayload`]) that
//! pre-empt the rest of the fast-check battery, so forged or replayed
//! bytes cost the validator one MAC recompute, never a decode or an eval.
//!
//! Replay windows are keyed by [`VerifyingKey::id`], not by hotkey or
//! UID:
//!
//! - a sybil swarm registering one shared key under many hotkeys shares
//!   ONE window — the first envelope of a round advances it and every
//!   other swarm member bounces off as [`FastCheck::ReplayedPayload`]
//!   ("one key, one submission per round");
//! - a recycled UID re-registered with a fresh hotkey derives a fresh
//!   key and therefore a fresh window — it inherits nothing from the
//!   departed identity.

use std::collections::HashMap;

use crate::gauntlet::fast_checks::FastCheck;
use crate::sparseloco::envelope::{self, VerifyingKey};

/// Running authentication counters for a network lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AuthStats {
    /// Submissions whose every slice parsed, verified, and was fresh.
    pub verified: u64,
    /// Submissions rejected with [`FastCheck::BadSignature`].
    pub bad_signature: u64,
    /// Submissions rejected with [`FastCheck::ReplayedPayload`].
    pub replayed: u64,
}

/// Stateful envelope verifier: key lookup is delegated to the caller
/// (the chain's registry), replay windows live here.
#[derive(Debug, Default)]
pub struct AuthVerifier {
    /// Highest accepted nonce per verifying-key id. Advances only on
    /// fully accepted submissions, so a rejected envelope cannot burn a
    /// victim's window.
    windows: HashMap<u64, u64>,
    /// Lifetime accept/reject counters.
    pub stats: AuthStats,
}

impl AuthVerifier {
    /// Fresh verifier with empty replay windows.
    pub fn new() -> Self {
        Self::default()
    }

    /// Authenticate one submission's sealed shard-slices.
    ///
    /// Returns `None` if the submission is authentic and fresh (the
    /// replay window advances), or the pre-verdict that rejects it.
    /// `lookup` resolves a claimed hotkey to its registered verifying
    /// key; `round` is the coordinator's current outer round (envelopes
    /// for any other round are stale or premature); `n_shards` is the
    /// expected slice count.
    pub fn verify_submission(
        &mut self,
        slices: &[Vec<u8>],
        lookup: &dyn Fn(&str) -> Option<VerifyingKey>,
        round: u64,
        n_shards: usize,
    ) -> Option<FastCheck> {
        match self.check(slices, lookup, round, n_shards) {
            Ok(()) => {
                self.stats.verified += 1;
                None
            }
            Err(v) => {
                match v {
                    FastCheck::BadSignature => self.stats.bad_signature += 1,
                    FastCheck::ReplayedPayload => self.stats.replayed += 1,
                    _ => {}
                }
                Some(v)
            }
        }
    }

    fn check(
        &mut self,
        slices: &[Vec<u8>],
        lookup: &dyn Fn(&str) -> Option<VerifyingKey>,
        round: u64,
        n_shards: usize,
    ) -> Result<(), FastCheck> {
        if slices.len() != n_shards || n_shards == 0 {
            return Err(FastCheck::BadSignature);
        }
        // Parse every slice before trusting anything: each must be a
        // well-formed envelope targeting its own slice position.
        let mut envs = Vec::with_capacity(slices.len());
        for (s, bytes) in slices.iter().enumerate() {
            let env = envelope::open(bytes).map_err(|_| FastCheck::BadSignature)?;
            if env.shard as usize != s {
                return Err(FastCheck::BadSignature);
            }
            envs.push(env);
        }
        // One identity and one nonce across the whole slice set.
        let (hotkey, nonce, env_round) = (envs[0].hotkey, envs[0].nonce, envs[0].round);
        if envs.iter().any(|e| e.hotkey != hotkey || e.nonce != nonce || e.round != env_round) {
            return Err(FastCheck::BadSignature);
        }
        let key = lookup(hotkey).ok_or(FastCheck::BadSignature)?;
        for env in &envs {
            if !env.verify(&key) {
                return Err(FastCheck::BadSignature);
            }
        }
        // Freshness, per verifying KEY (see module docs). Signature
        // problems outrank replay problems, so this comes last.
        if let Some(&w) = self.windows.get(&key.id()) {
            if nonce <= w {
                return Err(FastCheck::ReplayedPayload);
            }
        }
        if env_round != round {
            return Err(FastCheck::ReplayedPayload);
        }
        self.windows.insert(key.id(), nonce);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparseloco::envelope::SigningKey;

    const SEED: u64 = 0x7E57;

    fn sealed(hotkey: &str, key: &SigningKey, round: u64, n_shards: usize) -> Vec<Vec<u8>> {
        (0..n_shards)
            .map(|s| envelope::seal(&[s as u8; 32], hotkey, round, s as u32, round, key))
            .collect()
    }

    /// Registry with honestly derived keys for the given hotkeys.
    fn registry(hotkeys: &[&str]) -> HashMap<String, VerifyingKey> {
        hotkeys
            .iter()
            .map(|h| (h.to_string(), SigningKey::derive(SEED, h).verifying()))
            .collect()
    }

    #[test]
    fn honest_submission_accepted_across_rounds_and_shards() {
        let reg = registry(&["alice", "bob"]);
        let lookup = |h: &str| reg.get(h).copied();
        let mut auth = AuthVerifier::new();
        for round in 0..3u64 {
            for hk in ["alice", "bob"] {
                let s = sealed(hk, &SigningKey::derive(SEED, hk), round, 3);
                assert_eq!(auth.verify_submission(&s, &lookup, round, 3), None);
            }
        }
        assert_eq!(auth.stats, AuthStats { verified: 6, bad_signature: 0, replayed: 0 });
    }

    #[test]
    fn forged_signature_rejected() {
        let reg = registry(&["alice"]);
        let lookup = |h: &str| reg.get(h).copied();
        let mut auth = AuthVerifier::new();
        // signed with a key that is not alice's registered key
        let s = sealed("alice", &SigningKey::derive(SEED ^ 1, "alice"), 0, 2);
        assert_eq!(auth.verify_submission(&s, &lookup, 0, 2), Some(FastCheck::BadSignature));
        assert_eq!(auth.stats.bad_signature, 1);
    }

    #[test]
    fn unregistered_hotkey_rejected() {
        let reg = registry(&["alice"]);
        let lookup = |h: &str| reg.get(h).copied();
        let mut auth = AuthVerifier::new();
        let s = sealed("mallory", &SigningKey::derive(SEED, "mallory"), 0, 1);
        assert_eq!(auth.verify_submission(&s, &lookup, 0, 1), Some(FastCheck::BadSignature));
    }

    #[test]
    fn replayed_submission_rejected_but_window_survives() {
        let reg = registry(&["alice"]);
        let lookup = |h: &str| reg.get(h).copied();
        let key = SigningKey::derive(SEED, "alice");
        let mut auth = AuthVerifier::new();
        let round0 = sealed("alice", &key, 0, 2);
        assert_eq!(auth.verify_submission(&round0, &lookup, 0, 2), None);
        // verbatim replay in the next round: valid tag, stale nonce
        assert_eq!(
            auth.verify_submission(&round0, &lookup, 1, 2),
            Some(FastCheck::ReplayedPayload)
        );
        // alice herself is unharmed: her fresh round-1 envelope passes
        let round1 = sealed("alice", &key, 1, 2);
        assert_eq!(auth.verify_submission(&round1, &lookup, 1, 2), None);
        assert_eq!(auth.stats.replayed, 1);
    }

    #[test]
    fn sybil_swarm_sharing_one_key_gets_one_submission_per_round() {
        let shared = SigningKey::derive(SEED, "sybil-shared");
        // three hotkeys, all registered with the SAME verifying key —
        // registration is permissionless, the window is not
        let reg: HashMap<String, VerifyingKey> = ["s0", "s1", "s2"]
            .iter()
            .map(|h| (h.to_string(), shared.verifying()))
            .collect();
        let lookup = |h: &str| reg.get(h).copied();
        let mut auth = AuthVerifier::new();
        for round in 0..2u64 {
            let verdicts: Vec<_> = ["s0", "s1", "s2"]
                .iter()
                .map(|h| auth.verify_submission(&sealed(h, &shared, round, 1), &lookup, round, 1))
                .collect();
            assert_eq!(verdicts[0], None, "first swarm member passes");
            assert_eq!(verdicts[1], Some(FastCheck::ReplayedPayload));
            assert_eq!(verdicts[2], Some(FastCheck::ReplayedPayload));
        }
        assert_eq!(auth.stats, AuthStats { verified: 2, bad_signature: 0, replayed: 4 });
    }

    #[test]
    fn recycled_uid_with_fresh_hotkey_gets_fresh_window() {
        // "bob" departs after advancing his window to nonce 5; "dave"
        // joins on bob's recycled UID with a fresh hotkey. Dave's key id
        // differs, so his window starts empty — nonce 5 is fine for him.
        let mut reg = registry(&["bob"]);
        let mut auth = AuthVerifier::new();
        {
            let lookup = |h: &str| reg.get(h).copied();
            let bob = SigningKey::derive(SEED, "bob");
            assert_eq!(auth.verify_submission(&sealed("bob", &bob, 5, 1), &lookup, 5, 1), None);
        }
        reg.remove("bob"); // dereg: bob's key leaves the registry
        reg.insert("dave".into(), SigningKey::derive(SEED, "dave").verifying());
        let lookup = |h: &str| reg.get(h).copied();
        let dave = SigningKey::derive(SEED, "dave");
        assert_eq!(auth.verify_submission(&sealed("dave", &dave, 5, 1), &lookup, 5, 1), None);
        // and bob's stale bytes no longer authenticate at all
        let bob = SigningKey::derive(SEED, "bob");
        assert_eq!(
            auth.verify_submission(&sealed("bob", &bob, 6, 1), &lookup, 6, 1),
            Some(FastCheck::BadSignature)
        );
    }

    #[test]
    fn cross_slice_inconsistency_rejected() {
        let reg = registry(&["alice"]);
        let lookup = |h: &str| reg.get(h).copied();
        let key = SigningKey::derive(SEED, "alice");
        let mut auth = AuthVerifier::new();
        // wrong slice count
        let s = sealed("alice", &key, 0, 2);
        assert_eq!(auth.verify_submission(&s[..1], &lookup, 0, 2), Some(FastCheck::BadSignature));
        // slice in the wrong position (shard field mismatch)
        let swapped = vec![s[1].clone(), s[0].clone()];
        assert_eq!(auth.verify_submission(&swapped, &lookup, 0, 2), Some(FastCheck::BadSignature));
        // mixed nonces across the slice set
        let mixed = vec![
            envelope::seal(&[0; 32], "alice", 0, 0, 0, &key),
            envelope::seal(&[1; 32], "alice", 0, 1, 9, &key),
        ];
        assert_eq!(auth.verify_submission(&mixed, &lookup, 0, 2), Some(FastCheck::BadSignature));
    }

    #[test]
    fn wrong_round_is_a_replay_not_a_forgery() {
        let reg = registry(&["alice"]);
        let lookup = |h: &str| reg.get(h).copied();
        let key = SigningKey::derive(SEED, "alice");
        let mut auth = AuthVerifier::new();
        // validly signed for round 3, presented in round 2
        let s = sealed("alice", &key, 3, 1);
        assert_eq!(auth.verify_submission(&s, &lookup, 2, 1), Some(FastCheck::ReplayedPayload));
        // the rejection did NOT advance the window: round 3 still works
        assert_eq!(auth.verify_submission(&s, &lookup, 3, 1), None);
    }
}
