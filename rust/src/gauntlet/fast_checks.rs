//! Fast checks (paper §2.2): cheap per-submission validation the
//! validator runs on *every* peer every round, without forward passes —
//! payload authentication (signature + replay freshness, performed
//! upstream before any decode and fed in as pre-verdicts), liveness,
//! synchronization with the main model, payload geometry and norm sanity.

use crate::gauntlet::Submission;
use crate::util::stats::median;

/// Result of the fast-check battery for one submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FastCheck {
    Pass,
    /// Envelope failed authentication before decode: unparseable or
    /// inconsistent envelope slices, unregistered hotkey, or a tag that
    /// does not verify against the hotkey's registered key (forgery).
    BadSignature,
    /// Envelope authenticated but is not fresh: its nonce is inside the
    /// signer key's replay window, or it was signed for a different
    /// round — a verbatim replay of someone's (or one's own) old bytes.
    ReplayedPayload,
    /// Upload abandoned: the peer's link kept flapping and its bounded
    /// retry budget ran out, so the submission never fully landed
    /// (sibling slices that did land are *orphaned* in the object
    /// store). Delivered as a pre-verdict by the round engine — there is
    /// nothing complete to authenticate or decode.
    OrphanedUpload,
    /// Upload arrived after the round deadline.
    Late,
    /// Upload stalled mid-transfer and was cut off by the deadline event —
    /// it never completed (arrival time is +inf). Distinct from `Late`
    /// (which did land, just too late) for observability; both disqualify.
    LateUpload,
    /// Trained from a stale global model (base_round mismatch).
    OutOfSync,
    /// Malformed payload (geometry / NaN scales / out-of-range).
    Malformed,
    /// Update norm wildly out of family (> max_ratio * median norm).
    AbnormalNorm,
    /// Empty update (all-zero scales — free-rider).
    Empty,
    /// Byte-identical to another submission (this round or the previous
    /// one) — copying/duplicate behaviour (§2.2).
    Duplicate,
}

/// The order checks fire in: the first failing check in this list is the
/// submission's verdict. Authentication outranks everything (a forged
/// submission is never decoded, so nothing downstream of it is even
/// defined), an abandoned upload outranks duplicates (its bytes never
/// fully landed, so there is nothing to compare), duplicates outrank
/// liveness (a copied payload is damning regardless of when it arrived),
/// and the norm checks come last because they depend on the round's norm
/// population.
pub const PRECEDENCE: [FastCheck; 10] = [
    FastCheck::BadSignature,
    FastCheck::ReplayedPayload,
    FastCheck::OrphanedUpload,
    FastCheck::Duplicate,
    FastCheck::LateUpload,
    FastCheck::Late,
    FastCheck::OutOfSync,
    FastCheck::Malformed,
    FastCheck::Empty,
    FastCheck::AbnormalNorm,
];

impl FastCheck {
    pub fn passed(&self) -> bool {
        matches!(self, FastCheck::Pass)
    }

    /// Contribution of the fast battery to the final score.
    pub fn score(&self) -> f64 {
        match self {
            FastCheck::Pass => 1.0,
            // failures disqualify rather than merely down-weight
            _ => -1.0,
        }
    }
}

/// Parameters of the battery.
#[derive(Debug, Clone, Copy)]
pub struct FastCheckParams {
    pub round: usize,
    pub deadline: f64,
    pub expect_chunks: usize,
    pub expect_k: usize,
    pub expect_chunk: usize,
    /// Norm may exceed the round median by at most this factor.
    pub max_norm_ratio: f64,
}

/// Run the battery on every submission of a round. `prev_hashes` are the
/// payload content hashes from the previous round (copier detection).
/// Returns one verdict per submission, in order.
pub fn run_fast_checks(
    subs: &[Submission],
    p: &FastCheckParams,
    prev_hashes: &std::collections::HashSet<u64>,
) -> Vec<FastCheck> {
    run_fast_checks_pre(subs, p, prev_hashes, &[])
}

/// [`run_fast_checks`] with authentication pre-verdicts: `pre[i]`, when
/// `Some`, is the verdict the payload-auth layer reached for submission
/// `i` *before decode* ([`FastCheck::BadSignature`] or
/// [`FastCheck::ReplayedPayload`]) and pre-empts every other check. A
/// pre-failed submission's payload is treated as never decoded: it is
/// excluded from duplicate-hash seeding and from the norm-median
/// population, so an attacker cannot use rejected bytes to frame an
/// honest original as a duplicate or to shift the norm family. `pre` may
/// be shorter than `subs` (missing entries mean "no pre-verdict").
pub fn run_fast_checks_pre(
    subs: &[Submission],
    p: &FastCheckParams,
    prev_hashes: &std::collections::HashSet<u64>,
    pre: &[Option<FastCheck>],
) -> Vec<FastCheck> {
    let pre_at = |i: usize| pre.get(i).copied().flatten();
    // Within-round duplicates: every submission after the first holder of
    // a hash is flagged (the first might be the original).
    let mut seen = std::collections::HashMap::new();
    let mut dup = vec![false; subs.len()];
    for (i, s) in subs.iter().enumerate() {
        if pre_at(i).is_some() {
            continue; // rejected before decode: its hash does not exist
        }
        let h = s.payload.content_hash();
        if prev_hashes.contains(&h) {
            dup[i] = true;
        } else if seen.contains_key(&h) {
            dup[i] = true;
        } else {
            seen.insert(h, i);
        }
    }
    // Median norm across structurally-valid, authenticated submissions
    // (for the ratio check).
    let norms: Vec<f64> = subs
        .iter()
        .enumerate()
        .filter(|(i, s)| {
            pre_at(*i).is_none()
                && s.payload
                    .validate(p.expect_chunks, p.expect_k, p.expect_chunk)
                    .is_ok()
        })
        .map(|(_, s)| s.payload.l2_norm())
        .filter(|n| *n > 0.0)
        .collect();
    let med = if norms.is_empty() { 0.0 } else { median(&norms) };
    subs.iter()
        .enumerate()
        .map(|(i, s)| {
            if let Some(v) = pre_at(i) {
                return v;
            }
            if dup[i] {
                return FastCheck::Duplicate;
            }
            if s.uploaded_at.is_infinite() {
                return FastCheck::LateUpload;
            }
            if s.uploaded_at > p.deadline {
                return FastCheck::Late;
            }
            if s.base_round != p.round {
                return FastCheck::OutOfSync;
            }
            if s
                .payload
                .validate(p.expect_chunks, p.expect_k, p.expect_chunk)
                .is_err()
            {
                return FastCheck::Malformed;
            }
            let n = s.payload.l2_norm();
            if n == 0.0 {
                return FastCheck::Empty;
            }
            if med > 0.0 && n > p.max_norm_ratio * med {
                return FastCheck::AbnormalNorm;
            }
            FastCheck::Pass
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparseloco::topk::compress_dense;
    use crate::util::rng::Rng;

    fn sub(hot: &str, uid: usize, scale_mult: f32, base_round: usize, at: f64) -> Submission {
        let mut rng = Rng::new(uid as u64 + 1);
        let dense: Vec<f32> = (0..4 * 64).map(|_| rng.normal() as f32 * scale_mult).collect();
        let payload = compress_dense(&dense, 64, 8);
        Submission {
            hotkey: hot.into(),
            uid,
            round: 5,
            base_round,
            wire_bytes: 100,
            uploaded_at: at,
            payload,
        }
    }

    fn params() -> FastCheckParams {
        FastCheckParams {
            round: 5,
            deadline: 100.0,
            expect_chunks: 4,
            expect_k: 8,
            expect_chunk: 64,
            max_norm_ratio: 10.0,
        }
    }

    #[test]
    fn all_good_pass() {
        let subs: Vec<_> = (0..5).map(|i| sub(&format!("p{i}"), i, 0.01, 5, 50.0)).collect();
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert!(checks.iter().all(|c| c.passed()));
    }

    #[test]
    fn late_flagged() {
        let subs = vec![sub("a", 0, 0.01, 5, 150.0), sub("b", 1, 0.01, 5, 50.0)];
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::Late);
        assert!(checks[1].passed());
    }

    #[test]
    fn stalled_upload_flagged_as_late_upload() {
        // A stalled connection cut by the deadline event reports an
        // infinite arrival time -> LateUpload, not Late.
        let subs = vec![sub("a", 0, 0.01, 5, f64::INFINITY), sub("b", 1, 0.01, 5, 50.0)];
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::LateUpload);
        assert!(!checks[0].passed());
        assert!(checks[0].score() < 0.0, "LateUpload must disqualify");
        assert!(checks[1].passed());
    }

    #[test]
    fn stale_flagged() {
        let subs = vec![sub("a", 0, 0.01, 4, 50.0)];
        assert_eq!(run_fast_checks(&subs, &params(), &Default::default())[0], FastCheck::OutOfSync);
    }

    #[test]
    fn abnormal_norm_flagged() {
        let mut subs: Vec<_> = (0..6).map(|i| sub(&format!("p{i}"), i, 0.01, 5, 50.0)).collect();
        subs.push(sub("whale", 9, 50.0, 5, 50.0)); // ~5000x median
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(*checks.last().unwrap(), FastCheck::AbnormalNorm);
        assert!(checks[..6].iter().all(|c| c.passed()));
    }

    #[test]
    fn empty_flagged() {
        let mut s = sub("z", 0, 0.01, 5, 50.0);
        s.payload.scales.iter_mut().for_each(|x| *x = 0.0);
        let subs = vec![s, sub("a", 1, 0.01, 5, 50.0)];
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::Empty);
    }

    #[test]
    fn malformed_flagged() {
        let mut s = sub("m", 0, 0.01, 5, 50.0);
        s.payload.scales[0] = f32::INFINITY;
        let checks = run_fast_checks(&[s], &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::Malformed);
    }

    #[test]
    fn duplicate_within_round_flagged() {
        let a = sub("orig", 0, 0.01, 5, 50.0);
        let mut b = sub("copycat", 1, 0.02, 5, 50.0);
        b.payload = a.payload.clone();
        let checks = run_fast_checks(&[a, b], &params(), &Default::default());
        assert!(checks[0].passed(), "original must pass");
        assert_eq!(checks[1], FastCheck::Duplicate);
    }

    #[test]
    fn duplicate_of_previous_round_flagged() {
        let a = sub("orig", 0, 0.01, 5, 50.0);
        let prev: std::collections::HashSet<u64> =
            [a.payload.content_hash()].into_iter().collect();
        let checks = run_fast_checks(&[a], &params(), &prev);
        assert_eq!(checks[0], FastCheck::Duplicate);
    }

    #[test]
    fn scores() {
        assert_eq!(FastCheck::Pass.score(), 1.0);
        assert!(FastCheck::Late.score() < 0.0);
    }

    // ---- pre-verdicts (payload authentication) --------------------------

    #[test]
    fn pre_verdicts_pass_through_verbatim() {
        let subs = vec![sub("forger", 0, 0.01, 5, 50.0), sub("replayer", 1, 0.01, 5, 50.0)];
        let pre = vec![Some(FastCheck::BadSignature), Some(FastCheck::ReplayedPayload)];
        let checks = run_fast_checks_pre(&subs, &params(), &Default::default(), &pre);
        assert_eq!(checks, vec![FastCheck::BadSignature, FastCheck::ReplayedPayload]);
    }

    #[test]
    fn short_pre_slice_means_no_verdict_for_the_tail() {
        let subs = vec![sub("forger", 0, 0.01, 5, 50.0), sub("honest", 1, 0.01, 5, 50.0)];
        let pre = vec![Some(FastCheck::BadSignature)];
        let checks = run_fast_checks_pre(&subs, &params(), &Default::default(), &pre);
        assert_eq!(checks[0], FastCheck::BadSignature);
        assert!(checks[1].passed());
    }

    #[test]
    fn pre_failed_bytes_cannot_frame_the_honest_original_as_duplicate() {
        // A forger uploads a byte-identical copy of alice's payload but
        // fails authentication; because rejected bytes are never decoded,
        // alice — listed AFTER the forger — must still pass.
        let alice = sub("alice", 1, 0.01, 5, 50.0);
        let mut forger = sub("forger", 0, 0.02, 5, 50.0);
        forger.payload = alice.payload.clone();
        let pre = vec![Some(FastCheck::BadSignature), None];
        let checks =
            run_fast_checks_pre(&[forger, alice], &params(), &Default::default(), &pre);
        assert_eq!(checks[0], FastCheck::BadSignature);
        assert!(checks[1].passed(), "honest original framed as duplicate");
    }

    #[test]
    fn pre_failed_bytes_are_excluded_from_the_norm_median() {
        // Five rejected whales and two honest peers: if the rejected
        // payloads entered the median, the honest pair would be flagged
        // AbnormalNorm-relative-to-whales (or the whales would define the
        // family). With auth exclusion the honest pair simply passes.
        let mut subs: Vec<_> =
            (0..5).map(|i| sub(&format!("w{i}"), i, 50.0, 5, 50.0)).collect();
        subs.push(sub("a", 7, 0.01, 5, 50.0));
        subs.push(sub("b", 8, 0.01, 5, 50.0));
        let pre: Vec<_> = (0..5)
            .map(|_| Some(FastCheck::BadSignature))
            .chain([None, None])
            .collect();
        let checks = run_fast_checks_pre(&subs, &params(), &Default::default(), &pre);
        assert!(checks[5].passed() && checks[6].passed(), "{checks:?}");
    }

    // ---- verdict precedence (every variant, pinned order) ---------------

    /// Build a submission that would trip *all* post-auth checks at once:
    /// duplicate of the previous round, stalled upload, stale base round,
    /// malformed payload. Stripping failures one precedence rank at a
    /// time must surface exactly the next verdict in [`PRECEDENCE`].
    #[test]
    fn precedence_table_fires_highest_rank_first() {
        let p = params();
        let honest = sub("honest", 3, 0.01, 5, 50.0);
        let make_worst = || {
            let mut s = sub("worst", 0, 0.01, 4, f64::INFINITY);
            s.payload.scales[0] = f32::NAN;
            s
        };
        let prev: std::collections::HashSet<u64> =
            [make_worst().payload.content_hash()].into_iter().collect();

        // rank 0: a pre-verdict (BadSignature) beats everything
        let subs = vec![make_worst(), honest.clone()];
        let pre = vec![Some(FastCheck::BadSignature), None];
        assert_eq!(run_fast_checks_pre(&subs, &p, &prev, &pre)[0], FastCheck::BadSignature);
        // rank 1: ReplayedPayload likewise
        let pre = vec![Some(FastCheck::ReplayedPayload), None];
        assert_eq!(run_fast_checks_pre(&subs, &p, &prev, &pre)[0], FastCheck::ReplayedPayload);
        // rank 2: OrphanedUpload (abandoned after the retry budget) is
        // also a pre-verdict — the bytes never fully landed, so it fires
        // before Duplicate can even look at them
        let pre = vec![Some(FastCheck::OrphanedUpload), None];
        assert_eq!(run_fast_checks_pre(&subs, &p, &prev, &pre)[0], FastCheck::OrphanedUpload);
        // rank 3: authenticated -> Duplicate fires before liveness
        assert_eq!(run_fast_checks(&subs, &p, &prev)[0], FastCheck::Duplicate);
        // rank 4: not a duplicate -> the stalled upload (LateUpload)
        let subs = vec![make_worst(), honest.clone()];
        assert_eq!(run_fast_checks(&subs, &p, &Default::default())[0], FastCheck::LateUpload);
        // rank 5: upload completed, but late
        let mut s = make_worst();
        s.uploaded_at = p.deadline + 1.0;
        assert_eq!(
            run_fast_checks(&[s, honest.clone()], &p, &Default::default())[0],
            FastCheck::Late
        );
        // rank 6: punctual, but out of sync
        let mut s = make_worst();
        s.uploaded_at = 50.0;
        assert_eq!(
            run_fast_checks(&[s, honest.clone()], &p, &Default::default())[0],
            FastCheck::OutOfSync
        );
        // rank 7: synced, but malformed
        let mut s = make_worst();
        s.uploaded_at = 50.0;
        s.base_round = 5;
        assert_eq!(
            run_fast_checks(&[s, honest.clone()], &p, &Default::default())[0],
            FastCheck::Malformed
        );
        // rank 8: well-formed, but empty
        let mut s = sub("worst", 0, 0.01, 5, 50.0);
        s.payload.scales.iter_mut().for_each(|x| *x = 0.0);
        assert_eq!(
            run_fast_checks(&[s, honest.clone()], &p, &Default::default())[0],
            FastCheck::Empty
        );
        // rank 9: non-empty, but out of the norm family
        let s = sub("worst", 0, 50.0, 5, 50.0);
        assert_eq!(
            run_fast_checks(&[s, honest.clone()], &p, &Default::default())[0],
            FastCheck::AbnormalNorm
        );
        // all failures stripped: Pass
        let s = sub("worst", 0, 0.01, 5, 50.0);
        assert!(run_fast_checks(&[s, honest], &p, &Default::default())[0].passed());
    }

    #[test]
    fn precedence_covers_every_failing_variant_exactly_once() {
        // The table is the spec: every non-Pass variant appears exactly
        // once, every entry disqualifies, and Pass is not ranked.
        for v in PRECEDENCE {
            assert!(!v.passed());
            assert!(v.score() < 0.0, "{v:?} must disqualify");
            assert_eq!(PRECEDENCE.iter().filter(|&&x| x == v).count(), 1, "{v:?} listed twice");
        }
        let all = [
            FastCheck::BadSignature,
            FastCheck::ReplayedPayload,
            FastCheck::OrphanedUpload,
            FastCheck::Duplicate,
            FastCheck::LateUpload,
            FastCheck::Late,
            FastCheck::OutOfSync,
            FastCheck::Malformed,
            FastCheck::Empty,
            FastCheck::AbnormalNorm,
        ];
        assert_eq!(PRECEDENCE.len(), all.len());
        for v in all {
            assert!(PRECEDENCE.contains(&v), "{v:?} missing from the precedence table");
        }
    }
}
