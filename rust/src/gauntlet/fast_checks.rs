//! Fast checks (paper §2.2): cheap per-submission validation the
//! validator runs on *every* peer every round, without forward passes —
//! liveness, synchronization with the main model, payload geometry and
//! norm sanity.

use crate::gauntlet::Submission;
use crate::util::stats::median;

/// Result of the fast-check battery for one submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FastCheck {
    Pass,
    /// Upload arrived after the round deadline.
    Late,
    /// Upload stalled mid-transfer and was cut off by the deadline event —
    /// it never completed (arrival time is +inf). Distinct from `Late`
    /// (which did land, just too late) for observability; both disqualify.
    LateUpload,
    /// Trained from a stale global model (base_round mismatch).
    OutOfSync,
    /// Malformed payload (geometry / NaN scales / out-of-range).
    Malformed,
    /// Update norm wildly out of family (> max_ratio * median norm).
    AbnormalNorm,
    /// Empty update (all-zero scales — free-rider).
    Empty,
    /// Byte-identical to another submission (this round or the previous
    /// one) — copying/duplicate behaviour (§2.2).
    Duplicate,
}

impl FastCheck {
    pub fn passed(&self) -> bool {
        matches!(self, FastCheck::Pass)
    }

    /// Contribution of the fast battery to the final score.
    pub fn score(&self) -> f64 {
        match self {
            FastCheck::Pass => 1.0,
            // failures disqualify rather than merely down-weight
            _ => -1.0,
        }
    }
}

/// Parameters of the battery.
#[derive(Debug, Clone, Copy)]
pub struct FastCheckParams {
    pub round: usize,
    pub deadline: f64,
    pub expect_chunks: usize,
    pub expect_k: usize,
    pub expect_chunk: usize,
    /// Norm may exceed the round median by at most this factor.
    pub max_norm_ratio: f64,
}

/// Run the battery on every submission of a round. `prev_hashes` are the
/// payload content hashes from the previous round (copier detection).
/// Returns one verdict per submission, in order.
pub fn run_fast_checks(
    subs: &[Submission],
    p: &FastCheckParams,
    prev_hashes: &std::collections::HashSet<u64>,
) -> Vec<FastCheck> {
    // Within-round duplicates: every submission after the first holder of
    // a hash is flagged (the first might be the original).
    let mut seen = std::collections::HashMap::new();
    let hashes: Vec<u64> = subs.iter().map(|s| s.payload.content_hash()).collect();
    let mut dup = vec![false; subs.len()];
    for (i, &h) in hashes.iter().enumerate() {
        if prev_hashes.contains(&h) {
            dup[i] = true;
        } else if let Some(&first) = seen.get(&h) {
            let _: usize = first;
            dup[i] = true;
        } else {
            seen.insert(h, i);
        }
    }
    run_fast_checks_inner(subs, p, &dup)
}

fn run_fast_checks_inner(
    subs: &[Submission],
    p: &FastCheckParams,
    dup: &[bool],
) -> Vec<FastCheck> {
    // Median norm across structurally-valid submissions (for the ratio check).
    let norms: Vec<f64> = subs
        .iter()
        .filter(|s| {
            s.payload
                .validate(p.expect_chunks, p.expect_k, p.expect_chunk)
                .is_ok()
        })
        .map(|s| s.payload.l2_norm())
        .filter(|n| *n > 0.0)
        .collect();
    let med = if norms.is_empty() { 0.0 } else { median(&norms) };
    subs.iter()
        .zip(dup)
        .map(|(s, &is_dup)| {
            if is_dup {
                return FastCheck::Duplicate;
            }
            if s.uploaded_at.is_infinite() {
                return FastCheck::LateUpload;
            }
            if s.uploaded_at > p.deadline {
                return FastCheck::Late;
            }
            if s.base_round != p.round {
                return FastCheck::OutOfSync;
            }
            if s
                .payload
                .validate(p.expect_chunks, p.expect_k, p.expect_chunk)
                .is_err()
            {
                return FastCheck::Malformed;
            }
            let n = s.payload.l2_norm();
            if n == 0.0 {
                return FastCheck::Empty;
            }
            if med > 0.0 && n > p.max_norm_ratio * med {
                return FastCheck::AbnormalNorm;
            }
            FastCheck::Pass
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparseloco::topk::compress_dense;
    use crate::util::rng::Rng;

    fn sub(hot: &str, uid: usize, scale_mult: f32, base_round: usize, at: f64) -> Submission {
        let mut rng = Rng::new(uid as u64 + 1);
        let dense: Vec<f32> = (0..4 * 64).map(|_| rng.normal() as f32 * scale_mult).collect();
        let payload = compress_dense(&dense, 64, 8);
        Submission {
            hotkey: hot.into(),
            uid,
            round: 5,
            base_round,
            wire_bytes: 100,
            uploaded_at: at,
            payload,
        }
    }

    fn params() -> FastCheckParams {
        FastCheckParams {
            round: 5,
            deadline: 100.0,
            expect_chunks: 4,
            expect_k: 8,
            expect_chunk: 64,
            max_norm_ratio: 10.0,
        }
    }

    #[test]
    fn all_good_pass() {
        let subs: Vec<_> = (0..5).map(|i| sub(&format!("p{i}"), i, 0.01, 5, 50.0)).collect();
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert!(checks.iter().all(|c| c.passed()));
    }

    #[test]
    fn late_flagged() {
        let subs = vec![sub("a", 0, 0.01, 5, 150.0), sub("b", 1, 0.01, 5, 50.0)];
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::Late);
        assert!(checks[1].passed());
    }

    #[test]
    fn stalled_upload_flagged_as_late_upload() {
        // A stalled connection cut by the deadline event reports an
        // infinite arrival time -> LateUpload, not Late.
        let subs = vec![sub("a", 0, 0.01, 5, f64::INFINITY), sub("b", 1, 0.01, 5, 50.0)];
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::LateUpload);
        assert!(!checks[0].passed());
        assert!(checks[0].score() < 0.0, "LateUpload must disqualify");
        assert!(checks[1].passed());
    }

    #[test]
    fn stale_flagged() {
        let subs = vec![sub("a", 0, 0.01, 4, 50.0)];
        assert_eq!(run_fast_checks(&subs, &params(), &Default::default())[0], FastCheck::OutOfSync);
    }

    #[test]
    fn abnormal_norm_flagged() {
        let mut subs: Vec<_> = (0..6).map(|i| sub(&format!("p{i}"), i, 0.01, 5, 50.0)).collect();
        subs.push(sub("whale", 9, 50.0, 5, 50.0)); // ~5000x median
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(*checks.last().unwrap(), FastCheck::AbnormalNorm);
        assert!(checks[..6].iter().all(|c| c.passed()));
    }

    #[test]
    fn empty_flagged() {
        let mut s = sub("z", 0, 0.01, 5, 50.0);
        s.payload.scales.iter_mut().for_each(|x| *x = 0.0);
        let subs = vec![s, sub("a", 1, 0.01, 5, 50.0)];
        let checks = run_fast_checks(&subs, &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::Empty);
    }

    #[test]
    fn malformed_flagged() {
        let mut s = sub("m", 0, 0.01, 5, 50.0);
        s.payload.scales[0] = f32::INFINITY;
        let checks = run_fast_checks(&[s], &params(), &Default::default());
        assert_eq!(checks[0], FastCheck::Malformed);
    }

    #[test]
    fn duplicate_within_round_flagged() {
        let a = sub("orig", 0, 0.01, 5, 50.0);
        let mut b = sub("copycat", 1, 0.02, 5, 50.0);
        b.payload = a.payload.clone();
        let checks = run_fast_checks(&[a, b], &params(), &Default::default());
        assert!(checks[0].passed(), "original must pass");
        assert_eq!(checks[1], FastCheck::Duplicate);
    }

    #[test]
    fn duplicate_of_previous_round_flagged() {
        let a = sub("orig", 0, 0.01, 5, 50.0);
        let prev: std::collections::HashSet<u64> =
            [a.payload.content_hash()].into_iter().collect();
        let checks = run_fast_checks(&[a], &params(), &prev);
        assert_eq!(checks[0], FastCheck::Duplicate);
    }

    #[test]
    fn scores() {
        assert_eq!(FastCheck::Pass.score(), 1.0);
        assert!(FastCheck::Late.score() < 0.0);
    }
}
