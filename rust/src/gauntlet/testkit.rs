//! Deterministic synthetic fixtures for Gauntlet benches and tests.
//!
//! `benches/hotpath.rs` (score_round serial-vs-fan-out timing) and
//! `tests/gauntlet_churn.rs` (churn/probation/determinism assertions)
//! must drive the validator with the *same* workload, or the bench
//! measures something the tests never validated. Keeping the fixture
//! here — like `util::proptest`, a small always-compiled test substrate
//! — makes that a property of the code rather than of a pair of
//! copy-pasted helpers.

use crate::gauntlet::loss_score::EvalBatch;
use crate::gauntlet::validator::EvalDataProvider;
use crate::gauntlet::Submission;
use crate::runtime::Engine;
use crate::sparseloco::{codec, topk};
use crate::util::rng::Rng;

/// Deterministic full-mask eval batches from a seed.
pub fn eval_batches(seed: u64, b: usize, t: usize, vocab: usize, n: usize) -> Vec<EvalBatch> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let toks: Vec<i32> =
                (0..b * (t + 1)).map(|_| rng.below(vocab) as i32).collect();
            (toks, vec![1f32; b * t])
        })
        .collect()
}

/// Deterministic eval-data provider keyed by uid — recycled UIDs get
/// their predecessor's shards, like the real shard assignment would.
pub struct SyntheticEvalData {
    pub b: usize,
    pub t: usize,
    pub vocab: usize,
}

impl SyntheticEvalData {
    /// Provider shaped for the engine's config.
    pub fn for_engine(eng: &Engine) -> SyntheticEvalData {
        let c = &eng.manifest().config;
        SyntheticEvalData { b: c.batch_size, t: c.seq_len, vocab: c.vocab_size }
    }
}

impl EvalDataProvider for SyntheticEvalData {
    fn assigned_batches(&mut self, uid: usize, n: usize) -> Vec<EvalBatch> {
        eval_batches(0xA551 ^ ((uid as u64) << 8), self.b, self.t, self.vocab, n)
    }

    fn unassigned_batches(&mut self, n: usize) -> Vec<EvalBatch> {
        eval_batches(0xBEEF, self.b, self.t, self.vocab, n)
    }
}

/// Synthetic submission: Top-k compression of a dense N(0, scale)
/// vector, correct geometry for the engine's manifest, uploaded well
/// before any reasonable deadline. Distinct seeds give distinct payload
/// hashes (duplicate fast-check stays quiet); `scale` sets the payload
/// norm — tiny values (~1e-5) test clean under LossScore, large ones
/// trip the abnormal-norm check.
pub fn synthetic_submission(
    eng: &Engine,
    hotkey: &str,
    uid: usize,
    round: usize,
    seed: u64,
    scale: f32,
) -> Submission {
    let man = eng.manifest();
    let mut rng = Rng::new(seed);
    let dense: Vec<f32> = (0..man.n_alloc).map(|_| rng.normal() as f32 * scale).collect();
    let payload = topk::compress_dense(&dense, man.config.chunk, man.config.topk);
    Submission {
        hotkey: hotkey.into(),
        uid,
        round,
        base_round: round,
        wire_bytes: codec::wire_size(payload.n_chunks, payload.k),
        payload,
        uploaded_at: 10.0,
    }
}
