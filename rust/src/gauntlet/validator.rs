//! The Gauntlet validator: fuses fast checks, subset LossScore evaluation
//! and the persistent OpenSkill ranking into a final per-peer score, then
//! selects the round's contributors (paper §2.2) and the weights written
//! to the chain.
//!
//! LossScore evaluations dominate validator wall time and are independent
//! per submission, so `score_round` fans them across the rayon pool
//! (shared with the round engine; see `coordinator::network`) when
//! `GauntletConfig::parallel_eval` is set: eval data is prefetched
//! serially (the provider is `&mut`), the forward passes run in parallel
//! against the `Send + Sync` engine, and results merge back in stable
//! submission order. Each evaluation is a pure deterministic function of
//! its inputs, so the parallel path is bit-identical to the serial one —
//! asserted by the `gauntlet_churn` integration test.

use anyhow::Result;
use rayon::prelude::*;

use crate::config::run::GauntletConfig;
use crate::gauntlet::fast_checks::{run_fast_checks_pre, FastCheck, FastCheckParams};
use crate::gauntlet::loss_score::{loss_score, mean_loss, EvalBatch, LossScoreResult};
use crate::gauntlet::openskill::RatingBook;
use crate::gauntlet::Submission;
use crate::runtime::Engine;
use crate::telemetry::Telemetry;
use crate::util::rng::Rng;

/// Provides evaluation data for LossScore (assigned per peer + shared
/// unassigned) — implemented by the coordinator over the shard store.
pub trait EvalDataProvider {
    /// Batches from the peer's assigned shards for this round.
    fn assigned_batches(&mut self, uid: usize, n: usize) -> Vec<EvalBatch>;
    /// Batches from data assigned to no evaluated peer.
    fn unassigned_batches(&mut self, n: usize) -> Vec<EvalBatch>;
}

/// Verdict for one submission.
#[derive(Debug, Clone)]
pub struct PeerVerdict {
    pub hotkey: String,
    pub uid: usize,
    pub fast: FastCheck,
    pub loss_eval: Option<LossScoreResult>,
    /// Final fused score; selected contributors have the highest scores.
    pub score: f64,
    pub selected: bool,
}

/// Result of scoring one round.
#[derive(Debug, Clone)]
pub struct RoundVerdict {
    pub per_peer: Vec<PeerVerdict>,
    /// Indices (into the submission slice) selected for aggregation.
    pub selected: Vec<usize>,
    /// (uid, weight) pairs for `Subnet::set_weights`.
    pub weights: Vec<(usize, f64)>,
}

/// Persistent validator state.
pub struct Validator {
    pub cfg: GauntletConfig,
    /// Telemetry handle (disabled by default; the network attaches its
    /// own at construction). Pure observation — scoring never reads it.
    pub tele: Telemetry,
    pub book: RatingBook,
    rng: Rng,
    /// Payload hashes from the previous round (duplicate detection).
    prev_hashes: std::collections::HashSet<u64>,
    /// Peers whose most recent LossScore evaluation was harmful/copying:
    /// excluded and force-re-evaluated until they test clean.
    suspended: std::collections::HashSet<String>,
    /// Probation (§2.2 calibration "slightly more active participants
    /// than aggregated contributors"): a peer becomes selectable only
    /// after at least one clean LossScore evaluation, so fresh
    /// adversaries never poison the aggregation on their first rounds.
    proven: std::collections::HashSet<String>,
}

impl Validator {
    pub fn new(cfg: GauntletConfig, seed: u64) -> Self {
        Self {
            cfg,
            tele: Telemetry::default(),
            book: RatingBook::new(),
            rng: Rng::new(seed),
            prev_hashes: Default::default(),
            suspended: Default::default(),
            proven: Default::default(),
        }
    }

    /// Skill signal in (-1, 1): 0 for a fresh peer (mu=25), negative once
    /// the persistent rating falls below the prior (repeatedly ranked last
    /// in LossScore matches), positive for proven contributors.
    fn skill(rating: crate::gauntlet::Rating) -> f64 {
        ((rating.mu - 25.0) / 5.0).tanh()
    }

    /// Score a round of submissions and select contributors.
    #[allow(clippy::too_many_arguments)]
    pub fn score_round(
        &mut self,
        eng: &Engine,
        base_params: &[f32],
        subs: &[Submission],
        round: usize,
        deadline: f64,
        alpha: f32,
        max_contributors: usize,
        data: &mut dyn EvalDataProvider,
    ) -> Result<RoundVerdict> {
        self.score_round_auth(eng, base_params, subs, &[], round, deadline, alpha, max_contributors, data)
    }

    /// [`Validator::score_round`] with payload-authentication
    /// pre-verdicts: `pre[i]`, when `Some`, is the verdict the auth layer
    /// reached for submission `i` before decode (see
    /// `gauntlet::auth::AuthVerifier`). Pre-failed submissions are never
    /// decoded: they pre-empt the fast-check battery, stay out of the
    /// duplicate-hash memory and the norm median, and can never be
    /// evaluated or selected. An empty `pre` is plain `score_round`.
    #[allow(clippy::too_many_arguments)]
    pub fn score_round_auth(
        &mut self,
        eng: &Engine,
        base_params: &[f32],
        subs: &[Submission],
        pre: &[Option<FastCheck>],
        round: usize,
        deadline: f64,
        alpha: f32,
        max_contributors: usize,
        data: &mut dyn EvalDataProvider,
    ) -> Result<RoundVerdict> {
        let _span = self.tele.span("gauntlet.score_round");
        self.tele.count("gauntlet.submissions", subs.len() as u64);
        let man = eng.manifest();
        let fast = run_fast_checks_pre(
            subs,
            &FastCheckParams {
                round,
                deadline,
                expect_chunks: man.n_chunks,
                expect_k: man.config.topk,
                expect_chunk: man.config.chunk,
                max_norm_ratio: self.cfg.max_norm_ratio,
            },
            &self.prev_hashes,
            pre,
        );
        // Duplicate memory for the next round: only authenticated
        // payloads exist as far as the validator is concerned — a
        // rejected forgery's bytes were never decoded, so they must not
        // seed hashes an honest original could later collide with.
        self.prev_hashes = subs
            .iter()
            .enumerate()
            .filter(|(i, _)| pre.get(*i).copied().flatten().is_none())
            .map(|(_, s)| s.payload.content_hash())
            .collect();
        // ---- subset LossScore evaluation --------------------------------
        let passing: Vec<usize> =
            (0..subs.len()).filter(|&i| fast[i].passed()).collect();
        let n_eval = ((passing.len() as f64 * self.cfg.loss_eval_fraction).ceil() as usize)
            .min(passing.len());
        let mut eval_ids = passing.clone();
        self.rng.shuffle(&mut eval_ids);
        eval_ids.truncate(n_eval);
        // Suspended and unproven (probation) peers are always evaluated:
        // both are excluded from selection until they test clean, so they
        // must get the chance to test clean.
        for &i in &passing {
            let hk = &subs[i].hotkey;
            if (self.suspended.contains(hk) || !self.proven.contains(hk))
                && !eval_ids.contains(&i)
            {
                eval_ids.push(i);
            }
        }

        self.tele.count("gauntlet.loss_evals", eval_ids.len() as u64);
        let unassigned = data.unassigned_batches(self.cfg.eval_batches);
        let base_unassigned = mean_loss(eng, base_params, &unassigned)?;
        // Serial prologue: the data provider is `&mut`, so assigned
        // batches are prefetched in eval order before the fan-out (same
        // provider call sequence as the serial path).
        let assigned: Vec<Vec<EvalBatch>> = eval_ids
            .iter()
            .map(|&i| data.assigned_batches(subs[i].uid, self.cfg.eval_batches))
            .collect();
        // Per-submission evaluations are independent and deterministic;
        // fanning them across the pool and merging in eval order is
        // bit-identical to evaluating serially.
        let copy_margin = self.cfg.copy_margin;
        let eval_one =
            |(&i, batches): (&usize, &Vec<EvalBatch>)| -> Result<(usize, LossScoreResult)> {
                let base_assigned = mean_loss(eng, base_params, batches)?;
                let r = loss_score(
                    eng,
                    base_params,
                    &subs[i].payload,
                    alpha,
                    batches,
                    &unassigned,
                    base_assigned,
                    base_unassigned,
                    copy_margin,
                )?;
                Ok((i, r))
            };
        let evals: Vec<(usize, LossScoreResult)> = if self.cfg.parallel_eval {
            eval_ids
                .par_iter()
                .zip(assigned.par_iter())
                .map(eval_one)
                .collect::<Result<_>>()?
        } else {
            eval_ids
                .iter()
                .zip(assigned.iter())
                .map(eval_one)
                .collect::<Result<_>>()?
        };
        let mut loss_evals: Vec<Option<LossScoreResult>> = vec![None; subs.len()];
        for (i, r) in evals {
            loss_evals[i] = Some(r);
        }
        // ---- OpenSkill match over this round's evaluated peers ----------
        let mut ranked: Vec<(usize, f64)> = eval_ids
            .iter()
            .map(|&i| (i, loss_evals[i].unwrap().score()))
            .collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        if ranked.len() >= 2 {
            let match_entries: Vec<(&str, usize)> = ranked
                .iter()
                .enumerate()
                .map(|(rank, (i, _))| (subs[*i].hotkey.as_str(), rank))
                .collect();
            self.book.record_match(&match_entries);
        }
        // ---- update suspensions --------------------------------------------
        for &i in &eval_ids {
            let le = loss_evals[i].unwrap();
            if le.suspected_copy || le.assigned_improvement < -5e-3 {
                self.suspended.insert(subs[i].hotkey.clone());
            } else {
                self.suspended.remove(&subs[i].hotkey);
                self.proven.insert(subs[i].hotkey.clone());
            }
        }
        // ---- fuse scores -------------------------------------------------
        let mut per_peer: Vec<PeerVerdict> = subs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                // The rating ORDERS healthy peers under the contributor
                // cap (mapped into (0,1)); it never disqualifies by
                // itself. Negative scores are reserved for misbehaviour:
                // fast-check failures, copy suspicion, harmful updates,
                // and unresolved suspensions.
                let skill01 = (Self::skill(self.book.get(&s.hotkey)) + 1.0) / 2.0;
                let score = if !fast[i].passed() {
                    fast[i].score() // disqualifying negative
                } else if let Some(le) = loss_evals[i] {
                    if le.suspected_copy {
                        -1.0
                    } else if le.assigned_improvement < -5e-3 {
                        // Clearly harmful contribution (the paper's
                        // LossScore is the primary signal); near-zero
                        // improvements fall through — eval noise must not
                        // disqualify honest peers.
                        le.assigned_improvement
                    } else {
                        0.05 + self.cfg.fast_weight * fast[i].score()
                            + self.cfg.skill_weight * skill01
                            + le.assigned_improvement.clamp(0.0, 1.0)
                    }
                } else if self.suspended.contains(&s.hotkey) {
                    -0.5 // excluded until re-evaluated clean
                } else {
                    0.05 + self.cfg.fast_weight * fast[i].score()
                        + self.cfg.skill_weight * skill01
                };
                PeerVerdict {
                    hotkey: s.hotkey.clone(),
                    uid: s.uid,
                    fast: fast[i],
                    loss_eval: loss_evals[i],
                    score,
                    selected: false,
                }
            })
            .collect();
        // ---- contributor selection (cap, positives only) -----------------
        let mut order: Vec<usize> = (0..subs.len()).collect();
        order.sort_by(|&a, &b| per_peer[b].score.partial_cmp(&per_peer[a].score).unwrap());
        let selected: Vec<usize> = order
            .into_iter()
            .filter(|&i| per_peer[i].score > 0.0)
            .filter(|&i| self.proven.contains(&subs[i].hotkey))
            .take(max_contributors)
            .collect();
        for &i in &selected {
            per_peer[i].selected = true;
        }
        self.tele.count("gauntlet.selected", selected.len() as u64);
        if self.tele.enabled() {
            // Per-verdict tally — the format! is behind the enabled gate
            // so disabled runs never allocate here.
            for v in &per_peer {
                self.tele.count(&format!("gauntlet.verdict.{:?}", v.fast), 1);
            }
        }
        // ---- chain weights ------------------------------------------------
        let total: f64 = selected.iter().map(|&i| per_peer[i].score).sum();
        let weights: Vec<(usize, f64)> = selected
            .iter()
            .map(|&i| (subs[i].uid, per_peer[i].score / total.max(1e-9)))
            .collect();
        Ok(RoundVerdict { per_peer, selected, weights })
    }
}
