//! Gauntlet (paper §2.2): the permissionless validation + incentive
//! mechanism. A validator scores submitted pseudo-gradients with
//! * **LossScore** — loss improvement from applying each contribution,
//!   measured on the peer's *assigned* vs *unassigned* data (anti-copy),
//! * **fast checks** — liveness, geometry/sync, norm sanity on every
//!   submission,
//! * a persistent **OpenSkill** (Plackett–Luce) rating that stabilizes
//!   round-to-round randomness,
//! then selects the round's contributors (cap R=20) and writes weights to
//! the chain for emissions.

pub mod auth;
pub mod fast_checks;
pub mod loss_score;
pub mod openskill;
pub mod testkit;
pub mod validator;

use crate::sparseloco::Payload;

/// One peer's per-round submission (what lands in its R2 bucket).
#[derive(Debug, Clone)]
pub struct Submission {
    pub hotkey: String,
    pub uid: usize,
    /// Round this submission is for.
    pub round: usize,
    /// Round of the global model the peer trained from (sync check).
    pub base_round: usize,
    pub payload: Payload,
    /// Wire size actually uploaded (bytes).
    pub wire_bytes: usize,
    /// Virtual time the upload completed (liveness check).
    pub uploaded_at: f64,
}

pub use openskill::{Rating, RatingBook};
pub use validator::{RoundVerdict, Validator};
