//! Model presets — must stay in lock-step with `python/compile/configs.py`
//! (the integration tests cross-check layouts against `manifest.json`).

use anyhow::{bail, Result};

use crate::runtime::manifest::ModelConfig;

fn base(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.to_string(),
        vocab_size: 0,
        d_model: 0,
        n_layers: 0,
        n_heads: 0,
        n_kv_heads: 0,
        d_head: 0,
        d_ff: 0,
        seq_len: 0,
        batch_size: 4,
        inner_steps: 10,
        rope_theta: 500_000.0,
        norm_eps: 1e-5,
        init_std: 0.02,
        adam_b1: 0.9,
        adam_b2: 0.95,
        adam_eps: 1e-8,
        weight_decay: 0.1,
        ef_beta: 0.95,
        topk: 64,
        chunk: 4096,
        untie_embeddings: false,
    }
}

/// Look up a preset by name.
pub fn get(name: &str) -> Result<ModelConfig> {
    let mut c = base(name);
    match name {
        "tiny" => {
            c.vocab_size = 512;
            c.d_model = 128;
            c.n_layers = 2;
            c.n_heads = 4;
            c.n_kv_heads = 2;
            c.d_head = 32;
            c.d_ff = 320;
            c.seq_len = 32;
            c.batch_size = 4;
            c.inner_steps = 4;
        }
        "small" => {
            c.vocab_size = 4096;
            c.d_model = 256;
            c.n_layers = 4;
            c.n_heads = 8;
            c.n_kv_heads = 2;
            c.d_head = 32;
            c.d_ff = 704;
            c.seq_len = 128;
        }
        "base" => {
            c.vocab_size = 8192;
            c.d_model = 384;
            c.n_layers = 6;
            c.n_heads = 6;
            c.n_kv_heads = 2;
            c.d_head = 64;
            c.d_ff = 1024;
            c.seq_len = 128;
        }
        "m100" => {
            c.vocab_size = 16384;
            c.d_model = 768;
            c.n_layers = 12;
            c.n_heads = 12;
            c.n_kv_heads = 4;
            c.d_head = 64;
            c.d_ff = 2048;
            c.seq_len = 256;
        }
        // The paper's model (Table 4). Published parameter count
        // 72,747,327,488 matches untied-embedding accounting with
        // d_ff=28672 to within 0.0015% (see EXPERIMENTS.md T4).
        "covenant-72b" => {
            c.vocab_size = 262_208;
            c.d_model = 8192;
            c.n_layers = 80;
            c.n_heads = 64;
            c.n_kv_heads = 8;
            c.d_head = 128;
            c.d_ff = 28_672;
            c.seq_len = 2048;
            c.batch_size = 192;
            c.inner_steps = 30;
            c.untie_embeddings = true;
        }
        other => bail!("unknown preset '{other}' (tiny|small|base|m100|covenant-72b)"),
    }
    Ok(c)
}

/// All preset names.
pub fn names() -> &'static [&'static str] {
    &["tiny", "small", "base", "m100", "covenant-72b"]
}
