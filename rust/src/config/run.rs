//! Run / network / gauntlet configuration for the launcher.
//!
//! Defaults reproduce the paper's operating point (§3, §4.3): R=20
//! contributor cap, H=30 inner steps, 110 Mb/s uplink / 500 Mb/s downlink
//! per peer, 20-minute compute window, slightly more active peers than
//! aggregated contributors (Appendix A).
//!
//! Configs load from JSON files (`--config run.json`) and every field can
//! be overridden from the CLI.

use anyhow::{Context, Result};

use crate::netsim::{FaultConfig, FaultScenario, HeterogeneityConfig, WanConfig};
use crate::runtime::kernels::{self, KernelMode};
use crate::telemetry::TelemetryConfig;
use crate::util::json::Json;

/// Top-level run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact directory (model preset must already be AOT-compiled).
    pub artifacts: String,
    /// Outer rounds to run.
    pub rounds: usize,
    /// Contributor cap per round (paper: 20).
    pub max_contributors: usize,
    /// Target number of registered/active peers (paper: ~24 active mean).
    pub target_active: usize,
    /// Outer learning rate alpha (paper: 1.0, dropped to 0.65 late).
    pub outer_lr: f64,
    /// Error-feedback decay beta.
    pub ef_beta: f64,
    /// RNG seed for the whole run.
    pub seed: u64,
    /// Coordinator shards: the flat parameter vector is split into this
    /// many contiguous chunk-range shards, each owned by a
    /// `coordinator::shard::ShardCoordinator` with its own aggregation
    /// bucket and a cross-shard outer-step barrier. `1` (the default) is
    /// the single-coordinator degenerate case, bit-identical to the
    /// pre-sharding rounds; any value is clamped to the chunk count.
    /// Sharded aggregation is bitwise-identical to unsharded for every
    /// shard count (`tests/shard_parity.rs`). Distinct from the *data*
    /// shard count (`NetworkParams::data_shards`).
    pub n_shards: usize,
    /// Placement of shard coordinators on simulated hosts (host count,
    /// inter-host link shape, announce size). The default — as many
    /// hosts as shards, zero-cost links — makes the placed barrier
    /// bit-identical to the historical free `max()` barrier.
    pub placement: PlacementConfig,
    /// Coordinator-side fault injection (host crashes/stalls, upload
    /// link flaps) plus the detection/retry knobs. Disabled by default;
    /// the `COVENANT_FAULT_SCENARIO` env var can switch a *pristine*
    /// default config to a canned scenario (an explicitly configured
    /// fault setup always wins — see `FaultConfig::with_env`).
    pub faults: FaultConfig,
    /// Per-shard outer-optimizer momentum coefficient. Each shard host
    /// keeps only the momentum slice for its own chunk range (no host
    /// ever holds the full flat optimizer vector) and checkpoints it to
    /// the shard bucket every selection round, so a takeover host can
    /// fetch exactly the dead shard's slice. `0.0` (the default) is the
    /// degenerate plain-delta outer step, bit-identical to the
    /// pre-momentum rounds.
    pub outer_momentum: f64,
    /// Sign per-shard payload slices in `CVEV` envelopes and verify
    /// signature + nonce freshness before any decode (the trust
    /// boundary). `false` falls back to the legacy bare-codec wire
    /// format: old bytes still decode, but nothing is authenticated.
    pub sign_payloads: bool,
    /// Dense-kernel implementation for the whole run
    /// (`"reference" | "blocked" | "simd"`): installed as the
    /// process-global `runtime::kernels` mode at network construction.
    /// `reference`/`blocked` are bit-identical; `simd` keeps the
    /// codec/quant lane bit-identical but lane-accumulates the matmuls
    /// (deterministic across threads/reruns, tolerance-pinned vs
    /// blocked). Defaults to `blocked` unless the `COVENANT_KERNEL_MODE`
    /// env var overrides the process default.
    pub kernel_mode: KernelMode,
    /// Deterministic adversary cohort injected at network construction.
    pub adversary: AdversaryConfig,
    /// Simulated link shape + timing-model knobs.
    pub network: NetworkConfig,
    /// Validator (Gauntlet) knobs.
    pub gauntlet: GauntletConfig,
    /// Telemetry spine (pure observation): typed metric registry,
    /// Perfetto trace export, JSONL run log, deterministic lane
    /// sampling. Disabled by default — default-off runs are
    /// byte-identical to pre-telemetry behavior, and enabling changes
    /// only what is *recorded* (`tests/telemetry_determinism.rs`). The
    /// `COVENANT_TELEMETRY` env var can switch a *pristine* default on
    /// (an explicitly configured block always wins — see
    /// `TelemetryConfig::with_env`).
    pub telemetry: TelemetryConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            artifacts: "artifacts/tiny".into(),
            rounds: 20,
            max_contributors: 20,
            target_active: 24,
            outer_lr: 1.0,
            ef_beta: 0.95,
            seed: 0xC0DE,
            n_shards: 1,
            placement: PlacementConfig::default(),
            faults: FaultConfig::default(),
            outer_momentum: 0.0,
            sign_payloads: true,
            kernel_mode: kernels::default_mode(),
            adversary: AdversaryConfig::default(),
            network: NetworkConfig::default(),
            gauntlet: GauntletConfig::default(),
            telemetry: TelemetryConfig::default(),
        }
    }
}

/// Deterministic adversary cohort for the gauntlet suite: these peers are
/// appended *after* the honest initial peers at network construction (so
/// honest identities, UIDs and RNG streams are unchanged by their
/// presence) and attack the envelope layer every round. All zero by
/// default — production runs see only churn-rolled adversaries.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdversaryConfig {
    /// Sybil swarm size: hotkeys sharing ONE signing key. At most one of
    /// them authenticates per round; the rest are `ReplayedPayload`.
    pub sybils: usize,
    /// Free-riders replaying another peer's previous-round sealed slices
    /// verbatim (`ReplayedPayload` via nonce staleness).
    pub replayers: usize,
    /// Peers signing with a key that does not match their registered
    /// verifying key (`BadSignature`).
    pub forgers: usize,
    /// Peers flooding one target shard with oversized junk slices
    /// (`BadSignature`; junk bytes land in the shard's rejected
    /// accounting).
    pub shard_spammers: usize,
    /// Shard index targeted by `shard_spammers` (clamped to the shard
    /// count at run time).
    pub spam_shard: usize,
    /// Gradient-inflation peers: compute honestly, then blow up their
    /// payload scales 1000x (`AbnormalNorm` via the median-norm check —
    /// the classic `Whale`, injectable deterministically here).
    pub whales: usize,
}

impl AdversaryConfig {
    /// Total injected adversary count.
    pub fn total(&self) -> usize {
        self.sybils + self.replayers + self.forgers + self.shard_spammers + self.whales
    }
}

/// Placement of shard coordinators on simulated hosts.
///
/// Shards are assigned round-robin (`shard s -> host s % n_hosts`);
/// spare hosts (`n_hosts > n_shards`) sit idle until a fail-over
/// reassigns a dead shard's chunk range onto one. The inter-host link
/// carries barrier announcements and takeover state fetches; with the
/// default zero-cost link the placed barrier is bit-identical to the
/// historical free `max()` barrier.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementConfig {
    /// Simulated host count. `0` (the default) means "one host per
    /// shard".
    pub n_hosts: usize,
    /// Inter-host link bandwidth, bits/second. `0.0` (the default)
    /// means infinitely fast (zero transfer time).
    pub interhost_bps: f64,
    /// Inter-host per-message latency floor, seconds.
    pub interhost_latency_s: f64,
    /// Size of a shard-ready barrier announcement, bytes.
    pub announce_bytes: usize,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        Self {
            n_hosts: 0,
            interhost_bps: 0.0,
            interhost_latency_s: 0.0,
            announce_bytes: 256,
        }
    }
}

/// Simulated internet link shape (paper §4.3 bandwidth constraints).
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    /// Per-peer uplink, bits/second (paper: 110 Mb/s).
    pub uplink_bps: f64,
    /// Per-peer downlink, bits/second (paper: 500 Mb/s).
    pub downlink_bps: f64,
    /// Per-transfer latency floor, seconds (object-store RTT).
    pub latency_s: f64,
    /// Nominal compute window per round, seconds (paper: 20 min at 72B).
    /// With heterogeneity enabled this is the *median* tier's duration;
    /// the upload deadline is anchored to it either way.
    pub compute_window_s: f64,
    /// Overlap comm with the next round's compute (paper Fig. 1): the
    /// next round begins once the selected uploads have landed, while
    /// downloads (and straggling uploads) continue in the background;
    /// each peer starts its next compute as soon as its own download
    /// finishes. Off = barrier semantics (the round ends only when every
    /// peer has finished downloading).
    pub overlap: bool,
    /// Per-peer compute heterogeneity (tiers, jitter, stalls); disabled
    /// by default, which makes the timing model degenerate and bit-equal
    /// to the historical barrier timings.
    pub heterogeneity: HeterogeneityConfig,
    /// WAN topology layered over the per-peer links: pure-hash region
    /// assignment, asymmetric per-peer bandwidth spread, an inter-region
    /// latency hop, and optionally one oversubscribed FIFO uplink trunk
    /// per region. Disabled by default — bitwise degenerate (no regions,
    /// base link shapes pass through unchanged, no trunks).
    pub wan: WanConfig,
    /// Store per-peer link state in the struct-of-arrays bank
    /// (`peer::swarm::SwarmLinks`) instead of one `LinkPair` per peer
    /// slot. Timing is bit-identical either way (the bank replicates the
    /// FIFO link arithmetic expression-for-expression, pinned by
    /// `tests/swarm_scale.rs`); the flat layout is the swarm-scale
    /// representation. Off by default.
    pub soa_links: bool,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self {
            uplink_bps: 110e6,
            downlink_bps: 500e6,
            latency_s: 0.2,
            compute_window_s: 20.0 * 60.0,
            overlap: false,
            heterogeneity: HeterogeneityConfig::default(),
            wan: WanConfig::default(),
            soa_links: false,
        }
    }
}

/// Gauntlet validator configuration (paper §2.2).
#[derive(Debug, Clone)]
pub struct GauntletConfig {
    /// Peers evaluated with LossScore per round (subset for efficiency).
    pub loss_eval_fraction: f64,
    /// Batches per LossScore evaluation.
    pub eval_batches: usize,
    /// OpenSkill rating weight in the final score.
    pub skill_weight: f64,
    /// Fast-check weight in the final score.
    pub fast_weight: f64,
    /// Margin by which unassigned-data improvement must not exceed
    /// assigned-data improvement (anti-copying, §2.2).
    pub copy_margin: f64,
    /// Sync-check: max relative L2 distance of claimed base params hash.
    pub max_norm_ratio: f64,
    /// Fan LossScore evaluations across the rayon pool (per-submission
    /// evaluations are independent; verdicts merge in submission order,
    /// so results are bit-identical to the serial path either way).
    pub parallel_eval: bool,
}

impl Default for GauntletConfig {
    fn default() -> Self {
        Self {
            loss_eval_fraction: 0.5,
            eval_batches: 2,
            skill_weight: 0.7,
            fast_weight: 0.3,
            // LossScore on small batches is noisy; a margin keeps honest
            // peers (whose assigned/unassigned differential is small) from
            // being flagged, while blatant duplication is caught by the
            // duplicate-payload fast check.
            copy_margin: 0.05,
            max_norm_ratio: 10.0,
            parallel_eval: true,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file; missing fields keep defaults.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = Self::default();
        if let Some(v) = j.opt("artifacts") {
            c.artifacts = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("rounds") {
            c.rounds = v.as_usize()?;
        }
        if let Some(v) = j.opt("max_contributors") {
            c.max_contributors = v.as_usize()?;
        }
        if let Some(v) = j.opt("target_active") {
            c.target_active = v.as_usize()?;
        }
        if let Some(v) = j.opt("outer_lr") {
            c.outer_lr = v.as_f64()?;
        }
        if let Some(v) = j.opt("ef_beta") {
            c.ef_beta = v.as_f64()?;
        }
        if let Some(v) = j.opt("seed") {
            c.seed = v.as_i64()? as u64;
        }
        if let Some(v) = j.opt("n_shards") {
            c.n_shards = v.as_usize()?;
            anyhow::ensure!(c.n_shards >= 1, "n_shards must be >= 1 (got 0)");
        }
        if let Some(p) = j.opt("placement") {
            if let Some(v) = p.opt("n_hosts") {
                c.placement.n_hosts = v.as_usize()?;
            }
            if let Some(v) = p.opt("interhost_bps") {
                c.placement.interhost_bps = v.as_f64()?;
            }
            if let Some(v) = p.opt("interhost_latency_s") {
                c.placement.interhost_latency_s = v.as_f64()?;
            }
            if let Some(v) = p.opt("announce_bytes") {
                c.placement.announce_bytes = v.as_usize()?;
            }
        }
        if let Some(f) = j.opt("faults") {
            if let Some(v) = f.opt("enabled") {
                c.faults.enabled = v.as_bool()?;
            }
            if let Some(v) = f.opt("p_host_crash") {
                c.faults.p_host_crash = v.as_f64()?;
            }
            if let Some(v) = f.opt("p_host_stall") {
                c.faults.p_host_stall = v.as_f64()?;
            }
            if let Some(v) = f.opt("stall_s") {
                c.faults.stall_s = v.as_f64()?;
            }
            if let Some(v) = f.opt("p_link_flap") {
                c.faults.p_link_flap = v.as_f64()?;
            }
            if let Some(v) = f.opt("max_upload_retries") {
                c.faults.max_upload_retries = v.as_usize()? as u32;
            }
            if let Some(v) = f.opt("retry_backoff_s") {
                c.faults.retry_backoff_s = v.as_f64()?;
            }
            if let Some(v) = f.opt("failover_timeout_s") {
                c.faults.failover_timeout_s = v.as_f64()?;
            }
            if let Some(v) = f.opt("scenario") {
                let s = v.as_str()?;
                c.faults.scenario = match s {
                    "probabilistic" => FaultScenario::Probabilistic,
                    "ci-crashy" => FaultScenario::CiCrashy,
                    _ => anyhow::bail!(
                        "faults.scenario {s:?}: expected \"probabilistic\" or \"ci-crashy\" \
                         (scripted scenarios are test-only)"
                    ),
                };
            }
        }
        if let Some(v) = j.opt("outer_momentum") {
            c.outer_momentum = v.as_f64()?;
            anyhow::ensure!(
                (0.0..1.0).contains(&c.outer_momentum),
                "outer_momentum must be in [0, 1) (got {})",
                c.outer_momentum
            );
        }
        if let Some(v) = j.opt("sign_payloads") {
            c.sign_payloads = v.as_bool()?;
        }
        if let Some(v) = j.opt("kernel_mode") {
            let s = v.as_str()?;
            c.kernel_mode = KernelMode::parse(s).ok_or_else(|| {
                anyhow::anyhow!("kernel_mode {s:?}: expected \"reference\", \"blocked\" or \"simd\"")
            })?;
        }
        if let Some(a) = j.opt("adversary") {
            if let Some(v) = a.opt("sybils") {
                c.adversary.sybils = v.as_usize()?;
            }
            if let Some(v) = a.opt("replayers") {
                c.adversary.replayers = v.as_usize()?;
            }
            if let Some(v) = a.opt("forgers") {
                c.adversary.forgers = v.as_usize()?;
            }
            if let Some(v) = a.opt("shard_spammers") {
                c.adversary.shard_spammers = v.as_usize()?;
            }
            if let Some(v) = a.opt("spam_shard") {
                c.adversary.spam_shard = v.as_usize()?;
            }
            if let Some(v) = a.opt("whales") {
                c.adversary.whales = v.as_usize()?;
            }
        }
        if let Some(n) = j.opt("network") {
            if let Some(v) = n.opt("uplink_bps") {
                c.network.uplink_bps = v.as_f64()?;
            }
            if let Some(v) = n.opt("downlink_bps") {
                c.network.downlink_bps = v.as_f64()?;
            }
            if let Some(v) = n.opt("latency_s") {
                c.network.latency_s = v.as_f64()?;
            }
            if let Some(v) = n.opt("compute_window_s") {
                c.network.compute_window_s = v.as_f64()?;
            }
            if let Some(v) = n.opt("overlap") {
                c.network.overlap = v.as_bool()?;
            }
            if let Some(h) = n.opt("heterogeneity") {
                let het = &mut c.network.heterogeneity;
                if let Some(v) = h.opt("enabled") {
                    het.enabled = v.as_bool()?;
                }
                if let Some(v) = h.opt("fast_frac") {
                    het.fast_frac = v.as_f64()?;
                }
                if let Some(v) = h.opt("straggler_frac") {
                    het.straggler_frac = v.as_f64()?;
                }
                if let Some(v) = h.opt("fast_mult") {
                    het.fast_mult = v.as_f64()?;
                }
                if let Some(v) = h.opt("straggler_mult") {
                    het.straggler_mult = v.as_f64()?;
                }
                if let Some(v) = h.opt("jitter_frac") {
                    het.jitter_frac = v.as_f64()?;
                }
                if let Some(v) = h.opt("p_stall") {
                    het.p_stall = v.as_f64()?;
                }
                if let Some(v) = h.opt("stall_mult") {
                    het.stall_mult = v.as_f64()?;
                }
            }
            if let Some(w) = n.opt("wan") {
                let wan = &mut c.network.wan;
                if let Some(v) = w.opt("enabled") {
                    wan.enabled = v.as_bool()?;
                }
                if let Some(v) = w.opt("n_regions") {
                    wan.n_regions = v.as_usize()?;
                    anyhow::ensure!(wan.n_regions >= 1, "wan.n_regions must be >= 1 (got 0)");
                }
                if let Some(v) = w.opt("inter_region_latency_s") {
                    wan.inter_region_latency_s = v.as_f64()?;
                }
                if let Some(v) = w.opt("uplink_spread") {
                    wan.uplink_spread = v.as_f64()?;
                    anyhow::ensure!(
                        (0.0..1.0).contains(&wan.uplink_spread),
                        "wan.uplink_spread must be in [0, 1) (got {})",
                        wan.uplink_spread
                    );
                }
                if let Some(v) = w.opt("downlink_spread") {
                    wan.downlink_spread = v.as_f64()?;
                    anyhow::ensure!(
                        (0.0..1.0).contains(&wan.downlink_spread),
                        "wan.downlink_spread must be in [0, 1) (got {})",
                        wan.downlink_spread
                    );
                }
                if let Some(v) = w.opt("region_uplink_bps") {
                    wan.region_uplink_bps = v.as_f64()?;
                }
            }
            if let Some(v) = n.opt("soa_links") {
                c.network.soa_links = v.as_bool()?;
            }
        }
        if let Some(g) = j.opt("gauntlet") {
            if let Some(v) = g.opt("loss_eval_fraction") {
                c.gauntlet.loss_eval_fraction = v.as_f64()?;
            }
            if let Some(v) = g.opt("eval_batches") {
                c.gauntlet.eval_batches = v.as_usize()?;
            }
            if let Some(v) = g.opt("skill_weight") {
                c.gauntlet.skill_weight = v.as_f64()?;
            }
            if let Some(v) = g.opt("fast_weight") {
                c.gauntlet.fast_weight = v.as_f64()?;
            }
            if let Some(v) = g.opt("copy_margin") {
                c.gauntlet.copy_margin = v.as_f64()?;
            }
            if let Some(v) = g.opt("max_norm_ratio") {
                c.gauntlet.max_norm_ratio = v.as_f64()?;
            }
            if let Some(v) = g.opt("parallel_eval") {
                c.gauntlet.parallel_eval = v.as_bool()?;
            }
        }
        if let Some(t) = j.opt("telemetry") {
            if let Some(v) = t.opt("enabled") {
                c.telemetry.enabled = v.as_bool()?;
            }
            if let Some(v) = t.opt("sample_lanes") {
                c.telemetry.sample_lanes = v.as_usize()?;
            }
            if let Some(v) = t.opt("trace") {
                c.telemetry.trace = v.as_bool()?;
            }
            if let Some(v) = t.opt("run_log") {
                c.telemetry.run_log = v.as_bool()?;
            }
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_operating_point() {
        let c = RunConfig::default();
        assert_eq!(c.max_contributors, 20);
        assert_eq!(c.network.uplink_bps, 110e6);
        assert_eq!(c.network.downlink_bps, 500e6);
        assert_eq!(c.network.compute_window_s, 1200.0);
        assert!(c.target_active > c.max_contributors);
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(
            r#"{"rounds": 5, "outer_lr": 0.65,
                "network": {"uplink_bps": 1e6},
                "gauntlet": {"eval_batches": 7}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.rounds, 5);
        assert_eq!(c.outer_lr, 0.65);
        assert_eq!(c.network.uplink_bps, 1e6);
        assert_eq!(c.gauntlet.eval_batches, 7);
        // untouched fields keep defaults
        assert_eq!(c.max_contributors, 20);
    }

    #[test]
    fn n_shards_parses_and_defaults_to_single_coordinator() {
        // The degenerate single-coordinator case must stay the default
        // so existing runs keep bit-identical rounds.
        assert_eq!(RunConfig::default().n_shards, 1);
        let j = Json::parse(r#"{"n_shards": 4}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().n_shards, 4);
        let j = Json::parse(r#"{"n_shards": 0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "zero coordinators rejected");
    }

    #[test]
    fn signing_defaults_on_and_adversaries_default_off() {
        let c = RunConfig::default();
        assert!(c.sign_payloads, "payload auth must be on by default");
        assert_eq!(c.adversary, AdversaryConfig::default());
        assert_eq!(c.adversary.total(), 0, "no injected adversaries by default");
    }

    #[test]
    fn json_adversary_and_signing_overrides() {
        let j = Json::parse(
            r#"{"sign_payloads": false,
                "adversary": {"sybils": 3, "replayers": 1, "forgers": 2,
                              "shard_spammers": 1, "spam_shard": 2, "whales": 1}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(!c.sign_payloads);
        assert_eq!(c.adversary.sybils, 3);
        assert_eq!(c.adversary.replayers, 1);
        assert_eq!(c.adversary.forgers, 2);
        assert_eq!(c.adversary.shard_spammers, 1);
        assert_eq!(c.adversary.spam_shard, 2);
        assert_eq!(c.adversary.whales, 1);
        assert_eq!(c.adversary.total(), 8);
    }

    #[test]
    fn kernel_mode_parses_and_rejects_unknown() {
        // Default unless COVENANT_KERNEL_MODE overrides the process
        // default (which these tests don't set).
        assert!(matches!(
            RunConfig::default().kernel_mode,
            KernelMode::Reference | KernelMode::Blocked | KernelMode::Simd
        ));
        let j = Json::parse(r#"{"kernel_mode": "simd"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().kernel_mode, KernelMode::Simd);
        let j = Json::parse(r#"{"kernel_mode": "reference"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().kernel_mode, KernelMode::Reference);
        let j = Json::parse(r#"{"kernel_mode": "avx512"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "unknown kernel_mode rejected");
    }

    #[test]
    fn placement_and_faults_default_degenerate() {
        // Zero-cost placement + faults off must be the default so
        // existing runs keep bit-identical rounds (pinned end-to-end in
        // tests/failover.rs).
        let c = RunConfig::default();
        assert_eq!(c.placement, PlacementConfig::default());
        assert_eq!(c.placement.n_hosts, 0, "0 = one host per shard");
        assert_eq!(c.placement.interhost_bps, 0.0, "0.0 = zero-cost link");
        assert_eq!(c.faults, FaultConfig::default());
        assert!(!c.faults.enabled);
        assert_eq!(c.outer_momentum, 0.0, "plain-delta outer step by default");
    }

    #[test]
    fn json_placement_fault_and_momentum_overrides() {
        let j = Json::parse(
            r#"{"placement": {"n_hosts": 5, "interhost_bps": 1e9,
                              "interhost_latency_s": 0.05, "announce_bytes": 512},
                "faults": {"enabled": true, "p_host_crash": 0.02, "stall_s": 120.0,
                           "p_link_flap": 0.1, "max_upload_retries": 5,
                           "retry_backoff_s": 2.0, "failover_timeout_s": 90.0,
                           "scenario": "ci-crashy"},
                "outer_momentum": 0.9}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.placement.n_hosts, 5);
        assert_eq!(c.placement.interhost_bps, 1e9);
        assert_eq!(c.placement.interhost_latency_s, 0.05);
        assert_eq!(c.placement.announce_bytes, 512);
        assert!(c.faults.enabled);
        assert_eq!(c.faults.p_host_crash, 0.02);
        assert_eq!(c.faults.stall_s, 120.0);
        assert_eq!(c.faults.p_link_flap, 0.1);
        assert_eq!(c.faults.max_upload_retries, 5);
        assert_eq!(c.faults.retry_backoff_s, 2.0);
        assert_eq!(c.faults.failover_timeout_s, 90.0);
        assert_eq!(c.faults.scenario, FaultScenario::CiCrashy);
        assert_eq!(c.outer_momentum, 0.9);
        // untouched fault fields keep defaults
        assert_eq!(c.faults.p_host_stall, 0.0);
    }

    #[test]
    fn bad_fault_scenario_and_momentum_rejected() {
        let j = Json::parse(r#"{"faults": {"scenario": "chaos-monkey"}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "unknown scenario rejected");
        let j = Json::parse(r#"{"outer_momentum": 1.0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "momentum >= 1 rejected");
    }

    #[test]
    fn heterogeneity_defaults_degenerate() {
        // The degenerate timing model (barrier-equivalent) must be the
        // default so existing runs and tests keep bit-identical timings.
        let c = RunConfig::default();
        assert!(!c.network.overlap);
        assert!(!c.network.heterogeneity.enabled);
    }

    #[test]
    fn telemetry_defaults_off_and_degenerate() {
        // Observation-only contract: the default config records nothing
        // and keeps runs byte-identical to pre-telemetry behavior
        // (pinned end-to-end in tests/telemetry_determinism.rs).
        let c = RunConfig::default();
        assert_eq!(c.telemetry, TelemetryConfig::default());
        assert!(!c.telemetry.enabled);
        assert_eq!(c.telemetry.sample_lanes, 0, "0 = keep every lane");
    }

    #[test]
    fn json_telemetry_overrides() {
        let j = Json::parse(
            r#"{"telemetry": {"enabled": true, "sample_lanes": 64,
                              "trace": false, "run_log": true}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.telemetry.enabled);
        assert_eq!(c.telemetry.sample_lanes, 64);
        assert!(!c.telemetry.trace);
        assert!(c.telemetry.run_log);
    }

    #[test]
    fn wan_and_soa_links_default_degenerate() {
        // WAN off + AoS links must be the default so existing runs keep
        // bit-identical rounds (pinned end-to-end in
        // tests/swarm_scale.rs).
        let c = RunConfig::default();
        assert_eq!(c.network.wan, WanConfig::default());
        assert!(!c.network.wan.enabled);
        assert_eq!(c.network.wan.region_uplink_bps, 0.0, "0.0 = no region trunks");
        assert!(!c.network.soa_links);
    }

    #[test]
    fn json_wan_and_soa_links_overrides() {
        let j = Json::parse(
            r#"{"network": {"soa_links": true,
                "wan": {"enabled": true, "n_regions": 8,
                        "inter_region_latency_s": 0.25, "uplink_spread": 0.6,
                        "downlink_spread": 0.1, "region_uplink_bps": 2e9}}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.network.soa_links);
        let w = &c.network.wan;
        assert!(w.enabled);
        assert_eq!(w.n_regions, 8);
        assert_eq!(w.inter_region_latency_s, 0.25);
        assert_eq!(w.uplink_spread, 0.6);
        assert_eq!(w.downlink_spread, 0.1);
        assert_eq!(w.region_uplink_bps, 2e9);
        // untouched network fields keep defaults
        assert_eq!(c.network.uplink_bps, 110e6);
    }

    #[test]
    fn bad_wan_knobs_rejected() {
        let j = Json::parse(r#"{"network": {"wan": {"n_regions": 0}}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "zero regions rejected");
        let j = Json::parse(r#"{"network": {"wan": {"uplink_spread": 1.0}}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "spread >= 1 rejected");
        let j = Json::parse(r#"{"network": {"wan": {"downlink_spread": -0.1}}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "negative spread rejected");
    }

    #[test]
    fn json_heterogeneity_overrides() {
        let j = Json::parse(
            r#"{"network": {"overlap": true,
                "heterogeneity": {"enabled": true, "straggler_frac": 0.4,
                                  "straggler_mult": 1.8, "p_stall": 0.0}}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.network.overlap);
        let h = &c.network.heterogeneity;
        assert!(h.enabled);
        assert_eq!(h.straggler_frac, 0.4);
        assert_eq!(h.straggler_mult, 1.8);
        assert_eq!(h.p_stall, 0.0);
        // untouched heterogeneity fields keep defaults
        assert_eq!(h.fast_frac, 0.25);
    }
}
