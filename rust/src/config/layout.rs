//! Flat parameter layout (Rust mirror of `python/compile/configs.py`).
//!
//! Every tensor's allocation is padded to a multiple of the SparseLoCo
//! chunk (4096), 2-D tensors stored 64x64-block-major, so chunk-wise
//! compression is a plain reshape of the flat vector. Used for parameter
//! counting (Table 4), payload sizing (Fig. 3 at 72B scale) and the
//! offload manager's memory accounting (Fig. 1).

use crate::runtime::manifest::{ModelConfig, TensorSlot};

pub const BLOCK: usize = 64;

/// The flat layout: ordered tensor slots + totals.
#[derive(Debug, Clone)]
pub struct Layout {
    pub slots: Vec<TensorSlot>,
    pub n_params: usize,
    pub n_alloc: usize,
    pub chunk: usize,
}

impl Layout {
    pub fn build(cfg: &ModelConfig) -> Layout {
        let chunk = cfg.chunk;
        let mut slots = Vec::new();
        let mut off = 0usize;
        let mut n_params = 0usize;
        let push = |name: String, shape: Vec<usize>, is_2d: bool, off: &mut usize, n_params: &mut usize, slots: &mut Vec<TensorSlot>| {
            let size: usize = shape.iter().product();
            let slot = size.div_ceil(chunk) * chunk;
            slots.push(TensorSlot {
                name,
                shape,
                offset: *off,
                size,
                slot,
                is_2d,
                decay: is_2d,
            });
            *off += slot;
            *n_params += size;
        };
        let q_dim = cfg.n_heads * cfg.d_head;
        let kv_dim = cfg.n_kv_heads * cfg.d_head;
        push("embed".into(), vec![cfg.vocab_size, cfg.d_model], true, &mut off, &mut n_params, &mut slots);
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            push(format!("{p}attn_norm"), vec![cfg.d_model], false, &mut off, &mut n_params, &mut slots);
            push(format!("{p}wq"), vec![cfg.d_model, q_dim], true, &mut off, &mut n_params, &mut slots);
            push(format!("{p}wk"), vec![cfg.d_model, kv_dim], true, &mut off, &mut n_params, &mut slots);
            push(format!("{p}wv"), vec![cfg.d_model, kv_dim], true, &mut off, &mut n_params, &mut slots);
            push(format!("{p}wo"), vec![q_dim, cfg.d_model], true, &mut off, &mut n_params, &mut slots);
            push(format!("{p}mlp_norm"), vec![cfg.d_model], false, &mut off, &mut n_params, &mut slots);
            push(format!("{p}w_gate"), vec![cfg.d_model, cfg.d_ff], true, &mut off, &mut n_params, &mut slots);
            push(format!("{p}w_up"), vec![cfg.d_model, cfg.d_ff], true, &mut off, &mut n_params, &mut slots);
            push(format!("{p}w_down"), vec![cfg.d_ff, cfg.d_model], true, &mut off, &mut n_params, &mut slots);
        }
        push("final_norm".into(), vec![cfg.d_model], false, &mut off, &mut n_params, &mut slots);
        if cfg.untie_embeddings {
            push("lm_head".into(), vec![cfg.vocab_size, cfg.d_model], true, &mut off, &mut n_params, &mut slots);
        }
        Layout { slots, n_params, n_alloc: off, chunk }
    }

    pub fn n_chunks(&self) -> usize {
        self.n_alloc / self.chunk
    }

    /// Dense f32 bytes of the full flat state (one of params/m/v/ef).
    pub fn dense_bytes(&self) -> usize {
        self.n_alloc * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn tiny_layout_matches_python() {
        // Values cross-checked against python configs (see also the
        // integration test that reads manifest.json).
        let cfg = presets::get("tiny").unwrap();
        let lay = Layout::build(&cfg);
        assert_eq!(lay.n_params, 410_240);
        assert_eq!(lay.n_alloc, 430_080);
        assert_eq!(lay.n_chunks(), 105);
    }

    #[test]
    fn covenant72b_param_count_matches_table4() {
        // Table 4: 72,747,327,488 parameters. Our accounting (untied
        // embeddings, d_ff=28672) matches to within 0.0015%.
        let cfg = presets::get("covenant-72b").unwrap();
        let lay = Layout::build(&cfg);
        let target = 72_747_327_488u64;
        let got = lay.n_params as u64;
        let rel = (got as f64 - target as f64).abs() / target as f64;
        assert!(rel < 2e-5, "param count {got} vs {target} (rel {rel:.2e})");
    }

    #[test]
    fn chunks_never_straddle_tensors() {
        for name in ["tiny", "small", "base", "m100"] {
            let cfg = presets::get(name).unwrap();
            let lay = Layout::build(&cfg);
            for s in &lay.slots {
                assert_eq!(s.offset % lay.chunk, 0, "{name}/{}", s.name);
                assert_eq!(s.slot % lay.chunk, 0, "{name}/{}", s.name);
                assert!(s.slot >= s.size);
            }
            assert_eq!(lay.n_alloc % lay.chunk, 0);
        }
    }

    #[test]
    fn slots_are_contiguous_and_sorted() {
        let cfg = presets::get("small").unwrap();
        let lay = Layout::build(&cfg);
        let mut expect = 0;
        for s in &lay.slots {
            assert_eq!(s.offset, expect);
            expect += s.slot;
        }
        assert_eq!(expect, lay.n_alloc);
    }
}
