//! Config system: model presets (mirroring `python/compile/configs.py`),
//! the flat parameter layout, and run/network/gauntlet configuration for
//! the launcher.

pub mod layout;
pub mod presets;
pub mod run;

pub use layout::Layout;
pub use run::{GauntletConfig, NetworkConfig, RunConfig};
