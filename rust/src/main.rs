//! `covenant` CLI — leader entrypoint.

use anyhow::Result;
use covenant::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "covenant — permissionless distributed LLM pre-training (SparseLoCo + Gauntlet)

USAGE:
    covenant <COMMAND> [OPTIONS]

COMMANDS:
    smoke      Run every model op of a config end-to-end (--artifacts DIR|PRESET)
    config     Show a model preset and its parameter count (--name NAME)
    help       Show this message
"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.command.as_deref() {
        Some("smoke") => smoke(&args),
        Some("config") => config_show(&args),
        _ => usage(),
    }
}

fn config_show(args: &Args) -> Result<()> {
    use covenant::config::presets;
    let name = args.get_or("name", "covenant-72b");
    let cfg = presets::get(&name)?;
    let lay = covenant::config::layout::Layout::build(&cfg);
    println!("config: {}", cfg.name);
    println!("  layers        {}", cfg.n_layers);
    println!("  d_model       {}", cfg.d_model);
    println!("  query heads   {}", cfg.n_heads);
    println!("  kv heads      {}", cfg.n_kv_heads);
    println!("  d_ff          {}", cfg.d_ff);
    println!("  rope theta    {}", cfg.rope_theta);
    println!("  vocab         {}", cfg.vocab_size);
    println!("  seq len       {}", cfg.seq_len);
    println!("  parameters    {}", lay.n_params);
    println!("  flat alloc    {} ({} chunks)", lay.n_alloc, lay.n_chunks());
    Ok(())
}

fn smoke(args: &Args) -> Result<()> {
    use covenant::runtime::{ops, Engine};
    let dir = args.get_or("artifacts", "artifacts/tiny");
    let eng = Engine::new(&dir)?;
    let m = eng.manifest().clone();
    println!(
        "config={} n_params={} n_alloc={} chunks={}",
        m.config.name, m.n_params, m.n_alloc, m.n_chunks
    );
    // init_params
    let params = ops::init_params(&eng, 0)?;
    println!(
        "init_params ok: {} floats, params[0..4]={:?}",
        params.len(),
        &params[..4]
    );
    // eval_loss on pseudo-random tokens
    let b = m.config.batch_size;
    let t = m.config.seq_len;
    let tokens: Vec<i32> = (0..b * (t + 1))
        .map(|i| ((i as u64).wrapping_mul(2654435761) % m.config.vocab_size as u64) as i32)
        .collect();
    let mask = vec![1f32; b * t];
    let loss = ops::eval_loss(&eng, &params, &tokens, &mask)?;
    println!(
        "eval_loss ok: {} (ln V = {:.3})",
        loss,
        (m.config.vocab_size as f64).ln()
    );
    // compress round-trip
    let na = m.n_alloc;
    let delta: Vec<f32> = (0..na).map(|i| ((i as f32 * 0.618).sin()) * 1e-3).collect();
    let ef = vec![0f32; na];
    let (_ef_new, payload) = ops::compress(&eng, &delta, &ef, 0.95)?;
    println!("compress ok: {} values in {} chunks", payload.n_values(), payload.n_chunks);
    let dense = ops::decompress(&eng, &payload)?;
    let nnz = dense.iter().filter(|x| **x != 0.0).count();
    println!("decompress ok: {} nonzeros of {}", nnz, dense.len());
    // one train_step
    let zeros = vec![0f32; na];
    let (_p, _m2, _v2, step_loss) =
        ops::train_step(&eng, &params, &zeros, &zeros, 1.0, &tokens, &mask, 1e-3, 0.0)?;
    println!("train_step ok: loss={step_loss}");
    for (name, (calls, secs)) in eng.exec_stats() {
        println!("  perf {name}: {calls} calls, {secs:.3}s total");
    }
    println!("smoke OK");
    Ok(())
}
