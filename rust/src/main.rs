//! `covenant` CLI — leader entrypoint.

use anyhow::Result;
use covenant::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "covenant — permissionless distributed LLM pre-training (SparseLoCo + Gauntlet)

USAGE:
    covenant <COMMAND> [OPTIONS]

COMMANDS:
    smoke      Load + run every artifact of a config (--artifacts DIR)
    config     Show a model preset and its parameter count (--name NAME)
    help       Show this message
"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.command.as_deref() {
        Some("smoke") => smoke(&args),
        Some("config") => config_show(&args),
        _ => usage(),
    }
}

fn config_show(args: &Args) -> Result<()> {
    use covenant::config::presets;
    let name = args.get_or("name", "covenant-72b");
    let cfg = presets::get(&name)?;
    let lay = covenant::config::layout::Layout::build(&cfg);
    println!("config: {}", cfg.name);
    println!("  layers        {}", cfg.n_layers);
    println!("  d_model       {}", cfg.d_model);
    println!("  query heads   {}", cfg.n_heads);
    println!("  kv heads      {}", cfg.n_kv_heads);
    println!("  d_ff          {}", cfg.d_ff);
    println!("  rope theta    {}", cfg.rope_theta);
    println!("  vocab         {}", cfg.vocab_size);
    println!("  seq len       {}", cfg.seq_len);
    println!("  parameters    {}", lay.n_params);
    println!("  flat alloc    {} ({} chunks)", lay.n_alloc, lay.n_chunks());
    Ok(())
}

fn smoke(args: &Args) -> Result<()> {
    use covenant::runtime::{literal, Engine};
    let dir = args.get_or("artifacts", "artifacts/tiny");
    let eng = Engine::new(&dir)?;
    let m = eng.manifest().clone();
    println!(
        "config={} n_params={} n_alloc={} chunks={}",
        m.config.name, m.n_params, m.n_alloc, m.n_chunks
    );
    // init_params
    let outs = eng.run("init_params", &[literal::scalar_i32(0)])?;
    let params = literal::to_f32(&outs[0])?;
    println!(
        "init_params ok: {} floats, params[0..4]={:?}",
        params.len(),
        &params[..4]
    );
    // eval_loss on pseudo-random tokens
    let b = m.config.batch_size;
    let t = m.config.seq_len;
    let tokens: Vec<i32> = (0..b * (t + 1))
        .map(|i| ((i as u64).wrapping_mul(2654435761) % m.config.vocab_size as u64) as i32)
        .collect();
    let mask = vec![1f32; b * t];
    let loss = eng.run(
        "eval_loss",
        &[
            outs[0].clone(),
            literal::i32_tensor(&tokens, &[b, t + 1])?,
            literal::f32_tensor(&mask, &[b, t])?,
        ],
    )?;
    println!("eval_loss ok: {} (ln V = {:.3})", literal::to_scalar_f32(&loss[0])?, (m.config.vocab_size as f64).ln());
    // compress round-trip
    let na = m.n_alloc;
    let delta: Vec<f32> = (0..na).map(|i| ((i as f32 * 0.618).sin()) * 1e-3).collect();
    let ef = vec![0f32; na];
    let c = eng.run(
        "compress",
        &[
            literal::f32_vec(&delta),
            literal::f32_vec(&ef),
            literal::scalar_f32(0.95),
        ],
    )?;
    println!("compress ok");
    let d = eng.run("decompress", &[c[1].clone(), c[2].clone(), c[3].clone()])?;
    let dense = literal::to_f32(&d[0])?;
    let nnz = dense.iter().filter(|x| **x != 0.0).count();
    println!("decompress ok: {} nonzeros of {}", nnz, dense.len());
    // one train_step
    let zeros = vec![0f32; na];
    let ts = eng.run(
        "train_step",
        &[
            outs[0].clone(),
            literal::f32_vec(&zeros),
            literal::f32_vec(&zeros),
            literal::scalar_f32(1.0),
            literal::i32_tensor(&tokens, &[b, t + 1])?,
            literal::f32_tensor(&mask, &[b, t])?,
            literal::scalar_f32(1e-3),
            literal::scalar_f32(0.0),
        ],
    )?;
    println!("train_step ok: loss={}", literal::to_scalar_f32(&ts[3])?);
    for (name, (calls, secs)) in eng.exec_stats() {
        println!("  perf {name}: {calls} calls, {:.3}s total", secs);
    }
    println!("smoke OK");
    Ok(())
}
