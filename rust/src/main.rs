//! `covenant` CLI — leader entrypoint.

use anyhow::Result;
use covenant::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "covenant — permissionless distributed LLM pre-training (SparseLoCo + Gauntlet)

USAGE:
    covenant <COMMAND> [OPTIONS]

COMMANDS:
    smoke      Run every model op of a config end-to-end (--artifacts DIR|PRESET)
    config     Show a model preset and its parameter count (--name NAME)
    run        Drive full network rounds and emit run artifacts
               (--rounds N --peers N --seed S --n-shards N --artifacts DIR
                --telemetry [--sample-lanes K] --out-dir DIR)
    help       Show this message
"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.command.as_deref() {
        Some("smoke") => smoke(&args),
        Some("config") => config_show(&args),
        Some("run") => run_rounds(&args),
        _ => usage(),
    }
}

/// Drive `--rounds` full network rounds (churn, Gauntlet, sharded
/// aggregation, outer steps) and write the run artifacts: the per-round
/// CSV + loss sparkline always, plus — with `--telemetry` — the metric
/// registry snapshot, the structured JSONL run log, and a Chrome/Perfetto
/// `trace.json` replay of the round event spine.
fn run_rounds(args: &Args) -> Result<()> {
    use covenant::coordinator::network::{Network, NetworkParams};
    use covenant::runtime::Engine;
    use covenant::{metrics, telemetry};

    let mut run = covenant::config::run::RunConfig::default();
    run.artifacts = args.get_or("artifacts", "artifacts/tiny");
    run.rounds = args.get_usize("rounds", 4)?;
    run.seed = args.get_u64("seed", run.seed)?;
    run.n_shards = args.get_usize("n-shards", run.n_shards)?;
    let peers = args.get_usize("peers", run.target_active)?.max(1);
    run.target_active = peers;
    run.max_contributors = run.max_contributors.min(peers);
    if args.has_flag("telemetry") {
        run.telemetry.enabled = true;
    }
    run.telemetry.sample_lanes = args.get_usize("sample-lanes", run.telemetry.sample_lanes)?;
    let out_dir = std::path::PathBuf::from(args.get_or("out-dir", "target/covenant-run"));

    let eng = Engine::new(&run.artifacts)?;
    let h = eng.manifest().config.inner_steps;
    let rounds = run.rounds;
    let mut p = NetworkParams::quick(run, h, rounds);
    p.initial_peers = peers;
    let mut net = Network::new(&eng, p)?;
    for _ in 0..rounds {
        let r = net.run_round()?;
        println!(
            "round {:>4}  active {:>3}  submitted {:>3}  selected {:>3}  late {:>2}  loss {:>8.4}  wall {:>7.1}s  util {:>5.1}%",
            r.round,
            r.active,
            r.submitted,
            r.contributing,
            r.late_submissions,
            r.mean_loss,
            r.wall_clock(),
            100.0 * r.utilization(),
        );
    }

    let csv_path = out_dir.join("rounds.csv");
    metrics::write_csv(
        &csv_path,
        telemetry::runlog::csv_header(),
        &telemetry::runlog::csv_rows(&net.reports),
    )?;
    println!("wrote {}", csv_path.display());
    let losses: Vec<f64> = net.reports.iter().map(|r| r.mean_loss).collect();
    println!("loss  {}", metrics::sparkline(&losses));

    for p in net.telemetry.write_artifacts(&out_dir)? {
        println!("wrote {}", p.display());
    }
    if net.telemetry.enabled() {
        println!("{}", net.telemetry.snapshot().render());
        println!(
            "open {} at https://ui.perfetto.dev to browse the round timeline",
            out_dir.join("trace.json").display()
        );
    }
    Ok(())
}

fn config_show(args: &Args) -> Result<()> {
    use covenant::config::presets;
    let name = args.get_or("name", "covenant-72b");
    let cfg = presets::get(&name)?;
    let lay = covenant::config::layout::Layout::build(&cfg);
    println!("config: {}", cfg.name);
    println!("  layers        {}", cfg.n_layers);
    println!("  d_model       {}", cfg.d_model);
    println!("  query heads   {}", cfg.n_heads);
    println!("  kv heads      {}", cfg.n_kv_heads);
    println!("  d_ff          {}", cfg.d_ff);
    println!("  rope theta    {}", cfg.rope_theta);
    println!("  vocab         {}", cfg.vocab_size);
    println!("  seq len       {}", cfg.seq_len);
    println!("  parameters    {}", lay.n_params);
    println!("  flat alloc    {} ({} chunks)", lay.n_alloc, lay.n_chunks());
    Ok(())
}

fn smoke(args: &Args) -> Result<()> {
    use covenant::runtime::{ops, Engine};
    let dir = args.get_or("artifacts", "artifacts/tiny");
    let eng = Engine::new(&dir)?;
    let m = eng.manifest().clone();
    println!(
        "config={} n_params={} n_alloc={} chunks={}",
        m.config.name, m.n_params, m.n_alloc, m.n_chunks
    );
    // init_params
    let params = ops::init_params(&eng, 0)?;
    println!(
        "init_params ok: {} floats, params[0..4]={:?}",
        params.len(),
        &params[..4]
    );
    // eval_loss on pseudo-random tokens
    let b = m.config.batch_size;
    let t = m.config.seq_len;
    let tokens: Vec<i32> = (0..b * (t + 1))
        .map(|i| ((i as u64).wrapping_mul(2654435761) % m.config.vocab_size as u64) as i32)
        .collect();
    let mask = vec![1f32; b * t];
    let loss = ops::eval_loss(&eng, &params, &tokens, &mask)?;
    println!(
        "eval_loss ok: {} (ln V = {:.3})",
        loss,
        (m.config.vocab_size as f64).ln()
    );
    // compress round-trip
    let na = m.n_alloc;
    let delta: Vec<f32> = (0..na).map(|i| ((i as f32 * 0.618).sin()) * 1e-3).collect();
    let ef = vec![0f32; na];
    let (_ef_new, payload) = ops::compress(&eng, &delta, &ef, 0.95)?;
    println!("compress ok: {} values in {} chunks", payload.n_values(), payload.n_chunks);
    let dense = ops::decompress(&eng, &payload)?;
    let nnz = dense.iter().filter(|x| **x != 0.0).count();
    println!("decompress ok: {} nonzeros of {}", nnz, dense.len());
    // one train_step
    let zeros = vec![0f32; na];
    let (_p, _m2, _v2, step_loss) =
        ops::train_step(&eng, &params, &zeros, &zeros, 1.0, &tokens, &mask, 1e-3, 0.0)?;
    println!("train_step ok: loss={step_loss}");
    for (name, (calls, secs)) in eng.exec_stats() {
        println!("  perf {name}: {calls} calls, {secs:.3}s total");
    }
    println!("smoke OK");
    Ok(())
}
