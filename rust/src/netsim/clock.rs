//! Deterministic virtual clock (seconds, f64).
//!
//! All simulated time in the run (compute windows, transfers, chain
//! blocks) advances through one `VirtualClock`, making whole-network runs
//! bit-reproducible and letting us simulate a 2-hour Figure-3 window in
//! microseconds.

use std::cell::Cell;
use std::rc::Rc;

/// Shared virtual clock. Clone shares the underlying time.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: Rc<Cell<f64>>,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulated time in seconds.
    pub fn now(&self) -> f64 {
        self.now.get()
    }

    /// Advance by `dt` seconds (dt >= 0).
    pub fn advance(&self, dt: f64) {
        assert!(dt >= 0.0, "time cannot go backwards (dt={dt})");
        self.now.set(self.now.get() + dt);
    }

    /// Advance to an absolute time if it is in the future.
    pub fn advance_to(&self, t: f64) {
        if t > self.now.get() {
            self.now.set(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_shares() {
        let c = VirtualClock::new();
        let c2 = c.clone();
        c.advance(5.0);
        assert_eq!(c2.now(), 5.0);
        c2.advance_to(3.0); // in the past: no-op
        assert_eq!(c.now(), 5.0);
        c2.advance_to(8.0);
        assert_eq!(c.now(), 8.0);
    }

    #[test]
    #[should_panic]
    fn rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }
}
