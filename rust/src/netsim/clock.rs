//! Deterministic virtual clock (seconds, f64).
//!
//! All simulated time in the run (compute windows, transfers, chain
//! blocks) advances through one `VirtualClock`, making whole-network runs
//! bit-reproducible and letting us simulate a 2-hour Figure-3 window in
//! microseconds.
//!
//! The clock is `Send + Sync`: time is stored as the bit pattern of an
//! `f64` inside an `Arc<AtomicU64>`, so the event scheduler
//! ([`crate::netsim::sched`]) can be driven from the rayon round loop and
//! clones can be read from worker threads. Monotonicity is enforced with
//! CAS loops — concurrent `advance_to` calls can never move time
//! backwards. Clones share the underlying time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared virtual clock. Clone shares the underlying time.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    /// `f64` bit pattern of the current time (bits of `0.0` are `0`, so
    /// `AtomicU64::default()` is a clock at t = 0).
    now: Arc<AtomicU64>,
}

impl VirtualClock {
    /// A fresh clock at t = 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// A *detached* clock starting at `t` (does not share time with any
    /// existing clock) — used by the round engine to give each round's
    /// event scheduler its own cursor.
    pub fn at(t: f64) -> Self {
        assert!(t >= 0.0 && t.is_finite(), "clock must start at finite t >= 0 (t={t})");
        Self { now: Arc::new(AtomicU64::new(t.to_bits())) }
    }

    /// Current simulated time in seconds.
    pub fn now(&self) -> f64 {
        f64::from_bits(self.now.load(Ordering::Acquire))
    }

    /// Advance by `dt` seconds (dt >= 0).
    pub fn advance(&self, dt: f64) {
        assert!(dt >= 0.0, "time cannot go backwards (dt={dt})");
        let mut cur = self.now.load(Ordering::Acquire);
        loop {
            let next = (f64::from_bits(cur) + dt).to_bits();
            match self
                .now
                .compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Advance to an absolute time if it is in the future.
    pub fn advance_to(&self, t: f64) {
        let mut cur = self.now.load(Ordering::Acquire);
        while t > f64::from_bits(cur) {
            match self
                .now
                .compare_exchange_weak(cur, t.to_bits(), Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_shares() {
        let c = VirtualClock::new();
        let c2 = c.clone();
        c.advance(5.0);
        assert_eq!(c2.now(), 5.0);
        c2.advance_to(3.0); // in the past: no-op
        assert_eq!(c.now(), 5.0);
        c2.advance_to(8.0);
        assert_eq!(c.now(), 8.0);
    }

    #[test]
    #[should_panic]
    fn rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }

    #[test]
    fn detached_start() {
        let c = VirtualClock::at(42.0);
        assert_eq!(c.now(), 42.0);
        let d = VirtualClock::new();
        d.advance(1.0);
        assert_eq!(c.now(), 42.0, "detached clocks do not share time");
    }

    #[test]
    fn is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<VirtualClock>();
    }

    #[test]
    fn concurrent_advance_to_is_monotone() {
        let c = VirtualClock::new();
        std::thread::scope(|s| {
            for i in 0..8u64 {
                let c = c.clone();
                s.spawn(move || {
                    for j in 0..1000u64 {
                        c.advance_to(((i * 1000 + j) % 7000) as f64);
                    }
                });
            }
        });
        // the max target ever requested wins; time never went backwards
        assert_eq!(c.now(), 6999.0);
    }
}
