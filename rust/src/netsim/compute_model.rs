//! Deterministic per-peer compute-duration model.
//!
//! The paper's deadline economics only matter because peers are
//! heterogeneous: a 20-minute compute window is comfortable on 8xH100 and
//! hopeless on last-generation hardware, so stragglers miss the upload
//! deadline and the Gauntlet's `Late` verdicts have teeth. This module
//! assigns every hotkey a hardware *tier* (fast / median / straggler) and
//! produces per-round compute durations — tier multiplier, small
//! per-round jitter, and an occasional stall (driver hiccup, thermal
//! throttle) — as a pure function of `(run seed, hotkey, round)`. No
//! shared RNG stream is consumed, so enabling heterogeneity perturbs
//! *only* the simulated timeline, never the training math or the peers'
//! behavioural randomness.
//!
//! With `HeterogeneityConfig::enabled == false` the model is degenerate:
//! every duration is exactly the compute window (bit-for-bit), which is
//! what the event-spine equivalence test pins against the historical
//! barrier timings.

/// Hardware tier of a peer, fixed for the lifetime of its hotkey.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeTier {
    /// Better-than-window hardware; finishes early.
    Fast,
    /// Finishes right at the window (the calibration point).
    Median,
    /// Under-provisioned; regularly overruns the window.
    Straggler,
}

/// Heterogeneity knobs (configured via `config::run::NetworkConfig`).
#[derive(Debug, Clone)]
pub struct HeterogeneityConfig {
    /// Master switch. Off = degenerate model (every peer's compute takes
    /// exactly the window; zero jitter, zero stalls).
    pub enabled: bool,
    /// Fraction of hotkeys in the fast tier.
    pub fast_frac: f64,
    /// Fraction of hotkeys in the straggler tier.
    pub straggler_frac: f64,
    /// Compute-duration multiplier for fast peers (< 1).
    pub fast_mult: f64,
    /// Compute-duration multiplier for stragglers (> 1).
    pub straggler_mult: f64,
    /// Uniform per-round jitter amplitude as a fraction of the duration
    /// (duration *= 1 + jitter_frac * U[-1, 1)).
    pub jitter_frac: f64,
    /// Per-round probability of an occasional stall.
    pub p_stall: f64,
    /// Duration multiplier applied in stall rounds.
    pub stall_mult: f64,
}

impl Default for HeterogeneityConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            fast_frac: 0.25,
            straggler_frac: 0.15,
            fast_mult: 0.85,
            straggler_mult: 1.5,
            jitter_frac: 0.04,
            p_stall: 0.01,
            stall_mult: 3.0,
        }
    }
}

/// Stateless duration model seeded from the run seed.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    seed: u64,
    /// The heterogeneity knobs in effect.
    pub cfg: HeterogeneityConfig,
}

/// FNV-style mix of (seed, hotkey, tag) -> u64, matching the spirit of the
/// round engine's per-peer round seeds: stable across scheduling order and
/// population size. Shared with the fault-injection layer
/// (`netsim::faults`), which draws host-crash/stall/link-flap decisions
/// from the same pure hash so faults, like hardware tiers, never consume
/// a shared RNG stream.
pub(crate) fn mix(seed: u64, hotkey: &str, tag: u64) -> u64 {
    mix_finish(mix_prefix(seed, hotkey), tag)
}

/// The `(seed, hotkey)` half of [`mix`], split out so swarm-scale callers
/// can hash a hotkey's bytes once at join time and finish per round with
/// [`mix_finish`] — `mix(seed, hk, tag) == mix_finish(mix_prefix(seed, hk), tag)`
/// bit-for-bit, so prefix-based draws are interchangeable with string draws.
pub(crate) fn mix_prefix(seed: u64, hotkey: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64 ^ seed.wrapping_mul(0x9E3779B97F4A7C15);
    for b in hotkey.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The per-draw half of [`mix`]: fold `tag` into a [`mix_prefix`] state and
/// run the splitmix finalizer.
pub(crate) fn mix_finish(prefix: u64, tag: u64) -> u64 {
    let mut h = prefix ^ tag.wrapping_mul(0xD1B54A32D192ED03);
    h ^= h >> 29;
    h = h.wrapping_mul(0xBF58476D1CE4E5B9);
    h ^ (h >> 31)
}

/// Map a mixed hash to a uniform f64 in [0, 1).
pub(crate) fn unit(z: u64) -> f64 {
    (z >> 11) as f64 / (1u64 << 53) as f64
}

impl ComputeModel {
    /// A duration model for the given run seed and knobs.
    pub fn new(seed: u64, cfg: HeterogeneityConfig) -> Self {
        Self { seed, cfg }
    }

    /// The `mix_prefix` of a hotkey under this model's seed. Hash the
    /// string once at join time, then draw per round with the `*_from`
    /// variants — bit-identical to the string-keyed methods, without
    /// re-walking hotkey bytes on every draw (the O(peers · rounds)
    /// string-hash cost that dominates at swarm scale).
    pub fn prefix(&self, hotkey: &str) -> u64 {
        mix_prefix(self.seed, hotkey)
    }

    /// The tier a hotkey belongs to — a pure function of (seed, hotkey),
    /// so a peer's hardware never changes between rounds.
    pub fn tier(&self, hotkey: &str) -> ComputeTier {
        self.tier_from(mix_prefix(self.seed, hotkey))
    }

    /// [`ComputeModel::tier`] keyed by a precomputed [`ComputeModel::prefix`].
    pub fn tier_from(&self, prefix: u64) -> ComputeTier {
        if !self.cfg.enabled {
            return ComputeTier::Median;
        }
        let u = unit(mix_finish(prefix, 0x7E9));
        if u < self.cfg.fast_frac {
            ComputeTier::Fast
        } else if u < self.cfg.fast_frac + self.cfg.straggler_frac {
            ComputeTier::Straggler
        } else {
            ComputeTier::Median
        }
    }

    /// Tier duration multiplier.
    pub fn multiplier(&self, tier: ComputeTier) -> f64 {
        match tier {
            ComputeTier::Fast => self.cfg.fast_mult,
            ComputeTier::Median => 1.0,
            ComputeTier::Straggler => self.cfg.straggler_mult,
        }
    }

    /// Compute duration for `hotkey` in `round`, given the nominal compute
    /// window. Degenerate model: returns `window_s` unchanged (bit-exact).
    pub fn duration(&self, hotkey: &str, round: usize, window_s: f64) -> f64 {
        self.duration_from(mix_prefix(self.seed, hotkey), round, window_s)
    }

    /// [`ComputeModel::duration`] keyed by a precomputed
    /// [`ComputeModel::prefix`] — the swarm hot-path variant.
    pub fn duration_from(&self, prefix: u64, round: usize, window_s: f64) -> f64 {
        if !self.cfg.enabled {
            return window_s;
        }
        let mut d = window_s * self.multiplier(self.tier_from(prefix));
        let j = unit(mix_finish(prefix, 0x11D ^ ((round as u64) << 8)));
        d *= 1.0 + self.cfg.jitter_frac * (2.0 * j - 1.0);
        let s = unit(mix_finish(prefix, 0x57A11 ^ (round as u64).wrapping_mul(0x9E37)));
        if s < self.cfg.p_stall {
            d *= self.cfg.stall_mult;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_cfg() -> HeterogeneityConfig {
        HeterogeneityConfig { enabled: true, ..Default::default() }
    }

    #[test]
    fn prefix_split_matches_string_mix_bitwise() {
        for (seed, hk) in [(0u64, "hk-00000"), (0xC0DE, "hk-12345"), (u64::MAX, "swm-000007")] {
            let p = mix_prefix(seed, hk);
            for tag in [0u64, 0x7E9, 0x11D, 0x57A11, u64::MAX] {
                assert_eq!(mix(seed, hk, tag), mix_finish(p, tag));
            }
        }
        // the model-level variants agree too, enabled and disabled
        for cfg in [HeterogeneityConfig::default(), enabled_cfg()] {
            let m = ComputeModel::new(0xBEEF, cfg);
            let p = m.prefix("hk-00042");
            assert_eq!(m.tier("hk-00042"), m.tier_from(p));
            for r in 0..8 {
                assert_eq!(
                    m.duration("hk-00042", r, 1200.0).to_bits(),
                    m.duration_from(p, r, 1200.0).to_bits()
                );
            }
        }
    }

    #[test]
    fn degenerate_is_bit_exact_window() {
        let m = ComputeModel::new(7, HeterogeneityConfig::default());
        for r in 0..50 {
            assert_eq!(m.duration("hk-00003", r, 1200.0).to_bits(), 1200.0f64.to_bits());
            assert_eq!(m.tier("hk-00003"), ComputeTier::Median);
        }
    }

    #[test]
    fn tier_is_stable_per_hotkey() {
        let m = ComputeModel::new(42, enabled_cfg());
        for i in 0..40 {
            let hk = format!("hk-{i:05}");
            let t = m.tier(&hk);
            assert_eq!(t, m.tier(&hk));
            assert_eq!(t, ComputeModel::new(42, enabled_cfg()).tier(&hk));
        }
    }

    #[test]
    fn tier_fractions_roughly_respected() {
        let m = ComputeModel::new(3, enabled_cfg());
        let n = 5000;
        let mut fast = 0;
        let mut strag = 0;
        for i in 0..n {
            match m.tier(&format!("hk-{i:05}")) {
                ComputeTier::Fast => fast += 1,
                ComputeTier::Straggler => strag += 1,
                ComputeTier::Median => {}
            }
        }
        let ff = fast as f64 / n as f64;
        let sf = strag as f64 / n as f64;
        assert!((ff - 0.25).abs() < 0.03, "fast frac = {ff}");
        assert!((sf - 0.15).abs() < 0.03, "straggler frac = {sf}");
    }

    #[test]
    fn straggler_overruns_fast_underruns() {
        let mut cfg = enabled_cfg();
        cfg.jitter_frac = 0.0;
        cfg.p_stall = 0.0;
        let m = ComputeModel::new(1, cfg);
        let (mut saw_fast, mut saw_strag) = (false, false);
        for i in 0..200 {
            let hk = format!("hk-{i:05}");
            let d = m.duration(&hk, 0, 1000.0);
            match m.tier(&hk) {
                ComputeTier::Fast => {
                    assert!(d < 1000.0, "fast peer slower than window: {d}");
                    saw_fast = true;
                }
                ComputeTier::Straggler => {
                    assert!(d > 1000.0, "straggler faster than window: {d}");
                    saw_strag = true;
                }
                ComputeTier::Median => assert_eq!(d, 1000.0),
            }
        }
        assert!(saw_fast && saw_strag, "200 hotkeys must cover all tiers");
    }

    #[test]
    fn jitter_varies_by_round_but_is_deterministic() {
        let m = ComputeModel::new(9, enabled_cfg());
        let a0 = m.duration("hk-00000", 0, 1000.0);
        let a1 = m.duration("hk-00000", 1, 1000.0);
        assert_ne!(a0, a1, "jitter must vary round to round");
        assert_eq!(a0, m.duration("hk-00000", 0, 1000.0));
    }

    #[test]
    fn stalls_occur_at_configured_rate() {
        let mut cfg = enabled_cfg();
        cfg.p_stall = 0.1;
        cfg.jitter_frac = 0.0;
        cfg.fast_frac = 0.0;
        cfg.straggler_frac = 0.0;
        let m = ComputeModel::new(5, cfg);
        let n = 4000;
        let stalls = (0..n)
            .filter(|&r| m.duration("hk-00001", r, 100.0) > 200.0)
            .count();
        let rate = stalls as f64 / n as f64;
        assert!((rate - 0.1).abs() < 0.03, "stall rate = {rate}");
    }
}
