//! Bandwidth-constrained links.
//!
//! Each peer has an asymmetric internet link (paper §4.3: <=110 Mb/s up,
//! <=500 Mb/s down). A `Link` models one direction as a busy-until time:
//! a transfer of `bytes` occupies the link for `bytes*8/bps` seconds after
//! a latency floor, serialized FIFO — the object-store fan-out means peers
//! never contend with each other, only with their own link (Cloudflare
//! absorbs the fan-out, §3).

use super::clock::VirtualClock;

/// One direction of a peer's internet connection.
#[derive(Debug, Clone)]
pub struct Link {
    /// Bits per second.
    pub bps: f64,
    /// Per-transfer latency floor (object-store RTT), seconds.
    pub latency_s: f64,
    /// Time at which the link becomes free.
    busy_until: f64,
    /// Total bytes moved (for utilization accounting).
    pub bytes_total: u64,
}

impl Link {
    /// An idle link with the given bandwidth and latency floor.
    pub fn new(bps: f64, latency_s: f64) -> Self {
        assert!(bps > 0.0);
        Self { bps, latency_s, busy_until: 0.0, bytes_total: 0 }
    }

    /// Schedule a transfer starting no earlier than `start`; returns the
    /// completion time. Serializes with earlier transfers on this link.
    pub fn transfer(&mut self, start: f64, bytes: usize) -> f64 {
        let begin = start.max(self.busy_until);
        let duration = self.latency_s + bytes as f64 * 8.0 / self.bps;
        self.busy_until = begin + duration;
        self.bytes_total += bytes as u64;
        self.busy_until
    }

    /// Duration a transfer of `bytes` takes on an idle link.
    pub fn duration(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / self.bps
    }

    /// Time at which the link's transfer queue drains.
    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    /// Reset busy state (new round barrier).
    pub fn release_at(&mut self, t: f64) {
        self.busy_until = self.busy_until.max(t);
    }

    /// The link fails at time `t`, aborting whatever is in flight.
    ///
    /// `release_at` models a link that lives forever (busy time only ever
    /// grows); a *flap* is the opposite: if a transfer is still in flight
    /// at `t` (`busy_until > t`) the tail of that transfer is cancelled,
    /// the link is free again from `t`, and the caller re-queues the
    /// whole transfer (partial uploads are worthless — the object store
    /// only sees complete objects). Returns `true` if a transfer was
    /// actually cut. Bytes already charged stay charged: the wasted
    /// bandwidth of the aborted attempt is real traffic and shows up in
    /// utilization accounting.
    pub fn cut_at(&mut self, t: f64) -> bool {
        if self.busy_until > t {
            self.busy_until = t;
            true
        } else {
            false
        }
    }
}

/// A peer's full connection: uplink + downlink, sharing the virtual clock.
#[derive(Debug, Clone)]
pub struct LinkPair {
    /// Uplink (peer -> object store).
    pub up: Link,
    /// Downlink (object store -> peer).
    pub down: Link,
}

impl LinkPair {
    /// An idle asymmetric connection.
    pub fn new(uplink_bps: f64, downlink_bps: f64, latency_s: f64) -> Self {
        Self {
            up: Link::new(uplink_bps, latency_s),
            down: Link::new(downlink_bps, latency_s),
        }
    }

    /// Upload then (conceptually) the object store holds the bytes;
    /// returns completion time.
    pub fn upload(&mut self, clock: &VirtualClock, bytes: usize) -> f64 {
        self.up.transfer(clock.now(), bytes)
    }

    /// Download from the object store; returns completion time.
    pub fn download(&mut self, clock: &VirtualClock, bytes: usize) -> f64 {
        self.down.transfer(clock.now(), bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_matches_bandwidth() {
        let mut l = Link::new(8e6, 0.0); // 1 MB/s
        let done = l.transfer(0.0, 1_000_000);
        assert!((done - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_floor_applies() {
        let mut l = Link::new(1e9, 0.25);
        let done = l.transfer(0.0, 1);
        assert!(done >= 0.25);
    }

    #[test]
    fn serializes_fifo() {
        let mut l = Link::new(8e6, 0.0);
        let d1 = l.transfer(0.0, 1_000_000);
        let d2 = l.transfer(0.0, 1_000_000); // queued behind d1
        assert!((d1 - 1.0).abs() < 1e-9);
        assert!((d2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn paper_uplink_number() {
        // 72B-scale payload at 110 Mb/s: the Fig.3 claim t_comm ~ 70s is
        // dominated by this uplink (verified precisely in fig3 bench).
        let l = Link::new(110e6, 0.2);
        // ~0.5 GB dense would take ~36s/GB... compressed payload ~61 MB:
        let t = l.duration(61_000_000);
        assert!(t > 4.0 && t < 6.0, "t={t}");
    }

    #[test]
    fn accounting() {
        let mut l = Link::new(1e6, 0.0);
        l.transfer(0.0, 100);
        l.transfer(0.0, 200);
        assert_eq!(l.bytes_total, 300);
    }

    #[test]
    fn back_to_back_transfers_queue_fifo() {
        // Three transfers requested out of order in *request time* still
        // serialize in request order (FIFO): each begins no earlier than
        // the previous one's completion.
        let mut l = Link::new(8e6, 0.5); // 1 MB/s + 0.5s latency floor
        let d1 = l.transfer(0.0, 500_000); // 0.5 + 0.5 = 1.0
        let d2 = l.transfer(0.2, 500_000); // queued: 1.0 + 1.0 = 2.0
        let d3 = l.transfer(1.9, 500_000); // queued: 2.0 + 1.0 = 3.0
        assert!((d1 - 1.0).abs() < 1e-9, "d1={d1}");
        assert!((d2 - 2.0).abs() < 1e-9, "d2={d2}");
        assert!((d3 - 3.0).abs() < 1e-9, "d3={d3}");
        assert_eq!(l.busy_until(), d3);
    }

    #[test]
    fn idle_gap_does_not_queue() {
        // A transfer requested after the link went idle starts at its own
        // request time, not at the previous busy_until.
        let mut l = Link::new(8e6, 0.0);
        let d1 = l.transfer(0.0, 1_000_000); // done at 1.0
        let d2 = l.transfer(5.0, 1_000_000); // idle gap: starts at 5.0
        assert!((d1 - 1.0).abs() < 1e-9);
        assert!((d2 - 6.0).abs() < 1e-9, "d2={d2}");
    }

    #[test]
    fn release_at_is_monotone() {
        // release_at only ever *raises* busy_until: it can hold a link
        // busy (a stalled upload cut at the deadline) but can never free
        // it early or move time backwards.
        let mut l = Link::new(8e6, 0.0);
        l.transfer(0.0, 1_000_000); // busy until 1.0
        l.release_at(0.25); // in the past: no-op
        assert!((l.busy_until() - 1.0).abs() < 1e-9);
        l.release_at(3.0);
        assert!((l.busy_until() - 3.0).abs() < 1e-9);
        l.release_at(2.0); // earlier again: no-op
        assert!((l.busy_until() - 3.0).abs() < 1e-9);
        // and the next transfer queues behind the held busy window
        let d = l.transfer(0.0, 1_000_000);
        assert!((d - 4.0).abs() < 1e-9, "d={d}");
    }

    #[test]
    fn release_at_does_not_charge_bytes() {
        let mut l = Link::new(1e6, 0.0);
        l.release_at(100.0);
        assert_eq!(l.bytes_total, 0);
    }

    #[test]
    fn cut_mid_transfer_frees_the_link() {
        let mut l = Link::new(8e6, 0.0); // 1 MB/s
        let done = l.transfer(0.0, 1_000_000); // in flight until 1.0
        assert!((done - 1.0).abs() < 1e-9);
        assert!(l.cut_at(0.4), "an in-flight transfer must report as cut");
        assert!((l.busy_until() - 0.4).abs() < 1e-9);
        // The aborted attempt's bytes stay charged (wasted bandwidth).
        assert_eq!(l.bytes_total, 1_000_000);
    }

    #[test]
    fn cut_on_an_idle_link_is_a_no_op() {
        let mut l = Link::new(8e6, 0.0);
        l.transfer(0.0, 1_000_000); // done at 1.0
        assert!(!l.cut_at(1.0), "boundary: nothing in flight at busy_until");
        assert!(!l.cut_at(5.0));
        assert!((l.busy_until() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn requeue_after_cut_completes_later() {
        // Flap at 0.4, retry after a 0.6s backoff: the full transfer is
        // re-sent and completes at 2.0, not at the original 1.0.
        let mut l = Link::new(8e6, 0.0);
        l.transfer(0.0, 1_000_000);
        assert!(l.cut_at(0.4));
        let done = l.transfer(0.4 + 0.6, 1_000_000);
        assert!((done - 2.0).abs() < 1e-9, "done={done}");
        assert_eq!(l.bytes_total, 2_000_000);
    }

    #[test]
    fn release_at_stays_monotone_after_a_cut() {
        let mut l = Link::new(8e6, 0.0);
        l.transfer(0.0, 1_000_000);
        assert!(l.cut_at(0.25));
        l.release_at(0.1); // earlier than the cut: no-op
        assert!((l.busy_until() - 0.25).abs() < 1e-9);
        l.release_at(2.0);
        assert!((l.busy_until() - 2.0).abs() < 1e-9);
    }
}
