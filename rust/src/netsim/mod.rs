//! Network simulation substrate: virtual clock, per-peer
//! bandwidth-constrained FIFO links (paper §4.3's 110 Mb/s uplink /
//! 500 Mb/s downlink constraint), a discrete-event scheduler, and the
//! per-peer compute-duration model.
//!
//! The paper's communication phase runs over real internet links to object
//! storage; here transfers are scheduled on a deterministic virtual clock
//! so Figure 3's compute/communication timelines are reproducible, with
//! transfer durations computed from real payload byte-sizes.
//!
//! Since the event-spine rewire, the round engine no longer assumes a
//! compute-window barrier: [`sched::Scheduler`] pops typed events
//! (compute/upload/download completions, the round deadline, chain
//! blocks) off a binary heap in deterministic time order, and
//! [`compute_model::ComputeModel`] gives every hotkey a hardware tier so
//! stragglers genuinely miss deadlines instead of being assumed away.
//! [`VirtualClock`] is `Send + Sync` (atomic f64 bit-patterns), so the
//! clock can be shared with the rayon round loop.

pub mod clock;
pub mod compute_model;
pub mod link;
pub mod sched;
pub mod testkit;

pub use clock::VirtualClock;
pub use compute_model::{ComputeModel, ComputeTier, HeterogeneityConfig};
pub use link::{Link, LinkPair};
pub use sched::{Event, Scheduler};
