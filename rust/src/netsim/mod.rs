//! Network simulation substrate: virtual clock + per-peer
//! bandwidth-constrained FIFO links (paper §4.3's 110 Mb/s uplink /
//! 500 Mb/s downlink constraint).
//!
//! The paper's communication phase runs over real internet links to object
//! storage; here transfers are scheduled on a deterministic virtual clock
//! so Figure 3's compute/communication timelines are reproducible, with
//! transfer durations computed from real payload byte-sizes.

pub mod clock;
pub mod link;

pub use clock::VirtualClock;
pub use link::{Link, LinkPair};
