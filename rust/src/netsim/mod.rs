//! Network simulation substrate: virtual clock, per-peer
//! bandwidth-constrained FIFO links (paper §4.3's 110 Mb/s uplink /
//! 500 Mb/s downlink constraint), a discrete-event scheduler, and the
//! per-peer compute-duration model.
//!
//! The paper's communication phase runs over real internet links to object
//! storage; here transfers are scheduled on a deterministic virtual clock
//! so Figure 3's compute/communication timelines are reproducible, with
//! transfer durations computed from real payload byte-sizes.
//!
//! Since the event-spine rewire, the round engine no longer assumes a
//! compute-window barrier: [`sched::Scheduler`] pops typed events
//! (compute/upload/download completions, the round deadline, chain
//! blocks) off a binary heap in deterministic time order, and
//! [`compute_model::ComputeModel`] gives every hotkey a hardware tier so
//! stragglers genuinely miss deadlines instead of being assumed away.
//! [`VirtualClock`] is `Send + Sync` (atomic f64 bit-patterns), so the
//! clock can be shared with the rayon round loop.
//!
//! Under multi-coordinator sharding (`coordinator::shard`) the same
//! spine carries the shard protocol: per-slice upload completions
//! ([`Event::ShardUploadDone`]) and per-shard aggregation readiness
//! ([`Event::ShardAggregated`]) are ordinary events, and the outer step
//! applies at the cross-shard barrier (the last `ShardAggregated`).
//! Every timing model here is deterministic, so the sharded rounds stay
//! bit-reproducible: disjoint chunk ranges + fixed accumulation order
//! on the coordinator side, pure-hash durations on this side.
//!
//! Coordinator-side *faults* ride the same spine: [`faults::FaultModel`]
//! draws host crashes, host stalls, and upload-link flaps from a pure
//! hash of `(run seed, host or hotkey, round)`, and the round engine
//! turns them into [`Event::HostCrash`] / [`Event::ShardReassigned`] /
//! [`Event::UploadRetry`] trace events plus the recovery behaviour in
//! `coordinator::shard`. With faults off the layer draws nothing and
//! emits nothing, so degenerate rounds stay bit-identical.
//!
//! At swarm scale, [`wan::WanModel`] layers a WAN topology on top of
//! the per-peer links: pure-hash region assignment, asymmetric per-peer
//! bandwidth spread, an inter-region latency hop, and optionally one
//! oversubscribed FIFO uplink trunk per region. Disabled (the default)
//! it is bitwise degenerate — no regions, base link shapes unchanged,
//! no trunks.

#![deny(missing_docs)]

pub mod clock;
pub mod compute_model;
pub mod faults;
pub mod link;
pub mod sched;
pub mod testkit;
pub mod wan;

pub use clock::VirtualClock;
pub use compute_model::{ComputeModel, ComputeTier, HeterogeneityConfig};
pub use faults::{FaultConfig, FaultKind, FaultModel, FaultPlan, FaultScenario, ScriptedFault};
pub use link::{Link, LinkPair};
pub use sched::{Event, Scheduler};
pub use wan::{LinkShape, WanConfig, WanModel};
