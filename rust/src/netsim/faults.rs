//! Deterministic fault injection for placed shard hosts.
//!
//! Coordinator-side failures — a shard host crashing, stalling, or
//! sitting behind a flapping link — dominated real WAN runs (INTELLECT-1
//! reports orchestrator faults outweighing peer churn), so the simulator
//! injects them as first-class, *reproducible* events. Every decision is
//! a pure function of `(run seed, host or hotkey, round, attempt)` via
//! the same FNV-style hash the compute model uses: no shared RNG stream
//! is consumed, so enabling faults perturbs only the simulated timeline
//! and the recovery path, never the training math or the peers'
//! behavioural randomness.
//!
//! Three fault kinds exist:
//!
//! - **Host crash** — the host dies at round start, permanently. Shards
//!   assigned to it miss their barrier announcement; the round engine
//!   detects this after a timeout and reassigns the chunk range to a
//!   surviving host (see `coordinator::shard`). The last surviving host
//!   can never crash (the *survivor rule*), so a run always terminates.
//! - **Host stall** — the host pauses for a fixed interval; its barrier
//!   announcement is delayed but arrives. If the delay stays inside the
//!   detection timeout the barrier simply moves; no recovery fires.
//! - **Link flap** — a peer's upload link drops mid-transfer. The peer
//!   retries with bounded exponential backoff
//!   ([`crate::peer::worker::upload_backoff_s`]); exhausting the budget
//!   abandons the submission and orphans any slices that already landed
//!   in the object store.
//!
//! Scenarios: [`FaultScenario::Probabilistic`] draws from the configured
//! rates; [`FaultScenario::Scripted`] fires an exact list (tests);
//! [`FaultScenario::CiCrashy`] is the canned CI sweep — it crashes host
//! `round % n_hosts` and stalls host `(round + 1) % n_hosts` each round,
//! and is a complete no-op for single-host deployments (one host has no
//! failure domain), so default-config timing pins stay bit-exact when CI
//! re-runs the whole suite under `COVENANT_FAULT_SCENARIO=ci-crashy`.
//!
//! With `FaultConfig::default()` (disabled, all rates zero) the layer is
//! inert: zero hash draws, zero events, bit-identical rounds.

use super::compute_model::{mix, unit};

/// One scripted fault: `kind` hits `host` at the start of `round`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScriptedFault {
    /// Round index (0-based) the fault fires in.
    pub round: usize,
    /// Host index the fault targets.
    pub host: usize,
    /// What happens to the host.
    pub kind: FaultKind,
}

/// The kind of a scripted fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Permanent host crash at round start.
    HostCrash,
    /// Transient stall: the host's barrier announcement is delayed by
    /// `FaultConfig::stall_s`.
    HostStall,
}

/// How per-round faults are chosen.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultScenario {
    /// Draw crashes/stalls/flaps from the configured probabilities via
    /// the pure `(seed, host, round)` hash.
    Probabilistic,
    /// The canned CI scenario: each round `r >= 1` crashes host
    /// `r % n_hosts` (survivor rule permitting) and stalls host
    /// `(r + 1) % n_hosts`. No-op when the deployment has at most one
    /// host.
    CiCrashy,
    /// Fire exactly these faults (unit/integration tests). An explicit
    /// empty script pins a run as fault-free even when the
    /// `COVENANT_FAULT_SCENARIO` env var is set.
    Scripted(Vec<ScriptedFault>),
}

/// Fault-injection knobs (configured via `config::run::RunConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Master switch. Off = inert layer: no draws, no events.
    pub enabled: bool,
    /// Per-round, per-host crash probability (probabilistic scenario).
    pub p_host_crash: f64,
    /// Per-round, per-host stall probability (probabilistic scenario).
    pub p_host_stall: f64,
    /// Stall duration in simulated seconds.
    pub stall_s: f64,
    /// Per-attempt probability that a peer's upload link flaps
    /// mid-transfer.
    pub p_link_flap: f64,
    /// Upload retry budget after the first attempt; exceeding it
    /// abandons the submission (`FastCheck::OrphanedUpload`).
    pub max_upload_retries: u32,
    /// Base backoff before the first retry; attempt `k` waits
    /// `retry_backoff_s * 2^k` simulated seconds.
    pub retry_backoff_s: f64,
    /// How long past the round deadline the barrier waits for a missing
    /// shard announcement before declaring the host dead and reassigning
    /// its chunk range.
    pub failover_timeout_s: f64,
    /// How faults are chosen each round.
    pub scenario: FaultScenario,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            p_host_crash: 0.0,
            p_host_stall: 0.0,
            stall_s: 300.0,
            p_link_flap: 0.0,
            max_upload_retries: 3,
            retry_backoff_s: 5.0,
            failover_timeout_s: 60.0,
            scenario: FaultScenario::Probabilistic,
        }
    }
}

impl FaultConfig {
    /// Resolve the ambient `COVENANT_FAULT_SCENARIO` env var against this
    /// config. An *explicitly configured* fault setup (anything that
    /// differs from the pristine default — including an empty scripted
    /// scenario) always wins, so tests that pin exact fault behaviour
    /// stay deterministic under CI's env-driven third pass. Only a
    /// pristine default config picks up the env scenario; unknown names
    /// are ignored.
    pub fn with_env(self, env: Option<&str>) -> Self {
        if self != FaultConfig::default() {
            return self;
        }
        match env {
            Some("ci-crashy") => Self {
                enabled: true,
                scenario: FaultScenario::CiCrashy,
                ..self
            },
            _ => self,
        }
    }
}

/// The faults chosen for one round, before any recovery reaction.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Hosts that crash at the start of this round (already-dead hosts
    /// and the last survivor are never listed).
    pub crashes: Vec<usize>,
    /// `(host, delay_s)` stalls applied to this round's barrier
    /// announcements.
    pub stalls: Vec<(usize, f64)>,
}

impl FaultPlan {
    /// The announce delay for `host` this round (0.0 when not stalled).
    pub fn stall_of(&self, host: usize) -> f64 {
        self.stalls
            .iter()
            .find(|&&(h, _)| h == host)
            .map_or(0.0, |&(_, d)| d)
    }
}

/// Stateless fault model seeded from the run seed.
#[derive(Debug, Clone)]
pub struct FaultModel {
    seed: u64,
    /// The fault knobs in effect (env-resolved).
    pub cfg: FaultConfig,
}

/// Domain-separation tags so crash/stall/flap draws never collide.
const TAG_CRASH: u64 = 0xC4A5;
const TAG_STALL: u64 = 0x57A1;
const TAG_FLAP: u64 = 0xF1A9;

impl FaultModel {
    /// A fault model for the given run seed and (env-resolved) knobs.
    pub fn new(seed: u64, cfg: FaultConfig) -> Self {
        Self { seed, cfg }
    }

    /// Whether upload-link flaps can fire at all (cheap gate so the
    /// round engine's transfer loop stays draw-free when flaps are off).
    pub fn flaps_enabled(&self) -> bool {
        self.cfg.enabled && self.cfg.p_link_flap > 0.0
    }

    /// Pure per-host draw in [0, 1) for (host, round, tag).
    fn host_unit(&self, host: usize, round: usize, tag: u64) -> f64 {
        let t = tag
            ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15)
            ^ (host as u64).wrapping_mul(0xD1B54A32D192ED03);
        unit(mix(self.seed, "host", t))
    }

    /// The fault plan for `round` given which hosts are still alive.
    /// Crashes obey the survivor rule: the plan never kills the last
    /// living host, so every run can finish.
    pub fn round_plan(&self, round: usize, alive: &[bool]) -> FaultPlan {
        let mut plan = FaultPlan::default();
        if !self.cfg.enabled {
            return plan;
        }
        let n_hosts = alive.len();
        let mut living = alive.iter().filter(|&&a| a).count();
        match &self.cfg.scenario {
            FaultScenario::Probabilistic => {
                for h in 0..n_hosts {
                    if !alive[h] {
                        continue;
                    }
                    if living > 1
                        && self.cfg.p_host_crash > 0.0
                        && self.host_unit(h, round, TAG_CRASH) < self.cfg.p_host_crash
                    {
                        plan.crashes.push(h);
                        living -= 1;
                        continue;
                    }
                    if self.cfg.p_host_stall > 0.0
                        && self.host_unit(h, round, TAG_STALL) < self.cfg.p_host_stall
                    {
                        plan.stalls.push((h, self.cfg.stall_s));
                    }
                }
            }
            FaultScenario::CiCrashy => {
                // A single-host deployment has no failure domain: the one
                // host is always the last survivor, so the canned sweep
                // is a complete no-op and default-config timing pins
                // stay bit-exact under the env-driven CI pass.
                if n_hosts <= 1 || round == 0 {
                    return plan;
                }
                let c = round % n_hosts;
                if alive[c] && living > 1 {
                    plan.crashes.push(c);
                    living -= 1;
                }
                let s = (round + 1) % n_hosts;
                if alive[s] && !plan.crashes.contains(&s) {
                    plan.stalls.push((s, self.cfg.stall_s));
                }
            }
            FaultScenario::Scripted(script) => {
                for f in script {
                    if f.round != round || f.host >= n_hosts || !alive[f.host] {
                        continue;
                    }
                    match f.kind {
                        FaultKind::HostCrash => {
                            if living > 1 && !plan.crashes.contains(&f.host) {
                                plan.crashes.push(f.host);
                                living -= 1;
                            }
                        }
                        FaultKind::HostStall => {
                            if !plan.crashes.contains(&f.host) {
                                plan.stalls.push((f.host, self.cfg.stall_s));
                            }
                        }
                    }
                }
            }
        }
        plan
    }

    /// Whether `hotkey`'s upload of slice `shard` flaps on `attempt`
    /// (0-based) in `round`. Pure; consumes no RNG stream.
    pub fn link_flaps(&self, hotkey: &str, shard: usize, round: usize, attempt: u32) -> bool {
        if !self.flaps_enabled() {
            return false;
        }
        let t = TAG_FLAP
            ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15)
            ^ (shard as u64).wrapping_mul(0xD1B54A32D192ED03)
            ^ (attempt as u64).wrapping_mul(0xBF58476D1CE4E5B9);
        unit(mix(self.seed, hotkey, t)) < self.cfg.p_link_flap
    }

    /// How far into a flapped transfer the cut lands, as a fraction of
    /// the transfer's span in [0.25, 0.75). Pure per (hotkey, shard,
    /// round, attempt).
    pub fn flap_cut_frac(&self, hotkey: &str, shard: usize, round: usize, attempt: u32) -> f64 {
        let t = TAG_FLAP
            ^ 0x00FF_0000
            ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15)
            ^ (shard as u64).wrapping_mul(0xD1B54A32D192ED03)
            ^ (attempt as u64).wrapping_mul(0xBF58476D1CE4E5B9);
        0.25 + 0.5 * unit(mix(self.seed, hotkey, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn crashy(n: usize) -> Vec<bool> {
        vec![true; n]
    }

    #[test]
    fn default_config_is_inert() {
        let m = FaultModel::new(7, FaultConfig::default());
        for r in 0..30 {
            assert_eq!(m.round_plan(r, &crashy(4)), FaultPlan::default());
        }
        assert!(!m.flaps_enabled());
        assert!(!m.link_flaps("hk-00001", 0, 3, 0));
    }

    #[test]
    fn plans_are_deterministic_across_models() {
        let cfg = FaultConfig {
            enabled: true,
            p_host_crash: 0.3,
            p_host_stall: 0.3,
            ..Default::default()
        };
        let a = FaultModel::new(42, cfg.clone());
        let b = FaultModel::new(42, cfg);
        for r in 0..50 {
            assert_eq!(a.round_plan(r, &crashy(6)), b.round_plan(r, &crashy(6)));
        }
    }

    #[test]
    fn survivor_rule_never_kills_the_last_host() {
        let cfg = FaultConfig {
            enabled: true,
            p_host_crash: 1.0,
            ..Default::default()
        };
        let m = FaultModel::new(1, cfg);
        let mut alive = crashy(5);
        for r in 0..20 {
            for h in m.round_plan(r, &alive).crashes {
                alive[h] = false;
            }
            assert!(alive.iter().any(|&a| a), "round {r} killed every host");
        }
        assert_eq!(alive.iter().filter(|&&a| a).count(), 1);
    }

    #[test]
    fn ci_crashy_is_a_no_op_on_a_single_host() {
        let cfg = FaultConfig {
            enabled: true,
            scenario: FaultScenario::CiCrashy,
            ..Default::default()
        };
        let m = FaultModel::new(9, cfg);
        for r in 0..20 {
            assert_eq!(m.round_plan(r, &crashy(1)), FaultPlan::default());
        }
    }

    #[test]
    fn ci_crashy_crashes_round_mod_hosts_and_stalls_the_next() {
        let cfg = FaultConfig {
            enabled: true,
            scenario: FaultScenario::CiCrashy,
            ..Default::default()
        };
        let m = FaultModel::new(9, cfg.clone());
        assert_eq!(m.round_plan(0, &crashy(3)), FaultPlan::default());
        let p1 = m.round_plan(1, &crashy(3));
        assert_eq!(p1.crashes, vec![1]);
        assert_eq!(p1.stalls, vec![(2, cfg.stall_s)]);
        // With hosts 1 and 2 dead, host 0 is the last survivor: no more
        // crashes, and only host 0 can still stall.
        let alive = vec![true, false, false];
        for r in 2..10 {
            let p = m.round_plan(r, &alive);
            assert!(p.crashes.is_empty(), "round {r} broke the survivor rule");
            for (h, _) in p.stalls {
                assert_eq!(h, 0);
            }
        }
    }

    #[test]
    fn scripted_faults_fire_exactly_once() {
        let cfg = FaultConfig {
            enabled: true,
            scenario: FaultScenario::Scripted(vec![
                ScriptedFault { round: 2, host: 1, kind: FaultKind::HostCrash },
                ScriptedFault { round: 3, host: 0, kind: FaultKind::HostStall },
            ]),
            ..Default::default()
        };
        let m = FaultModel::new(0, cfg.clone());
        assert_eq!(m.round_plan(1, &crashy(2)), FaultPlan::default());
        assert_eq!(m.round_plan(2, &crashy(2)).crashes, vec![1]);
        let alive = vec![true, false];
        assert_eq!(
            m.round_plan(3, &alive).stalls,
            vec![(0, cfg.stall_s)]
        );
        assert_eq!(m.round_plan(4, &alive), FaultPlan::default());
    }

    #[test]
    fn env_scenario_applies_only_to_pristine_defaults() {
        let pristine = FaultConfig::default().with_env(Some("ci-crashy"));
        assert!(pristine.enabled);
        assert_eq!(pristine.scenario, FaultScenario::CiCrashy);
        // An explicit (even empty) script is an opt-out.
        let pinned = FaultConfig {
            scenario: FaultScenario::Scripted(vec![]),
            ..Default::default()
        };
        let resolved = pinned.clone().with_env(Some("ci-crashy"));
        assert_eq!(resolved, pinned);
        // Unknown names and absence leave the config alone.
        assert_eq!(FaultConfig::default().with_env(Some("nope")), FaultConfig::default());
        assert_eq!(FaultConfig::default().with_env(None), FaultConfig::default());
    }

    #[test]
    fn flap_draws_are_pure_and_rate_respecting() {
        let cfg = FaultConfig {
            enabled: true,
            p_link_flap: 0.25,
            ..Default::default()
        };
        let m = FaultModel::new(11, cfg);
        assert!(m.flaps_enabled());
        let n = 4000;
        let mut flaps = 0;
        for i in 0..n {
            let hk = format!("hk-{i:05}");
            let f = m.link_flaps(&hk, 0, 3, 0);
            assert_eq!(f, m.link_flaps(&hk, 0, 3, 0));
            if f {
                flaps += 1;
            }
        }
        let rate = flaps as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.03, "flap rate = {rate}");
        let fr = m.flap_cut_frac("hk-00001", 0, 3, 0);
        assert!((0.25..0.75).contains(&fr));
        assert_eq!(fr.to_bits(), m.flap_cut_frac("hk-00001", 0, 3, 0).to_bits());
    }
}
