//! Shared heterogeneity fixtures for tests and benches (the netsim
//! analogue of `gauntlet::testkit`): a stress-tier configuration whose
//! stragglers deterministically overrun the default deadline, and a
//! deterministic search for a run seed whose initial cohort contains a
//! straggler minority. Keeping these here means `tests/netsim_events.rs`
//! and `benches/fig3_timeline.rs` exercise the *same* operating point.

use super::compute_model::{ComputeModel, ComputeTier, HeterogeneityConfig};

/// A heterogeneity config for straggler stress tests: no jitter, no
/// stalls (fully analyzable timings), and a straggler multiplier of 1.5
/// so a straggler's compute (1.5 x 20 min) overruns the default
/// 24-minute upload deadline every round.
pub fn stress_heterogeneity(fast_frac: f64) -> HeterogeneityConfig {
    HeterogeneityConfig {
        enabled: true,
        fast_frac,
        straggler_frac: 0.25,
        fast_mult: 0.85,
        straggler_mult: 1.5,
        jitter_frac: 0.0,
        p_stall: 0.0,
        stall_mult: 3.0,
    }
}

/// Find a run seed whose first `peers` minted hotkeys (`hk-00000`, ...,
/// in churn mint order) contain at least one straggler while keeping a
/// punctual majority. Tier assignment is a pure function of
/// (seed, hotkey), so this is cheap, deterministic, and requires no
/// network run. Returns (seed, straggler count).
pub fn seed_with_straggler_minority(
    peers: usize,
    cfg: &HeterogeneityConfig,
) -> (u64, usize) {
    for seed in 0..2000u64 {
        let cm = ComputeModel::new(seed, cfg.clone());
        let n = (0..peers)
            .filter(|i| cm.tier(&format!("hk-{i:05}")) == ComputeTier::Straggler)
            .count();
        if (1..=peers / 3).contains(&n) {
            return (seed, n);
        }
    }
    panic!("no seed with a straggler minority among {peers} peers in 2000 candidates");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_a_straggler_minority_seed() {
        let cfg = stress_heterogeneity(0.0);
        let (seed, n) = seed_with_straggler_minority(6, &cfg);
        assert!((1..=2).contains(&n));
        // the found seed really does produce that many stragglers
        let cm = ComputeModel::new(seed, cfg);
        let again = (0..6)
            .filter(|i| cm.tier(&format!("hk-{i:05}")) == ComputeTier::Straggler)
            .count();
        assert_eq!(n, again);
    }

    #[test]
    fn stress_stragglers_overrun_default_deadline() {
        let cfg = stress_heterogeneity(0.0);
        // 1.5 x 1200s window = 1800s > 1200 + 240 deadline
        assert!(cfg.straggler_mult * 1200.0 > 1200.0 + 240.0);
        assert_eq!(cfg.jitter_frac, 0.0);
        assert_eq!(cfg.p_stall, 0.0);
    }
}
