//! WAN topology model: regions, asymmetric per-peer links, and
//! oversubscribed region uplink trunks.
//!
//! At swarm scale (10k–100k+ peers) the flat "every peer gets the §4.3
//! reference link" model stops being representative: real swarms span
//! geographic regions with an extra latency hop to the object store,
//! per-peer bandwidth spread (consumer uplinks are the narrow,
//! *asymmetric* side), and oversubscribed regional backhaul that
//! serializes concurrent uploads. This module layers all three onto the
//! existing [`Link`](super::link::Link) FIFO model without touching it:
//!
//! * **Regions** — every hotkey maps to a region by a pure hash of
//!   `(run seed, hotkey)` (the same `mix` construction the compute-tier
//!   and fault models use). Region `0` is the object store's home
//!   region; peers elsewhere pay `inter_region_latency_s` extra on
//!   every transfer's latency floor.
//! * **Asymmetric spread** — per-peer up/down bandwidth multipliers
//!   drawn from independent pure-hash taps, with separate spread knobs
//!   for each direction (uplinks vary more than downlinks).
//! * **Oversubscribed uplink trunks** — optionally, each region gets
//!   one shared FIFO [`Link`](super::link::Link) of
//!   `region_uplink_bps`; an upload occupies its peer's own uplink
//!   first and then the region trunk. Because the trunk *is* a FIFO
//!   `Link`, serialization can delay completions but can never reorder
//!   them — the property test pins this.
//!
//! Like the compute-tier and fault layers, every draw is a pure
//! function of `(run seed, hotkey)`: **no RNG stream is consumed**, so
//! enabling the WAN model perturbs only simulated timing, never the
//! training math or any peer's behavioural randomness. Disabled (the
//! default), `link_shape` returns its inputs bit-for-bit unchanged,
//! every region is `0`, and no trunks exist — rounds are byte-identical
//! to the flat model.

use super::compute_model::{mix_finish, mix_prefix, unit};
use super::link::Link;

/// Hash tag for the region draw (see `compute_model::mix`).
const TAG_REGION: u64 = 0x9E61_0472;
/// Hash tag for the per-peer uplink-bandwidth multiplier draw.
const TAG_UPLINK: u64 = 0x0B75_110A;
/// Hash tag for the per-peer downlink-bandwidth multiplier draw.
const TAG_DOWNLINK: u64 = 0x0B75_22D0;

/// WAN topology knobs (configured via `config::run::NetworkConfig`,
/// JSON block `network.wan`). Default-off: the degenerate config maps
/// every peer to region 0 with its base link, bit-identical to the flat
/// model.
#[derive(Debug, Clone, PartialEq)]
pub struct WanConfig {
    /// Master switch. Off = no regions, no bandwidth spread, no trunks;
    /// `link_shape` returns base values bit-for-bit.
    pub enabled: bool,
    /// Number of regions peers hash into. Region 0 is the object
    /// store's home region (no extra latency).
    pub n_regions: usize,
    /// Extra latency-floor seconds on every transfer for peers outside
    /// region 0 (one WAN hop to the store).
    pub inter_region_latency_s: f64,
    /// Per-peer uplink bandwidth multiplier is drawn uniformly from
    /// `[1 - uplink_spread, 1]`; uplinks are the narrow, high-variance
    /// side of consumer links.
    pub uplink_spread: f64,
    /// Per-peer downlink multiplier drawn from `[1 - downlink_spread, 1]`.
    pub downlink_spread: f64,
    /// Shared FIFO uplink trunk bandwidth per region (oversubscribed
    /// backhaul); `0.0` (the default) = uncontended, no trunks.
    pub region_uplink_bps: f64,
}

impl Default for WanConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            n_regions: 4,
            inter_region_latency_s: 0.12,
            uplink_spread: 0.5,
            downlink_spread: 0.25,
            region_uplink_bps: 0.0,
        }
    }
}

/// A peer's WAN-shaped link parameters, feeding
/// [`LinkPair::new`](super::link::LinkPair::new).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkShape {
    /// Uplink bits per second after the per-peer multiplier.
    pub up_bps: f64,
    /// Downlink bits per second after the per-peer multiplier.
    pub down_bps: f64,
    /// Latency floor, seconds, including the inter-region hop if any.
    pub latency_s: f64,
}

/// Stateless WAN model seeded from the run seed. All draws are pure
/// hashes of `(seed, hotkey)` — stable under churn (a hotkey that
/// leaves and rejoins lands in the same region with the same link) and
/// free of RNG-stream consumption.
#[derive(Debug, Clone)]
pub struct WanModel {
    seed: u64,
    /// The topology knobs in effect.
    pub cfg: WanConfig,
}

impl WanModel {
    /// A WAN model for the given run seed and knobs.
    pub fn new(seed: u64, cfg: WanConfig) -> Self {
        Self { seed, cfg }
    }

    /// Whether the topology is active (disabled = flat model).
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The `(seed, hotkey)` hash prefix — hash once at join time, draw
    /// per call with the `*_from` variants (bit-identical to the
    /// string-keyed methods; same split as `ComputeModel::prefix`).
    pub fn prefix(&self, hotkey: &str) -> u64 {
        mix_prefix(self.seed, hotkey)
    }

    /// The region a hotkey lives in — a pure function of
    /// `(seed, hotkey)`, so it never changes across rounds, leaves, or
    /// rejoins. Always `0` when disabled.
    pub fn region(&self, hotkey: &str) -> usize {
        self.region_from(mix_prefix(self.seed, hotkey))
    }

    /// [`WanModel::region`] keyed by a precomputed [`WanModel::prefix`].
    pub fn region_from(&self, prefix: u64) -> usize {
        if !self.cfg.enabled || self.cfg.n_regions <= 1 {
            return 0;
        }
        (mix_finish(prefix, TAG_REGION) % self.cfg.n_regions as u64) as usize
    }

    /// Shape a peer's link from the base (flat-model) parameters.
    /// Disabled, the base values come back bit-for-bit unchanged — the
    /// degeneracy the scale-invariance suite pins.
    pub fn link_shape(
        &self,
        hotkey: &str,
        up_bps: f64,
        down_bps: f64,
        latency_s: f64,
    ) -> LinkShape {
        self.shape_from(mix_prefix(self.seed, hotkey), up_bps, down_bps, latency_s)
    }

    /// [`WanModel::link_shape`] keyed by a precomputed [`WanModel::prefix`].
    pub fn shape_from(&self, prefix: u64, up_bps: f64, down_bps: f64, latency_s: f64) -> LinkShape {
        if !self.cfg.enabled {
            return LinkShape { up_bps, down_bps, latency_s };
        }
        let up = up_bps * (1.0 - self.cfg.uplink_spread * unit(mix_finish(prefix, TAG_UPLINK)));
        let down =
            down_bps * (1.0 - self.cfg.downlink_spread * unit(mix_finish(prefix, TAG_DOWNLINK)));
        let latency = if self.region_from(prefix) == 0 {
            latency_s
        } else {
            latency_s + self.cfg.inter_region_latency_s
        };
        LinkShape { up_bps: up, down_bps: down, latency_s: latency }
    }

    /// The per-region shared uplink trunks, one FIFO [`Link`] per
    /// region, or an empty vec when trunking is off (disabled model or
    /// `region_uplink_bps == 0`). Trunks have a zero latency floor —
    /// the inter-region hop is already charged on the peer's own link —
    /// so an uncontended trunk only delays a transfer by its
    /// serialization time.
    pub fn trunks(&self) -> Vec<Link> {
        if !self.cfg.enabled || self.cfg.region_uplink_bps <= 0.0 {
            return Vec::new();
        }
        (0..self.cfg.n_regions.max(1))
            .map(|_| Link::new(self.cfg.region_uplink_bps, 0.0))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_cfg() -> WanConfig {
        WanConfig { enabled: true, ..Default::default() }
    }

    #[test]
    fn disabled_model_is_bitwise_degenerate() {
        let m = WanModel::new(0xC0DE, WanConfig::default());
        assert!(!m.enabled());
        assert!(m.trunks().is_empty());
        for hk in ["hk-00000", "hk-00917", "swm-000003"] {
            assert_eq!(m.region(hk), 0);
            let s = m.link_shape(hk, 110e6, 500e6, 0.2);
            assert_eq!(s.up_bps.to_bits(), 110e6f64.to_bits());
            assert_eq!(s.down_bps.to_bits(), 500e6f64.to_bits());
            assert_eq!(s.latency_s.to_bits(), 0.2f64.to_bits());
        }
    }

    #[test]
    fn draws_are_pure_functions_of_seed_and_hotkey() {
        let m = WanModel::new(7, enabled_cfg());
        for i in 0..50 {
            let hk = format!("hk-{i:05}");
            let r = m.region(&hk);
            let s = m.link_shape(&hk, 110e6, 500e6, 0.2);
            // repeat draws, fresh model, and prefix variants all agree
            assert_eq!(r, m.region(&hk));
            assert_eq!(r, WanModel::new(7, enabled_cfg()).region(&hk));
            let p = m.prefix(&hk);
            assert_eq!(r, m.region_from(p));
            let s2 = m.shape_from(p, 110e6, 500e6, 0.2);
            assert_eq!(s.up_bps.to_bits(), s2.up_bps.to_bits());
            assert_eq!(s.down_bps.to_bits(), s2.down_bps.to_bits());
            assert_eq!(s.latency_s.to_bits(), s2.latency_s.to_bits());
            assert!(r < 4);
        }
        // the seed feeds every draw
        let other = WanModel::new(8, enabled_cfg());
        let moved = (0..64).any(|i| {
            let hk = format!("hk-{i:05}");
            other.region(&hk) != m.region(&hk)
        });
        assert!(moved, "a different seed must reshuffle regions");
    }

    #[test]
    fn regions_cover_and_latency_splits_home_vs_remote() {
        let m = WanModel::new(3, enabled_cfg());
        let mut seen = [0usize; 4];
        for i in 0..400 {
            let hk = format!("hk-{i:05}");
            let r = m.region(&hk);
            seen[r] += 1;
            let s = m.link_shape(&hk, 110e6, 500e6, 0.2);
            if r == 0 {
                assert_eq!(s.latency_s.to_bits(), 0.2f64.to_bits(), "home region: no hop");
            } else {
                assert!((s.latency_s - 0.32).abs() < 1e-12, "remote: one WAN hop");
            }
            // spreads bound the multipliers
            assert!(s.up_bps <= 110e6 && s.up_bps >= 0.5 * 110e6);
            assert!(s.down_bps <= 500e6 && s.down_bps >= 0.75 * 500e6);
        }
        assert!(seen.iter().all(|&n| n > 0), "400 hotkeys must cover all 4 regions: {seen:?}");
    }

    #[test]
    fn uplink_spread_is_wider_than_downlink_spread() {
        // asymmetry: the default knobs give uplinks more variance
        let m = WanModel::new(11, enabled_cfg());
        let (mut up_lo, mut down_lo) = (f64::MAX, f64::MAX);
        for i in 0..500 {
            let s = m.link_shape(&format!("hk-{i:05}"), 1.0, 1.0, 0.0);
            up_lo = up_lo.min(s.up_bps);
            down_lo = down_lo.min(s.down_bps);
        }
        assert!(up_lo < 0.55 && up_lo >= 0.5, "uplink floor ~0.5, got {up_lo}");
        assert!(down_lo < 0.80 && down_lo >= 0.75, "downlink floor ~0.75, got {down_lo}");
    }

    #[test]
    fn trunks_exist_only_when_oversubscribed() {
        let mut cfg = enabled_cfg();
        assert!(WanModel::new(1, cfg.clone()).trunks().is_empty());
        cfg.region_uplink_bps = 1e9;
        let trunks = WanModel::new(1, cfg).trunks();
        assert_eq!(trunks.len(), 4);
        assert!(trunks.iter().all(|t| t.bps == 1e9 && t.latency_s == 0.0));
    }

    #[test]
    fn single_region_topology_is_all_home() {
        let cfg = WanConfig { n_regions: 1, ..enabled_cfg() };
        let m = WanModel::new(9, cfg);
        for i in 0..32 {
            assert_eq!(m.region(&format!("hk-{i:05}")), 0);
        }
    }
}
