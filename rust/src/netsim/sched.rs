//! Discrete-event scheduler over the virtual clock.
//!
//! The round engine (`coordinator::network`) no longer advances time with
//! a single compute-window barrier: every peer's compute completion,
//! upload completion, download completion, the round deadline, and chain
//! block boundaries are *events* in a binary-heap queue, popped in
//! monotonically non-decreasing time order. Ties are broken by scheduling
//! sequence number, so the pop order — and therefore the whole simulated
//! timeline — is fully deterministic.
//!
//! The scheduler owns a [`VirtualClock`] cursor that advances to each
//! popped event's timestamp. The round engine uses *detached* cursors
//! (`VirtualClock::at`) per processing wave and only folds the resulting
//! round-end time back into the shared network clock, so simulated wall
//! time stays monotone even when a straggler's upload completes after the
//! next round has conceptually begun (the overlap case).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::clock::VirtualClock;

/// Typed simulation events. `peer` indices refer to the round engine's
/// peer-slot order (stable within a round).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A peer finished its H inner steps (or, for non-computing
    /// behaviours, reached the end of its fabrication window).
    ComputeDone { peer: usize },
    /// A peer's payload upload to its bucket completed. Under
    /// multi-coordinator sharding this is the *final* shard slice
    /// landing (earlier slices emit `ShardUploadDone`), so with one
    /// shard the event stream is unchanged.
    UploadDone { peer: usize },
    /// One shard slice of a peer's payload finished uploading (emitted
    /// for every slice but the last; `n_shards = 1` rounds never see
    /// this event).
    ShardUploadDone { peer: usize, shard: usize },
    /// A shard coordinator's aggregation became ready: the last selected
    /// slice for its chunk range had arrived. The outer step applies
    /// only once every shard has fired this — the cross-shard barrier.
    ShardAggregated { shard: usize },
    /// A peer finished downloading the round's selected payloads.
    DownloadDone { peer: usize },
    /// An adversarial peer's junk slice landed on a targeted shard
    /// coordinator (shard-targeted spam). Injected by the round engine
    /// when the spammer's transfer completes, so attacks are visible in
    /// the event trace alongside honest transfers; the engine takes no
    /// action on it (the submission is rejected by payload auth).
    AdversarySpam { peer: usize, shard: usize },
    /// The round's upload deadline passed; in-flight stalled uploads are
    /// cut off here and yield a `LateUpload` fast-check verdict.
    DeadlineHit,
    /// The chain produced a block (emissions tick).
    ChainBlock { height: u64 },
    /// A shard coordinator's barrier announcement landed on the other
    /// shard hosts (emitted only when the announcement actually costs
    /// time: a stalled host or a nonzero-cost inter-host link — the
    /// degenerate zero-cost single-host config never sees it).
    ShardAnnounce { shard: usize, host: usize },
    /// A simulated shard host died at round start (permanent; injected
    /// by `netsim::faults`). Trace-only: recovery reacts at the
    /// detection timeout, not here.
    HostCrash { host: usize },
    /// A dead host's shard was reassigned: host `from` missed its
    /// barrier announcement past the detection timeout and host `to`
    /// took over the chunk range, rebuilding state from the object
    /// store.
    ShardReassigned { shard: usize, from: usize, to: usize },
    /// A peer's upload of slice `shard` flapped mid-transfer on
    /// `attempt` and will be retried after deterministic exponential
    /// backoff (the final, budget-exhausting flap emits no retry —
    /// the submission is abandoned and fast-checked as
    /// `OrphanedUpload`).
    UploadRetry { peer: usize, shard: usize, attempt: u32 },
}

impl Event {
    /// Stable snake_case name of the event variant, used as the metric
    /// key suffix by the telemetry spine (`sched.event.<kind>`). Pure
    /// and allocation-free, so counting events stays cheap and the
    /// resulting metric names are identical across runs.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::ComputeDone { .. } => "compute_done",
            Event::UploadDone { .. } => "upload_done",
            Event::ShardUploadDone { .. } => "shard_upload_done",
            Event::ShardAggregated { .. } => "shard_aggregated",
            Event::DownloadDone { .. } => "download_done",
            Event::AdversarySpam { .. } => "adversary_spam",
            Event::DeadlineHit => "deadline_hit",
            Event::ChainBlock { .. } => "chain_block",
            Event::ShardAnnounce { .. } => "shard_announce",
            Event::HostCrash { .. } => "host_crash",
            Event::ShardReassigned { .. } => "shard_reassigned",
            Event::UploadRetry { .. } => "upload_retry",
        }
    }
}

#[derive(Debug)]
struct Entry {
    t: f64,
    seq: u64,
    ev: Event,
}

// `seq` is unique per scheduler, so equality on `seq` alone is consistent
// with the (t, seq) ordering below.
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the earliest (t, seq) pops
        // first. total_cmp gives a total order on f64 without NaN panics.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Binary-heap event queue over a monotonically-advancing clock cursor.
#[derive(Debug)]
pub struct Scheduler {
    heap: BinaryHeap<Entry>,
    clock: VirtualClock,
    seq: u64,
    /// Events popped so far (observability).
    pub processed: u64,
}

impl Scheduler {
    /// A scheduler whose cursor starts at `clock.now()`. The clock may be
    /// shared (events then advance the shared time) or detached
    /// ([`VirtualClock::at`]).
    pub fn new(clock: VirtualClock) -> Self {
        Self { heap: BinaryHeap::new(), clock, seq: 0, processed: 0 }
    }

    /// Current cursor time.
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Rewind this scheduler for a fresh wave starting at `t`: drop any
    /// still-queued events (the binary heap keeps its capacity, so a
    /// swarm-scale round reuses one allocation across waves and rounds
    /// instead of building a new heap per wave), detach a new cursor at
    /// `t`, and restart the tie-break sequence. `processed` keeps
    /// accumulating — it is lifetime observability, not wave state.
    pub fn reset(&mut self, t: f64) {
        self.heap.clear();
        self.clock = VirtualClock::at(t);
        self.seq = 0;
    }

    /// Current queue capacity (events the heap can hold without
    /// reallocating) — lets swarm-scale callers assert steady-state
    /// rounds stop growing memory.
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Schedule `ev` at absolute time `t`. Times earlier than the cursor
    /// are clamped to it (an event cannot fire in the past).
    pub fn schedule_at(&mut self, t: f64, ev: Event) {
        assert!(!t.is_nan(), "event time must not be NaN ({ev:?})");
        let t = t.max(self.clock.now());
        self.heap.push(Entry { t, seq: self.seq, ev });
        self.seq += 1;
    }

    /// Schedule `ev` at `dt` seconds after the cursor.
    pub fn schedule_in(&mut self, dt: f64, ev: Event) {
        assert!(dt >= 0.0, "negative event delay ({dt})");
        self.schedule_at(self.clock.now() + dt, ev);
    }

    /// Pop the earliest event, advancing the cursor to its time.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let e = self.heap.pop()?;
        self.clock.advance_to(e.t);
        self.processed += 1;
        Some((e.t, e.ev))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.t)
    }

    /// Number of events still queued.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether the queue has drained.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut s = Scheduler::new(VirtualClock::new());
        s.schedule_at(5.0, Event::DeadlineHit);
        s.schedule_at(1.0, Event::ComputeDone { peer: 0 });
        s.schedule_at(3.0, Event::UploadDone { peer: 0 });
        let order: Vec<f64> = std::iter::from_fn(|| s.pop()).map(|(t, _)| t).collect();
        assert_eq!(order, vec![1.0, 3.0, 5.0]);
        assert_eq!(s.processed, 3);
    }

    #[test]
    fn ties_break_by_schedule_order() {
        let mut s = Scheduler::new(VirtualClock::new());
        s.schedule_at(2.0, Event::ComputeDone { peer: 7 });
        s.schedule_at(2.0, Event::ComputeDone { peer: 3 });
        s.schedule_at(2.0, Event::ComputeDone { peer: 9 });
        let peers: Vec<usize> = std::iter::from_fn(|| s.pop())
            .map(|(_, ev)| match ev {
                Event::ComputeDone { peer } => peer,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(peers, vec![7, 3, 9], "FIFO among simultaneous events");
    }

    #[test]
    fn event_kinds_are_stable_and_distinct() {
        let events = [
            Event::ComputeDone { peer: 0 },
            Event::UploadDone { peer: 0 },
            Event::ShardUploadDone { peer: 0, shard: 0 },
            Event::ShardAggregated { shard: 0 },
            Event::DownloadDone { peer: 0 },
            Event::AdversarySpam { peer: 0, shard: 0 },
            Event::DeadlineHit,
            Event::ChainBlock { height: 0 },
            Event::ShardAnnounce { shard: 0, host: 0 },
            Event::HostCrash { host: 0 },
            Event::ShardReassigned { shard: 0, from: 0, to: 1 },
            Event::UploadRetry { peer: 0, shard: 0, attempt: 1 },
        ];
        let kinds: std::collections::BTreeSet<&str> =
            events.iter().map(|e| e.kind()).collect();
        assert_eq!(kinds.len(), events.len(), "every variant has a distinct kind");
        assert_eq!(Event::DeadlineHit.kind(), "deadline_hit");
        assert_eq!(Event::HostCrash { host: 3 }.kind(), "host_crash");
        // payload fields don't leak into the kind
        assert_eq!(
            Event::ComputeDone { peer: 1 }.kind(),
            Event::ComputeDone { peer: 9 }.kind()
        );
    }

    #[test]
    fn cursor_advances_monotonically() {
        let mut s = Scheduler::new(VirtualClock::at(10.0));
        s.schedule_at(4.0, Event::DeadlineHit); // in the past: clamped
        let (t, _) = s.pop().unwrap();
        assert_eq!(t, 10.0);
        assert_eq!(s.now(), 10.0);
        s.schedule_in(2.5, Event::DeadlineHit);
        let (t, _) = s.pop().unwrap();
        assert_eq!(t, 12.5);
    }

    #[test]
    fn reset_reuses_heap_and_matches_fresh_scheduler() {
        let mut s = Scheduler::new(VirtualClock::at(100.0));
        for i in 0..64 {
            s.schedule_at(100.0 + i as f64, Event::ComputeDone { peer: i });
        }
        while s.pop().is_some() {}
        let cap = s.capacity();
        assert!(cap >= 64);
        // reset rewinds the cursor and keeps the heap allocation
        s.reset(5.0);
        assert_eq!(s.capacity(), cap, "reset must retain heap capacity");
        assert!(s.is_empty());
        assert_eq!(s.now(), 5.0);
        assert_eq!(s.processed, 64, "processed is lifetime, not wave, state");
        // a reset scheduler pops the same (t, seq) order as a fresh one
        let mut fresh = Scheduler::new(VirtualClock::at(5.0));
        for sch in [&mut s, &mut fresh] {
            sch.schedule_at(9.0, Event::ComputeDone { peer: 1 });
            sch.schedule_at(9.0, Event::ComputeDone { peer: 2 });
            sch.schedule_at(6.0, Event::DeadlineHit);
        }
        loop {
            match (s.pop(), fresh.pop()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b),
            }
        }
        // pending events are dropped by reset, not replayed
        s.schedule_at(50.0, Event::DeadlineHit);
        s.reset(0.0);
        assert!(s.pop().is_none());
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut s = Scheduler::new(VirtualClock::new());
        s.schedule_at(1.0, Event::ComputeDone { peer: 0 });
        let (t, _) = s.pop().unwrap();
        assert_eq!(t, 1.0);
        // handler schedules the follow-up event
        s.schedule_at(6.0, Event::UploadDone { peer: 0 });
        s.schedule_at(4.0, Event::ChainBlock { height: 1 });
        assert_eq!(s.peek_time(), Some(4.0));
        let (t, ev) = s.pop().unwrap();
        assert_eq!((t, ev), (4.0, Event::ChainBlock { height: 1 }));
        let (t, ev) = s.pop().unwrap();
        assert_eq!((t, ev), (6.0, Event::UploadDone { peer: 0 }));
        assert!(s.is_empty());
    }
}
