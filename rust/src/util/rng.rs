//! Deterministic PRNG (SplitMix64 core) + distributions.
//!
//! Every stochastic component in the simulation (churn, data sampling,
//! adversarial noise, validator subset selection) draws from a seeded
//! `Rng`, so entire runs — including Figures 4/5/6 — are bit-reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare_normal: None }
    }

    /// Derive an independent stream (e.g. per-peer) from this one.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9);
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * th.sin());
        r * th.cos()
    }

    /// Poisson-distributed count (Knuth for small lambda, normal approx above).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let z = self.normal();
            return (lambda + lambda.sqrt() * z).round().max(0.0) as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher-Yates.
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(11);
        for lambda in [0.5, 3.0, 20.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < 0.1 * lambda + 0.05, "lambda={lambda} mean={mean}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let mut s = r.sample_indices(20, 8);
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
