//! Minimal CLI argument parsing (offline environment; no clap).
//!
//! Supports `command --flag value --bool-flag positional` style:
//! `Args::parse()` splits argv into a subcommand, `--key value` options
//! and bare positionals.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from process argv (skipping argv[0]).
    pub fn parse() -> Self {
        Self::from_vec(std::env::args().skip(1).collect())
    }

    pub fn from_vec(argv: Vec<String>) -> Self {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(key.to_string());
                }
            } else if a.command.is_none() && a.positional.is_empty() {
                a.command = Some(tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{s}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{key} expects a number, got '{s}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{s}'")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = Args::from_vec(v(&["train", "--rounds", "10", "--verbose", "--k=3", "pos1"]));
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("rounds"), Some("10"));
        assert_eq!(a.get("k"), Some("3"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::from_vec(v(&["x", "--n", "5", "--f", "2.5"]));
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(Args::from_vec(v(&["x", "--n", "zzz", "--q", "1"]))
            .get_usize("n", 1)
            .is_err());
    }
}
