//! Small statistics + benchmark-harness helpers (criterion is unavailable
//! offline; `cargo bench` runs our harness=false binaries which use this).

use std::time::Instant;

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
    }
}

pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, 50.0)
}

/// Time one closure invocation in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Benchmark a closure: `warmup` unmeasured runs, then `iters` measured.
pub fn bench(warmup: usize, iters: usize, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Print one benchmark row: name, mean time, throughput (if bytes given).
pub fn report(name: &str, s: &Summary, bytes_per_iter: Option<f64>) {
    let thpt = bytes_per_iter
        .map(|b| format!("  {:>8.1} MB/s", b / s.mean / 1e6))
        .unwrap_or_default();
    println!(
        "{name:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  (n={}){thpt}",
        fmt_time(s.mean),
        fmt_time(s.p50),
        fmt_time(s.p95),
        s.n
    );
}

pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// Render an aligned text table (for the paper-table benches).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for c in 0..ncol {
            widths[c] = widths[c].max(r.get(c).map(|s| s.len()).unwrap_or(0));
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for c in 0..ncol {
            let cell = cells.get(c).cloned().unwrap_or_default();
            s.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
        }
        println!("{}", s.trim_end());
    };
    line(header.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for r in rows {
        line(r.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 95.0), 9.5);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
