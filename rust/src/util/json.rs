//! Minimal JSON parser/serializer backing the manifest/config loaders
//! (predates the crate's serde_json dependency, which the benches use
//! for report emission). Supports the full JSON grammar minus `\u`
//! surrogate pairs beyond the BMP; numbers are f64 (integers round-trip
//! exactly to 2^53, far beyond anything in our manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Object field lookup with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- parse / serialize ------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number '{s}'"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut o = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            o.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": -2.5e3}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -2500.0);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integer_fidelity() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_f64().unwrap(), 9007199254740992.0);
        assert_eq!(v.to_string(), "9007199254740992");
    }

    #[test]
    fn object_roundtrip() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr_usize(&[1, 2, 3])),
            ("s", Json::str("q\"uote")),
        ]);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
