//! In-crate substrate utilities (this environment is offline, so these
//! replace serde/clap/rand/criterion): JSON, deterministic RNG, CLI
//! parsing, stats/bench harness, and a tiny property-test helper.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
