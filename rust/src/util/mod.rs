//! In-crate substrate utilities: JSON, deterministic RNG, CLI parsing,
//! stats/bench harness, and a tiny property-test helper. These replace
//! clap/rand/proptest/criterion (the crate keeps its dependency set to
//! anyhow + rayon + serde); the hand-rolled `json` module predates the
//! serde dependency and still backs the manifest/config loaders.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
