//! Checkpoint I/O: flat parameter vectors as little-endian f32 files with
//! a small header (the paper open-sources intermediate + final checkpoints;
//! ours serve the anneal/SFT pipeline and the examples).
//!
//! Two formats exist:
//!
//! - `CVNTCKPT` — a bare parameter vector ([`save`]/[`load`], with
//!   in-memory twins [`to_bytes`]/[`from_bytes`] used by the shard
//!   coordinators to checkpoint outer-momentum slices into the object
//!   store).
//! - `CVNTSTAT` — a combined training state: the parameter vector plus
//!   the per-shard outer-momentum slices ([`save_state`]/[`load_state`]),
//!   what a resuming or fail-over coordinator needs to continue
//!   bit-identically.
//!
//! Both loaders are hostile-input safe: every length field is
//! bounds-checked (`checked_mul`, explicit remaining-byte checks), so a
//! truncated, corrupt, or adversarial file is always a clean `Err`, never
//! a panic or an absurd allocation. `tests` pin this.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

const MAGIC: &[u8; 8] = b"CVNTCKPT";
const STATE_MAGIC: &[u8; 8] = b"CVNTSTAT";

/// Serialize a flat parameter vector to checkpoint bytes.
pub fn to_bytes(params: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + params.len() * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(params.len() as u64).to_le_bytes());
    for x in params {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Take `n` bytes off the front of `rest`, or a clean `Err`.
fn take<'a>(rest: &mut &'a [u8], n: usize, what: &str) -> Result<&'a [u8]> {
    ensure!(rest.len() >= n, "checkpoint truncated reading {what}: {} < {n} bytes", rest.len());
    let (head, tail) = rest.split_at(n);
    *rest = tail;
    Ok(head)
}

/// Read a u64 length field and the f32 vector it describes.
fn take_f32_vec(rest: &mut &[u8], what: &str) -> Result<Vec<f32>> {
    let lenb = take(rest, 8, what)?;
    let n = u64::from_le_bytes(lenb.try_into().unwrap());
    // A hostile length field must not overflow the byte-count math (a
    // debug-build panic) or trigger an absurd allocation: check against
    // what is actually present before allocating anything.
    let need = n
        .checked_mul(4)
        .filter(|&b| b <= rest.len() as u64)
        .ok_or_else(|| anyhow::anyhow!("checkpoint {what} length {n} exceeds file size"))?
        as usize;
    let bytes = take(rest, need, what)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Parse checkpoint bytes back into a flat parameter vector
/// (bit-identical round trip with [`to_bytes`]).
pub fn from_bytes(bytes: &[u8]) -> Result<Vec<f32>> {
    let mut rest = bytes;
    let magic = take(&mut rest, 8, "magic")?;
    if magic != MAGIC {
        bail!("not a covenant checkpoint (bad magic)");
    }
    let params = take_f32_vec(&mut rest, "params")?;
    ensure!(rest.is_empty(), "checkpoint has {} trailing bytes", rest.len());
    Ok(params)
}

/// Save a flat parameter vector.
pub fn save(path: impl AsRef<Path>, params: &[f32]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&to_bytes(params))?;
    Ok(())
}

/// Load a flat parameter vector.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Serialize combined training state: the parameter vector plus the
/// per-shard outer-momentum slices (in shard order).
pub fn state_to_bytes(params: &[f32], momentum: &[&[f32]]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(STATE_MAGIC);
    out.extend_from_slice(&(params.len() as u64).to_le_bytes());
    for x in params {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out.extend_from_slice(&(momentum.len() as u64).to_le_bytes());
    for m in momentum {
        out.extend_from_slice(&(m.len() as u64).to_le_bytes());
        for x in *m {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    out
}

/// Parse combined training state (bit-identical round trip with
/// [`state_to_bytes`]). Returns `(params, momentum slices)`.
pub fn state_from_bytes(bytes: &[u8]) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
    let mut rest = bytes;
    let magic = take(&mut rest, 8, "magic")?;
    if magic != STATE_MAGIC {
        bail!("not a covenant state checkpoint (bad magic)");
    }
    let params = take_f32_vec(&mut rest, "params")?;
    let nsb = take(&mut rest, 8, "slice count")?;
    let n_slices = u64::from_le_bytes(nsb.try_into().unwrap());
    // Each slice needs at least its 8-byte length header.
    ensure!(
        n_slices.checked_mul(8).is_some_and(|b| b <= rest.len() as u64),
        "state checkpoint slice count {n_slices} exceeds file size"
    );
    let mut momentum = Vec::with_capacity(n_slices as usize);
    for s in 0..n_slices {
        momentum.push(take_f32_vec(&mut rest, &format!("momentum slice {s}"))?);
    }
    ensure!(rest.is_empty(), "state checkpoint has {} trailing bytes", rest.len());
    Ok((params, momentum))
}

/// Save combined training state (params + per-shard momentum slices).
pub fn save_state(path: impl AsRef<Path>, params: &[f32], momentum: &[&[f32]]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, state_to_bytes(params, momentum))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Load combined training state. Returns `(params, momentum slices)`.
pub fn load_state(path: impl AsRef<Path>) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("opening state checkpoint {}", path.display()))?;
    state_from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize, seed: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.5 - 3.0) * (1.0 + seed)).collect()
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("covenant-ckpt-test");
        let path = dir.join("p.ckpt");
        let params = params(1000, 0.0);
        save(&path, &params).unwrap();
        assert_eq!(load(&path).unwrap(), params);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("covenant-ckpt-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bytes_roundtrip_is_bit_identical() {
        // Includes awkward values: -0.0, subnormals, inf, NaN payloads
        // must all survive byte-for-byte.
        let mut p = params(257, 1.0);
        p.extend_from_slice(&[-0.0, f32::MIN_POSITIVE / 2.0, f32::INFINITY, f32::NAN]);
        let back = from_bytes(&to_bytes(&p)).unwrap();
        assert_eq!(back.len(), p.len());
        for (a, b) in p.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(from_bytes(&to_bytes(&[])).unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn state_roundtrip_is_bit_identical() {
        let dir = std::env::temp_dir().join("covenant-ckpt-test3");
        let path = dir.join("s.ckpt");
        let p = params(300, 2.0);
        let m0 = params(100, 3.0);
        let m1 = params(200, 4.0);
        save_state(&path, &p, &[&m0, &m1]).unwrap();
        let (p2, m2) = load_state(&path).unwrap();
        assert_eq!(p2, p);
        assert_eq!(m2, vec![m0, m1]);
        // no momentum slices is a valid state (momentum off)
        let (p3, m3) = state_from_bytes(&state_to_bytes(&p, &[])).unwrap();
        assert_eq!(p3, p);
        assert!(m3.is_empty());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_and_corrupt_files_err_cleanly() {
        // Every prefix of a valid checkpoint must be a clean Err (except
        // the full file); same for the combined state format.
        let p = params(10, 0.0);
        let ck = to_bytes(&p);
        for cut in 0..ck.len() {
            assert!(from_bytes(&ck[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        let st = state_to_bytes(&p, &[&p[..4], &p[4..]]);
        for cut in 0..st.len() {
            assert!(state_from_bytes(&st[..cut]).is_err(), "state prefix of {cut} bytes accepted");
        }
        // trailing junk is also rejected
        let mut long = ck.clone();
        long.push(0);
        assert!(from_bytes(&long).is_err());
        // wrong magic for the right shape
        let mut swapped = st.clone();
        swapped[..8].copy_from_slice(MAGIC);
        assert!(state_from_bytes(&swapped).is_err());
    }

    #[test]
    fn hostile_length_fields_never_panic() {
        // A length field of u64::MAX must not overflow the `n * 4`
        // byte-count math or attempt a huge allocation.
        let mut evil = Vec::new();
        evil.extend_from_slice(MAGIC);
        evil.extend_from_slice(&u64::MAX.to_le_bytes());
        evil.extend_from_slice(&[0u8; 64]);
        assert!(from_bytes(&evil).is_err());
        // Same for the state format's slice count and slice lengths.
        let mut evil = Vec::new();
        evil.extend_from_slice(STATE_MAGIC);
        evil.extend_from_slice(&0u64.to_le_bytes()); // empty params
        evil.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd slice count
        assert!(state_from_bytes(&evil).is_err());
        let mut evil = Vec::new();
        evil.extend_from_slice(STATE_MAGIC);
        evil.extend_from_slice(&0u64.to_le_bytes());
        evil.extend_from_slice(&1u64.to_le_bytes());
        evil.extend_from_slice(&(u64::MAX / 2).to_le_bytes()); // absurd slice len
        assert!(state_from_bytes(&evil).is_err());
    }
}
