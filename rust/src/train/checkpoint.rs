//! Checkpoint I/O: flat parameter vectors as little-endian f32 files with
//! a small header (the paper open-sources intermediate + final checkpoints;
//! ours serve the anneal/SFT pipeline and the examples).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

const MAGIC: &[u8; 8] = b"CVNTCKPT";

/// Save a flat parameter vector.
pub fn save(path: impl AsRef<Path>, params: &[f32]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    // bulk write
    let bytes: Vec<u8> = params.iter().flat_map(|x| x.to_le_bytes()).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Load a flat parameter vector.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a covenant checkpoint", path.display());
    }
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)?;
    let n = u64::from_le_bytes(lenb) as usize;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    ensure!(bytes.len() == n * 4, "checkpoint truncated: {} != {}", bytes.len(), n * 4);
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("covenant-ckpt-test");
        let path = dir.join("p.ckpt");
        let params: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect();
        save(&path, &params).unwrap();
        assert_eq!(load(&path).unwrap(), params);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("covenant-ckpt-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
