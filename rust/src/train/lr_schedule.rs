//! Learning-rate schedules — exact reproduction of the paper's Figure 2.
//!
//! Pre-training inner LR (§4.1): linear warmup of 1,500 inner steps to
//! 1.2e-4, cosine decay toward 1.2e-5, *flattened* for 13,500 steps around
//! the 80k mark (lower-than-planned participation required a longer
//! horizon), then resumed decay; an annealing tail re-warms and rapidly
//! decays on the high-quality mixture. The outer LR alpha is 1.0, dropped
//! to 0.65 at 110k inner steps when metrics plateaued. SFT (§5) uses a 4k
//! cosine stage then an 8k warmup/cosine-then-linear stage.
//!
//! `Schedule` is a piecewise combinator; every paper schedule is a
//! constructor, and each can be *scaled* to our shorter runs while
//! preserving the shape (same fractions of total).

/// One schedule segment over `steps` inner steps.
#[derive(Debug, Clone, Copy)]
pub enum Segment {
    /// Linear from `from` to `to`.
    Linear { from: f64, to: f64, steps: usize },
    /// Cosine from `from` to `to` (half period).
    Cosine { from: f64, to: f64, steps: usize },
    /// Constant hold.
    Constant { lr: f64, steps: usize },
}

impl Segment {
    pub fn steps(&self) -> usize {
        match *self {
            Segment::Linear { steps, .. }
            | Segment::Cosine { steps, .. }
            | Segment::Constant { steps, .. } => steps,
        }
    }

    fn at(&self, i: usize) -> f64 {
        match *self {
            Segment::Linear { from, to, steps } => {
                let t = i as f64 / steps.max(1) as f64;
                from + (to - from) * t
            }
            Segment::Cosine { from, to, steps } => {
                let t = i as f64 / steps.max(1) as f64;
                to + (from - to) * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
            }
            Segment::Constant { lr, .. } => lr,
        }
    }

    fn end(&self) -> f64 {
        match *self {
            Segment::Linear { to, .. } => to,
            Segment::Cosine { to, .. } => to,
            Segment::Constant { lr, .. } => lr,
        }
    }
}

/// Piecewise schedule.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub segments: Vec<Segment>,
}

impl Schedule {
    pub fn new(segments: Vec<Segment>) -> Self {
        Self { segments }
    }

    pub fn total_steps(&self) -> usize {
        self.segments.iter().map(|s| s.steps()).sum()
    }

    /// LR at inner step `step` (clamps to the final value afterwards).
    pub fn lr(&self, step: usize) -> f64 {
        let mut s = step;
        for seg in &self.segments {
            if s < seg.steps() {
                return seg.at(s);
            }
            s -= seg.steps();
        }
        self.segments.last().map(|seg| seg.end()).unwrap_or(0.0)
    }

    /// LRs for a whole round starting at `step0` (input to train_round).
    pub fn round_lrs(&self, step0: usize, h: usize) -> Vec<f32> {
        (0..h).map(|i| self.lr(step0 + i) as f32).collect()
    }

    // --------------------------------------------------------------------
    // Paper constructors (Figure 2)
    // --------------------------------------------------------------------

    /// Pre-training inner LR at full paper scale (inner steps).
    ///
    /// warmup 1,500 -> 1.2e-4; cosine toward 1.2e-5 with the decay split at
    /// 80k by a 13,500-step flat window; resumed decay to ~1.2e-5 at the
    /// pre-anneal point (~180.5k); anneal tail: re-warm to 4e-5 then decay
    /// to ~0 over the final ~2.7k steps (90 outer steps of H=30).
    pub fn covenant_pretrain() -> Self {
        Self::covenant_pretrain_scaled(1.0)
    }

    /// Same shape compressed by `scale` (scale=1.0 is the paper's 183.3k
    /// inner steps; scale=0.01 gives a 1.8k-step run with identical
    /// fractions). LR magnitudes are preserved.
    pub fn covenant_pretrain_scaled(scale: f64) -> Self {
        let s = |x: f64| ((x * scale).round() as usize).max(1);
        let peak = 1.2e-4;
        let floor = 1.2e-5;
        let warmup = s(1500.0);
        // Cosine planned over the original horizon; flatten at 80k for
        // 13.5k steps. We model it as: cosine part 1 (80k-1.5k steps of a
        // 165k-step cosine), hold, cosine part 2 (remaining).
        let cos_total = s(165_000.0);
        let part1 = s(78_500.0);
        let hold_steps = s(13_500.0);
        let part2 = cos_total - part1;
        // LR value where the flatten begins:
        let frac1 = part1 as f64 / cos_total as f64;
        let lr_at_flat =
            floor + (peak - floor) * 0.5 * (1.0 + (std::f64::consts::PI * frac1).cos());
        let anneal_warm = s(300.0);
        let anneal_decay = s(2_400.0);
        Schedule::new(vec![
            Segment::Linear { from: 0.0, to: peak, steps: warmup },
            Segment::Cosine { from: peak, to: lr_at_flat, steps: part1 },
            Segment::Constant { lr: lr_at_flat, steps: hold_steps },
            Segment::Cosine { from: lr_at_flat, to: floor, steps: part2 },
            // Annealing phase (§4.1): warm up and rapidly decay on HQ data.
            Segment::Linear { from: floor, to: 4e-5, steps: anneal_warm },
            Segment::Cosine { from: 4e-5, to: 1e-6, steps: anneal_decay },
        ])
    }

    /// SFT stage 1 (4k context): 3% warmup then cosine spanning 1.5 epochs
    /// (stage stops at 36,500 of the 80,514-step cosine -> ends ~2.97e-6).
    pub fn sft_stage1() -> Self {
        Self::sft_stage1_scaled(1.0)
    }

    pub fn sft_stage1_scaled(scale: f64) -> Self {
        let s = |x: f64| ((x * scale).round() as usize).max(1);
        let peak = 5e-6;
        let span = s(80_514.0); // 1.5 epochs
        let warmup = (span as f64 * 0.03).round() as usize;
        Schedule::new(vec![
            Segment::Linear { from: 0.0, to: peak, steps: warmup },
            Segment::Cosine { from: peak, to: 0.0, steps: span - warmup },
        ])
    }

    /// Steps actually run in stage 1 (68% of one epoch = 36,500 at scale 1).
    pub fn sft_stage1_run_steps(scale: f64) -> usize {
        ((36_500.0 * scale).round() as usize).max(1)
    }

    /// SFT stage 2 (8k context + 20% replay): warmup 25 steps from the
    /// stage-1 handoff (~2.97e-6) to 3.57e-6, cosine to step 10,100, then
    /// linear to zero over the remaining 10,400 (20,500 total).
    pub fn sft_stage2() -> Self {
        Self::sft_stage2_scaled(1.0)
    }

    pub fn sft_stage2_scaled(scale: f64) -> Self {
        let s = |x: f64| ((x * scale).round() as usize).max(1);
        let handoff = 2.97e-6;
        let peak = 3.57e-6;
        let warmup = s(25.0);
        let cos = s(10_100.0) - warmup;
        let lin = s(10_400.0);
        // cosine is cut at 10,100 of a notional longer horizon; model the
        // value reached there as 60% of peak then linear to zero.
        let cut = 0.6 * peak;
        Schedule::new(vec![
            Segment::Linear { from: handoff, to: peak, steps: warmup },
            Segment::Cosine { from: peak, to: cut, steps: cos },
            Segment::Linear { from: cut, to: 0.0, steps: lin },
        ])
    }

    /// Emit a CSV series (step, lr) sampled every `stride` steps.
    pub fn to_csv(&self, stride: usize) -> String {
        let mut out = String::from("step,lr\n");
        let total = self.total_steps();
        let mut s = 0;
        while s <= total {
            out.push_str(&format!("{s},{:.6e}\n", self.lr(s)));
            s += stride;
        }
        out
    }
}

/// The outer (Nesterov-free SGD) LR alpha over *outer* rounds:
/// 1.0, dropped to 0.65 at the plateau (110k inner steps = round 3,667 at
/// H=30; paper §4.1).
#[derive(Debug, Clone)]
pub struct OuterAlphaSchedule {
    pub initial: f64,
    pub dropped: f64,
    /// Inner-step index of the drop.
    pub drop_at_inner_step: usize,
    pub inner_steps_per_round: usize,
}

impl OuterAlphaSchedule {
    pub fn paper(h: usize) -> Self {
        Self { initial: 1.0, dropped: 0.65, drop_at_inner_step: 110_000, inner_steps_per_round: h }
    }

    pub fn scaled(scale: f64, h: usize) -> Self {
        Self {
            initial: 1.0,
            dropped: 0.65,
            drop_at_inner_step: ((110_000.0 * scale).round() as usize).max(1),
            inner_steps_per_round: h,
        }
    }

    pub fn alpha(&self, round: usize) -> f64 {
        if round * self.inner_steps_per_round >= self.drop_at_inner_step {
            self.dropped
        } else {
            self.initial
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_knot_values() {
        let s = Schedule::covenant_pretrain();
        // warmup endpoint
        assert!((s.lr(1500) - 1.2e-4).abs() < 1e-9);
        assert!(s.lr(0) < 1e-7);
        // flatten window: constant between 80k and 93.5k
        let a = s.lr(81_000);
        let b = s.lr(92_000);
        assert!((a - b).abs() < 1e-12, "flat window not flat: {a} vs {b}");
        // near the floor just before anneal
        let pre_anneal = 1500 + 78_500 + 13_500 + (165_000 - 78_500);
        let v = s.lr(pre_anneal - 1);
        assert!((v - 1.2e-5).abs() < 1e-6, "floor = {v}");
        // anneal re-warms above floor then decays below it
        let warm_peak = s.lr(pre_anneal + 300);
        assert!(warm_peak > 3.9e-5);
        let end = s.lr(s.total_steps());
        assert!(end <= 1.1e-6);
    }

    #[test]
    fn monotone_decay_outside_warmup_and_flat() {
        let s = Schedule::covenant_pretrain();
        // cosine part 1 strictly decreasing
        assert!(s.lr(10_000) > s.lr(40_000));
        assert!(s.lr(40_000) > s.lr(79_000));
        // after flatten, resumes decreasing
        assert!(s.lr(95_000) > s.lr(150_000));
    }

    #[test]
    fn continuity_at_segment_joints() {
        for sc in [Schedule::covenant_pretrain(), Schedule::sft_stage1(), Schedule::sft_stage2()] {
            let mut boundary = 0usize;
            for seg in &sc.segments[..sc.segments.len() - 1] {
                boundary += seg.steps();
                let before = sc.lr(boundary - 1);
                let after = sc.lr(boundary);
                // Allow the anneal re-warm jump only where slope changes
                // smoothly; max step-to-step change bounded by warmup slope.
                assert!(
                    (after - before).abs() < 2e-7,
                    "jump at {boundary}: {before} -> {after}"
                );
            }
        }
    }

    #[test]
    fn scaled_preserves_shape() {
        let full = Schedule::covenant_pretrain();
        let small = Schedule::covenant_pretrain_scaled(0.01);
        let ft = full.total_steps() as f64;
        let st = small.total_steps() as f64;
        for frac in [0.05, 0.3, 0.55, 0.85, 0.99] {
            let a = full.lr((ft * frac) as usize);
            let b = small.lr((st * frac) as usize);
            assert!((a - b).abs() < 0.15 * a.max(1e-9), "shape drift at {frac}: {a} vs {b}");
        }
    }

    #[test]
    fn sft_stage1_handoff_matches_paper() {
        // §5: stage-1 cosine leaves off at ~2.97e-6 after 36,500 steps.
        let s = Schedule::sft_stage1();
        let v = s.lr(Schedule::sft_stage1_run_steps(1.0));
        assert!((v - 2.97e-6).abs() < 0.1e-6, "handoff = {v:e}");
    }

    #[test]
    fn sft_stage2_ends_at_zero() {
        let s = Schedule::sft_stage2();
        assert_eq!(s.total_steps(), 20_500);
        assert!(s.lr(20_500) < 1e-12);
        // warmup peak
        assert!((s.lr(25) - 3.57e-6).abs() < 1e-9);
    }

    #[test]
    fn outer_alpha_drop() {
        let a = OuterAlphaSchedule::paper(30);
        assert_eq!(a.alpha(0), 1.0);
        assert_eq!(a.alpha(3_666), 1.0);
        assert_eq!(a.alpha(3_667), 0.65); // 3667*30 = 110,010 >= 110k
    }

    #[test]
    fn round_lrs_match_pointwise() {
        let s = Schedule::covenant_pretrain();
        let lrs = s.round_lrs(1000, 30);
        for (i, &lr) in lrs.iter().enumerate() {
            assert!((lr as f64 - s.lr(1000 + i)).abs() < 1e-10);
        }
    }

    #[test]
    fn csv_emission() {
        let s = Schedule::sft_stage2();
        let csv = s.to_csv(5000);
        assert!(csv.starts_with("step,lr\n"));
        assert!(csv.lines().count() >= 4);
    }
}
