//! Training layer: LR schedules (Fig. 2), the single-process trainer
//! driver used by baselines/benches, and the anneal + SFT stages.

pub mod checkpoint;
pub mod lr_schedule;
pub mod trainer;

pub use lr_schedule::{OuterAlphaSchedule, Schedule, Segment};
pub use trainer::Trainer;
