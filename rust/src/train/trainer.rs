//! Single-replica trainer: owns one model replica's full state (params +
//! inner AdamW moments) and drives inner steps/rounds through the engine.
//!
//! Used by every simulated peer, by the centralized AdamW baseline
//! (Table 1), and by the anneal/SFT stages.

use anyhow::Result;

use super::checkpoint;
use crate::runtime::{ops, Engine};

/// One replica's training state.
pub struct Trainer<'e> {
    pub eng: &'e Engine,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Global inner-step counter (drives Adam bias correction + schedule).
    pub inner_step: usize,
    /// Gradient clip (0 disables; SFT uses 1.0 per §5).
    pub clip: f32,
}

impl<'e> Trainer<'e> {
    /// Fresh replica from the deterministic initializer.
    pub fn new(eng: &'e Engine, seed: i32) -> Result<Self> {
        let params = ops::init_params(eng, seed)?;
        Ok(Self::from_params(eng, params))
    }

    /// Replica starting from existing parameters (peer join / SFT).
    pub fn from_params(eng: &'e Engine, params: Vec<f32>) -> Self {
        let n = params.len();
        Trainer { eng, params, m: vec![0.0; n], v: vec![0.0; n], inner_step: 0, clip: 0.0 }
    }

    /// Reset optimizer state (fresh inner optimizer after a phase switch).
    pub fn reset_optimizer(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.inner_step = 0;
    }

    /// Overwrite parameters (outer sync) keeping optimizer state — exactly
    /// what SparseLoCo peers do after the outer step.
    pub fn set_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }

    /// One inner step (in place — no state cloning). Returns the loss.
    pub fn step(&mut self, tokens: &[i32], mask: &[f32], lr: f32) -> Result<f32> {
        let loss = ops::train_step_in_place(
            self.eng,
            &mut self.params,
            &mut self.m,
            &mut self.v,
            (self.inner_step + 1) as f32,
            tokens,
            mask,
            lr,
            self.clip,
        )?;
        self.inner_step += 1;
        Ok(loss)
    }

    /// One fused H-step round (the compute phase, in place). Returns
    /// per-step losses.
    pub fn round(&mut self, tokens: &[i32], mask: &[f32], lrs: &[f32]) -> Result<Vec<f32>> {
        let h = lrs.len();
        let losses = ops::train_round_in_place(
            self.eng,
            &mut self.params,
            &mut self.m,
            &mut self.v,
            self.inner_step as f32,
            tokens,
            mask,
            lrs,
            self.clip,
        )?;
        self.inner_step += h;
        Ok(losses)
    }

    /// Evaluate mean loss on a batch without touching state.
    pub fn eval(&self, tokens: &[i32], mask: &[f32]) -> Result<f32> {
        ops::eval_loss(self.eng, &self.params, tokens, mask)
    }

    /// Save this replica's parameters as a checkpoint file.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        checkpoint::save(path, &self.params)
    }

    /// Replica resumed from a checkpoint file (fresh inner optimizer —
    /// SparseLoCo peers do not checkpoint inner moments; the bit-exact
    /// resume surface is the *outer* state, see
    /// [`checkpoint::save_state`]).
    pub fn from_checkpoint(eng: &'e Engine, path: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self::from_params(eng, checkpoint::load(path)?))
    }
}
