//! SparseLoCo on the Rust side: the wire codec for compressed
//! pseudo-gradients (12-bit indices + 2-bit values + per-chunk scales,
//! paper §2.1), the chunk-parallel Top-k compressor with fused error
//! feedback, and the dense scatter hot path the aggregator builds on.
//! Compression, encode and decode all fan out across the rayon pool for
//! large payloads while staying bit-identical to their serial paths.

pub mod codec;
pub mod envelope;
pub mod payload;
pub mod quant;
pub mod topk;

pub use payload::Payload;
pub use quant::{dequant_level, quantize_value};
