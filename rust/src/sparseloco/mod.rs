//! SparseLoCo on the Rust side: the wire codec for compressed
//! pseudo-gradients (12-bit indices + 2-bit values + per-chunk scales,
//! paper §2.1), a reference chunk-wise Top-k compressor (used by tests and
//! by simulated adversarial peers that don't run the XLA path), and the
//! dense scatter/aggregation hot path.

pub mod codec;
pub mod payload;
pub mod quant;
pub mod topk;

pub use payload::Payload;
pub use quant::{dequant_level, quantize_value};
