//! Wire codec for compressed pseudo-gradients.
//!
//! The paper (§2.1) transmits, per selected value, a 12-bit chunk-local
//! index and a 2-bit quantized value (14 bits/value), plus one f32 scale
//! per chunk — reaching >146x compression vs dense f32 pseudo-gradients
//! while staying within 2x of the 7.36-bit/value information-theoretic
//! index bound without any entropy coder.
//!
//! Wire layout (little-endian):
//!   magic  "CVPG"        4 B
//!   version u16          2 B
//!   k, log2(chunk) u8    2 B
//!   n_chunks u32         4 B
//!   scales   n_chunks * f32
//!   codes    ceil(n_chunks*k/4)  (2 bits each, packed 4/byte)
//!   indices  ceil(n_chunks*k*12/8)  (12 bits each, packed)

use anyhow::{bail, ensure, Result};

use super::payload::Payload;

const MAGIC: &[u8; 4] = b"CVPG";
const VERSION: u16 = 1;

/// Paper accounting: bits per transmitted value for indices.
pub const INDEX_BITS: usize = 12;
/// Bits per transmitted value for the quantized magnitude.
pub const VALUE_BITS: usize = 2;

/// Serialize a payload to wire bytes.
pub fn encode(p: &Payload) -> Vec<u8> {
    let nv = p.n_values();
    let mut out = Vec::with_capacity(wire_size(p.n_chunks, p.k));
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(p.k as u8);
    out.push(p.chunk.trailing_zeros() as u8);
    out.extend_from_slice(&(p.n_chunks as u32).to_le_bytes());
    for &s in &p.scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    // 2-bit codes, 4 per byte.
    let mut byte = 0u8;
    for (i, &c) in p.codes.iter().enumerate() {
        byte |= (c & 3) << ((i % 4) * 2);
        if i % 4 == 3 {
            out.push(byte);
            byte = 0;
        }
    }
    if nv % 4 != 0 {
        out.push(byte);
    }
    // 12-bit indices: pack pairs into 3 bytes.
    let mut i = 0;
    while i + 1 < nv {
        let a = p.idx[i] as u32;
        let b = p.idx[i + 1] as u32;
        let packed = a | (b << 12); // 24 bits
        out.push((packed & 0xFF) as u8);
        out.push(((packed >> 8) & 0xFF) as u8);
        out.push(((packed >> 16) & 0xFF) as u8);
        i += 2;
    }
    if i < nv {
        let a = p.idx[i] as u32;
        out.push((a & 0xFF) as u8);
        out.push(((a >> 8) & 0xFF) as u8);
    }
    out
}

/// Deserialize wire bytes.
pub fn decode(bytes: &[u8]) -> Result<Payload> {
    ensure!(bytes.len() >= 12, "wire payload too short");
    ensure!(&bytes[0..4] == MAGIC, "bad magic");
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    ensure!(version == VERSION, "unsupported wire version {version}");
    let k = bytes[6] as usize;
    let chunk_log2 = bytes[7] as usize;
    ensure!(chunk_log2 <= 12, "chunk too large for 12-bit indices");
    let chunk = 1usize << chunk_log2;
    ensure!(k >= 1 && k <= chunk, "bad k {k}");
    let n_chunks = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
    let nv = n_chunks * k;
    let scales_end = 12 + n_chunks * 4;
    let codes_len = nv.div_ceil(4);
    let codes_end = scales_end + codes_len;
    let idx_len = (nv / 2) * 3 + if nv % 2 == 1 { 2 } else { 0 };
    let total = codes_end + idx_len;
    if bytes.len() != total {
        bail!("wire payload length {} != expected {}", bytes.len(), total);
    }
    let mut scales = Vec::with_capacity(n_chunks);
    for c in 0..n_chunks {
        let o = 12 + c * 4;
        scales.push(f32::from_le_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]]));
    }
    let mut codes = Vec::with_capacity(nv);
    for i in 0..nv {
        let b = bytes[scales_end + i / 4];
        codes.push((b >> ((i % 4) * 2)) & 3);
    }
    let mut idx = Vec::with_capacity(nv);
    let mut i = 0;
    let mut o = codes_end;
    while i + 1 < nv {
        let packed =
            bytes[o] as u32 | ((bytes[o + 1] as u32) << 8) | ((bytes[o + 2] as u32) << 16);
        idx.push((packed & 0xFFF) as u16);
        idx.push(((packed >> 12) & 0xFFF) as u16);
        o += 3;
        i += 2;
    }
    if i < nv {
        let a = bytes[o] as u32 | ((bytes[o + 1] as u32) << 8);
        idx.push((a & 0xFFF) as u16);
    }
    let p = Payload { n_chunks, k, chunk, idx, codes, scales };
    p.validate(n_chunks, k, chunk)?;
    Ok(p)
}

/// Exact wire size in bytes for a payload geometry.
pub fn wire_size(n_chunks: usize, k: usize) -> usize {
    let nv = n_chunks * k;
    12 + n_chunks * 4 + nv.div_ceil(4) + (nv / 2) * 3 + if nv % 2 == 1 { 2 } else { 0 }
}

/// Wire bits per transmitted value (paper's 12 + 2 = 14 plus amortized
/// scale + header overhead).
pub fn bits_per_value(n_chunks: usize, k: usize) -> f64 {
    wire_size(n_chunks, k) as f64 * 8.0 / (n_chunks * k) as f64
}

/// Compression ratio vs dense f32 of the full flat vector.
pub fn compression_ratio(n_alloc: usize, n_chunks: usize, k: usize) -> f64 {
    (n_alloc * 4) as f64 / wire_size(n_chunks, k) as f64
}

/// The paper's own accounting (§2.1/§4.1): index+value bits only, ignoring
/// scales/header -> 32 / ((k/C) * 14) = 146.29x for C=4096, k=64.
pub fn paper_compression_ratio(chunk: usize, k: usize) -> f64 {
    32.0 / ((k as f64 / chunk as f64) * (INDEX_BITS + VALUE_BITS) as f64)
}

/// Information-theoretic lower bound on index bits/value:
/// log2(C(chunk, k)) / k (paper: ~7.36 for C=4096, k=64).
pub fn index_bits_lower_bound(chunk: usize, k: usize) -> f64 {
    // log2(C(n, k)) via lgamma.
    fn lgamma(x: f64) -> f64 {
        // Stirling series; exact enough for n <= 2^20.
        if x < 10.0 {
            // ln((x+5)!) - sum ln(x..x+5)
            let mut acc = 0.0;
            let mut y = x;
            while y < 10.0 {
                acc -= y.ln();
                y += 1.0;
            }
            return acc + lgamma(y);
        }
        0.5 * ((2.0 * std::f64::consts::PI).ln() - x.ln())
            + x * ((x + 1.0 / (12.0 * x - 1.0 / (10.0 * x))).ln() - 1.0)
    }
    let n = chunk as f64;
    let kk = k as f64;
    let log2e = std::f64::consts::LOG2_E;
    (lgamma(n + 1.0) - lgamma(kk + 1.0) - lgamma(n - kk + 1.0)) * log2e / kk
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparseloco::topk::compress_dense;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_payload(rng: &mut Rng, n_chunks: usize, k: usize, chunk: usize) -> Payload {
        let mut idx = Vec::new();
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        for _ in 0..n_chunks {
            let sel = rng.sample_indices(chunk, k);
            for &s in &sel {
                idx.push(s as u16);
                codes.push(rng.below(4) as u8);
            }
            scales.push(rng.f32() * 2.0);
        }
        Payload { n_chunks, k, chunk, idx, codes, scales }
    }

    #[test]
    fn roundtrip_simple() {
        let mut rng = Rng::new(1);
        let p = random_payload(&mut rng, 7, 5, 64);
        let bytes = encode(&p);
        assert_eq!(bytes.len(), wire_size(7, 5));
        let q = decode(&bytes).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn roundtrip_property() {
        check(
            60,
            |r| {
                let n_chunks = r.range(1, 40);
                let k = r.range(1, 17);
                let chunk = 1usize << r.range(5, 13); // 32..4096
                let k = k.min(chunk);
                random_payload(r, n_chunks, k, chunk)
            },
            |p| {
                let q = decode(&encode(p)).unwrap();
                *p == q
            },
        );
    }

    #[test]
    fn paper_geometry_bits_per_value() {
        // C=4096, k=64: 14 bits/value + 32/64 scale bits + header.
        let bpv = bits_per_value(3080, 64); // ~12.6M-param model
        assert!(bpv > 14.0 && bpv < 14.6, "bits/value = {bpv}");
    }

    #[test]
    fn paper_compression_claims() {
        // §2.1: >146x with the paper's accounting.
        let r = paper_compression_ratio(4096, 64);
        assert!((r - 146.29).abs() < 0.1, "r = {r}");
        // Full-wire ratio is slightly lower but still > 140x.
        let full = compression_ratio(3080 * 4096, 3080, 64);
        assert!(full > 140.0 && full < 146.3, "full = {full}");
    }

    #[test]
    fn index_bound_is_7_36_bits() {
        let b = index_bits_lower_bound(4096, 64);
        assert!((b - 7.36).abs() < 0.05, "bound = {b}");
    }

    #[test]
    fn rejects_corrupt() {
        let mut rng = Rng::new(2);
        let p = random_payload(&mut rng, 3, 4, 64);
        let mut bytes = encode(&p);
        assert!(decode(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(decode(&bytes).is_err()); // bad magic
        let mut b2 = encode(&p);
        b2.push(0);
        assert!(decode(&b2).is_err()); // trailing garbage
    }

    #[test]
    fn odd_value_count_roundtrip() {
        let mut rng = Rng::new(3);
        let p = random_payload(&mut rng, 3, 3, 32); // 9 values (odd)
        assert_eq!(decode(&encode(&p)).unwrap(), p);
    }

    #[test]
    fn roundtrip_through_compressor() {
        let mut rng = Rng::new(4);
        let dense: Vec<f32> = (0..4 * 256).map(|_| rng.normal() as f32 * 0.01).collect();
        let p = compress_dense(&dense, 256, 16);
        assert_eq!(decode(&encode(&p)).unwrap(), p);
    }
}
