//! Wire codec for compressed pseudo-gradients.
//!
//! The paper (§2.1) transmits, per selected value, a 12-bit chunk-local
//! index and a 2-bit quantized value (14 bits/value), plus one f32 scale
//! per chunk — reaching >146x compression vs dense f32 pseudo-gradients
//! while staying within 2x of the 7.36-bit/value information-theoretic
//! index bound without any entropy coder.
//!
//! Wire layout (little-endian), verified byte-for-byte by the encode/
//! decode round-trip tests below (including the `nv % 4 != 0` partial
//! code byte and the `nv % 2 == 1` 2-byte index tail):
//!
//! | section | bytes                                       |
//! |---------|---------------------------------------------|
//! | magic `"CVPG"` | 4                                    |
//! | version u16    | 2                                    |
//! | k u8, log2(chunk) u8 | 2                              |
//! | n_chunks u32   | 4                                    |
//! | scales         | n_chunks * 4 (f32)                   |
//! | codes          | ceil(nv/4) — 2 bits each, 4 per byte, value j at bits (j%4)*2 |
//! | indices        | (nv/2)*3 + (2 if nv odd) = ceil(nv*12/8) — index pairs packed a \| b<<12 into 3 bytes |
//!
//! where `nv = n_chunks * k`. Encoding and decoding are
//! embarrassingly parallel per output byte/value; both fan out over the
//! rayon pool above [`PAR_MIN_VALUES`] and produce bytes identical to the
//! serial path. [`encode_into`] serializes into a caller-owned reusable
//! buffer for callers that keep the bytes (the round engine itself uses
//! the allocating [`encode`], since the wire bytes are moved into the
//! object store and must be owned).
//!
//! ## Kernel modes
//!
//! The codec participates in the [`KernelMode`] switch through
//! [`encode_into_mode`] / [`decode_mode`] (the plain entry points read
//! the process-global mode): `Reference` pins the serial byte-at-a-time
//! path (the GB/s bench baseline), `Blocked` is that same scalar loop
//! fanned out over rayon, and `Simd` swaps the per-byte inner loops for
//! word-at-a-time SWAR forms — 8 codes packed through one `u64` (two
//! output bytes per op), 4 codes unpacked through one `u32` per packed
//! byte, and 12-bit index pairs moved two-at-a-time through a 48-bit
//! window. Packing 2-bit fields is pure bit shuffling with no arithmetic
//! to reassociate, so **all three modes produce byte-identical wire
//! bytes and byte-identical decoded payloads on every input, hostile
//! ones included** — asserted by the mode-parity tests below, by
//! `tests/kernel_equivalence.rs`, and per hostile case in
//! `tests/wire_robustness.rs`.

use rayon::prelude::*;

use anyhow::{bail, ensure, Result};

use super::payload::Payload;
use crate::runtime::kernels::{self, KernelMode};

const MAGIC: &[u8; 4] = b"CVPG";
const VERSION: u16 = 1;

/// Paper accounting: bits per transmitted value for indices.
pub const INDEX_BITS: usize = 12;
/// Bits per transmitted value for the quantized magnitude.
pub const VALUE_BITS: usize = 2;

/// Below this many transmitted values the serial path is used.
pub const PAR_MIN_VALUES: usize = 1 << 14;

/// Work-unit granularity for parallel section fills (output elements).
const PAR_TASK: usize = 1 << 13;

const HEADER_BYTES: usize = 12;

/// Serialize a payload to wire bytes.
pub fn encode(p: &Payload) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(p, &mut out);
    out
}

/// Serialize into a reusable buffer (cleared and resized; the capacity
/// survives across rounds) under the process-global kernel mode.
pub fn encode_into(p: &Payload, out: &mut Vec<u8>) {
    encode_into_mode(p, out, kernels::mode())
}

/// Serialize into a reusable buffer under an explicit [`KernelMode`].
/// All modes emit byte-identical wire bytes (see the module docs);
/// `Reference` additionally pins the serial path regardless of size.
pub fn encode_into_mode(p: &Payload, out: &mut Vec<u8>, mode: KernelMode) {
    // The header stores log2(chunk): a non-power-of-two chunk would
    // silently round down and corrupt every index on the wire. Payload
    // construction (`topk::compress_dense`, `Payload::from_parts`,
    // decode's own validation) enforces this; the assert catches any
    // hand-rolled Payload that skipped those paths.
    assert!(
        p.chunk.is_power_of_two(),
        "payload chunk {} is not a power of two; the wire header stores log2(chunk)",
        p.chunk
    );
    let simd = mode == KernelMode::Simd;
    let nv = p.n_values();
    let total = wire_size(p.n_chunks, p.k);
    out.clear();
    out.resize(total, 0);
    // ---- header ---------------------------------------------------------
    out[0..4].copy_from_slice(MAGIC);
    out[4..6].copy_from_slice(&VERSION.to_le_bytes());
    out[6] = p.k as u8;
    out[7] = p.chunk.trailing_zeros() as u8;
    out[8..12].copy_from_slice(&(p.n_chunks as u32).to_le_bytes());
    let (_, rest) = out.split_at_mut(HEADER_BYTES);
    let (scales_sec, rest) = rest.split_at_mut(p.n_chunks * 4);
    let (codes_sec, idx_sec) = rest.split_at_mut(nv.div_ceil(4));
    // ---- scales ---------------------------------------------------------
    for (dst, &s) in scales_sec.chunks_exact_mut(4).zip(&p.scales) {
        dst.copy_from_slice(&s.to_le_bytes());
    }
    // ---- codes: 2 bits each, 4 per byte --------------------------------
    let codes = &p.codes;
    let fill_codes = |sec: &mut [u8], byte_base: usize| {
        let mut j = 0;
        if simd {
            // SWAR: 8 code bytes -> one u64, gather the 2-bit fields of
            // each byte down to two packed output bytes. `& 0x03…` per
            // byte matches the scalar `c & 3`; the shift-OR gather
            // places code i at bit 2*i of the 16-bit result, exactly
            // the scalar `(c & 3) << (sh * 2)` layout.
            while j + 2 <= sec.len() && (byte_base + j) * 4 + 8 <= nv {
                let lo = (byte_base + j) * 4;
                let w = u64::from_le_bytes(codes[lo..lo + 8].try_into().unwrap())
                    & 0x0303_0303_0303_0303;
                let t = w | (w >> 6);
                let u = t | (t >> 12);
                sec[j] = u as u8;
                sec[j + 1] = (u >> 32) as u8;
                j += 2;
            }
        }
        for (j, b) in sec.iter_mut().enumerate().skip(j) {
            let lo = (byte_base + j) * 4;
            let hi = (lo + 4).min(nv);
            let mut byte = 0u8;
            for (sh, &c) in codes[lo..hi].iter().enumerate() {
                byte |= (c & 3) << (sh * 2);
            }
            *b = byte;
        }
    };
    // ---- indices: pairs packed a | b<<12 into 3 bytes -------------------
    let idx = &p.idx;
    let pairs = nv / 2;
    let fill_idx = |sec: &mut [u8], pair_base: usize| {
        let mut g = 0;
        if simd {
            // Two 24-bit packed pairs through one 48-bit window. Each
            // pair is computed with the exact scalar expression
            // (`a | b<<12`, low 24 bits kept), so hostile indices that
            // overflow 12 bits OR-overlap identically to the scalar
            // path before truncation.
            while (g + 2) * 3 <= sec.len() {
                let i = (pair_base + g) * 2;
                let p0 = (idx[i] as u32 | ((idx[i + 1] as u32) << 12)) & 0x00FF_FFFF;
                let p1 = (idx[i + 2] as u32 | ((idx[i + 3] as u32) << 12)) & 0x00FF_FFFF;
                let w = p0 as u64 | ((p1 as u64) << 24);
                sec[g * 3..g * 3 + 6].copy_from_slice(&w.to_le_bytes()[..6]);
                g += 2;
            }
        }
        for (g, dst) in sec.chunks_exact_mut(3).enumerate().skip(g) {
            let i = (pair_base + g) * 2;
            let packed = idx[i] as u32 | ((idx[i + 1] as u32) << 12);
            dst[0] = (packed & 0xFF) as u8;
            dst[1] = ((packed >> 8) & 0xFF) as u8;
            dst[2] = ((packed >> 16) & 0xFF) as u8;
        }
    };
    let (idx_pairs_sec, idx_tail_sec) = idx_sec.split_at_mut(pairs * 3);
    if nv >= PAR_MIN_VALUES && mode != KernelMode::Reference {
        codes_sec
            .par_chunks_mut(PAR_TASK)
            .enumerate()
            .for_each(|(ci, sec)| fill_codes(sec, ci * PAR_TASK));
        idx_pairs_sec
            .par_chunks_mut(3 * PAR_TASK)
            .enumerate()
            .for_each(|(ci, sec)| fill_idx(sec, ci * PAR_TASK));
    } else {
        fill_codes(codes_sec, 0);
        fill_idx(idx_pairs_sec, 0);
    }
    if nv % 2 == 1 {
        let a = idx[nv - 1] as u32;
        idx_tail_sec[0] = (a & 0xFF) as u8;
        idx_tail_sec[1] = ((a >> 8) & 0xFF) as u8;
    }
}

/// Deserialize wire bytes under the process-global kernel mode.
pub fn decode(bytes: &[u8]) -> Result<Payload> {
    decode_mode(bytes, kernels::mode())
}

/// Deserialize wire bytes under an explicit [`KernelMode`]. All modes
/// produce byte-identical payloads and agree on every `Err` (all size and
/// geometry validation happens before any section is parsed, so the
/// vectorized path can never be steered into an attacker-sized
/// allocation the scalar path would have refused).
pub fn decode_mode(bytes: &[u8], mode: KernelMode) -> Result<Payload> {
    let simd = mode == KernelMode::Simd;
    ensure!(bytes.len() >= HEADER_BYTES, "wire payload too short");
    ensure!(&bytes[0..4] == MAGIC, "bad magic");
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    ensure!(version == VERSION, "unsupported wire version {version}");
    let k = bytes[6] as usize;
    let chunk_log2 = bytes[7] as usize;
    ensure!(chunk_log2 <= 12, "chunk too large for 12-bit indices");
    let chunk = 1usize << chunk_log2;
    ensure!(k >= 1 && k <= chunk, "bad k {k}");
    let n_chunks = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
    let nv = n_chunks * k;
    let total = wire_size(n_chunks, k);
    if bytes.len() != total {
        bail!("wire payload length {} != expected {}", bytes.len(), total);
    }
    let scales_sec = &bytes[HEADER_BYTES..HEADER_BYTES + n_chunks * 4];
    let codes_end = HEADER_BYTES + n_chunks * 4 + nv.div_ceil(4);
    let codes_sec = &bytes[HEADER_BYTES + n_chunks * 4..codes_end];
    let idx_sec = &bytes[codes_end..];

    let scales: Vec<f32> = scales_sec
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut codes = vec![0u8; nv];
    let fill_codes = |out: &mut [u8], base: usize| {
        let mut j = 0;
        if simd && base % 4 == 0 {
            // SWAR unpack: one packed byte -> four code bytes through a
            // u32 spread (v | v<<12, then | <<6, masked to 2 bits per
            // byte) — code i lands in byte i exactly as the scalar
            // shift-and-mask. Task bases are multiples of PAR_TASK
            // (itself a multiple of 4), so the window is byte-aligned.
            while j + 4 <= out.len() {
                let v = codes_sec[(base + j) / 4] as u32;
                let x = v | (v << 12);
                let y = (x | (x << 6)) & 0x0303_0303;
                out[j..j + 4].copy_from_slice(&y.to_le_bytes());
                j += 4;
            }
        }
        for (j, c) in out.iter_mut().enumerate().skip(j) {
            let i = base + j;
            *c = (codes_sec[i / 4] >> ((i % 4) * 2)) & 3;
        }
    };
    let mut idx = vec![0u16; nv];
    let pairs = nv / 2;
    let fill_idx = |out: &mut [u16], pair_base: usize| {
        let mut g = 0;
        if simd {
            // Two packed pairs (6 bytes) through one 48-bit window; each
            // 12-bit field is extracted with the same shift-and-mask as
            // the scalar path, just from a wider word.
            let n_pairs_here = out.len() / 2;
            while g + 2 <= n_pairs_here {
                let o = (pair_base + g) * 3;
                let w = idx_sec[o] as u64
                    | ((idx_sec[o + 1] as u64) << 8)
                    | ((idx_sec[o + 2] as u64) << 16)
                    | ((idx_sec[o + 3] as u64) << 24)
                    | ((idx_sec[o + 4] as u64) << 32)
                    | ((idx_sec[o + 5] as u64) << 40);
                out[g * 2] = (w & 0xFFF) as u16;
                out[g * 2 + 1] = ((w >> 12) & 0xFFF) as u16;
                out[g * 2 + 2] = ((w >> 24) & 0xFFF) as u16;
                out[g * 2 + 3] = ((w >> 36) & 0xFFF) as u16;
                g += 2;
            }
        }
        for (g, dst) in out.chunks_exact_mut(2).enumerate().skip(g) {
            let o = (pair_base + g) * 3;
            let packed =
                idx_sec[o] as u32 | ((idx_sec[o + 1] as u32) << 8) | ((idx_sec[o + 2] as u32) << 16);
            dst[0] = (packed & 0xFFF) as u16;
            dst[1] = ((packed >> 12) & 0xFFF) as u16;
        }
    };
    let (idx_pairs, idx_tail) = idx.split_at_mut(pairs * 2);
    if nv >= PAR_MIN_VALUES && mode != KernelMode::Reference {
        // PAR_TASK is a multiple of 4, so every task starts byte-aligned.
        codes
            .par_chunks_mut(PAR_TASK)
            .enumerate()
            .for_each(|(ci, out)| fill_codes(out, ci * PAR_TASK));
        idx_pairs
            .par_chunks_mut(2 * PAR_TASK)
            .enumerate()
            .for_each(|(ci, out)| fill_idx(out, ci * PAR_TASK));
    } else {
        fill_codes(&mut codes, 0);
        fill_idx(idx_pairs, 0);
    }
    if nv % 2 == 1 {
        let o = pairs * 3;
        let a = idx_sec[o] as u32 | ((idx_sec[o + 1] as u32) << 8);
        idx_tail[0] = (a & 0xFFF) as u16;
    }
    let p = Payload { n_chunks, k, chunk, idx, codes, scales };
    p.validate(n_chunks, k, chunk)?;
    Ok(p)
}

/// Exact wire size in bytes for a payload geometry.
pub fn wire_size(n_chunks: usize, k: usize) -> usize {
    let nv = n_chunks * k;
    HEADER_BYTES + n_chunks * 4 + nv.div_ceil(4) + (nv / 2) * 3 + if nv % 2 == 1 { 2 } else { 0 }
}

/// Wire bits per transmitted value (paper's 12 + 2 = 14 plus amortized
/// scale + header overhead).
pub fn bits_per_value(n_chunks: usize, k: usize) -> f64 {
    wire_size(n_chunks, k) as f64 * 8.0 / (n_chunks * k) as f64
}

/// Compression ratio vs dense f32 of the full flat vector.
pub fn compression_ratio(n_alloc: usize, n_chunks: usize, k: usize) -> f64 {
    (n_alloc * 4) as f64 / wire_size(n_chunks, k) as f64
}

/// The paper's own accounting (§2.1/§4.1): index+value bits only, ignoring
/// scales/header -> 32 / ((k/C) * 14) = 146.29x for C=4096, k=64.
pub fn paper_compression_ratio(chunk: usize, k: usize) -> f64 {
    32.0 / ((k as f64 / chunk as f64) * (INDEX_BITS + VALUE_BITS) as f64)
}

/// Information-theoretic lower bound on index bits/value:
/// log2(C(chunk, k)) / k (paper: ~7.36 for C=4096, k=64).
pub fn index_bits_lower_bound(chunk: usize, k: usize) -> f64 {
    // log2(C(n, k)) via lgamma.
    fn lgamma(x: f64) -> f64 {
        // Stirling series; exact enough for n <= 2^20.
        if x < 10.0 {
            // ln((x+5)!) - sum ln(x..x+5)
            let mut acc = 0.0;
            let mut y = x;
            while y < 10.0 {
                acc -= y.ln();
                y += 1.0;
            }
            return acc + lgamma(y);
        }
        0.5 * ((2.0 * std::f64::consts::PI).ln() - x.ln())
            + x * ((x + 1.0 / (12.0 * x - 1.0 / (10.0 * x))).ln() - 1.0)
    }
    let n = chunk as f64;
    let kk = k as f64;
    let log2e = std::f64::consts::LOG2_E;
    (lgamma(n + 1.0) - lgamma(kk + 1.0) - lgamma(n - kk + 1.0)) * log2e / kk
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparseloco::topk::compress_dense;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_payload(rng: &mut Rng, n_chunks: usize, k: usize, chunk: usize) -> Payload {
        let mut idx = Vec::new();
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        for _ in 0..n_chunks {
            let sel = rng.sample_indices(chunk, k);
            for &s in &sel {
                idx.push(s as u16);
                codes.push(rng.below(4) as u8);
            }
            scales.push(rng.f32() * 2.0);
        }
        Payload { n_chunks, k, chunk, idx, codes, scales }
    }

    #[test]
    fn roundtrip_simple() {
        let mut rng = Rng::new(1);
        let p = random_payload(&mut rng, 7, 5, 64);
        let bytes = encode(&p);
        assert_eq!(bytes.len(), wire_size(7, 5));
        let q = decode(&bytes).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn roundtrip_property() {
        check(
            60,
            |r| {
                let n_chunks = r.range(1, 40);
                let k = r.range(1, 17);
                let chunk = 1usize << r.range(5, 13); // 32..4096
                let k = k.min(chunk);
                random_payload(r, n_chunks, k, chunk)
            },
            |p| {
                let q = decode(&encode(p)).unwrap();
                *p == q
            },
        );
    }

    #[test]
    fn roundtrip_above_parallel_threshold() {
        // nv >= PAR_MIN_VALUES exercises the rayon fill paths; bytes and
        // round-trip must be identical to the serial reference.
        let mut rng = Rng::new(9);
        let n_chunks = PAR_MIN_VALUES / 32 + 3; // k=33 -> nv > threshold, odd tails
        let p = random_payload(&mut rng, n_chunks, 33, 4096);
        assert!(p.n_values() >= PAR_MIN_VALUES);
        let bytes = encode(&p);
        assert_eq!(bytes.len(), wire_size(n_chunks, 33));
        assert_eq!(decode(&bytes).unwrap(), p);
    }

    #[test]
    fn encode_into_reuses_buffer_and_matches() {
        let mut rng = Rng::new(4);
        let a = random_payload(&mut rng, 12, 7, 128);
        let b = random_payload(&mut rng, 30, 3, 64);
        let mut buf = Vec::new();
        encode_into(&a, &mut buf);
        assert_eq!(buf, encode(&a));
        // reuse with a different (smaller) payload: content must match a
        // fresh encode exactly, stale capacity notwithstanding
        encode_into(&b, &mut buf);
        assert_eq!(buf, encode(&b));
    }

    #[test]
    fn paper_geometry_bits_per_value() {
        // C=4096, k=64: 14 bits/value + 32/64 scale bits + header.
        let bpv = bits_per_value(3080, 64); // ~12.6M-param model
        assert!(bpv > 14.0 && bpv < 14.6, "bits/value = {bpv}");
    }

    #[test]
    fn paper_compression_claims() {
        // §2.1: >146x with the paper's accounting.
        let r = paper_compression_ratio(4096, 64);
        assert!((r - 146.29).abs() < 0.1, "r = {r}");
        // Full-wire ratio is slightly lower but still > 140x.
        let full = compression_ratio(3080 * 4096, 3080, 64);
        assert!(full > 140.0 && full < 146.3, "full = {full}");
    }

    #[test]
    fn index_bound_is_7_36_bits() {
        let b = index_bits_lower_bound(4096, 64);
        assert!((b - 7.36).abs() < 0.05, "bound = {b}");
    }

    #[test]
    fn rejects_corrupt() {
        let mut rng = Rng::new(2);
        let p = random_payload(&mut rng, 3, 4, 64);
        let mut bytes = encode(&p);
        assert!(decode(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(decode(&bytes).is_err()); // bad magic
        let mut b2 = encode(&p);
        b2.push(0);
        assert!(decode(&b2).is_err()); // trailing garbage
    }

    #[test]
    fn odd_value_count_roundtrip() {
        let mut rng = Rng::new(3);
        let p = random_payload(&mut rng, 3, 3, 32); // 9 values (odd)
        assert_eq!(decode(&encode(&p)).unwrap(), p);
    }

    #[test]
    fn tail_bytes_all_small_nv_residues() {
        // nv % 4 in {1,2,3} exercises the partial code byte; nv % 2 == 1
        // the 2-byte index tail. Cover every residue class exhaustively.
        let mut rng = Rng::new(6);
        for k in 1..=9usize {
            for n_chunks in 1..=5usize {
                let p = random_payload(&mut rng, n_chunks, k, 16);
                let bytes = encode(&p);
                assert_eq!(bytes.len(), wire_size(n_chunks, k), "k={k} nc={n_chunks}");
                assert_eq!(decode(&bytes).unwrap(), p, "k={k} nc={n_chunks}");
            }
        }
    }

    #[test]
    fn roundtrip_through_compressor() {
        let mut rng = Rng::new(4);
        let dense: Vec<f32> = (0..4 * 256).map(|_| rng.normal() as f32 * 0.01).collect();
        let p = compress_dense(&dense, 256, 16);
        assert_eq!(decode(&encode(&p)).unwrap(), p);
    }

    /// encode/decode under every mode on a payload: wire bytes and
    /// decoded payloads must be byte-identical across modes.
    fn assert_mode_parity(p: &Payload) {
        let mut reference = Vec::new();
        encode_into_mode(p, &mut reference, KernelMode::Reference);
        for mode in [KernelMode::Blocked, KernelMode::Simd] {
            let mut got = Vec::new();
            encode_into_mode(p, &mut got, mode);
            assert_eq!(reference, got, "encode bytes differ in {mode:?}");
            let q = decode_mode(&got, mode).unwrap();
            assert_eq!(*p, q, "decode payload differs in {mode:?}");
        }
        assert_eq!(decode_mode(&reference, KernelMode::Reference).unwrap(), *p);
    }

    #[test]
    fn simd_wire_bytes_identical_all_residues() {
        // Every nv % 4 (partial code byte) and nv % 2 (odd index tail)
        // residue class, plus k values straddling the 8-code SWAR word.
        let mut rng = Rng::new(21);
        for k in 1..=9usize {
            for n_chunks in 1..=5usize {
                assert_mode_parity(&random_payload(&mut rng, n_chunks, k, 16));
            }
        }
        // 12-bit-maximal indices (chunk 4096): the widest field values
        // the SWAR window must move without cross-pair contamination.
        assert_mode_parity(&random_payload(&mut rng, 5, 7, 4096));
    }

    #[test]
    fn simd_wire_bytes_identical_above_parallel_threshold() {
        // Exercises the rayon SWAR fill paths and their task-boundary
        // tails (PAR_TASK chunks with nv % PAR_TASK != 0).
        let mut rng = Rng::new(22);
        let n_chunks = PAR_MIN_VALUES / 32 + 3; // k=33 -> nv > threshold, odd tails
        let p = random_payload(&mut rng, n_chunks, 33, 4096);
        assert!(p.n_values() >= PAR_MIN_VALUES);
        assert_mode_parity(&p);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_chunk_is_refused_at_encode() {
        // A chunk of 48 would write trailing_zeros() = 4 into the header
        // and silently decode as chunk 16, corrupting every index. The
        // construction paths (compress_dense, from_parts) assert first;
        // this pins the encoder's own backstop for hand-rolled payloads.
        let p = Payload {
            n_chunks: 1,
            k: 2,
            chunk: 48,
            idx: vec![1, 40],
            codes: vec![3, 0],
            scales: vec![1.0],
        };
        let _ = encode(&p);
    }
}
