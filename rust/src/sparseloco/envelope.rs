//! Signed payload envelopes: the authenticated layer of the wire format.
//!
//! The bare [`codec`] bytes (`CVPG`) say nothing about *who* produced
//! them — any peer could upload bytes into a bucket and attribute them to
//! an arbitrary hotkey. Permissionless participation (paper §3, Gauntlet
//! §2.2) needs the coordinator to check origin and freshness *before*
//! spending any decode or scoring work on a submission. This module wraps
//! each shard-slice in a `CVEV` envelope carrying a
//! `(hotkey, round, shard, nonce)` header and a 128-bit authentication
//! tag over the header fields and the payload bytes.
//!
//! Envelope layout (little-endian), fixed 48-byte header:
//!
//! | section | bytes |
//! |---------|-------|
//! | magic `"CVEV"`   | 4  |
//! | version u16      | 2  |
//! | hotkey_len u16   | 2  |
//! | shard u32        | 4  |
//! | round u64        | 8  |
//! | nonce u64        | 8  |
//! | payload_len u32  | 4  |
//! | tag              | 16 |
//! | hotkey bytes     | hotkey_len |
//! | payload bytes    | payload_len (bare `CVPG` codec bytes) |
//!
//! [`open`] is parse-only and zero-copy: it borrows from the sealed
//! buffer and validates the exact total length against the header's
//! length fields *before* touching the variable sections, so hostile
//! length fields can never size an allocation. [`decode_compat`] keeps
//! the wire format versioned: pre-envelope bare `CVPG` buffers still
//! decode, so old bytes remain readable.
//!
//! Keys are deterministic *test* keys derived from the run seed — a
//! keyed two-lane FNV/splitmix MAC stands in for a real signature scheme
//! (no cryptography crates in this container). The API is shaped like a
//! detached-signature scheme (`SigningKey` / `VerifyingKey` /
//! [`Envelope::verify`]) so an Ed25519 implementation can drop in without
//! touching any call site.

use anyhow::{bail, ensure, Context, Result};

use super::codec;
use super::payload::Payload;

const MAGIC: &[u8; 4] = b"CVEV";
const VERSION: u16 = 1;

/// Fixed envelope header size in bytes (everything before the hotkey).
pub const HEADER_BYTES: usize = 48;
/// Authentication-tag width in bytes (two 64-bit lanes).
pub const SIG_BYTES: usize = 16;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// One keyed FNV-1a lane of the MAC; parts are length-prefixed so part
/// boundaries are unambiguous (no concatenation collisions).
struct Lane(u64);

impl Lane {
    fn new(key: u64, domain: &[u8]) -> Self {
        let mut l = Lane(FNV_OFFSET ^ splitmix(key));
        l.part(domain);
        l
    }

    fn word(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }

    fn part(&mut self, bytes: &[u8]) {
        self.word(bytes.len() as u64);
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }

    fn finish(self, key: u64) -> u64 {
        splitmix(self.0 ^ key.rotate_left(29))
    }
}

/// 128-bit authentication tag carried in the envelope header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Signature {
    lo: u64,
    hi: u64,
}

impl Signature {
    /// Wire form: `lo` then `hi`, little-endian.
    pub fn to_bytes(self) -> [u8; SIG_BYTES] {
        let mut out = [0u8; SIG_BYTES];
        out[..8].copy_from_slice(&self.lo.to_le_bytes());
        out[8..].copy_from_slice(&self.hi.to_le_bytes());
        out
    }

    /// Parse the wire form.
    pub fn from_bytes(bytes: [u8; SIG_BYTES]) -> Self {
        Signature {
            lo: u64::from_le_bytes(bytes[..8].try_into().unwrap()),
            hi: u64::from_le_bytes(bytes[8..].try_into().unwrap()),
        }
    }
}

/// Per-hotkey signing key (two 64-bit MAC lanes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SigningKey {
    k0: u64,
    k1: u64,
}

impl SigningKey {
    /// Deterministic test key for `hotkey` under `run_seed` — every
    /// process in a run derives the same key for the same identity, so
    /// simulated peers need no key-distribution machinery.
    pub fn derive(run_seed: u64, hotkey: &str) -> Self {
        let h = fnv1a(hotkey.as_bytes());
        SigningKey {
            k0: splitmix(run_seed ^ h ^ 0x4356_4556_2D4B_4559), // "CVEV-KEY"
            k1: splitmix(run_seed.rotate_left(32) ^ h.wrapping_mul(FNV_PRIME) ^ 0x6B65_7931),
        }
    }

    /// The verification half of this key.
    pub fn verifying(&self) -> VerifyingKey {
        VerifyingKey { k0: self.k0, k1: self.k1 }
    }

    /// Tag `payload` bound to the full envelope header context.
    pub fn sign(&self, hotkey: &str, round: u64, shard: u32, nonce: u64, payload: &[u8]) -> Signature {
        mac(self.k0, self.k1, hotkey, round, shard, nonce, payload)
    }
}

/// The verification half of a [`SigningKey`]. With the MAC stand-in it
/// holds the same lanes (shared secret); the type split keeps call sites
/// honest about which direction of the scheme they need.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VerifyingKey {
    k0: u64,
    k1: u64,
}

impl VerifyingKey {
    /// Stable identifier for replay-window bookkeeping: windows are keyed
    /// by the *key*, not the hotkey, so a sybil swarm sharing one key
    /// shares one window, and a recycled UID with a fresh hotkey gets a
    /// fresh window.
    pub fn id(&self) -> u64 {
        splitmix(self.k0 ^ self.k1.rotate_left(17))
    }

    /// Recompute the tag and compare.
    pub fn verify(
        &self,
        hotkey: &str,
        round: u64,
        shard: u32,
        nonce: u64,
        payload: &[u8],
        sig: Signature,
    ) -> bool {
        mac(self.k0, self.k1, hotkey, round, shard, nonce, payload) == sig
    }
}

fn mac(k0: u64, k1: u64, hotkey: &str, round: u64, shard: u32, nonce: u64, payload: &[u8]) -> Signature {
    let mut lanes = [Lane::new(k0, b"CVEV-SIG-V1/0"), Lane::new(k1, b"CVEV-SIG-V1/1")];
    for lane in &mut lanes {
        lane.part(hotkey.as_bytes());
        lane.word(round);
        lane.word(shard as u64);
        lane.word(nonce);
        lane.part(payload);
    }
    let [a, b] = lanes;
    Signature { lo: a.finish(k0), hi: b.finish(k1) }
}

/// A parsed envelope borrowing the sealed buffer ([`open`] never
/// allocates or copies).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Envelope<'a> {
    /// Claimed producer identity (authenticated by [`Envelope::verify`]).
    pub hotkey: &'a str,
    /// Outer round the payload was produced for.
    pub round: u64,
    /// Coordinator shard this slice targets.
    pub shard: u32,
    /// Replay counter; the verifier only accepts strictly increasing
    /// nonces per verifying key.
    pub nonce: u64,
    /// Authentication tag over the header fields and payload.
    pub sig: Signature,
    /// The bare `CVPG` codec bytes (still undecoded).
    pub payload: &'a [u8],
}

impl Envelope<'_> {
    /// Check the tag against `key`. The tag covers every header field
    /// and the payload bytes, so any tamper — identity, round, shard,
    /// nonce, or content — fails verification.
    pub fn verify(&self, key: &VerifyingKey) -> bool {
        key.verify(self.hotkey, self.round, self.shard, self.nonce, self.payload, self.sig)
    }
}

/// Exact sealed size for a hotkey/payload byte count.
pub fn sealed_size(hotkey_len: usize, payload_len: usize) -> usize {
    HEADER_BYTES + hotkey_len + payload_len
}

/// Sign and frame `payload` into a sealed envelope buffer.
pub fn seal(payload: &[u8], hotkey: &str, round: u64, shard: u32, nonce: u64, key: &SigningKey) -> Vec<u8> {
    assert!(hotkey.len() <= u16::MAX as usize, "hotkey too long for envelope");
    assert!(payload.len() <= u32::MAX as usize, "payload too long for envelope");
    let sig = key.sign(hotkey, round, shard, nonce, payload);
    let mut out = Vec::with_capacity(sealed_size(hotkey.len(), payload.len()));
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(hotkey.len() as u16).to_le_bytes());
    out.extend_from_slice(&shard.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&nonce.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&sig.to_bytes());
    out.extend_from_slice(hotkey.as_bytes());
    out.extend_from_slice(payload);
    out
}

/// Parse a sealed buffer without verifying the tag (that is the caller's
/// next step, against the chain's registered key for the claimed hotkey).
///
/// The exact total length is checked against the header's length fields
/// *before* the variable sections are touched, and nothing is allocated,
/// so hostile `hotkey_len`/`payload_len` values bounce off cheaply.
pub fn open(bytes: &[u8]) -> Result<Envelope<'_>> {
    ensure!(bytes.len() >= HEADER_BYTES, "envelope too short: {} bytes", bytes.len());
    ensure!(&bytes[0..4] == MAGIC, "bad envelope magic");
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    ensure!(version == VERSION, "unsupported envelope version {version}");
    let hk_len = u16::from_le_bytes([bytes[6], bytes[7]]) as usize;
    let shard = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let round = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let nonce = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
    let payload_len = u32::from_le_bytes(bytes[28..32].try_into().unwrap()) as usize;
    let sig = Signature::from_bytes(bytes[32..HEADER_BYTES].try_into().unwrap());
    // u64 arithmetic: the sum cannot overflow even with hostile fields
    let expect = HEADER_BYTES as u64 + hk_len as u64 + payload_len as u64;
    if bytes.len() as u64 != expect {
        bail!("envelope length {} != expected {}", bytes.len(), expect);
    }
    let hotkey = std::str::from_utf8(&bytes[HEADER_BYTES..HEADER_BYTES + hk_len])
        .context("envelope hotkey is not utf-8")?;
    Ok(Envelope { hotkey, round, shard, nonce, sig, payload: &bytes[HEADER_BYTES + hk_len..] })
}

/// True if the buffer leads with the envelope magic (as opposed to bare
/// `CVPG` codec bytes).
pub fn is_sealed(bytes: &[u8]) -> bool {
    bytes.len() >= 4 && &bytes[0..4] == MAGIC
}

/// Version-compatible decode: bare pre-envelope `CVPG` codec buffers
/// still decode, and sealed `CVEV` buffers decode their payload section.
///
/// Authentication is **not** performed here — callers on the trust
/// boundary must [`open`] and [`Envelope::verify`] first; this is the
/// convenience path for trusted local bytes (self-produced payloads,
/// archived rounds).
pub fn decode_compat(bytes: &[u8]) -> Result<Payload> {
    if is_sealed(bytes) {
        codec::decode(open(bytes)?.payload)
    } else {
        codec::decode(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparseloco::topk::compress_dense;
    use crate::util::rng::Rng;

    fn wire(seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        let dense: Vec<f32> = (0..8 * 64).map(|_| rng.normal() as f32 * 0.01).collect();
        codec::encode(&compress_dense(&dense, 64, 8))
    }

    #[test]
    fn seal_open_roundtrip() {
        let key = SigningKey::derive(0xA1, "hk-00003");
        let payload = wire(1);
        let sealed = seal(&payload, "hk-00003", 7, 2, 7, &key);
        assert_eq!(sealed.len(), sealed_size(8, payload.len()));
        let env = open(&sealed).unwrap();
        assert_eq!(env.hotkey, "hk-00003");
        assert_eq!(env.round, 7);
        assert_eq!(env.shard, 2);
        assert_eq!(env.nonce, 7);
        assert_eq!(env.payload, &payload[..]);
        assert!(env.verify(&key.verifying()));
    }

    #[test]
    fn wrong_key_fails_verification() {
        let key = SigningKey::derive(0xA1, "hk-00003");
        let sealed = seal(&wire(2), "hk-00003", 1, 0, 1, &key);
        let env = open(&sealed).unwrap();
        // different seed, different hotkey: both produce different keys
        assert!(!env.verify(&SigningKey::derive(0xA2, "hk-00003").verifying()));
        assert!(!env.verify(&SigningKey::derive(0xA1, "hk-00004").verifying()));
    }

    #[test]
    fn any_tampered_byte_is_caught() {
        let key = SigningKey::derive(9, "peer");
        let vk = key.verifying();
        let sealed = seal(&wire(3), "peer", 4, 1, 4, &key);
        // Flip one bit in every byte position: the envelope must either
        // fail to parse or fail verification — never verify clean.
        for pos in 0..sealed.len() {
            let mut t = sealed.clone();
            t[pos] ^= 1;
            if let Ok(env) = open(&t) {
                assert!(!env.verify(&vk), "tamper at byte {pos} verified clean");
            }
        }
    }

    #[test]
    fn header_fields_are_all_bound_by_the_tag() {
        let key = SigningKey::derive(5, "peer");
        let payload = wire(4);
        let base = key.sign("peer", 3, 1, 3, &payload);
        assert_ne!(base, key.sign("peer", 4, 1, 3, &payload), "round unbound");
        assert_ne!(base, key.sign("peer", 3, 2, 3, &payload), "shard unbound");
        assert_ne!(base, key.sign("peer", 3, 1, 4, &payload), "nonce unbound");
        assert_ne!(base, key.sign("reep", 3, 1, 3, &payload), "hotkey unbound");
        assert_ne!(base, key.sign("peer", 3, 1, 3, &payload[1..]), "payload unbound");
    }

    #[test]
    fn derive_is_deterministic_and_identity_separated() {
        let a = SigningKey::derive(7, "alice");
        assert_eq!(a, SigningKey::derive(7, "alice"));
        assert_ne!(a, SigningKey::derive(7, "bob"));
        assert_ne!(a, SigningKey::derive(8, "alice"));
        assert_ne!(a.verifying().id(), SigningKey::derive(7, "bob").verifying().id());
    }

    #[test]
    fn signature_byte_roundtrip() {
        let sig = SigningKey::derive(1, "x").sign("x", 1, 0, 1, b"abc");
        assert_eq!(Signature::from_bytes(sig.to_bytes()), sig);
    }

    #[test]
    fn every_truncation_is_a_clean_err() {
        let key = SigningKey::derive(2, "hk");
        let sealed = seal(&wire(5), "hk", 1, 0, 1, &key);
        for len in 0..sealed.len() {
            assert!(open(&sealed[..len]).is_err(), "prefix of {len} bytes parsed");
        }
    }

    #[test]
    fn hostile_length_fields_never_allocate() {
        let key = SigningKey::derive(2, "hk");
        let mut sealed = seal(&wire(6), "hk", 1, 0, 1, &key);
        // hotkey_len = u16::MAX
        sealed[6] = 0xFF;
        sealed[7] = 0xFF;
        assert!(open(&sealed).is_err());
        sealed[6] = 2;
        sealed[7] = 0;
        // payload_len = u32::MAX: expected length overflows the buffer,
        // the exact-length check rejects before anything is sized
        sealed[28..32].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(open(&sealed).is_err());
    }

    #[test]
    fn non_utf8_hotkey_is_rejected() {
        let key = SigningKey::derive(3, "hk");
        let mut sealed = seal(&wire(7), "hk", 1, 0, 1, &key);
        sealed[HEADER_BYTES] = 0xFF; // invalid utf-8 lead byte
        sealed[HEADER_BYTES + 1] = 0xFF;
        assert!(open(&sealed).is_err());
    }

    #[test]
    fn decode_compat_accepts_both_wire_generations() {
        let payload = wire(8);
        let bare = codec::decode(&payload).unwrap();
        // generation 1: bare CVPG bytes
        assert_eq!(decode_compat(&payload).unwrap(), bare);
        // generation 2: sealed CVEV envelope
        let key = SigningKey::derive(4, "hk");
        let sealed = seal(&payload, "hk", 2, 0, 2, &key);
        assert_eq!(decode_compat(&sealed).unwrap(), bare);
        assert!(is_sealed(&sealed));
        assert!(!is_sealed(&payload));
    }
}
