//! Reference chunk-wise Top-k compressor in Rust.
//!
//! Mirrors the Pallas kernel's semantics (argsort by |value| descending,
//! per-chunk max-abs scale, 2-bit quantization). Used by:
//! * integration tests cross-checking the XLA `compress` artifact,
//! * simulated adversarial/byzantine peers that fabricate payloads
//!   without running the model,
//! * the INTELLECT-1-style dense-int8 baseline (via `compress_dense` with
//!   k = chunk, for payload-size comparisons only).

use super::payload::Payload;
use super::quant::quantize_value;

/// Compress a dense flat vector (len must be a multiple of `chunk`).
pub fn compress_dense(acc: &[f32], chunk: usize, k: usize) -> Payload {
    assert!(acc.len() % chunk == 0, "dense length not a multiple of chunk");
    assert!(k <= chunk);
    let n_chunks = acc.len() / chunk;
    let mut idx = Vec::with_capacity(n_chunks * k);
    let mut codes = Vec::with_capacity(n_chunks * k);
    let mut scales = Vec::with_capacity(n_chunks);
    let mut order: Vec<u32> = Vec::with_capacity(chunk);
    for r in 0..n_chunks {
        let row = &acc[r * chunk..(r + 1) * chunk];
        order.clear();
        order.extend(0..chunk as u32);
        // Stable sort by descending |value| (ties -> lower index first),
        // matching jnp.argsort(-|x|).
        order.sort_by(|&a, &b| {
            let va = row[a as usize].abs();
            let vb = row[b as usize].abs();
            vb.partial_cmp(&va).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        let sel = &order[..k];
        let scale = sel
            .iter()
            .map(|&i| row[i as usize].abs())
            .fold(0f32, f32::max);
        scales.push(scale);
        for &i in sel {
            idx.push(i as u16);
            codes.push(quantize_value(row[i as usize], scale));
        }
    }
    Payload { n_chunks, k, chunk, idx, codes, scales }
}

/// Error-feedback compression step (SparseLoCo Eq. 1), all in Rust:
/// acc = beta*ef + delta; payload = TopK+Q(acc); ef' = acc - dequant(payload).
/// Returns (payload, new_ef).
pub fn compress_with_ef(
    delta: &[f32],
    ef: &[f32],
    beta: f32,
    chunk: usize,
    k: usize,
) -> (Payload, Vec<f32>) {
    assert_eq!(delta.len(), ef.len());
    let acc: Vec<f32> = delta.iter().zip(ef).map(|(d, e)| beta * e + d).collect();
    let payload = compress_dense(&acc, chunk, k);
    let mut ef_new = acc;
    // subtract transmitted
    for r in 0..payload.n_chunks {
        let base = r * chunk;
        for j in 0..k {
            let pos = base + payload.idx[r * k + j] as usize;
            ef_new[pos] -= payload.value(r, j);
        }
    }
    (payload, ef_new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn selects_largest_magnitudes() {
        let mut row = vec![0.0f32; 16];
        row[3] = -5.0;
        row[7] = 4.0;
        row[11] = 0.5;
        let p = compress_dense(&row, 16, 2);
        let mut sel: Vec<u16> = p.idx.clone();
        sel.sort_unstable();
        assert_eq!(sel, vec![3, 7]);
        assert_eq!(p.scales[0], 5.0);
        // -5 at full scale -> code 0 (-1); +4/5 = 0.8 -> code 3 (+1)
        let d = p.to_dense();
        assert_eq!(d[3], -5.0);
        assert!((d[7] - 5.0).abs() < 1e-6); // quantization error: 4 -> 5
    }

    #[test]
    fn ef_identity() {
        let mut rng = Rng::new(10);
        let n = 8 * 64;
        let delta: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
        let ef: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.001).collect();
        let beta = 0.95f32;
        let (payload, ef2) = compress_with_ef(&delta, &ef, beta, 64, 8);
        let dense = payload.to_dense();
        for i in 0..n {
            let acc = beta * ef[i] + delta[i];
            assert!((ef2[i] + dense[i] - acc).abs() < 1e-5, "at {i}");
        }
    }

    #[test]
    fn indices_distinct_per_chunk() {
        check(
            40,
            |r| {
                let chunk = 1usize << r.range(4, 9);
                let k = r.range(1, chunk.min(16) + 1);
                let n = r.range(1, 5) * chunk;
                let dense: Vec<f32> = (0..n).map(|_| r.normal() as f32).collect();
                (dense, chunk, k)
            },
            |(dense, chunk, k)| {
                let p = compress_dense(dense, *chunk, *k);
                (0..p.n_chunks).all(|r| {
                    let mut s: Vec<u16> = p.idx[r * k..(r + 1) * k].to_vec();
                    s.sort_unstable();
                    s.dedup();
                    s.len() == *k
                })
            },
        );
    }

    #[test]
    fn quantization_error_bounded() {
        check(
            30,
            |r| (0..256).map(|_| r.normal() as f32).collect::<Vec<f32>>(),
            |dense| {
                let p = compress_dense(dense, 256, 32);
                let d = p.to_dense();
                (0..p.n_values()).all(|j| {
                    let pos = p.idx[j] as usize;
                    (d[pos] - dense[pos]).abs() <= p.scales[0] / 3.0 + 1e-5
                })
            },
        );
    }

    #[test]
    fn k_equals_chunk_is_dense() {
        let mut rng = Rng::new(11);
        let dense: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let p = compress_dense(&dense, 64, 64);
        let d = p.to_dense();
        // every position transmitted (within quantization error)
        for i in 0..64 {
            assert!((d[i] - dense[i]).abs() <= p.scales[0] / 3.0 + 1e-6);
        }
    }
}
