//! Chunk-wise Top-k compressor (the communication-phase hot path).
//!
//! Semantics mirror the Pallas kernel the AOT artifacts were compiled
//! from: per chunk, order by |value| descending (ties broken by lower
//! index — `jnp.argsort(-|x|)`), keep the top k, scale by the chunk's
//! max-abs selected value, 2-bit quantize. Used by:
//! * every peer's compress phase (directly, or fused with the
//!   error-feedback update via [`compress_with_ef_into`]),
//! * simulated adversarial/byzantine peers that fabricate payloads
//!   without running the model,
//! * the INTELLECT-1-style dense-int8 baseline (via `compress_dense` with
//!   k = chunk, for payload-size comparisons only).
//!
//! Chunks are independent, so compression is chunk-parallel across the
//! rayon pool above [`PAR_MIN_CHUNKS`]; per-chunk selection reuses a
//! thread-local scratch index buffer (no per-chunk allocations). Serial
//! and parallel paths produce bit-identical payloads — and so do all
//! three [`KernelMode`]s: under `Simd` the selected values are gathered
//! into a contiguous scratch row and quantized by the branchless lane
//! quantizer (`quant::quantize_slice_into`), which is byte-identical to
//! the scalar `quantize_value` on every input, and the `beta*ef + delta`
//! error-feedback combine runs through the elementwise lane helper
//! `kernels::scale_add_into` (IEEE-exact, nothing to reassociate).
//! Selection itself (sort order, scale pick) is mode-independent.

use rayon::prelude::*;

use super::payload::Payload;
use super::quant::{quantize_slice_into, quantize_value};
use crate::runtime::kernels::{self, KernelMode};

/// Below this many chunks the serial path is used (rayon dispatch would
/// dominate for tiny payloads).
pub const PAR_MIN_CHUNKS: usize = 16;

/// Order for per-chunk selection: |value| descending, ties by lower index
/// (a strict total order for finite inputs).
#[inline]
fn rank(row: &[f32], a: u32, b: u32) -> std::cmp::Ordering {
    let va = row[a as usize].abs();
    let vb = row[b as usize].abs();
    vb.partial_cmp(&va).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
}

/// Compress one chunk into preallocated output rows. Under `simd` the
/// selected values are gathered into `vals` and lane-quantized —
/// byte-identical to the scalar per-value loop (the branchless quantizer
/// matches `quantize_value` on every input, NaN included).
fn compress_chunk(
    row: &[f32],
    k: usize,
    simd: bool,
    order: &mut Vec<u32>,
    vals: &mut Vec<f32>,
    idx_out: &mut [u16],
    code_out: &mut [u8],
    scale_out: &mut f32,
) {
    let chunk = row.len();
    order.clear();
    order.extend(0..chunk as u32);
    if k < chunk {
        // Partial selection, then sort just the selected prefix — same
        // total order as a full stable sort, ~chunk/k times cheaper.
        order.select_nth_unstable_by(k - 1, |&a, &b| rank(row, a, b));
        order.truncate(k);
    }
    order.sort_unstable_by(|&a, &b| rank(row, a, b));
    // max |v| among selected = first element of the sorted prefix
    let scale = row[order[0] as usize].abs();
    *scale_out = scale;
    if simd {
        vals.clear();
        for (j, &i) in order.iter().take(k).enumerate() {
            idx_out[j] = i as u16;
            vals.push(row[i as usize]);
        }
        quantize_slice_into(vals, scale, code_out);
    } else {
        for (j, &i) in order.iter().take(k).enumerate() {
            idx_out[j] = i as u16;
            code_out[j] = quantize_value(row[i as usize], scale);
        }
    }
}

/// Compress a dense flat vector (len must be a multiple of `chunk`)
/// under the process-global kernel mode.
pub fn compress_dense(acc: &[f32], chunk: usize, k: usize) -> Payload {
    compress_dense_mode(acc, chunk, k, kernels::mode())
}

/// Compress a dense flat vector under an explicit [`KernelMode`]. All
/// modes produce bit-identical payloads (see the module docs);
/// `Reference` additionally pins the serial path.
pub fn compress_dense_mode(acc: &[f32], chunk: usize, k: usize, mode: KernelMode) -> Payload {
    assert!(acc.len() % chunk == 0, "dense length not a multiple of chunk");
    assert!(k >= 1 && k <= chunk, "bad k");
    // The wire header stores log2(chunk) and packs indices into 12 bits;
    // construction is where a bad geometry must die, not on the wire.
    assert!(
        chunk.is_power_of_two(),
        "chunk {chunk} must be a power of two (the wire header stores log2(chunk))"
    );
    assert!(chunk <= 1 << 12, "chunk {chunk} exceeds the 12-bit index range");
    let simd = mode == KernelMode::Simd;
    let n_chunks = acc.len() / chunk;
    let mut idx = vec![0u16; n_chunks * k];
    let mut codes = vec![0u8; n_chunks * k];
    let mut scales = vec![0f32; n_chunks];
    if n_chunks >= PAR_MIN_CHUNKS && mode != KernelMode::Reference {
        idx.par_chunks_mut(k)
            .zip(codes.par_chunks_mut(k))
            .zip(scales.par_iter_mut())
            .enumerate()
            .for_each_init(
                || (Vec::with_capacity(chunk), Vec::with_capacity(k)),
                |(order, vals), (r, ((idx_row, code_row), scale))| {
                    let row = &acc[r * chunk..(r + 1) * chunk];
                    compress_chunk(row, k, simd, order, vals, idx_row, code_row, scale);
                },
            );
    } else {
        let mut order = Vec::with_capacity(chunk);
        let mut vals = Vec::with_capacity(k);
        for r in 0..n_chunks {
            compress_chunk(
                &acc[r * chunk..(r + 1) * chunk],
                k,
                simd,
                &mut order,
                &mut vals,
                &mut idx[r * k..(r + 1) * k],
                &mut codes[r * k..(r + 1) * k],
                &mut scales[r],
            );
        }
    }
    Payload { n_chunks, k, chunk, idx, codes, scales }
}

/// Error-feedback compression step (SparseLoCo Eq. 1):
/// acc = beta*ef + delta; payload = TopK+Q(acc); ef' = acc - dequant(payload).
/// Returns (payload, new_ef). Allocating variant of
/// [`compress_with_ef_into`].
pub fn compress_with_ef(
    delta: &[f32],
    ef: &[f32],
    beta: f32,
    chunk: usize,
    k: usize,
) -> (Payload, Vec<f32>) {
    assert_eq!(delta.len(), ef.len());
    let mut ef_new = ef.to_vec();
    let mut acc = vec![0f32; delta.len()];
    let payload = compress_with_ef_into(delta, &mut ef_new, beta, chunk, k, &mut acc);
    (payload, ef_new)
}

/// In-place error-feedback compression: updates `ef` to the new residual
/// and uses `acc_scratch` as the accumulator buffer (resized as needed,
/// reusable across rounds — this is what kills the per-round allocations
/// on the peer hot path).
pub fn compress_with_ef_into(
    delta: &[f32],
    ef: &mut Vec<f32>,
    beta: f32,
    chunk: usize,
    k: usize,
    acc_scratch: &mut Vec<f32>,
) -> Payload {
    assert_eq!(delta.len(), ef.len());
    acc_scratch.resize(delta.len(), 0.0);
    // Elementwise lane combine: IEEE-exact vs the scalar loop in every
    // kernel mode (each lane performs exactly `beta * ef[i] + delta[i]`).
    kernels::scale_add_into(beta, ef, delta, acc_scratch);
    compress_acc_update_ef(acc_scratch, ef, chunk, k)
}

/// Compress an already-formed EF accumulator and write the residual:
/// payload = TopK+Q(acc); ef := acc - dequant(payload).
///
/// This is the single implementation of the Eq. 1 residual update —
/// callers that fuse the accumulator differently (e.g. the peer's
/// `compress_phase` computing `beta*ef + (theta_global - theta_local)`
/// straight into a scratch buffer) share it, keeping every compress
/// path bit-identical.
pub fn compress_acc_update_ef(acc: &[f32], ef: &mut [f32], chunk: usize, k: usize) -> Payload {
    assert_eq!(acc.len(), ef.len());
    let payload = compress_dense(acc, chunk, k);
    ef.copy_from_slice(acc);
    for r in 0..payload.n_chunks {
        let base = r * chunk;
        for j in 0..k {
            let pos = base + payload.idx[r * k + j] as usize;
            ef[pos] -= payload.value(r, j);
        }
    }
    payload
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn selects_largest_magnitudes() {
        let mut row = vec![0.0f32; 16];
        row[3] = -5.0;
        row[7] = 4.0;
        row[11] = 0.5;
        let p = compress_dense(&row, 16, 2);
        let mut sel: Vec<u16> = p.idx.clone();
        sel.sort_unstable();
        assert_eq!(sel, vec![3, 7]);
        assert_eq!(p.scales[0], 5.0);
        // -5 at full scale -> code 0 (-1); +4/5 = 0.8 -> code 3 (+1)
        let d = p.to_dense();
        assert_eq!(d[3], -5.0);
        assert!((d[7] - 5.0).abs() < 1e-6); // quantization error: 4 -> 5
    }

    #[test]
    fn ef_identity() {
        let mut rng = Rng::new(10);
        let n = 8 * 64;
        let delta: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
        let ef: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.001).collect();
        let beta = 0.95f32;
        let (payload, ef2) = compress_with_ef(&delta, &ef, beta, 64, 8);
        let dense = payload.to_dense();
        for i in 0..n {
            let acc = beta * ef[i] + delta[i];
            assert!((ef2[i] + dense[i] - acc).abs() < 1e-5, "at {i}");
        }
    }

    #[test]
    fn fused_matches_allocating_path() {
        let mut rng = Rng::new(77);
        let n = 40 * 64; // above the parallel threshold
        let delta: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
        let ef0: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.001).collect();
        let (p_a, ef_a) = compress_with_ef(&delta, &ef0, 0.95, 64, 8);
        let mut ef_b = ef0.clone();
        let mut scratch = Vec::new();
        let p_b = compress_with_ef_into(&delta, &mut ef_b, 0.95, 64, 8, &mut scratch);
        assert_eq!(p_a, p_b);
        assert_eq!(ef_a, ef_b);
    }

    #[test]
    fn parallel_and_serial_selection_identical() {
        // Same input compressed below and above the parallel threshold
        // (by reshaping chunk geometry) must agree per chunk; more
        // directly: a payload over >= PAR_MIN_CHUNKS chunks must match a
        // chunk-by-chunk serial reference.
        let mut rng = Rng::new(5);
        let chunk = 128;
        let n_chunks = PAR_MIN_CHUNKS + 5;
        let dense: Vec<f32> = (0..n_chunks * chunk).map(|_| rng.normal() as f32).collect();
        let par = compress_dense(&dense, chunk, 9);
        for r in 0..n_chunks {
            let single = compress_dense(&dense[r * chunk..(r + 1) * chunk], chunk, 9);
            assert_eq!(&par.idx[r * 9..(r + 1) * 9], &single.idx[..]);
            assert_eq!(&par.codes[r * 9..(r + 1) * 9], &single.codes[..]);
            assert_eq!(par.scales[r], single.scales[0]);
        }
    }

    #[test]
    fn indices_distinct_per_chunk() {
        check(
            40,
            |r| {
                let chunk = 1usize << r.range(4, 9);
                let k = r.range(1, chunk.min(16) + 1);
                let n = r.range(1, 5) * chunk;
                let dense: Vec<f32> = (0..n).map(|_| r.normal() as f32).collect();
                (dense, chunk, k)
            },
            |(dense, chunk, k)| {
                let p = compress_dense(dense, *chunk, *k);
                (0..p.n_chunks).all(|r| {
                    let mut s: Vec<u16> = p.idx[r * k..(r + 1) * k].to_vec();
                    s.sort_unstable();
                    s.dedup();
                    s.len() == *k
                })
            },
        );
    }

    #[test]
    fn quantization_error_bounded() {
        check(
            30,
            |r| (0..256).map(|_| r.normal() as f32).collect::<Vec<f32>>(),
            |dense| {
                let p = compress_dense(dense, 256, 32);
                let d = p.to_dense();
                (0..p.n_values()).all(|j| {
                    let pos = p.idx[j] as usize;
                    (d[pos] - dense[pos]).abs() <= p.scales[0] / 3.0 + 1e-5
                })
            },
        );
    }

    #[test]
    fn k_equals_chunk_is_dense() {
        let mut rng = Rng::new(11);
        let dense: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let p = compress_dense(&dense, 64, 64);
        let d = p.to_dense();
        // every position transmitted (within quantization error)
        for i in 0..64 {
            assert!((d[i] - dense[i]).abs() <= p.scales[0] / 3.0 + 1e-6);
        }
    }

    #[test]
    fn simd_compression_bitwise_identical_to_scalar() {
        // Gather + lane quantize must produce byte-identical payloads in
        // every mode: odd k (lane tails), all-zero chunks (scale 0, the
        // eps-guard path), and above the chunk-parallel threshold.
        let mut rng = Rng::new(31);
        for (n_chunks, chunk, k) in
            [(1usize, 16usize, 1usize), (3, 64, 7), (5, 128, 9), (PAR_MIN_CHUNKS + 5, 256, 33)]
        {
            let mut dense: Vec<f32> = (0..n_chunks * chunk).map(|_| rng.normal() as f32).collect();
            if n_chunks > 2 {
                dense[2 * chunk..3 * chunk].fill(0.0); // zero-scale chunk
            }
            let reference = compress_dense_mode(&dense, chunk, k, KernelMode::Reference);
            let blocked = compress_dense_mode(&dense, chunk, k, KernelMode::Blocked);
            let simd = compress_dense_mode(&dense, chunk, k, KernelMode::Simd);
            assert_eq!(reference, blocked, "blocked differs at {n_chunks}x{chunk} k={k}");
            assert_eq!(reference, simd, "simd differs at {n_chunks}x{chunk} k={k}");
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_chunk_is_refused() {
        // chunk = 48 would silently hit the wire as log2 -> 4 (chunk 16)
        // and corrupt every index; construction must refuse it.
        let dense = vec![1.0f32; 48];
        let _ = compress_dense(&dense, 48, 4);
    }
}
