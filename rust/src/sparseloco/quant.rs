//! 2-bit symmetric quantization (Rust mirror of
//! `python/compile/kernels/quant2bit.py` — bit-for-bit identical math).
//!
//! Codebook: code c in {0,1,2,3} -> level (c * 2/3 - 1) in
//! {-1, -1/3, +1/3, +1}, times the per-chunk max-abs scale. Decision
//! thresholds at {-2/3, 0, +2/3}.

/// Dequantized unit level for a 2-bit code (f32 arithmetic identical to
/// the Pallas kernel: `c * (2/3) - 1`).
#[inline]
pub fn dequant_level(code: u8) -> f32 {
    code as f32 * (2.0f32 / 3.0f32) - 1.0f32
}

/// Quantize one value given its chunk scale (max-abs).
#[inline]
pub fn quantize_value(v: f32, scale: f32) -> u8 {
    let x = v / scale.max(1e-12);
    if x < -2.0 / 3.0 {
        0
    } else if x < 0.0 {
        1
    } else if x < 2.0 / 3.0 {
        2
    } else {
        3
    }
}

/// Dequantize one value.
#[inline]
pub fn dequant_value(code: u8, scale: f32) -> f32 {
    dequant_level(code) * scale
}

/// Branchless quantizer, bit-identical to [`quantize_value`] on every
/// input including NaN: each comparison is negated (`!(x < t)`) so a NaN
/// `x` fails all three and lands on code 3, exactly like the scalar
/// if-chain's final `else`. Written branch-free so rustc vectorizes the
/// lane loop in [`quantize_slice_into`].
#[inline]
pub fn quantize_value_branchless(v: f32, scale: f32) -> u8 {
    let x = v / scale.max(1e-12);
    u8::from(!(x < -2.0 / 3.0)) + u8::from(!(x < 0.0)) + u8::from(!(x < 2.0 / 3.0))
}

/// SIMD lane width for the slice quantize/dequant helpers (matches
/// `runtime::kernels::LANES`).
const LANES: usize = 8;

/// Quantize a slice against one chunk scale. Byte-identical to calling
/// [`quantize_value`] per element (the branchless form computes the same
/// `v / scale.max(1e-12)` then the same three threshold tests); the
/// [`LANES`]-wide strip loop is purely for autovectorization.
#[inline]
pub fn quantize_slice_into(vals: &[f32], scale: f32, out: &mut [u8]) {
    debug_assert_eq!(vals.len(), out.len());
    let mut cv = vals.chunks_exact(LANES);
    let mut co = out.chunks_exact_mut(LANES);
    for (xv, xo) in (&mut cv).zip(&mut co) {
        for i in 0..LANES {
            xo[i] = quantize_value_branchless(xv[i], scale);
        }
    }
    for (&v, o) in cv.remainder().iter().zip(co.into_remainder()) {
        *o = quantize_value_branchless(v, scale);
    }
}

/// Dequantize a slice of codes against one scale into `out`.
/// Byte-identical to calling [`dequant_value`] per element — elementwise,
/// no accumulation, so lane execution cannot reassociate anything.
#[inline]
pub fn dequant_slice_into(codes: &[u8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    let mut cc = codes.chunks_exact(LANES);
    let mut co = out.chunks_exact_mut(LANES);
    for (xc, xo) in (&mut cc).zip(&mut co) {
        for i in 0..LANES {
            xo[i] = dequant_level(xc[i]) * scale;
        }
    }
    for (&c, o) in cc.remainder().iter().zip(co.into_remainder()) {
        *o = dequant_level(c) * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels() {
        assert_eq!(dequant_level(0), -1.0);
        assert_eq!(dequant_level(3), 1.0);
        assert!((dequant_level(1) + 1.0 / 3.0).abs() < 1e-6);
        assert!((dequant_level(2) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn thresholds() {
        let s = 1.0;
        assert_eq!(quantize_value(-1.0, s), 0);
        assert_eq!(quantize_value(-0.67, s), 0);
        assert_eq!(quantize_value(-0.5, s), 1);
        assert_eq!(quantize_value(-0.01, s), 1);
        assert_eq!(quantize_value(0.01, s), 2);
        assert_eq!(quantize_value(0.5, s), 2);
        assert_eq!(quantize_value(0.67, s), 3);
        assert_eq!(quantize_value(1.0, s), 3);
    }

    #[test]
    fn roundtrip_error_bound() {
        // |dequant(quant(v)) - v| <= scale/3 for |v| <= scale.
        let scale = 2.5f32;
        let mut v = -scale;
        while v <= scale {
            let err = (dequant_value(quantize_value(v, scale), scale) - v).abs();
            assert!(err <= scale / 3.0 + 1e-5, "v={v} err={err}");
            v += 0.01;
        }
    }

    #[test]
    fn zero_scale_safe() {
        assert_eq!(quantize_value(0.0, 0.0), 2); // 0/eps = 0 -> code 2
        assert_eq!(dequant_value(2, 0.0), 0.0);
    }

    #[test]
    fn branchless_matches_branchy_on_every_class_of_input() {
        // Exact threshold values, subnormals, infinities, NaN, signed
        // zero, hostile scales — the branchless form must agree with the
        // if-chain everywhere (NaN comparisons are all-false, so the
        // negated tests land it on 3 like the final `else`).
        let vals = [
            -2.0f32,
            -1.0,
            -2.0 / 3.0,
            -0.5,
            -f32::MIN_POSITIVE,
            -0.0,
            0.0,
            f32::MIN_POSITIVE,
            0.5,
            2.0 / 3.0,
            1.0,
            2.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
        ];
        let scales = [0.0f32, 1e-20, 1e-12, 0.5, 1.0, 3.7, f32::INFINITY, f32::NAN];
        for &s in &scales {
            for &v in &vals {
                assert_eq!(
                    quantize_value(v, s),
                    quantize_value_branchless(v, s),
                    "v={v} scale={s}"
                );
            }
        }
        // dense sweep around the thresholds
        let mut v = -1.5f32;
        while v <= 1.5 {
            assert_eq!(quantize_value(v, 1.0), quantize_value_branchless(v, 1.0), "v={v}");
            v += 1.0 / 1024.0;
        }
    }

    #[test]
    fn slice_helpers_match_scalar_loops_bitwise() {
        // Lengths straddling the lane width, including the NaN lane.
        for len in [0usize, 1, 7, 8, 9, 16, 17, 100] {
            let vals: Vec<f32> = (0..len)
                .map(|i| if i == 3 { f32::NAN } else { (i as f32) * 0.13 - 1.0 })
                .collect();
            let scale = 0.9f32;
            let mut got = vec![0u8; len];
            quantize_slice_into(&vals, scale, &mut got);
            let want: Vec<u8> = vals.iter().map(|&v| quantize_value(v, scale)).collect();
            assert_eq!(want, got, "quantize len {len}");

            let mut dq_got = vec![0f32; len];
            dequant_slice_into(&got, scale, &mut dq_got);
            for (j, (&c, &d)) in got.iter().zip(&dq_got).enumerate() {
                assert_eq!(
                    dequant_value(c, scale).to_bits(),
                    d.to_bits(),
                    "dequant len {len} j {j}"
                );
            }
        }
    }
}
