//! 2-bit symmetric quantization (Rust mirror of
//! `python/compile/kernels/quant2bit.py` — bit-for-bit identical math).
//!
//! Codebook: code c in {0,1,2,3} -> level (c * 2/3 - 1) in
//! {-1, -1/3, +1/3, +1}, times the per-chunk max-abs scale. Decision
//! thresholds at {-2/3, 0, +2/3}.

/// Dequantized unit level for a 2-bit code (f32 arithmetic identical to
/// the Pallas kernel: `c * (2/3) - 1`).
#[inline]
pub fn dequant_level(code: u8) -> f32 {
    code as f32 * (2.0f32 / 3.0f32) - 1.0f32
}

/// Quantize one value given its chunk scale (max-abs).
#[inline]
pub fn quantize_value(v: f32, scale: f32) -> u8 {
    let x = v / scale.max(1e-12);
    if x < -2.0 / 3.0 {
        0
    } else if x < 0.0 {
        1
    } else if x < 2.0 / 3.0 {
        2
    } else {
        3
    }
}

/// Dequantize one value.
#[inline]
pub fn dequant_value(code: u8, scale: f32) -> f32 {
    dequant_level(code) * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels() {
        assert_eq!(dequant_level(0), -1.0);
        assert_eq!(dequant_level(3), 1.0);
        assert!((dequant_level(1) + 1.0 / 3.0).abs() < 1e-6);
        assert!((dequant_level(2) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn thresholds() {
        let s = 1.0;
        assert_eq!(quantize_value(-1.0, s), 0);
        assert_eq!(quantize_value(-0.67, s), 0);
        assert_eq!(quantize_value(-0.5, s), 1);
        assert_eq!(quantize_value(-0.01, s), 1);
        assert_eq!(quantize_value(0.01, s), 2);
        assert_eq!(quantize_value(0.5, s), 2);
        assert_eq!(quantize_value(0.67, s), 3);
        assert_eq!(quantize_value(1.0, s), 3);
    }

    #[test]
    fn roundtrip_error_bound() {
        // |dequant(quant(v)) - v| <= scale/3 for |v| <= scale.
        let scale = 2.5f32;
        let mut v = -scale;
        while v <= scale {
            let err = (dequant_value(quantize_value(v, scale), scale) - v).abs();
            assert!(err <= scale / 3.0 + 1e-5, "v={v} err={err}");
            v += 0.01;
        }
    }

    #[test]
    fn zero_scale_safe() {
        assert_eq!(quantize_value(0.0, 0.0), 2); // 0/eps = 0 -> code 2
        assert_eq!(dequant_value(2, 0.0), 0.0);
    }
}
