//! Compressed pseudo-gradient payload: the in-memory form peers exchange.
//!
//! One payload = per-chunk Top-k indices, 2-bit value codes, and f32
//! max-abs scales for the whole flat parameter vector. Conversions:
//! XLA artifact outputs -> `Payload` -> wire bytes (`codec`) -> dense
//! scatter (aggregation hot path).

use anyhow::{bail, ensure, Result};

use super::quant::dequant_level;
use crate::runtime::kernels::{self, KernelMode, LANES};

/// Compressed pseudo-gradient for one peer, one round.
#[derive(Debug, Clone, PartialEq)]
pub struct Payload {
    pub n_chunks: usize,
    pub k: usize,
    pub chunk: usize,
    /// Chunk-local indices, row-major `[n_chunks * k]`, each `< chunk`.
    pub idx: Vec<u16>,
    /// 2-bit codes (stored unpacked, 1 byte each), `[n_chunks * k]`.
    pub codes: Vec<u8>,
    /// Per-chunk max-abs scales, `[n_chunks]`.
    pub scales: Vec<f32>,
}

impl Payload {
    /// Assemble from the raw i32/f32 buffers an XLA `compress` call returns.
    pub fn from_parts(
        idx_i32: &[i32],
        codes_i32: &[i32],
        scales_f32: &[f32],
        k: usize,
        chunk: usize,
    ) -> Result<Self> {
        ensure!(k > 0 && chunk > 0, "bad k/chunk");
        // The wire header stores log2(chunk) and packs indices into 12
        // bits: a non-power-of-two (or oversized) chunk would silently
        // corrupt every index on encode, so refuse it at construction.
        ensure!(
            chunk.is_power_of_two() && chunk <= 1 << 12,
            "chunk {chunk} must be a power of two <= 4096 (wire header stores log2(chunk))"
        );
        ensure!(idx_i32.len() == codes_i32.len(), "idx/codes length mismatch");
        ensure!(idx_i32.len() % k == 0, "idx length not a multiple of k");
        let n_chunks = idx_i32.len() / k;
        ensure!(scales_f32.len() == n_chunks, "scales length mismatch");
        let mut idx = Vec::with_capacity(idx_i32.len());
        for &i in idx_i32 {
            ensure!(i >= 0 && (i as usize) < chunk, "index {i} out of chunk bound {chunk}");
            idx.push(i as u16);
        }
        let mut codes = Vec::with_capacity(codes_i32.len());
        for &c in codes_i32 {
            ensure!((0..4).contains(&c), "code {c} out of 2-bit range");
            codes.push(c as u8);
        }
        Ok(Payload { n_chunks, k, chunk, idx, codes, scales: scales_f32.to_vec() })
    }

    /// Number of values transmitted.
    pub fn n_values(&self) -> usize {
        self.n_chunks * self.k
    }

    /// Dense length this payload expands to.
    pub fn dense_len(&self) -> usize {
        self.n_chunks * self.chunk
    }

    /// Dequantized value at position `j` of chunk `r`.
    #[inline]
    pub fn value(&self, r: usize, j: usize) -> f32 {
        dequant_level(self.codes[r * self.k + j]) * self.scales[r]
    }

    /// L2 norm of the decompressed update — used for the validator's
    /// median-norm scaling (paper §2.2) without materializing the dense
    /// vector. Note: within a chunk, Top-k indices are distinct, so the
    /// norm is exact.
    pub fn l2_norm(&self) -> f64 {
        let mut acc = 0f64;
        for r in 0..self.n_chunks {
            let s = self.scales[r] as f64;
            let mut unit = 0f64;
            for j in 0..self.k {
                let l = dequant_level(self.codes[r * self.k + j]) as f64;
                unit += l * l;
            }
            acc += s * s * unit;
        }
        acc.sqrt()
    }

    /// Scatter `weight * value` into a dense accumulator (aggregation hot
    /// path; see benches/hotpath.rs).
    pub fn accumulate_into(&self, out: &mut [f32], weight: f32) -> Result<()> {
        ensure!(out.len() == self.dense_len(), "dense length mismatch");
        for r in 0..self.n_chunks {
            self.accumulate_chunk_into(r, &mut out[r * self.chunk..(r + 1) * self.chunk], weight);
        }
        Ok(())
    }

    /// Scatter one chunk's values into that chunk's dense slice
    /// (`out.len() == self.chunk`). Lets the aggregator parallelize over
    /// disjoint chunk ranges while keeping per-position accumulation
    /// order identical to the serial path.
    ///
    /// Under [`KernelMode::Simd`] the dequantized values are computed in
    /// [`LANES`]-wide strips (the vectorizable half of the work) and then
    /// scattered in the original j order — adversarial payloads may
    /// repeat an index within a chunk, so preserving store order keeps
    /// the result bit-identical to the scalar path even then.
    #[inline]
    pub fn accumulate_chunk_into(&self, r: usize, out: &mut [f32], weight: f32) {
        self.accumulate_chunk_into_mode(r, out, weight, kernels::mode())
    }

    /// [`Payload::accumulate_chunk_into`] under an explicit mode (all
    /// modes are bit-identical; the split exists so tests and benches can
    /// pin a path without touching the process-global switch).
    #[inline]
    pub fn accumulate_chunk_into_mode(
        &self,
        r: usize,
        out: &mut [f32],
        weight: f32,
        mode: KernelMode,
    ) {
        debug_assert_eq!(out.len(), self.chunk);
        let s = self.scales[r] * weight;
        let row = r * self.k;
        if mode == KernelMode::Simd {
            let codes = &self.codes[row..row + self.k];
            let idx = &self.idx[row..row + self.k];
            let mut vals = [0f32; LANES];
            for (cb, ib) in codes.chunks(LANES).zip(idx.chunks(LANES)) {
                for (v, &c) in vals.iter_mut().zip(cb) {
                    *v = dequant_level(c) * s;
                }
                for (&i, &v) in ib.iter().zip(&vals[..cb.len()]) {
                    out[i as usize] += v;
                }
            }
        } else {
            for j in 0..self.k {
                let pos = self.idx[row + j] as usize;
                out[pos] += dequant_level(self.codes[row + j]) * s;
            }
        }
    }

    /// Extract the contiguous chunk range `[chunk0, chunk1)` as a
    /// standalone payload (the per-shard slice peers upload under
    /// multi-coordinator sharding). Chunk-local indices are unchanged —
    /// a slice's chunk `r` is the full payload's chunk `chunk0 + r` —
    /// so scattering every slice into its shard's dense range
    /// reproduces the full payload's scatter exactly, value for value.
    /// A full-cover slice (`0..n_chunks`) is a plain clone, and its wire
    /// encoding is byte-identical to the unsliced payload's.
    pub fn slice_chunks(&self, chunk0: usize, chunk1: usize) -> Result<Payload> {
        ensure!(
            chunk0 < chunk1 && chunk1 <= self.n_chunks,
            "chunk slice [{chunk0}, {chunk1}) out of bounds for {} chunks",
            self.n_chunks
        );
        if chunk0 == 0 && chunk1 == self.n_chunks {
            return Ok(self.clone());
        }
        Ok(Payload {
            n_chunks: chunk1 - chunk0,
            k: self.k,
            chunk: self.chunk,
            idx: self.idx[chunk0 * self.k..chunk1 * self.k].to_vec(),
            codes: self.codes[chunk0 * self.k..chunk1 * self.k].to_vec(),
            scales: self.scales[chunk0..chunk1].to_vec(),
        })
    }

    /// Expand to a fresh dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.dense_len()];
        self.accumulate_into(&mut out, 1.0).expect("sized above");
        out
    }

    /// Content hash (FNV-1a) — used by the Gauntlet duplicate-submission
    /// fast check (§2.2: "prevent participants from copying others or
    /// submitting duplicate behavior").
    pub fn content_hash(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        };
        for &i in &self.idx {
            eat(i as u8);
            eat((i >> 8) as u8);
        }
        for &c in &self.codes {
            eat(c);
        }
        for &s in &self.scales {
            for b in s.to_le_bytes() {
                eat(b);
            }
        }
        h
    }

    /// Structural validation (used by Gauntlet fast checks).
    pub fn validate(
        &self,
        expect_chunks: usize,
        expect_k: usize,
        expect_chunk: usize,
    ) -> Result<()> {
        if self.n_chunks != expect_chunks || self.k != expect_k || self.chunk != expect_chunk {
            bail!(
                "payload geometry mismatch: ({}, {}, {}) vs expected ({}, {}, {})",
                self.n_chunks, self.k, self.chunk, expect_chunks, expect_k, expect_chunk
            );
        }
        ensure!(self.idx.len() == self.n_values(), "idx len");
        ensure!(self.codes.len() == self.n_values(), "codes len");
        ensure!(self.scales.len() == self.n_chunks, "scales len");
        for &i in &self.idx {
            ensure!((i as usize) < self.chunk, "index out of range");
        }
        for &c in &self.codes {
            ensure!(c < 4, "code out of range");
        }
        for &s in &self.scales {
            ensure!(s.is_finite() && s >= 0.0, "scale not finite/non-negative: {s}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Payload {
        Payload {
            n_chunks: 2,
            k: 3,
            chunk: 8,
            idx: vec![0, 3, 7, 1, 2, 5],
            codes: vec![3, 0, 2, 1, 3, 0],
            scales: vec![1.5, 0.5],
        }
    }

    #[test]
    fn dense_scatter() {
        let p = sample();
        let d = p.to_dense();
        assert_eq!(d.len(), 16);
        assert_eq!(d[0], 1.5); // code 3 -> +1 * 1.5
        assert_eq!(d[3], -1.5); // code 0 -> -1 * 1.5
        assert!((d[7] - 0.5).abs() < 1e-6); // code 2 -> +1/3 * 1.5
        assert_eq!(d[8 + 5], -0.5);
        // untouched positions zero
        assert_eq!(d[2], 0.0);
    }

    #[test]
    fn l2_norm_matches_dense() {
        let p = sample();
        let d = p.to_dense();
        let dense_norm: f64 = d.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        assert!((p.l2_norm() - dense_norm).abs() < 1e-9);
    }

    #[test]
    fn accumulate_weighted() {
        let p = sample();
        let mut acc = vec![0f32; 16];
        p.accumulate_into(&mut acc, 2.0).unwrap();
        assert_eq!(acc[0], 3.0);
    }

    #[test]
    fn from_parts_validates() {
        assert!(Payload::from_parts(&[0, 1], &[0, 4], &[1.0], 2, 8).is_err()); // bad code
        assert!(Payload::from_parts(&[0, 9], &[0, 1], &[1.0], 2, 8).is_err()); // idx >= chunk
        assert!(Payload::from_parts(&[0, 1], &[0, 1], &[1.0, 2.0], 2, 8).is_err()); // scales len
        let p = Payload::from_parts(&[0, 1], &[0, 1], &[1.0], 2, 8).unwrap();
        assert_eq!(p.n_chunks, 1);
    }

    #[test]
    fn slice_chunks_scatter_matches_full() {
        let p = sample();
        let full = p.to_dense();
        // concatenating the slices' dense expansions reproduces the full
        // payload's, value for value (the shard invariant's payload leg)
        for ranges in [vec![(0usize, 1usize), (1, 2)], vec![(0, 2)]] {
            let mut stitched = Vec::new();
            for &(a, b) in &ranges {
                stitched.extend(p.slice_chunks(a, b).unwrap().to_dense());
            }
            assert_eq!(stitched, full, "ranges {ranges:?}");
        }
        // a full-cover slice is the payload itself
        assert_eq!(p.slice_chunks(0, 2).unwrap(), p);
        // slice geometry is standalone-valid
        let s = p.slice_chunks(1, 2).unwrap();
        assert!(s.validate(1, 3, 8).is_ok());
        assert_eq!(s.scales, vec![0.5]);
        // out-of-range slices rejected
        assert!(p.slice_chunks(0, 3).is_err());
        assert!(p.slice_chunks(1, 1).is_err());
    }

    #[test]
    fn validate_geometry() {
        let p = sample();
        assert!(p.validate(2, 3, 8).is_ok());
        assert!(p.validate(2, 3, 16).is_err());
        let mut bad = sample();
        bad.scales[0] = f32::NAN;
        assert!(bad.validate(2, 3, 8).is_err());
    }

    #[test]
    fn from_parts_rejects_non_power_of_two_chunk() {
        // log2(chunk) on the wire: chunk 48 would encode as 16 and
        // corrupt every index, chunk 8192 exceeds the 12-bit index range.
        assert!(Payload::from_parts(&[0, 1], &[0, 1], &[1.0], 2, 48).is_err());
        assert!(Payload::from_parts(&[0, 1], &[0, 1], &[1.0], 2, 8192).is_err());
        assert!(Payload::from_parts(&[0, 1], &[0, 1], &[1.0], 2, 4096).is_ok());
    }

    #[test]
    fn simd_scatter_bitwise_identical_even_with_repeated_indices() {
        // An adversarial payload can repeat an index within a chunk, so
        // the SIMD scatter must preserve the original store order to stay
        // bit-identical (float += is order-sensitive).
        let p = Payload {
            n_chunks: 1,
            k: 11, // odd: exercises the partial final lane strip
            chunk: 16,
            idx: vec![3, 3, 3, 7, 0, 3, 9, 3, 3, 1, 3],
            codes: vec![3, 1, 2, 0, 3, 2, 1, 0, 3, 2, 1],
            scales: vec![1.7],
        };
        for weight in [1.0f32, 0.37] {
            let mut scalar = vec![0.125f32; 16];
            let mut simd = scalar.clone();
            p.accumulate_chunk_into_mode(0, &mut scalar, weight, KernelMode::Blocked);
            p.accumulate_chunk_into_mode(0, &mut simd, weight, KernelMode::Simd);
            for (a, b) in scalar.iter().zip(&simd) {
                assert_eq!(a.to_bits(), b.to_bits(), "weight {weight}");
            }
        }
    }
}
