//! Multiple-choice task generation + continuation-loss scoring.

use anyhow::Result;

use crate::data::grammar::{Grammar, AMARK, QMARK, SEP};
use crate::runtime::{ops, Engine};
use crate::util::rng::Rng;

/// One multiple-choice task.
#[derive(Debug, Clone)]
pub struct McTask {
    pub prompt: Vec<i32>,
    /// Candidate continuations (each >= 1 token).
    pub choices: Vec<Vec<i32>>,
    pub correct: usize,
}

/// The synthetic benchmark suites (paper Table 1/2 analogues).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EvalSuite {
    FactsEasy,
    FactsHard,
    Filler,
    Instruct,
}

impl EvalSuite {
    pub fn all() -> [EvalSuite; 4] {
        [EvalSuite::FactsEasy, EvalSuite::FactsHard, EvalSuite::Filler, EvalSuite::Instruct]
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvalSuite::FactsEasy => "facts-easy (ARC-E analogue)",
            EvalSuite::FactsHard => "facts-hard (ARC-C analogue)",
            EvalSuite::Filler => "filler-cont (HellaSwag analogue)",
            EvalSuite::Instruct => "instruct-qa (IFEval analogue)",
        }
    }

    /// Generate `n` tasks for this suite.
    pub fn tasks(&self, grammar: &Grammar, n: usize, seed: u64) -> Vec<McTask> {
        let mut rng = Rng::new(seed ^ 0xE7A1);
        (0..n)
            .map(|_| match self {
                EvalSuite::FactsEasy => fact_task(grammar, &mut rng, false, false),
                EvalSuite::FactsHard => fact_task(grammar, &mut rng, true, false),
                EvalSuite::Instruct => fact_task(grammar, &mut rng, false, true),
                EvalSuite::Filler => filler_task(grammar, &mut rng),
            })
            .collect()
    }
}

fn fact_task(g: &Grammar, rng: &mut Rng, hard: bool, instruct: bool) -> McTask {
    let (mut prompt, correct_tok, distractors) = g.mc_fact_query(rng, 4, hard);
    if instruct {
        // Q/A chat-template analogue: QMARK s r AMARK -> o
        prompt = vec![QMARK, prompt[1], prompt[2], AMARK];
    }
    let mut choices = vec![vec![correct_tok]];
    choices.extend(distractors.into_iter().map(|d| vec![d]));
    // shuffle choices, track correct
    let mut order: Vec<usize> = (0..choices.len()).collect();
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&i| i == 0).unwrap();
    let choices = order.into_iter().map(|i| choices[i].clone()).collect();
    McTask { prompt, choices, correct }
}

fn filler_task(g: &Grammar, rng: &mut Rng) -> McTask {
    // Build a filler walk; the correct continuation follows the Markov
    // chain, distractors are random unrelated filler tokens.
    let stream = g.stream(crate::data::grammar::GrammarKind::Web, rng.next_u64(), 4096);
    // find a filler run of >= 5 tokens
    let filler_lo = (g.vocab_size - filler_count(g)) as i32;
    let mut start = 0;
    let mut run = 0;
    for (i, &t) in stream.iter().enumerate() {
        if t >= filler_lo {
            run += 1;
            if run >= 6 {
                start = i - 5;
                break;
            }
        } else {
            run = 0;
        }
    }
    let prompt: Vec<i32> = stream[start..start + 5].to_vec();
    let correct_tok = stream[start + 5];
    let mut choices = vec![vec![correct_tok]];
    while choices.len() < 4 {
        let d = filler_lo + rng.below((g.vocab_size as i32 - filler_lo) as usize) as i32;
        if d != correct_tok {
            choices.push(vec![d]);
        }
    }
    let mut order: Vec<usize> = (0..4).collect();
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&i| i == 0).unwrap();
    let choices = order.into_iter().map(|i| choices[i].clone()).collect();
    McTask { prompt, choices, correct }
}

fn filler_count(g: &Grammar) -> usize {
    g.vocab_size - (4 + g.n_subjects + g.n_relations + g.n_objects)
}

/// Results for one suite.
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub suite: EvalSuite,
    pub n: usize,
    pub correct: usize,
}

impl SuiteResult {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.n.max(1) as f64
    }
}

/// Scores tasks through the `loss_per_seq` op.
pub struct Scorer<'e> {
    pub eng: &'e Engine,
}

impl<'e> Scorer<'e> {
    pub fn new(eng: &'e Engine) -> Self {
        Self { eng }
    }

    /// Build the padded (tokens, mask) pair for one (prompt, choice).
    fn encode(&self, prompt: &[i32], choice: &[i32]) -> (Vec<i32>, Vec<f32>) {
        let c = &self.eng.manifest().config;
        let t = c.seq_len;
        let mut tokens = Vec::with_capacity(t + 1);
        tokens.extend_from_slice(prompt);
        tokens.extend_from_slice(choice);
        tokens.resize(t + 1, SEP);
        let mut mask = vec![0f32; t];
        // choice token at sequence index i is the target at index i-1
        for i in 0..choice.len() {
            let pos = prompt.len() + i - 1;
            if pos < t {
                mask[pos] = 1.0;
            }
        }
        (tokens, mask)
    }

    /// Mean continuation loss for each (prompt, choice) pair, batched
    /// through the fixed `[B, T+1]` eval op.
    pub fn choice_losses(&self, params: &[f32], tasks: &[McTask]) -> Result<Vec<Vec<f32>>> {
        let c = &self.eng.manifest().config;
        let b = c.batch_size;
        let t = c.seq_len;
        // flatten all (task, choice) pairs
        let mut pairs = Vec::new();
        for (ti, task) in tasks.iter().enumerate() {
            for (ci, choice) in task.choices.iter().enumerate() {
                pairs.push((ti, ci, self.encode(&task.prompt, choice)));
            }
        }
        let mut out: Vec<Vec<f32>> =
            tasks.iter().map(|t| vec![0f32; t.choices.len()]).collect();
        for batch in pairs.chunks(b) {
            let mut tokens = Vec::with_capacity(b * (t + 1));
            let mut mask = Vec::with_capacity(b * t);
            for (_, _, (tk, mk)) in batch {
                tokens.extend_from_slice(tk);
                mask.extend_from_slice(mk);
            }
            // pad the final partial batch with copies of its first row
            for _ in batch.len()..b {
                tokens.extend_from_slice(&batch[0].2 .0);
                mask.extend_from_slice(&batch[0].2 .1);
            }
            let losses = ops::loss_per_seq(self.eng, params, &tokens, &mask)?;
            for (row, (ti, ci, _)) in batch.iter().enumerate() {
                out[*ti][*ci] = losses[row];
            }
        }
        Ok(out)
    }

    /// Run one suite: accuracy by arg-min continuation loss.
    pub fn run_suite(
        &self,
        params: &[f32],
        grammar: &Grammar,
        suite: EvalSuite,
        n: usize,
        seed: u64,
    ) -> Result<SuiteResult> {
        let tasks = suite.tasks(grammar, n, seed);
        let losses = self.choice_losses(params, &tasks)?;
        let mut correct = 0;
        for (task, ls) in tasks.iter().zip(&losses) {
            let best = ls
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best == task.correct {
                correct += 1;
            }
        }
        Ok(SuiteResult { suite, n: tasks.len(), correct })
    }

    /// Run all suites.
    pub fn run_all(
        &self,
        params: &[f32],
        grammar: &Grammar,
        n: usize,
        seed: u64,
    ) -> Result<Vec<SuiteResult>> {
        EvalSuite::all()
            .iter()
            .map(|&s| self.run_suite(params, grammar, s, n, seed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> Grammar {
        Grammar::new(512, 42)
    }

    #[test]
    fn tasks_well_formed() {
        for suite in EvalSuite::all() {
            let tasks = suite.tasks(&g(), 50, 1);
            assert_eq!(tasks.len(), 50);
            for t in tasks {
                assert_eq!(t.choices.len(), 4);
                assert!(t.correct < 4);
                assert!(!t.prompt.is_empty());
                // all tokens in range
                for tok in t.prompt.iter().chain(t.choices.iter().flatten()) {
                    assert!(*tok >= 0 && (*tok as usize) < 512);
                }
            }
        }
    }

    #[test]
    fn tasks_deterministic() {
        let a = EvalSuite::FactsEasy.tasks(&g(), 10, 7);
        let b = EvalSuite::FactsEasy.tasks(&g(), 10, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn correct_choice_position_uniformish() {
        // shuffling should not bias the correct answer's position
        let tasks = EvalSuite::FactsEasy.tasks(&g(), 400, 3);
        let mut counts = [0usize; 4];
        for t in &tasks {
            counts[t.correct] += 1;
        }
        for c in counts {
            assert!(c > 50, "position bias: {counts:?}");
        }
    }

    #[test]
    fn filler_correct_is_valid_successor() {
        // The correct continuation appears in the corpus after the prompt
        // prefix; distractors are random. Just sanity-check the structure.
        let tasks = EvalSuite::Filler.tasks(&g(), 20, 5);
        for t in &tasks {
            assert_eq!(t.prompt.len(), 5);
            assert_eq!(t.choices[t.correct].len(), 1);
        }
    }
}
