//! Evaluation harness: synthetic multiple-choice benchmark suites scored
//! by length-normalized continuation loss — the same mechanism lm-eval
//! uses for the paper's zero-shot benchmarks (ARC, HellaSwag, MMLU, ...).
//!
//! Suite mapping to the paper's Table 1/2 benchmarks (DESIGN.md T1/T2):
//! * `FactsEasy`  — frequent facts (ARC-Easy analogue)
//! * `FactsHard`  — tail facts (ARC-Challenge/MMLU analogue)
//! * `Filler`     — Markov-continuation plausibility (HellaSwag analogue)
//! * `Instruct`   — Q/A-format facts (IFEval analogue; tests the SFT
//!   format introduced in §5)

pub mod mc;

pub use mc::{EvalSuite, McTask, Scorer, SuiteResult};
