//! Metrics emitters: CSV series + ASCII timelines for every figure.

pub mod timeline;

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Write a CSV file: header + rows.
pub fn write_csv(path: impl AsRef<Path>, header: &str, rows: &[Vec<String>]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(())
}

/// Simple inline ASCII sparkline for loss curves in reports.
///
/// The scale is fit over the *finite* values only; NaN/±inf entries
/// render as `?` instead of poisoning the range (a `-inf` low used to
/// push the bar index to `usize::MAX` and panic). The bar index is
/// clamped, so even adversarial inputs cannot go out of bounds.
pub fn sparkline(values: &[f64]) -> String {
    if values.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let finite = values.iter().copied().filter(|v| v.is_finite());
    let lo = finite.clone().fold(f64::INFINITY, f64::min);
    let hi = finite.fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    values
        .iter()
        .map(|v| {
            if !v.is_finite() {
                return '?';
            }
            BARS[(((v - lo) / span) * 7.0).round().clamp(0.0, 7.0) as usize]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }

    #[test]
    fn sparkline_non_finite_inputs_never_panic() {
        // -inf used to drag the low end to -inf and index out of bounds
        let s = sparkline(&[f64::NEG_INFINITY, 1.0, 2.0, f64::INFINITY, f64::NAN]);
        assert_eq!(s.chars().count(), 5);
        let chars: Vec<char> = s.chars().collect();
        assert_eq!(chars[0], '?');
        assert_eq!(chars[3], '?');
        assert_eq!(chars[4], '?');
        // the finite values still scale over their own range
        assert_eq!(chars[1], '▁');
        assert_eq!(chars[2], '█');
    }

    #[test]
    fn sparkline_all_non_finite_and_single_value() {
        assert_eq!(sparkline(&[f64::NAN, f64::INFINITY]), "??");
        assert_eq!(sparkline(&[]), "");
        // a single finite value sits on the bottom bar, no divide blowup
        assert_eq!(sparkline(&[3.5]), "▁");
    }

    #[test]
    fn csv_write() {
        let dir = std::env::temp_dir().join("covenant-test-csv");
        let path = dir.join("x.csv");
        write_csv(&path, "a,b", &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn csv_write_creates_nested_parent_dirs() {
        let dir = std::env::temp_dir().join("covenant-test-csv-nested");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("a/b/c.csv");
        write_csv(&path, "h", &[vec!["v".into()]]).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "h\nv\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn csv_write_unwritable_path_is_clean_err() {
        // a path whose "parent directory" is an existing regular file:
        // create_dir_all (or the create) must fail as an Err, not panic
        let dir = std::env::temp_dir().join("covenant-test-csv-unwritable");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, "not a directory").unwrap();
        let err = write_csv(blocker.join("x.csv"), "h", &[]).unwrap_err();
        assert!(!err.to_string().is_empty());
        std::fs::remove_dir_all(dir).ok();
    }
}
