//! Metrics emitters: CSV series + ASCII timelines for every figure.

pub mod timeline;

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Write a CSV file: header + rows.
pub fn write_csv(path: impl AsRef<Path>, header: &str, rows: &[Vec<String>]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(())
}

/// Simple inline ASCII sparkline for loss curves in reports.
pub fn sparkline(values: &[f64]) -> String {
    if values.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = values.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    values
        .iter()
        .map(|v| BARS[(((v - lo) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }

    #[test]
    fn csv_write() {
        let dir = std::env::temp_dir().join("covenant-test-csv");
        let path = dir.join("x.csv");
        write_csv(&path, "a,b", &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(dir).ok();
    }
}
