//! Figure-3-style compute/communication timelines.
//!
//! Two renderings over the round reports:
//!
//! * **Round rows** ([`rows`], [`render_ascii`], [`to_csv`]) — one row per
//!   round of black (compute) and red (sync) segments, the paper's Fig. 3
//!   bars.
//! * **Peer lanes** ([`render_lanes_ascii`]) — one row per *peer* within a
//!   round, drawn from the event spine's [`PeerLane`] segments: compute
//!   (`#`), upload (`^`), download (`v`), overlap of segments (`*`). This
//!   is where heterogeneity and the Fig.-1 overlap trick become visible:
//!   stragglers' `#` runs past the deadline column (`|`), and with overlap
//!   enabled upload/download tails extend past the round boundary.
//! * **Shard lanes** ([`render_shard_lanes_ascii`]) — one row per
//!   *coordinator shard* within a round, drawn from
//!   [`ShardLane`]: the gather window (`g`, nominal compute end to the
//!   shard's aggregation-ready time) and the cross-shard barrier column
//!   (`B`) where the outer step applied. A shard whose `g` run stretches
//!   to the barrier is the round's critical shard.
//!
//! Fail-over is visible in both renderings: peer lanes draw a retry tick
//! (`r`) at each backoff-delayed re-upload after a link flap, and shard
//! lanes draw the host-crash detection marker (`X`), the takeover span
//! (`t`, detection until the replacement host rebuilt the shard's state
//! from the object store), and a `REASSIGNED from->to` annotation.
//!
//! Swarm-scale contract: `RoundReport::lanes` holds only the
//! *materialized* lane cohort — with telemetry lane sampling on, the
//! deterministic bottom-k subset, assembled from the round engine's
//! struct-of-arrays lane table (`peer::swarm::LaneTable`). Everything
//! here is O(|lanes|), so rendering a 100k-peer round costs O(sample),
//! never O(peers); exact whole-population counts live in
//! `RoundReport::lane_population`, which is computed off the flat
//! arrays without materializing a single [`PeerLane`].

use crate::coordinator::{PeerLane, RoundReport, ShardLane};

/// One rendered timeline row.
#[derive(Debug, Clone)]
pub struct TimelineRow {
    pub round: usize,
    pub compute_s: f64,
    pub comm_s: f64,
}

/// Extract rows for a window of rounds.
pub fn rows(reports: &[RoundReport]) -> Vec<TimelineRow> {
    reports
        .iter()
        .map(|r| TimelineRow {
            round: r.round,
            compute_s: r.t_compute_end - r.t_start,
            comm_s: r.t_comm(),
        })
        .collect()
}

/// ASCII rendering: '#' = compute, '!' = sync, scaled to `width` columns
/// per row (the paper's Fig. 3 black/red bars).
pub fn render_ascii(rows: &[TimelineRow], width: usize) -> String {
    let mut out = String::new();
    for r in rows {
        let total = r.compute_s + r.comm_s;
        let comm_cols = ((r.comm_s / total.max(1e-9)) * width as f64).round() as usize;
        let comm_cols = comm_cols.clamp(usize::from(r.comm_s > 0.0), width);
        let compute_cols = width - comm_cols;
        out.push_str(&format!(
            "round {:>5} |{}{}| compute {:>7.1}s  sync {:>6.1}s  util {:>5.1}%\n",
            r.round,
            "#".repeat(compute_cols),
            "!".repeat(comm_cols),
            r.compute_s,
            r.comm_s,
            100.0 * r.compute_s / total.max(1e-9),
        ));
    }
    out
}

/// CSV emitter (round, t_compute, t_comm, utilization).
pub fn to_csv(rows: &[TimelineRow]) -> String {
    let mut s = String::from("round,compute_s,comm_s,utilization\n");
    for r in rows {
        let total = r.compute_s + r.comm_s;
        s.push_str(&format!(
            "{},{:.3},{:.3},{:.6}\n",
            r.round,
            r.compute_s,
            r.comm_s,
            r.compute_s / total.max(1e-9)
        ));
    }
    s
}

/// Mean utilization over rows.
pub fn mean_utilization(rows: &[TimelineRow]) -> f64 {
    if rows.is_empty() {
        return 0.0;
    }
    rows.iter()
        .map(|r| r.compute_s / (r.compute_s + r.comm_s).max(1e-9))
        .sum::<f64>()
        / rows.len() as f64
}

/// Shared row-painting core for the per-peer and per-shard lane
/// renderers: one `.`-filled character row spanning the time window
/// `[t0, t1)`, painted with segments and single-column markers.
///
/// Cells are half-open ranges of floor-mapped columns, so segments that
/// merely *abut* in time (an upload starting exactly at compute end)
/// never share a cell — `*` marks only genuine overlap. Sub-cell
/// segments keep a one-cell minimum so they stay visible. Markers
/// overwrite whatever is under them (they are annotations, not
/// segments), and a marker at the window's far edge lands on the final
/// column rather than falling off the row.
struct RowPainter {
    row: Vec<char>,
    t0: f64,
    t1: f64,
}

impl RowPainter {
    fn new(width: usize, t0: f64, t1: f64) -> Self {
        Self { row: vec!['.'; width], t0, t1 }
    }

    /// Virtual seconds per column (the one-cell minimum used by
    /// [`Self::seg_min_cell`]).
    fn cell_s(&self) -> f64 {
        (self.t1 - self.t0) / self.row.len() as f64
    }

    /// Paint `[a, b)` (virtual seconds) with `c`; cells already holding
    /// a different segment become `*` (overlap). Zero- and
    /// negative-duration segments paint nothing.
    fn seg(&mut self, a: f64, b: f64, c: char) {
        if b <= a || self.t1 <= self.t0 || self.row.is_empty() {
            return;
        }
        let len = self.row.len();
        let scale = len as f64 / (self.t1 - self.t0);
        let lo = (((a - self.t0) * scale).floor().max(0.0) as usize).min(len - 1);
        let hi =
            ((((b.min(self.t1) - self.t0) * scale).floor().max(0.0) as usize).max(lo + 1)).min(len);
        for cell in self.row.iter_mut().take(hi).skip(lo) {
            *cell = if *cell == '.' || *cell == c { c } else { '*' };
        }
    }

    /// [`Self::seg`] with a one-cell minimum duration, so instantaneous
    /// events (a zero-cost takeover, a shard ready before compute end)
    /// stay visible.
    fn seg_min_cell(&mut self, a: f64, b: f64, c: char) {
        if self.row.is_empty() {
            return;
        }
        self.seg(a, b.max(a + self.cell_s()), c);
    }

    /// Drop a single-column marker at virtual time `t` (overwrites
    /// segments under it). Out-of-window and non-finite times paint
    /// nothing.
    fn marker(&mut self, t: f64, c: char) {
        if self.t1 <= self.t0 || !t.is_finite() || t < self.t0 || self.row.is_empty() {
            return;
        }
        let len = self.row.len();
        let i = (((t - self.t0) / (self.t1 - self.t0) * len as f64) as usize).min(len - 1);
        self.row[i] = c;
    }

    fn finish(self) -> String {
        self.row.into_iter().collect()
    }
}

/// Per-peer lane rendering of one round: `#` compute, `^` upload,
/// `v` download, `*` overlapping segments, `r` a retried upload (the
/// backoff-delayed re-send after a link flap), `|` the upload deadline.
/// The window spans the round start to the latest finite segment end
/// (so overlap-mode tails that cross into the next round stay visible).
/// Stalled uploads (infinite end) are drawn up to the deadline; lanes the
/// Gauntlet flagged late are annotated `LATE`.
pub fn render_lanes_ascii(rep: &RoundReport, width: usize) -> String {
    if rep.lanes.is_empty() || width == 0 {
        return String::new();
    }
    let t0 = rep.t_start;
    let mut t1 = rep.t_comm_end.max(rep.deadline);
    for l in &rep.lanes {
        for seg in [l.compute, l.upload, l.download].into_iter().flatten() {
            if seg.1.is_finite() {
                t1 = t1.max(seg.1);
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "round {} [{:.0}s..{:.0}s]  # compute  ^ upload  v download  * overlap  r retry  | deadline\n",
        rep.round, t0, t1
    ));
    for l in &rep.lanes {
        let mut p = RowPainter::new(width, t0, t1);
        if let Some((a, b)) = l.compute {
            p.seg(a, b, '#');
        }
        if let Some((a, b)) = l.upload {
            let b = if b.is_finite() { b } else { rep.deadline };
            p.seg(a, b, '^');
        }
        if let Some((a, b)) = l.download {
            p.seg(a, b, 'v');
        }
        // retried-upload ticks: drawn over the segments (the retry *is*
        // part of the upload) but under the deadline marker
        for &rt in &l.retry_at {
            p.marker(rt, 'r');
        }
        p.marker(rep.deadline, '|');
        let tier = format!("{:?}", l.tier);
        out.push_str(&format!(
            "{:<9} {:<9} |{}|{}\n",
            l.hotkey,
            tier,
            p.finish(),
            if l.late { " LATE" } else { "" },
        ));
    }
    out
}

/// Per-coordinator-shard lane rendering of one round: `g` is the
/// shard's gather window (from the nominal compute end until its last
/// selected slice arrived and aggregation became ready), `B` the
/// cross-shard barrier column where the outer step applied (identical
/// for every shard — that's the barrier). Fail-over rounds additionally
/// draw `X` where the shard's dead host was detected and a `t` span
/// while the takeover host rebuilt the shard's state from the object
/// store, with a trailing `REASSIGNED from->to` annotation. Rows are
/// annotated with the shard's chunk range, received bytes, and current
/// host. Empty string when the round selected nothing (no shard
/// aggregated).
pub fn render_shard_lanes_ascii(rep: &RoundReport, width: usize) -> String {
    if rep.shard_lanes.is_empty() || width == 0 {
        return String::new();
    }
    let t0 = rep.t_start;
    // The barrier is identical across lanes by construction (it is the
    // max of every shard's ready time).
    let barrier = rep.shard_lanes[0].applied_at;
    let mut t1 = rep.t_comm_end;
    if barrier.is_finite() {
        t1 = t1.max(barrier);
    }
    for l in &rep.shard_lanes {
        if let Some((_, _, recovered_at)) = l.takeover {
            if recovered_at.is_finite() {
                t1 = t1.max(recovered_at);
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "round {} [{:.0}s..{:.0}s]  g gather  B outer-step barrier  X crash detected  t takeover\n",
        rep.round, t0, t1
    ));
    for l in &rep.shard_lanes {
        let mut p = RowPainter::new(width, t0, t1);
        if l.ready_at.is_finite() {
            // A shard that became ready *before* the nominal compute end
            // (all its selected peers were fast-tier) still gets a
            // visible one-cell gather mark at its ready time.
            p.seg_min_cell(rep.t_compute_end.min(l.ready_at), l.ready_at, 'g');
        }
        if let Some((_, t_detect, recovered_at)) = l.takeover {
            // Takeover span: detection until the replacement host has the
            // shard's state rebuilt (one-cell minimum so a zero-cost
            // rebuild stays visible), with the crash-detection marker on
            // its leading edge.
            p.seg_min_cell(t_detect, recovered_at, 't');
            p.marker(t_detect, 'X');
        }
        p.marker(barrier, 'B');
        let fail = match l.takeover {
            Some((from, ..)) => format!("  REASSIGNED {}->{}", from, l.host),
            None => String::new(),
        };
        out.push_str(&format!(
            "shard {:<3} chunks [{:>4}, {:>4}) |{}| {:>9} B ready {:>8.1}s host {}{}\n",
            l.shard,
            l.chunk0,
            l.chunk1,
            p.finish(),
            l.bytes,
            l.ready_at,
            l.host,
            fail,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::ComputeTier;

    fn row(c: f64, s: f64) -> TimelineRow {
        TimelineRow { round: 0, compute_s: c, comm_s: s }
    }

    #[test]
    fn utilization_math() {
        let rs = [row(1200.0, 70.0)];
        let u = mean_utilization(&rs);
        // the paper's 20min/70s point: ~94.5%
        assert!((u - 0.9449).abs() < 0.001, "u={u}");
    }

    #[test]
    fn ascii_renders() {
        let s = render_ascii(&[row(1200.0, 70.0)], 60);
        assert!(s.contains('#') && s.contains('!'));
        assert!(s.contains("94.5%"));
    }

    #[test]
    fn ascii_zero_comm_round() {
        // A round with no communication at all (nothing selected): the
        // whole bar is compute, no '!' columns, no div-by-zero.
        let s = render_ascii(&[row(600.0, 0.0)], 40);
        assert_eq!(s.lines().count(), 1);
        assert!(s.contains(&"#".repeat(40)));
        assert!(!s.contains('!'));
        assert!(s.contains("100.0%"));
    }

    #[test]
    fn ascii_comm_dominated_round() {
        // Comm >> compute: the compute side may round to zero columns but
        // the bar must stay exactly `width` wide and not underflow.
        let s = render_ascii(&[row(0.001, 5000.0)], 30);
        let bar: String = s.chars().skip_while(|&c| c != '|').take(32).collect();
        assert_eq!(bar.chars().count(), 32, "bar must be |{{30 cols}}|");
        assert!(s.matches('!').count() == 30, "all columns are sync: {s}");
    }

    #[test]
    fn ascii_empty_slice() {
        assert_eq!(render_ascii(&[], 60), "");
        assert_eq!(rows(&[]).len(), 0);
        assert_eq!(to_csv(&[]).lines().count(), 1, "header only");
        assert_eq!(mean_utilization(&[]), 0.0);
    }

    #[test]
    fn csv_emits() {
        let s = to_csv(&[row(10.0, 1.0)]);
        assert!(s.starts_with("round,"));
        assert!(s.lines().count() == 2);
    }

    fn lane_report() -> RoundReport {
        RoundReport {
            round: 3,
            t_start: 0.0,
            t_compute_end: 100.0,
            t_comm_end: 110.0,
            deadline: 120.0,
            active: 2,
            submitted: 2,
            contributing: 1,
            adversarial_submitted: 0,
            adversarial_selected: 0,
            late_submissions: 1,
            rejected_pre_decode: 0,
            mean_loss: 0.0,
            bytes_up: 0,
            bytes_down: 0,
            retried_uploads: 0,
            orphaned_slices: 0,
            recovered_shards: 0,
            outer_alpha: 1.0,
            rejections: Vec::new(),
            lanes: vec![
                PeerLane {
                    uid: 0,
                    hotkey: "hk-00000".into(),
                    tier: ComputeTier::Median,
                    compute: Some((0.0, 100.0)),
                    upload: Some((100.0, 104.0)),
                    download: Some((108.0, 110.0)),
                    late: false,
                    retry_at: Vec::new(),
                },
                PeerLane {
                    uid: 1,
                    hotkey: "hk-00001".into(),
                    tier: ComputeTier::Straggler,
                    compute: Some((0.0, 150.0)),
                    upload: Some((150.0, f64::INFINITY)),
                    download: Some((108.0, 110.0)),
                    late: true,
                    retry_at: Vec::new(),
                },
            ],
            shard_lanes: vec![
                ShardLane {
                    shard: 0,
                    chunk0: 0,
                    chunk1: 3,
                    ready_at: 104.0,
                    applied_at: 107.0,
                    bytes: 1200,
                    host: 0,
                    takeover: None,
                },
                ShardLane {
                    shard: 1,
                    chunk0: 3,
                    chunk1: 5,
                    ready_at: 107.0,
                    applied_at: 107.0,
                    bytes: 900,
                    host: 1,
                    takeover: None,
                },
            ],
            lane_population: Default::default(),
        }
    }

    #[test]
    fn lanes_render_segments_and_late_flag() {
        let s = render_lanes_ascii(&lane_report(), 60);
        assert_eq!(s.lines().count(), 3, "header + 2 lanes");
        // check the lane rows, not the header legend
        let body: Vec<&str> = s.lines().skip(1).collect();
        let median = body[0];
        let straggler = body[1];
        assert!(median.contains('#') && median.contains('^') && median.contains('v'));
        assert!(!median.contains("LATE"));
        assert!(straggler.contains("LATE"));
        assert!(straggler.contains("Straggler"));
        // straggler's compute overruns its own download window: overlap cell
        assert!(straggler.contains('*'), "overlap cells marked: {s}");
        // deadline marker lands in every lane row
        assert!(median.contains('|') && straggler.contains('|'));
    }

    #[test]
    fn lanes_empty_report() {
        let mut rep = lane_report();
        rep.lanes.clear();
        assert_eq!(render_lanes_ascii(&rep, 60), "");
        assert_eq!(render_lanes_ascii(&lane_report(), 0), "");
    }

    #[test]
    fn shard_lanes_render_gather_and_barrier() {
        let rep = lane_report();
        let s = render_shard_lanes_ascii(&rep, 60);
        assert_eq!(s.lines().count(), 3, "header + 2 shard lanes");
        let body: Vec<&str> = s.lines().skip(1).collect();
        // every shard row shows its chunk range and the barrier column
        assert!(body[0].contains("chunks [   0,    3)"));
        assert!(body[1].contains("chunks [   3,    5)"));
        assert!(body.iter().all(|r| r.contains('B')), "barrier in every row: {s}");
        // the early shard's gather ends before the barrier; the critical
        // shard's gather run reaches it
        assert!(body[0].contains('g') && body[1].contains('g'));
        assert!(body[0].contains("1200 B"));
    }

    #[test]
    fn shard_lanes_empty_when_nothing_selected() {
        let mut rep = lane_report();
        rep.shard_lanes.clear();
        assert_eq!(render_shard_lanes_ascii(&rep, 60), "");
        assert_eq!(render_shard_lanes_ascii(&lane_report(), 0), "");
    }

    #[test]
    fn retry_ticks_mark_flapped_uploads() {
        let mut rep = lane_report();
        rep.lanes[0].retry_at = vec![101.0, 103.0];
        let s = render_lanes_ascii(&rep, 60);
        assert!(s.lines().next().unwrap().contains("r retry"), "legend: {s}");
        let body: Vec<&str> = s.lines().skip(1).collect();
        assert!(body[0].matches('r').count() >= 1, "retry ticks drawn: {s}");
        assert!(!body[1].contains('r'), "no phantom ticks on clean lanes");
        // out-of-window / infinite retry times never panic or paint
        rep.lanes[0].retry_at = vec![f64::INFINITY, -5.0];
        render_lanes_ascii(&rep, 60);
    }

    /// Width 1 is the degenerate shared-core edge: every segment and
    /// marker collapses onto one cell, the later paint wins, and nothing
    /// indexes out of bounds.
    #[test]
    fn lanes_width_one_never_panics() {
        let s = render_lanes_ascii(&lane_report(), 1);
        assert_eq!(s.lines().count(), 3, "header + 2 lanes");
        for row in s.lines().skip(1) {
            // the deadline marker is painted last and overwrites the one
            // cell, so the bar reads `|||` (pipe, deadline, pipe)
            assert!(row.contains("|||"), "single-cell bar holds the deadline: {s}");
        }
    }

    #[test]
    fn shard_lanes_width_one_never_panics() {
        let mut rep = lane_report();
        rep.shard_lanes[0].takeover = Some((1, 105.0, 106.0));
        let s = render_shard_lanes_ascii(&rep, 1);
        assert_eq!(s.lines().count(), 3, "header + 2 shard lanes");
        for row in s.lines().skip(1) {
            let bar = row.split('|').nth(1).unwrap();
            assert_eq!(bar, "B", "barrier marker wins the single cell: {s}");
        }
    }

    /// Zero-duration segments: plain `seg` paints nothing (an empty
    /// half-open interval), while the gather/takeover paths use the
    /// one-cell minimum and stay visible.
    #[test]
    fn zero_duration_segments() {
        let mut rep = lane_report();
        rep.lanes[0].compute = Some((50.0, 50.0));
        rep.lanes[0].upload = Some((50.0, 50.0));
        rep.lanes[0].download = None;
        let s = render_lanes_ascii(&rep, 60);
        let bar = s.lines().nth(1).unwrap().split('|').nth(1).unwrap().to_string();
        assert!(!bar.contains('#') && !bar.contains('^'), "empty segments paint nothing: {s}");

        // shard side: a shard ready exactly at compute end still shows a
        // one-cell gather mark, and a zero-cost takeover keeps its 'X'
        // (the marker overwrites the one-cell 't' span at the same spot)
        rep.shard_lanes[0].ready_at = rep.t_compute_end;
        rep.shard_lanes[1].takeover = Some((0, 105.0, 105.0));
        let s = render_shard_lanes_ascii(&rep, 60);
        let body: Vec<&str> = s.lines().skip(1).collect();
        let bar = |row: &str| row.split('|').nth(1).unwrap().to_string();
        assert!(bar(body[0]).contains('g'), "one-cell gather mark survives: {s}");
        assert!(bar(body[1]).contains('X'), "zero-cost takeover keeps its marker: {s}");
    }

    /// The mass-failure edge: every shard's host but one dies, all chunk
    /// ranges pile onto the lone survivor. Every dead lane shows the
    /// crash marker, takeover span, and reassignment annotation; the
    /// survivor's lane stays clean.
    #[test]
    fn shard_lanes_render_mass_failover() {
        let mut rep = lane_report();
        rep.t_comm_end = 400.0;
        rep.recovered_shards = 3;
        rep.shard_lanes = (0..4)
            .map(|s| ShardLane {
                shard: s,
                chunk0: s,
                chunk1: s + 1,
                ready_at: 104.0,
                applied_at: 380.0,
                bytes: 100,
                host: 3,
                takeover: if s < 3 { Some((s, 180.0, 350.0)) } else { None },
            })
            .collect();
        let s = render_shard_lanes_ascii(&rep, 60);
        assert!(s.lines().next().unwrap().contains("X crash detected"));
        let body: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(body.len(), 4);
        // inspect the painted bar between the pipes, not the annotations
        // (the word "host" contains a 't')
        let bar = |row: &str| row.split('|').nth(1).unwrap().to_string();
        for (i, row) in body.iter().take(3).enumerate() {
            assert!(bar(row).contains('X'), "crash marker in dead lane {i}: {s}");
            assert!(bar(row).contains('t'), "takeover span in dead lane {i}: {s}");
            assert!(
                row.contains(&format!("REASSIGNED {i}->3")),
                "annotation in dead lane {i}: {s}"
            );
        }
        assert!(!bar(body[3]).contains('X') && !bar(body[3]).contains('t'));
        assert!(!body[3].contains("REASSIGNED"));
        assert!(body[3].contains("host 3"));
        assert!(body.iter().all(|r| bar(r).contains('B')), "barrier survives fail-over: {s}");
    }
}
