//! Figure-3-style compute/communication timelines.
//!
//! Renders successive rounds as rows of black (compute) and red (sync)
//! segments over a time window — ASCII here, with a CSV emitter for
//! plotting.

use crate::coordinator::RoundReport;

/// One rendered timeline row.
#[derive(Debug, Clone)]
pub struct TimelineRow {
    pub round: usize,
    pub compute_s: f64,
    pub comm_s: f64,
}

/// Extract rows for a window of rounds.
pub fn rows(reports: &[RoundReport]) -> Vec<TimelineRow> {
    reports
        .iter()
        .map(|r| TimelineRow {
            round: r.round,
            compute_s: r.t_compute_end - r.t_start,
            comm_s: r.t_comm(),
        })
        .collect()
}

/// ASCII rendering: '#' = compute, '!' = sync, scaled to `width` columns
/// per row (the paper's Fig. 3 black/red bars).
pub fn render_ascii(rows: &[TimelineRow], width: usize) -> String {
    let mut out = String::new();
    for r in rows {
        let total = r.compute_s + r.comm_s;
        let comm_cols = ((r.comm_s / total.max(1e-9)) * width as f64).round() as usize;
        let comm_cols = comm_cols.clamp(usize::from(r.comm_s > 0.0), width);
        let compute_cols = width - comm_cols;
        out.push_str(&format!(
            "round {:>5} |{}{}| compute {:>7.1}s  sync {:>6.1}s  util {:>5.1}%\n",
            r.round,
            "#".repeat(compute_cols),
            "!".repeat(comm_cols),
            r.compute_s,
            r.comm_s,
            100.0 * r.compute_s / total.max(1e-9),
        ));
    }
    out
}

/// CSV emitter (round, t_compute, t_comm, utilization).
pub fn to_csv(rows: &[TimelineRow]) -> String {
    let mut s = String::from("round,compute_s,comm_s,utilization\n");
    for r in rows {
        let total = r.compute_s + r.comm_s;
        s.push_str(&format!(
            "{},{:.3},{:.3},{:.6}\n",
            r.round,
            r.compute_s,
            r.comm_s,
            r.compute_s / total.max(1e-9)
        ));
    }
    s
}

/// Mean utilization over rows.
pub fn mean_utilization(rows: &[TimelineRow]) -> f64 {
    if rows.is_empty() {
        return 0.0;
    }
    rows.iter()
        .map(|r| r.compute_s / (r.compute_s + r.comm_s).max(1e-9))
        .sum::<f64>()
        / rows.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(c: f64, s: f64) -> TimelineRow {
        TimelineRow { round: 0, compute_s: c, comm_s: s }
    }

    #[test]
    fn utilization_math() {
        let rs = [row(1200.0, 70.0)];
        let u = mean_utilization(&rs);
        // the paper's 20min/70s point: ~94.5%
        assert!((u - 0.9449).abs() < 0.001, "u={u}");
    }

    #[test]
    fn ascii_renders() {
        let s = render_ascii(&[row(1200.0, 70.0)], 60);
        assert!(s.contains('#') && s.contains('!'));
        assert!(s.contains("94.5%"));
    }

    #[test]
    fn csv_emits() {
        let s = to_csv(&[row(10.0, 1.0)]);
        assert!(s.starts_with("round,"));
        assert!(s.lines().count() == 2);
    }
}
