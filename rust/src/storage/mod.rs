//! Object-storage substrate (Cloudflare-R2 stand-in, paper §3).
//!
//! Peers upload compressed pseudo-gradients to *their own* bucket and
//! publish the location; the validator reads and scores them; every peer
//! downloads the selected set directly. This module provides the store
//! (buckets, keys, credentials, byte-accounted objects) — transfer *times*
//! come from `netsim`, which models each peer's link.

pub mod object_store;

pub use object_store::{Bucket, ObjectStore};
