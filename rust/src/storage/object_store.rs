//! In-memory object store with buckets, access keys and byte accounting.
//!
//! Mirrors the R2 usage in the paper: per-peer buckets with read
//! credentials shared over the network, read-after-write visibility, and
//! no peer-to-peer connectivity requirement. The store itself is
//! infinitely fast; link time is charged by `netsim`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Access credential for a bucket (the paper's peers publish read creds).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Credential(pub String);

/// One bucket: a key-value object namespace owned by a peer.
#[derive(Debug, Default)]
pub struct Bucket {
    objects: BTreeMap<String, Vec<u8>>,
    pub read_cred: Option<Credential>,
    pub bytes_stored: u64,
    pub puts: u64,
    pub gets: u64,
}

/// The whole store: bucket name -> bucket.
#[derive(Debug, Default)]
pub struct ObjectStore {
    buckets: BTreeMap<String, Bucket>,
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a bucket with a read credential; fails if it exists.
    pub fn create_bucket(&mut self, name: &str, read_cred: &str) -> Result<()> {
        if self.buckets.contains_key(name) {
            bail!("bucket '{name}' already exists");
        }
        self.buckets.insert(
            name.to_string(),
            Bucket { read_cred: Some(Credential(read_cred.to_string())), ..Default::default() },
        );
        Ok(())
    }

    pub fn delete_bucket(&mut self, name: &str) -> Result<()> {
        self.buckets
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| anyhow!("bucket '{name}' not found"))
    }

    pub fn bucket(&self, name: &str) -> Result<&Bucket> {
        self.buckets.get(name).ok_or_else(|| anyhow!("bucket '{name}' not found"))
    }

    /// Owner-side put (no credential needed — owners write their bucket).
    pub fn put(&mut self, bucket: &str, key: &str, data: Vec<u8>) -> Result<usize> {
        let b = self
            .buckets
            .get_mut(bucket)
            .ok_or_else(|| anyhow!("bucket '{bucket}' not found"))?;
        let len = data.len();
        if let Some(old) = b.objects.insert(key.to_string(), data) {
            b.bytes_stored -= old.len() as u64;
        }
        b.bytes_stored += len as u64;
        b.puts += 1;
        Ok(len)
    }

    /// Credentialed read (any peer with the published credential).
    pub fn get(&mut self, bucket: &str, key: &str, cred: &str) -> Result<Vec<u8>> {
        let b = self
            .buckets
            .get_mut(bucket)
            .ok_or_else(|| anyhow!("bucket '{bucket}' not found"))?;
        match &b.read_cred {
            Some(Credential(c)) if c == cred => {}
            Some(_) => bail!("bad credential for bucket '{bucket}'"),
            None => bail!("bucket '{bucket}' is not readable"),
        }
        b.gets += 1;
        b.objects
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("object '{bucket}/{key}' not found"))
    }

    /// Object size without transferring it (HEAD).
    pub fn head(&self, bucket: &str, key: &str) -> Result<usize> {
        Ok(self
            .bucket(bucket)?
            .objects
            .get(key)
            .ok_or_else(|| anyhow!("object '{bucket}/{key}' not found"))?
            .len())
    }

    /// List keys with a prefix.
    pub fn list(&self, bucket: &str, prefix: &str) -> Result<Vec<String>> {
        Ok(self
            .bucket(bucket)?
            .objects
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect())
    }

    pub fn delete(&mut self, bucket: &str, key: &str) -> Result<()> {
        let b = self
            .buckets
            .get_mut(bucket)
            .ok_or_else(|| anyhow!("bucket '{bucket}' not found"))?;
        match b.objects.remove(key) {
            Some(old) => {
                b.bytes_stored -= old.len() as u64;
                Ok(())
            }
            None => bail!("object '{bucket}/{key}' not found"),
        }
    }

    /// Total bytes across all buckets.
    pub fn total_bytes(&self) -> u64 {
        self.buckets.values().map(|b| b.bytes_stored).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut s = ObjectStore::new();
        s.create_bucket("peer-0", "cred0").unwrap();
        s.put("peer-0", "round-1/grad.bin", vec![1, 2, 3]).unwrap();
        assert_eq!(s.get("peer-0", "round-1/grad.bin", "cred0").unwrap(), vec![1, 2, 3]);
        assert_eq!(s.head("peer-0", "round-1/grad.bin").unwrap(), 3);
    }

    #[test]
    fn credential_enforced() {
        let mut s = ObjectStore::new();
        s.create_bucket("peer-0", "cred0").unwrap();
        s.put("peer-0", "x", vec![0]).unwrap();
        assert!(s.get("peer-0", "x", "wrong").is_err());
    }

    #[test]
    fn overwrite_accounting() {
        let mut s = ObjectStore::new();
        s.create_bucket("b", "c").unwrap();
        s.put("b", "k", vec![0; 100]).unwrap();
        s.put("b", "k", vec![0; 40]).unwrap();
        assert_eq!(s.bucket("b").unwrap().bytes_stored, 40);
        assert_eq!(s.total_bytes(), 40);
    }

    #[test]
    fn list_prefix() {
        let mut s = ObjectStore::new();
        s.create_bucket("b", "c").unwrap();
        s.put("b", "r1/a", vec![]).unwrap();
        s.put("b", "r1/b", vec![]).unwrap();
        s.put("b", "r2/a", vec![]).unwrap();
        assert_eq!(s.list("b", "r1/").unwrap().len(), 2);
    }

    #[test]
    fn missing_errors() {
        let mut s = ObjectStore::new();
        assert!(s.get("nope", "k", "c").is_err());
        s.create_bucket("b", "c").unwrap();
        assert!(s.get("b", "nope", "c").is_err());
        assert!(s.delete("b", "nope").is_err());
        assert!(s.create_bucket("b", "c2").is_err());
    }

    #[test]
    fn delete_bucket_and_object() {
        let mut s = ObjectStore::new();
        s.create_bucket("b", "c").unwrap();
        s.put("b", "k", vec![9; 10]).unwrap();
        s.delete("b", "k").unwrap();
        assert_eq!(s.total_bytes(), 0);
        s.delete_bucket("b").unwrap();
        assert!(s.bucket("b").is_err());
    }
}
