//! Subnet state machine: UIDs, hotkeys, stake, weights, emissions.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::sparseloco::envelope::VerifyingKey;

/// One registered slot in the subnet's UID table.
#[derive(Debug, Clone)]
pub struct Neuron {
    pub uid: usize,
    /// Current owner hotkey (changes when the UID is recycled).
    pub hotkey: String,
    pub stake: f64,
    /// Block at which the current owner registered.
    pub registered_at: u64,
    /// Validator-assigned weight (normalized at emission time).
    pub weight: f64,
    /// Cumulative rewards earned by the *current* owner.
    pub emissions: f64,
    pub active: bool,
}

/// A Bittensor-like subnet with a bounded UID table.
#[derive(Debug)]
pub struct Subnet {
    pub netuid: u32,
    pub max_uids: usize,
    pub block: u64,
    /// Seconds per block (Bittensor: 12s).
    pub block_time_s: f64,
    neurons: Vec<Option<Neuron>>,
    /// Registration fee burned on entry (recycle cost).
    pub burn: f64,
    /// Emission per block distributed by weight.
    pub emission_per_block: f64,
    /// All hotkeys ever seen with their first-registration block
    /// (ground truth for Fig. 5's "lower bound" comparison).
    pub hotkey_history: BTreeMap<String, u64>,
    /// Payload-verification key registry for *currently registered*
    /// hotkeys. Registration is permissionless (any registered hotkey may
    /// publish any key, including one shared with other hotkeys — sybil
    /// swarms do exactly that); the entry is dropped with the hotkey, on
    /// deregistration or UID recycling, so a recycled UID's new owner
    /// never inherits the old key.
    keys: BTreeMap<String, VerifyingKey>,
}

impl Subnet {
    pub fn new(netuid: u32, max_uids: usize) -> Self {
        Self {
            netuid,
            max_uids,
            block: 0,
            block_time_s: 12.0,
            neurons: vec![None; max_uids],
            burn: 1.0,
            emission_per_block: 1.0,
            hotkey_history: BTreeMap::new(),
            keys: BTreeMap::new(),
        }
    }

    /// Advance the chain to the given simulated time.
    pub fn sync_to_time(&mut self, t: f64) {
        let target = (t / self.block_time_s) as u64;
        while self.block < target {
            self.block += 1;
            self.emit_block();
        }
    }

    fn emit_block(&mut self) {
        let total_w: f64 = self
            .neurons
            .iter()
            .flatten()
            .filter(|n| n.active)
            .map(|n| n.weight)
            .sum();
        if total_w <= 0.0 {
            return;
        }
        for n in self.neurons.iter_mut().flatten() {
            if n.active && n.weight > 0.0 {
                let share = self.emission_per_block * n.weight / total_w;
                n.emissions += share;
                n.stake += share;
            }
        }
    }

    /// Register a hotkey; recycles the lowest-stake inactive (then active)
    /// UID when the table is full. Returns the assigned UID.
    pub fn register(&mut self, hotkey: &str, stake: f64) -> Result<usize> {
        if self.uid_of(hotkey).is_some() {
            bail!("hotkey '{hotkey}' already registered");
        }
        self.hotkey_history.entry(hotkey.to_string()).or_insert(self.block);
        let uid = match self.neurons.iter().position(|n| n.is_none()) {
            Some(free) => free,
            None => {
                // Recycle: prefer inactive, lowest stake.
                let victim = self
                    .neurons
                    .iter()
                    .enumerate()
                    .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
                    .min_by(|(_, a), (_, b)| {
                        (a.active, a.stake)
                            .partial_cmp(&(b.active, b.stake))
                            .unwrap()
                    })
                    .map(|(i, _)| i)
                    .ok_or_else(|| anyhow!("no UID to recycle"))?;
                victim
            }
        };
        // Evict the victim hotkey's key along with its slot: the UID's
        // new owner starts with no registered key.
        if let Some(old) = &self.neurons[uid] {
            let old_hotkey = old.hotkey.clone();
            self.keys.remove(&old_hotkey);
        }
        self.neurons[uid] = Some(Neuron {
            uid,
            hotkey: hotkey.to_string(),
            stake: (stake - self.burn).max(0.0),
            registered_at: self.block,
            weight: 0.0,
            emissions: 0.0,
            active: true,
        });
        Ok(uid)
    }

    /// Deregister (peer leaves voluntarily); the UID becomes free and the
    /// hotkey's verification key leaves the registry with it.
    pub fn deregister(&mut self, hotkey: &str) -> Result<()> {
        let uid = self.uid_of(hotkey).ok_or_else(|| anyhow!("hotkey '{hotkey}' not registered"))?;
        self.neurons[uid] = None;
        self.keys.remove(hotkey);
        Ok(())
    }

    /// Publish the payload-verification key for a registered hotkey
    /// (overwrites any previous key for the same hotkey — key rotation).
    pub fn register_key(&mut self, hotkey: &str, key: VerifyingKey) -> Result<()> {
        if self.uid_of(hotkey).is_none() {
            bail!("hotkey '{hotkey}' not registered; cannot publish a key");
        }
        self.keys.insert(hotkey.to_string(), key);
        Ok(())
    }

    /// The currently registered verification key for a hotkey, if any.
    pub fn verifying_key(&self, hotkey: &str) -> Option<VerifyingKey> {
        self.keys.get(hotkey).copied()
    }

    /// Mark liveness (peers that stop submitting go inactive).
    pub fn set_active(&mut self, hotkey: &str, active: bool) -> Result<()> {
        let uid = self.uid_of(hotkey).ok_or_else(|| anyhow!("hotkey '{hotkey}' not registered"))?;
        self.neurons[uid].as_mut().unwrap().active = active;
        Ok(())
    }

    /// Validator weight-setting (Gauntlet scores -> on-chain weights).
    pub fn set_weights(&mut self, weights: &[(usize, f64)]) -> Result<()> {
        for &(uid, w) in weights {
            if w < 0.0 || !w.is_finite() {
                bail!("invalid weight {w} for uid {uid}");
            }
            let n = self
                .neurons
                .get_mut(uid)
                .and_then(|n| n.as_mut())
                .ok_or_else(|| anyhow!("uid {uid} not registered"))?;
            n.weight = w;
        }
        Ok(())
    }

    pub fn uid_of(&self, hotkey: &str) -> Option<usize> {
        self.neurons
            .iter()
            .flatten()
            .find(|n| n.hotkey == hotkey)
            .map(|n| n.uid)
    }

    pub fn neuron(&self, uid: usize) -> Option<&Neuron> {
        self.neurons.get(uid).and_then(|n| n.as_ref())
    }

    pub fn neurons(&self) -> impl Iterator<Item = &Neuron> {
        self.neurons.iter().flatten()
    }

    pub fn registered_count(&self) -> usize {
        self.neurons.iter().flatten().count()
    }

    /// Count of UIDs ever handed out is capped, but hotkey history keeps
    /// the true unique-participant count (Fig. 5 is a lower bound because
    /// the paper only tracks UIDs).
    pub fn unique_hotkeys_ever(&self) -> usize {
        self.hotkey_history.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_assigns_uids() {
        let mut s = Subnet::new(3, 4);
        let a = s.register("hk-a", 10.0).unwrap();
        let b = s.register("hk-b", 10.0).unwrap();
        assert_ne!(a, b);
        assert_eq!(s.registered_count(), 2);
        assert!(s.register("hk-a", 10.0).is_err()); // duplicate
    }

    #[test]
    fn recycles_lowest_stake_when_full() {
        let mut s = Subnet::new(3, 2);
        s.register("a", 10.0).unwrap();
        s.register("b", 5.0).unwrap();
        let uid_b = s.uid_of("b").unwrap();
        // table full: "c" takes b's UID (lowest stake)
        let uid_c = s.register("c", 20.0).unwrap();
        assert_eq!(uid_b, uid_c);
        assert!(s.uid_of("b").is_none());
        // history keeps all three
        assert_eq!(s.unique_hotkeys_ever(), 3);
    }

    #[test]
    fn inactive_recycled_before_active() {
        let mut s = Subnet::new(3, 2);
        s.register("a", 1.0).unwrap();
        s.register("b", 100.0).unwrap();
        s.set_active("b", false).unwrap();
        let uid_b = s.uid_of("b").unwrap();
        let uid_c = s.register("c", 1.0).unwrap();
        assert_eq!(uid_b, uid_c, "inactive high-stake UID should recycle first");
    }

    #[test]
    fn emissions_follow_weights() {
        let mut s = Subnet::new(3, 4);
        let a = s.register("a", 0.0).unwrap();
        let b = s.register("b", 0.0).unwrap();
        s.set_weights(&[(a, 3.0), (b, 1.0)]).unwrap();
        s.sync_to_time(120.0); // 10 blocks
        let ea = s.neuron(a).unwrap().emissions;
        let eb = s.neuron(b).unwrap().emissions;
        assert!((ea / eb - 3.0).abs() < 1e-9, "{ea} vs {eb}");
        assert!((ea + eb - 10.0).abs() < 1e-9);
    }

    #[test]
    fn weight_validation() {
        let mut s = Subnet::new(3, 2);
        let a = s.register("a", 0.0).unwrap();
        assert!(s.set_weights(&[(a, -1.0)]).is_err());
        assert!(s.set_weights(&[(a, f64::NAN)]).is_err());
        assert!(s.set_weights(&[(99, 1.0)]).is_err());
    }

    #[test]
    fn block_time() {
        let mut s = Subnet::new(3, 2);
        s.sync_to_time(60.0);
        assert_eq!(s.block, 5);
    }

    // ---- key registry / recycled-UID hygiene ----------------------------

    use crate::sparseloco::envelope::SigningKey;

    #[test]
    fn key_registration_requires_a_registered_hotkey() {
        let mut s = Subnet::new(3, 2);
        let key = SigningKey::derive(1, "ghost").verifying();
        assert!(s.register_key("ghost", key).is_err());
        s.register("a", 1.0).unwrap();
        let ka = SigningKey::derive(1, "a").verifying();
        s.register_key("a", ka).unwrap();
        assert_eq!(s.verifying_key("a"), Some(ka));
        assert_eq!(s.verifying_key("ghost"), None);
        // rotation: a later registration overwrites
        let ka2 = SigningKey::derive(2, "a").verifying();
        s.register_key("a", ka2).unwrap();
        assert_eq!(s.verifying_key("a"), Some(ka2));
    }

    #[test]
    fn deregistration_drops_the_key() {
        let mut s = Subnet::new(3, 2);
        s.register("a", 1.0).unwrap();
        s.register_key("a", SigningKey::derive(1, "a").verifying()).unwrap();
        s.deregister("a").unwrap();
        assert_eq!(s.verifying_key("a"), None);
        // re-registering the hotkey does NOT resurrect the old key
        s.register("a", 1.0).unwrap();
        assert_eq!(s.verifying_key("a"), None);
    }

    #[test]
    fn recycled_uid_with_fresh_hotkey_inherits_neither_key_nor_scores() {
        let mut s = Subnet::new(3, 2);
        s.register("a", 10.0).unwrap();
        let uid_b = s.register("b", 1.0).unwrap();
        s.register_key("b", SigningKey::derive(1, "b").verifying()).unwrap();
        // give b on-chain standing: weight and accumulated emissions
        s.set_weights(&[(uid_b, 1.0)]).unwrap();
        s.sync_to_time(120.0);
        assert!(s.neuron(uid_b).unwrap().emissions > 0.0);
        // table full: "c" recycles b's UID (lowest stake)
        let uid_c = s.register("c", 20.0).unwrap();
        assert_eq!(uid_c, uid_b);
        // b's key is gone with b — c starts keyless until it publishes
        assert_eq!(s.verifying_key("b"), None);
        assert_eq!(s.verifying_key("c"), None);
        let kc = SigningKey::derive(1, "c").verifying();
        s.register_key("c", kc).unwrap();
        assert_eq!(s.verifying_key("c"), Some(kc));
        // and c's key is its own, not b's
        assert_ne!(kc, SigningKey::derive(1, "b").verifying());
        // no inherited scores: weight, emissions, stake all reset
        let n = s.neuron(uid_c).unwrap();
        assert_eq!(n.hotkey, "c");
        assert_eq!(n.weight, 0.0, "recycled UID inherited the old weight");
        assert_eq!(n.emissions, 0.0, "recycled UID inherited old emissions");
        assert_eq!(n.stake, 20.0 - s.burn);
    }

    #[test]
    fn sybil_swarm_may_share_one_key_registration_is_permissionless() {
        // The chain does not police key reuse — the Gauntlet's per-key
        // replay window is what makes a shared key useless (one
        // submission per round for the whole swarm).
        let mut s = Subnet::new(3, 4);
        let shared = SigningKey::derive(7, "sybil-shared").verifying();
        for hk in ["s0", "s1", "s2"] {
            s.register(hk, 1.0).unwrap();
            s.register_key(hk, shared).unwrap();
        }
        for hk in ["s0", "s1", "s2"] {
            assert_eq!(s.verifying_key(hk), Some(shared));
        }
    }
}
