//! Blockchain substrate (Bittensor-subnet stand-in, paper §3).
//!
//! Provides the coordination primitives Gauntlet needs: hotkey
//! registration into a bounded UID table (with recycling of the
//! lowest-stake UID when full — the reason Fig. 5's unique-participant
//! count is a lower bound), block production tied to the virtual clock,
//! validator weight-setting, and per-round emissions.

pub mod subnet;

pub use subnet::{Neuron, Subnet};
