//! Pre-tokenized shard hosting + assigned-shard batch sampling.
//!
//! Paper §4.1: "we pre-tokenize all data and host shards on object
//! storage. Peers download shards ahead of time, replacing consumed
//! shards in the background." And §2.2: each peer is assigned a
//! (potentially overlapping) subset of data; the validator scores
//! submissions on assigned vs unassigned data.
//!
//! Shards are u16-LE token arrays keyed `shards/<kind>/<id>.tok` in a
//! `data` bucket. Assignment is deterministic per (round, uid).

use anyhow::{ensure, Result};

use super::grammar::{Grammar, GrammarKind};
use crate::storage::ObjectStore;
use crate::util::rng::Rng;

pub const DATA_BUCKET: &str = "data";
pub const DATA_CRED: &str = "data-public";

/// Encode tokens as u16 little-endian bytes.
pub fn encode_tokens(tokens: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(tokens.len() * 2);
    for &t in tokens {
        debug_assert!((0..65536).contains(&t));
        out.extend_from_slice(&(t as u16).to_le_bytes());
    }
    out
}

/// Decode u16-LE bytes back to tokens.
pub fn decode_tokens(bytes: &[u8]) -> Result<Vec<i32>> {
    ensure!(bytes.len() % 2 == 0, "shard byte length not even");
    Ok(bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]) as i32)
        .collect())
}

fn kind_name(kind: GrammarKind) -> &'static str {
    match kind {
        GrammarKind::Web => "web",
        GrammarKind::HighQuality => "hq",
        GrammarKind::Instruction => "inst",
    }
}

/// Generates shards into the object store and serves them.
pub struct ShardStore {
    pub grammar: Grammar,
    pub shard_tokens: usize,
    pub n_shards: usize,
    /// Tail shards reserved as *unassigned* validation data (Gauntlet's
    /// anti-copy check evaluates on data assigned to no peer, §2.2).
    pub reserved: usize,
}

impl ShardStore {
    pub fn new(grammar: Grammar, shard_tokens: usize, n_shards: usize) -> Self {
        let reserved = (n_shards / 8).max(1);
        Self { grammar, shard_tokens, n_shards, reserved }
    }

    /// Shards available for peer assignment (excludes reserved tail).
    pub fn n_assignable(&self) -> usize {
        self.n_shards - self.reserved
    }

    /// A reserved (never-assigned) shard id.
    pub fn reserved_shard(&self, i: usize) -> usize {
        self.n_assignable() + i % self.reserved
    }

    /// Publish all shards of a mixture into the store (idempotent).
    pub fn publish(&self, store: &mut ObjectStore, kind: GrammarKind) -> Result<u64> {
        if store.bucket(DATA_BUCKET).is_err() {
            store.create_bucket(DATA_BUCKET, DATA_CRED)?;
        }
        let mut bytes = 0u64;
        for id in 0..self.n_shards {
            let key = format!("shards/{}/{id}.tok", kind_name(kind));
            let toks = self.grammar.stream(kind, id as u64, self.shard_tokens);
            bytes += (toks.len() * 2) as u64;
            store.put(DATA_BUCKET, &key, encode_tokens(&toks))?;
        }
        Ok(bytes)
    }

    /// Fetch one shard (peer-side download; link time charged by caller).
    pub fn fetch(
        &self,
        store: &mut ObjectStore,
        kind: GrammarKind,
        id: usize,
    ) -> Result<Vec<i32>> {
        let key = format!("shards/{}/{id}.tok", kind_name(kind));
        decode_tokens(&store.get(DATA_BUCKET, &key, DATA_CRED)?)
    }

    /// Shard byte size (for netsim download accounting).
    pub fn shard_bytes(&self) -> usize {
        self.shard_tokens * 2
    }

    /// Deterministic shard assignment for a peer: `n_assigned` shard ids,
    /// overlapping across peers (paper: "potentially overlapping subset").
    pub fn assign(&self, uid: usize, round: usize, n_assigned: usize) -> Vec<usize> {
        let mut rng = Rng::new(
            (uid as u64)
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(round as u64),
        );
        (0..n_assigned).map(|_| rng.below(self.n_assignable())).collect()
    }
}

/// Samples fixed-shape training batches out of downloaded shards.
#[derive(Debug, Clone)]
pub struct BatchSampler {
    pub seq_len: usize,
    pub batch_size: usize,
    rng: Rng,
    tokens: Vec<i32>,
}

impl BatchSampler {
    /// `tokens`: concatenation of the peer's downloaded shards.
    pub fn new(tokens: Vec<i32>, seq_len: usize, batch_size: usize, seed: u64) -> Self {
        assert!(tokens.len() > seq_len + 1, "not enough tokens for one sequence");
        Self { seq_len, batch_size, rng: Rng::new(seed), tokens }
    }

    /// One batch: `[B, T+1]` tokens, row-major.
    pub fn batch(&mut self) -> Vec<i32> {
        let span = self.seq_len + 1;
        let mut out = Vec::with_capacity(self.batch_size * span);
        for _ in 0..self.batch_size {
            let start = self.rng.below(self.tokens.len() - span);
            out.extend_from_slice(&self.tokens[start..start + span]);
        }
        out
    }

    /// `h` stacked batches: `[H, B, T+1]` row-major (the train_round input).
    pub fn round_batch(&mut self, h: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(h * self.batch_size * (self.seq_len + 1));
        for _ in 0..h {
            out.extend(self.batch());
        }
        out
    }

    /// All-ones loss mask matching `batch()` ([B, T]).
    pub fn ones_mask(&self) -> Vec<f32> {
        vec![1.0; self.batch_size * self.seq_len]
    }

    /// All-ones loss mask matching `round_batch(h)` ([H, B, T]).
    pub fn ones_round_mask(&self, h: usize) -> Vec<f32> {
        vec![1.0; h * self.batch_size * self.seq_len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_setup() -> (ObjectStore, ShardStore) {
        let g = Grammar::new(512, 1);
        (ObjectStore::new(), ShardStore::new(g, 4096, 8))
    }

    #[test]
    fn encode_decode_roundtrip() {
        let toks: Vec<i32> = (0..5000).map(|i| i % 512).collect();
        assert_eq!(decode_tokens(&encode_tokens(&toks)).unwrap(), toks);
        assert!(decode_tokens(&[1, 2, 3]).is_err());
    }

    #[test]
    fn publish_fetch_roundtrip() {
        let (mut store, ss) = store_setup();
        let bytes = ss.publish(&mut store, GrammarKind::Web).unwrap();
        assert_eq!(bytes, 8 * 4096 * 2);
        let t0 = ss.fetch(&mut store, GrammarKind::Web, 0).unwrap();
        assert_eq!(t0.len(), 4096);
        // deterministic: same as regenerating
        assert_eq!(t0, ss.grammar.stream(GrammarKind::Web, 0, 4096));
    }

    #[test]
    fn assignments_deterministic_and_overlapping() {
        let (_, ss) = store_setup();
        let a1 = ss.assign(3, 10, 4);
        let a2 = ss.assign(3, 10, 4);
        assert_eq!(a1, a2);
        let b = ss.assign(4, 10, 4);
        assert_ne!(a1, b); // different peers -> different (w.h.p.)
        // assignments never touch the reserved tail
        assert!(a1.iter().all(|&s| s < ss.n_assignable()));
    }

    #[test]
    fn reserved_shards_disjoint_from_assignable() {
        let (_, ss) = store_setup();
        assert!(ss.reserved >= 1);
        for i in 0..ss.reserved {
            assert!(ss.reserved_shard(i) >= ss.n_assignable());
            assert!(ss.reserved_shard(i) < ss.n_shards);
        }
    }

    #[test]
    fn batch_shapes() {
        let toks: Vec<i32> = (0..10_000).map(|i| i % 512).collect();
        let mut bs = BatchSampler::new(toks, 32, 4, 7);
        assert_eq!(bs.batch().len(), 4 * 33);
        assert_eq!(bs.round_batch(5).len(), 5 * 4 * 33);
        assert_eq!(bs.ones_mask().len(), 4 * 32);
        assert_eq!(bs.ones_round_mask(5).len(), 5 * 4 * 32);
    }

    #[test]
    fn batches_deterministic_per_seed() {
        let toks: Vec<i32> = (0..10_000).map(|i| i % 512).collect();
        let mut a = BatchSampler::new(toks.clone(), 32, 4, 7);
        let mut b = BatchSampler::new(toks.clone(), 32, 4, 7);
        assert_eq!(a.batch(), b.batch());
        let mut c = BatchSampler::new(toks, 32, 4, 8);
        assert_ne!(a.batch(), c.batch());
    }
}
