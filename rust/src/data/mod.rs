//! Data substrate: synthetic pre-tokenized corpus + shard hosting.
//!
//! The paper trains on ~1.1T DCLM tokens pre-tokenized into shards hosted
//! on object storage (§4.1); peers download assigned shards ahead of time.
//! Here the corpus is a deterministic synthetic token language with
//! learnable structure at three levels (separator statistics, Markov
//! filler chains, and a fact table used by the multiple-choice evals), so
//! loss curves and benchmark accuracies measure real learning. Shards are
//! generated per (seed, shard_id), stored in the object store, and
//! assigned to peers in overlapping subsets exactly as Gauntlet expects
//! (assigned vs unassigned data, §2.2).

pub mod grammar;
pub mod shards;

pub use grammar::{Grammar, GrammarKind};
pub use shards::{BatchSampler, ShardStore};
