//! Deterministic synthetic token language.
//!
//! Three nested levels of learnable structure:
//! 1. *Separator/marker statistics* — learned within a few steps (fast
//!    visible loss drop from ln V).
//! 2. *Markov filler chains* — each filler token has `BRANCH` equally
//!    likely successors (entropy ln BRANCH nats), defined by hashing, so
//!    the floor is known analytically.
//! 3. *Fact table* — (subject, relation) -> object, a deterministic
//!    mapping; the multiple-choice eval suites (synthetic ARC/MMLU
//!    analogues) test exactly this knowledge.
//!
//! `GrammarKind` variants reproduce the paper's data phases: `Web` (main
//! pre-training mix), `HighQuality` (annealing mix, §4.1 — denser facts,
//! less noise), `Instruction` (SFT mix, §5 — Q/A format with answer-masked
//! loss).

use crate::util::rng::Rng;

/// Special tokens.
pub const BOS: i32 = 0;
pub const SEP: i32 = 1;
pub const QMARK: i32 = 2; // "question" marker (instruction data)
pub const AMARK: i32 = 3; // "answer" marker

const N_SPECIAL: usize = 4;
/// Successors per filler token (entropy floor = ln(BRANCH) nats).
pub const BRANCH: usize = 4;

/// Which data mixture to generate (paper §4.1/§5 phases).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GrammarKind {
    /// Main pre-training web mix: 50% facts, 50% filler.
    Web,
    /// Annealing mix: fact-dense, low-noise "curated" data.
    HighQuality,
    /// SFT mix: QMARK s r AMARK o — with loss masked to the answer.
    Instruction,
}

/// The synthetic language for one vocab size.
#[derive(Debug, Clone)]
pub struct Grammar {
    pub vocab_size: usize,
    pub n_subjects: usize,
    pub n_relations: usize,
    pub n_objects: usize,
    /// Global corpus seed: defines the fact table + Markov transitions.
    pub world_seed: u64,
    subj0: usize,
    rel0: usize,
    obj0: usize,
    filler0: usize,
    n_filler: usize,
}

fn mix(seed: u64, a: u64, b: u64) -> u64 {
    let mut z = seed ^ a.wrapping_mul(0x9E3779B97F4A7C15) ^ b.wrapping_mul(0xD1B54A32D192ED03);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Grammar {
    pub fn new(vocab_size: usize, world_seed: u64) -> Self {
        assert!(vocab_size >= 256, "vocab too small for the grammar");
        let n_subjects = 64;
        let n_relations = 16;
        let n_objects = 64;
        let subj0 = N_SPECIAL;
        let rel0 = subj0 + n_subjects;
        let obj0 = rel0 + n_relations;
        let filler0 = obj0 + n_objects;
        Self {
            vocab_size,
            n_subjects,
            n_relations,
            n_objects,
            world_seed,
            subj0,
            rel0,
            obj0,
            filler0,
            n_filler: vocab_size - filler0,
        }
    }

    // ---- token id helpers --------------------------------------------------
    pub fn subject(&self, i: usize) -> i32 {
        (self.subj0 + i % self.n_subjects) as i32
    }

    pub fn relation(&self, i: usize) -> i32 {
        (self.rel0 + i % self.n_relations) as i32
    }

    pub fn object(&self, i: usize) -> i32 {
        (self.obj0 + i % self.n_objects) as i32
    }

    /// The deterministic fact table: (subject index, relation index) -> object index.
    pub fn fact_object(&self, s: usize, r: usize) -> usize {
        (mix(self.world_seed, s as u64, r as u64) % self.n_objects as u64) as usize
    }

    /// Markov successor j in [0, BRANCH) of filler token index f.
    fn filler_next(&self, f: usize, j: usize) -> usize {
        (mix(self.world_seed ^ 0xF1EE, f as u64, j as u64) % self.n_filler as u64) as usize
    }

    /// Zipf-ish sample over n items (weight 1/(1+i)).
    fn zipf(&self, rng: &mut Rng, n: usize) -> usize {
        // inverse-CDF on harmonic weights via rejection-free approximation:
        // draw u, return floor(exp(u * ln(n+1))) - 1 (log-uniform).
        let u = rng.f64();
        let x = ((n as f64 + 1.0).powf(u)) - 1.0;
        (x as usize).min(n - 1)
    }

    // ---- generation --------------------------------------------------------
    /// Append one sentence to `out`.
    pub fn sentence(&self, kind: GrammarKind, rng: &mut Rng, out: &mut Vec<i32>) {
        let p_fact = match kind {
            GrammarKind::Web => 0.5,
            GrammarKind::HighQuality => 0.85,
            GrammarKind::Instruction => 1.0,
        };
        if rng.f64() < p_fact {
            let s = self.zipf(rng, self.n_subjects);
            let r = self.zipf(rng, self.n_relations);
            let o = self.fact_object(s, r);
            match kind {
                GrammarKind::Instruction => {
                    out.push(QMARK);
                    out.push(self.subject(s));
                    out.push(self.relation(r));
                    out.push(AMARK);
                    out.push(self.object(o));
                }
                _ => {
                    out.push(self.subject(s));
                    out.push(self.relation(r));
                    out.push(self.object(o));
                }
            }
        } else {
            // Filler run: Markov chain, length 4..12.
            let len = rng.range(4, 12);
            let mut f = rng.below(self.n_filler);
            for _ in 0..len {
                out.push((self.filler0 + f) as i32);
                f = self.filler_next(f, rng.below(BRANCH));
            }
        }
        out.push(SEP);
    }

    /// Generate a token stream of exactly `len` tokens (BOS-started).
    pub fn stream(&self, kind: GrammarKind, seed: u64, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(mix(self.world_seed, seed, 0x57EA));
        let mut out = Vec::with_capacity(len + 16);
        out.push(BOS);
        while out.len() < len {
            self.sentence(kind, &mut rng, &mut out);
        }
        out.truncate(len);
        out
    }

    /// Analytic entropy floor of the filler process (nats/token).
    pub fn filler_entropy_floor(&self) -> f64 {
        (BRANCH as f64).ln()
    }

    /// A multiple-choice fact query: returns (prompt, correct object token,
    /// distractor object tokens). Distractors are other objects, distinct
    /// from the correct one.
    pub fn mc_fact_query(
        &self,
        rng: &mut Rng,
        n_choices: usize,
        hard: bool,
    ) -> (Vec<i32>, i32, Vec<i32>) {
        // Easy suite: frequent (low-index) subjects; hard: tail subjects.
        let s = if hard {
            self.n_subjects - 1 - self.zipf(rng, self.n_subjects / 2)
        } else {
            self.zipf(rng, self.n_subjects / 2)
        };
        let r = rng.below(self.n_relations);
        let o = self.fact_object(s, r);
        let prompt = vec![BOS, self.subject(s), self.relation(r)];
        let mut distractors = Vec::new();
        let mut d = (o + 1) % self.n_objects;
        while distractors.len() < n_choices - 1 {
            if d != o {
                distractors.push(self.object(d));
            }
            d = (d + 1 + rng.below(self.n_objects - 2)) % self.n_objects;
        }
        (prompt, self.object(o), distractors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> Grammar {
        Grammar::new(512, 42)
    }

    #[test]
    fn deterministic_streams() {
        let a = g().stream(GrammarKind::Web, 7, 1000);
        let b = g().stream(GrammarKind::Web, 7, 1000);
        assert_eq!(a, b);
        let c = g().stream(GrammarKind::Web, 8, 1000);
        assert_ne!(a, c);
    }

    #[test]
    fn tokens_in_range() {
        for kind in [GrammarKind::Web, GrammarKind::HighQuality, GrammarKind::Instruction] {
            let s = g().stream(kind, 1, 5000);
            assert!(s.iter().all(|&t| t >= 0 && (t as usize) < 512));
        }
    }

    #[test]
    fn facts_are_consistent() {
        let gr = g();
        for s in 0..gr.n_subjects {
            for r in 0..gr.n_relations {
                assert_eq!(gr.fact_object(s, r), gr.fact_object(s, r));
                assert!(gr.fact_object(s, r) < gr.n_objects);
            }
        }
    }

    #[test]
    fn corpus_contains_facts_matching_table() {
        // Scan web stream for (subj, rel, obj) triples; every complete
        // triple must match the fact table.
        let gr = g();
        let s = gr.stream(GrammarKind::Web, 3, 20_000);
        let subj_range = |t: i32| {
            (t as usize) >= gr.subj0 && (t as usize) < gr.subj0 + gr.n_subjects
        };
        let mut found = 0;
        for w in s.windows(3) {
            if subj_range(w[0]) {
                let si = w[0] as usize - gr.subj0;
                let ri = w[1] as usize - gr.rel0;
                if ri < gr.n_relations {
                    let oi = w[2] as usize - gr.obj0;
                    assert_eq!(oi, gr.fact_object(si, ri));
                    found += 1;
                }
            }
        }
        assert!(found > 100, "too few facts in stream: {found}");
    }

    #[test]
    fn instruction_format() {
        let gr = g();
        let s = gr.stream(GrammarKind::Instruction, 5, 1000);
        // every QMARK is followed by subj, rel, AMARK, obj, SEP
        for (i, &t) in s.iter().enumerate() {
            if t == QMARK && i + 5 < s.len() {
                assert_eq!(s[i + 3], AMARK);
                assert_eq!(s[i + 5], SEP);
            }
        }
    }

    #[test]
    fn mc_query_distractors_distinct() {
        let gr = g();
        let mut rng = Rng::new(1);
        for hard in [false, true] {
            for _ in 0..100 {
                let (prompt, correct, ds) = gr.mc_fact_query(&mut rng, 4, hard);
                assert_eq!(prompt.len(), 3);
                assert_eq!(ds.len(), 3);
                assert!(!ds.contains(&correct));
            }
        }
    }

    #[test]
    fn high_quality_is_fact_denser() {
        let gr = g();
        let count_seps_facts = |kind| {
            let s = gr.stream(kind, 9, 20_000);
            let in_subj = |t: i32| {
                (t as usize) >= gr.subj0 && (t as usize) < gr.subj0 + gr.n_subjects
            };
            s.iter().filter(|&&t| in_subj(t)).count() as f64 / s.len() as f64
        };
        assert!(count_seps_facts(GrammarKind::HighQuality) > 1.4 * count_seps_facts(GrammarKind::Web));
    }
}
