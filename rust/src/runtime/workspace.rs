//! Reusable per-call state for the native backend: unpacked weights,
//! gradient accumulators, forward residual caches and backward scratch,
//! all allocated once per model config and reused across
//! `train_step`/`train_round`/`eval_loss` calls.
//!
//! Before this module existed, every op call re-materialized the full
//! weight set from the flat block-major vector into fresh row-major
//! `Vec`s, allocated ~15 activation buffers per layer per step, and
//! packed gradients into a fresh flat vector — allocation traffic that
//! dominated small-config steps and serialized the allocator under the
//! peer fan-out. A [`Workspace`] holds all of that as long-lived buffers:
//!
//! * **Packed-weights cache**: `ensure_weights` keeps a private copy of
//!   the flat parameter vector it last unpacked and re-unpacks only when
//!   the incoming params differ (exact slice comparison — a SIMD memcmp
//!   that early-exits on the first difference, so a miss costs almost
//!   nothing and a hit costs one linear scan). Exact bitwise comparison
//!   rather than a fingerprint: validator candidates embed
//!   adversary-chosen payloads, and a hash collision would silently
//!   score the wrong model. The validator's `mean_loss` loop — many
//!   `eval_loss` calls against the *same* candidate params, routed
//!   through one checkout via `ops::eval_loss_many` — unpacks once per
//!   candidate and hits the cache on every batch after the first.
//! * **Scratch reuse**: activations, attention buffers and backward
//!   temporaries live in the internal `Scratch`/`FwdCache` containers and
//!   are overwritten in place each call (buffers that *accumulate* are
//!   explicitly zeroed at their point of use).
//! * **In-place gradient pack**: `Grads::to_flat_into` writes the flat
//!   gradient into a reusable buffer (`Workspace::grads_flat`).
//!
//! Workspaces are not thread-safe themselves; the [`Engine`] keeps a pool
//! and checks one out per op call (`Engine::with_workspace`), so
//! concurrent ops on the shared engine each get their own buffers while
//! steady-state traffic allocates nothing.
//!
//! [`Engine`]: super::engine::Engine

use crate::config::layout::{Layout, BLOCK};
use crate::runtime::manifest::ModelConfig;

// ==========================================================================
// Flat-vector <-> row-major tensors (block-major layout)
// ==========================================================================

/// Read a 2-D tensor out of the flat vector (undoing 64x64-block-major)
/// into a preallocated row-major buffer of length `r * c`.
pub(crate) fn unpack_2d_into(flat: &[f32], offset: usize, r: usize, c: usize, out: &mut [f32]) {
    assert!(r % BLOCK == 0 && c % BLOCK == 0, "dims must be block multiples");
    debug_assert_eq!(out.len(), r * c);
    let bc = c / BLOCK;
    for br in 0..r / BLOCK {
        for bj in 0..bc {
            let base = offset + (br * bc + bj) * BLOCK * BLOCK;
            for rr in 0..BLOCK {
                let src = &flat[base + rr * BLOCK..base + (rr + 1) * BLOCK];
                let d0 = (br * BLOCK + rr) * c + bj * BLOCK;
                out[d0..d0 + BLOCK].copy_from_slice(src);
            }
        }
    }
}

/// Write a row-major 2-D tensor into the flat vector (block-major).
pub(crate) fn pack_2d(rm: &[f32], offset: usize, r: usize, c: usize, flat: &mut [f32]) {
    let bc = c / BLOCK;
    for br in 0..r / BLOCK {
        for bj in 0..bc {
            let base = offset + (br * bc + bj) * BLOCK * BLOCK;
            for rr in 0..BLOCK {
                let s0 = (br * BLOCK + rr) * c + bj * BLOCK;
                flat[base + rr * BLOCK..base + (rr + 1) * BLOCK]
                    .copy_from_slice(&rm[s0..s0 + BLOCK]);
            }
        }
    }
}

// ==========================================================================
// Weight / gradient containers (row-major)
// ==========================================================================

/// Row-major tensors of one transformer layer (weights or gradients).
pub(crate) struct LayerW {
    pub attn_norm: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub w_gate: Vec<f32>,
    pub w_up: Vec<f32>,
    pub w_down: Vec<f32>,
}

impl LayerW {
    /// Zero-filled buffers shaped like layer `li`'s slots.
    fn zeros(lay: &Layout, li: usize) -> LayerW {
        let s = &lay.slots;
        let b = 1 + li * 9;
        let z = |i: usize| vec![0f32; s[i].size];
        LayerW {
            attn_norm: z(b),
            wq: z(b + 1),
            wk: z(b + 2),
            wv: z(b + 3),
            wo: z(b + 4),
            mlp_norm: z(b + 5),
            w_gate: z(b + 6),
            w_up: z(b + 7),
            w_down: z(b + 8),
        }
    }

    fn zero(&mut self) {
        self.attn_norm.fill(0.0);
        self.wq.fill(0.0);
        self.wk.fill(0.0);
        self.wv.fill(0.0);
        self.wo.fill(0.0);
        self.mlp_norm.fill(0.0);
        self.w_gate.fill(0.0);
        self.w_up.fill(0.0);
        self.w_down.fill(0.0);
    }
}

/// All weights, row-major (the unpacked view of the flat vector).
pub(crate) struct Weights {
    pub embed: Vec<f32>,
    pub layers: Vec<LayerW>,
    pub final_norm: Vec<f32>,
    pub lm_head: Option<Vec<f32>>,
}

impl Weights {
    fn zeros(cfg: &ModelConfig, lay: &Layout) -> Weights {
        let s = &lay.slots;
        let fnorm_i = 1 + cfg.n_layers * 9;
        Weights {
            embed: vec![0f32; s[0].size],
            layers: (0..cfg.n_layers).map(|li| LayerW::zeros(lay, li)).collect(),
            final_norm: vec![0f32; s[fnorm_i].size],
            lm_head: cfg.untie_embeddings.then(|| vec![0f32; s[fnorm_i + 1].size]),
        }
    }
}

/// Unpack the flat (block-major) parameter vector into preallocated
/// row-major buffers. Slot order matches `Layout::build`: embed, 9
/// tensors per layer, final_norm, optional lm_head.
pub(crate) fn unpack_weights_into(
    cfg: &ModelConfig,
    lay: &Layout,
    flat: &[f32],
    w: &mut Weights,
) {
    let s = &lay.slots;
    let g1 = |i: usize, dst: &mut Vec<f32>| {
        dst.copy_from_slice(&flat[s[i].offset..s[i].offset + s[i].size])
    };
    let g2 = |i: usize, dst: &mut Vec<f32>| {
        unpack_2d_into(flat, s[i].offset, s[i].shape[0], s[i].shape[1], dst)
    };
    g2(0, &mut w.embed);
    for (li, lw) in w.layers.iter_mut().enumerate() {
        let b = 1 + li * 9;
        g1(b, &mut lw.attn_norm);
        g2(b + 1, &mut lw.wq);
        g2(b + 2, &mut lw.wk);
        g2(b + 3, &mut lw.wv);
        g2(b + 4, &mut lw.wo);
        g1(b + 5, &mut lw.mlp_norm);
        g2(b + 6, &mut lw.w_gate);
        g2(b + 7, &mut lw.w_up);
        g2(b + 8, &mut lw.w_down);
    }
    let fnorm_i = 1 + cfg.n_layers * 9;
    g1(fnorm_i, &mut w.final_norm);
    if let Some(h) = &mut w.lm_head {
        g2(fnorm_i + 1, h);
    }
}

/// Row-major gradient accumulators, packed to flat at the end of backward.
pub(crate) struct Grads {
    pub embed: Vec<f32>,
    pub layers: Vec<LayerW>,
    pub final_norm: Vec<f32>,
    pub lm_head: Option<Vec<f32>>,
}

impl Grads {
    pub(crate) fn zeros(cfg: &ModelConfig, lay: &Layout) -> Grads {
        let w = Weights::zeros(cfg, lay);
        Grads {
            embed: w.embed,
            layers: w.layers,
            final_norm: w.final_norm,
            lm_head: w.lm_head,
        }
    }

    /// Reset every accumulator to zero (start of a backward pass).
    pub fn zero(&mut self) {
        self.embed.fill(0.0);
        for l in &mut self.layers {
            l.zero();
        }
        self.final_norm.fill(0.0);
        if let Some(h) = &mut self.lm_head {
            h.fill(0.0);
        }
    }

    /// Pack into the flat (block-major, chunk-padded) gradient buffer,
    /// overwriting it completely (slot padding stays zero).
    pub fn to_flat_into(&self, cfg: &ModelConfig, lay: &Layout, flat: &mut [f32]) {
        debug_assert_eq!(flat.len(), lay.n_alloc);
        flat.fill(0.0);
        let s = &lay.slots;
        let p2 = |rm: &[f32], i: usize, flat: &mut [f32]| {
            pack_2d(rm, s[i].offset, s[i].shape[0], s[i].shape[1], flat)
        };
        let p1 = |rm: &[f32], i: usize, flat: &mut [f32]| {
            flat[s[i].offset..s[i].offset + s[i].size].copy_from_slice(rm)
        };
        p2(&self.embed, 0, flat);
        for (li, l) in self.layers.iter().enumerate() {
            let b = 1 + li * 9;
            p1(&l.attn_norm, b, flat);
            p2(&l.wq, b + 1, flat);
            p2(&l.wk, b + 2, flat);
            p2(&l.wv, b + 3, flat);
            p2(&l.wo, b + 4, flat);
            p1(&l.mlp_norm, b + 5, flat);
            p2(&l.w_gate, b + 6, flat);
            p2(&l.w_up, b + 7, flat);
            p2(&l.w_down, b + 8, flat);
        }
        let fnorm_i = 1 + cfg.n_layers * 9;
        p1(&self.final_norm, fnorm_i, flat);
        if let Some(h) = &self.lm_head {
            p2(h, fnorm_i + 1, flat);
        }
    }
}

// ==========================================================================
// Forward residual cache + backward scratch
// ==========================================================================

/// Per-layer forward residuals kept for the backward pass.
pub(crate) struct LayerCache {
    pub x_in: Vec<f32>,  // [N, D]
    pub rinv1: Vec<f32>, // [N]
    pub h: Vec<f32>,     // [N, D]
    pub q: Vec<f32>,     // [B, Hq, T, dh] (post-RoPE)
    pub k: Vec<f32>,     // [B, Hkv, T, dh] (post-RoPE)
    pub v: Vec<f32>,     // [B, Hkv, T, dh]
    pub att: Vec<f32>,   // [B, Hq, T, T] (only j <= i written/read)
    pub aflat: Vec<f32>, // [N, Hq*dh]
    pub x_mid: Vec<f32>, // [N, D]
    pub rinv2: Vec<f32>, // [N]
    pub h2: Vec<f32>,    // [N, D]
    pub gpre: Vec<f32>,  // [N, F]
    pub upre: Vec<f32>,  // [N, F]
}

impl LayerCache {
    fn zeros(cfg: &ModelConfig) -> LayerCache {
        let (b, t, d) = (cfg.batch_size, cfg.seq_len, cfg.d_model);
        let (hq, hkv, dh, f) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff);
        let n = b * t;
        LayerCache {
            x_in: vec![0f32; n * d],
            rinv1: vec![0f32; n],
            h: vec![0f32; n * d],
            q: vec![0f32; b * hq * t * dh],
            k: vec![0f32; b * hkv * t * dh],
            v: vec![0f32; b * hkv * t * dh],
            att: vec![0f32; b * hq * t * t],
            aflat: vec![0f32; n * hq * dh],
            x_mid: vec![0f32; n * d],
            rinv2: vec![0f32; n],
            h2: vec![0f32; n * d],
            gpre: vec![0f32; n * f],
            upre: vec![0f32; n * f],
        }
    }
}

/// Whole-model forward cache (per-layer residuals + final norm state).
pub(crate) struct FwdCache {
    pub layers: Vec<LayerCache>,
    pub x_pre_final: Vec<f32>,
    pub rinv_f: Vec<f32>,
    pub xf: Vec<f32>,
}

impl FwdCache {
    fn zeros(cfg: &ModelConfig) -> FwdCache {
        let n = cfg.batch_size * cfg.seq_len;
        FwdCache {
            layers: (0..cfg.n_layers).map(|_| LayerCache::zeros(cfg)).collect(),
            x_pre_final: vec![0f32; n * cfg.d_model],
            rinv_f: vec![0f32; n],
            xf: vec![0f32; n * cfg.d_model],
        }
    }
}

/// Reused activation / backward temporaries (sized once per config).
pub(crate) struct Scratch {
    pub inp: Vec<i32>,      // [N] input tokens
    pub tgt: Vec<i32>,      // [N] target tokens
    pub x: Vec<f32>,        // [N, D] running activation
    pub proj: Vec<f32>,     // [N, max(Hq*dh, D)] projection scratch
    pub attn_out: Vec<f32>, // [B, Hq, T, dh] attention output (pre-merge)
    pub logits: Vec<f32>,   // [N, V] (reused as dlogits in backward)
    pub lse: Vec<f32>,      // [N]
    pub tl: Vec<f32>,       // [N]
    pub gate: Vec<f32>,     // [N, F]
    pub sg: Vec<f32>,       // [N, F]
    pub nf1: Vec<f32>,      // [N, F] (dgate / dgpre)
    pub nf2: Vec<f32>,      // [N, F] (dupre)
    pub dxf: Vec<f32>,      // [N, D]
    pub dx: Vec<f32>,       // [N, D]
    pub dh2: Vec<f32>,      // [N, D]
    pub dh2b: Vec<f32>,     // [N, D]
    pub daflat: Vec<f32>,   // [N, Hq*dh]
    pub da: Vec<f32>,       // [B, Hq, T, dh]
    pub dq: Vec<f32>,       // [B, Hq, T, dh]
    pub dk: Vec<f32>,       // [B, Hkv, T, dh]
    pub dv: Vec<f32>,       // [B, Hkv, T, dh]
    pub ds_row: Vec<f32>,   // [T]
    pub dqf: Vec<f32>,      // [N, Hq*dh]
    pub dkf: Vec<f32>,      // [N, Hkv*dh]
    pub dvf: Vec<f32>,      // [N, Hkv*dh]
    pub dh_sum: Vec<f32>,   // [N, D]
    pub tmp: Vec<f32>,      // [N, D]
}

impl Scratch {
    fn zeros(cfg: &ModelConfig) -> Scratch {
        let (b, t, d, v) = (cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size);
        let (hq, hkv, dh, f) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff);
        let n = b * t;
        let (qd, kvd) = (hq * dh, hkv * dh);
        Scratch {
            inp: vec![0i32; n],
            tgt: vec![0i32; n],
            x: vec![0f32; n * d],
            proj: vec![0f32; n * qd.max(d)],
            attn_out: vec![0f32; b * hq * t * dh],
            logits: vec![0f32; n * v],
            lse: vec![0f32; n],
            tl: vec![0f32; n],
            gate: vec![0f32; n * f],
            sg: vec![0f32; n * f],
            nf1: vec![0f32; n * f],
            nf2: vec![0f32; n * f],
            dxf: vec![0f32; n * d],
            dx: vec![0f32; n * d],
            dh2: vec![0f32; n * d],
            dh2b: vec![0f32; n * d],
            daflat: vec![0f32; n * qd],
            da: vec![0f32; b * hq * t * dh],
            dq: vec![0f32; b * hq * t * dh],
            dk: vec![0f32; b * hkv * t * dh],
            dv: vec![0f32; b * hkv * t * dh],
            ds_row: vec![0f32; t],
            dqf: vec![0f32; n * qd],
            dkf: vec![0f32; n * kvd],
            dvf: vec![0f32; n * kvd],
            dh_sum: vec![0f32; n * d],
            tmp: vec![0f32; n * d],
        }
    }
}

// ==========================================================================
// Workspace
// ==========================================================================

/// All reusable state for one in-flight op on one thread. Checked out of
/// the engine's pool by `Engine::with_workspace`. Forward-path buffers
/// (weights, activations, scratch) are sized at construction; the
/// training-only state (gradient accumulators, flat gradient, decay
/// mask) is allocated lazily on the first backward pass, so eval-only
/// workspaces — the validator's common case — stay at a fraction of the
/// footprint.
pub struct Workspace {
    pub(crate) weights: Weights,
    /// Copy of the flat params `weights` was unpacked from (empty =
    /// nothing cached). Exact comparison, not a fingerprint: see the
    /// module docs.
    pub(crate) params_copy: Vec<f32>,
    /// Gradient accumulators (allocated on first backward pass).
    pub(crate) grads: Option<Grads>,
    /// Flat (block-major) gradient of the last backward pass (empty
    /// until the first backward pass).
    pub(crate) grads_flat: Vec<f32>,
    pub(crate) fwd: FwdCache,
    pub(crate) scratch: Scratch,
    /// RoPE tables for the config's (seq_len, d_head, theta): [T, dh/2].
    pub(crate) rope_cos: Vec<f32>,
    pub(crate) rope_sin: Vec<f32>,
    /// 1.0 where weight decay applies (2-D tensor positions); empty
    /// until the first backward pass.
    pub(crate) decay_mask: Vec<f32>,
}

impl Workspace {
    /// Allocate the forward-path buffers for `cfg`'s shapes (training
    /// state follows lazily on the first backward pass; after that the
    /// native hot path performs no allocations in steady state).
    pub fn new(cfg: &ModelConfig, lay: &Layout) -> Workspace {
        let (t, dh) = (cfg.seq_len, cfg.d_head);
        let half = dh / 2;
        let mut cos = vec![0f32; t * half];
        let mut sin = vec![0f32; t * half];
        for pos in 0..t {
            for e in 0..half {
                let inv = 1.0 / cfg.rope_theta.powf((2 * e) as f64 / dh as f64);
                let ang = pos as f64 * inv;
                cos[pos * half + e] = ang.cos() as f32;
                sin[pos * half + e] = ang.sin() as f32;
            }
        }
        Workspace {
            weights: Weights::zeros(cfg, lay),
            params_copy: Vec::new(),
            grads: None,
            grads_flat: Vec::new(),
            fwd: FwdCache::zeros(cfg),
            scratch: Scratch::zeros(cfg),
            rope_cos: cos,
            rope_sin: sin,
            decay_mask: Vec::new(),
        }
    }

    /// Whether `self.weights` is already the unpack of `flat`: bitwise
    /// element comparison against the cached copy (so -0.0 vs +0.0 is a
    /// miss, NaN == NaN is a hit — bitwise identity, exactly the
    /// determinism contract's terms). Soundness does not rest on a hash.
    fn weights_hit(&self, flat: &[f32]) -> bool {
        // Lane-strip bitwise comparator from the kernels module: exact
        // in every KernelMode (a bit compare has nothing to reassociate),
        // and the strip form autovectorizes the full-parameter scan.
        super::kernels::bits_eq_f32(&self.params_copy, flat)
    }

    /// Make `self.weights` the row-major view of `flat`, reusing the
    /// cached unpack when `flat` is bit-identical to the cached copy of
    /// the last-unpacked params. This is what makes repeated evals
    /// against one candidate model — the validator's `mean_loss` loop —
    /// cheap.
    pub(crate) fn ensure_weights(&mut self, cfg: &ModelConfig, lay: &Layout, flat: &[f32]) {
        if self.weights_hit(flat) {
            return;
        }
        unpack_weights_into(cfg, lay, flat, &mut self.weights);
        self.params_copy.clear();
        self.params_copy.extend_from_slice(flat);
    }

    /// Like [`Workspace::ensure_weights`] but without populating the
    /// params cache — the training path unpacks, runs fwd/bwd, and then
    /// mutates the params in place, so a cached copy would be a dead
    /// full-parameter memcpy on every inner step. The stale copy is
    /// cleared so a later cached call can never false-hit.
    pub(crate) fn ensure_weights_uncached(
        &mut self,
        cfg: &ModelConfig,
        lay: &Layout,
        flat: &[f32],
    ) {
        if self.weights_hit(flat) {
            return;
        }
        unpack_weights_into(cfg, lay, flat, &mut self.weights);
        self.params_copy.clear();
    }

    /// Invalidate the packed-weights cache (params changed in place).
    pub(crate) fn invalidate_weights(&mut self) {
        self.params_copy.clear();
    }

    /// Allocate the training-only state (gradient accumulators, flat
    /// gradient buffer, decay mask) on the first backward pass.
    pub(crate) fn ensure_grads(&mut self, cfg: &ModelConfig, lay: &Layout) {
        if self.grads.is_none() {
            self.grads = Some(Grads::zeros(cfg, lay));
        }
        if self.grads_flat.len() != lay.n_alloc {
            self.grads_flat = vec![0f32; lay.n_alloc];
        }
        if self.decay_mask.len() != lay.n_alloc {
            let mut mask = vec![0f32; lay.n_alloc];
            for s in &lay.slots {
                if s.decay {
                    mask[s.offset..s.offset + s.size].fill(1.0);
                }
            }
            self.decay_mask = mask;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn block_major_roundtrip() {
        let (r, c) = (128, 192);
        let rm: Vec<f32> = (0..r * c).map(|i| i as f32).collect();
        let mut flat = vec![0f32; r * c + 64];
        pack_2d(&rm, 64, r, c, &mut flat);
        let mut back = vec![0f32; r * c];
        unpack_2d_into(&flat, 64, r, c, &mut back);
        assert_eq!(back, rm);
    }

    #[test]
    fn weights_cache_hits_and_invalidates() {
        let cfg = presets::get("tiny").unwrap();
        let lay = Layout::build(&cfg);
        let mut ws = Workspace::new(&cfg, &lay);
        let flat = vec![0.25f32; lay.n_alloc];
        ws.ensure_weights(&cfg, &lay, &flat);
        assert_eq!(ws.weights.embed[0], 0.25);
        // a repeat with identical params must be a cache hit: poke a
        // marker into the unpacked weights and confirm it survives
        ws.weights.embed[0] = 123.0;
        ws.ensure_weights(&cfg, &lay, &flat);
        assert_eq!(ws.weights.embed[0], 123.0, "identical params must not re-unpack");
        // a single changed element must miss and re-unpack everything
        let mut flat2 = flat.clone();
        flat2[lay.n_alloc - 1] += 1.0;
        ws.ensure_weights(&cfg, &lay, &flat2);
        assert_eq!(ws.weights.embed[0], 0.25, "changed params must re-unpack");
        // explicit invalidation forces the next call to re-unpack too
        ws.weights.embed[0] = 123.0;
        ws.invalidate_weights();
        ws.ensure_weights(&cfg, &lay, &flat2);
        assert_eq!(ws.weights.embed[0], 0.25);
        // the uncached (training-path) variant unpacks but never stores
        // a params copy — and clears any stale one
        let flat3 = vec![0.75f32; lay.n_alloc];
        ws.ensure_weights_uncached(&cfg, &lay, &flat3);
        assert_eq!(ws.weights.embed[0], 0.75);
        assert!(ws.params_copy.is_empty(), "uncached unpack must not cache");
        // a cache hit from a previous *cached* unpack is still honored
        ws.ensure_weights(&cfg, &lay, &flat3);
        ws.weights.embed[0] = 123.0;
        ws.ensure_weights_uncached(&cfg, &lay, &flat3);
        assert_eq!(ws.weights.embed[0], 123.0, "uncached call may reuse a valid cache");
    }

    #[test]
    fn training_state_is_lazy() {
        let cfg = presets::get("tiny").unwrap();
        let lay = Layout::build(&cfg);
        let mut ws = Workspace::new(&cfg, &lay);
        // eval-only workspaces never pay for training state
        assert!(ws.grads.is_none());
        assert!(ws.grads_flat.is_empty());
        assert!(ws.decay_mask.is_empty());
        ws.ensure_grads(&cfg, &lay);
        assert!(ws.grads.is_some());
        assert_eq!(ws.grads_flat.len(), lay.n_alloc);
        assert_eq!(ws.decay_mask.len(), lay.n_alloc);
        // decay mask marks exactly the 2-D slots
        for s in &lay.slots {
            let expect = if s.decay { 1.0 } else { 0.0 };
            assert!(ws.decay_mask[s.offset..s.offset + s.size]
                .iter()
                .all(|&x| x == expect));
        }
    }

    #[test]
    fn grads_pack_roundtrip_preserves_padding() {
        let cfg = presets::get("tiny").unwrap();
        let lay = Layout::build(&cfg);
        let mut g = Grads::zeros(&cfg, &lay);
        g.embed.iter_mut().enumerate().for_each(|(i, x)| *x = i as f32);
        let mut flat = vec![7f32; lay.n_alloc]; // stale garbage must be cleared
        g.to_flat_into(&cfg, &lay, &mut flat);
        for s in &lay.slots {
            assert!(flat[s.offset + s.size..s.offset + s.slot].iter().all(|&x| x == 0.0));
        }
        // unpack the embed slot back and compare
        let s0 = &lay.slots[0];
        let mut back = vec![0f32; s0.size];
        unpack_2d_into(&flat, s0.offset, s0.shape[0], s0.shape[1], &mut back);
        assert_eq!(back, g.embed);
    }
}
