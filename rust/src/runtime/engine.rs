//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them once
//! on the CPU PJRT client, and executes them from the request path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id proto incompatibility between
//! jax >= 0.5 and xla_extension 0.5.1.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;

/// Compiled-executable cache keyed by artifact name.
///
/// `Engine` is deliberately **not** `Send`: PJRT wrapper types hold raw
/// pointers, so all device compute stays on the coordinator thread. The
/// simulation layers (netsim, storage, chain) are pure Rust and run on a
/// virtual clock, so this costs nothing on the 1-core testbed.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// Cumulative wall time spent inside PJRT execute, per artifact.
    exec_stats: RefCell<HashMap<String, (u64, f64)>>,
}

impl Engine {
    /// Create a CPU engine for one artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) one artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        let _ = t0; // compile time visible via `covenant smoke`
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (pay compile cost up front).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with literal inputs; returns untupled outputs.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// result buffer is a tuple literal that we decompose here.
    pub fn run(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?;
        ensure!(
            spec.inputs.len() == inputs.len(),
            "artifact '{name}' expects {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<Literal>(inputs)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = lit.to_tuple().context("decomposing result tuple")?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.exec_stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        ensure!(
            outs.len() == spec.outputs.len(),
            "artifact '{name}' returned {} outputs, manifest says {}",
            outs.len(),
            spec.outputs.len()
        );
        Ok(outs)
    }

    /// (calls, total_seconds) per artifact, for the perf report.
    pub fn exec_stats(&self) -> HashMap<String, (u64, f64)> {
        self.exec_stats.borrow().clone()
    }
}
