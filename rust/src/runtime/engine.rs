//! Execution engine: resolves a model (artifact directory or preset name)
//! to a [`Manifest`] and dispatches every operation to the native CPU
//! backend ([`super::native`]).
//!
//! Historically this wrapped a PJRT CPU client executing AOT HLO-text
//! artifacts produced by `python/compile/aot.py`; that path required the
//! external `xla` crate and on-disk artifacts, neither of which this
//! offline environment provides. The native backend implements the same
//! ops (validated against finite differences and the Python semantics) in
//! pure Rust, which also makes `Engine` `Send + Sync` — the coordinator
//! fans peer compute out across a rayon pool sharing one engine.
//! Re-introducing an accelerator backend is a ROADMAP item; the seam is
//! exactly this type plus `runtime::ops`.
//!
//! Model resolution order for [`Engine::new`]:
//! 1. `<dir>/manifest.json` exists — load it (an AOT artifact directory);
//! 2. otherwise the final path component names a preset (`tiny`, `small`,
//!    ...) — synthesize the manifest from `config::presets`. This keeps
//!    every historical call site (`Engine::new("artifacts/tiny")`) working
//!    hermetically.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;
use super::workspace::Workspace;
use crate::config::layout::Layout;
use crate::config::presets;

/// Shared, thread-safe execution engine (one per model/config).
pub struct Engine {
    manifest: Manifest,
    /// Flat parameter layout, built once (ops on the validator hot loop
    /// would otherwise recompute it per call).
    layout: Layout,
    /// Cumulative wall time inside each op: name -> (calls, seconds).
    exec_stats: Mutex<HashMap<String, (u64, f64)>>,
    /// Pool of reusable [`Workspace`]s (unpacked weights, grads, scratch).
    /// Each in-flight op checks one out, so concurrent ops never share
    /// buffers and steady-state traffic allocates nothing; the pool grows
    /// to the peak op concurrency and is then stable.
    workspaces: Mutex<Vec<Workspace>>,
}

impl Engine {
    /// Engine for an artifact directory *or* a preset-named path
    /// (`artifacts/tiny` resolves to the `tiny` preset when no
    /// `manifest.json` is present).
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = if dir.join("manifest.json").is_file() {
            Manifest::load(dir).with_context(|| format!("loading {}", dir.display()))?
        } else {
            let name = dir.file_name().and_then(|s| s.to_str()).ok_or_else(|| {
                anyhow!("artifact path '{}' has no final component", dir.display())
            })?;
            let cfg = presets::get(name).with_context(|| {
                format!(
                    "no manifest.json under '{}' and its basename is not a preset",
                    dir.display()
                )
            })?;
            Manifest::synthesize(cfg, dir.to_path_buf())
        };
        let layout = Layout::build(&manifest.config);
        Ok(Self {
            manifest,
            layout,
            exec_stats: Mutex::new(HashMap::new()),
            workspaces: Mutex::new(Vec::new()),
        })
    }

    /// Engine directly from a preset name (`tiny`, `small`, `base`, ...).
    pub fn from_preset(name: &str) -> Result<Self> {
        let cfg = presets::get(name)?;
        let manifest = Manifest::synthesize(cfg, format!("native://{name}").into());
        let layout = Layout::build(&manifest.config);
        Ok(Self {
            manifest,
            layout,
            exec_stats: Mutex::new(HashMap::new()),
            workspaces: Mutex::new(Vec::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The flat parameter layout (cached; identical to
    /// `Layout::build(&self.manifest().config)`).
    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Run `f` with a workspace checked out of the pool (allocating a
    /// fresh one only when every pooled workspace is in use). The
    /// workspace returns to the pool afterwards, packed-weights cache
    /// intact — repeated evals against the same params hit the cache
    /// across calls.
    pub fn with_workspace<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        let ws = self.workspaces.lock().expect("workspace pool lock").pop();
        let mut ws =
            ws.unwrap_or_else(|| Workspace::new(&self.manifest.config, &self.layout));
        let out = f(&mut ws);
        self.workspaces.lock().expect("workspace pool lock").push(ws);
        out
    }

    /// Record one op execution (called by `runtime::ops`).
    pub(crate) fn note(&self, name: &str, t0: Instant) {
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.exec_stats.lock().expect("stats lock");
        let e = stats.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
    }

    /// (calls, total_seconds) per op, for the perf report.
    pub fn exec_stats(&self) -> HashMap<String, (u64, f64)> {
        self.exec_stats.lock().expect("stats lock").clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_fallback_resolves_tiny() {
        let eng = Engine::new("artifacts/tiny").unwrap();
        assert_eq!(eng.manifest().config.name, "tiny");
        assert_eq!(eng.manifest().n_alloc, 430_080);
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(Engine::new("artifacts/no-such-model").is_err());
    }

    #[test]
    fn from_preset_and_stats() {
        let eng = Engine::from_preset("tiny").unwrap();
        assert!(eng.exec_stats().is_empty());
        eng.note("x", Instant::now());
        assert_eq!(eng.exec_stats()["x"].0, 1);
    }

    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
    }
}
