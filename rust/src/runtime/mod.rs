//! Runtime layer: PJRT client wrapper + artifact manifest.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (`make artifacts`), compiles them once on the PJRT CPU client, and
//! exposes `Engine::run(name, inputs)` to the coordinator. Python never
//! runs on this path.

pub mod engine;
pub mod literal;
pub mod manifest;
pub mod ops;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelConfig, TensorSlot};
