//! Runtime layer: model resolution + the native CPU execution backend.
//!
//! [`Engine`] resolves an artifact directory or preset name to a
//! [`Manifest`], tracks per-op timing and pools reusable [`Workspace`]s;
//! [`ops`] exposes each paper operation (init, fused inner rounds,
//! compression, outer step, evaluation) as a typed function over host
//! vectors; [`native`] holds the model math (transformer
//! forward/backward + AdamW over the flat block-major layout) on top of
//! the cache-blocked, rayon-parallel — and bit-deterministic — dense
//! kernels in [`kernels`]. The engine is `Send + Sync`, so the
//! coordinator fans peer compute out across threads against one shared
//! engine, and the Gauntlet validator fans LossScore evaluations across
//! the same pool.
//!
//! [`Workspace`]: workspace::Workspace

pub mod engine;
pub mod kernels;
pub mod manifest;
pub mod native;
pub mod ops;
pub mod workspace;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelConfig, TensorSlot};
