//! Runtime layer: model resolution + the native CPU execution backend.
//!
//! [`Engine`] resolves an artifact directory or preset name to a
//! [`Manifest`] and tracks per-op timing; [`ops`] exposes each paper
//! operation (init, fused inner rounds, compression, outer step,
//! evaluation) as a typed function over host vectors; [`native`] holds
//! the model math (transformer forward/backward + AdamW over the flat
//! block-major layout). The engine is `Send + Sync`, so the coordinator
//! can fan peer compute out across threads against one shared engine.

pub mod engine;
pub mod manifest;
pub mod native;
pub mod ops;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelConfig, TensorSlot};
