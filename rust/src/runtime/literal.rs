//! Literal construction/extraction helpers around the `xla` crate.

use anyhow::Result;
use xla::Literal;

/// 1-D f32 literal.
pub fn f32_vec(data: &[f32]) -> Literal {
    Literal::vec1(data)
}

/// f32 literal with an explicit shape.
pub fn f32_tensor(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// i32 literal with an explicit shape.
pub fn i32_tensor(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Scalar literals.
pub fn scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Extract an f32 vector (any shape, row-major).
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract an i32 vector (any shape, row-major).
pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Extract a scalar f32.
pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
