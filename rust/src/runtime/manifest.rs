//! Model manifest: config + flat parameter layout + op signatures.
//!
//! Two provenances:
//! * **Loaded** — `manifest.json` written by `python/compile/aot.py`
//!   alongside AOT-compiled HLO artifacts (the contract between the
//!   Python compile pipeline and this runtime);
//! * **Synthesized** — built directly from a `config::presets` entry via
//!   [`Manifest::synthesize`] when no artifact directory exists. The
//!   native backend needs only the config and layout, so a synthesized
//!   manifest is fully equivalent for execution.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Model/training configuration (mirrors `python/compile/configs.py`).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub inner_steps: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub init_std: f64,
    pub adam_b1: f64,
    pub adam_b2: f64,
    pub adam_eps: f64,
    pub weight_decay: f64,
    pub ef_beta: f64,
    pub topk: usize,
    pub chunk: usize,
    pub untie_embeddings: bool,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            vocab_size: j.get("vocab_size")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            d_head: j.get("d_head")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            batch_size: j.get("batch_size")?.as_usize()?,
            inner_steps: j.get("inner_steps")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            norm_eps: j.get("norm_eps")?.as_f64()?,
            init_std: j.get("init_std")?.as_f64()?,
            adam_b1: j.get("adam_b1")?.as_f64()?,
            adam_b2: j.get("adam_b2")?.as_f64()?,
            adam_eps: j.get("adam_eps")?.as_f64()?,
            weight_decay: j.get("weight_decay")?.as_f64()?,
            ef_beta: j.get("ef_beta")?.as_f64()?,
            topk: j.get("topk")?.as_usize()?,
            chunk: j.get("chunk")?.as_usize()?,
            untie_embeddings: j
                .opt("untie_embeddings")
                .map(|v| v.as_bool())
                .transpose()?
                .unwrap_or(false),
        })
    }
}

/// One named tensor inside the flat parameter vector.
#[derive(Debug, Clone)]
pub struct TensorSlot {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub slot: usize,
    pub is_2d: bool,
    pub decay: bool,
}

/// Shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The whole `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub n_params: usize,
    pub n_alloc: usize,
    pub n_chunks: usize,
    pub tensors: Vec<TensorSlot>,
    pub artifacts: HashMap<String, ArtifactSpec>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let tensors = j
            .get("tensors")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(TensorSlot {
                    name: t.get("name")?.as_str()?.to_string(),
                    shape: t
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_>>()?,
                    offset: t.get("offset")?.as_usize()?,
                    size: t.get("size")?.as_usize()?,
                    slot: t.get("slot")?.as_usize()?,
                    is_2d: t.get("is_2d")?.as_bool()?,
                    decay: t.get("decay")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = HashMap::new();
        for (name, a) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs: a
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(IoSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(IoSpec::from_json)
                        .collect::<Result<_>>()?,
                },
            );
        }
        Ok(Manifest {
            config: ModelConfig::from_json(j.get("config")?)?,
            n_params: j.get("n_params")?.as_usize()?,
            n_alloc: j.get("n_alloc")?.as_usize()?,
            n_chunks: j.get("n_chunks")?.as_usize()?,
            tensors,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Build a manifest straight from a model config (no artifact files).
    ///
    /// Layout fields are produced by the same `config::layout::Layout` the
    /// Python side mirrors, so a synthesized manifest is indistinguishable
    /// from a loaded one as far as the native backend is concerned. The
    /// `artifacts` table is left empty — there are no HLO files.
    pub fn synthesize(config: ModelConfig, dir: PathBuf) -> Manifest {
        let lay = crate::config::layout::Layout::build(&config);
        Manifest {
            n_params: lay.n_params,
            n_alloc: lay.n_alloc,
            n_chunks: lay.n_chunks(),
            tensors: lay.slots,
            artifacts: HashMap::new(),
            config,
            dir,
        }
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    pub fn tensor(&self, name: &str) -> Option<&TensorSlot> {
        self.tensors.iter().find(|t| t.name == name)
    }
}
