//! Native CPU reference backend: the full model math in plain Rust.
//!
//! Implements every operation the coordinator needs — parameter init,
//! fused inner rounds (transformer forward + hand-derived backward +
//! AdamW), evaluation losses, SparseLoCo compression and the outer step —
//! over the same flat, chunk-aligned, 64x64-block-major parameter layout
//! as `python/compile` (see `config::layout`). This is what makes the
//! crate hermetic: `cargo test` exercises real training dynamics with no
//! AOT artifacts, no PJRT client and no Python on the path.
//!
//! Architecture (paper §4.1, Table 4, scaled presets): decoder-only
//! transformer with RMSNorm, GQA attention (query heads share K/V panels
//! in groups of `n_heads / n_kv_heads`), RoPE (theta = 500k), SwiGLU MLP,
//! and tied token-embedding/LM-head unless `untie_embeddings`.
//!
//! The backward pass is validated against finite differences in-repo
//! (`backward_matches_finite_differences`, directional checks on a micro
//! config; the same math was checked to ~2e-7 relative error in f64
//! during development). The optimizer matches
//! `python/compile/optim.py`: bias-corrected AdamW, decoupled weight
//! decay masked to 2-D tensors, optional global-norm clipping.
//!
//! Numerics are deterministic: same inputs, same outputs, bit for bit —
//! every reduction runs in a fixed serial order. Parallelism lives a
//! level up (the coordinator fans whole peers out; see
//! `coordinator::network`).

#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]

use anyhow::{ensure, Result};

use crate::config::layout::{Layout, BLOCK};
use crate::runtime::manifest::{Manifest, ModelConfig};
use crate::util::rng::Rng;

// ==========================================================================
// Small dense kernels (serial; autovectorized at opt-level >= 2)
// ==========================================================================

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[inline]
fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// out[m,n] = a[m,p] @ b[p,n] (all row-major).
fn matmul(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let or = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            axpy(av, &b[kk * n..(kk + 1) * n], or);
        }
    }
}

/// out[m,n] = a[m,p] @ b[n,p]^T — `b` row-major [n,p] (e.g. logits via the
/// tied embedding).
fn matmul_bt(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            or[j] = dot(ar, &b[j * p..(j + 1) * p]);
        }
    }
}

/// out[p,n] += a[m,p]^T @ b[m,n] (weight gradients).
fn matmul_at_add(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), p * n);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let br = &b[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            axpy(av, br, &mut out[kk * n..(kk + 1) * n]);
        }
    }
}

// ==========================================================================
// Flat-vector <-> row-major tensors (block-major layout)
// ==========================================================================

/// Read a 2-D tensor out of the flat vector (undoing 64x64-block-major).
fn unpack_2d(flat: &[f32], offset: usize, r: usize, c: usize) -> Vec<f32> {
    assert!(r % BLOCK == 0 && c % BLOCK == 0, "dims must be block multiples");
    let mut out = vec![0f32; r * c];
    let bc = c / BLOCK;
    for br in 0..r / BLOCK {
        for bj in 0..bc {
            let base = offset + (br * bc + bj) * BLOCK * BLOCK;
            for rr in 0..BLOCK {
                let src = &flat[base + rr * BLOCK..base + (rr + 1) * BLOCK];
                let d0 = (br * BLOCK + rr) * c + bj * BLOCK;
                out[d0..d0 + BLOCK].copy_from_slice(src);
            }
        }
    }
    out
}

/// Write a row-major 2-D tensor into the flat vector (block-major).
fn pack_2d(rm: &[f32], offset: usize, r: usize, c: usize, flat: &mut [f32]) {
    let bc = c / BLOCK;
    for br in 0..r / BLOCK {
        for bj in 0..bc {
            let base = offset + (br * bc + bj) * BLOCK * BLOCK;
            for rr in 0..BLOCK {
                let s0 = (br * BLOCK + rr) * c + bj * BLOCK;
                flat[base + rr * BLOCK..base + (rr + 1) * BLOCK]
                    .copy_from_slice(&rm[s0..s0 + BLOCK]);
            }
        }
    }
}

/// Row-major weights of one transformer layer.
struct LayerW {
    attn_norm: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    mlp_norm: Vec<f32>,
    w_gate: Vec<f32>,
    w_up: Vec<f32>,
    w_down: Vec<f32>,
}

/// All weights unpacked to row-major (per inner step; tiny vs. the
/// matmuls it feeds).
struct Weights {
    embed: Vec<f32>,
    layers: Vec<LayerW>,
    final_norm: Vec<f32>,
    lm_head: Option<Vec<f32>>,
}

/// Slot order produced by `Layout::build`: embed, then 9 tensors per
/// layer, final_norm, optional lm_head.
fn unpack_weights(cfg: &ModelConfig, lay: &Layout, flat: &[f32]) -> Weights {
    let s = &lay.slots;
    let g1 = |i: usize| flat[s[i].offset..s[i].offset + s[i].size].to_vec();
    let g2 = |i: usize| unpack_2d(flat, s[i].offset, s[i].shape[0], s[i].shape[1]);
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let b = 1 + li * 9;
        layers.push(LayerW {
            attn_norm: g1(b),
            wq: g2(b + 1),
            wk: g2(b + 2),
            wv: g2(b + 3),
            wo: g2(b + 4),
            mlp_norm: g1(b + 5),
            w_gate: g2(b + 6),
            w_up: g2(b + 7),
            w_down: g2(b + 8),
        });
    }
    let fnorm_i = 1 + cfg.n_layers * 9;
    Weights {
        embed: g2(0),
        layers,
        final_norm: g1(fnorm_i),
        lm_head: cfg.untie_embeddings.then(|| g2(fnorm_i + 1)),
    }
}

/// Row-major gradient accumulators, packed to flat at the end of backward.
struct Grads {
    embed: Vec<f32>,
    layers: Vec<LayerW>,
    final_norm: Vec<f32>,
    lm_head: Option<Vec<f32>>,
}

impl Grads {
    fn zeros_like(cfg: &ModelConfig, lay: &Layout) -> Grads {
        let s = &lay.slots;
        let z1 = |i: usize| vec![0f32; s[i].size];
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let b = 1 + li * 9;
            layers.push(LayerW {
                attn_norm: z1(b),
                wq: z1(b + 1),
                wk: z1(b + 2),
                wv: z1(b + 3),
                wo: z1(b + 4),
                mlp_norm: z1(b + 5),
                w_gate: z1(b + 6),
                w_up: z1(b + 7),
                w_down: z1(b + 8),
            });
        }
        let fnorm_i = 1 + cfg.n_layers * 9;
        Grads {
            embed: z1(0),
            layers,
            final_norm: z1(fnorm_i),
            lm_head: cfg.untie_embeddings.then(|| z1(fnorm_i + 1)),
        }
    }

    /// Pack into the flat (block-major, chunk-padded) gradient vector.
    fn to_flat(&self, cfg: &ModelConfig, lay: &Layout) -> Vec<f32> {
        let s = &lay.slots;
        let mut flat = vec![0f32; lay.n_alloc];
        let p2 = |rm: &[f32], i: usize, flat: &mut [f32]| {
            pack_2d(rm, s[i].offset, s[i].shape[0], s[i].shape[1], flat)
        };
        let p1 = |rm: &[f32], i: usize, flat: &mut [f32]| {
            flat[s[i].offset..s[i].offset + s[i].size].copy_from_slice(rm)
        };
        p2(&self.embed, 0, &mut flat);
        for (li, l) in self.layers.iter().enumerate() {
            let b = 1 + li * 9;
            p1(&l.attn_norm, b, &mut flat);
            p2(&l.wq, b + 1, &mut flat);
            p2(&l.wk, b + 2, &mut flat);
            p2(&l.wv, b + 3, &mut flat);
            p2(&l.wo, b + 4, &mut flat);
            p1(&l.mlp_norm, b + 5, &mut flat);
            p2(&l.w_gate, b + 6, &mut flat);
            p2(&l.w_up, b + 7, &mut flat);
            p2(&l.w_down, b + 8, &mut flat);
        }
        let fnorm_i = 1 + cfg.n_layers * 9;
        p1(&self.final_norm, fnorm_i, &mut flat);
        if let Some(h) = &self.lm_head {
            p2(h, fnorm_i + 1, &mut flat);
        }
        flat
    }
}

// ==========================================================================
// Model blocks
// ==========================================================================

/// y = x * g / rms(x); returns 1/rms per row in `rinv`.
fn rmsnorm_fwd(x: &[f32], g: &[f32], eps: f32, d: usize, out: &mut [f32], rinv: &mut [f32]) {
    let rows = x.len() / d;
    for i in 0..rows {
        let xr = &x[i * d..(i + 1) * d];
        let ms = dot(xr, xr) / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        rinv[i] = r;
        let or = &mut out[i * d..(i + 1) * d];
        for j in 0..d {
            or[j] = xr[j] * r * g[j];
        }
    }
}

/// Backward of rmsnorm: accumulates dx into `dx_acc`, dgain into `dg_acc`.
fn rmsnorm_bwd(
    x: &[f32],
    g: &[f32],
    rinv: &[f32],
    dy: &[f32],
    d: usize,
    dx_acc: &mut [f32],
    dg_acc: &mut [f32],
) {
    let rows = x.len() / d;
    for i in 0..rows {
        let xr = &x[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        let r = rinv[i];
        // dxr_j = dy_j * g_j ; s = sum_j dxr_j x_j
        let mut s = 0f32;
        for j in 0..d {
            s += dyr[j] * g[j] * xr[j];
        }
        let coef = r * r * r * s / d as f32;
        let dxr = &mut dx_acc[i * d..(i + 1) * d];
        for j in 0..d {
            let dxg = dyr[j] * g[j];
            dxr[j] += dxg * r - xr[j] * coef;
            dg_acc[j] += dyr[j] * xr[j] * r;
        }
    }
}

/// cos/sin tables [T, dh/2].
fn rope_tables(t: usize, dh: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = vec![0f32; t * half];
    let mut sin = vec![0f32; t * half];
    for pos in 0..t {
        for e in 0..half {
            let inv = 1.0 / theta.powf((2 * e) as f64 / dh as f64);
            let ang = pos as f64 * inv;
            cos[pos * half + e] = ang.cos() as f32;
            sin[pos * half + e] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// In-place RoPE over [B, H, T, dh]; `dir` = +1 forward, -1 backward
/// (rotation by the negated angle).
fn rope_apply(
    x: &mut [f32],
    b: usize,
    h: usize,
    t: usize,
    dh: usize,
    cos: &[f32],
    sin: &[f32],
    dir: f32,
) {
    let half = dh / 2;
    for bh in 0..b * h {
        for ti in 0..t {
            let row = &mut x[(bh * t + ti) * dh..(bh * t + ti + 1) * dh];
            for e in 0..half {
                let c = cos[ti * half + e];
                let s = sin[ti * half + e] * dir;
                let x0 = row[2 * e];
                let x1 = row[2 * e + 1];
                row[2 * e] = x0 * c - x1 * s;
                row[2 * e + 1] = x0 * s + x1 * c;
            }
        }
    }
}

/// [B*T, H*dh] -> [B, H, T, dh].
fn split_heads(src: &[f32], b: usize, t: usize, h: usize, dh: usize, dst: &mut [f32]) {
    for bi in 0..b {
        for ti in 0..t {
            let s0 = (bi * t + ti) * h * dh;
            for hi in 0..h {
                let d0 = ((bi * h + hi) * t + ti) * dh;
                dst[d0..d0 + dh].copy_from_slice(&src[s0 + hi * dh..s0 + (hi + 1) * dh]);
            }
        }
    }
}

/// [B, H, T, dh] -> [B*T, H*dh].
fn merge_heads(src: &[f32], b: usize, t: usize, h: usize, dh: usize, dst: &mut [f32]) {
    for bi in 0..b {
        for ti in 0..t {
            let d0 = (bi * t + ti) * h * dh;
            for hi in 0..h {
                let s0 = ((bi * h + hi) * t + ti) * dh;
                dst[d0 + hi * dh..d0 + (hi + 1) * dh].copy_from_slice(&src[s0..s0 + dh]);
            }
        }
    }
}

/// Per-layer forward residuals kept for the backward pass.
struct LayerCache {
    x_in: Vec<f32>,    // [N, D]
    rinv1: Vec<f32>,   // [N]
    h: Vec<f32>,       // [N, D]
    q: Vec<f32>,       // [B, Hq, T, dh] (post-RoPE)
    k: Vec<f32>,       // [B, Hkv, T, dh] (post-RoPE)
    v: Vec<f32>,       // [B, Hkv, T, dh]
    att: Vec<f32>,     // [B, Hq, T, T] (zeros above the diagonal)
    aflat: Vec<f32>,   // [N, Hq*dh]
    x_mid: Vec<f32>,   // [N, D]
    rinv2: Vec<f32>,   // [N]
    h2: Vec<f32>,      // [N, D]
    gpre: Vec<f32>,    // [N, F]
    upre: Vec<f32>,    // [N, F]
}

struct FwdCache {
    layers: Vec<LayerCache>,
    x_pre_final: Vec<f32>,
    rinv_f: Vec<f32>,
    xf: Vec<f32>,
}

/// Full forward: tokens [B*T] -> logits [N, V] plus residual cache.
fn forward(cfg: &ModelConfig, w: &Weights, tokens: &[i32]) -> (Vec<f32>, FwdCache) {
    let (b, t, d, v) = (cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size);
    let (hq, hkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head);
    let (qd, kvd, f) = (hq * dh, hkv * dh, cfg.d_ff);
    let n = b * t;
    let group = hq / hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let eps = cfg.norm_eps as f32;
    let (cos, sin) = rope_tables(t, dh, cfg.rope_theta);

    // token embedding gather
    let mut x = vec![0f32; n * d];
    for i in 0..n {
        let tok = tokens[i] as usize;
        x[i * d..(i + 1) * d].copy_from_slice(&w.embed[tok * d..(tok + 1) * d]);
    }

    let mut layers = Vec::with_capacity(cfg.n_layers);
    let mut proj = vec![0f32; n * qd.max(d)]; // projection / residual scratch
    for lw in &w.layers {
        let x_in = x.clone();
        let mut h = vec![0f32; n * d];
        let mut rinv1 = vec![0f32; n];
        rmsnorm_fwd(&x, &lw.attn_norm, eps, d, &mut h, &mut rinv1);

        let mut q = vec![0f32; b * hq * t * dh];
        let mut k = vec![0f32; b * hkv * t * dh];
        let mut v_t = vec![0f32; b * hkv * t * dh];
        matmul(&h, &lw.wq, n, d, qd, &mut proj[..n * qd]);
        split_heads(&proj[..n * qd], b, t, hq, dh, &mut q);
        matmul(&h, &lw.wk, n, d, kvd, &mut proj[..n * kvd]);
        split_heads(&proj[..n * kvd], b, t, hkv, dh, &mut k);
        matmul(&h, &lw.wv, n, d, kvd, &mut proj[..n * kvd]);
        split_heads(&proj[..n * kvd], b, t, hkv, dh, &mut v_t);
        rope_apply(&mut q, b, hq, t, dh, &cos, &sin, 1.0);
        rope_apply(&mut k, b, hkv, t, dh, &cos, &sin, 1.0);

        // causal GQA attention
        let mut att = vec![0f32; b * hq * t * t];
        let mut a = vec![0f32; b * hq * t * dh];
        for bi in 0..b {
            for hi in 0..hq {
                let kv = hi / group;
                let qb = &q[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                let kb = &k[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let vb = &v_t[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let attb = &mut att[((bi * hq + hi) * t) * t..((bi * hq + hi + 1) * t) * t];
                let ab = &mut a[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                for i in 0..t {
                    let qr = &qb[i * dh..(i + 1) * dh];
                    let row = &mut attb[i * t..i * t + i + 1];
                    let mut mx = f32::NEG_INFINITY;
                    for j in 0..=i {
                        let s = dot(qr, &kb[j * dh..(j + 1) * dh]) * scale;
                        row[j] = s;
                        mx = mx.max(s);
                    }
                    let mut z = 0f32;
                    for j in 0..=i {
                        row[j] = (row[j] - mx).exp();
                        z += row[j];
                    }
                    let ar = &mut ab[i * dh..(i + 1) * dh];
                    for j in 0..=i {
                        row[j] /= z;
                        axpy(row[j], &vb[j * dh..(j + 1) * dh], ar);
                    }
                }
            }
        }
        let mut aflat = vec![0f32; n * qd];
        merge_heads(&a, b, t, hq, dh, &mut aflat);
        // x = x + aflat @ wo
        matmul(&aflat, &lw.wo, n, qd, d, &mut proj[..n * d]);
        for i in 0..n * d {
            x[i] += proj[i];
        }
        let x_mid = x.clone();

        let mut h2 = vec![0f32; n * d];
        let mut rinv2 = vec![0f32; n];
        rmsnorm_fwd(&x, &lw.mlp_norm, eps, d, &mut h2, &mut rinv2);
        let mut gpre = vec![0f32; n * f];
        let mut upre = vec![0f32; n * f];
        matmul(&h2, &lw.w_gate, n, d, f, &mut gpre);
        matmul(&h2, &lw.w_up, n, d, f, &mut upre);
        // gate = silu(gpre) * upre, reusing a scratch buffer
        let mut gate = vec![0f32; n * f];
        for i in 0..n * f {
            let z = gpre[i];
            let sg = 1.0 / (1.0 + (-z).exp());
            gate[i] = z * sg * upre[i];
        }
        matmul(&gate, &lw.w_down, n, f, d, &mut proj[..n * d]);
        for i in 0..n * d {
            x[i] += proj[i];
        }

        layers.push(LayerCache {
            x_in,
            rinv1,
            h,
            q,
            k,
            v: v_t,
            att,
            aflat,
            x_mid,
            rinv2,
            h2,
            gpre,
            upre,
        });
    }

    let x_pre_final = x.clone();
    let mut xf = vec![0f32; n * d];
    let mut rinv_f = vec![0f32; n];
    rmsnorm_fwd(&x, &w.final_norm, eps, d, &mut xf, &mut rinv_f);
    let head = w.lm_head.as_ref().unwrap_or(&w.embed);
    let mut logits = vec![0f32; n * v];
    matmul_bt(&xf, head, n, d, v, &mut logits);
    (logits, FwdCache { layers, x_pre_final, rinv_f, xf })
}

/// Per-position CE pieces from logits: (log-sum-exp, target logit).
fn ce_terms(logits: &[f32], tgt: &[i32], v: usize) -> (Vec<f32>, Vec<f32>) {
    let n = tgt.len();
    let mut lse = vec![0f32; n];
    let mut tl = vec![0f32; n];
    for i in 0..n {
        let row = &logits[i * v..(i + 1) * v];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for &l in row {
            z += (l - mx).exp();
        }
        lse[i] = z.ln() + mx;
        tl[i] = row[tgt[i] as usize];
    }
    (lse, tl)
}

/// Shared forward(+backward) entry.
///
/// `tokens`: [B, T+1] row-major; `mask`: [B, T] over target positions.
/// Returns (mean masked loss, per-sequence losses, flat grads of the mean
/// loss if requested).
fn loss_fwd_bwd(
    cfg: &ModelConfig,
    lay: &Layout,
    flat_params: &[f32],
    tokens: &[i32],
    mask: &[f32],
    want_grads: bool,
) -> (f32, Vec<f32>, Option<Vec<f32>>) {
    let (b, t, d, v) = (cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size);
    let (hq, hkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head);
    let (qd, kvd, f) = (hq * dh, hkv * dh, cfg.d_ff);
    let n = b * t;
    let group = hq / hkv;
    let scale = 1.0 / (dh as f32).sqrt();

    // split [B, T+1] into inputs and targets
    let mut inp = vec![0i32; n];
    let mut tgt = vec![0i32; n];
    for bi in 0..b {
        for ti in 0..t {
            inp[bi * t + ti] = tokens[bi * (t + 1) + ti];
            tgt[bi * t + ti] = tokens[bi * (t + 1) + ti + 1];
        }
    }
    let w = unpack_weights(cfg, lay, flat_params);
    let (logits, cache) = forward(cfg, &w, &inp);
    let (lse, tl) = ce_terms(&logits, &tgt, v);

    let msum: f64 = mask.iter().map(|&x| x as f64).sum();
    let msum = msum.max(1e-6);
    let mut total = 0f64;
    let mut per_seq = vec![0f32; b];
    for bi in 0..b {
        let mut acc = 0f64;
        let mut den = 0f64;
        for ti in 0..t {
            let i = bi * t + ti;
            let ce = (lse[i] - tl[i]) as f64;
            acc += ce * mask[i] as f64;
            den += mask[i] as f64;
        }
        total += acc;
        per_seq[bi] = (acc / den.max(1e-6)) as f32;
    }
    let loss = (total / msum) as f32;
    if !want_grads {
        return (loss, per_seq, None);
    }

    // ---- backward -------------------------------------------------------
    // dlogits of the mean masked loss: mask/msum * (softmax - onehot)
    let mut dlogits = logits; // reuse: overwritten in place
    for i in 0..n {
        let wgt = (mask[i] as f64 / msum) as f32;
        let row = &mut dlogits[i * v..(i + 1) * v];
        let l = lse[i];
        for j in 0..v {
            row[j] = (row[j] - l).exp() * wgt;
        }
        row[tgt[i] as usize] -= wgt;
    }

    let mut g = Grads::zeros_like(cfg, lay);
    let head = w.lm_head.as_ref().unwrap_or(&w.embed);
    let ghead_is_embed = w.lm_head.is_none();
    // dxf = dlogits @ head ; ghead += dlogits^T @ xf
    let mut dxf = vec![0f32; n * d];
    matmul(&dlogits, head, n, v, d, &mut dxf);
    {
        let ghead = if ghead_is_embed { &mut g.embed } else { g.lm_head.as_mut().unwrap() };
        matmul_at_add(&dlogits, &cache.xf, n, v, d, ghead);
    }
    drop(dlogits);
    let mut dx = vec![0f32; n * d];
    rmsnorm_bwd(
        &cache.x_pre_final,
        &w.final_norm,
        &cache.rinv_f,
        &dxf,
        d,
        &mut dx,
        &mut g.final_norm,
    );
    drop(dxf);

    let (cos, sin) = rope_tables(t, dh, cfg.rope_theta);
    let mut scratch_nf = vec![0f32; n * f];
    let mut scratch_nf2 = vec![0f32; n * f];
    for li in (0..cfg.n_layers).rev() {
        let lw = &w.layers[li];
        let lc = &cache.layers[li];
        let gl = &mut g.layers[li];

        // ---- MLP block: x = x_mid + (silu(gpre) * upre) @ w_down --------
        // recompute gate activations from cached pre-activations
        let mut gate = vec![0f32; n * f];
        let mut sg = vec![0f32; n * f];
        for i in 0..n * f {
            let z = lc.gpre[i];
            let s = 1.0 / (1.0 + (-z).exp());
            sg[i] = s;
            gate[i] = z * s * lc.upre[i];
        }
        // dgate = dx @ w_down^T ; g.w_down += gate^T @ dx
        let dgate = &mut scratch_nf;
        matmul_bt(&dx, &lw.w_down, n, d, f, dgate);
        matmul_at_add(&gate, &dx, n, f, d, &mut gl.w_down);
        drop(gate);
        // dgpre = dgate*upre * sg*(1 + z*(1-sg)) ; dupre = dgate*silu
        let dupre = &mut scratch_nf2;
        for i in 0..n * f {
            let z = lc.gpre[i];
            let s = sg[i];
            let dg_i = dgate[i];
            dupre[i] = dg_i * z * s;
            dgate[i] = dg_i * lc.upre[i] * s * (1.0 + z * (1.0 - s));
        }
        let dgpre = dgate;
        // weight grads + dh2
        matmul_at_add(&lc.h2, dgpre, n, d, f, &mut gl.w_gate);
        matmul_at_add(&lc.h2, dupre, n, d, f, &mut gl.w_up);
        let mut dh2 = vec![0f32; n * d];
        matmul_bt(dgpre, &lw.w_gate, n, f, d, &mut dh2);
        let mut dh2b = vec![0f32; n * d];
        matmul_bt(dupre, &lw.w_up, n, f, d, &mut dh2b);
        for i in 0..n * d {
            dh2[i] += dh2b[i];
        }
        drop(dh2b);
        // residual: dx (of x_mid) = dx + rmsnorm_bwd(dh2)
        rmsnorm_bwd(&lc.x_mid, &lw.mlp_norm, &lc.rinv2, &dh2, d, &mut dx, &mut gl.mlp_norm);
        drop(dh2);

        // ---- attention block: x_mid = x_in + aflat @ wo ------------------
        let mut daflat = vec![0f32; n * qd];
        matmul_bt(&dx, &lw.wo, n, d, qd, &mut daflat);
        matmul_at_add(&lc.aflat, &dx, n, qd, d, &mut gl.wo);
        let mut da = vec![0f32; b * hq * t * dh];
        split_heads(&daflat, b, t, hq, dh, &mut da);
        drop(daflat);

        let mut dq = vec![0f32; b * hq * t * dh];
        let mut dk = vec![0f32; b * hkv * t * dh];
        let mut dv = vec![0f32; b * hkv * t * dh];
        let mut ds_row = vec![0f32; t];
        for bi in 0..b {
            for hi in 0..hq {
                let kv = hi / group;
                let attb = &lc.att[((bi * hq + hi) * t) * t..((bi * hq + hi + 1) * t) * t];
                let dab = &da[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                let qb = &lc.q[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                let kb = &lc.k[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let vb = &lc.v[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let dqb = &mut dq[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                for i in 0..t {
                    let dar = &dab[i * dh..(i + 1) * dh];
                    let attr = &attb[i * t..i * t + i + 1];
                    // dv_j += att_ij * da_i ; datt_ij = <da_i, v_j>
                    let mut dsum = 0f32;
                    for j in 0..=i {
                        let datt = dot(dar, &vb[j * dh..(j + 1) * dh]);
                        ds_row[j] = datt;
                        dsum += datt * attr[j];
                    }
                    let dvb = &mut dv[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                    let dqr = &mut dqb[i * dh..(i + 1) * dh];
                    for j in 0..=i {
                        let a_ij = attr[j];
                        axpy(a_ij, dar, &mut dvb[j * dh..(j + 1) * dh]);
                        let ds = a_ij * (ds_row[j] - dsum) * scale;
                        axpy(ds, &kb[j * dh..(j + 1) * dh], dqr);
                        let dk0 = ((bi * hkv + kv) * t + j) * dh;
                        axpy(ds, &qb[i * dh..(i + 1) * dh], &mut dk[dk0..dk0 + dh]);
                    }
                }
            }
        }
        drop(da);
        rope_apply(&mut dq, b, hq, t, dh, &cos, &sin, -1.0);
        rope_apply(&mut dk, b, hkv, t, dh, &cos, &sin, -1.0);
        let mut dqf = vec![0f32; n * qd];
        let mut dkf = vec![0f32; n * kvd];
        let mut dvf = vec![0f32; n * kvd];
        merge_heads(&dq, b, t, hq, dh, &mut dqf);
        merge_heads(&dk, b, t, hkv, dh, &mut dkf);
        merge_heads(&dv, b, t, hkv, dh, &mut dvf);
        drop(dq);
        drop(dk);
        drop(dv);
        matmul_at_add(&lc.h, &dqf, n, d, qd, &mut gl.wq);
        matmul_at_add(&lc.h, &dkf, n, d, kvd, &mut gl.wk);
        matmul_at_add(&lc.h, &dvf, n, d, kvd, &mut gl.wv);
        let mut dh_sum = vec![0f32; n * d];
        let mut tmp = vec![0f32; n * d];
        matmul_bt(&dqf, &lw.wq, n, qd, d, &mut dh_sum);
        matmul_bt(&dkf, &lw.wk, n, kvd, d, &mut tmp);
        for i in 0..n * d {
            dh_sum[i] += tmp[i];
        }
        matmul_bt(&dvf, &lw.wv, n, kvd, d, &mut tmp);
        for i in 0..n * d {
            dh_sum[i] += tmp[i];
        }
        // residual: dx (of x_in) = dx + rmsnorm_bwd(dh_sum)
        rmsnorm_bwd(&lc.x_in, &lw.attn_norm, &lc.rinv1, &dh_sum, d, &mut dx, &mut gl.attn_norm);
    }

    // embedding gather backward
    for i in 0..n {
        let tok = inp[i] as usize;
        axpy(1.0, &dx[i * d..(i + 1) * d], &mut g.embed[tok * d..(tok + 1) * d]);
    }

    (loss, per_seq, Some(g.to_flat(cfg, lay)))
}

// ==========================================================================
// Optimizer (mirrors python/compile/optim.py)
// ==========================================================================

/// 1.0 where weight decay applies (2-D tensor positions), 0.0 elsewhere
/// (norm gains and slot padding).
fn decay_mask(lay: &Layout) -> Vec<f32> {
    let mut mask = vec![0f32; lay.n_alloc];
    for s in &lay.slots {
        if s.decay {
            mask[s.offset..s.offset + s.size].fill(1.0);
        }
    }
    mask
}

/// One bias-corrected AdamW step in place. `step` is 1-based.
fn adamw(
    cfg: &ModelConfig,
    wd_mask: &[f32],
    p: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: f32,
    lr: f32,
    clip: f32,
) {
    let clip_scale = if clip > 0.0 {
        let norm = grads.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        (clip as f64 / norm.max(1e-12)).min(1.0) as f32
    } else {
        1.0
    };
    let b1 = cfg.adam_b1 as f32;
    let b2 = cfg.adam_b2 as f32;
    let bc1 = 1.0 - (cfg.adam_b1).powf(step as f64) as f32;
    let bc2 = 1.0 - (cfg.adam_b2).powf(step as f64) as f32;
    let aeps = cfg.adam_eps as f32;
    let wd = cfg.weight_decay as f32;
    for i in 0..p.len() {
        let gi = grads[i] * clip_scale;
        m[i] = b1 * m[i] + (1.0 - b1) * gi;
        v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
        let mh = m[i] / bc1;
        let vh = v[i] / bc2;
        let upd = mh / (vh.sqrt() + aeps) + wd * wd_mask[i] * p[i];
        p[i] -= lr * upd;
    }
}

// ==========================================================================
// Public ops (called through runtime::ops)
// ==========================================================================

/// Deterministic init from a seed: N(0, init_std) for 2-D tensors with the
/// residual projections (wo, w_down) scaled 1/sqrt(2*n_layers); norm gains
/// init to 1; slot padding zero.
pub fn init_params(man: &Manifest, lay: &Layout, seed: i32) -> Vec<f32> {
    let cfg = &man.config;
    let mut rng = Rng::new((seed as u32 as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xC0DE_1417);
    let mut flat = vec![0f32; lay.n_alloc];
    let resid_scale = 1.0 / (2.0 * cfg.n_layers as f64).sqrt();
    for s in &lay.slots {
        if !s.is_2d {
            flat[s.offset..s.offset + s.size].fill(1.0);
            continue;
        }
        let std = cfg.init_std
            * if s.name.ends_with("wo") || s.name.ends_with("w_down") {
                resid_scale
            } else {
                1.0
            };
        let rm: Vec<f32> = (0..s.size).map(|_| (rng.normal() * std) as f32).collect();
        pack_2d(&rm, s.offset, s.shape[0], s.shape[1], &mut flat);
    }
    flat
}

/// One inner step: fwd/bwd + AdamW. `step` is the 1-based step index.
pub fn train_step(
    man: &Manifest,
    lay: &Layout,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step: f32,
    tokens: &[i32],
    mask: &[f32],
    lr: f32,
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
    let cfg = &man.config;
    ensure!(params.len() == lay.n_alloc, "params length mismatch");
    ensure!(m.len() == lay.n_alloc, "m length mismatch");
    ensure!(v.len() == lay.n_alloc, "v length mismatch");
    let wd_mask = decay_mask(lay);
    let (loss, _, grads) = loss_fwd_bwd(cfg, lay, params, tokens, mask, true);
    let mut p = params.to_vec();
    let mut m2 = m.to_vec();
    let mut v2 = v.to_vec();
    adamw(cfg, &wd_mask, &mut p, &grads.unwrap(), &mut m2, &mut v2, step, lr, clip);
    Ok((p, m2, v2, loss))
}

/// H fused inner steps (the compute phase). `step0` is the 0-based global
/// inner-step count before this round.
pub fn train_round(
    man: &Manifest,
    lay: &Layout,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step0: f32,
    tokens: &[i32],
    mask: &[f32],
    lrs: &[f32],
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    let cfg = &man.config;
    ensure!(params.len() == lay.n_alloc, "params length mismatch");
    ensure!(m.len() == lay.n_alloc, "m length mismatch");
    ensure!(v.len() == lay.n_alloc, "v length mismatch");
    let (b, t) = (cfg.batch_size, cfg.seq_len);
    let h = lrs.len();
    let wd_mask = decay_mask(lay);
    let mut p = params.to_vec();
    let mut m2 = m.to_vec();
    let mut v2 = v.to_vec();
    let mut losses = Vec::with_capacity(h);
    for hs in 0..h {
        let toks = &tokens[hs * b * (t + 1)..(hs + 1) * b * (t + 1)];
        let msk = &mask[hs * b * t..(hs + 1) * b * t];
        let (loss, _, grads) = loss_fwd_bwd(cfg, lay, &p, toks, msk, true);
        adamw(
            cfg,
            &wd_mask,
            &mut p,
            &grads.unwrap(),
            &mut m2,
            &mut v2,
            step0 + hs as f32 + 1.0,
            lrs[hs],
            clip,
        );
        losses.push(loss);
    }
    Ok((p, m2, v2, losses))
}

/// Mean masked loss on one [B, T+1] batch.
pub fn eval_loss(
    man: &Manifest,
    lay: &Layout,
    params: &[f32],
    tokens: &[i32],
    mask: &[f32],
) -> Result<f32> {
    let cfg = &man.config;
    ensure!(params.len() == lay.n_alloc, "params length mismatch");
    let (loss, _, _) = loss_fwd_bwd(cfg, lay, params, tokens, mask, false);
    Ok(loss)
}

/// Per-sequence masked loss (multiple-choice scoring).
pub fn loss_per_seq(
    man: &Manifest,
    lay: &Layout,
    params: &[f32],
    tokens: &[i32],
    mask: &[f32],
) -> Result<Vec<f32>> {
    let cfg = &man.config;
    ensure!(params.len() == lay.n_alloc, "params length mismatch");
    let (_, per_seq, _) = loss_fwd_bwd(cfg, lay, params, tokens, mask, false);
    Ok(per_seq)
}

/// Outer step: theta' = theta - alpha * delta (Eq. 2).
pub fn outer_step(params: &[f32], delta: &[f32], alpha: f32) -> Result<Vec<f32>> {
    ensure!(params.len() == delta.len(), "outer_step length mismatch");
    Ok(params.iter().zip(delta).map(|(p, d)| p - alpha * d).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny_manifest() -> (Manifest, Layout) {
        let man = Manifest::synthesize(presets::get("tiny").unwrap(), "native://tiny".into());
        let lay = Layout::build(&man.config);
        (man, lay)
    }

    /// Smallest config whose 2-D dims are all BLOCK multiples, with a
    /// real GQA group (2 query heads per KV head).
    fn micro_config() -> ModelConfig {
        ModelConfig {
            name: "micro".into(),
            vocab_size: 64,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            d_head: 64,
            d_ff: 64,
            seq_len: 4,
            batch_size: 2,
            inner_steps: 1,
            rope_theta: 500_000.0,
            norm_eps: 1e-5,
            // large init so gradients clear the f32 finite-difference
            // noise floor
            init_std: 0.2,
            adam_b1: 0.9,
            adam_b2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.1,
            ef_beta: 0.95,
            topk: 8,
            chunk: 64,
            untie_embeddings: false,
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Directional finite-difference check of the hand-derived
        // backward pass: for several directions d (the full gradient and
        // per-tensor masked gradients), the analytic <grad, d> must match
        // (L(p + eps d) - L(p - eps d)) / (2 eps). Catches structural
        // errors (missing RoPE/GQA/residual/norm terms) that
        // loss-decreases tests cannot see. (The same math was validated
        // against f64 finite differences to ~2e-7 relative error in the
        // prototype; f32 evaluation noise forces the looser tolerance
        // here.)
        let cfg = micro_config();
        let lay = Layout::build(&cfg);
        let man = Manifest::synthesize(cfg.clone(), "native://micro".into());
        let params = init_params(&man, &lay, 7);
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        // mixed mask exercises the masked-CE normalization
        let mask: Vec<f32> = (0..cfg.batch_size * cfg.seq_len)
            .map(|i| if i % 3 == 0 { 0.0 } else { 1.0 })
            .collect();
        let (_, _, grads) = loss_fwd_bwd(&cfg, &lay, &params, &tokens, &mask, true);
        let g = grads.unwrap();

        let loss_at = |p: &[f32]| -> f64 {
            let (l, _, _) = loss_fwd_bwd(&cfg, &lay, p, &tokens, &mask, false);
            l as f64
        };
        let check_direction = |d: &[f32], label: &str| {
            let norm = d.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!(norm > 1e-6, "degenerate direction {label}");
            let eps = 5e-3;
            let step: Vec<f32> = d.iter().map(|&x| (x as f64 / norm) as f32).collect();
            let plus: Vec<f32> =
                params.iter().zip(&step).map(|(p, s)| p + eps as f32 * s).collect();
            let minus: Vec<f32> =
                params.iter().zip(&step).map(|(p, s)| p - eps as f32 * s).collect();
            let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
            let analytic =
                g.iter().zip(&step).map(|(&gi, &si)| gi as f64 * si as f64).sum::<f64>();
            let err = (numeric - analytic).abs();
            let tol = 2e-3 + 0.03 * numeric.abs().max(analytic.abs());
            assert!(
                err < tol,
                "{label}: numeric {numeric:.6} vs analytic {analytic:.6} (err {err:.2e})"
            );
        };

        // full-gradient direction
        check_direction(&g, "full gradient");
        // per-tensor masked directions (structural coverage)
        for suffix in ["embed", "wq", "wk", "wv", "wo", "attn_norm", "w_gate", "w_down"] {
            let mut d = vec![0f32; g.len()];
            let mut hit = false;
            for s in &lay.slots {
                if s.name.ends_with(suffix) {
                    d[s.offset..s.offset + s.size]
                        .copy_from_slice(&g[s.offset..s.offset + s.size]);
                    hit = true;
                }
            }
            assert!(hit, "no slot matches {suffix}");
            check_direction(&d, suffix);
        }
    }

    #[test]
    fn init_is_deterministic_and_layout_shaped() {
        let (man, lay) = tiny_manifest();
        let a = init_params(&man, &lay, 3);
        let b = init_params(&man, &lay, 3);
        assert_eq!(a, b);
        assert_ne!(a, init_params(&man, &lay, 4));
        assert_eq!(a.len(), man.n_alloc);
        // norm gains are exactly 1.0
        let fnorm = lay.slots.iter().find(|s| s.name == "final_norm").unwrap();
        assert!(a[fnorm.offset..fnorm.offset + fnorm.size].iter().all(|&x| x == 1.0));
        // padding stays zero
        for s in &lay.slots {
            assert!(a[s.offset + s.size..s.offset + s.slot].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn block_major_roundtrip() {
        let (r, c) = (128, 192);
        let rm: Vec<f32> = (0..r * c).map(|i| i as f32).collect();
        let mut flat = vec![0f32; r * c + 64];
        pack_2d(&rm, 64, r, c, &mut flat);
        let back = unpack_2d(&flat, 64, r, c);
        assert_eq!(back, rm);
    }

    #[test]
    fn eval_loss_near_ln_v_at_init() {
        let (man, lay) = tiny_manifest();
        let cfg = &man.config;
        let params = init_params(&man, &lay, 0);
        let mut rng = Rng::new(7);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let loss = eval_loss(&man, &lay, &params, &tokens, &mask).unwrap();
        let ln_v = (cfg.vocab_size as f32).ln();
        assert!((loss - ln_v).abs() < 0.5, "init loss {loss} vs ln V {ln_v}");
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let (man, lay) = tiny_manifest();
        let cfg = &man.config;
        let n = man.n_alloc;
        let mut params = init_params(&man, &lay, 1);
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let l0 = eval_loss(&man, &lay, &params, &tokens, &mask).unwrap();
        for step in 1..=8 {
            let (p, m2, v2, _) =
                train_step(&man, &lay, &params, &m, &v, step as f32, &tokens, &mask, 3e-3, 0.0)
                    .unwrap();
            params = p;
            m = m2;
            v = v2;
        }
        let l1 = eval_loss(&man, &lay, &params, &tokens, &mask).unwrap();
        assert!(l1 < l0 - 0.3, "loss did not memorize: {l0} -> {l1}");
    }

    #[test]
    fn train_round_matches_stepwise() {
        let (man, lay) = tiny_manifest();
        let cfg = &man.config;
        let n = man.n_alloc;
        let h = 3;
        let params = init_params(&man, &lay, 2);
        let mut rng = Rng::new(9);
        let tokens: Vec<i32> = (0..h * cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; h * cfg.batch_size * cfg.seq_len];
        let lrs = vec![1e-3f32; h];
        let zeros = vec![0f32; n];
        let (pr, mr, vr, losses) =
            train_round(&man, &lay, &params, &zeros, &zeros, 0.0, &tokens, &mask, &lrs, 0.0)
                .unwrap();
        assert_eq!(losses.len(), h);
        // stepwise replay must be bit-identical
        let (mut p, mut m, mut v) = (params, vec![0f32; n], vec![0f32; n]);
        let bt = cfg.batch_size * (cfg.seq_len + 1);
        let bm = cfg.batch_size * cfg.seq_len;
        for hs in 0..h {
            let (p2, m2, v2, loss) = train_step(
                &man,
                &lay,
                &p,
                &m,
                &v,
                (hs + 1) as f32,
                &tokens[hs * bt..(hs + 1) * bt],
                &mask[hs * bm..(hs + 1) * bm],
                1e-3,
                0.0,
            )
            .unwrap();
            assert_eq!(loss, losses[hs]);
            p = p2;
            m = m2;
            v = v2;
        }
        assert_eq!(p, pr);
        assert_eq!(m, mr);
        assert_eq!(v, vr);
    }

    #[test]
    fn loss_per_seq_consistent_with_mean() {
        let (man, lay) = tiny_manifest();
        let cfg = &man.config;
        let params = init_params(&man, &lay, 5);
        let mut rng = Rng::new(11);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let per = loss_per_seq(&man, &lay, &params, &tokens, &mask).unwrap();
        assert_eq!(per.len(), cfg.batch_size);
        let mean = eval_loss(&man, &lay, &params, &tokens, &mask).unwrap();
        let per_mean: f32 = per.iter().sum::<f32>() / per.len() as f32;
        // all-ones mask: mean of per-seq means equals the global mean
        assert!((mean - per_mean).abs() < 1e-4, "{mean} vs {per_mean}");
    }

    #[test]
    fn clip_bounds_update_norm() {
        let (man, lay) = tiny_manifest();
        let cfg = &man.config;
        let n = man.n_alloc;
        let params = init_params(&man, &lay, 1);
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let zeros = vec![0f32; n];
        let tiny_clip = 1e-4f32;
        let (p_clip, ..) =
            train_step(&man, &lay, &params, &zeros, &zeros, 1.0, &tokens, &mask, 1e-3, tiny_clip)
                .unwrap();
        let (p_free, ..) =
            train_step(&man, &lay, &params, &zeros, &zeros, 1.0, &tokens, &mask, 1e-3, 0.0)
                .unwrap();
        let d_clip: f64 = p_clip
            .iter()
            .zip(&params)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let d_free: f64 = p_free
            .iter()
            .zip(&params)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(d_clip < d_free, "clipped step should move less: {d_clip} vs {d_free}");
    }

    #[test]
    fn outer_step_applies_alpha() {
        let p = vec![1.0f32, 2.0, 3.0];
        let d = vec![0.5f32, -0.5, 0.0];
        let out = outer_step(&p, &d, 2.0).unwrap();
        assert_eq!(out, vec![0.0, 3.0, 3.0]);
        assert!(outer_step(&p, &d[..2], 1.0).is_err());
    }
}
