//! Native CPU reference backend: the full model math in plain Rust.
//!
//! Implements every operation the coordinator needs — parameter init,
//! fused inner rounds (transformer forward + hand-derived backward +
//! AdamW), evaluation losses, SparseLoCo compression and the outer step —
//! over the same flat, chunk-aligned, 64x64-block-major parameter layout
//! as `python/compile` (see `config::layout`). This is what makes the
//! crate hermetic: `cargo test` exercises real training dynamics with no
//! AOT artifacts, no PJRT client and no Python on the path.
//!
//! Architecture (paper §4.1, Table 4, scaled presets): decoder-only
//! transformer with RMSNorm, GQA attention (query heads share K/V panels
//! in groups of `n_heads / n_kv_heads`), RoPE (theta = 500k), SwiGLU MLP,
//! and tied token-embedding/LM-head unless `untie_embeddings`.
//!
//! The backward pass is validated against finite differences in-repo
//! (`backward_matches_finite_differences`, directional checks on a micro
//! config; the same math was checked to ~2e-7 relative error in f64
//! during development). The optimizer matches
//! `python/compile/optim.py`: bias-corrected AdamW, decoupled weight
//! decay masked to 2-D tensors, optional global-norm clipping.
//!
//! ## Hot-path structure (see also [`super::kernels`], [`super::workspace`])
//!
//! The dense products run on the cache-blocked, rayon-parallel kernels in
//! `runtime::kernels`; those are **bit-identical** to their serial naive
//! references by construction (fixed per-element accumulation order), so
//! numerics stay deterministic: same inputs, same outputs, bit for bit,
//! at any thread count. All per-call state — unpacked weights, forward
//! residuals, backward scratch, the flat gradient — lives in a reusable
//! [`Workspace`], so steady-state `train_step`/`eval_loss` calls allocate
//! nothing beyond trivial per-sequence outputs. Coordinator-level
//! parallelism (whole peers fanned across the pool) composes with the
//! kernel-level parallelism through rayon's work stealing.

#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]

use anyhow::{ensure, Result};

use crate::config::layout::Layout;
use crate::runtime::kernels::{axpy, dot, matmul, matmul_at_add, matmul_bt};
use crate::runtime::manifest::{Manifest, ModelConfig};
use crate::runtime::workspace::{pack_2d, FwdCache, Scratch, Weights, Workspace};
use crate::util::rng::Rng;

// ==========================================================================
// Model blocks
// ==========================================================================

/// y = x * g / rms(x); returns 1/rms per row in `rinv`.
fn rmsnorm_fwd(x: &[f32], g: &[f32], eps: f32, d: usize, out: &mut [f32], rinv: &mut [f32]) {
    let rows = x.len() / d;
    for i in 0..rows {
        let xr = &x[i * d..(i + 1) * d];
        let ms = dot(xr, xr) / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        rinv[i] = r;
        let or = &mut out[i * d..(i + 1) * d];
        for j in 0..d {
            or[j] = xr[j] * r * g[j];
        }
    }
}

/// Backward of rmsnorm: accumulates dx into `dx_acc`, dgain into `dg_acc`.
fn rmsnorm_bwd(
    x: &[f32],
    g: &[f32],
    rinv: &[f32],
    dy: &[f32],
    d: usize,
    dx_acc: &mut [f32],
    dg_acc: &mut [f32],
) {
    let rows = x.len() / d;
    for i in 0..rows {
        let xr = &x[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        let r = rinv[i];
        // dxr_j = dy_j * g_j ; s = sum_j dxr_j x_j
        let mut s = 0f32;
        for j in 0..d {
            s += dyr[j] * g[j] * xr[j];
        }
        let coef = r * r * r * s / d as f32;
        let dxr = &mut dx_acc[i * d..(i + 1) * d];
        for j in 0..d {
            let dxg = dyr[j] * g[j];
            dxr[j] += dxg * r - xr[j] * coef;
            dg_acc[j] += dyr[j] * xr[j] * r;
        }
    }
}

/// In-place RoPE over [B, H, T, dh]; `dir` = +1 forward, -1 backward
/// (rotation by the negated angle). `cos`/`sin` are the workspace's
/// cached [T, dh/2] tables.
fn rope_apply(
    x: &mut [f32],
    b: usize,
    h: usize,
    t: usize,
    dh: usize,
    cos: &[f32],
    sin: &[f32],
    dir: f32,
) {
    let half = dh / 2;
    for bh in 0..b * h {
        for ti in 0..t {
            let row = &mut x[(bh * t + ti) * dh..(bh * t + ti + 1) * dh];
            for e in 0..half {
                let c = cos[ti * half + e];
                let s = sin[ti * half + e] * dir;
                let x0 = row[2 * e];
                let x1 = row[2 * e + 1];
                row[2 * e] = x0 * c - x1 * s;
                row[2 * e + 1] = x0 * s + x1 * c;
            }
        }
    }
}

/// [B*T, H*dh] -> [B, H, T, dh].
fn split_heads(src: &[f32], b: usize, t: usize, h: usize, dh: usize, dst: &mut [f32]) {
    for bi in 0..b {
        for ti in 0..t {
            let s0 = (bi * t + ti) * h * dh;
            for hi in 0..h {
                let d0 = ((bi * h + hi) * t + ti) * dh;
                dst[d0..d0 + dh].copy_from_slice(&src[s0 + hi * dh..s0 + (hi + 1) * dh]);
            }
        }
    }
}

/// [B, H, T, dh] -> [B*T, H*dh].
fn merge_heads(src: &[f32], b: usize, t: usize, h: usize, dh: usize, dst: &mut [f32]) {
    for bi in 0..b {
        for ti in 0..t {
            let d0 = (bi * t + ti) * h * dh;
            for hi in 0..h {
                let s0 = ((bi * h + hi) * t + ti) * dh;
                dst[d0 + hi * dh..d0 + (hi + 1) * dh].copy_from_slice(&src[s0..s0 + dh]);
            }
        }
    }
}

/// Full forward over the workspace buffers: reads `s.inp` ([B*T] input
/// tokens), fills `s.x` (final activations), `s.logits`, and the residual
/// cache. All buffers are overwritten (accumulating ones zeroed here).
fn forward(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut FwdCache,
    s: &mut Scratch,
    cos: &[f32],
    sin: &[f32],
) {
    let (b, t, d, v) = (cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size);
    let (hq, hkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head);
    let (qd, kvd, f) = (hq * dh, hkv * dh, cfg.d_ff);
    let n = b * t;
    let group = hq / hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let eps = cfg.norm_eps as f32;

    // token embedding gather
    for i in 0..n {
        let tok = s.inp[i] as usize;
        s.x[i * d..(i + 1) * d].copy_from_slice(&w.embed[tok * d..(tok + 1) * d]);
    }

    for (li, lw) in w.layers.iter().enumerate() {
        let lc = &mut cache.layers[li];
        lc.x_in.copy_from_slice(&s.x);
        rmsnorm_fwd(&s.x, &lw.attn_norm, eps, d, &mut lc.h, &mut lc.rinv1);

        matmul(&lc.h, &lw.wq, n, d, qd, &mut s.proj[..n * qd]);
        split_heads(&s.proj[..n * qd], b, t, hq, dh, &mut lc.q);
        matmul(&lc.h, &lw.wk, n, d, kvd, &mut s.proj[..n * kvd]);
        split_heads(&s.proj[..n * kvd], b, t, hkv, dh, &mut lc.k);
        matmul(&lc.h, &lw.wv, n, d, kvd, &mut s.proj[..n * kvd]);
        split_heads(&s.proj[..n * kvd], b, t, hkv, dh, &mut lc.v);
        rope_apply(&mut lc.q, b, hq, t, dh, cos, sin, 1.0);
        rope_apply(&mut lc.k, b, hkv, t, dh, cos, sin, 1.0);

        // causal GQA attention (s.attn_out accumulates; zero it first)
        s.attn_out.fill(0.0);
        for bi in 0..b {
            for hi in 0..hq {
                let kv = hi / group;
                let qb = &lc.q[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                let kb = &lc.k[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let vb = &lc.v[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let attb =
                    &mut lc.att[((bi * hq + hi) * t) * t..((bi * hq + hi + 1) * t) * t];
                let ab =
                    &mut s.attn_out[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                for i in 0..t {
                    let qr = &qb[i * dh..(i + 1) * dh];
                    let row = &mut attb[i * t..i * t + i + 1];
                    let mut mx = f32::NEG_INFINITY;
                    for j in 0..=i {
                        let sc = dot(qr, &kb[j * dh..(j + 1) * dh]) * scale;
                        row[j] = sc;
                        mx = mx.max(sc);
                    }
                    let mut z = 0f32;
                    for j in 0..=i {
                        row[j] = (row[j] - mx).exp();
                        z += row[j];
                    }
                    let ar = &mut ab[i * dh..(i + 1) * dh];
                    for j in 0..=i {
                        row[j] /= z;
                        axpy(row[j], &vb[j * dh..(j + 1) * dh], ar);
                    }
                }
            }
        }
        merge_heads(&s.attn_out, b, t, hq, dh, &mut lc.aflat);
        // x = x + aflat @ wo
        matmul(&lc.aflat, &lw.wo, n, qd, d, &mut s.proj[..n * d]);
        for i in 0..n * d {
            s.x[i] += s.proj[i];
        }
        lc.x_mid.copy_from_slice(&s.x);

        rmsnorm_fwd(&s.x, &lw.mlp_norm, eps, d, &mut lc.h2, &mut lc.rinv2);
        matmul(&lc.h2, &lw.w_gate, n, d, f, &mut lc.gpre);
        matmul(&lc.h2, &lw.w_up, n, d, f, &mut lc.upre);
        // gate = silu(gpre) * upre
        for i in 0..n * f {
            let z = lc.gpre[i];
            let sg = 1.0 / (1.0 + (-z).exp());
            s.gate[i] = z * sg * lc.upre[i];
        }
        matmul(&s.gate, &lw.w_down, n, f, d, &mut s.proj[..n * d]);
        for i in 0..n * d {
            s.x[i] += s.proj[i];
        }
    }

    cache.x_pre_final.copy_from_slice(&s.x);
    rmsnorm_fwd(&s.x, &w.final_norm, eps, d, &mut cache.xf, &mut cache.rinv_f);
    let head: &[f32] = w.lm_head.as_deref().unwrap_or(&w.embed);
    matmul_bt(&cache.xf, head, n, d, v, &mut s.logits);
}

/// Per-position CE pieces from logits into `lse`/`tl` buffers.
fn ce_terms(logits: &[f32], tgt: &[i32], v: usize, lse: &mut [f32], tl: &mut [f32]) {
    let n = tgt.len();
    for i in 0..n {
        let row = &logits[i * v..(i + 1) * v];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for &l in row {
            z += (l - mx).exp();
        }
        lse[i] = z.ln() + mx;
        tl[i] = row[tgt[i] as usize];
    }
}

/// Shared forward(+backward) entry over a checked-out [`Workspace`].
///
/// `tokens`: [B, T+1] row-major; `mask`: [B, T] over target positions.
/// Returns (mean masked loss, per-sequence losses); when `want_grads`,
/// the flat gradient of the mean loss is left in `ws.grads_flat`.
fn loss_fwd_bwd(
    cfg: &ModelConfig,
    lay: &Layout,
    ws: &mut Workspace,
    flat_params: &[f32],
    tokens: &[i32],
    mask: &[f32],
    want_grads: bool,
) -> (f32, Vec<f32>) {
    let (b, t, d, v) = (cfg.batch_size, cfg.seq_len, cfg.d_model, cfg.vocab_size);
    let (hq, hkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head);
    let (qd, kvd, f) = (hq * dh, hkv * dh, cfg.d_ff);
    let n = b * t;
    let group = hq / hkv;
    let scale = 1.0 / (dh as f32).sqrt();

    if want_grads {
        // Training callers mutate the params right after this pass, so
        // skip populating the params cache (it would be dead weight).
        ws.ensure_weights_uncached(cfg, lay, flat_params);
        ws.ensure_grads(cfg, lay);
    } else {
        ws.ensure_weights(cfg, lay, flat_params);
    }
    let Workspace {
        weights: w,
        grads,
        grads_flat,
        fwd: cache,
        scratch: s,
        rope_cos: cos,
        rope_sin: sin,
        ..
    } = ws;

    // split [B, T+1] into inputs and targets
    for bi in 0..b {
        for ti in 0..t {
            s.inp[bi * t + ti] = tokens[bi * (t + 1) + ti];
            s.tgt[bi * t + ti] = tokens[bi * (t + 1) + ti + 1];
        }
    }
    forward(cfg, w, cache, s, cos, sin);
    ce_terms(&s.logits, &s.tgt, v, &mut s.lse, &mut s.tl);

    let msum: f64 = mask.iter().map(|&x| x as f64).sum();
    let msum = msum.max(1e-6);
    let mut total = 0f64;
    let mut per_seq = vec![0f32; b];
    for bi in 0..b {
        let mut acc = 0f64;
        let mut den = 0f64;
        for ti in 0..t {
            let i = bi * t + ti;
            let ce = (s.lse[i] - s.tl[i]) as f64;
            acc += ce * mask[i] as f64;
            den += mask[i] as f64;
        }
        total += acc;
        per_seq[bi] = (acc / den.max(1e-6)) as f32;
    }
    let loss = (total / msum) as f32;
    if !want_grads {
        return (loss, per_seq);
    }

    // ---- backward -------------------------------------------------------
    // dlogits of the mean masked loss: mask/msum * (softmax - onehot),
    // computed in place over s.logits.
    for i in 0..n {
        let wgt = (mask[i] as f64 / msum) as f32;
        let row = &mut s.logits[i * v..(i + 1) * v];
        let l = s.lse[i];
        for j in 0..v {
            row[j] = (row[j] - l).exp() * wgt;
        }
        row[s.tgt[i] as usize] -= wgt;
    }

    let g = grads.as_mut().expect("ensure_grads ran above");
    g.zero();
    let head: &[f32] = w.lm_head.as_deref().unwrap_or(&w.embed);
    let ghead_is_embed = w.lm_head.is_none();
    // dxf = dlogits @ head ; ghead += dlogits^T @ xf
    matmul(&s.logits, head, n, v, d, &mut s.dxf);
    {
        let ghead = if ghead_is_embed { &mut g.embed } else { g.lm_head.as_mut().unwrap() };
        matmul_at_add(&s.logits, &cache.xf, n, v, d, ghead);
    }
    s.dx.fill(0.0);
    rmsnorm_bwd(
        &cache.x_pre_final,
        &w.final_norm,
        &cache.rinv_f,
        &s.dxf,
        d,
        &mut s.dx,
        &mut g.final_norm,
    );

    for li in (0..cfg.n_layers).rev() {
        let lw = &w.layers[li];
        let lc = &cache.layers[li];
        let gl = &mut g.layers[li];

        // ---- MLP block: x = x_mid + (silu(gpre) * upre) @ w_down --------
        // recompute gate activations from cached pre-activations
        for i in 0..n * f {
            let z = lc.gpre[i];
            let sg = 1.0 / (1.0 + (-z).exp());
            s.sg[i] = sg;
            s.gate[i] = z * sg * lc.upre[i];
        }
        // dgate = dx @ w_down^T ; g.w_down += gate^T @ dx
        matmul_bt(&s.dx, &lw.w_down, n, d, f, &mut s.nf1);
        matmul_at_add(&s.gate, &s.dx, n, f, d, &mut gl.w_down);
        // dgpre = dgate*upre * sg*(1 + z*(1-sg)) ; dupre = dgate*silu
        for i in 0..n * f {
            let z = lc.gpre[i];
            let sg = s.sg[i];
            let dg_i = s.nf1[i];
            s.nf2[i] = dg_i * z * sg;
            s.nf1[i] = dg_i * lc.upre[i] * sg * (1.0 + z * (1.0 - sg));
        }
        // weight grads + dh2 (nf1 = dgpre, nf2 = dupre)
        matmul_at_add(&lc.h2, &s.nf1, n, d, f, &mut gl.w_gate);
        matmul_at_add(&lc.h2, &s.nf2, n, d, f, &mut gl.w_up);
        matmul_bt(&s.nf1, &lw.w_gate, n, f, d, &mut s.dh2);
        matmul_bt(&s.nf2, &lw.w_up, n, f, d, &mut s.dh2b);
        for i in 0..n * d {
            s.dh2[i] += s.dh2b[i];
        }
        // residual: dx (of x_mid) = dx + rmsnorm_bwd(dh2)
        rmsnorm_bwd(&lc.x_mid, &lw.mlp_norm, &lc.rinv2, &s.dh2, d, &mut s.dx, &mut gl.mlp_norm);

        // ---- attention block: x_mid = x_in + aflat @ wo ------------------
        matmul_bt(&s.dx, &lw.wo, n, d, qd, &mut s.daflat);
        matmul_at_add(&lc.aflat, &s.dx, n, qd, d, &mut gl.wo);
        split_heads(&s.daflat, b, t, hq, dh, &mut s.da);

        s.dq.fill(0.0);
        s.dk.fill(0.0);
        s.dv.fill(0.0);
        for bi in 0..b {
            for hi in 0..hq {
                let kv = hi / group;
                let attb = &lc.att[((bi * hq + hi) * t) * t..((bi * hq + hi + 1) * t) * t];
                let dab = &s.da[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                let qb = &lc.q[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                let kb = &lc.k[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let vb = &lc.v[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                let dqb = &mut s.dq[((bi * hq + hi) * t) * dh..((bi * hq + hi + 1) * t) * dh];
                for i in 0..t {
                    let dar = &dab[i * dh..(i + 1) * dh];
                    let attr = &attb[i * t..i * t + i + 1];
                    // dv_j += att_ij * da_i ; datt_ij = <da_i, v_j>
                    let mut dsum = 0f32;
                    for j in 0..=i {
                        let datt = dot(dar, &vb[j * dh..(j + 1) * dh]);
                        s.ds_row[j] = datt;
                        dsum += datt * attr[j];
                    }
                    let dvb =
                        &mut s.dv[((bi * hkv + kv) * t) * dh..((bi * hkv + kv + 1) * t) * dh];
                    let dqr = &mut dqb[i * dh..(i + 1) * dh];
                    for j in 0..=i {
                        let a_ij = attr[j];
                        axpy(a_ij, dar, &mut dvb[j * dh..(j + 1) * dh]);
                        let ds = a_ij * (s.ds_row[j] - dsum) * scale;
                        axpy(ds, &kb[j * dh..(j + 1) * dh], dqr);
                        let dk0 = ((bi * hkv + kv) * t + j) * dh;
                        axpy(ds, &qb[i * dh..(i + 1) * dh], &mut s.dk[dk0..dk0 + dh]);
                    }
                }
            }
        }
        rope_apply(&mut s.dq, b, hq, t, dh, cos, sin, -1.0);
        rope_apply(&mut s.dk, b, hkv, t, dh, cos, sin, -1.0);
        merge_heads(&s.dq, b, t, hq, dh, &mut s.dqf);
        merge_heads(&s.dk, b, t, hkv, dh, &mut s.dkf);
        merge_heads(&s.dv, b, t, hkv, dh, &mut s.dvf);
        matmul_at_add(&lc.h, &s.dqf, n, d, qd, &mut gl.wq);
        matmul_at_add(&lc.h, &s.dkf, n, d, kvd, &mut gl.wk);
        matmul_at_add(&lc.h, &s.dvf, n, d, kvd, &mut gl.wv);
        matmul_bt(&s.dqf, &lw.wq, n, qd, d, &mut s.dh_sum);
        matmul_bt(&s.dkf, &lw.wk, n, kvd, d, &mut s.tmp);
        for i in 0..n * d {
            s.dh_sum[i] += s.tmp[i];
        }
        matmul_bt(&s.dvf, &lw.wv, n, kvd, d, &mut s.tmp);
        for i in 0..n * d {
            s.dh_sum[i] += s.tmp[i];
        }
        // residual: dx (of x_in) = dx + rmsnorm_bwd(dh_sum)
        rmsnorm_bwd(
            &lc.x_in,
            &lw.attn_norm,
            &lc.rinv1,
            &s.dh_sum,
            d,
            &mut s.dx,
            &mut gl.attn_norm,
        );
    }

    // embedding gather backward
    for i in 0..n {
        let tok = s.inp[i] as usize;
        axpy(1.0, &s.dx[i * d..(i + 1) * d], &mut g.embed[tok * d..(tok + 1) * d]);
    }

    g.to_flat_into(cfg, lay, grads_flat);
    (loss, per_seq)
}

// ==========================================================================
// Optimizer (mirrors python/compile/optim.py)
// ==========================================================================

/// One bias-corrected AdamW step in place. `step` is 1-based.
fn adamw(
    cfg: &ModelConfig,
    wd_mask: &[f32],
    p: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: f32,
    lr: f32,
    clip: f32,
) {
    let clip_scale = if clip > 0.0 {
        let norm = grads.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        (clip as f64 / norm.max(1e-12)).min(1.0) as f32
    } else {
        1.0
    };
    let b1 = cfg.adam_b1 as f32;
    let b2 = cfg.adam_b2 as f32;
    let bc1 = 1.0 - (cfg.adam_b1).powf(step as f64) as f32;
    let bc2 = 1.0 - (cfg.adam_b2).powf(step as f64) as f32;
    let aeps = cfg.adam_eps as f32;
    let wd = cfg.weight_decay as f32;
    for i in 0..p.len() {
        let gi = grads[i] * clip_scale;
        m[i] = b1 * m[i] + (1.0 - b1) * gi;
        v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
        let mh = m[i] / bc1;
        let vh = v[i] / bc2;
        let upd = mh / (vh.sqrt() + aeps) + wd * wd_mask[i] * p[i];
        p[i] -= lr * upd;
    }
}

// ==========================================================================
// Public ops (called through runtime::ops with an engine workspace)
// ==========================================================================

/// Deterministic init from a seed: N(0, init_std) for 2-D tensors with the
/// residual projections (wo, w_down) scaled 1/sqrt(2*n_layers); norm gains
/// init to 1; slot padding zero.
pub fn init_params(man: &Manifest, lay: &Layout, seed: i32) -> Vec<f32> {
    let cfg = &man.config;
    let mut rng = Rng::new((seed as u32 as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xC0DE_1417);
    let mut flat = vec![0f32; lay.n_alloc];
    let resid_scale = 1.0 / (2.0 * cfg.n_layers as f64).sqrt();
    for s in &lay.slots {
        if !s.is_2d {
            flat[s.offset..s.offset + s.size].fill(1.0);
            continue;
        }
        let std = cfg.init_std
            * if s.name.ends_with("wo") || s.name.ends_with("w_down") {
                resid_scale
            } else {
                1.0
            };
        let rm: Vec<f32> = (0..s.size).map(|_| (rng.normal() * std) as f32).collect();
        pack_2d(&rm, s.offset, s.shape[0], s.shape[1], &mut flat);
    }
    flat
}

/// One inner step, in place: fwd/bwd + AdamW over caller-owned state.
/// `step` is the 1-based step index. Returns the step loss.
pub fn train_step_in_place(
    man: &Manifest,
    lay: &Layout,
    ws: &mut Workspace,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    step: f32,
    tokens: &[i32],
    mask: &[f32],
    lr: f32,
    clip: f32,
) -> Result<f32> {
    let cfg = &man.config;
    ensure!(p.len() == lay.n_alloc, "params length mismatch");
    ensure!(m.len() == lay.n_alloc, "m length mismatch");
    ensure!(v.len() == lay.n_alloc, "v length mismatch");
    let (loss, _) = loss_fwd_bwd(cfg, lay, ws, p, tokens, mask, true);
    adamw(cfg, &ws.decay_mask, p, &ws.grads_flat, m, v, step, lr, clip);
    // p changed in place under the cached unpack; drop the cached copy
    // rather than paying an always-miss comparison next call.
    ws.invalidate_weights();
    Ok(loss)
}

/// One inner step: fwd/bwd + AdamW. `step` is the 1-based step index.
pub fn train_step(
    man: &Manifest,
    lay: &Layout,
    ws: &mut Workspace,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step: f32,
    tokens: &[i32],
    mask: &[f32],
    lr: f32,
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
    let mut p = params.to_vec();
    let mut m2 = m.to_vec();
    let mut v2 = v.to_vec();
    let loss =
        train_step_in_place(man, lay, ws, &mut p, &mut m2, &mut v2, step, tokens, mask, lr, clip)?;
    Ok((p, m2, v2, loss))
}

/// H fused inner steps (the compute phase), in place over caller-owned
/// replica state — the peer hot path; steady-state rounds allocate
/// nothing beyond the per-step loss vector. `step0` is the 0-based global
/// inner-step count before this round.
pub fn train_round_in_place(
    man: &Manifest,
    lay: &Layout,
    ws: &mut Workspace,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    step0: f32,
    tokens: &[i32],
    mask: &[f32],
    lrs: &[f32],
    clip: f32,
) -> Result<Vec<f32>> {
    let cfg = &man.config;
    ensure!(p.len() == lay.n_alloc, "params length mismatch");
    ensure!(m.len() == lay.n_alloc, "m length mismatch");
    ensure!(v.len() == lay.n_alloc, "v length mismatch");
    let (b, t) = (cfg.batch_size, cfg.seq_len);
    let h = lrs.len();
    let mut losses = Vec::with_capacity(h);
    for hs in 0..h {
        let toks = &tokens[hs * b * (t + 1)..(hs + 1) * b * (t + 1)];
        let msk = &mask[hs * b * t..(hs + 1) * b * t];
        let (loss, _) = loss_fwd_bwd(cfg, lay, ws, p, toks, msk, true);
        adamw(
            cfg,
            &ws.decay_mask,
            p,
            &ws.grads_flat,
            m,
            v,
            step0 + hs as f32 + 1.0,
            lrs[hs],
            clip,
        );
        ws.invalidate_weights();
        losses.push(loss);
    }
    Ok(losses)
}

/// H fused inner steps (the compute phase). `step0` is the 0-based global
/// inner-step count before this round.
pub fn train_round(
    man: &Manifest,
    lay: &Layout,
    ws: &mut Workspace,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step0: f32,
    tokens: &[i32],
    mask: &[f32],
    lrs: &[f32],
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    let mut p = params.to_vec();
    let mut m2 = m.to_vec();
    let mut v2 = v.to_vec();
    let losses = train_round_in_place(
        man, lay, ws, &mut p, &mut m2, &mut v2, step0, tokens, mask, lrs, clip,
    )?;
    Ok((p, m2, v2, losses))
}

/// Mean masked loss on one [B, T+1] batch.
pub fn eval_loss(
    man: &Manifest,
    lay: &Layout,
    ws: &mut Workspace,
    params: &[f32],
    tokens: &[i32],
    mask: &[f32],
) -> Result<f32> {
    let cfg = &man.config;
    ensure!(params.len() == lay.n_alloc, "params length mismatch");
    let (loss, _) = loss_fwd_bwd(cfg, lay, ws, params, tokens, mask, false);
    Ok(loss)
}

/// Per-sequence masked loss (multiple-choice scoring).
pub fn loss_per_seq(
    man: &Manifest,
    lay: &Layout,
    ws: &mut Workspace,
    params: &[f32],
    tokens: &[i32],
    mask: &[f32],
) -> Result<Vec<f32>> {
    let cfg = &man.config;
    ensure!(params.len() == lay.n_alloc, "params length mismatch");
    let (_, per_seq) = loss_fwd_bwd(cfg, lay, ws, params, tokens, mask, false);
    Ok(per_seq)
}

/// Outer step: theta' = theta - alpha * delta (Eq. 2).
pub fn outer_step(params: &[f32], delta: &[f32], alpha: f32) -> Result<Vec<f32>> {
    ensure!(params.len() == delta.len(), "outer_step length mismatch");
    Ok(params.iter().zip(delta).map(|(p, d)| p - alpha * d).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny_manifest() -> (Manifest, Layout) {
        let man = Manifest::synthesize(presets::get("tiny").unwrap(), "native://tiny".into());
        let lay = Layout::build(&man.config);
        (man, lay)
    }

    fn ws_for(cfg: &ModelConfig, lay: &Layout) -> Workspace {
        Workspace::new(cfg, lay)
    }

    /// Smallest config whose 2-D dims are all BLOCK multiples, with a
    /// real GQA group (2 query heads per KV head).
    fn micro_config() -> ModelConfig {
        ModelConfig {
            name: "micro".into(),
            vocab_size: 64,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            d_head: 64,
            d_ff: 64,
            seq_len: 4,
            batch_size: 2,
            inner_steps: 1,
            rope_theta: 500_000.0,
            norm_eps: 1e-5,
            // large init so gradients clear the f32 finite-difference
            // noise floor
            init_std: 0.2,
            adam_b1: 0.9,
            adam_b2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.1,
            ef_beta: 0.95,
            topk: 8,
            chunk: 64,
            untie_embeddings: false,
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Directional finite-difference check of the hand-derived
        // backward pass: for several directions d (the full gradient and
        // per-tensor masked gradients), the analytic <grad, d> must match
        // (L(p + eps d) - L(p - eps d)) / (2 eps). Catches structural
        // errors (missing RoPE/GQA/residual/norm terms) that
        // loss-decreases tests cannot see. (The same math was validated
        // against f64 finite differences to ~2e-7 relative error in the
        // prototype; f32 evaluation noise forces the looser tolerance
        // here.)
        let cfg = micro_config();
        let lay = Layout::build(&cfg);
        let man = Manifest::synthesize(cfg.clone(), "native://micro".into());
        let mut ws = ws_for(&cfg, &lay);
        let params = init_params(&man, &lay, 7);
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        // mixed mask exercises the masked-CE normalization
        let mask: Vec<f32> = (0..cfg.batch_size * cfg.seq_len)
            .map(|i| if i % 3 == 0 { 0.0 } else { 1.0 })
            .collect();
        let (_, _) = loss_fwd_bwd(&cfg, &lay, &mut ws, &params, &tokens, &mask, true);
        let g = ws.grads_flat.clone();

        let loss_at = |ws: &mut Workspace, p: &[f32]| -> f64 {
            let (l, _) = loss_fwd_bwd(&cfg, &lay, ws, p, &tokens, &mask, false);
            l as f64
        };
        let check_direction = |ws: &mut Workspace, d: &[f32], label: &str| {
            let norm = d.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!(norm > 1e-6, "degenerate direction {label}");
            let eps = 5e-3;
            let step: Vec<f32> = d.iter().map(|&x| (x as f64 / norm) as f32).collect();
            let plus: Vec<f32> =
                params.iter().zip(&step).map(|(p, s)| p + eps as f32 * s).collect();
            let minus: Vec<f32> =
                params.iter().zip(&step).map(|(p, s)| p - eps as f32 * s).collect();
            let numeric = (loss_at(ws, &plus) - loss_at(ws, &minus)) / (2.0 * eps);
            let analytic =
                g.iter().zip(&step).map(|(&gi, &si)| gi as f64 * si as f64).sum::<f64>();
            let err = (numeric - analytic).abs();
            let tol = 2e-3 + 0.03 * numeric.abs().max(analytic.abs());
            assert!(
                err < tol,
                "{label}: numeric {numeric:.6} vs analytic {analytic:.6} (err {err:.2e})"
            );
        };

        // full-gradient direction
        check_direction(&mut ws, &g, "full gradient");
        // per-tensor masked directions (structural coverage)
        for suffix in ["embed", "wq", "wk", "wv", "wo", "attn_norm", "w_gate", "w_down"] {
            let mut d = vec![0f32; g.len()];
            let mut hit = false;
            for s in &lay.slots {
                if s.name.ends_with(suffix) {
                    d[s.offset..s.offset + s.size]
                        .copy_from_slice(&g[s.offset..s.offset + s.size]);
                    hit = true;
                }
            }
            assert!(hit, "no slot matches {suffix}");
            check_direction(&mut ws, &d, suffix);
        }
    }

    #[test]
    fn init_is_deterministic_and_layout_shaped() {
        let (man, lay) = tiny_manifest();
        let a = init_params(&man, &lay, 3);
        let b = init_params(&man, &lay, 3);
        assert_eq!(a, b);
        assert_ne!(a, init_params(&man, &lay, 4));
        assert_eq!(a.len(), man.n_alloc);
        // norm gains are exactly 1.0
        let fnorm = lay.slots.iter().find(|s| s.name == "final_norm").unwrap();
        assert!(a[fnorm.offset..fnorm.offset + fnorm.size].iter().all(|&x| x == 1.0));
        // padding stays zero
        for s in &lay.slots {
            assert!(a[s.offset + s.size..s.offset + s.slot].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn eval_loss_near_ln_v_at_init() {
        let (man, lay) = tiny_manifest();
        let cfg = man.config.clone();
        let mut ws = ws_for(&cfg, &lay);
        let params = init_params(&man, &lay, 0);
        let mut rng = Rng::new(7);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let loss = eval_loss(&man, &lay, &mut ws, &params, &tokens, &mask).unwrap();
        let ln_v = (cfg.vocab_size as f32).ln();
        assert!((loss - ln_v).abs() < 0.5, "init loss {loss} vs ln V {ln_v}");
    }

    #[test]
    fn workspace_reuse_is_bit_identical_to_fresh() {
        // The packed-weights cache and scratch reuse must never change
        // results: evaluating twice through one workspace, and through a
        // fresh one, yields identical bits — also after the params change.
        let (man, lay) = tiny_manifest();
        let cfg = man.config.clone();
        let mut ws = ws_for(&cfg, &lay);
        let p1 = init_params(&man, &lay, 1);
        let p2 = init_params(&man, &lay, 2);
        let mut rng = Rng::new(13);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let a1 = eval_loss(&man, &lay, &mut ws, &p1, &tokens, &mask).unwrap();
        let a1_again = eval_loss(&man, &lay, &mut ws, &p1, &tokens, &mask).unwrap();
        let a2 = eval_loss(&man, &lay, &mut ws, &p2, &tokens, &mask).unwrap();
        let a1_back = eval_loss(&man, &lay, &mut ws, &p1, &tokens, &mask).unwrap();
        let mut fresh = ws_for(&cfg, &lay);
        let b1 = eval_loss(&man, &lay, &mut fresh, &p1, &tokens, &mask).unwrap();
        let mut fresh2 = ws_for(&cfg, &lay);
        let b2 = eval_loss(&man, &lay, &mut fresh2, &p2, &tokens, &mask).unwrap();
        assert_eq!(a1.to_bits(), b1.to_bits());
        assert_eq!(a1_again.to_bits(), b1.to_bits());
        assert_eq!(a1_back.to_bits(), b1.to_bits());
        assert_eq!(a2.to_bits(), b2.to_bits());
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let (man, lay) = tiny_manifest();
        let cfg = man.config.clone();
        let n = man.n_alloc;
        let mut ws = ws_for(&cfg, &lay);
        let mut params = init_params(&man, &lay, 1);
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let l0 = eval_loss(&man, &lay, &mut ws, &params, &tokens, &mask).unwrap();
        for step in 1..=8 {
            train_step_in_place(
                &man, &lay, &mut ws, &mut params, &mut m, &mut v, step as f32, &tokens, &mask,
                3e-3, 0.0,
            )
            .unwrap();
        }
        let l1 = eval_loss(&man, &lay, &mut ws, &params, &tokens, &mask).unwrap();
        assert!(l1 < l0 - 0.3, "loss did not memorize: {l0} -> {l1}");
    }

    #[test]
    fn train_round_matches_stepwise() {
        let (man, lay) = tiny_manifest();
        let cfg = man.config.clone();
        let n = man.n_alloc;
        let h = 3;
        let mut ws = ws_for(&cfg, &lay);
        let params = init_params(&man, &lay, 2);
        let mut rng = Rng::new(9);
        let tokens: Vec<i32> = (0..h * cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; h * cfg.batch_size * cfg.seq_len];
        let lrs = vec![1e-3f32; h];
        let zeros = vec![0f32; n];
        let (pr, mr, vr, losses) = train_round(
            &man, &lay, &mut ws, &params, &zeros, &zeros, 0.0, &tokens, &mask, &lrs, 0.0,
        )
        .unwrap();
        assert_eq!(losses.len(), h);
        // stepwise replay must be bit-identical (through the same
        // workspace and through the out-of-place wrapper alike)
        let (mut p, mut m, mut v) = (params, vec![0f32; n], vec![0f32; n]);
        let bt = cfg.batch_size * (cfg.seq_len + 1);
        let bm = cfg.batch_size * cfg.seq_len;
        for hs in 0..h {
            let (p2, m2, v2, loss) = train_step(
                &man,
                &lay,
                &mut ws,
                &p,
                &m,
                &v,
                (hs + 1) as f32,
                &tokens[hs * bt..(hs + 1) * bt],
                &mask[hs * bm..(hs + 1) * bm],
                1e-3,
                0.0,
            )
            .unwrap();
            assert_eq!(loss, losses[hs]);
            p = p2;
            m = m2;
            v = v2;
        }
        assert_eq!(p, pr);
        assert_eq!(m, mr);
        assert_eq!(v, vr);
    }

    #[test]
    fn loss_per_seq_consistent_with_mean() {
        let (man, lay) = tiny_manifest();
        let cfg = man.config.clone();
        let mut ws = ws_for(&cfg, &lay);
        let params = init_params(&man, &lay, 5);
        let mut rng = Rng::new(11);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let per = loss_per_seq(&man, &lay, &mut ws, &params, &tokens, &mask).unwrap();
        assert_eq!(per.len(), cfg.batch_size);
        let mean = eval_loss(&man, &lay, &mut ws, &params, &tokens, &mask).unwrap();
        let per_mean: f32 = per.iter().sum::<f32>() / per.len() as f32;
        // all-ones mask: mean of per-seq means equals the global mean
        assert!((mean - per_mean).abs() < 1e-4, "{mean} vs {per_mean}");
    }

    #[test]
    fn clip_bounds_update_norm() {
        let (man, lay) = tiny_manifest();
        let cfg = man.config.clone();
        let n = man.n_alloc;
        let mut ws = ws_for(&cfg, &lay);
        let params = init_params(&man, &lay, 1);
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab_size) as i32)
            .collect();
        let mask = vec![1f32; cfg.batch_size * cfg.seq_len];
        let zeros = vec![0f32; n];
        let tiny_clip = 1e-4f32;
        let (p_clip, ..) = train_step(
            &man, &lay, &mut ws, &params, &zeros, &zeros, 1.0, &tokens, &mask, 1e-3, tiny_clip,
        )
        .unwrap();
        let (p_free, ..) = train_step(
            &man, &lay, &mut ws, &params, &zeros, &zeros, 1.0, &tokens, &mask, 1e-3, 0.0,
        )
        .unwrap();
        let d_clip: f64 = p_clip
            .iter()
            .zip(&params)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let d_free: f64 = p_free
            .iter()
            .zip(&params)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(d_clip < d_free, "clipped step should move less: {d_clip} vs {d_free}");
    }

    #[test]
    fn outer_step_applies_alpha() {
        let p = vec![1.0f32, 2.0, 3.0];
        let d = vec![0.5f32, -0.5, 0.0];
        let out = outer_step(&p, &d, 2.0).unwrap();
        assert_eq!(out, vec![0.0, 3.0, 3.0]);
        assert!(outer_step(&p, &d[..2], 1.0).is_err());
    }
}
