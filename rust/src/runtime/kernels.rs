//! Dense CPU kernels for the native backend, selectable via
//! [`KernelMode`]: bit-exact serial references, cache-blocked
//! rayon-parallel kernels that are **bit-identical** to those references,
//! and explicit 8-lane SIMD microkernels written so stable rustc
//! autovectorizes them (fixed-width `[f32; LANES]` lane accumulators, no
//! dependencies, no unsafe — a `#[cfg(target_feature)]`-gated intrinsics
//! path can later slot in behind the same `*_mode` entry points).
//!
//! ## Determinism contract (two classes)
//!
//! **[`KernelMode::Reference`] and [`KernelMode::Blocked`] are
//! byte-identical on every input.** Every blocked kernel computes each
//! output element with the exact floating-point operation sequence of its
//! `*_ref` sibling: one multiply-add per k index, accumulated in strictly
//! increasing k order into a single accumulation chain. Blocking only
//! reorders *which* element is computed when (row panels across the rayon
//! pool, k/column panels for cache reuse inside a panel) — never the
//! order of additions within an element. Rust never licenses float
//! reassociation, so the optimized kernels produce byte-identical results
//! to the references on every input, regardless of thread count or
//! scheduling.
//!
//! **[`KernelMode::Simd`] is lane-accumulated**: each output element is
//! the combination of [`LANES`] partial sums — lane `l` accumulates the
//! multiply-adds whose reduction index `≡ l (mod LANES)` — folded by the
//! fixed binary tree [`tree8`]. This reassociates the additions, so SIMD
//! matmul results are **not** bit-equal to the single-chain reference;
//! they ARE bit-deterministic across thread counts, panel splits and
//! reruns, because the lane assignment and combine tree depend only on
//! the reduction length, never on scheduling. `tests/kernel_equivalence.rs`
//! pins both properties: rerun/thread-count bit-identity, and a relative
//! -error tolerance envelope against the blocked reference (the ROADMAP's
//! "tolerance pins where accumulation order does not permit" clause).
//!
//! The mode is a process-global switch ([`set_mode`]) so the round
//! engine, Gauntlet fan-out and workspace ops all flow through one
//! selection; tests and benches that need a *specific* path use the
//! `*_mode` entry points ([`matmul_mode`] et al.) and never touch the
//! global. The ambient default is [`KernelMode::Blocked`], overridable
//! for a whole process with `COVENANT_KERNEL_MODE=reference|blocked|simd`
//! (how CI runs the full suite in both default and SIMD modes) and per
//! run with the `kernel_mode` config knob (`config::run`).
//!
//! Panel sizes: row panels of `m / (4 * threads)` rows fan out across
//! rayon (disjoint `&mut` output slices, so scheduling cannot race); the
//! blocked k dimension is processed in panels of [`KC`] so the shared `b`
//! panel stays cache-resident across a task's rows; `matmul_bt` tiles
//! columns by [`JT`] so a small group of `b` rows is reused across the
//! panel's rows. The SIMD kernels tile columns by [`LANES`] and unroll
//! the reduction by [`LANES`], holding an 8x8 `[[f32; 8]; 8]` register
//! tile per column group.
//!
//! [`force_naive`] survives as a compatibility shim over the mode switch
//! (`true` = [`KernelMode::Reference`], `false` = the ambient default).

#![allow(clippy::needless_range_loop)]

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use rayon::prelude::*;

/// SIMD lane width: every lane-accumulated kernel splits its reduction
/// into this many partial sums (and tiles columns by the same width).
/// Eight f32 lanes = one AVX2 register / two NEON registers; the lane
/// structs are plain `[f32; 8]` so stable rustc autovectorizes them on
/// whatever the target offers.
pub const LANES: usize = 8;

/// k-panel size for the blocked kernels: `KC` rows of `b` (each `n`
/// floats) are streamed against a task's row panel before moving to the
/// next k range.
pub const KC: usize = 256;

/// Column tile for blocked [`matmul_bt`]: rows of the transposed operand
/// reused across a panel's rows.
pub const JT: usize = 8;

/// Below this many multiply-adds a matmul stays on the current thread —
/// rayon task overhead would dominate (covers the tiny norm/head shapes).
const PAR_MIN_MADDS: usize = 1 << 15;

/// Which kernel implementation the dense hot paths use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Naive serial references: the semantics oracle and benchmark
    /// baseline. Byte-identical to [`KernelMode::Blocked`] on every
    /// input.
    Reference,
    /// Cache-blocked, rayon row-panel-parallel kernels, byte-identical
    /// to [`KernelMode::Reference`] (single accumulation chain per
    /// output element, strictly increasing k order).
    Blocked,
    /// Explicit 8-lane SIMD microkernels: rayon-parallel like `Blocked`,
    /// lane-accumulated with the fixed [`tree8`] combine. Deterministic
    /// across threads/reruns but NOT bit-equal to the other two modes
    /// (reassociation); pinned by tolerance tests instead.
    Simd,
}

impl KernelMode {
    /// Parse a mode name (`reference` | `blocked` | `simd`,
    /// case-insensitive). `None` for anything else.
    pub fn parse(s: &str) -> Option<KernelMode> {
        match s.to_ascii_lowercase().as_str() {
            "reference" | "naive" => Some(KernelMode::Reference),
            "blocked" => Some(KernelMode::Blocked),
            "simd" => Some(KernelMode::Simd),
            _ => None,
        }
    }

    /// Canonical lower-case name (round-trips through [`KernelMode::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelMode::Reference => "reference",
            KernelMode::Blocked => "blocked",
            KernelMode::Simd => "simd",
        }
    }
}

const MODE_UNSET: u8 = u8::MAX;
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

fn mode_to_u8(m: KernelMode) -> u8 {
    match m {
        KernelMode::Reference => 0,
        KernelMode::Blocked => 1,
        KernelMode::Simd => 2,
    }
}

fn mode_from_u8(v: u8) -> KernelMode {
    match v {
        0 => KernelMode::Reference,
        2 => KernelMode::Simd,
        _ => KernelMode::Blocked,
    }
}

/// The process default: `COVENANT_KERNEL_MODE` if set (panics on an
/// unknown value — it is a CI/dev knob and a typo must not silently run
/// the wrong suite), otherwise [`KernelMode::Blocked`].
pub fn default_mode() -> KernelMode {
    static DEFAULT: OnceLock<KernelMode> = OnceLock::new();
    *DEFAULT.get_or_init(|| match std::env::var("COVENANT_KERNEL_MODE") {
        Ok(s) => KernelMode::parse(&s).unwrap_or_else(|| {
            panic!("COVENANT_KERNEL_MODE={s:?}: expected reference|blocked|simd")
        }),
        Err(_) => KernelMode::Blocked,
    })
}

/// Set the process-global kernel mode. Every mode is deterministic in
/// itself, so toggling is always *safe*; but `Simd` is not bit-equal to
/// the other two, so code comparing outputs across calls must hold the
/// mode fixed in between (the bit-equivalence tests serialize on a mutex
/// for exactly this reason).
pub fn set_mode(m: KernelMode) {
    MODE.store(mode_to_u8(m), Ordering::SeqCst);
}

/// The current process-global kernel mode (lazily initialized from
/// [`default_mode`] on first read).
pub fn mode() -> KernelMode {
    let v = MODE.load(Ordering::Relaxed);
    if v == MODE_UNSET {
        let d = default_mode();
        MODE.store(mode_to_u8(d), Ordering::SeqCst);
        return d;
    }
    mode_from_u8(v)
}

/// Compatibility shim over [`set_mode`]: route every kernel through the
/// serial naive references (`true`) or restore the ambient default
/// (`false`).
pub fn force_naive(on: bool) {
    set_mode(if on { KernelMode::Reference } else { default_mode() });
}

/// Whether the references are currently selected.
pub fn naive_forced() -> bool {
    mode() == KernelMode::Reference
}

/// Serial dot product: single accumulation chain in increasing index
/// order (the per-element order the Reference/Blocked kernels preserve).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x (autovectorizes; lanes are independent elements, so
/// vectorization never reorders an accumulation chain).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// The canonical lane combine: a fixed binary tree over [`LANES`] partial
/// sums. Every lane-accumulated kernel folds with exactly this tree, so
/// a SIMD result depends only on the input values and reduction length —
/// never on blocking, threading or call site.
#[inline]
pub fn tree8(l: &[f32; LANES]) -> f32 {
    ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]))
}

/// Lane-accumulated dot product: lane `l` sums the products of elements
/// at indices `≡ l (mod LANES)`, combined by [`tree8`]. Deterministic
/// for a given input; NOT bit-equal to the single-chain [`dot`].
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut l = [0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for i in 0..LANES {
            l[i] += xa[i] * xb[i];
        }
    }
    for (i, (&xa, &xb)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        l[i] += xa * xb;
    }
    tree8(&l)
}

/// out[i] = beta * a[i] + b[i], in [`LANES`]-wide strips. Elementwise —
/// every lane performs exactly the scalar operation on its own element,
/// so this is IEEE-exact against the scalar loop on every input (used by
/// the error-feedback combine in `sparseloco::topk`, which must stay
/// byte-identical across kernel modes).
#[inline]
pub fn scale_add_into(beta: f32, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    let mut co = out.chunks_exact_mut(LANES);
    for ((xa, xb), xo) in (&mut ca).zip(&mut cb).zip(&mut co) {
        for i in 0..LANES {
            xo[i] = beta * xa[i] + xb[i];
        }
    }
    for ((&xa, &xb), xo) in
        ca.remainder().iter().zip(cb.remainder()).zip(co.into_remainder())
    {
        *xo = beta * xa + xb;
    }
}

/// Bitwise slice equality (`f32::to_bits` per element), in [`LANES`]-wide
/// strips with an early exit — the "SIMD memcmp" the workspace
/// packed-weights cache keys on. Exact by construction: -0.0 vs +0.0 is
/// a mismatch, NaN == NaN (same payload) is a match.
#[inline]
pub fn bits_eq_f32(a: &[f32], b: &[f32]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        let mut same = true;
        for i in 0..LANES {
            same &= xa[i].to_bits() == xb[i].to_bits();
        }
        if !same {
            return false;
        }
    }
    ca.remainder()
        .iter()
        .zip(cb.remainder())
        .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Rows per rayon task: aim for ~4 tasks per thread so work-stealing can
/// balance panels of uneven cost without creating per-row task overhead.
fn rows_per_task(rows: usize) -> usize {
    let tasks = rayon::current_num_threads().max(1) * 4;
    rows.div_ceil(tasks).max(1)
}

// ==========================================================================
// Naive serial references (the former `runtime::native` kernels, kept as
// the semantics oracle for equivalence tests and the benchmark baseline)
// ==========================================================================

/// Reference: out[m,n] = a[m,p] @ b[p,n] (row-major, serial triple loop).
pub fn matmul_ref(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let or = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            axpy(av, &b[kk * n..(kk + 1) * n], or);
        }
    }
}

/// Reference: out[m,n] = a[m,p] @ b[n,p]^T (serial).
pub fn matmul_bt_ref(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), n * p);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            or[j] = dot(ar, &b[j * p..(j + 1) * p]);
        }
    }
}

/// Reference: out[p,n] += a[m,p]^T @ b[m,n] (serial).
pub fn matmul_at_add_ref(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), p * n);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let br = &b[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            axpy(av, br, &mut out[kk * n..(kk + 1) * n]);
        }
    }
}

// ==========================================================================
// Blocked / parallel kernels (bit-identical to the references)
// ==========================================================================

/// One row panel of blocked `matmul`: k-blocked so the `b` panel (`kc *
/// n` floats) is reused across the panel's rows. Per output element the
/// additions still run in strictly increasing k order (panels are visited
/// in order, and in order within a panel) — bit-identical to
/// [`matmul_ref`].
fn matmul_rows(a: &[f32], b: &[f32], p: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    out.fill(0.0);
    let mut k0 = 0;
    while k0 < p {
        let kc = KC.min(p - k0);
        for i in 0..rows {
            let ar = &a[i * p + k0..i * p + k0 + kc];
            let or = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in ar.iter().enumerate() {
                axpy(av, &b[(k0 + kk) * n..(k0 + kk + 1) * n], or);
            }
        }
        k0 += kc;
    }
}

/// One row panel of SIMD `matmul`: columns tiled by [`LANES`], the k
/// reduction unrolled by [`LANES`] into an 8x8 register tile
/// (`acc[l][j]`: lane `l` holds the partial sums of k indices `≡ l`),
/// folded per column by [`tree8`]. The lane assignment depends only on
/// `p`, so results are identical for any row-panel split.
fn matmul_rows_simd(a: &[f32], b: &[f32], p: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    let mut j0 = 0;
    while j0 < n {
        let jw = LANES.min(n - j0);
        for i in 0..rows {
            let ar = &a[i * p..(i + 1) * p];
            if jw == LANES {
                let mut acc = [[0f32; LANES]; LANES];
                let mut k = 0;
                while k + LANES <= p {
                    for l in 0..LANES {
                        let av = ar[k + l];
                        let br = &b[(k + l) * n + j0..(k + l) * n + j0 + LANES];
                        for j in 0..LANES {
                            acc[l][j] += av * br[j];
                        }
                    }
                    k += LANES;
                }
                let mut l = 0;
                while k < p {
                    let av = ar[k];
                    let br = &b[k * n + j0..k * n + j0 + LANES];
                    for j in 0..LANES {
                        acc[l][j] += av * br[j];
                    }
                    k += 1;
                    l += 1;
                }
                let or = &mut out[i * n + j0..i * n + j0 + LANES];
                for j in 0..LANES {
                    or[j] = tree8(&[
                        acc[0][j], acc[1][j], acc[2][j], acc[3][j], acc[4][j], acc[5][j],
                        acc[6][j], acc[7][j],
                    ]);
                }
            } else {
                // column tail: same lane scheme, one element at a time
                let or = &mut out[i * n + j0..i * n + j0 + jw];
                for (dj, o) in or.iter_mut().enumerate() {
                    let mut lanes = [0f32; LANES];
                    let mut l = 0;
                    for (k, &av) in ar.iter().enumerate() {
                        lanes[l] += av * b[k * n + j0 + dj];
                        l += 1;
                        if l == LANES {
                            l = 0;
                        }
                    }
                    *o = tree8(&lanes);
                }
            }
        }
        j0 += LANES;
    }
}

/// out[m,n] = a[m,p] @ b[p,n] (row-major) under an explicit mode.
/// `Reference`/`Blocked` are bit-identical; `Simd` is lane-accumulated
/// (see the module docs). Parallel over row panels above the madds
/// threshold in the non-reference modes.
pub fn matmul_mode(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    m: usize,
    p: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let rows = match mode {
        KernelMode::Reference => return matmul_ref(a, b, m, p, n, out),
        KernelMode::Blocked => matmul_rows,
        KernelMode::Simd => matmul_rows_simd,
    };
    if m * p * n < PAR_MIN_MADDS {
        return rows(a, b, p, n, out);
    }
    let rpt = rows_per_task(m);
    out.par_chunks_mut(rpt * n)
        .zip(a.par_chunks(rpt * p))
        .for_each(|(oc, ac)| rows(ac, b, p, n, oc));
}

/// out[m,n] = a[m,p] @ b[p,n] under the process-global [`mode`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    matmul_mode(mode(), a, b, m, p, n, out)
}

/// One row panel of blocked `matmul_bt`: columns tiled by [`JT`] so a
/// small group of `b` rows stays hot across the panel's rows. Each output
/// element is one serial [`dot`] — identical chain to [`matmul_bt_ref`].
fn matmul_bt_rows(a: &[f32], b: &[f32], p: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    let mut j0 = 0;
    while j0 < n {
        let jt = JT.min(n - j0);
        for i in 0..rows {
            let ar = &a[i * p..(i + 1) * p];
            let or = &mut out[i * n + j0..i * n + j0 + jt];
            for (dj, o) in or.iter_mut().enumerate() {
                *o = dot(ar, &b[(j0 + dj) * p..(j0 + dj + 1) * p]);
            }
        }
        j0 += jt;
    }
}

/// One row panel of SIMD `matmul_bt`: both operands of each output
/// element are contiguous, so each element is one [`dot8`]. Column tiling
/// as in the blocked path (pure cache reuse; per-element math unchanged).
fn matmul_bt_rows_simd(a: &[f32], b: &[f32], p: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    let mut j0 = 0;
    while j0 < n {
        let jt = JT.min(n - j0);
        for i in 0..rows {
            let ar = &a[i * p..(i + 1) * p];
            let or = &mut out[i * n + j0..i * n + j0 + jt];
            for (dj, o) in or.iter_mut().enumerate() {
                *o = dot8(ar, &b[(j0 + dj) * p..(j0 + dj + 1) * p]);
            }
        }
        j0 += jt;
    }
}

/// out[m,n] = a[m,p] @ b[n,p]^T — `b` row-major [n,p] (logits through the
/// tied embedding, `dx` through transposed weights) — under an explicit
/// mode. Parallel over row panels in the non-reference modes.
pub fn matmul_bt_mode(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    m: usize,
    p: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), n * p);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let rows = match mode {
        KernelMode::Reference => return matmul_bt_ref(a, b, m, p, n, out),
        KernelMode::Blocked => matmul_bt_rows,
        KernelMode::Simd => matmul_bt_rows_simd,
    };
    if m * p * n < PAR_MIN_MADDS {
        return rows(a, b, p, n, out);
    }
    let rpt = rows_per_task(m);
    out.par_chunks_mut(rpt * n)
        .zip(a.par_chunks(rpt * p))
        .for_each(|(oc, ac)| rows(ac, b, p, n, oc));
}

/// out[m,n] = a[m,p] @ b[n,p]^T under the process-global [`mode`].
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    matmul_bt_mode(mode(), a, b, m, p, n, out)
}

/// One output row panel of blocked `matmul_at_add` (rows `kk0..kk0+krows`
/// of the p-dimension): walks all m rows of `a`/`b` in order, so per
/// output element the additions run in increasing i order exactly as in
/// [`matmul_at_add_ref`].
fn matmul_at_add_rows(
    a: &[f32],
    b: &[f32],
    m: usize,
    p: usize,
    n: usize,
    kk0: usize,
    oc: &mut [f32],
) {
    let krows = oc.len() / n;
    for i in 0..m {
        let br = &b[i * n..(i + 1) * n];
        let ar = &a[i * p + kk0..i * p + kk0 + krows];
        for (kk, &av) in ar.iter().enumerate() {
            axpy(av, br, &mut oc[kk * n..(kk + 1) * n]);
        }
    }
}

/// One output row panel of SIMD `matmul_at_add`: per output row, columns
/// tiled by [`LANES`] with the i reduction unrolled into the 8x8 lane
/// tile (lane `l` holds i indices `≡ l`), tree-folded and then added
/// once onto the existing accumulator value. Lane assignment depends
/// only on `m`, so results are identical for any panel split.
fn matmul_at_add_rows_simd(
    a: &[f32],
    b: &[f32],
    m: usize,
    p: usize,
    n: usize,
    kk0: usize,
    oc: &mut [f32],
) {
    let krows = oc.len() / n;
    for kk in 0..krows {
        let col = kk0 + kk;
        let or = &mut oc[kk * n..(kk + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let jw = LANES.min(n - j0);
            if jw == LANES {
                let mut acc = [[0f32; LANES]; LANES];
                let mut i = 0;
                while i + LANES <= m {
                    for l in 0..LANES {
                        let av = a[(i + l) * p + col];
                        let br = &b[(i + l) * n + j0..(i + l) * n + j0 + LANES];
                        for j in 0..LANES {
                            acc[l][j] += av * br[j];
                        }
                    }
                    i += LANES;
                }
                let mut l = 0;
                while i < m {
                    let av = a[i * p + col];
                    let br = &b[i * n + j0..i * n + j0 + LANES];
                    for j in 0..LANES {
                        acc[l][j] += av * br[j];
                    }
                    i += 1;
                    l += 1;
                }
                for j in 0..LANES {
                    or[j0 + j] += tree8(&[
                        acc[0][j], acc[1][j], acc[2][j], acc[3][j], acc[4][j], acc[5][j],
                        acc[6][j], acc[7][j],
                    ]);
                }
            } else {
                for dj in 0..jw {
                    let mut lanes = [0f32; LANES];
                    let mut l = 0;
                    for i in 0..m {
                        lanes[l] += a[i * p + col] * b[i * n + j0 + dj];
                        l += 1;
                        if l == LANES {
                            l = 0;
                        }
                    }
                    or[j0 + dj] += tree8(&lanes);
                }
            }
            j0 += LANES;
        }
    }
}

/// out[p,n] += a[m,p]^T @ b[m,n] (weight gradients) under an explicit
/// mode. Parallelized over *output* row panels (the p dimension): each
/// task owns a disjoint `out[kk0..kk0+krows]` range.
pub fn matmul_at_add_mode(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    m: usize,
    p: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), p * n);
    if m == 0 || p == 0 || n == 0 {
        return;
    }
    let rows = match mode {
        KernelMode::Reference => return matmul_at_add_ref(a, b, m, p, n, out),
        KernelMode::Blocked => matmul_at_add_rows,
        KernelMode::Simd => matmul_at_add_rows_simd,
    };
    if m * p * n < PAR_MIN_MADDS {
        return rows(a, b, m, p, n, 0, out);
    }
    let rpt = rows_per_task(p);
    out.par_chunks_mut(rpt * n)
        .enumerate()
        .for_each(|(ci, oc)| rows(a, b, m, p, n, ci * rpt, oc));
}

/// out[p,n] += a[m,p]^T @ b[m,n] under the process-global [`mode`].
pub fn matmul_at_add(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    matmul_at_add_mode(mode(), a, b, m, p, n, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        bits_eq_f32(a, b)
    }

    fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| {
                let d = (x as f64 - y as f64).abs();
                d / (x.abs() as f64).max(y.abs() as f64).max(1e-6)
            })
            .fold(0.0, f64::max)
    }

    /// Odd shapes plus sizes straddling the KC / JT / LANES / row-panel
    /// boundaries.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 7),
        (4, 64, 8),
        (5, 255, 9),
        (8, 256, 16),
        (9, 257, 7),
        (17, 96, 33),
        (2, 512, 65),
        (33, 320, 128),
    ];

    /// Tolerance for the Simd-vs-Blocked comparison: reassociating a
    /// length-p f32 reduction into 8 lanes perturbs each output element
    /// by a few ulps per accumulation step; 1e-4 relative is orders of
    /// magnitude above what the unit-normal test inputs produce while
    /// still catching any structural error (wrong lane, wrong tree,
    /// dropped tail). The same pin (looser, end-to-end) guards
    /// `tests/kernel_equivalence.rs`.
    const SIMD_REL_TOL: f64 = 1e-4;

    #[test]
    fn matmul_matches_reference_bitwise() {
        let mut rng = Rng::new(11);
        for &(m, p, n) in SHAPES {
            let a = randv(&mut rng, m * p);
            let b = randv(&mut rng, p * n);
            let mut want = vec![0f32; m * n];
            matmul_ref(&a, &b, m, p, n, &mut want);
            let mut got = vec![7f32; m * n]; // must be fully overwritten
            matmul_mode(KernelMode::Blocked, &a, &b, m, p, n, &mut got);
            assert!(bits_eq(&want, &got), "matmul mismatch at {m}x{p}x{n}");
        }
    }

    #[test]
    fn matmul_bt_matches_reference_bitwise() {
        let mut rng = Rng::new(12);
        for &(m, p, n) in SHAPES {
            let a = randv(&mut rng, m * p);
            let b = randv(&mut rng, n * p);
            let mut want = vec![0f32; m * n];
            matmul_bt_ref(&a, &b, m, p, n, &mut want);
            let mut got = vec![7f32; m * n];
            matmul_bt_mode(KernelMode::Blocked, &a, &b, m, p, n, &mut got);
            assert!(bits_eq(&want, &got), "matmul_bt mismatch at {m}x{p}x{n}");
        }
    }

    #[test]
    fn matmul_at_add_matches_reference_bitwise() {
        let mut rng = Rng::new(13);
        for &(m, p, n) in SHAPES {
            let a = randv(&mut rng, m * p);
            let b = randv(&mut rng, m * n);
            // nonzero initial accumulator: the += semantics must agree too
            let init = randv(&mut rng, p * n);
            let mut want = init.clone();
            matmul_at_add_ref(&a, &b, m, p, n, &mut want);
            let mut got = init;
            matmul_at_add_mode(KernelMode::Blocked, &a, &b, m, p, n, &mut got);
            assert!(bits_eq(&want, &got), "matmul_at_add mismatch at {m}x{p}x{n}");
        }
    }

    #[test]
    fn simd_kernels_within_tolerance_of_blocked() {
        let mut rng = Rng::new(15);
        for &(m, p, n) in SHAPES {
            let a = randv(&mut rng, m * p);
            let b = randv(&mut rng, p * n);
            let bt = randv(&mut rng, n * p);
            let bn = randv(&mut rng, m * n);
            let init = randv(&mut rng, p * n);

            let mut blocked = vec![0f32; m * n];
            matmul_mode(KernelMode::Blocked, &a, &b, m, p, n, &mut blocked);
            let mut simd = vec![7f32; m * n];
            matmul_mode(KernelMode::Simd, &a, &b, m, p, n, &mut simd);
            let e = max_rel_err(&blocked, &simd);
            assert!(e < SIMD_REL_TOL, "matmul simd err {e:.2e} at {m}x{p}x{n}");

            matmul_bt_mode(KernelMode::Blocked, &a, &bt, m, p, n, &mut blocked);
            matmul_bt_mode(KernelMode::Simd, &a, &bt, m, p, n, &mut simd);
            let e = max_rel_err(&blocked, &simd);
            assert!(e < SIMD_REL_TOL, "matmul_bt simd err {e:.2e} at {m}x{p}x{n}");

            let mut blocked = init.clone();
            matmul_at_add_mode(KernelMode::Blocked, &a, &bn, m, p, n, &mut blocked);
            let mut simd = init.clone();
            matmul_at_add_mode(KernelMode::Simd, &a, &bn, m, p, n, &mut simd);
            let e = max_rel_err(&blocked, &simd);
            assert!(e < SIMD_REL_TOL, "matmul_at_add simd err {e:.2e} at {m}x{p}x{n}");
        }
    }

    #[test]
    fn simd_matmul_bit_identical_across_panel_splits_and_reruns() {
        // The lane assignment depends only on the reduction length, so
        // the serial small-shape path and the rayon row-panel path must
        // agree bitwise, and reruns must reproduce exactly.
        let mut rng = Rng::new(16);
        let (m, p, n) = (33, 320, 65); // above the parallel threshold
        let a = randv(&mut rng, m * p);
        let b = randv(&mut rng, p * n);
        let mut par = vec![0f32; m * n];
        matmul_mode(KernelMode::Simd, &a, &b, m, p, n, &mut par);
        // serial single-panel path on the same input
        let mut ser = vec![0f32; m * n];
        matmul_rows_simd(&a, &b, p, n, &mut ser);
        assert!(bits_eq(&par, &ser), "simd panel split changed bits");
        for _ in 0..3 {
            let mut again = vec![0f32; m * n];
            matmul_mode(KernelMode::Simd, &a, &b, m, p, n, &mut again);
            assert!(bits_eq(&par, &again), "simd rerun changed bits");
        }
    }

    #[test]
    fn dot8_matches_scalar_within_tolerance_and_is_deterministic() {
        let mut rng = Rng::new(17);
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let a = randv(&mut rng, len);
            let b = randv(&mut rng, len);
            let s = dot(&a, &b) as f64;
            let v = dot8(&a, &b) as f64;
            assert!(
                (s - v).abs() <= 1e-4 * s.abs().max(v.abs()).max(1.0),
                "len {len}: {s} vs {v}"
            );
            assert_eq!(dot8(&a, &b).to_bits(), dot8(&a, &b).to_bits());
        }
    }

    #[test]
    fn scale_add_into_is_elementwise_exact() {
        let mut rng = Rng::new(18);
        for len in [0usize, 1, 7, 8, 9, 100] {
            let a = randv(&mut rng, len);
            let b = randv(&mut rng, len);
            let mut got = vec![0f32; len];
            scale_add_into(0.95, &a, &b, &mut got);
            let want: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| 0.95 * x + y).collect();
            assert!(bits_eq(&want, &got), "len {len}");
        }
    }

    #[test]
    fn bits_eq_f32_is_bitwise() {
        let a = vec![1.0f32, -0.0, f32::NAN, 3.5, 0.0, 1.0, 2.0, 3.0, 4.0];
        let mut b = a.clone();
        assert!(bits_eq_f32(&a, &b), "identical bits must match (incl. NaN)");
        b[1] = 0.0; // -0.0 vs +0.0
        assert!(!bits_eq_f32(&a, &b), "-0.0 vs +0.0 must mismatch");
        assert!(!bits_eq_f32(&a, &a[..8]), "length mismatch");
    }

    #[test]
    fn mode_parse_round_trips() {
        for m in [KernelMode::Reference, KernelMode::Blocked, KernelMode::Simd] {
            assert_eq!(KernelMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(KernelMode::parse("SIMD"), Some(KernelMode::Simd));
        assert_eq!(KernelMode::parse("naive"), Some(KernelMode::Reference));
        assert_eq!(KernelMode::parse("avx512"), None);
    }

    #[test]
    fn repeated_runs_are_deterministic() {
        // Same inputs, many runs across the pool: identical bits each
        // time, in every mode.
        let mut rng = Rng::new(14);
        let (m, p, n) = (33, 320, 65);
        let a = randv(&mut rng, m * p);
        let b = randv(&mut rng, p * n);
        for mode in [KernelMode::Reference, KernelMode::Blocked, KernelMode::Simd] {
            let mut first = vec![0f32; m * n];
            matmul_mode(mode, &a, &b, m, p, n, &mut first);
            for _ in 0..5 {
                let mut again = vec![0f32; m * n];
                matmul_mode(mode, &a, &b, m, p, n, &mut again);
                assert!(bits_eq(&first, &again), "{mode:?}");
            }
        }
    }
}
