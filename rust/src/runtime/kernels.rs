//! Dense CPU kernels for the native backend: cache-blocked, rayon-parallel
//! matrix products that are **bit-identical** to the naive serial
//! references they replace.
//!
//! ## Determinism contract
//!
//! Every kernel in this module computes each output element with the exact
//! floating-point operation sequence of its `*_ref` sibling: one
//! multiply-add per k index, accumulated in strictly increasing k order
//! into a single accumulation chain. Blocking only reorders *which*
//! element is computed when (row panels across the rayon pool, k/column
//! panels for cache reuse inside a panel) — never the order of additions
//! within an element. Rust never licenses float reassociation, so the
//! optimized kernels produce byte-identical results to the references on
//! every input, regardless of thread count or scheduling. The
//! `kernel_equivalence` integration test and the unit tests below assert
//! this on odd shapes and panel-boundary sizes.
//!
//! Panel sizes: row panels of `m / (4 * threads)` rows fan out across
//! rayon (disjoint `&mut` output slices, so scheduling cannot race); the
//! k dimension is processed in panels of [`KC`] so the shared `b` panel
//! stays cache-resident across a task's rows; `matmul_bt` tiles columns by
//! [`JT`] so a small group of `b` rows is reused across the panel's rows.
//!
//! [`force_naive`] routes every call through the serial references — used
//! by `benches/hotpath.rs` to measure the blocked/parallel speedup against
//! the pre-optimization baseline on the same host, inside one process.
//! Because both paths are bit-identical, toggling it is always safe.

#![allow(clippy::needless_range_loop)]

use std::sync::atomic::{AtomicBool, Ordering};

use rayon::prelude::*;

/// k-panel size: `KC` rows of `b` (each `n` floats) are streamed against a
/// task's row panel before moving to the next k range.
pub const KC: usize = 256;

/// Column tile for [`matmul_bt`]: rows of the transposed operand reused
/// across a panel's rows.
pub const JT: usize = 8;

/// Below this many multiply-adds a matmul stays on the current thread —
/// rayon task overhead would dominate (covers the tiny norm/head shapes).
const PAR_MIN_MADDS: usize = 1 << 15;

static FORCE_NAIVE: AtomicBool = AtomicBool::new(false);

/// Route all kernels through the serial naive references (benchmark
/// baseline). Safe to toggle at any time: both paths are bit-identical.
pub fn force_naive(on: bool) {
    FORCE_NAIVE.store(on, Ordering::SeqCst);
}

/// Whether [`force_naive`] is currently set.
pub fn naive_forced() -> bool {
    FORCE_NAIVE.load(Ordering::SeqCst)
}

/// Serial dot product: single accumulation chain in increasing index
/// order (the per-element order every kernel here preserves).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x (autovectorizes; lanes are independent elements, so
/// vectorization never reorders an accumulation chain).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Rows per rayon task: aim for ~4 tasks per thread so work-stealing can
/// balance panels of uneven cost without creating per-row task overhead.
fn rows_per_task(rows: usize) -> usize {
    let tasks = rayon::current_num_threads().max(1) * 4;
    rows.div_ceil(tasks).max(1)
}

// ==========================================================================
// Naive serial references (the former `runtime::native` kernels, kept as
// the semantics oracle for equivalence tests and the benchmark baseline)
// ==========================================================================

/// Reference: out[m,n] = a[m,p] @ b[p,n] (row-major, serial triple loop).
pub fn matmul_ref(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let or = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            axpy(av, &b[kk * n..(kk + 1) * n], or);
        }
    }
}

/// Reference: out[m,n] = a[m,p] @ b[n,p]^T (serial).
pub fn matmul_bt_ref(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), n * p);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            or[j] = dot(ar, &b[j * p..(j + 1) * p]);
        }
    }
}

/// Reference: out[p,n] += a[m,p]^T @ b[m,n] (serial).
pub fn matmul_at_add_ref(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), p * n);
    for i in 0..m {
        let ar = &a[i * p..(i + 1) * p];
        let br = &b[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            axpy(av, br, &mut out[kk * n..(kk + 1) * n]);
        }
    }
}

// ==========================================================================
// Blocked / parallel kernels (bit-identical to the references)
// ==========================================================================

/// One row panel of `matmul`: k-blocked so the `b` panel (`kc * n`
/// floats) is reused across the panel's rows. Per output element the
/// additions still run in strictly increasing k order (panels are visited
/// in order, and in order within a panel) — bit-identical to
/// [`matmul_ref`].
fn matmul_rows(a: &[f32], b: &[f32], p: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    out.fill(0.0);
    let mut k0 = 0;
    while k0 < p {
        let kc = KC.min(p - k0);
        for i in 0..rows {
            let ar = &a[i * p + k0..i * p + k0 + kc];
            let or = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in ar.iter().enumerate() {
                axpy(av, &b[(k0 + kk) * n..(k0 + kk + 1) * n], or);
            }
        }
        k0 += kc;
    }
}

/// out[m,n] = a[m,p] @ b[p,n] (row-major) — cache-blocked, parallel over
/// row panels, bit-identical to [`matmul_ref`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if naive_forced() {
        return matmul_ref(a, b, m, p, n, out);
    }
    if m * p * n < PAR_MIN_MADDS {
        return matmul_rows(a, b, p, n, out);
    }
    let rpt = rows_per_task(m);
    out.par_chunks_mut(rpt * n)
        .zip(a.par_chunks(rpt * p))
        .for_each(|(oc, ac)| matmul_rows(ac, b, p, n, oc));
}

/// One row panel of `matmul_bt`: columns tiled by [`JT`] so a small group
/// of `b` rows stays hot across the panel's rows. Each output element is
/// one serial [`dot`] — identical chain to [`matmul_bt_ref`].
fn matmul_bt_rows(a: &[f32], b: &[f32], p: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    let mut j0 = 0;
    while j0 < n {
        let jt = JT.min(n - j0);
        for i in 0..rows {
            let ar = &a[i * p..(i + 1) * p];
            let or = &mut out[i * n + j0..i * n + j0 + jt];
            for (dj, o) in or.iter_mut().enumerate() {
                *o = dot(ar, &b[(j0 + dj) * p..(j0 + dj + 1) * p]);
            }
        }
        j0 += jt;
    }
}

/// out[m,n] = a[m,p] @ b[n,p]^T — `b` row-major [n,p] (logits through the
/// tied embedding, `dx` through transposed weights). Parallel over row
/// panels, bit-identical to [`matmul_bt_ref`].
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), n * p);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if naive_forced() {
        return matmul_bt_ref(a, b, m, p, n, out);
    }
    if m * p * n < PAR_MIN_MADDS {
        return matmul_bt_rows(a, b, p, n, out);
    }
    let rpt = rows_per_task(m);
    out.par_chunks_mut(rpt * n)
        .zip(a.par_chunks(rpt * p))
        .for_each(|(oc, ac)| matmul_bt_rows(ac, b, p, n, oc));
}

/// out[p,n] += a[m,p]^T @ b[m,n] (weight gradients). Parallelized over
/// *output* row panels (the p dimension): each task owns a disjoint
/// `out[kk0..kk0+krows]` range and walks all m rows of `a`/`b` in order,
/// so per output element the additions run in increasing i order exactly
/// as in [`matmul_at_add_ref`].
pub fn matmul_at_add(a: &[f32], b: &[f32], m: usize, p: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * p);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), p * n);
    if m == 0 || p == 0 || n == 0 {
        return;
    }
    if naive_forced() || m * p * n < PAR_MIN_MADDS {
        return matmul_at_add_ref(a, b, m, p, n, out);
    }
    let rpt = rows_per_task(p);
    out.par_chunks_mut(rpt * n).enumerate().for_each(|(ci, oc)| {
        let kk0 = ci * rpt;
        let krows = oc.len() / n;
        for i in 0..m {
            let br = &b[i * n..(i + 1) * n];
            let ar = &a[i * p + kk0..i * p + kk0 + krows];
            for (kk, &av) in ar.iter().enumerate() {
                axpy(av, br, &mut oc[kk * n..(kk + 1) * n]);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    /// Odd shapes plus sizes straddling the KC / JT / row-panel
    /// boundaries.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 7),
        (4, 64, 8),
        (5, 255, 9),
        (8, 256, 16),
        (9, 257, 7),
        (17, 96, 33),
        (2, 512, 65),
        (33, 320, 128),
    ];

    #[test]
    fn matmul_matches_reference_bitwise() {
        let mut rng = Rng::new(11);
        for &(m, p, n) in SHAPES {
            let a = randv(&mut rng, m * p);
            let b = randv(&mut rng, p * n);
            let mut want = vec![0f32; m * n];
            matmul_ref(&a, &b, m, p, n, &mut want);
            let mut got = vec![7f32; m * n]; // must be fully overwritten
            matmul(&a, &b, m, p, n, &mut got);
            assert!(bits_eq(&want, &got), "matmul mismatch at {m}x{p}x{n}");
        }
    }

    #[test]
    fn matmul_bt_matches_reference_bitwise() {
        let mut rng = Rng::new(12);
        for &(m, p, n) in SHAPES {
            let a = randv(&mut rng, m * p);
            let b = randv(&mut rng, n * p);
            let mut want = vec![0f32; m * n];
            matmul_bt_ref(&a, &b, m, p, n, &mut want);
            let mut got = vec![7f32; m * n];
            matmul_bt(&a, &b, m, p, n, &mut got);
            assert!(bits_eq(&want, &got), "matmul_bt mismatch at {m}x{p}x{n}");
        }
    }

    #[test]
    fn matmul_at_add_matches_reference_bitwise() {
        let mut rng = Rng::new(13);
        for &(m, p, n) in SHAPES {
            let a = randv(&mut rng, m * p);
            let b = randv(&mut rng, m * n);
            // nonzero initial accumulator: the += semantics must agree too
            let init = randv(&mut rng, p * n);
            let mut want = init.clone();
            matmul_at_add_ref(&a, &b, m, p, n, &mut want);
            let mut got = init;
            matmul_at_add(&a, &b, m, p, n, &mut got);
            assert!(bits_eq(&want, &got), "matmul_at_add mismatch at {m}x{p}x{n}");
        }
    }

    #[test]
    fn repeated_runs_are_deterministic() {
        // Same inputs, many runs across the pool: identical bits each time.
        let mut rng = Rng::new(14);
        let (m, p, n) = (33, 320, 65);
        let a = randv(&mut rng, m * p);
        let b = randv(&mut rng, p * n);
        let mut first = vec![0f32; m * n];
        matmul(&a, &b, m, p, n, &mut first);
        for _ in 0..5 {
            let mut again = vec![0f32; m * n];
            matmul(&a, &b, m, p, n, &mut again);
            assert!(bits_eq(&first, &again));
        }
    }
}
