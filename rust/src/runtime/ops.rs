//! Typed wrappers over the AOT artifacts: each paper operation (init,
//! inner round, compression, outer step, evaluation) as a plain Rust
//! function over host vectors. This is the entire L3<->L2 surface.

use anyhow::{ensure, Result};

use super::engine::Engine;
use super::literal::{f32_tensor, f32_vec, i32_tensor, scalar_f32, scalar_i32, to_f32, to_i32, to_scalar_f32};
use crate::sparseloco::Payload;

/// Initialize a flat parameter vector from a seed.
pub fn init_params(eng: &Engine, seed: i32) -> Result<Vec<f32>> {
    let outs = eng.run("init_params", &[scalar_i32(seed)])?;
    to_f32(&outs[0])
}

/// One inner step. Returns (params', m', v', loss).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    eng: &Engine,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step: f32,
    tokens: &[i32],
    mask: &[f32],
    lr: f32,
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    let outs = eng.run(
        "train_step",
        &[
            f32_vec(params),
            f32_vec(m),
            f32_vec(v),
            scalar_f32(step),
            i32_tensor(tokens, &[b, t + 1])?,
            f32_tensor(mask, &[b, t])?,
            scalar_f32(lr),
            scalar_f32(clip),
        ],
    )?;
    Ok((to_f32(&outs[0])?, to_f32(&outs[1])?, to_f32(&outs[2])?, to_scalar_f32(&outs[3])?))
}

/// H fused inner steps (the compute phase). Returns (params', m', v',
/// per-step losses).
#[allow(clippy::too_many_arguments)]
pub fn train_round(
    eng: &Engine,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step0: f32,
    tokens: &[i32],
    mask: &[f32],
    lrs: &[f32],
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    let c = &eng.manifest().config;
    let (h, b, t) = (c.inner_steps, c.batch_size, c.seq_len);
    ensure!(lrs.len() == h, "lrs must have H={h} entries");
    ensure!(tokens.len() == h * b * (t + 1), "tokens shape mismatch");
    let outs = eng.run(
        "train_round",
        &[
            f32_vec(params),
            f32_vec(m),
            f32_vec(v),
            scalar_f32(step0),
            i32_tensor(tokens, &[h, b, t + 1])?,
            f32_tensor(mask, &[h, b, t])?,
            f32_tensor(lrs, &[h])?,
            scalar_f32(clip),
        ],
    )?;
    Ok((to_f32(&outs[0])?, to_f32(&outs[1])?, to_f32(&outs[2])?, to_f32(&outs[3])?))
}

/// SparseLoCo compression with error feedback (Eq. 1).
/// Returns (new_ef, payload).
pub fn compress(
    eng: &Engine,
    delta: &[f32],
    ef: &[f32],
    beta: f32,
) -> Result<(Vec<f32>, Payload)> {
    let man = eng.manifest();
    let outs = eng.run(
        "compress",
        &[f32_vec(delta), f32_vec(ef), scalar_f32(beta)],
    )?;
    let ef_new = to_f32(&outs[0])?;
    let idx = to_i32(&outs[1])?;
    let codes = to_i32(&outs[2])?;
    let scales = to_f32(&outs[3])?;
    let payload =
        Payload::from_parts(&idx, &codes, &scales, man.config.topk, man.config.chunk)?;
    Ok((ef_new, payload))
}

/// Decompress a payload through the XLA artifact (validation path; the
/// hot path uses `Payload::accumulate_into` in pure Rust).
pub fn decompress_xla(eng: &Engine, p: &Payload) -> Result<Vec<f32>> {
    let nc = p.n_chunks;
    let k = p.k;
    let idx: Vec<i32> = p.idx.iter().map(|&x| x as i32).collect();
    let codes: Vec<i32> = p.codes.iter().map(|&x| x as i32).collect();
    let outs = eng.run(
        "decompress",
        &[
            i32_tensor(&idx, &[nc, k])?,
            i32_tensor(&codes, &[nc, k])?,
            f32_tensor(&p.scales, &[nc, 1])?,
        ],
    )?;
    to_f32(&outs[0])
}

/// Outer step theta' = theta - alpha * delta (Eq. 2).
pub fn outer_step(eng: &Engine, params: &[f32], delta: &[f32], alpha: f32) -> Result<Vec<f32>> {
    let outs = eng.run(
        "outer_step",
        &[f32_vec(params), f32_vec(delta), scalar_f32(alpha)],
    )?;
    to_f32(&outs[0])
}

/// Mean masked loss on one batch.
pub fn eval_loss(eng: &Engine, params: &[f32], tokens: &[i32], mask: &[f32]) -> Result<f32> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    let outs = eng.run(
        "eval_loss",
        &[
            f32_vec(params),
            i32_tensor(tokens, &[b, t + 1])?,
            f32_tensor(mask, &[b, t])?,
        ],
    )?;
    to_scalar_f32(&outs[0])
}

/// Per-sequence masked loss (multiple-choice scoring).
pub fn loss_per_seq(eng: &Engine, params: &[f32], tokens: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    let outs = eng.run(
        "loss_per_seq",
        &[
            f32_vec(params),
            i32_tensor(tokens, &[b, t + 1])?,
            f32_tensor(mask, &[b, t])?,
        ],
    )?;
    to_f32(&outs[0])
}
