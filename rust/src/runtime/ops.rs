//! Typed model operations: each paper operation (init, inner round,
//! compression, outer step, evaluation) as a plain Rust function over host
//! vectors. This is the entire coordinator <-> model surface; everything
//! below it is the native backend in [`super::native`].
//!
//! All functions validate shapes against the engine's manifest, time
//! themselves into `Engine::exec_stats`, and are deterministic — the
//! parallel round engine and the fan-out Gauntlet validator depend on
//! byte-identical results regardless of which thread runs an op. Every
//! model op checks a [`Workspace`] out of the engine's pool
//! (`Engine::with_workspace`), so token/mask splitting, weight unpacking
//! and gradient packing reuse long-lived buffers instead of allocating
//! per call; the in-place variants ([`train_round_in_place`]) additionally
//! update caller-owned replica state without cloning it.
//!
//! [`Workspace`]: super::workspace::Workspace

use std::time::Instant;

use anyhow::{ensure, Result};

use super::engine::Engine;
use super::native;
use crate::sparseloco::{topk, Payload};

/// Initialize a flat parameter vector from a seed.
pub fn init_params(eng: &Engine, seed: i32) -> Result<Vec<f32>> {
    let t0 = Instant::now();
    let out = native::init_params(eng.manifest(), eng.layout(), seed);
    eng.note("init_params", t0);
    Ok(out)
}

/// One inner step. `step` is the 1-based step index (drives Adam bias
/// correction). Returns (params', m', v', loss).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    eng: &Engine,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step: f32,
    tokens: &[i32],
    mask: &[f32],
    lr: f32,
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    ensure!(tokens.len() == b * (t + 1), "tokens shape mismatch");
    ensure!(mask.len() == b * t, "mask shape mismatch");
    let t0 = Instant::now();
    let out = eng.with_workspace(|ws| {
        native::train_step(
            eng.manifest(),
            eng.layout(),
            ws,
            params,
            m,
            v,
            step,
            tokens,
            mask,
            lr,
            clip,
        )
    })?;
    eng.note("train_step", t0);
    Ok(out)
}

/// One inner step updating caller-owned state in place (no params/m/v
/// cloning). Bit-identical to [`train_step`]. Returns the loss.
#[allow(clippy::too_many_arguments)]
pub fn train_step_in_place(
    eng: &Engine,
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    step: f32,
    tokens: &[i32],
    mask: &[f32],
    lr: f32,
    clip: f32,
) -> Result<f32> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    ensure!(tokens.len() == b * (t + 1), "tokens shape mismatch");
    ensure!(mask.len() == b * t, "mask shape mismatch");
    let t0 = Instant::now();
    let out = eng.with_workspace(|ws| {
        native::train_step_in_place(
            eng.manifest(),
            eng.layout(),
            ws,
            params,
            m,
            v,
            step,
            tokens,
            mask,
            lr,
            clip,
        )
    })?;
    eng.note("train_step", t0);
    Ok(out)
}

/// H fused inner steps (the compute phase). `step0` is the 0-based global
/// inner-step count before this round. Returns (params', m', v',
/// per-step losses).
#[allow(clippy::too_many_arguments)]
pub fn train_round(
    eng: &Engine,
    params: &[f32],
    m: &[f32],
    v: &[f32],
    step0: f32,
    tokens: &[i32],
    mask: &[f32],
    lrs: &[f32],
    clip: f32,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    let c = &eng.manifest().config;
    let (h, b, t) = (c.inner_steps, c.batch_size, c.seq_len);
    ensure!(lrs.len() == h, "lrs must have H={h} entries");
    ensure!(tokens.len() == h * b * (t + 1), "tokens shape mismatch");
    ensure!(mask.len() == h * b * t, "mask shape mismatch");
    let t0 = Instant::now();
    let out = eng.with_workspace(|ws| {
        native::train_round(
            eng.manifest(),
            eng.layout(),
            ws,
            params,
            m,
            v,
            step0,
            tokens,
            mask,
            lrs,
            clip,
        )
    })?;
    eng.note("train_round", t0);
    Ok(out)
}

/// H fused inner steps updating caller-owned replica state in place (the
/// peer hot path: no params/m/v cloning). Bit-identical to
/// [`train_round`]. Returns per-step losses.
#[allow(clippy::too_many_arguments)]
pub fn train_round_in_place(
    eng: &Engine,
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    step0: f32,
    tokens: &[i32],
    mask: &[f32],
    lrs: &[f32],
    clip: f32,
) -> Result<Vec<f32>> {
    let c = &eng.manifest().config;
    let (h, b, t) = (c.inner_steps, c.batch_size, c.seq_len);
    ensure!(lrs.len() == h, "lrs must have H={h} entries");
    ensure!(tokens.len() == h * b * (t + 1), "tokens shape mismatch");
    ensure!(mask.len() == h * b * t, "mask shape mismatch");
    let t0 = Instant::now();
    let out = eng.with_workspace(|ws| {
        native::train_round_in_place(
            eng.manifest(),
            eng.layout(),
            ws,
            params,
            m,
            v,
            step0,
            tokens,
            mask,
            lrs,
            clip,
        )
    })?;
    eng.note("train_round", t0);
    Ok(out)
}

/// SparseLoCo compression with error feedback (Eq. 1):
/// acc = beta*ef + delta; payload = TopK+Q(acc); ef' = acc - dequant.
/// Returns (new_ef, payload).
pub fn compress(
    eng: &Engine,
    delta: &[f32],
    ef: &[f32],
    beta: f32,
) -> Result<(Vec<f32>, Payload)> {
    let man = eng.manifest();
    ensure!(delta.len() == man.n_alloc, "delta length mismatch");
    ensure!(ef.len() == man.n_alloc, "ef length mismatch");
    let t0 = Instant::now();
    let (payload, ef_new) =
        topk::compress_with_ef(delta, ef, beta, man.config.chunk, man.config.topk);
    eng.note("compress", t0);
    Ok((ef_new, payload))
}

/// Decompress a payload to its dense vector (validation path; the hot
/// path uses `Payload::accumulate_into` directly).
pub fn decompress(eng: &Engine, p: &Payload) -> Result<Vec<f32>> {
    let t0 = Instant::now();
    let out = p.to_dense();
    eng.note("decompress", t0);
    Ok(out)
}

/// Outer step theta' = theta - alpha * delta (Eq. 2).
pub fn outer_step(eng: &Engine, params: &[f32], delta: &[f32], alpha: f32) -> Result<Vec<f32>> {
    let t0 = Instant::now();
    let out = native::outer_step(params, delta, alpha)?;
    eng.note("outer_step", t0);
    Ok(out)
}

/// Mean masked loss on one batch.
pub fn eval_loss(eng: &Engine, params: &[f32], tokens: &[i32], mask: &[f32]) -> Result<f32> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    ensure!(tokens.len() == b * (t + 1), "tokens shape mismatch");
    ensure!(mask.len() == b * t, "mask shape mismatch");
    let t0 = Instant::now();
    let out = eng.with_workspace(|ws| {
        native::eval_loss(eng.manifest(), eng.layout(), ws, params, tokens, mask)
    })?;
    eng.note("eval_loss", t0);
    Ok(out)
}

/// Mean masked loss for several batches against one parameter vector,
/// through a **single** workspace checkout: the packed-weights unpack
/// happens once for the whole set, however many batches there are and
/// however many other candidates are being evaluated concurrently on
/// the shared pool (per-batch checkouts would let interleaved pops hand
/// each batch a workspace caching a different candidate). This is the
/// validator's `mean_loss` hot path.
pub fn eval_loss_many(
    eng: &Engine,
    params: &[f32],
    batches: &[(Vec<i32>, Vec<f32>)],
) -> Result<Vec<f32>> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    for (tokens, mask) in batches {
        ensure!(tokens.len() == b * (t + 1), "tokens shape mismatch");
        ensure!(mask.len() == b * t, "mask shape mismatch");
    }
    let t0 = Instant::now();
    let out = eng.with_workspace(|ws| {
        batches
            .iter()
            .map(|(tokens, mask)| {
                native::eval_loss(eng.manifest(), eng.layout(), ws, params, tokens, mask)
            })
            .collect::<Result<Vec<f32>>>()
    })?;
    eng.note("eval_loss", t0);
    Ok(out)
}

/// Per-sequence masked loss (multiple-choice scoring).
pub fn loss_per_seq(
    eng: &Engine,
    params: &[f32],
    tokens: &[i32],
    mask: &[f32],
) -> Result<Vec<f32>> {
    let c = &eng.manifest().config;
    let (b, t) = (c.batch_size, c.seq_len);
    ensure!(tokens.len() == b * (t + 1), "tokens shape mismatch");
    ensure!(mask.len() == b * t, "mask shape mismatch");
    let t0 = Instant::now();
    let out = eng.with_workspace(|ws| {
        native::loss_per_seq(eng.manifest(), eng.layout(), ws, params, tokens, mask)
    })?;
    eng.note("loss_per_seq", t0);
    Ok(out)
}
